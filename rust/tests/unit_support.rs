//! Edge-case tests for the support layers (table rendering, JSON corners,
//! CLI corners, tensors, search degenerate inputs) — no artifacts needed.

use mpq::coordinator::{EvalResult, SearchAlgo, SearchEnv};
use mpq::quant::QuantConfig;
use mpq::report::Table;
use mpq::runtime::HostTensor;
use mpq::util::cli::Args;
use mpq::util::json::{self, Value};

struct AlwaysPass;

impl SearchEnv for AlwaysPass {
    fn num_layers(&self) -> usize {
        0
    }
    fn eval(&mut self, _c: &QuantConfig, _t: Option<f64>) -> mpq::Result<EvalResult> {
        Ok(EvalResult { loss: 0.0, accuracy: 1.0, exact: true })
    }
}

#[test]
fn searches_handle_zero_layers() {
    for algo in [SearchAlgo::Greedy, SearchAlgo::Bisection] {
        let out = algo.run(&mut AlwaysPass, &[], &[8.0, 4.0], 0.99).unwrap();
        assert_eq!(out.config.num_layers(), 0);
        assert_eq!(out.accuracy, 1.0);
    }
}

#[test]
fn searches_handle_empty_bit_list() {
    struct One;
    impl SearchEnv for One {
        fn num_layers(&self) -> usize {
            1
        }
        fn eval(&mut self, _c: &QuantConfig, _t: Option<f64>) -> mpq::Result<EvalResult> {
            Ok(EvalResult { loss: 0.0, accuracy: 1.0, exact: true })
        }
    }
    for algo in [SearchAlgo::Greedy, SearchAlgo::Bisection] {
        let out = algo.run(&mut One, &[0], &[], 0.5).unwrap();
        assert_eq!(out.config, QuantConfig::float(1));
    }
}

#[test]
#[should_panic(expected = "ordering must cover")]
fn greedy_rejects_partial_ordering() {
    struct Two;
    impl SearchEnv for Two {
        fn num_layers(&self) -> usize {
            2
        }
        fn eval(&mut self, _c: &QuantConfig, _t: Option<f64>) -> mpq::Result<EvalResult> {
            Ok(EvalResult { loss: 0.0, accuracy: 1.0, exact: true })
        }
    }
    let _ = SearchAlgo::Greedy.run(&mut Two, &[0], &[8.0], 0.5);
}

#[test]
fn table_renders_empty_and_wide() {
    let t = Table::new("empty", &["a"]);
    let r = t.render();
    assert!(r.contains("empty"));
    let mut w = Table::new("wide", &["col", "very-long-header-name"]);
    w.push_row(vec!["x".into(), "y".into()]);
    let r = w.render();
    // Every data row must be exactly as wide as the header row.
    let lines: Vec<&str> = r.lines().collect();
    assert_eq!(lines[1].len(), lines[2].len());
    assert_eq!(lines[2].len(), lines[4].len());
}

#[test]
fn json_numbers_edge_cases() {
    assert_eq!(json::parse("1e20").unwrap().as_f64().unwrap(), 1e20);
    assert_eq!(json::parse("-0.0").unwrap().as_f64().unwrap(), 0.0);
    assert!(json::parse("0.1").unwrap().as_usize().is_err());
    assert!(json::parse("-3").unwrap().as_usize().is_err());
    assert_eq!(json::parse("-3").unwrap().as_i64().unwrap(), -3);
    // Large integers survive the write path unquoted.
    assert_eq!(Value::Num(9e15).to_string(), "9e15".parse::<f64>().unwrap().to_string());
}

#[test]
fn json_deep_nesting_roundtrip() {
    let mut v = Value::Num(1.0);
    for _ in 0..64 {
        v = Value::Arr(vec![v]);
    }
    let text = v.to_string();
    assert_eq!(json::parse(&text).unwrap(), v);
}

#[test]
fn cli_last_duplicate_wins_and_types_checked() {
    let a = Args::parse(["x".into(), "--k".into(), "1".into(), "--k".into(), "2".into()]).unwrap();
    assert_eq!(a.req::<u32>("k").unwrap(), 2);
    assert!(a.req::<u32>("missing").is_err());
    let b = Args::parse(["x".into(), "--n".into(), "abc".into()]).unwrap();
    assert!(b.req::<u32>("n").is_err());
}

#[test]
fn host_tensor_roundtrip_shapes() {
    let t = HostTensor::f32(vec![0.0; 24], vec![2, 3, 4]);
    assert_eq!(t.numel(), 24);
    let s = t.slice_rows(1, 1);
    assert_eq!(s.dims(), &[1, 3, 4]);
    assert_eq!(s.numel(), 12);
}

#[test]
fn quant_config_weight_only_views() {
    let mut c = QuantConfig::uniform(3, 4.0);
    c.bits_a = vec![16.0; 3];
    assert_eq!(c.layer_bits(0), 4.0); // layer_bits reads the weight width
    assert_eq!(c.count_at(4.0), 3);
    assert_eq!(c.avg_bits_w(), 4.0);
}

#[test]
fn eval_result_semantics() {
    // exact=false results still carry a decision-valid accuracy bound.
    let r = EvalResult { loss: 1.0, accuracy: 0.97, exact: false };
    assert!(r.accuracy < 0.99);
}
