//! Data-plane integration tests: config-keyed dispatch (batches never mix
//! configs), zero-copy batch assembly parity against the reference copy
//! path, drain-free config swaps under load, and multi-tenant serving
//! from frontier picks — all over stub backends, so no artifacts or PJRT
//! device is needed.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::Duration;

use mpq::api::{build_frontier_synthetic, parse_tenants};
use mpq::coordinator::SearchAlgo;
use mpq::quant::QuantConfig;
use mpq::runtime::{BatchArena, HostTensor, TensorData};
use mpq::server::{
    pad_batch, serve_multi_with_backend, BatchJob, InferOptions, ServeOptions, ServingBackend,
};
use mpq::util::rng::Rng;

/// Stub worker pool: each worker is a plain thread applying `f` to every
/// job. Dropping blocks until in-flight batches finish — the drain
/// contract [`ServingBackend`] requires.
struct StubBackend {
    txs: Vec<mpsc::Sender<BatchJob>>,
    joins: Vec<thread::JoinHandle<()>>,
    sizes: Vec<usize>,
}

impl StubBackend {
    fn new<F>(workers: usize, sizes: &[usize], f: F) -> Self
    where
        F: Fn(&BatchJob) -> Vec<f32> + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let mut txs = Vec::new();
        let mut joins = Vec::new();
        for _ in 0..workers {
            let (tx, rx) = mpsc::channel::<BatchJob>();
            let f = f.clone();
            joins.push(thread::spawn(move || {
                while let Ok(job) = rx.recv() {
                    let flat = f(&job);
                    job.complete(Ok(flat));
                }
            }));
            txs.push(tx);
        }
        Self { txs, joins, sizes: sizes.to_vec() }
    }
}

impl ServingBackend for StubBackend {
    fn num_workers(&self) -> usize {
        self.txs.len()
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.sizes.to_vec()
    }

    fn submit(&mut self, w: usize, job: BatchJob) {
        if let Err(mpsc::SendError(job)) = self.txs[w].send(job) {
            job.complete(Err(anyhow::anyhow!("stub worker gone")));
        }
    }
}

impl Drop for StubBackend {
    fn drop(&mut self) {
        self.txs.clear();
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

fn example(v: f32) -> HostTensor {
    HostTensor::f32(vec![v], vec![1, 1])
}

/// Join with a watchdog so a drain bug fails the test instead of hanging
/// the whole suite.
fn join_within(join: thread::JoinHandle<()>, secs: u64) {
    let (tx, rx) = mpsc::channel();
    thread::spawn(move || {
        let ok = join.join().is_ok();
        let _ = tx.send(ok);
    });
    let ok = rx
        .recv_timeout(Duration::from_secs(secs))
        .expect("dispatcher join did not return after shutdown");
    assert!(ok, "dispatcher panicked");
}

#[test]
fn mixed_config_admissions_never_co_batch() {
    // Every payload encodes its config id as floor(x / 1000): a batch
    // mixing configs would surface a row whose prefix disagrees with the
    // job's config id.
    let violations = Arc::new(AtomicUsize::new(0));
    let v = violations.clone();
    let backend = StubBackend::new(2, &[8], move |job: &BatchJob| {
        let mut flat = vec![0.0f32; job.bucket()];
        for (i, x) in job.xs().iter().enumerate() {
            let val = x.f32_data().unwrap()[0];
            if (val / 1000.0).floor() as u32 != job.config_id() {
                v.fetch_add(1, Ordering::Relaxed);
            }
            flat[i] = val + 0.25;
        }
        flat
    });
    let configs =
        vec![QuantConfig::float(2), QuantConfig::uniform(2, 8.0), QuantConfig::uniform(2, 4.0)];
    let opts = ServeOptions {
        max_batch: 8,
        max_wait: Duration::from_micros(500),
        queue_depth: 1024,
        ..ServeOptions::default()
    };
    let (handle, join) = serve_multi_with_backend(backend, configs, &opts).unwrap();

    // Interleave the three configs in a seeded-random admission order
    // from several client threads at once.
    thread::scope(|s| {
        for t in 0..4u64 {
            let handle = handle.clone();
            s.spawn(move || {
                let mut rng = Rng::seed_from(0xDA7A + t);
                for i in 0..50u32 {
                    let config = rng.below(3) as u32;
                    let val = (config * 1000 + i) as f32;
                    let opts = InferOptions { config: Some(config), ..InferOptions::default() };
                    let out = handle.infer_with(example(val), &opts).expect("infer failed");
                    assert_eq!(out, vec![val + 0.25]);
                }
            });
        }
    });

    let stats = handle.stats();
    assert_eq!(stats.requests, 200);
    assert_eq!(stats.errors, 0);
    assert_eq!(violations.load(Ordering::Relaxed), 0, "a batch mixed serving configs");
    assert_eq!(stats.per_config.len(), 3, "all three configs saw traffic");
    assert_eq!(stats.per_config.iter().map(|c| c.requests).sum::<usize>(), 200);

    handle.shutdown();
    join_within(join, 10);
}

#[test]
fn zero_copy_assembly_matches_copy_path_in_flight() {
    // For every batch the engine actually forms (whatever its size and
    // fill), the arena's zero-copy assembly must be byte-identical to the
    // reference `pad_batch` copy path — at 1, 2, and 8 workers.
    for workers in [1usize, 2, 8] {
        let mismatches = Arc::new(AtomicUsize::new(0));
        let m = mismatches.clone();
        let backend = StubBackend::new(workers, &[1, 2, 4, 8], move |job: &BatchJob| {
            let padded = pad_batch(job.xs(), &[1], job.bucket());
            let mut arena = BatchArena::new();
            let view = arena.assemble(job.xs(), &[1], job.bucket());
            let reference = padded.f32_data().unwrap();
            let zero_copy: &[f32] = match view.data() {
                TensorData::F32(d) => d,
                TensorData::I32(_) => &[],
            };
            let identical = view.dims() == padded.dims()
                && reference.len() == zero_copy.len()
                && reference.iter().zip(zero_copy).all(|(a, b)| a.to_bits() == b.to_bits());
            if !identical {
                m.fetch_add(1, Ordering::Relaxed);
            }
            zero_copy.iter().map(|v| v * 2.0 + 1.0).collect()
        });
        let opts = ServeOptions {
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            queue_depth: 1024,
            ..ServeOptions::default()
        };
        let (handle, join) =
            serve_multi_with_backend(backend, vec![QuantConfig::float(1)], &opts).unwrap();

        thread::scope(|s| {
            for t in 0..4i32 {
                let handle = handle.clone();
                s.spawn(move || {
                    for i in 0..25i32 {
                        let val = (t * 100 + i) as f32;
                        let out = handle.infer(example(val)).expect("infer failed");
                        assert_eq!(out, vec![val * 2.0 + 1.0], "workers={workers}");
                    }
                });
            }
        });

        let stats = handle.stats();
        assert_eq!(stats.requests, 100, "workers={workers}");
        assert_eq!(stats.errors, 0, "workers={workers}");
        assert_eq!(mismatches.load(Ordering::Relaxed), 0, "workers={workers}: assembly diverged");

        handle.shutdown();
        join_within(join, 10);
    }
}

#[test]
fn config_swap_under_load_drops_nothing() {
    // Stub output = x * bits_w[0], so every response reveals which
    // configuration its batch executed under.
    let backend = StubBackend::new(2, &[4], |job: &BatchJob| {
        let scale = job.config().bits_w[0];
        let mut flat = vec![0.0f32; job.bucket()];
        for (i, x) in job.xs().iter().enumerate() {
            flat[i] = x.f32_data().unwrap()[0] * scale;
        }
        flat
    });
    let opts = ServeOptions {
        max_batch: 4,
        max_wait: Duration::from_micros(200),
        queue_depth: 4096,
        ..ServeOptions::default()
    };
    let (handle, join) =
        serve_multi_with_backend(backend, vec![QuantConfig::uniform(3, 8.0)], &opts).unwrap();

    let answered = AtomicUsize::new(0);
    let wrong = AtomicUsize::new(0);
    thread::scope(|s| {
        for t in 0..4i32 {
            let handle = handle.clone();
            let (answered, wrong) = (&answered, &wrong);
            s.spawn(move || {
                for i in 0..100i32 {
                    let v = (t * 1000 + i) as f32 + 1.0;
                    let out = handle.infer(example(v)).expect("swap must not drop requests");
                    if out != vec![v * 8.0] && out != vec![v * 4.0] {
                        wrong.fetch_add(1, Ordering::Relaxed);
                    }
                    answered.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
        // Swap mid-stream: batches already dispatched finish on 8-bit,
        // later admissions resolve 4-bit. No drain, no drops.
        thread::sleep(Duration::from_millis(5));
        handle.swap_config(0, QuantConfig::uniform(3, 4.0)).unwrap();
    });
    assert_eq!(answered.into_inner(), 400, "every admitted request must be answered");
    assert_eq!(wrong.into_inner(), 0, "a response matched neither the old nor the new config");

    // Requests admitted after the swap observe the new config only.
    for i in 0..8i32 {
        let v = i as f32 + 0.5;
        assert_eq!(handle.infer(example(v)).unwrap(), vec![v * 4.0]);
    }
    let stats = handle.stats();
    assert_eq!(stats.requests, 408);
    assert_eq!(stats.errors, 0);
    assert_eq!(stats.rejected, 0);

    handle.shutdown();
    join_within(join, 10);
}

#[test]
fn tenant_picks_serve_from_one_engine() {
    // Build a synthetic frontier, resolve one pick per tenant, and serve
    // both picked configs from a single engine with per-tenant routing.
    let report = build_frontier_synthetic(
        20,
        7,
        1,
        SearchAlgo::Greedy,
        &[0.9, 0.97, 0.99],
        None,
        false,
        None,
        None,
    )
    .unwrap();
    let artifact = report.artifact;
    let tenants = parse_tenants("gold:latency<=1.0;bronze:latency<=0.7").unwrap();
    let configs: Vec<QuantConfig> =
        tenants.iter().map(|t| artifact.pick(&t.pick).unwrap().config.clone()).collect();
    let expect: Vec<u64> = configs.iter().map(QuantConfig::key).collect();

    // The worker sees, per batch, the exact config the tenant's pick
    // resolved — routing by id must never cross tenants.
    let mismatched = Arc::new(AtomicUsize::new(0));
    let m = mismatched.clone();
    let backend = StubBackend::new(2, &[4], move |job: &BatchJob| {
        if expect[job.config_id() as usize] != job.config().key() {
            m.fetch_add(1, Ordering::Relaxed);
        }
        vec![job.config_id() as f32 + 0.5; job.bucket()]
    });
    let opts = ServeOptions {
        max_batch: 4,
        max_wait: Duration::from_micros(200),
        queue_depth: 1024,
        ..ServeOptions::default()
    };
    let (handle, join) = serve_multi_with_backend(backend, configs, &opts).unwrap();
    assert_eq!(handle.num_configs(), 2);

    thread::scope(|s| {
        for tenant in 0..2u32 {
            let handle = handle.clone();
            s.spawn(move || {
                let opts = InferOptions { config: Some(tenant), ..InferOptions::default() };
                for i in 0..40 {
                    let out = handle.infer_with(example(i as f32), &opts).unwrap();
                    assert_eq!(out, vec![tenant as f32 + 0.5], "tenant {tenant} mis-routed");
                }
            });
        }
    });

    assert_eq!(mismatched.load(Ordering::Relaxed), 0);
    let stats = handle.stats();
    let rows: Vec<(u32, usize)> =
        stats.per_config.iter().map(|c| (c.config, c.requests)).collect();
    assert_eq!(rows, vec![(0, 40), (1, 40)]);
    // An out-of-table id is rejected at admission, not at dispatch.
    let err = handle
        .infer_with(example(0.0), &InferOptions { config: Some(9), ..InferOptions::default() })
        .unwrap_err();
    assert!(format!("{err:#}").contains("unknown serving config"), "{err:#}");

    handle.shutdown();
    join_within(join, 10);
}
