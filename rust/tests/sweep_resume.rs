//! Kill-and-resume properties of the budget × accuracy-floor sweep: a
//! synthetic sweep aborted at a grid point and resumed from its
//! checkpoint must emit a report *byte-identical* to an uninterrupted
//! run — at 1 and 2 workers — because every cell is answered either from
//! the atomically written per-cell log or by a deterministic fresh
//! search. Mirrors what the CI `mpq report --sweep` smoke does end to
//! end through the binary.

use mpq::coordinator::SearchAlgo;
use mpq::report::{
    budget_sweep_synthetic, render_sweep, sweep_cells_json, sweep_fingerprint, BudgetKind,
    SweepCheckpoint, SweepGrid,
};

const LAYERS: usize = 20;
const SEED: u64 = 7;

fn grid() -> SweepGrid {
    SweepGrid {
        kind: BudgetKind::Latency,
        budgets: vec![0.55, 0.7, 0.9],
        floors: vec![0.9, 0.99],
    }
}

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("mpq_sweep_ck_{name}.json"))
}

fn fingerprint(g: &SweepGrid) -> String {
    let order: Vec<usize> = (0..LAYERS).collect();
    sweep_fingerprint(SearchAlgo::Greedy, g, &order, &format!("synthetic/n{LAYERS}/seed{SEED}"))
}

#[test]
fn aborted_sweep_resumes_byte_identically_at_1_and_2_workers() {
    let g = grid();
    for workers in [1usize, 2] {
        // Uninterrupted reference run (no checkpoint at all).
        let full =
            budget_sweep_synthetic(LAYERS, SEED, workers, SearchAlgo::Greedy, &g, None, None)
                .unwrap();
        assert_eq!(full.len(), 6);
        let full_json = sweep_cells_json(&full);
        let full_render = render_sweep("sweep", &g, &full).render();

        // Kill the sweep after two completed grid points.
        let path = tmp(&format!("abort_w{workers}"));
        let _ = std::fs::remove_file(&path);
        let mut ck = SweepCheckpoint::attach(&path, &fingerprint(&g), false).unwrap();
        let err = budget_sweep_synthetic(
            LAYERS,
            SEED,
            workers,
            SearchAlgo::Greedy,
            &g,
            Some(&mut ck),
            Some(2),
        )
        .unwrap_err();
        assert!(err.to_string().contains("aborted after 2"), "{err}");
        assert_eq!(ck.completed(), 2, "both finished cells must be persisted");
        drop(ck);

        // Resume: the two recorded cells are answered from the log, the
        // remaining four run fresh — and the final report byte-matches.
        let mut re = SweepCheckpoint::attach(&path, &fingerprint(&g), true).unwrap();
        assert_eq!(re.loaded(), 2);
        let resumed = budget_sweep_synthetic(
            LAYERS,
            SEED,
            workers,
            SearchAlgo::Greedy,
            &g,
            Some(&mut re),
            None,
        )
        .unwrap();
        assert_eq!(re.completed(), 6, "resume must append only the missing cells");
        assert_eq!(sweep_cells_json(&resumed), full_json, "workers {workers}: RESULT diff");
        assert_eq!(
            render_sweep("sweep", &g, &resumed).render(),
            full_render,
            "workers {workers}: rendered report diff"
        );
        let _ = std::fs::remove_file(&path);
    }
}

#[test]
fn worker_count_never_changes_the_report() {
    let g = grid();
    let w1 = budget_sweep_synthetic(LAYERS, SEED, 1, SearchAlgo::Greedy, &g, None, None).unwrap();
    let w2 = budget_sweep_synthetic(LAYERS, SEED, 2, SearchAlgo::Greedy, &g, None, None).unwrap();
    assert_eq!(sweep_cells_json(&w1), sweep_cells_json(&w2));
}

#[test]
fn resume_rejects_mismatched_or_missing_checkpoints() {
    let g = grid();
    let path = tmp("mismatch");
    let _ = std::fs::remove_file(&path);
    // Missing file cannot be resumed.
    assert!(SweepCheckpoint::attach(&path, &fingerprint(&g), true).is_err());
    // A checkpoint from a different grid is rejected loudly.
    let mut ck = SweepCheckpoint::attach(&path, &fingerprint(&g), false).unwrap();
    let _ = budget_sweep_synthetic(LAYERS, SEED, 1, SearchAlgo::Greedy, &g, Some(&mut ck), None)
        .unwrap();
    drop(ck);
    let other = SweepGrid { kind: BudgetKind::Size, ..grid() };
    let err = SweepCheckpoint::attach(&path, &fingerprint(&other), true).unwrap_err();
    assert!(err.to_string().contains("different sweep"), "{err}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn fresh_attach_truncates_a_stale_sweep_log() {
    let g = grid();
    let path = tmp("truncate");
    let _ = std::fs::remove_file(&path);
    let mut ck = SweepCheckpoint::attach(&path, &fingerprint(&g), false).unwrap();
    let _ = budget_sweep_synthetic(LAYERS, SEED, 1, SearchAlgo::Greedy, &g, Some(&mut ck), None)
        .unwrap();
    assert_eq!(ck.completed(), 6);
    drop(ck);
    let fresh = SweepCheckpoint::attach(&path, &fingerprint(&g), false).unwrap();
    assert_eq!(fresh.completed(), 0, "non-resume attach must start clean");
}
