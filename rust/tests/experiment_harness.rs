//! End-to-end acceptance for the experiment harness: the checked-in
//! `experiments/paper_repro.yaml` suite is deterministic (byte-identical
//! comparison artifacts across reruns and across `--workers 1` vs `2`),
//! the checked-in null baseline gates clean, and a perturbed baseline
//! fails the gate naming the offending variant and metric — asserted
//! here against both the library API and the real `mpq` binary, not
//! just in CI.

use std::path::{Path, PathBuf};
use std::process::Command;

use mpq::experiment::{gate, run_suite, Baseline, ExperimentSuite, RunOptions};
use mpq::util::json::Value;

fn repo_path(rel: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join(rel)
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("mpq_exp_harness_{tag}_{}", std::process::id()))
}

#[test]
fn checked_in_suite_parses_and_serialization_is_a_fixed_point() {
    let text = std::fs::read_to_string(repo_path("experiments/paper_repro.yaml")).unwrap();
    let suite = ExperimentSuite::parse(&text).unwrap();
    assert_eq!(suite.name, "paper_repro");
    assert_eq!(suite.variants.len(), 8);
    // Both algorithms and all three informed metrics are pinned.
    let names: Vec<&str> = suite.variants.iter().map(|v| v.name.as_str()).collect();
    for required in ["greedy_hessian", "bisection_qe", "greedy_hessian_latency"] {
        assert!(names.contains(&required), "suite lost variant `{required}`");
    }
    let canon = suite.serialize();
    let reparsed = ExperimentSuite::parse(&canon).unwrap();
    assert_eq!(reparsed, suite, "parse -> serialize -> parse is not a fixed point");
    assert_eq!(reparsed.serialize(), canon, "canonical form is not byte-stable");
}

#[test]
fn checked_in_baseline_is_in_canonical_form() {
    let path = repo_path("experiments/baseline.json");
    let base = Baseline::load(&path).unwrap();
    assert_eq!(base.suite, "paper_repro");
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        base.render(),
        text,
        "experiments/baseline.json is not in canonical form — \
         regenerate it with `mpq experiment run ... --update-baseline`"
    );
}

#[test]
fn paper_repro_is_deterministic_across_worker_counts() {
    let suite = ExperimentSuite::load(&repo_path("experiments/paper_repro.yaml")).unwrap();
    let dir = tmp("det");
    let a = run_suite(
        &suite,
        &RunOptions { out_dir: dir.join("w1"), workers_override: Some(1) },
    )
    .unwrap();
    let b = run_suite(
        &suite,
        &RunOptions { out_dir: dir.join("w2"), workers_override: Some(2) },
    )
    .unwrap();
    assert_eq!(
        a.deterministic_json(),
        b.deterministic_json(),
        "comparison artifact differs between --workers 1 and --workers 2"
    );
    assert_eq!(a.digest(), b.digest());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn gate_passes_on_the_null_baseline_and_names_a_perturbed_metric() {
    let suite = ExperimentSuite::load(&repo_path("experiments/paper_repro.yaml")).unwrap();
    let dir = tmp("gate");
    let cmp =
        run_suite(&suite, &RunOptions { out_dir: dir.clone(), workers_override: None }).unwrap();
    let base = Baseline::load(&repo_path("experiments/baseline.json")).unwrap();

    // The checked-in baseline is all-null: every metric passes with a flag.
    let report = gate(&cmp, &base, 2.0);
    assert!(report.passed(), "{}", report.render());
    assert!(!report.flags.is_empty(), "null baselines must flag, not silently pass");

    // A perturbed deterministic baseline fails, naming variant + metric.
    let mut bad = base.clone();
    bad.variants
        .get_mut("greedy_hessian")
        .unwrap()
        .insert("decision_evals".to_string(), Value::Num(-1.0));
    let report = gate(&cmp, &bad, 2.0);
    assert!(!report.passed(), "perturbed baseline must fail the gate");
    assert!(
        report
            .violations
            .iter()
            .any(|v| v.variant == "greedy_hessian" && v.metric == "decision_evals"),
        "violation does not name the culprit:\n{}",
        report.render()
    );

    // --update-baseline semantics: deterministic fields get recorded,
    // measured fields stay as the previous baseline had them (null here),
    // and the on-disk form round-trips byte-identically.
    let updated = cmp.to_baseline(Some(&base), false);
    assert_eq!(updated.variants["greedy_hessian"]["wall_ms"], Value::Null);
    assert_eq!(updated.bench, base.bench);
    let path = dir.join("baseline.json");
    updated.save(&path).unwrap();
    let text1 = std::fs::read_to_string(&path).unwrap();
    let loaded = Baseline::load(&path).unwrap();
    assert_eq!(loaded, updated);
    loaded.save(&path).unwrap();
    assert_eq!(std::fs::read_to_string(&path).unwrap(), text1);

    // The refreshed baseline now exact-checks every deterministic field.
    let report = gate(&cmp, &updated, 2.0);
    assert!(report.passed(), "{}", report.render());
    assert!(report.checked > 0);
    std::fs::remove_dir_all(&dir).ok();
}

/// Run the real binary: `mpq experiment run <suite> [args...]`.
fn run_cli(out: &Path, extra: &[&str]) -> std::process::Output {
    let suite = repo_path("experiments/paper_repro.yaml");
    Command::new(env!("CARGO_BIN_EXE_mpq"))
        .arg("experiment")
        .arg("run")
        .arg(&suite)
        .arg("--out")
        .arg(out)
        .args(extra)
        .output()
        .expect("spawning mpq")
}

#[test]
fn cli_comparison_artifact_is_byte_identical_across_workers() {
    let dir = tmp("cli");
    let a = run_cli(&dir.join("a"), &["--workers", "1"]);
    assert!(a.status.success(), "stderr:\n{}", String::from_utf8_lossy(&a.stderr));
    let b = run_cli(&dir.join("b"), &["--workers", "2"]);
    assert!(b.status.success(), "stderr:\n{}", String::from_utf8_lossy(&b.stderr));
    let ja = std::fs::read(dir.join("a/comparison.json")).unwrap();
    let jb = std::fs::read(dir.join("b/comparison.json")).unwrap();
    assert_eq!(ja, jb, "comparison.json differs between --workers 1 and 2");
    // Stable RESULT envelope on stdout for scripts (no workers, no timings).
    let line = |out: &[u8]| {
        String::from_utf8_lossy(out)
            .lines()
            .find(|l| l.starts_with("RESULT "))
            .expect("missing RESULT line")
            .to_string()
    };
    assert_eq!(line(&a.stdout), line(&b.stdout));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_gate_fails_on_a_perturbed_baseline_naming_the_culprit() {
    let dir = tmp("cli_gate");
    std::fs::create_dir_all(&dir).unwrap();

    // The checked-in (all-null) baseline passes.
    let base = repo_path("experiments/baseline.json");
    let ok = run_cli(&dir.join("ok"), &["--baseline", base.to_str().unwrap()]);
    assert!(ok.status.success(), "stderr:\n{}", String::from_utf8_lossy(&ok.stderr));

    // Pin one deterministic metric to a wrong value: exit code 1 and a
    // VIOLATION line naming the variant and metric.
    let mut bad = Baseline::load(&base).unwrap();
    bad.variants
        .get_mut("greedy_hessian")
        .unwrap()
        .insert("decision_evals".to_string(), Value::Num(-1.0));
    let bad_path = dir.join("bad_baseline.json");
    bad.save(&bad_path).unwrap();
    let fail = run_cli(&dir.join("bad"), &["--baseline", bad_path.to_str().unwrap()]);
    assert!(!fail.status.success(), "perturbed baseline must fail the CLI gate");
    let stdout = String::from_utf8_lossy(&fail.stdout);
    assert!(
        stdout.contains("VIOLATION greedy_hessian/decision_evals"),
        "stdout does not name the culprit:\n{stdout}"
    );
    std::fs::remove_dir_all(&dir).ok();
}
