//! Properties of the unified search API (`mpq::api`), all artifact-free:
//!
//! * With `Objective = AccuracyTarget`, `run_search` is bit-identical to
//!   the pre-redesign `SearchAlgo::run` path at 1/2/8 workers, for both
//!   algorithms.
//! * `LatencyBudget` is monotone: tighter budgets quantize further (never
//!   less), and stop as soon as the budget is met.
//! * Checkpoint/resume: a run killed mid-search resumes to the *exact*
//!   final configuration and decision-eval count of an uninterrupted run.
//! * The `SearchEvent` stream is consistent with the reported outcome.

use std::path::PathBuf;
use std::sync::Arc;

use mpq::api::{
    checkpoint_fingerprint, run_search, AccuracyTarget, Checkpoint, CostModel, FootprintBudget,
    LatencyBudget, Objective, SearchEvent, SyntheticCost, SyntheticEnv,
};
use mpq::coordinator::{ParallelEnv, SearchAlgo, SearchOutcome};
use mpq::quant::QUANT_BITS;

const WORKER_COUNTS: [usize; 3] = [1, 2, 8];

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("mpq_search_api_{name}.json"))
}

fn assert_same(a: &SearchOutcome, b: &SearchOutcome, what: &str) {
    assert_eq!(a.config, b.config, "{what}: config");
    assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits(), "{what}: accuracy");
    assert_eq!(a.evals, b.evals, "{what}: decision evals");
}

#[test]
fn accuracy_target_matches_pre_redesign_path_at_all_worker_counts() {
    for algo in [SearchAlgo::Greedy, SearchAlgo::Bisection] {
        for seed in [1u64, 2, 3, 4, 5] {
            let n = 8 + (seed as usize) * 5;
            let env = SyntheticEnv::new(n, seed);
            let order = env.order();
            let target = 0.93;
            // Pre-redesign entry point: plain accuracy floor, one worker.
            let mut seq = ParallelEnv::new(&env, 1);
            let baseline = algo.run(&mut seq, &order, &QUANT_BITS, target).unwrap();
            assert!(baseline.accuracy >= target, "baseline should meet its floor");
            // Objective-driven path at every worker count.
            let objective = AccuracyTarget::new(target);
            for workers in WORKER_COUNTS {
                let env = SyntheticEnv::new(n, seed);
                let mut penv = ParallelEnv::new(&env, workers);
                let out =
                    run_search(algo, &mut penv, &order, &QUANT_BITS, &objective, None, None)
                        .unwrap();
                assert_same(&out, &baseline, &format!("{algo:?} seed {seed} x{workers}"));
            }
        }
    }
}

#[test]
fn latency_budget_is_monotone_and_stops_at_the_budget() {
    let n = 24;
    let seed = 11u64;
    let cost: Arc<SyntheticCost> = Arc::new(SyntheticCost::new(n, seed));
    let floor = 0.5; // permissive floor: most layers can quantize
    let run = |objective: &dyn Objective| -> SearchOutcome {
        let env = SyntheticEnv::new(n, seed);
        let order = env.order();
        let mut penv = ParallelEnv::new(&env, 1);
        run_search(SearchAlgo::Greedy, &mut penv, &order, &QUANT_BITS, objective, None, None)
            .unwrap()
    };
    let exhaustive = run(&AccuracyTarget::new(floor));
    let exhaustive_lat = cost.rel_latency(&exhaustive.config);

    let mut prev_lat = f64::INFINITY;
    let mut prev_evals = 0usize;
    for budget in [1.0, 0.85, 0.7, 0.55, 0.4] {
        let out = run(&LatencyBudget::new(floor, budget, cost.clone()));
        let lat = cost.rel_latency(&out.config);
        // Tighter budgets quantize at least as far and never re-litigate
        // earlier decisions: latency non-increasing, evals non-decreasing.
        assert!(lat <= prev_lat + 1e-12, "budget {budget}: latency regressed {lat} > {prev_lat}");
        assert!(out.evals >= prev_evals, "budget {budget}: evals shrank");
        // Either the budget was met, or the search ran to exhaustion
        // (identical to the accuracy-only outcome).
        assert!(
            lat <= budget || out.config == exhaustive.config,
            "budget {budget}: ended at {lat} without exhausting the search"
        );
        // Budgeted runs never quantize beyond the exhaustive endpoint.
        assert!(lat >= exhaustive_lat - 1e-12, "budget {budget}: beyond exhaustive endpoint");
        assert!(out.accuracy >= floor, "budget {budget}: accuracy floor violated");
        prev_lat = lat;
        prev_evals = out.evals;
    }
    // A generous budget stops well before exhaustion.
    let generous = run(&LatencyBudget::new(floor, 0.95, cost.clone()));
    assert!(generous.evals < exhaustive.evals, "a near-free budget should stop early");
}

#[test]
fn latency_budget_stops_bisection_mid_width() {
    let n = 24;
    let seed = 11u64;
    let cost: Arc<SyntheticCost> = Arc::new(SyntheticCost::new(n, seed));
    let floor = 0.5;
    let run = |objective: &dyn Objective| -> SearchOutcome {
        let env = SyntheticEnv::new(n, seed);
        let order = env.order();
        let mut penv = ParallelEnv::new(&env, 1);
        run_search(SearchAlgo::Bisection, &mut penv, &order, &QUANT_BITS, objective, None, None)
            .unwrap()
    };
    let exhaustive = run(&AccuracyTarget::new(floor));
    for budget in [0.95, 0.8, 0.6] {
        let out = run(&LatencyBudget::new(floor, budget, cost.clone()));
        let lat = cost.rel_latency(&out.config);
        assert!(
            lat <= budget || out.config == exhaustive.config,
            "budget {budget}: ended at {lat} without exhausting the search"
        );
        assert!(out.evals <= exhaustive.evals, "budget {budget}: more evals than exhaustive");
        assert!(
            lat >= cost.rel_latency(&exhaustive.config) - 1e-12,
            "budget {budget}: quantized beyond the exhaustive endpoint"
        );
        assert!(out.accuracy >= floor, "budget {budget}: accuracy floor violated");
    }
}

#[test]
fn footprint_budget_stops_once_size_is_met() {
    let n = 16;
    let cost: Arc<SyntheticCost> = Arc::new(SyntheticCost::new(n, 5));
    let env = SyntheticEnv::new(n, 5);
    let order = env.order();
    let objective = FootprintBudget::new(0.5, 0.6, cost.clone());
    let mut penv = ParallelEnv::new(&env, 2);
    let out =
        run_search(SearchAlgo::Greedy, &mut penv, &order, &QUANT_BITS, &objective, None, None)
            .unwrap();
    assert!(cost.rel_size(&out.config) <= 0.6, "size budget not met");
    assert!(out.accuracy >= 0.5);
}

#[test]
fn checkpoint_resume_matches_uninterrupted_run() {
    for algo in [SearchAlgo::Greedy, SearchAlgo::Bisection] {
        for workers in [1usize, 2] {
            for abort_at in [1usize, 3, 7, 15] {
                let name = format!("resume_{algo:?}_{workers}_{abort_at}").to_lowercase();
                let path = tmp(&name);
                let _ = std::fs::remove_file(&path);
                let n = 18;
                let seed = 21u64;
                let target = 0.9;
                let objective = AccuracyTarget::new(target);
                let order: Vec<usize> = (0..n).collect();
                let fp = checkpoint_fingerprint(
                    algo,
                    &QUANT_BITS,
                    &objective.describe(),
                    &order,
                    "search-api-test",
                );

                // Uninterrupted baseline.
                let env = SyntheticEnv::new(n, seed);
                let mut penv = ParallelEnv::new(&env, workers);
                let baseline =
                    run_search(algo, &mut penv, &order, &QUANT_BITS, &objective, None, None)
                        .unwrap();

                // Interrupted run: the environment dies after `abort_at`
                // raw evaluations; whatever decisions were made are on
                // disk.
                let env = SyntheticEnv::new(n, seed).abort_after(abort_at);
                let mut penv = ParallelEnv::new(&env, workers);
                let mut ck = Checkpoint::attach(&path, &fp, false).unwrap();
                let interrupted = run_search(
                    algo,
                    &mut penv,
                    &order,
                    &QUANT_BITS,
                    &objective,
                    None,
                    Some(&mut ck),
                );
                if interrupted.is_ok() {
                    // Tiny searches can finish before the abort fires;
                    // resume below must still reproduce the outcome.
                    assert_same(interrupted.as_ref().unwrap(), &baseline, &name);
                }
                let recorded = ck.len();
                drop(ck);

                // Resume: replays the recorded prefix without evaluating,
                // then continues live on a healthy environment.
                let env = SyntheticEnv::new(n, seed);
                let mut penv = ParallelEnv::new(&env, workers);
                let mut ck = Checkpoint::attach(&path, &fp, true).unwrap();
                let resumed = run_search(
                    algo,
                    &mut penv,
                    &order,
                    &QUANT_BITS,
                    &objective,
                    None,
                    Some(&mut ck),
                )
                .unwrap();
                assert_same(&resumed, &baseline, &format!("{name}: resumed vs uninterrupted"));
                assert_eq!(ck.replayed(), recorded, "{name}: full prefix should replay");
                if workers == 1 {
                    // Sequential raw evals are 1:1 with decisions, so the
                    // resumed run evaluates exactly the unreplayed tail
                    // (plus the final exact eval, already in `evals`).
                    assert_eq!(
                        env.evals(),
                        baseline.evals - recorded,
                        "{name}: replayed decisions must not touch the environment"
                    );
                }
                let _ = std::fs::remove_file(&path);
            }
        }
    }
}

#[test]
fn resume_with_wrong_search_is_rejected() {
    let path = tmp("wrong_fingerprint");
    let _ = std::fs::remove_file(&path);
    let objective = AccuracyTarget::new(0.9);
    let order: Vec<usize> = (0..6).collect();
    let fp_greedy = checkpoint_fingerprint(
        SearchAlgo::Greedy,
        &QUANT_BITS,
        &objective.describe(),
        &order,
        "ctx",
    );
    let env = SyntheticEnv::new(6, 1);
    let mut penv = ParallelEnv::new(&env, 1);
    let mut ck = Checkpoint::attach(&path, &fp_greedy, false).unwrap();
    run_search(
        SearchAlgo::Greedy,
        &mut penv,
        &order,
        &QUANT_BITS,
        &objective,
        None,
        Some(&mut ck),
    )
    .unwrap();
    drop(ck);
    // Same file, different algorithm (or objective, or order) -> reject.
    let fp_bisect = checkpoint_fingerprint(
        SearchAlgo::Bisection,
        &QUANT_BITS,
        &objective.describe(),
        &order,
        "ctx",
    );
    assert!(Checkpoint::attach(&path, &fp_bisect, true).is_err());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn event_stream_is_consistent_with_the_outcome() {
    let n = 14;
    let env = SyntheticEnv::new(n, 9);
    let order = env.order();
    let cost: Arc<SyntheticCost> = Arc::new(SyntheticCost::new(n, 9));
    let objective = LatencyBudget::new(0.6, 0.75, cost);
    let mut events: Vec<SearchEvent> = Vec::new();
    let mut obs = |ev: &SearchEvent| events.push(ev.clone());
    let mut penv = ParallelEnv::new(&env, 4);
    let out = run_search(
        SearchAlgo::Greedy,
        &mut penv,
        &order,
        &QUANT_BITS,
        &objective,
        Some(&mut obs),
        None,
    )
    .unwrap();

    assert!(matches!(events.first(), Some(SearchEvent::Started { .. })));
    assert!(matches!(events.last(), Some(SearchEvent::Finished { .. })));
    let decisions = events
        .iter()
        .filter(|e| matches!(e, SearchEvent::Decision { replayed: false, .. }))
        .count();
    assert_eq!(decisions, out.evals - 1, "one Decision per eval, plus the final exact eval");
    // The budget stop is visible in the stream, with the cost recorded.
    let satisfied = events.iter().any(|e| match e {
        SearchEvent::BudgetSatisfied { cost } => *cost <= 0.75,
        _ => false,
    });
    assert!(satisfied, "budget satisfaction should be announced");
    // Every live decision carries the objective's tracked cost.
    for e in &events {
        if let SearchEvent::Decision { cost, replayed: false, .. } = e {
            assert!(cost.is_some(), "latency objectives report cost per decision");
        }
    }
}
