//! Integration tests against the real AOT artifacts: manifest contract,
//! PJRT execution, quantizer cross-layer agreement, calibration, metrics
//! and a small end-to-end search. Skipped (with a loud note) when
//! `make artifacts` has not produced an artifacts directory.

use mpq::coordinator::{Pipeline, SearchAlgo, SearchEnv};
use mpq::latency::{AccelModel, CostModel};
use mpq::model::{ArtifactIndex, ModelArtifacts};
use mpq::quant::{CalibrationOptions, QuantConfig, Scales, QUANT_BITS};
use mpq::sensitivity::{self, MetricKind};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = mpq::artifacts_dir();
    if dir.is_none() {
        eprintln!("SKIP: no artifacts directory; run `make artifacts`");
    }
    dir
}

/// Calibrated pipelines are expensive (graph compilation + calibration), so
/// each test builds at most one and the heavyweight flows share helpers.
fn calibrated_pipeline(model: &str) -> Option<Pipeline> {
    let dir = artifacts()?;
    let mut p = Pipeline::new(&dir, model).expect("pipeline");
    let scales_path = dir.join(format!("{model}_scales.json"));
    if let Ok(s) = Scales::load(&scales_path) {
        if s.num_layers() == p.num_quant_layers() {
            p.scales = s;
            p.sync_scales().unwrap();
            return Some(p);
        }
    }
    p.calibrate(&CalibrationOptions::default()).expect("calibrate");
    p.scales.save(&scales_path).ok();
    Some(p)
}

#[test]
fn index_and_manifests_load() {
    let Some(dir) = artifacts() else { return };
    let index = ArtifactIndex::load(&dir).unwrap();
    assert!(!index.models.is_empty());
    for entry in &index.models {
        let arts = ModelArtifacts::load(&dir, &entry.model).unwrap();
        let m = &arts.manifest;
        assert_eq!(m.model, entry.model);
        assert!(m.float_val_acc > 0.5, "{} float accuracy suspiciously low", m.model);
        assert_eq!(arts.val.count, m.data["val"].count);
        // Parameter blob matches the manifest.
        assert_eq!(arts.params.num_params(), m.params.len());
        for (i, p) in m.params.iter().enumerate() {
            assert_eq!(arts.params.values(i).len(), p.numel);
        }
    }
}

#[test]
fn float_eval_matches_exported_baseline() {
    let Some(mut p) = calibrated_pipeline("resnet_s") else { return };
    let n = p.num_quant_layers();
    let r = p.eval_config(&QuantConfig::float(n), None).unwrap();
    // Same parameters, same data, same graph family as the python-side
    // evaluation at export time — accuracies must agree tightly. (Python
    // evaluated with the diff path; the kernel path is verified equal in
    // pytest, so this closes the python->rust loop.)
    let expected = p.float_val_acc();
    assert!(
        (r.accuracy - expected).abs() < 0.01,
        "rust float acc {} vs exported {}",
        r.accuracy,
        expected
    );
    assert!(r.exact);
}

#[test]
fn quantization_degrades_gracefully_and_monotonically() {
    let Some(mut p) = calibrated_pipeline("resnet_s") else { return };
    let n = p.num_quant_layers();
    let a16 = p.eval_config(&QuantConfig::float(n), None).unwrap().accuracy;
    let a8 = p.eval_config(&QuantConfig::uniform(n, 8.0), None).unwrap().accuracy;
    let a4 = p.eval_config(&QuantConfig::uniform(n, 4.0), None).unwrap().accuracy;
    assert!(a8 >= a4, "int8 ({a8}) must beat int4 ({a4})");
    assert!(a16 >= a8 - 0.02, "float must be >= int8 - slack");
    // The int4 cliff: uniform int4 must fail a 99% relative target (this is
    // what makes the mixed-precision search non-trivial).
    assert!(a4 < 0.99 * a16, "int4 did not degrade: {a4} vs {a16}");
}

#[test]
fn calibration_beats_identity_scales() {
    let Some(dir) = artifacts() else { return };
    let mut p = Pipeline::new(&dir, "resnet_s").unwrap();
    let n = p.num_quant_layers();
    let cfg = QuantConfig::uniform(n, 8.0);
    // Identity scales clip everything outside [-1, 1]: accuracy collapses.
    let before = p.eval_config(&cfg, None).unwrap().accuracy;
    p.calibrate(&CalibrationOptions::default()).unwrap();
    let after = p.eval_config(&cfg, None).unwrap().accuracy;
    assert!(
        after > before + 0.05,
        "calibration should improve int8 accuracy: {before} -> {after}"
    );
}

#[test]
fn eval_cache_and_determinism() {
    let Some(mut p) = calibrated_pipeline("resnet_s") else { return };
    let n = p.num_quant_layers();
    let mut cfg = QuantConfig::uniform(n, 8.0);
    cfg.set_layer(0, 16.0);
    let r1 = p.eval_config(&cfg, None).unwrap();
    let execs_after_first = p.stats.batch_execs;
    let r2 = p.eval_config(&cfg, None).unwrap();
    assert_eq!(p.stats.batch_execs, execs_after_first, "second eval must hit the cache");
    assert_eq!(r1.accuracy, r2.accuracy);
    assert_eq!(r1.loss, r2.loss);
    assert_eq!(p.stats.cache_hits, 1);
}

#[test]
fn hessian_trace_shapes_and_determinism() {
    let Some(mut p) = calibrated_pipeline("resnet_s") else { return };
    let t1 = p.hessian_trace(1, 42).unwrap();
    let t2 = p.hessian_trace(1, 42).unwrap();
    assert_eq!(t1.len(), p.num_quant_layers());
    assert!(t1.iter().all(|v| v.is_finite()));
    for (a, b) in t1.iter().zip(&t2) {
        assert!((a - b).abs() <= 1e-6 * a.abs().max(1.0), "seeded HVP must be deterministic");
    }
    // Same seed family, different probes -> different estimates (sanity
    // that the probes actually vary).
    let t3 = p.hessian_trace(1, 43).unwrap();
    assert!(t1.iter().zip(&t3).any(|(a, b)| (a - b).abs() > 0.0));
}

#[test]
fn noise_metric_orders_layers() {
    let Some(mut p) = calibrated_pipeline("resnet_s") else { return };
    let s = sensitivity::compute(&mut p, MetricKind::Noise, 2, 7).unwrap();
    assert_eq!(s.scores.len(), p.num_quant_layers());
    assert!(s.scores.iter().all(|v| v.is_finite()));
    // Perturbing weights must hurt on average for at least some layers.
    assert!(s.scores.iter().any(|&v| v > 0.0));
}

#[test]
fn qe_metric_against_kernel_semantics() {
    let Some(dir) = artifacts() else { return };
    let p = Pipeline::new(&dir, "bert_s").unwrap();
    let s = sensitivity::qe_sensitivity(&p);
    assert_eq!(s.scores.len(), p.num_quant_layers());
    // ε_QE is scale-normalized: all scores in (0, ~0.6) at 4 bits for
    // roughly-Gaussian weights (pure clipping error stays below max|x|).
    assert!(s.scores.iter().all(|&v| v > 0.0 && v < 1.0), "{:?}", s.scores);
}

#[test]
fn logits_shape_matches_task() {
    let Some(mut p) = calibrated_pipeline("bert_s") else { return };
    let n = p.num_quant_layers();
    let m = p.artifacts.manifest.clone();
    let x = p.artifacts.val.x.slice_rows(0, m.eval_batch);
    let out = p.logits(&QuantConfig::uniform(n, 8.0), &x).unwrap();
    // span task: (batch, seq, 2) logits.
    assert_eq!(out.len(), m.eval_batch * m.x_shape[0] * 2);
}

#[test]
fn small_end_to_end_search_meets_target() {
    let Some(mut p) = calibrated_pipeline("resnet_s") else { return };
    let target = 0.98 * p.float_val_acc();
    let order = sensitivity::qe_sensitivity(&p).order;
    let out = SearchAlgo::Greedy.run(&mut p, &order, &QUANT_BITS, target).unwrap();
    assert!(out.accuracy >= target, "search result violates its accuracy floor");
    // Something must actually have been quantized at this loose target.
    assert!(out.config.count_at(16.0) < p.num_layers());
}

#[test]
fn cost_model_paper_shape_on_real_manifests() {
    let Some(dir) = artifacts() else { return };
    for model in ["resnet_s", "bert_s"] {
        let arts = ModelArtifacts::load(&dir, model).unwrap();
        let cm = CostModel::new(&arts.manifest, &AccelModel::a100_like());
        let n = arts.manifest.num_quant_layers;
        let r8 = cm.rel_latency(&QuantConfig::uniform(n, 8.0));
        let r4 = cm.rel_latency(&QuantConfig::uniform(n, 4.0));
        // Paper Table 1 shape: int8 in (50%, 90%), int4 below int8, both
        // showing diminishing returns (int4 > pure byte ratio 25%).
        assert!(r8 > 0.5 && r8 < 0.9, "{model}: rel latency int8 {r8}");
        assert!(r4 < r8, "{model}: int4 {r4} !< int8 {r8}");
        assert!(r4 > 0.25, "{model}: int4 {r4} unrealistically good");
        let s8 = cm.rel_size(&QuantConfig::uniform(n, 8.0));
        assert!((s8 - 0.5).abs() < 0.02, "{model}: rel size int8 {s8}");
    }
}

#[test]
fn scales_roundtrip_with_pipeline() {
    let Some(p) = calibrated_pipeline("resnet_s") else { return };
    let tmp = std::env::temp_dir().join("mpq_it_scales.json");
    p.scales.save(&tmp).unwrap();
    let loaded = Scales::load(&tmp).unwrap();
    assert_eq!(loaded, p.scales);
    let _ = std::fs::remove_file(&tmp);
}
