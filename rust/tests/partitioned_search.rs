//! End-to-end properties of the subgraph-partitioned search: `K = 1`
//! must be byte-identical to the whole-model search (both algorithms, at
//! every worker count), segment splits must cover the sensitivity order
//! exactly once, reconciliation must never exceed the global budget when
//! every segment met its scoped one, composed `K > 1` frontiers must
//! survive brute-force re-evaluation of every point they claim, and a
//! killed partitioned run must resume into a byte-identical result.

use std::sync::Arc;

use mpq::api::{
    build_frontier_synthetic, build_frontier_synthetic_partitioned, partitioned_search_synthetic,
    run_search, CostModel, ObjectiveSpec, Partition, SearchEvent, SyntheticCost, SyntheticEnv,
};
use mpq::coordinator::{ParallelEnv, SearchAlgo, SyncSearchEnv};
use mpq::quant::QUANT_BITS;
use mpq::report::{budget_sweep_from_frontier, BudgetKind, SweepGrid};

const LAYERS: usize = 20;
const SEED: u64 = 7;
const FLOORS: [f64; 3] = [0.9, 0.97, 0.99];

/// A comparable key for one `Decision` event (bit-exact on the floats).
type DecisionKey = (u32, usize, bool, u64, Option<u64>, bool);

fn decision_key(ev: &SearchEvent) -> Option<DecisionKey> {
    match *ev {
        SearchEvent::Decision { bits, index, accepted, accuracy, cost, replayed } => Some((
            bits.to_bits(),
            index,
            accepted,
            accuracy.to_bits(),
            cost.map(f64::to_bits),
            replayed,
        )),
        _ => None,
    }
}

#[test]
fn k1_matches_the_monolithic_search_at_every_worker_count() {
    for algo in [SearchAlgo::Greedy, SearchAlgo::Bisection] {
        for spec in [
            ObjectiveSpec::AccuracyTarget,
            ObjectiveSpec::LatencyBudget { rel_latency: 0.7 },
            ObjectiveSpec::FootprintBudget { rel_size: 0.6 },
        ] {
            let mut part_decisions: Vec<DecisionKey> = Vec::new();
            let mut obs = |ev: &SearchEvent| part_decisions.extend(decision_key(ev));
            let part = partitioned_search_synthetic(
                LAYERS,
                SEED,
                algo,
                &spec,
                0.95,
                1,
                None,
                false,
                None,
                Some(&mut obs),
            )
            .unwrap();
            assert!(part.segments.is_empty(), "K=1 runs the monolithic search itself");

            for workers in [1usize, 2, 8] {
                let env = SyntheticEnv::new(LAYERS, SEED);
                let order = env.order();
                let objective = spec.build(0.95, Arc::new(SyntheticCost::new(LAYERS, SEED)));
                let mut mono_decisions: Vec<DecisionKey> = Vec::new();
                let mut mobs = |ev: &SearchEvent| mono_decisions.extend(decision_key(ev));
                let mut penv = ParallelEnv::new(&env, workers);
                let mono = run_search(
                    algo,
                    &mut penv,
                    &order,
                    &QUANT_BITS,
                    objective.as_ref(),
                    Some(&mut mobs),
                    None,
                )
                .unwrap();
                let label = format!("{} {spec:?} at {workers} workers", algo.label());
                assert_eq!(part.outcome.config, mono.config, "config diff: {label}");
                assert_eq!(
                    part.outcome.accuracy.to_bits(),
                    mono.accuracy.to_bits(),
                    "accuracy diff: {label}"
                );
                assert_eq!(part.outcome.evals, mono.evals, "evals diff: {label}");
                assert_eq!(part_decisions, mono_decisions, "decision stream diff: {label}");
            }
        }
    }
}

#[test]
fn segment_splits_cover_every_order_exactly_once() {
    for n in [1usize, 2, 3, 5, 8, 13, 21, 34] {
        // A deterministic pseudo-shuffled order (no rand dependency).
        let mut order: Vec<usize> = (0..n).collect();
        let mut state = 0x9e37_79b9_7f4a_7c15u64 ^ n as u64;
        for i in (1..n).rev() {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            order.swap(i, (state >> 33) as usize % (i + 1));
        }
        for k in 1..=12 {
            let p = Partition::split(&order, k);
            assert_eq!(p.num_segments(), k.min(n.max(1)));
            assert_eq!(p.num_layers(), n);
            // Concatenating the segments reassembles the order: every
            // layer appears exactly once, contiguously, in order.
            let covered: Vec<usize> =
                p.segments().iter().flat_map(|s| s.layers.iter().copied()).collect();
            assert_eq!(covered, order, "n={n} k={k}: segments must tile the order");
            let share: f64 = p.segments().iter().map(|s| s.share).sum();
            assert!((share - 1.0).abs() < 1e-9, "n={n} k={k}: shares sum to {share}");
            let sizes: Vec<usize> = p.segments().iter().map(|s| s.layers.len()).collect();
            let spread = sizes.iter().max().unwrap() - sizes.iter().min().unwrap();
            assert!(spread <= 1, "n={n} k={k}: unbalanced segment sizes {sizes:?}");
            assert!(sizes.iter().all(|&s| s > 0), "n={n} k={k}: empty segment");
        }
    }
}

#[test]
fn reconciliation_never_exceeds_a_satisfied_global_budget() {
    let cost = SyntheticCost::new(LAYERS, SEED);
    let mut any_satisfied = false;
    for algo in [SearchAlgo::Greedy, SearchAlgo::Bisection] {
        for spec in [
            ObjectiveSpec::LatencyBudget { rel_latency: 0.7 },
            ObjectiveSpec::FootprintBudget { rel_size: 0.7 },
        ] {
            for k in [2usize, 3, 4] {
                let out = partitioned_search_synthetic(
                    LAYERS, SEED, algo, &spec, 0.9, k, None, false, None, None,
                )
                .unwrap();
                let label = format!("{} {spec:?} K={k}", algo.label());
                assert_eq!(out.segments.len(), k, "{label}");
                assert_eq!(out.satisfied.len(), k, "{label}");

                // Brute force: the reconciled accuracy is the exact
                // re-evaluated accuracy of the composed configuration.
                let env = SyntheticEnv::new(LAYERS, SEED);
                let fresh = SyncSearchEnv::eval(&env, &out.outcome.config, None).unwrap();
                assert!(fresh.exact, "{label}");
                assert_eq!(
                    fresh.accuracy.to_bits(),
                    out.outcome.accuracy.to_bits(),
                    "reconciled accuracy must be the exact re-evaluated value: {label}"
                );

                // The conditional composition guarantee: if every segment
                // met its scoped budget, the composed configuration meets
                // the global one (cost additivity).
                if out.all_satisfied() {
                    any_satisfied = true;
                    let (rel, budget) = match spec {
                        ObjectiveSpec::LatencyBudget { rel_latency } => {
                            (cost.rel_latency(&out.outcome.config), rel_latency)
                        }
                        ObjectiveSpec::FootprintBudget { rel_size } => {
                            (cost.rel_size(&out.outcome.config), rel_size)
                        }
                        ObjectiveSpec::AccuracyTarget => unreachable!(),
                    };
                    assert!(
                        rel <= budget + 1e-12,
                        "composed cost {rel} exceeds global budget {budget}: {label}"
                    );
                }
            }
        }
    }
    assert!(any_satisfied, "property never exercised: no run satisfied all scoped budgets");
}

#[test]
fn composed_frontier_survives_brute_force_re_evaluation() {
    let report = build_frontier_synthetic_partitioned(
        LAYERS,
        SEED,
        1,
        SearchAlgo::Greedy,
        &FLOORS,
        4,
        None,
        false,
        None,
        None,
    )
    .unwrap();
    let artifact = &report.artifact;
    assert_eq!(artifact.partitions, 4);
    assert!(artifact.fingerprint.ends_with("/K4"), "{}", artifact.fingerprint);

    let env = SyntheticEnv::new(LAYERS, SEED);
    let cost = SyntheticCost::new(LAYERS, SEED);
    for trail in &artifact.trails {
        assert!(!trail.points.is_empty(), "floor {}", trail.floor);
        for p in &trail.points {
            let fresh = SyncSearchEnv::eval(&env, &p.config, None).unwrap();
            assert!(fresh.exact);
            assert_eq!(
                fresh.accuracy.to_bits(),
                p.accuracy.to_bits(),
                "floor {}: recorded accuracy must be the exact re-evaluated value",
                trail.floor
            );
            assert!(
                p.accuracy >= trail.abs_floor - 1e-12,
                "floor {}: composed point breaks its floor ({} < {})",
                trail.floor,
                p.accuracy,
                trail.abs_floor
            );
            assert_eq!(cost.rel_latency(&p.config).to_bits(), p.rel_latency.to_bits());
            assert_eq!(cost.rel_size(&p.config).to_bits(), p.rel_size.to_bits());
        }
        // The composition walk only deepens quantization, so both
        // relative costs fall monotonically along the trail.
        for w in trail.points.windows(2) {
            assert!(w[1].rel_latency <= w[0].rel_latency + 1e-12, "floor {}", trail.floor);
            assert!(w[1].rel_size <= w[0].rel_size + 1e-12, "floor {}", trail.floor);
        }
    }

    // Every sweep cell the composed frontier claims holds under
    // brute-force re-evaluation of the backing configuration.
    for kind in [BudgetKind::Latency, BudgetKind::Size] {
        let g = SweepGrid { kind, budgets: vec![0.55, 0.7, 0.9], floors: FLOORS.to_vec() };
        let cells = budget_sweep_from_frontier(artifact, &g, None).unwrap();
        assert_eq!(cells.len(), 9);
        for c in &cells {
            let trail = artifact
                .trails
                .iter()
                .find(|t| t.floor.to_bits() == c.floor.to_bits())
                .expect("cell floor must come from a trail");
            let point = trail
                .points
                .iter()
                .find(|p| {
                    p.accuracy.to_bits() == c.accuracy.to_bits()
                        && p.rel_latency.to_bits() == c.rel_latency.to_bits()
                        && p.rel_size.to_bits() == c.rel_size.to_bits()
                })
                .expect("every cell must be backed by a recorded trail point");
            let fresh = SyncSearchEnv::eval(&env, &point.config, None).unwrap();
            assert_eq!(fresh.accuracy.to_bits(), c.accuracy.to_bits());
            if c.met_floor {
                assert!(fresh.accuracy >= trail.abs_floor - 1e-12);
            }
            if c.met_budget {
                let rel = match kind {
                    BudgetKind::Latency => cost.rel_latency(&point.config),
                    BudgetKind::Size => cost.rel_size(&point.config),
                };
                assert!(rel <= c.budget + 1e-12, "claimed cell exceeds its budget");
            }
        }
    }
}

#[test]
fn k1_partitioned_frontier_is_byte_identical_to_the_monolithic_builder() {
    let mono = build_frontier_synthetic(
        LAYERS,
        SEED,
        2,
        SearchAlgo::Greedy,
        &FLOORS,
        None,
        false,
        None,
        None,
    )
    .unwrap();
    let part = build_frontier_synthetic_partitioned(
        LAYERS,
        SEED,
        2,
        SearchAlgo::Greedy,
        &FLOORS,
        1,
        None,
        false,
        None,
        None,
    )
    .unwrap();
    assert_eq!(
        part.artifact.to_json().to_string(),
        mono.artifact.to_json().to_string(),
        "K=1 must delegate byte-identically (artifact, fingerprint, and all)"
    );
}

#[test]
fn aborted_partitioned_search_resumes_byte_identically() {
    let spec = ObjectiveSpec::LatencyBudget { rel_latency: 0.7 };
    let run = |checkpoint: Option<&std::path::Path>, resume, abort| {
        partitioned_search_synthetic(
            LAYERS,
            SEED,
            SearchAlgo::Greedy,
            &spec,
            0.9,
            4,
            checkpoint,
            resume,
            abort,
            None,
        )
    };
    let full = run(None, false, None).unwrap();

    let prefix = std::env::temp_dir().join("mpq_part_search_ck");
    let cleanup = || {
        for s in 0..4 {
            let _ = std::fs::remove_file(format!("{}.seg{s}", prefix.display()));
        }
    };
    cleanup();

    // Kill mid-run: the shared synthetic env errors after 8 raw
    // evaluations, somewhere inside the concurrent segment searches.
    let err = run(Some(&prefix), false, Some(8)).unwrap_err();
    assert!(format!("{err:#}").contains("abort"), "{err:#}");

    // Resume: whatever each segment committed before the kill replays
    // from its own decision log; the rest runs fresh.
    let resumed = run(Some(&prefix), true, None).unwrap();
    assert!(resumed.replayed_decisions > 0, "the killed run's decisions must replay");
    assert_eq!(resumed.outcome.config, full.outcome.config);
    assert_eq!(resumed.outcome.accuracy.to_bits(), full.outcome.accuracy.to_bits());
    assert_eq!(resumed.outcome.evals, full.outcome.evals);
    assert_eq!(resumed.satisfied, full.satisfied);
    assert!(resumed.checkpointed_decisions > 0);
    cleanup();
}

#[test]
fn aborted_partitioned_frontier_resumes_byte_identically() {
    let floors = [0.9, 0.99];
    let build = |checkpoint: Option<&std::path::Path>, resume, abort| {
        build_frontier_synthetic_partitioned(
            LAYERS,
            SEED,
            1,
            SearchAlgo::Greedy,
            &floors,
            4,
            checkpoint,
            resume,
            abort,
            None,
        )
    };
    let full_json = build(None, false, None).unwrap().artifact.to_json().to_string();

    let prefix = std::env::temp_dir().join("mpq_part_frontier_ck");
    let cleanup = || {
        for i in 0..floors.len() {
            for s in 0..4 {
                let _ = std::fs::remove_file(format!("{}.floor{i}.seg{s}", prefix.display()));
            }
        }
    };
    cleanup();

    let err = build(Some(&prefix), false, Some(10)).unwrap_err();
    assert!(format!("{err:#}").contains("abort"), "{err:#}");

    let resumed = build(Some(&prefix), true, None).unwrap();
    assert!(resumed.replayed_decisions > 0, "the killed build's decisions must replay");
    assert_eq!(
        resumed.artifact.to_json().to_string(),
        full_json,
        "resumed composed frontier must byte-match the uninterrupted build"
    );
    cleanup();
}
