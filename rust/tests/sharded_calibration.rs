//! Parity properties for the sharded calibration/sensitivity driver: at
//! 1, 2 and 8 workers, [`calibrate_sharded`] and [`hessian_trace_sharded`]
//! must produce *bit-identical* scales, adjustment reports, and traces —
//! the same contract `batched_search.rs` asserts for the search engine.
//! No artifacts or PJRT device needed: [`SyntheticStage`] runs the real
//! driver (sharding, scatter over scoped threads, fixed-order host
//! reduction, broadcast protocol) over deterministic per-batch math.

use mpq::api::SyntheticStage;
use mpq::coordinator::{
    act_stats_sharded, calibrate_sharded, hessian_trace_sharded, shard_indices, StageRunner,
};
use mpq::quant::{CalibrationOptions, Scales};

const WORKER_COUNTS: [usize; 3] = [1, 2, 8];

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn assert_scales_bits(a: &Scales, b: &Scales, what: &str) {
    assert_eq!(bits(&a.alpha_w), bits(&b.alpha_w), "{what}: alpha_w");
    assert_eq!(bits(&a.gamma_w), bits(&b.gamma_w), "{what}: gamma_w");
    assert_eq!(bits(&a.alpha_a), bits(&b.alpha_a), "{what}: alpha_a");
    assert_eq!(bits(&a.gamma_a), bits(&b.gamma_a), "{what}: gamma_a");
}

#[test]
fn calibration_bit_identical_across_worker_counts() {
    // Layer/batch/group shapes chosen so groups split unevenly across
    // workers (the hard case for reduction order): 10 batches in groups
    // of 4 -> groups of 4, 4, 2.
    for (layers, batches, grad_batches, epochs) in
        [(6usize, 10usize, 4usize, 2usize), (3, 5, 8, 1), (12, 16, 1, 2), (1, 1, 4, 3)]
    {
        let opts = CalibrationOptions { grad_batches, epochs, ..Default::default() };
        let mut reference = None;
        for workers in WORKER_COUNTS {
            let mut stage = SyntheticStage::new(layers, batches, workers, 42);
            let (scales, report) = calibrate_sharded(&mut stage, &opts, None).unwrap();
            // The final broadcast must have installed the returned scales.
            assert_scales_bits(
                &scales,
                stage.current_scales(),
                &format!("workers {workers}: broadcast install"),
            );
            // One broadcast after step 1 plus one per Adam step.
            let expected_steps = epochs * batches.div_ceil(grad_batches.max(1));
            assert_eq!(report.steps, expected_steps, "workers {workers}: steps");
            assert_eq!(stage.broadcasts(), 1 + report.steps, "workers {workers}: broadcasts");
            match &reference {
                None => reference = Some((scales, report)),
                Some((ref_scales, ref_report)) => {
                    let what = format!(
                        "layers {layers} batches {batches} group {grad_batches} \
                         workers {workers}"
                    );
                    assert_scales_bits(&scales, ref_scales, &what);
                    assert_eq!(
                        report.loss_before.to_bits(),
                        ref_report.loss_before.to_bits(),
                        "{what}: loss_before"
                    );
                    assert_eq!(
                        report.loss_after.to_bits(),
                        ref_report.loss_after.to_bits(),
                        "{what}: loss_after"
                    );
                    assert_eq!(report.steps, ref_report.steps, "{what}: steps");
                }
            }
        }
    }
}

#[test]
fn adjustment_moves_scales_toward_lower_loss() {
    // Sanity that the sharded loop actually optimizes (not just agrees
    // with itself): with a real learning rate the quadratic loss drops.
    let opts =
        CalibrationOptions { lr: 0.05, epochs: 8, grad_batches: 4, ..Default::default() };
    let mut stage = SyntheticStage::new(5, 12, 2, 7);
    let (_, report) = calibrate_sharded(&mut stage, &opts, None).unwrap();
    assert!(
        report.loss_after < report.loss_before,
        "loss did not drop: {} -> {}",
        report.loss_before,
        report.loss_after
    );
}

#[test]
fn hessian_trace_bit_identical_across_worker_counts() {
    for (layers, trials) in [(6usize, 7usize), (4, 1), (9, 16)] {
        let mut reference: Option<Vec<f64>> = None;
        for workers in WORKER_COUNTS {
            let mut stage = SyntheticStage::new(layers, 8, workers, 13);
            let traces = hessian_trace_sharded(&mut stage, trials, 99).unwrap();
            assert_eq!(traces.len(), layers);
            match &reference {
                None => reference = Some(traces),
                Some(r) => {
                    let what = format!("layers {layers} trials {trials} workers {workers}");
                    let tb = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                    assert_eq!(tb(&traces), tb(r), "{what}");
                }
            }
        }
    }
    // Different seeds must give different traces (probes actually vary).
    let mut a = SyntheticStage::new(4, 8, 2, 13);
    let mut b = SyntheticStage::new(4, 8, 2, 13);
    let ta = hessian_trace_sharded(&mut a, 5, 1).unwrap();
    let tb = hessian_trace_sharded(&mut b, 5, 2).unwrap();
    assert_ne!(ta, tb);
}

#[test]
fn act_stats_bit_identical_and_worker_independent() {
    let mut reference: Option<Vec<f32>> = None;
    for workers in WORKER_COUNTS {
        let mut stage = SyntheticStage::new(7, 11, workers, 5);
        let stats = act_stats_sharded(&mut stage).unwrap();
        assert_eq!(stats.len(), 7);
        match &reference {
            None => reference = Some(stats),
            Some(r) => assert_eq!(bits(&stats), bits(r), "workers {workers}"),
        }
    }
}

#[test]
fn stage_calibration_is_deterministic_per_seed() {
    let opts = CalibrationOptions::default();
    let run = |seed: u64| {
        let mut stage = SyntheticStage::new(5, 9, 3, seed);
        calibrate_sharded(&mut stage, &opts, None).unwrap().0
    };
    assert_scales_bits(&run(11), &run(11), "same seed");
    let a = run(11);
    let b = run(12);
    assert_ne!(bits(&a.gamma_w), bits(&b.gamma_w), "different seeds must differ");
}

#[test]
fn shard_layout_never_loses_or_reorders_items() {
    let items: Vec<usize> = (0..23).collect();
    for workers in [1usize, 2, 3, 8, 23, 64] {
        let shards = shard_indices(&items, workers);
        assert!(shards.len() <= workers.max(1));
        assert!(shards.iter().all(|s| !s.is_empty()), "workers {workers}: empty shard");
        let flat: Vec<usize> = shards.into_iter().flatten().collect();
        assert_eq!(flat, items, "workers {workers}");
    }
}

#[test]
fn calibration_events_report_epochs_and_finish() {
    let opts = CalibrationOptions { epochs: 2, grad_batches: 4, ..Default::default() };
    let mut stage = SyntheticStage::new(4, 8, 2, 3);
    let mut started = 0usize;
    let mut epochs = Vec::new();
    let mut finished = 0usize;
    {
        let mut obs = |ev: &mpq::api::SearchEvent| match ev {
            mpq::api::SearchEvent::CalibrationStarted { workers, batches, .. } => {
                started += 1;
                assert_eq!((*workers, *batches), (2, 8));
            }
            mpq::api::SearchEvent::AdjustEpoch { epoch, .. } => epochs.push(*epoch),
            mpq::api::SearchEvent::CalibrationFinished { steps, .. } => {
                finished += 1;
                assert_eq!(*steps, 4); // 2 epochs x ceil(8/4) groups
            }
            _ => {}
        };
        calibrate_sharded(&mut stage, &opts, Some(&mut obs)).unwrap();
    }
    assert_eq!(started, 1);
    assert_eq!(epochs, vec![0, 1]);
    assert_eq!(finished, 1);
}

/// A one-worker stage whose kernels delegate to the synthetic math —
/// used to double-check that `StageRunner` is object-safe enough for the
/// driver's `?Sized` bounds (the API the pool and pipeline share).
#[test]
fn driver_accepts_dyn_stage_runner() {
    let mut stage = SyntheticStage::new(3, 6, 2, 21);
    let dyn_stage: &mut dyn StageRunner = &mut stage;
    let stats = act_stats_sharded(dyn_stage).unwrap();
    assert_eq!(stats.len(), 3);
}
