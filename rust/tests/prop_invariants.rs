//! Randomized property tests over the coordinator invariants (DESIGN.md §7).
//! No artifacts or PJRT device needed — these run against synthetic
//! evaluation environments with known structure, using the in-tree seeded
//! RNG for reproducible case generation.

use mpq::coordinator::{EvalResult, SearchAlgo, SearchEnv};
use mpq::quant::{eps_qe, quantize, QuantConfig, FLOAT_BITS, QUANT_BITS};
use mpq::sensitivity::{levenshtein, Sensitivity, MetricKind};
use mpq::util::json::{self, Value};
use mpq::util::rng::Rng;

const CASES: usize = 60;

/// Separable monotone environment: accuracy = 1 - Σ penalty_i · q(bits_i).
struct MonotoneEnv {
    penalty: Vec<f64>,
    evals: usize,
}

impl MonotoneEnv {
    fn random(rng: &mut Rng, n: usize) -> Self {
        let penalty = (0..n)
            .map(|_| if rng.uniform() < 0.3 { rng.uniform() * 0.2 } else { rng.uniform() * 1e-3 })
            .collect();
        Self { penalty, evals: 0 }
    }

    fn order_by_penalty(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.penalty.len()).collect();
        idx.sort_by(|&a, &b| self.penalty[a].partial_cmp(&self.penalty[b]).unwrap());
        idx
    }
}

impl SearchEnv for MonotoneEnv {
    fn num_layers(&self) -> usize {
        self.penalty.len()
    }

    fn eval(&mut self, cfg: &QuantConfig, _t: Option<f64>) -> mpq::Result<EvalResult> {
        self.evals += 1;
        let cost: f64 = cfg
            .bits_w
            .iter()
            .enumerate()
            .map(|(i, &b)| self.penalty[i] * f64::from(16.0 - b) / 12.0)
            .sum();
        Ok(EvalResult { loss: cost, accuracy: 1.0 - cost, exact: true })
    }
}

fn valid_bits(cfg: &QuantConfig) -> bool {
    cfg.bits_w
        .iter()
        .chain(cfg.bits_a.iter())
        .all(|b| QUANT_BITS.contains(b) || *b == FLOAT_BITS)
}

#[test]
fn prop_greedy_meets_target_and_returns_valid_configs() {
    let mut rng = Rng::seed_from(101);
    for case in 0..CASES {
        let n = 1 + rng.below(40);
        let mut env = MonotoneEnv::random(&mut rng, n);
        let order = env.order_by_penalty();
        let target = 0.9 + rng.uniform() * 0.1;
        let out = SearchAlgo::Greedy.run(&mut env, &order, &QUANT_BITS, target).unwrap();
        assert!(valid_bits(&out.config), "case {case}: invalid bits {:?}", out.config.bits_w);
        // The float config trivially satisfies any target <= 1; greedy must
        // never return a config below target in a monotone env.
        assert!(out.accuracy >= target - 1e-12, "case {case}: {} < {target}", out.accuracy);
        // Eval budget: paper's worst case bN plus the final exact eval.
        assert!(out.evals <= QUANT_BITS.len() * n + 1, "case {case}: budget");
    }
}

#[test]
fn prop_greedy_monotone_in_target() {
    // A stricter target can never produce a *smaller* (more compressed)
    // model in a separable monotone environment.
    let mut rng = Rng::seed_from(202);
    for _ in 0..CASES {
        let n = 2 + rng.below(24);
        let seed_env = MonotoneEnv::random(&mut rng, n);
        let order = seed_env.order_by_penalty();
        let run = |target: f64| {
            let mut env = MonotoneEnv { penalty: seed_env.penalty.clone(), evals: 0 };
            SearchAlgo::Greedy.run(&mut env, &order, &QUANT_BITS, target).unwrap()
        };
        let loose = run(0.95);
        let strict = run(0.999);
        let bits_sum = |c: &QuantConfig| c.bits_w.iter().sum::<f32>();
        assert!(
            bits_sum(&strict.config) >= bits_sum(&loose.config) - 1e-6,
            "stricter target must keep at least as many bits"
        );
    }
}

#[test]
fn prop_bisection_valid_and_within_budget() {
    let mut rng = Rng::seed_from(303);
    for case in 0..CASES {
        let n = 1 + rng.below(60);
        let mut env = MonotoneEnv::random(&mut rng, n);
        // Adversarial (random) ordering — bisection must still terminate
        // and return a valid config, even if compression suffers.
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let target = 0.9 + rng.uniform() * 0.1;
        let out = SearchAlgo::Bisection.run(&mut env, &order, &QUANT_BITS, target).unwrap();
        assert!(valid_bits(&out.config), "case {case}");
        // O(b log N) + slack; generous but catches runaway loops.
        let budget = QUANT_BITS.len() * (2 * (n as f64).log2().ceil() as usize + 6) + 1;
        assert!(out.evals <= budget, "case {case}: {} evals > {budget} (n={n})", out.evals);
    }
}

#[test]
fn prop_bisection_respects_threshold_structure() {
    // In a threshold environment with the true ordering, bisection must
    // recover the exact thresholds (its structural assumption).
    struct ThresholdEnv {
        pos: Vec<usize>,
        ok8: usize,
        ok4: usize,
    }
    impl SearchEnv for ThresholdEnv {
        fn num_layers(&self) -> usize {
            self.pos.len()
        }
        fn eval(&mut self, cfg: &QuantConfig, _t: Option<f64>) -> mpq::Result<EvalResult> {
            let ok = cfg.bits_w.iter().enumerate().all(|(l, &b)| {
                if b <= 4.0 {
                    self.pos[l] < self.ok4
                } else if b <= 8.0 {
                    self.pos[l] < self.ok8
                } else {
                    true
                }
            });
            Ok(EvalResult { loss: 0.0, accuracy: if ok { 1.0 } else { 0.0 }, exact: true })
        }
    }
    let mut rng = Rng::seed_from(404);
    for _ in 0..CASES {
        let n = 1 + rng.below(48);
        let ok8 = rng.below(n + 1);
        let ok4 = rng.below(ok8 + 1);
        let order: Vec<usize> = (0..n).collect();
        let mut env = ThresholdEnv { pos: order.clone(), ok8, ok4 };
        let out = SearchAlgo::Bisection.run(&mut env, &order, &QUANT_BITS, 0.5).unwrap();
        for l in 0..n {
            let expect = if l < ok4 {
                4.0
            } else if l < ok8 {
                8.0
            } else {
                16.0
            };
            assert_eq!(out.config.layer_bits(l), expect, "n={n} ok8={ok8} ok4={ok4} layer={l}");
        }
    }
}

#[test]
fn prop_greedy_usually_beats_bisection_on_monotone_envs() {
    // The paper's empirical claim (Table 2): greedy compresses at least as
    // well as bisection. At the first bit level with a correct ordering
    // greedy's accepted set is a superset of bisection's prefix; at lower
    // levels the diverged budgets can occasionally flip a case, so the
    // claim is statistical, not per-case.
    let mut rng = Rng::seed_from(505);
    let mut greedy_wins = 0usize;
    let mut ties = 0usize;
    for _ in 0..CASES {
        let n = 4 + rng.below(32);
        let base = MonotoneEnv::random(&mut rng, n);
        // Noisy ordering (a few random adjacent swaps): with a perfect
        // ordering both algorithms select the identical prefix; greedy's
        // advantage — the paper's point — is robustness to mis-ordering.
        let mut order = base.order_by_penalty();
        for _ in 0..(n / 3).max(1) {
            let i = rng.below(n - 1);
            order.swap(i, i + 1);
        }
        let mut e1 = MonotoneEnv { penalty: base.penalty.clone(), evals: 0 };
        let mut e2 = MonotoneEnv { penalty: base.penalty.clone(), evals: 0 };
        let g = SearchAlgo::Greedy.run(&mut e1, &order, &QUANT_BITS, 0.99).unwrap();
        let b = SearchAlgo::Bisection.run(&mut e2, &order, &QUANT_BITS, 0.99).unwrap();
        let sum = |c: &QuantConfig| c.bits_w.iter().sum::<f32>();
        if sum(&g.config) < sum(&b.config) - 1e-6 {
            greedy_wins += 1;
        } else if sum(&g.config) <= sum(&b.config) + 1e-6 {
            ties += 1;
        }
        // Both must always respect the accuracy floor.
        assert!(g.accuracy >= 0.99 - 1e-12);
        assert!(b.accuracy >= 0.99 - 1e-12);
    }
    assert!(
        greedy_wins + ties >= CASES * 8 / 10,
        "greedy should win or tie in >=80% of cases (won {greedy_wins}, tied {ties})"
    );
    assert!(greedy_wins > 0, "greedy should strictly win on some cases");
}

#[test]
fn prop_random_sensitivity_is_seeded_permutation() {
    let mut rng = Rng::seed_from(606);
    for _ in 0..CASES {
        let n = 1 + rng.below(64);
        let seed = rng.next_u64();
        let a = Sensitivity::random(n, seed);
        let b = Sensitivity::random(n, seed);
        assert_eq!(a.order, b.order);
        let mut sorted = a.order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..n).collect::<Vec<_>>());
        assert_eq!(a.metric, MetricKind::Random);
    }
}

#[test]
fn prop_levenshtein_metric_axioms() {
    let mut rng = Rng::seed_from(707);
    for _ in 0..CASES {
        let n = rng.below(24);
        let mut a: Vec<usize> = (0..n).collect();
        let mut b: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut a);
        rng.shuffle(&mut b);
        assert_eq!(levenshtein(&a, &a), 0);
        assert_eq!(levenshtein(&a, &b), levenshtein(&b, &a));
        assert!(levenshtein(&a, &b) <= n);
    }
}

#[test]
fn prop_quantizer_invariants() {
    let mut rng = Rng::seed_from(808);
    for _ in 0..CASES {
        let n = 1 + rng.below(512);
        let x: Vec<f32> = (0..n).map(|_| (rng.gaussian() * 3.0) as f32).collect();
        let maxabs = x.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-12);
        // Monotone error in bits.
        let e = [2.0, 4.0, 8.0].map(|b| eps_qe(&x, b));
        assert!(e[0] >= e[1] && e[1] >= e[2]);
        assert_eq!(eps_qe(&x, 16.0), 0.0);
        // Projection: Q(Q(x)) == Q(x).
        let q1 = quantize(&x, 1.0 / maxabs, maxabs, 4.0);
        let q2 = quantize(&q1, 1.0 / maxabs, maxabs, 4.0);
        for (a, b) in q1.iter().zip(&q2) {
            assert!((a - b).abs() < 1e-6);
        }
        // Bounded output.
        assert!(q1.iter().all(|v| v.abs() <= maxabs * 1.000001));
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn random_value(rng: &mut Rng, depth: usize) -> Value {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.bool()),
            2 => Value::Num((rng.gaussian() * 100.0 * 8.0).round() / 8.0),
            3 => {
                let len = rng.below(12);
                let s: String = (0..len)
                    .map(|_| {
                        let c = rng.below(96) as u8 + 32;
                        c as char
                    })
                    .collect();
                Value::Str(s)
            }
            4 => Value::Arr((0..rng.below(5)).map(|_| random_value(rng, depth - 1)).collect()),
            _ => {
                let m = (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_value(rng, depth - 1)))
                    .collect();
                Value::Obj(m)
            }
        }
    }
    let mut rng = Rng::seed_from(909);
    for _ in 0..200 {
        let v = random_value(&mut rng, 3);
        let text = v.to_string();
        let re = json::parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
        assert_eq!(re, v, "roundtrip failed for {text}");
    }
}

#[test]
fn prop_config_key_collision_free_on_small_sets() {
    // Hash keys must distinguish every config in a realistic search run.
    let mut rng = Rng::seed_from(1010);
    let n = 26;
    let mut seen = std::collections::HashMap::new();
    for _ in 0..2000 {
        let mut c = QuantConfig::float(n);
        for i in 0..n {
            c.set_layer(i, [4.0, 8.0, 16.0][rng.below(3)]);
        }
        if let Some(prev) = seen.insert(c.key(), c.clone()) {
            assert_eq!(prev, c, "hash collision between distinct configs");
        }
    }
}
