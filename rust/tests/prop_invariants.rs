//! Randomized property tests over the coordinator invariants (DESIGN.md §7).
//! No artifacts or PJRT device needed — these run against synthetic
//! evaluation environments with known structure, using the in-tree seeded
//! RNG for reproducible case generation.

use mpq::api::synthetic_sensitivity;
use mpq::coordinator::{EvalCache, EvalResult, SearchAlgo, SearchEnv};
use mpq::quant::{eps_qe, quantize, QuantConfig, FLOAT_BITS, QUANT_BITS};
use mpq::sensitivity::{levenshtein, Sensitivity, MetricKind};
use mpq::server::{LatencyRing, ServeRecorder};
use mpq::util::json::{self, Value};
use mpq::util::rng::Rng;

const CASES: usize = 60;

/// Separable monotone environment: accuracy = 1 - Σ penalty_i · q(bits_i).
struct MonotoneEnv {
    penalty: Vec<f64>,
    evals: usize,
}

impl MonotoneEnv {
    fn random(rng: &mut Rng, n: usize) -> Self {
        let penalty = (0..n)
            .map(|_| if rng.uniform() < 0.3 { rng.uniform() * 0.2 } else { rng.uniform() * 1e-3 })
            .collect();
        Self { penalty, evals: 0 }
    }

    fn order_by_penalty(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.penalty.len()).collect();
        idx.sort_by(|&a, &b| self.penalty[a].partial_cmp(&self.penalty[b]).unwrap());
        idx
    }
}

impl SearchEnv for MonotoneEnv {
    fn num_layers(&self) -> usize {
        self.penalty.len()
    }

    fn eval(&mut self, cfg: &QuantConfig, _t: Option<f64>) -> mpq::Result<EvalResult> {
        self.evals += 1;
        let cost: f64 = cfg
            .bits_w
            .iter()
            .enumerate()
            .map(|(i, &b)| self.penalty[i] * f64::from(16.0 - b) / 12.0)
            .sum();
        Ok(EvalResult { loss: cost, accuracy: 1.0 - cost, exact: true })
    }
}

fn valid_bits(cfg: &QuantConfig) -> bool {
    cfg.bits_w
        .iter()
        .chain(cfg.bits_a.iter())
        .all(|b| QUANT_BITS.contains(b) || *b == FLOAT_BITS)
}

#[test]
fn prop_greedy_meets_target_and_returns_valid_configs() {
    let mut rng = Rng::seed_from(101);
    for case in 0..CASES {
        let n = 1 + rng.below(40);
        let mut env = MonotoneEnv::random(&mut rng, n);
        let order = env.order_by_penalty();
        let target = 0.9 + rng.uniform() * 0.1;
        let out = SearchAlgo::Greedy.run(&mut env, &order, &QUANT_BITS, target).unwrap();
        assert!(valid_bits(&out.config), "case {case}: invalid bits {:?}", out.config.bits_w);
        // The float config trivially satisfies any target <= 1; greedy must
        // never return a config below target in a monotone env.
        assert!(out.accuracy >= target - 1e-12, "case {case}: {} < {target}", out.accuracy);
        // Eval budget: paper's worst case bN plus the final exact eval.
        assert!(out.evals <= QUANT_BITS.len() * n + 1, "case {case}: budget");
    }
}

#[test]
fn prop_greedy_monotone_in_target() {
    // A stricter target can never produce a *smaller* (more compressed)
    // model in a separable monotone environment.
    let mut rng = Rng::seed_from(202);
    for _ in 0..CASES {
        let n = 2 + rng.below(24);
        let seed_env = MonotoneEnv::random(&mut rng, n);
        let order = seed_env.order_by_penalty();
        let run = |target: f64| {
            let mut env = MonotoneEnv { penalty: seed_env.penalty.clone(), evals: 0 };
            SearchAlgo::Greedy.run(&mut env, &order, &QUANT_BITS, target).unwrap()
        };
        let loose = run(0.95);
        let strict = run(0.999);
        let bits_sum = |c: &QuantConfig| c.bits_w.iter().sum::<f32>();
        assert!(
            bits_sum(&strict.config) >= bits_sum(&loose.config) - 1e-6,
            "stricter target must keep at least as many bits"
        );
    }
}

#[test]
fn prop_bisection_valid_and_within_budget() {
    let mut rng = Rng::seed_from(303);
    for case in 0..CASES {
        let n = 1 + rng.below(60);
        let mut env = MonotoneEnv::random(&mut rng, n);
        // Adversarial (random) ordering — bisection must still terminate
        // and return a valid config, even if compression suffers.
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let target = 0.9 + rng.uniform() * 0.1;
        let out = SearchAlgo::Bisection.run(&mut env, &order, &QUANT_BITS, target).unwrap();
        assert!(valid_bits(&out.config), "case {case}");
        // O(b log N) + slack; generous but catches runaway loops.
        let budget = QUANT_BITS.len() * (2 * (n as f64).log2().ceil() as usize + 6) + 1;
        assert!(out.evals <= budget, "case {case}: {} evals > {budget} (n={n})", out.evals);
    }
}

#[test]
fn prop_bisection_respects_threshold_structure() {
    // In a threshold environment with the true ordering, bisection must
    // recover the exact thresholds (its structural assumption).
    struct ThresholdEnv {
        pos: Vec<usize>,
        ok8: usize,
        ok4: usize,
    }
    impl SearchEnv for ThresholdEnv {
        fn num_layers(&self) -> usize {
            self.pos.len()
        }
        fn eval(&mut self, cfg: &QuantConfig, _t: Option<f64>) -> mpq::Result<EvalResult> {
            let ok = cfg.bits_w.iter().enumerate().all(|(l, &b)| {
                if b <= 4.0 {
                    self.pos[l] < self.ok4
                } else if b <= 8.0 {
                    self.pos[l] < self.ok8
                } else {
                    true
                }
            });
            Ok(EvalResult { loss: 0.0, accuracy: if ok { 1.0 } else { 0.0 }, exact: true })
        }
    }
    let mut rng = Rng::seed_from(404);
    for _ in 0..CASES {
        let n = 1 + rng.below(48);
        let ok8 = rng.below(n + 1);
        let ok4 = rng.below(ok8 + 1);
        let order: Vec<usize> = (0..n).collect();
        let mut env = ThresholdEnv { pos: order.clone(), ok8, ok4 };
        let out = SearchAlgo::Bisection.run(&mut env, &order, &QUANT_BITS, 0.5).unwrap();
        for l in 0..n {
            let expect = if l < ok4 {
                4.0
            } else if l < ok8 {
                8.0
            } else {
                16.0
            };
            assert_eq!(out.config.layer_bits(l), expect, "n={n} ok8={ok8} ok4={ok4} layer={l}");
        }
    }
}

#[test]
fn prop_greedy_usually_beats_bisection_on_monotone_envs() {
    // The paper's empirical claim (Table 2): greedy compresses at least as
    // well as bisection. At the first bit level with a correct ordering
    // greedy's accepted set is a superset of bisection's prefix; at lower
    // levels the diverged budgets can occasionally flip a case, so the
    // claim is statistical, not per-case.
    let mut rng = Rng::seed_from(505);
    let mut greedy_wins = 0usize;
    let mut ties = 0usize;
    for _ in 0..CASES {
        let n = 4 + rng.below(32);
        let base = MonotoneEnv::random(&mut rng, n);
        // Noisy ordering (a few random adjacent swaps): with a perfect
        // ordering both algorithms select the identical prefix; greedy's
        // advantage — the paper's point — is robustness to mis-ordering.
        let mut order = base.order_by_penalty();
        for _ in 0..(n / 3).max(1) {
            let i = rng.below(n - 1);
            order.swap(i, i + 1);
        }
        let mut e1 = MonotoneEnv { penalty: base.penalty.clone(), evals: 0 };
        let mut e2 = MonotoneEnv { penalty: base.penalty.clone(), evals: 0 };
        let g = SearchAlgo::Greedy.run(&mut e1, &order, &QUANT_BITS, 0.99).unwrap();
        let b = SearchAlgo::Bisection.run(&mut e2, &order, &QUANT_BITS, 0.99).unwrap();
        let sum = |c: &QuantConfig| c.bits_w.iter().sum::<f32>();
        if sum(&g.config) < sum(&b.config) - 1e-6 {
            greedy_wins += 1;
        } else if sum(&g.config) <= sum(&b.config) + 1e-6 {
            ties += 1;
        }
        // Both must always respect the accuracy floor.
        assert!(g.accuracy >= 0.99 - 1e-12);
        assert!(b.accuracy >= 0.99 - 1e-12);
    }
    assert!(
        greedy_wins + ties >= CASES * 8 / 10,
        "greedy should win or tie in >=80% of cases (won {greedy_wins}, tied {ties})"
    );
    assert!(greedy_wins > 0, "greedy should strictly win on some cases");
}

#[test]
fn prop_random_sensitivity_is_seeded_permutation() {
    let mut rng = Rng::seed_from(606);
    for _ in 0..CASES {
        let n = 1 + rng.below(64);
        let seed = rng.next_u64();
        let a = Sensitivity::random(n, seed);
        let b = Sensitivity::random(n, seed);
        assert_eq!(a.order, b.order);
        let mut sorted = a.order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..n).collect::<Vec<_>>());
        assert_eq!(a.metric, MetricKind::Random);
    }
}

#[test]
fn prop_every_metric_yields_finite_deterministic_scores() {
    // Every sensitivity metric — including the cross-layer one — must
    // produce one finite score per layer, induce a permutation ordering,
    // and be a pure function of (layers, trials, seed): re-running with
    // the same seed is bit-identical, a different seed is not (except for
    // degenerate single-layer models, where some orderings coincide).
    let mut rng = Rng::seed_from(1212);
    for case in 0..12 {
        // Small-ish shapes: the inter-layer grid is O(n^2 · trials).
        let layers = 1 + rng.below(12);
        let trials = 1 + rng.below(4);
        let seed = rng.next_u64();
        let workers = 1 + rng.below(3);
        for metric in MetricKind::ALL {
            let what = format!("case {case} {} n={layers} t={trials}", metric.label());
            let a = synthetic_sensitivity(metric, layers, trials, seed, workers).unwrap();
            assert_eq!(a.metric, metric, "{what}");
            assert_eq!(a.scores.len(), layers, "{what}");
            assert!(a.scores.iter().all(|s| s.is_finite()), "{what}: {:?}", a.scores);
            let mut sorted = a.order.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..layers).collect::<Vec<_>>(), "{what}: not a permutation");
            // Scores must induce exactly the published order.
            let re = Sensitivity::from_scores(metric, a.scores.clone());
            assert_eq!(re.order, a.order, "{what}");
            // Deterministic per seed at a different worker count...
            let b = synthetic_sensitivity(metric, layers, trials, seed, workers % 3 + 1).unwrap();
            let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&a.scores), bits(&b.scores), "{what}: worker count leaked");
            // ...and seed-addressed: a fresh seed must move at least one
            // score. Random is exempt — its rank-valued scores can
            // legitimately coincide for small models (1/n! chance per
            // seed pair); its seeding is covered by the dedicated
            // permutation test above.
            if layers > 1 && metric != MetricKind::Random {
                let c = synthetic_sensitivity(metric, layers, trials, seed ^ 0xDEAD, workers)
                    .unwrap();
                assert_ne!(bits(&a.scores), bits(&c.scores), "{what}: seed ignored");
            }
        }
    }
}

#[test]
fn prop_levenshtein_metric_axioms() {
    let mut rng = Rng::seed_from(707);
    for _ in 0..CASES {
        let n = rng.below(24);
        let mut a: Vec<usize> = (0..n).collect();
        let mut b: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut a);
        rng.shuffle(&mut b);
        assert_eq!(levenshtein(&a, &a), 0);
        assert_eq!(levenshtein(&a, &b), levenshtein(&b, &a));
        assert!(levenshtein(&a, &b) <= n);
    }
}

#[test]
fn prop_quantizer_invariants() {
    let mut rng = Rng::seed_from(808);
    for _ in 0..CASES {
        let n = 1 + rng.below(512);
        let x: Vec<f32> = (0..n).map(|_| (rng.gaussian() * 3.0) as f32).collect();
        let maxabs = x.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-12);
        // Monotone error in bits.
        let e = [2.0, 4.0, 8.0].map(|b| eps_qe(&x, b));
        assert!(e[0] >= e[1] && e[1] >= e[2]);
        assert_eq!(eps_qe(&x, 16.0), 0.0);
        // Projection: Q(Q(x)) == Q(x).
        let q1 = quantize(&x, 1.0 / maxabs, maxabs, 4.0);
        let q2 = quantize(&q1, 1.0 / maxabs, maxabs, 4.0);
        for (a, b) in q1.iter().zip(&q2) {
            assert!((a - b).abs() < 1e-6);
        }
        // Bounded output.
        assert!(q1.iter().all(|v| v.abs() <= maxabs * 1.000001));
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn random_value(rng: &mut Rng, depth: usize) -> Value {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.bool()),
            2 => Value::Num((rng.gaussian() * 100.0 * 8.0).round() / 8.0),
            3 => {
                let len = rng.below(12);
                let s: String = (0..len)
                    .map(|_| {
                        let c = rng.below(96) as u8 + 32;
                        c as char
                    })
                    .collect();
                Value::Str(s)
            }
            4 => Value::Arr((0..rng.below(5)).map(|_| random_value(rng, depth - 1)).collect()),
            _ => {
                let m = (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_value(rng, depth - 1)))
                    .collect();
                Value::Obj(m)
            }
        }
    }
    let mut rng = Rng::seed_from(909);
    for _ in 0..200 {
        let v = random_value(&mut rng, 3);
        let text = v.to_string();
        let re = json::parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
        assert_eq!(re, v, "roundtrip failed for {text}");
    }
}

// --------------------------------------------------- serving statistics

#[test]
fn prop_serve_percentiles_stay_within_observed_bounds() {
    // Random shard layouts, batch sizes, and latencies: every percentile
    // of the merged snapshot must sit inside the observed min/max, with
    // p=0 and p=1 hitting the retained extremes exactly.
    let mut rng = Rng::seed_from(1111);
    for case in 0..CASES {
        let workers = 1 + rng.below(4);
        let recorder = ServeRecorder::new(workers, 64 * workers);
        let mut min = u64::MAX;
        let mut max = 0u64;
        let batches = 1 + rng.below(40);
        for _ in 0..batches {
            let n = 1 + rng.below(6);
            let lats: Vec<u64> = (0..n).map(|_| rng.below(1_000_000) as u64).collect();
            min = min.min(*lats.iter().min().unwrap());
            max = max.max(*lats.iter().max().unwrap());
            recorder.record_batch(rng.below(workers), &lats, 0);
        }
        let stats = recorder.snapshot();
        for _ in 0..16 {
            let p = rng.uniform();
            let v = stats.percentile_us(p);
            assert!(v >= min && v <= max, "case {case}: p{p} = {v} outside [{min}, {max}]");
        }
        assert!(stats.percentile_us(0.0) >= min);
        assert!(stats.percentile_us(1.0) <= max);
        // Out-of-range p clamps instead of panicking.
        assert_eq!(stats.percentile_us(-0.5), stats.percentile_us(0.0));
        assert_eq!(stats.percentile_us(1.5), stats.percentile_us(1.0));
        let mean = stats.mean_us();
        assert!(mean >= min as f64 && mean <= max as f64, "case {case}: mean {mean}");
    }
}

#[test]
fn serve_percentiles_empty_single_and_exact_boundaries() {
    // Empty recorder: every percentile (and the mean) is 0, not a panic.
    let empty = ServeRecorder::new(2, 128).snapshot();
    for p in [0.0, 0.5, 0.99, 1.0] {
        assert_eq!(empty.percentile_us(p), 0);
    }
    assert_eq!(empty.mean_us(), 0.0);

    // A single sample answers every quantile with itself.
    let one = ServeRecorder::new(1, 64);
    one.record_batch(0, &[1234], 0);
    let s = one.snapshot();
    for p in [0.0, 0.25, 0.5, 0.999, 1.0] {
        assert_eq!(s.percentile_us(p), 1234);
    }

    // Exact-boundary quantiles on a known ladder: rank interpolation, not
    // rounded ranks (p50 of [10, 20, 30, 40] is 25).
    let rec = ServeRecorder::new(1, 64);
    rec.record_batch(0, &[10, 20, 30, 40], 0);
    let s = rec.snapshot();
    assert_eq!(s.percentile_us(0.0), 10);
    assert_eq!(s.percentile_us(0.5), 25);
    assert_eq!(s.percentile_us(1.0), 40);
    // Quantiles landing exactly on a rank return that sample unchanged.
    assert_eq!(s.percentile_us(1.0 / 3.0), 20);
    assert_eq!(s.percentile_us(2.0 / 3.0), 30);
}

#[test]
fn serve_percentiles_survive_latency_ring_wraparound() {
    // Push far more samples than the ring retains: percentiles must come
    // from the retained (most recent) window and stay within its bounds.
    let rec = ServeRecorder::new(1, 64); // one shard, 64-sample ring
    for i in 0..10_000u64 {
        rec.record_batch(0, &[i], 0);
    }
    let s = rec.snapshot();
    assert_eq!(s.requests, 10_000);
    let (lo, hi) = (s.percentile_us(0.0), s.percentile_us(1.0));
    assert!(lo >= 9_936 && hi <= 9_999, "retained window is the newest 64: [{lo}, {hi}]");
    for p in [0.1, 0.5, 0.9, 0.99] {
        let v = s.percentile_us(p);
        assert!(v >= lo && v <= hi, "p{p} = {v} escaped [{lo}, {hi}]");
    }
    // The ring itself reports both retained and lifetime counts.
    let mut ring = LatencyRing::new(8);
    for i in 0..100u64 {
        ring.push(i);
    }
    assert_eq!(ring.samples().len(), 8);
    assert_eq!(ring.total(), 100);
}

// ------------------------------------------------------------ eval cache

fn cache_tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("mpq_prop_evalcache_{name}.json"))
}

fn exact(loss: f64, acc: f64) -> EvalResult {
    EvalResult { loss, accuracy: acc, exact: true }
}

#[test]
fn eval_cache_lru_order_survives_roundtrip_and_stats_accumulate() {
    let path = cache_tmp("roundtrip");
    let _ = std::fs::remove_file(&path);

    // Session 1: fill a bounded cache and establish a recency order by
    // touching entries 1 and 3 after inserting 1..=4.
    let mut c = EvalCache::with_capacity(&path, "ctx", Some(4));
    for k in 1..=4u64 {
        c.insert(k, &exact(k as f64 * 0.1, 1.0 - k as f64 * 0.1));
    }
    assert!(c.lookup(1).is_some());
    assert!(c.lookup(3).is_some());
    c.save().unwrap();
    let session1_hits = c.hits();
    assert_eq!(session1_hits, 2);
    drop(c);

    // Session 2: the persisted recency order decides eviction — inserting
    // two fresh keys must evict exactly the least-recently-used 2 and 4.
    let mut re = EvalCache::with_capacity(&path, "ctx", Some(4));
    assert_eq!(re.len(), 4);
    assert_eq!(re.lifetime_hits(), session1_hits as u64);
    re.insert(5, &exact(0.5, 0.5));
    re.insert(6, &exact(0.6, 0.4));
    assert!(re.lookup(2).is_none(), "oldest entry must be evicted first");
    assert!(re.lookup(4).is_none(), "second-oldest goes next");
    for k in [1u64, 3, 5, 6] {
        assert!(re.lookup(k).is_some(), "key {k} must survive");
    }
    assert_eq!(re.evictions(), 2);
    re.save().unwrap();
    drop(re);

    // Session 3: cumulative hit/evict stats accumulated across sessions.
    let third = EvalCache::load(&path, "ctx");
    assert_eq!(third.lifetime_hits(), 2 + 4, "2 hits (s1) + 4 hits (s2); misses don't count");
    assert_eq!(third.lifetime_evictions(), 2);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn eval_cache_capacity_shrink_evicts_in_recency_order() {
    let path = cache_tmp("shrink");
    let _ = std::fs::remove_file(&path);
    let mut c = EvalCache::load(&path, "ctx"); // unbounded
    for k in 1..=5u64 {
        c.insert(k, &exact(0.1, 0.9));
    }
    // Refresh 2 then 1: recency order is now 3 < 4 < 5 < 2 < 1.
    assert!(c.lookup(2).is_some());
    assert!(c.lookup(1).is_some());
    c.set_capacity(Some(2));
    assert_eq!(c.len(), 2);
    assert_eq!(c.evictions(), 3);
    assert!(c.lookup(1).is_some(), "most recent survives");
    assert!(c.lookup(2).is_some(), "second most recent survives");
    for k in [3u64, 4, 5] {
        assert!(c.lookup(k).is_none(), "key {k} should have been evicted");
    }
    // Shrinking below an already-met bound is a no-op.
    c.set_capacity(Some(2));
    assert_eq!(c.len(), 2);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn prop_config_key_collision_free_on_small_sets() {
    // Hash keys must distinguish every config in a realistic search run.
    let mut rng = Rng::seed_from(1010);
    let n = 26;
    let mut seen = std::collections::HashMap::new();
    for _ in 0..2000 {
        let mut c = QuantConfig::float(n);
        for i in 0..n {
            c.set_layer(i, [4.0, 8.0, 16.0][rng.below(3)]);
        }
        if let Some(prev) = seen.insert(c.key(), c.clone()) {
            assert_eq!(prev, c, "hash collision between distinct configs");
        }
    }
}
