//! Parity and correctness properties for the inter-layer-augmented
//! Hessian metric: at 1, 2 and 8 workers,
//! [`interlayer_scores_sharded`] must produce *bit-identical* scores —
//! the same contract `sharded_noise.rs` asserts for ε_N. No artifacts or
//! PJRT device needed: [`SyntheticStage`] runs the real driver (pair-major
//! grid flattening, scatter over scoped threads, fixed-order host
//! reduction) over deterministic per-item math. Also covers the
//! `(i, j, trial)` pair-seed addressing, the symmetric coupling matrix,
//! the planted-coupling reordering that diagonal-only metrics must miss,
//! and the per-metric stale-cache recompute gate introduced with the v4
//! schema bump.

use mpq::api::{synthetic_sensitivity, ModelContext, SyntheticStage};
use mpq::coordinator::{
    hessian_trace_sharded, interlayer_reduction_sharded, interlayer_scores_sharded,
    noise_scores_sharded,
};
use mpq::quant::calibrate::{pair_at, pair_count, pair_index};
use mpq::sensitivity::{MetricKind, ScoreCache, Sensitivity};
use mpq::util::json::Value;
use mpq::util::rng::{noise_seed, pair_seed, probe_seed};

const WORKER_COUNTS: [usize; 3] = [1, 2, 8];
const LAMBDA: f64 = 0.05;

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn interlayer_scores_bit_identical_across_worker_counts() {
    // Grid shapes chosen so the flattened pair-major (pair, trial) items
    // split unevenly across workers — including fewer items than workers.
    for (layers, trials) in [(6usize, 3usize), (4, 1), (9, 5), (1, 2), (2, 16)] {
        let mut reference: Option<Vec<f64>> = None;
        for workers in WORKER_COUNTS {
            let mut stage = SyntheticStage::new(layers, 8, workers, 42);
            let scores = interlayer_scores_sharded(&mut stage, LAMBDA, trials, 7).unwrap();
            assert_eq!(scores.len(), layers);
            match &reference {
                None => reference = Some(scores),
                Some(r) => {
                    let what = format!("layers {layers} trials {trials} workers {workers}");
                    assert_eq!(bits(&scores), bits(r), "{what}");
                }
            }
        }
    }
}

#[test]
fn pair_draws_are_pair_seed_addressed() {
    // Different base seeds must perturb differently...
    let mut a = SyntheticStage::new(5, 8, 2, 13);
    let mut b = SyntheticStage::new(5, 8, 2, 13);
    let sa = interlayer_scores_sharded(&mut a, LAMBDA, 3, 1).unwrap();
    let sb = interlayer_scores_sharded(&mut b, LAMBDA, 3, 2).unwrap();
    assert_ne!(sa, sb, "different seeds must give different scores");
    // ...and more trials must change the averages (the grid is
    // (pair, trial)-addressed, not a shared stream that happens to
    // coincide on a prefix).
    let mut c = SyntheticStage::new(5, 8, 2, 13);
    let sc = interlayer_scores_sharded(&mut c, LAMBDA, 4, 1).unwrap();
    assert_ne!(sa, sc, "trial count is part of the addressing");

    // The pair seeds themselves: stable, symmetric in the unordered pair,
    // and collision-free against both the Hessian probe stream and the
    // ε_N noise stream under the same base seed.
    assert_eq!(pair_seed(7, 1, 3, 2), pair_seed(7, 1, 3, 2));
    assert_eq!(pair_seed(7, 3, 1, 2), pair_seed(7, 1, 3, 2));
    let mut seeds: Vec<u64> = Vec::new();
    for t in 0..8u64 {
        seeds.push(probe_seed(42, t));
    }
    for l in 0..8u64 {
        for t in 0..8u64 {
            seeds.push(noise_seed(42, l, t));
        }
    }
    for i in 0..8u64 {
        for j in i..8u64 {
            for t in 0..8u64 {
                seeds.push(pair_seed(42, i, j, t));
            }
        }
    }
    let total = seeds.len();
    seeds.sort_unstable();
    seeds.dedup();
    assert_eq!(seeds.len(), total, "probe/noise/pair seed domains collided");
}

#[test]
fn coupling_matrix_is_symmetric_with_zero_diagonal() {
    let n = 5usize;
    // The flat pair grid round-trips through its index maps.
    for i in 0..n {
        for j in i..n {
            assert_eq!(pair_at(n, pair_index(n, i, j)), (i, j));
            assert_eq!(pair_index(n, j, i), pair_index(n, i, j));
        }
    }
    assert_eq!(pair_count(n), 15);

    let mut stage = SyntheticStage::new(n, 8, 3, 17);
    let red = interlayer_reduction_sharded(&mut stage, LAMBDA, 4, 9).unwrap();
    assert_eq!(red.base.len(), n);
    assert_eq!(red.coupling.len(), n * n);
    assert_eq!(red.scores.len(), n);
    for i in 0..n {
        assert_eq!(red.coupling[i * n + i].to_bits(), 0.0f64.to_bits(), "diagonal must be zero");
        for j in 0..n {
            assert_eq!(
                red.coupling[i * n + j].to_bits(),
                red.coupling[j * n + i].to_bits(),
                "coupling({i},{j}) must equal coupling({j},{i}) bit-for-bit"
            );
        }
    }
    // Scores are exactly base + row sums, accumulated in j-ascending order.
    for i in 0..n {
        let mut expect = red.base[i];
        for j in 0..n {
            if j != i {
                expect += red.coupling[i * n + j];
            }
        }
        assert_eq!(red.scores[i].to_bits(), expect.to_bits());
    }
}

/// The tentpole's analytic fixture: 4 layers whose diagonal degradations
/// grow strictly with layer index, plus a planted coupling between layers
/// 0 and 1 (see `SyntheticStage::planted_coupling`). Diagonal-only
/// metrics (ε_N noise, the interaction-free `base` term, and the
/// Hutchinson Hessian trace) cannot see the coupling, so they must not
/// rank the coupled pair on top — the cross-layer metric must.
#[test]
fn planted_coupling_reorders_what_diagonal_metrics_miss() {
    let (n, trials, stage_seed, metric_seed) = (4usize, 32usize, 13u64, 11u64);
    let mut stage = SyntheticStage::new(n, 8, 2, stage_seed);
    let red = interlayer_reduction_sharded(&mut stage, LAMBDA, trials, metric_seed).unwrap();

    // Only the planted (0, 1) pair carries an interaction; every other
    // finite difference cancels to rounding noise because the paired run
    // reuses the exact diagonal draws.
    assert!(red.coupling[1] > 0.1, "planted coupling must be visible, got {}", red.coupling[1]);
    for i in 0..n {
        for j in 0..n {
            if (i.min(j), i.max(j)) != (0, 1) {
                let c = red.coupling[i * n + j];
                assert!(c < 1e-9, "unplanted pair ({i},{j}) must not couple, got {c}");
            }
        }
    }

    // Diagonal-only view: strictly ordered by layer index — the coupled
    // layers look *least* sensitive without the cross term.
    let base_order = Sensitivity::from_scores(MetricKind::Noise, red.base.clone()).order;
    assert_eq!(base_order, vec![0, 1, 2, 3], "base term must order by layer index");

    // Cross-layer view: the coupled pair {0, 1} is the most sensitive.
    let il = Sensitivity::from_scores(MetricKind::InterLayer, red.scores.clone());
    let mut top2 = [il.order[n - 2], il.order[n - 1]];
    top2.sort_unstable();
    assert_eq!(top2, [0, 1], "coupled layers must rank most sensitive, order {:?}", il.order);
    assert!(red.scores[0] > red.scores[3], "coupling must outweigh the diagonal gap");

    // ε_N over the same stage misses the reordering entirely...
    let mut stage = SyntheticStage::new(n, 8, 2, stage_seed);
    let noise = noise_scores_sharded(&mut stage, LAMBDA, trials, metric_seed).unwrap();
    let noise_order = Sensitivity::from_scores(MetricKind::Noise, noise).order;
    assert_eq!(noise_order, vec![0, 1, 2, 3], "noise must order by layer index");

    // ...and so does the plain Hessian trace: its top-2 is never the
    // coupled pair (the synthetic per-element traces are flat across
    // layers, so nothing pushes 0 and 1 jointly to the front).
    let mut stage = SyntheticStage::new(n, 8, 2, stage_seed);
    let hessian = hessian_trace_sharded(&mut stage, trials, metric_seed).unwrap();
    let h_order = Sensitivity::from_scores(MetricKind::Hessian, hessian).order;
    let mut h_top2 = [h_order[n - 2], h_order[n - 1]];
    h_top2.sort_unstable();
    assert_ne!(h_top2, [0, 1], "plain Hessian must miss the planted coupling");

    // The shared synthetic stand-in routes through the same driver:
    // byte-identical to running it by hand with stage seed == metric seed.
    let mut stage = SyntheticStage::new(n, 8, 2, metric_seed);
    let direct = interlayer_scores_sharded(&mut stage, LAMBDA, trials, metric_seed).unwrap();
    let sens = synthetic_sensitivity(MetricKind::InterLayer, n, trials, metric_seed, 2).unwrap();
    assert_eq!(sens.metric, MetricKind::InterLayer);
    assert_eq!(bits(&sens.scores), bits(&direct));
}

// ------------------------------------------------- per-metric cache gating

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("mpq_interlayer_cache_{name}"));
    let _ = std::fs::create_dir_all(&dir);
    dir
}

fn write_versioned(path: &std::path::Path, version: usize, scores: &[f64]) {
    let v = Value::obj(vec![
        ("version", Value::Num(version as f64)),
        ("scores", Value::Arr(scores.iter().map(|&s| Value::Num(s)).collect())),
    ]);
    std::fs::write(path, v.to_string()).unwrap();
}

#[test]
fn v4_bump_invalidates_per_metric_not_whole_cache() {
    // The v4 bump introduced the inter-layer metric without touching any
    // existing metric's draw scheme, so only inter-layer entries demand
    // the new version.
    assert_eq!(ScoreCache::VERSION, 4);
    assert_eq!(ModelContext::SENS_CACHE_VERSION, ScoreCache::VERSION);
    assert_eq!(ScoreCache::min_version_for(MetricKind::InterLayer), 4);
    for metric in [MetricKind::Random, MetricKind::Qe, MetricKind::Noise, MetricKind::Hessian] {
        assert_eq!(ScoreCache::min_version_for(metric), 3, "{}", metric.label());
    }

    let dir = tmp_dir("gate");
    let scores = vec![0.25f64, 0.5, 0.75];

    // A v3 file under the inter-layer entry predates the metric: reject.
    let il = ScoreCache::for_model(&dir, "m", MetricKind::InterLayer, 3, 7);
    write_versioned(il.path(), 3, &scores);
    assert_eq!(il.load(3), None, "v3 inter-layer cache must recompute");

    // The same v3 bytes under a Hessian entry survive the upgrade: the
    // Hessian draws have been stable since v3.
    let hessian = ScoreCache::for_model(&dir, "m", MetricKind::Hessian, 3, 7);
    write_versioned(hessian.path(), 3, &scores);
    let loaded = hessian.load(3).expect("v3 Hessian cache must survive the v4 bump");
    assert_eq!(bits(&loaded), bits(&scores));

    // v1/v2 files are rejected for every metric, as is a future version.
    write_versioned(hessian.path(), 2, &scores);
    assert_eq!(hessian.load(3), None, "v2 file must recompute");
    write_versioned(hessian.path(), 5, &scores);
    assert_eq!(hessian.load(3), None, "future version must recompute");

    // A freshly saved inter-layer entry round-trips at the current version.
    il.save(&scores);
    let loaded = il.load(3).expect("current-version inter-layer cache must load");
    assert_eq!(bits(&loaded), bits(&scores));
    assert_eq!(il.load(4), None, "layer mismatch must recompute");

    // Metric, trials, and seed are all part of the entry's identity.
    assert_ne!(il.path(), hessian.path());
    assert_ne!(
        ScoreCache::for_model(&dir, "m", MetricKind::InterLayer, 4, 7).path(),
        il.path()
    );
    assert_ne!(
        ScoreCache::for_model(&dir, "m", MetricKind::InterLayer, 3, 8).path(),
        il.path()
    );

    let _ = std::fs::remove_dir_all(&dir);
}
