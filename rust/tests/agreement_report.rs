//! Properties of the metric-agreement report: the serialized payload is
//! byte-identical at every worker count (the contract the CI smoke
//! byte-diffs), the grid covers all four informed metrics under both
//! algorithms, deltas are anchored to the same algorithm's Hessian row,
//! and the rendering names the metric pair with the lowest agreement.

use mpq::report::{rank_correlation, AgreementReport, AGREEMENT_METRICS};
use mpq::sensitivity::MetricKind;

#[test]
fn report_payload_is_byte_identical_across_worker_counts() {
    let reference = AgreementReport::synthetic(12, 3, 9, 1, 0.92).unwrap().to_json().to_string();
    for workers in [2usize, 4, 8] {
        let got = AgreementReport::synthetic(12, 3, 9, workers, 0.92).unwrap();
        assert_eq!(
            got.to_json().to_string(),
            reference,
            "agreement payload must not depend on worker count ({workers} workers)"
        );
    }
}

#[test]
fn grid_covers_both_algorithms_and_every_informed_metric() {
    let r = AgreementReport::synthetic(10, 2, 3, 2, 0.9).unwrap();
    // Sensitivities arrive in AGREEMENT_METRICS order; random is excluded
    // (an uninformed permutation has nothing to agree with).
    let metrics: Vec<MetricKind> = r.sensitivities.iter().map(|s| s.metric).collect();
    assert_eq!(metrics, AGREEMENT_METRICS.to_vec());
    assert!(!metrics.contains(&MetricKind::Random));
    for s in &r.sensitivities {
        assert_eq!(s.scores.len(), 10);
        let mut sorted = s.order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..10).collect::<Vec<_>>(), "{} order", s.metric.label());
    }
    // 2 algorithms x 4 metrics, metrics inner.
    assert_eq!(r.cells.len(), 8);
    for (i, cell) in r.cells.iter().enumerate() {
        assert_eq!(cell.metric, AGREEMENT_METRICS[i % 4]);
        assert_eq!(cell.bits.len(), 10);
    }
    // C(4, 2) pairs, each with a finite rho in [-1, 1].
    assert_eq!(r.pairs.len(), 6);
    for p in &r.pairs {
        assert!(p.rho.is_finite() && p.rho.abs() <= 1.0 + 1e-12, "rho={}", p.rho);
        assert!(p.edit_distance <= 10);
        // The stored rho is reproducible from the stored score vectors.
        let a = r.sensitivities.iter().find(|s| s.metric == p.a).unwrap();
        let b = r.sensitivities.iter().find(|s| s.metric == p.b).unwrap();
        assert_eq!(p.rho.to_bits(), rank_correlation(&a.scores, &b.scores).to_bits());
    }
}

#[test]
fn deltas_are_anchored_to_the_same_algorithms_hessian_row() {
    let r = AgreementReport::synthetic(10, 2, 3, 1, 0.9).unwrap();
    let json = r.to_json();
    let cells = json.req("cells").unwrap().as_arr().unwrap();
    assert_eq!(cells.len(), 8);
    for cell in cells {
        let metric = cell.req("metric").unwrap().as_str().unwrap().to_string();
        let d_acc = cell.req("d_accuracy").unwrap().as_f64().unwrap();
        let d_evals = cell.req("d_evals").unwrap().as_f64().unwrap();
        let d_size = cell.req("d_rel_size").unwrap().as_f64().unwrap();
        let d_lat = cell.req("d_rel_latency").unwrap().as_f64().unwrap();
        if metric == "Hessian" {
            // The anchor's deltas against itself are exactly zero.
            for d in [d_acc, d_evals, d_size, d_lat] {
                assert_eq!(d, 0.0, "Hessian row must be its own baseline");
            }
        } else {
            for d in [d_acc, d_evals, d_size, d_lat] {
                assert!(d.is_finite());
            }
        }
    }
    // The payload names the lowest-agreement pair, matching the struct.
    let low = r.lowest_agreement().unwrap();
    let la = json.req("lowest_agreement").unwrap();
    assert_eq!(la.req("a").unwrap().as_str().unwrap(), low.a.label());
    assert_eq!(la.req("b").unwrap().as_str().unwrap(), low.b.label());
    assert!(r.pairs.iter().all(|p| p.rho >= low.rho));
}

#[test]
fn render_names_the_lowest_agreement_pair_and_the_full_grid() {
    let r = AgreementReport::synthetic(8, 2, 5, 1, 0.9).unwrap();
    let text = r.render();
    let low = r.lowest_agreement().unwrap();
    assert!(
        text.contains(&format!(
            "lowest agreement: {} vs {} (rho={:+.3})",
            low.a.label(),
            low.b.label(),
            low.rho,
        )),
        "{text}"
    );
    // Every informed metric shows up under both algorithm rows.
    for mk in AGREEMENT_METRICS {
        assert!(text.contains(mk.label()), "{text}");
    }
    for algo in ["Bisection", "Greedy"] {
        assert!(text.contains(algo), "{text}");
    }
}
