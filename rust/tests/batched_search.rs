//! Parity properties for the batched/parallel search engine: at 1, 2 and 8
//! workers — with and without a cross-run [`EvalCache`] in the loop — both
//! algorithms must return the *same* `SearchOutcome.config`, accuracy and
//! decision-eval count as the plain sequential path. No artifacts or PJRT
//! device needed; randomized synthetic environments with the in-tree
//! seeded RNG.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use mpq::coordinator::{
    EvalCache, EvalResult, ParallelEnv, SearchAlgo, SearchEnv, SearchOutcome, SyncSearchEnv,
};
use mpq::quant::{QuantConfig, QUANT_BITS};
use mpq::util::rng::Rng;

const CASES: usize = 40;
const WORKER_COUNTS: [usize; 3] = [1, 2, 8];

/// Separable monotone environment, shared-state (`&self`) evaluation.
struct MonotoneSync {
    penalty: Vec<f64>,
    evals: AtomicUsize,
}

impl MonotoneSync {
    fn random(rng: &mut Rng, n: usize) -> Self {
        let penalty = (0..n)
            .map(|_| if rng.uniform() < 0.3 { rng.uniform() * 0.2 } else { rng.uniform() * 1e-3 })
            .collect();
        Self { penalty, evals: AtomicUsize::new(0) }
    }

    fn clone_fresh(&self) -> Self {
        Self { penalty: self.penalty.clone(), evals: AtomicUsize::new(0) }
    }

    fn cost(&self, cfg: &QuantConfig) -> f64 {
        cfg.bits_w
            .iter()
            .enumerate()
            .map(|(i, &b)| self.penalty[i] * f64::from(16.0 - b) / 12.0)
            .sum()
    }
}

impl SyncSearchEnv for MonotoneSync {
    fn num_layers(&self) -> usize {
        self.penalty.len()
    }

    fn eval(&self, cfg: &QuantConfig, _t: Option<f64>) -> mpq::Result<EvalResult> {
        self.evals.fetch_add(1, Ordering::Relaxed);
        let cost = self.cost(cfg);
        Ok(EvalResult { loss: cost, accuracy: 1.0 - cost, exact: true })
    }
}

/// An independent, deliberately simple sequential reference: implements
/// `SearchEnv` directly (default `eval_many`, batch hint 1), so the parity
/// tests compare the batched engine against the unbatched code path rather
/// than against itself.
struct SeqRef<'a>(&'a MonotoneSync);

impl SearchEnv for SeqRef<'_> {
    fn num_layers(&self) -> usize {
        self.0.num_layers()
    }

    fn eval(&mut self, cfg: &QuantConfig, t: Option<f64>) -> mpq::Result<EvalResult> {
        SyncSearchEnv::eval(self.0, cfg, t)
    }
}

/// A `SyncSearchEnv` wrapper that routes every evaluation through a shared
/// `EvalCache`, mimicking the pipeline's persistent-cache path on a
/// synthetic environment.
struct Cached<'a> {
    inner: &'a MonotoneSync,
    cache: &'a Mutex<EvalCache>,
}

impl SyncSearchEnv for Cached<'_> {
    fn num_layers(&self) -> usize {
        self.inner.num_layers()
    }

    fn eval(&self, cfg: &QuantConfig, t: Option<f64>) -> mpq::Result<EvalResult> {
        let key = cfg.key();
        if let Some(hit) = self.cache.lock().unwrap().lookup(key) {
            return Ok(hit);
        }
        let r = SyncSearchEnv::eval(self.inner, cfg, t)?;
        self.cache.lock().unwrap().insert(key, &r);
        Ok(r)
    }
}

fn assert_same(a: &SearchOutcome, b: &SearchOutcome, what: &str) {
    assert_eq!(a.config, b.config, "{what}: config");
    assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits(), "{what}: accuracy");
    assert_eq!(a.evals, b.evals, "{what}: decision evals");
}

#[test]
fn prop_greedy_parallel_matches_sequential_at_all_worker_counts() {
    let mut rng = Rng::seed_from(4242);
    for case in 0..CASES {
        let n = 1 + rng.below(40);
        let base = MonotoneSync::random(&mut rng, n);
        // Noisy ordering creates accept/reject flips — the hard case for
        // outcome-adaptive speculation.
        let mut order: Vec<usize> = (0..n).collect();
        if n >= 2 {
            for _ in 0..(n / 3).max(1) {
                let i = rng.below(n - 1);
                order.swap(i, i + 1);
            }
        }
        let target = 0.9 + rng.uniform() * 0.1;
        let seq =
            SearchAlgo::Greedy.run(&mut SeqRef(&base), &order, &QUANT_BITS, target).unwrap();
        for workers in WORKER_COUNTS {
            let env = base.clone_fresh();
            let mut p = ParallelEnv::new(&env, workers);
            let out = SearchAlgo::Greedy.run(&mut p, &order, &QUANT_BITS, target).unwrap();
            assert_same(&out, &seq, &format!("case {case} workers {workers}"));
            // Speculation may waste evals but never misses decisions.
            assert!(p.raw_evals() >= out.evals, "case {case} workers {workers}");
        }
    }
}

#[test]
fn prop_bisection_parallel_matches_sequential_at_all_worker_counts() {
    let mut rng = Rng::seed_from(5252);
    for case in 0..CASES {
        let n = 1 + rng.below(60);
        let base = MonotoneSync::random(&mut rng, n);
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let target = 0.9 + rng.uniform() * 0.1;
        let seq =
            SearchAlgo::Bisection.run(&mut SeqRef(&base), &order, &QUANT_BITS, target).unwrap();
        for workers in WORKER_COUNTS {
            let env = base.clone_fresh();
            let mut p = ParallelEnv::new(&env, workers);
            let out = SearchAlgo::Bisection.run(&mut p, &order, &QUANT_BITS, target).unwrap();
            assert_same(&out, &seq, &format!("case {case} workers {workers}"));
            assert!(p.raw_evals() >= out.evals, "case {case} workers {workers}");
        }
    }
}

#[test]
fn prop_eval_cache_preserves_outcomes_across_runs_and_workers() {
    let dir = std::env::temp_dir().join("mpq_batched_search_cache");
    let _ = std::fs::create_dir_all(&dir);
    let mut rng = Rng::seed_from(6262);
    for case in 0..CASES / 2 {
        let n = 2 + rng.below(24);
        let base = MonotoneSync::random(&mut rng, n);
        let order: Vec<usize> = (0..n).collect();
        let target = 0.9 + rng.uniform() * 0.1;
        let seq =
            SearchAlgo::Greedy.run(&mut SeqRef(&base), &order, &QUANT_BITS, target).unwrap();

        let path = dir.join(format!("case_{case}.json"));
        let _ = std::fs::remove_file(&path);
        let context = format!("monotone-{case}");
        for (run, workers) in [(0usize, 1usize), (1, 2), (2, 8), (3, 8)] {
            // Each run reloads the cache written by the previous one, so
            // later runs answer mostly (finally: entirely) from cache.
            let cache = Mutex::new(EvalCache::load(&path, &context));
            let env = base.clone_fresh();
            let cached = Cached { inner: &env, cache: &cache };
            let mut p = ParallelEnv::new(&cached, workers);
            let out = SearchAlgo::Greedy.run(&mut p, &order, &QUANT_BITS, target).unwrap();
            assert_same(&out, &seq, &format!("case {case} run {run} workers {workers}"));
            let mut guard = cache.lock().unwrap();
            if run > 0 {
                assert!(guard.hits() > 0, "case {case} run {run}: cache never hit");
            }
            if run == 3 {
                // Same worker count as run 2 -> identical frontier, so
                // every evaluation must now be a cache hit.
                assert_eq!(
                    env.evals.load(Ordering::Relaxed),
                    0,
                    "case {case}: warm rerun still touched the environment"
                );
            }
            guard.save().unwrap();
        }
        let _ = std::fs::remove_file(&path);
    }
}

#[test]
fn degenerate_inputs_match_sequential() {
    // Zero layers and empty bit lists through the parallel adapter.
    let env = MonotoneSync { penalty: vec![], evals: AtomicUsize::new(0) };
    for workers in WORKER_COUNTS {
        for algo in [SearchAlgo::Greedy, SearchAlgo::Bisection] {
            let mut p = ParallelEnv::new(&env, workers);
            let out = algo.run(&mut p, &[], &QUANT_BITS, 0.99).unwrap();
            assert_eq!(out.config.num_layers(), 0);
        }
    }
    let one = MonotoneSync { penalty: vec![0.0], evals: AtomicUsize::new(0) };
    for workers in WORKER_COUNTS {
        let mut p = ParallelEnv::new(&one, workers);
        let out = SearchAlgo::Greedy.run(&mut p, &[0], &[], 0.5).unwrap();
        assert_eq!(out.config, QuantConfig::float(1));
    }
}
