//! Integration tests for the multi-worker serving engine, driven against
//! a stub [`ServingBackend`] — no artifacts or PJRT device needed. The
//! stub's workers are plain threads that echo a function of each input,
//! optionally after a fixed delay so saturation, deadlines and admission
//! control become observable.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use mpq::runtime::HostTensor;
use mpq::server::{serve_with_backend, BatchJob, ServeOptions, ServerHandle, ServingBackend};

/// Per-row stub model: `y = 2x + 1` on the first element of each example.
fn stub_flat(job: &BatchJob) -> Vec<f32> {
    let mut flat = vec![0.0f32; job.bucket()];
    for (i, x) in job.xs().iter().enumerate() {
        if let Some(data) = x.f32_data() {
            flat[i] = data[0] * 2.0 + 1.0;
        }
    }
    flat
}

struct StubBackend {
    txs: Vec<mpsc::Sender<BatchJob>>,
    joins: Vec<thread::JoinHandle<()>>,
    sizes: Vec<usize>,
}

impl StubBackend {
    fn new(workers: usize, sizes: &[usize], delay: Duration) -> Self {
        let mut txs = Vec::new();
        let mut joins = Vec::new();
        for _ in 0..workers {
            let (tx, rx) = mpsc::channel::<BatchJob>();
            joins.push(thread::spawn(move || {
                while let Ok(job) = rx.recv() {
                    if !delay.is_zero() {
                        thread::sleep(delay);
                    }
                    let flat = stub_flat(&job);
                    job.complete(Ok(flat));
                }
            }));
            txs.push(tx);
        }
        Self { txs, joins, sizes: sizes.to_vec() }
    }
}

impl ServingBackend for StubBackend {
    fn num_workers(&self) -> usize {
        self.txs.len()
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.sizes.to_vec()
    }

    fn submit(&mut self, w: usize, job: BatchJob) {
        if let Err(mpsc::SendError(job)) = self.txs[w].send(job) {
            job.complete(Err(anyhow::anyhow!("stub worker gone")));
        }
    }
}

impl Drop for StubBackend {
    fn drop(&mut self) {
        // Close the channels, then block until in-flight batches finish —
        // the contract that makes `shutdown` a drain.
        self.txs.clear();
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

fn example(v: f32) -> HostTensor {
    HostTensor::f32(vec![v], vec![1, 1])
}

/// Join with a watchdog so a drain bug fails the test instead of hanging
/// the whole suite.
fn join_within(join: thread::JoinHandle<()>, secs: u64) {
    let (tx, rx) = mpsc::channel();
    thread::spawn(move || {
        let ok = join.join().is_ok();
        let _ = tx.send(ok);
    });
    let ok = rx
        .recv_timeout(Duration::from_secs(secs))
        .expect("dispatcher join did not return after shutdown");
    assert!(ok, "dispatcher panicked");
}

#[test]
fn responses_match_inputs_across_workers() {
    // Deliberately unsorted bucket list: the engine must normalize it
    // rather than treating the tail as the max batch size.
    let backend = StubBackend::new(2, &[4, 2, 8], Duration::from_millis(1));
    // max_batch (4) < concurrent clients (8): every generation of
    // lockstep resubmissions splits into at least two back-to-back
    // batches, so the second one always finds worker 0 busy and lands on
    // worker 1 — making the both-workers-active assert deterministic.
    let opts = ServeOptions {
        max_batch: 4,
        max_wait: Duration::from_micros(200),
        queue_depth: 1024,
        deadline: None,
        ..ServeOptions::default()
    };
    let (handle, join) = serve_with_backend(backend, &opts).unwrap();

    thread::scope(|s| {
        for t in 0..8i32 {
            let handle: ServerHandle = handle.clone();
            s.spawn(move || {
                for i in 0..25i32 {
                    let v = (t * 100 + i) as f32;
                    let out = handle.infer(example(v)).expect("infer failed");
                    assert_eq!(out, vec![v * 2.0 + 1.0], "response for input {v}");
                }
            });
        }
    });

    let stats = handle.stats();
    assert_eq!(stats.requests, 200);
    assert_eq!(stats.errors, 0);
    assert_eq!(stats.rejected, 0);
    assert_eq!(stats.deadline_missed, 0);
    assert_eq!(stats.per_worker.len(), 2);
    let active = stats.per_worker.iter().filter(|w| w.batches > 0).count();
    assert_eq!(active, 2, "batches must fan out across both workers");

    handle.shutdown();
    join_within(join, 10);
}

#[test]
fn expired_deadlines_get_errors_not_results() {
    // One slow worker, one in-flight slot: a long-running batch forces
    // later requests to wait past their deadline.
    let backend = StubBackend::new(1, &[8], Duration::from_millis(200));
    let opts = ServeOptions {
        max_batch: 8,
        max_wait: Duration::from_millis(1),
        workers: 1,
        queue_depth: 64,
        deadline: None,
        max_inflight: 1,
        ..ServeOptions::default()
    };
    let (handle, join) = serve_with_backend(backend, &opts).unwrap();

    let blocker = {
        let handle = handle.clone();
        thread::spawn(move || handle.infer(example(1.0)))
    };
    thread::sleep(Duration::from_millis(20)); // blocker occupies the worker

    thread::scope(|s| {
        let misses: Vec<_> = (0..2)
            .map(|_| {
                let handle = handle.clone();
                s.spawn(move || {
                    handle.infer_with_deadline(example(2.0), Some(Duration::from_millis(20)))
                })
            })
            .collect();
        for m in misses {
            let err = m.join().unwrap().expect_err("expired request must not get a result");
            assert!(format!("{err:#}").contains("deadline"), "{err:#}");
        }
    });
    assert_eq!(blocker.join().unwrap().unwrap(), vec![3.0]);
    assert_eq!(handle.stats().deadline_missed, 2);

    handle.shutdown();
    join_within(join, 10);
}

#[test]
fn full_queue_rejects_admissions() {
    let backend = StubBackend::new(1, &[8], Duration::from_millis(300));
    let opts = ServeOptions {
        max_batch: 1, // one request per batch: saturation is immediate
        max_wait: Duration::ZERO,
        workers: 1,
        queue_depth: 2,
        deadline: None,
        max_inflight: 1,
        ..ServeOptions::default()
    };
    let (handle, join) = serve_with_backend(backend, &opts).unwrap();

    let ok = AtomicUsize::new(0);
    let rejected = AtomicUsize::new(0);
    thread::scope(|s| {
        for i in 0..16 {
            let handle = handle.clone();
            let (ok, rejected) = (&ok, &rejected);
            s.spawn(move || match handle.infer(example(i as f32)) {
                Ok(out) => {
                    assert_eq!(out, vec![i as f32 * 2.0 + 1.0]);
                    ok.fetch_add(1, Ordering::Relaxed);
                }
                Err(e) => {
                    assert!(format!("{e:#}").contains("queue full"), "{e:#}");
                    rejected.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
    });
    let (ok, rejected) = (ok.into_inner(), rejected.into_inner());
    assert_eq!(ok + rejected, 16);
    assert!(rejected >= 1, "a 16-burst against depth 2 must shed load");
    let stats = handle.stats();
    assert_eq!(stats.rejected, rejected);
    assert_eq!(stats.requests, ok);
    assert!(stats.max_queue_depth <= 2);

    handle.shutdown();
    join_within(join, 30);
}

#[test]
fn shutdown_drains_and_join_returns() {
    let backend = StubBackend::new(2, &[4], Duration::from_millis(50));
    let opts = ServeOptions {
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        workers: 2,
        queue_depth: 64,
        deadline: None,
        max_inflight: 1,
        ..ServeOptions::default()
    };
    let (handle, join) = serve_with_backend(backend, &opts).unwrap();

    thread::scope(|s| {
        for i in 0..40 {
            let handle = handle.clone();
            s.spawn(move || {
                let out = handle.infer(example(i as f32)).expect("admitted before shutdown");
                assert_eq!(out, vec![i as f32 * 2.0 + 1.0]);
            });
        }
        // Shut down mid-flight: ~10 batches of 50 ms across 2 workers are
        // still queued or executing 100 ms in.
        thread::sleep(Duration::from_millis(100));
        handle.shutdown();
        assert!(handle.is_shutdown());
        // Already-admitted requests are drained (the asserts above), and
        // new admissions fail fast.
        let err = handle.infer(example(0.0)).unwrap_err();
        assert!(format!("{err:#}").contains("stopped"), "{err:#}");
    });

    let stats = handle.stats();
    assert_eq!(stats.requests, 40);
    join_within(join, 10);
}

#[test]
fn dropping_last_handle_ends_dispatcher() {
    // The pre-rework server leaked its executor thread as long as any
    // handle clone lived — and even dropping everything left `join`
    // hanging. Now the last handle drop closes the queue.
    let backend = StubBackend::new(1, &[4], Duration::ZERO);
    let (handle, join) = serve_with_backend(backend, &ServeOptions::default()).unwrap();
    let clone = handle.clone();
    drop(handle);
    drop(clone);
    join_within(join, 10);
}
