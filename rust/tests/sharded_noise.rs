//! Parity properties for the sharded ε_N noise metric: at 1, 2 and 8
//! workers, [`noise_scores_sharded`] must produce *bit-identical* scores
//! — the same contract `sharded_calibration.rs` asserts for calibration
//! and the Hessian trace. No artifacts or PJRT device needed:
//! [`SyntheticStage`] runs the real driver (grid flattening, scatter over
//! scoped threads, fixed-order host reduction against the worker-0 clean
//! loss) over deterministic per-item math. Also covers the (layer, trial)
//! seed addressing and the stale sensitivity-cache recompute gate.

use mpq::api::{ModelContext, SyntheticStage};
use mpq::coordinator::{noise_scores_sharded, StageRunner};
use mpq::sensitivity::ScoreCache;
use mpq::util::json::{self, Value};
use mpq::util::rng::noise_seed;

const WORKER_COUNTS: [usize; 3] = [1, 2, 8];

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn noise_scores_bit_identical_across_worker_counts() {
    // Grid shapes chosen so the flattened (layer, trial) items split
    // unevenly across workers — including fewer items than workers.
    for (layers, trials) in [(6usize, 3usize), (4, 1), (9, 5), (1, 2), (2, 16)] {
        let mut reference: Option<Vec<f64>> = None;
        for workers in WORKER_COUNTS {
            let mut stage = SyntheticStage::new(layers, 8, workers, 42);
            let scores = noise_scores_sharded(&mut stage, 0.05, trials, 7).unwrap();
            assert_eq!(scores.len(), layers);
            match &reference {
                None => reference = Some(scores),
                Some(r) => {
                    let what = format!("layers {layers} trials {trials} workers {workers}");
                    assert_eq!(bits(&scores), bits(r), "{what}");
                }
            }
        }
    }
}

#[test]
fn noise_draws_are_trial_seed_addressed() {
    // Different base seeds must perturb differently...
    let mut a = SyntheticStage::new(5, 8, 2, 13);
    let mut b = SyntheticStage::new(5, 8, 2, 13);
    let sa = noise_scores_sharded(&mut a, 0.05, 3, 1).unwrap();
    let sb = noise_scores_sharded(&mut b, 0.05, 3, 2).unwrap();
    assert_ne!(sa, sb, "different seeds must give different scores");
    // ...and more trials must change the per-layer average (the grid is
    // (layer, trial)-addressed, not a shared stream that happens to
    // coincide on a prefix).
    let mut c = SyntheticStage::new(5, 8, 2, 13);
    let sc = noise_scores_sharded(&mut c, 0.05, 4, 1).unwrap();
    assert_ne!(sa, sc, "trial count is part of the addressing");
    // The underlying per-(layer, trial) seeds are stable and unique.
    assert_eq!(noise_seed(1, 2, 3), noise_seed(1, 2, 3));
    assert_ne!(noise_seed(1, 2, 3), noise_seed(1, 3, 2));
}

#[test]
fn noise_scores_deterministic_per_stage_seed() {
    let run = |stage_seed: u64| {
        let mut stage = SyntheticStage::new(7, 8, 3, stage_seed);
        noise_scores_sharded(&mut stage, 0.05, 3, 99).unwrap()
    };
    assert_eq!(bits(&run(11)), bits(&run(11)));
    assert_ne!(bits(&run(11)), bits(&run(12)));
}

#[test]
fn driver_accepts_dyn_stage_runner() {
    let mut stage = SyntheticStage::new(3, 6, 2, 21);
    let dyn_stage: &mut dyn StageRunner = &mut stage;
    let scores = noise_scores_sharded(dyn_stage, 0.05, 2, 5).unwrap();
    assert_eq!(scores.len(), 3);
}

// ---------------------------------------------------- stale-cache recompute

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("mpq_sens_cache_{name}.json"))
}

#[test]
fn stale_v1_and_v2_sensitivity_caches_are_recomputed() {
    let version = ModelContext::SENS_CACHE_VERSION;
    assert!(version >= 3, "sharded noise requires the v3 cache bump");
    assert_eq!(version, ScoreCache::VERSION, "ModelContext aliases the cache's own version");
    let path = tmp("stale");
    let cache = ScoreCache::new(&path, version);
    let scores = vec![0.25f64, 0.5, 0.75];

    // An unversioned v1 file (serial shared-RNG era) must be rejected.
    let v1 = Value::obj(vec![(
        "scores",
        Value::Arr(scores.iter().map(|&s| Value::Num(s)).collect()),
    )]);
    std::fs::write(&path, v1.to_string()).unwrap();
    assert_eq!(cache.load(3), None, "v1 file must recompute");

    // A v2 file (trial-seeded Hessian, serial noise) must be rejected too.
    let v2 = Value::obj(vec![
        ("version", Value::Num(2.0)),
        ("scores", Value::Arr(scores.iter().map(|&s| Value::Num(s)).collect())),
    ]);
    std::fs::write(&path, v2.to_string()).unwrap();
    assert_eq!(cache.load(3), None, "v2 file must recompute");

    // The current version round-trips exactly...
    cache.save(&scores);
    let loaded = cache.load(3).expect("current version must load");
    assert_eq!(bits(&loaded), bits(&scores));
    // ...but only for the layer count it was written for.
    assert_eq!(cache.load(4), None, "layer mismatch must recompute");

    // Corrupt files degrade to a recompute, never an error.
    std::fs::write(&path, "{not json").unwrap();
    assert_eq!(cache.load(3), None);
    let _ = std::fs::remove_file(&path);
    assert_eq!(cache.load(3), None, "missing file recomputes");
}

#[test]
fn score_cache_files_are_valid_json_with_version() {
    let path = tmp("roundtrip");
    let cache = ScoreCache::new(&path, ModelContext::SENS_CACHE_VERSION);
    cache.save(&[1.0, 2.0]);
    assert_eq!(cache.path(), path.as_path());
    let v = json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(v.req("version").unwrap().as_usize().unwrap(), ModelContext::SENS_CACHE_VERSION);
    assert_eq!(v.req("scores").unwrap().as_arr().unwrap().len(), 2);
    let _ = std::fs::remove_file(&path);
}
