//! End-to-end properties of the one-pass Pareto frontier: the
//! `<model>_frontier.json` artifact built by one accuracy-exhaustion
//! search per floor must answer every budget × accuracy-floor sweep cell
//! *byte-identically* to re-searching it — at 1, 2, and 8 workers, for
//! both budget kinds — while a killed build resumes from its per-floor
//! decision logs into the exact same artifact. Mirrors what the CI
//! `mpq pareto` / `mpq report --sweep --from-frontier` smoke does end to
//! end through the binary.

use mpq::api::{
    build_frontier_synthetic, run_search, AccuracyTarget, FrontierArtifact, FrontierPoint,
    FrontierReport, PickSpec, SearchEvent, SyntheticEnv,
};
use mpq::coordinator::{ParallelEnv, SearchAlgo};
use mpq::quant::QUANT_BITS;
use mpq::report::{
    budget_sweep_from_frontier, budget_sweep_synthetic, render_sweep, sweep_cells_json,
    BudgetKind, SweepGrid,
};

const LAYERS: usize = 20;
const SEED: u64 = 7;
const FLOORS: [f64; 3] = [0.9, 0.97, 0.99];

fn grid(kind: BudgetKind) -> SweepGrid {
    SweepGrid { kind, budgets: vec![0.55, 0.7, 0.9], floors: FLOORS.to_vec() }
}

fn build(workers: usize) -> FrontierReport {
    build_frontier_synthetic(
        LAYERS,
        SEED,
        workers,
        SearchAlgo::Greedy,
        &FLOORS,
        None,
        false,
        None,
        None,
    )
    .unwrap()
}

#[test]
fn frontier_lookup_reproduces_the_sweep_cell_for_cell() {
    // One artifact answers both budget kinds: the trails record both
    // relative costs for every committed configuration.
    let artifact = build(1).artifact;
    for kind in [BudgetKind::Latency, BudgetKind::Size] {
        let g = grid(kind);
        // `budget_sweep_from_frontier` takes no environment at all — the
        // zero-searches claim is structural, not just asserted.
        let looked_up = budget_sweep_from_frontier(&artifact, &g, None).unwrap();
        assert_eq!(looked_up.len(), 9);
        for workers in [1usize, 2, 8] {
            let searched =
                budget_sweep_synthetic(LAYERS, SEED, workers, SearchAlgo::Greedy, &g, None, None)
                    .unwrap();
            assert_eq!(
                sweep_cells_json(&looked_up),
                sweep_cells_json(&searched),
                "{} sweep at {workers} workers: RESULT diff",
                g.kind.label()
            );
            assert_eq!(
                render_sweep("sweep", &g, &looked_up).render(),
                render_sweep("sweep", &g, &searched).render(),
                "{} sweep at {workers} workers: rendered report diff",
                g.kind.label()
            );
        }
    }
}

#[test]
fn worker_count_never_changes_the_artifact() {
    let one = build(1).artifact.to_json().to_string();
    let two = build(2).artifact.to_json().to_string();
    assert_eq!(one, two, "frontier artifact must be byte-identical across worker counts");
}

#[test]
fn frontier_build_costs_one_exhaustion_search_per_floor() {
    // Count Decision events in the build's own stream and check them
    // against the report and against standalone accuracy-only searches.
    let mut streamed = 0usize;
    let mut obs = |ev: &SearchEvent| {
        if matches!(ev, SearchEvent::Decision { .. }) {
            streamed += 1;
        }
    };
    let report = build_frontier_synthetic(
        LAYERS,
        SEED,
        1,
        SearchAlgo::Greedy,
        &FLOORS,
        None,
        false,
        None,
        Some(&mut obs),
    )
    .unwrap();
    assert_eq!(report.decision_evals, streamed, "event stream and report disagree");
    assert_eq!(report.replayed_decisions, 0);
    let per_floor: usize = report.artifact.trails.iter().map(|t| t.decisions).sum();
    assert_eq!(report.decision_evals, per_floor);

    for trail in &report.artifact.trails {
        // The same floor as a standalone accuracy-exhaustion search: the
        // frontier build must have spent exactly this search's decision
        // evals on it, ending at the same configuration and accuracy.
        let env = SyntheticEnv::new(LAYERS, SEED);
        let order = env.order();
        let mut penv = ParallelEnv::new(&env, 1);
        // The synthetic float baseline is exactly 1.0: floor = abs floor.
        let objective = AccuracyTarget::new(trail.floor);
        let outcome = run_search(
            SearchAlgo::Greedy,
            &mut penv,
            &order,
            &QUANT_BITS,
            &objective,
            None,
            None,
        )
        .unwrap();
        assert_eq!(outcome.evals, trail.decisions + 1, "floor {}", trail.floor);
        let last = trail.points.last().unwrap();
        assert_eq!(outcome.config, last.config, "floor {}", trail.floor);
        assert_eq!(outcome.accuracy, last.accuracy, "floor {}", trail.floor);
    }
}

#[test]
fn aborted_frontier_build_resumes_byte_identically() {
    let full = build(1);
    let full_json = full.artifact.to_json().to_string();

    let prefix = std::env::temp_dir().join("mpq_frontier_ck_resume");
    let cleanup = || {
        for i in 0..FLOORS.len() {
            let _ = std::fs::remove_file(format!("{}.floor{i}", prefix.display()));
        }
    };
    cleanup();

    // Kill the build mid-floor: the synthetic env errors after 10 raw
    // evaluations, well inside floor 0's exhaustion search.
    let err = build_frontier_synthetic(
        LAYERS,
        SEED,
        1,
        SearchAlgo::Greedy,
        &FLOORS,
        Some(&prefix),
        false,
        Some(10),
        None,
    )
    .unwrap_err();
    assert!(format!("{err:#}").contains("abort"), "{err:#}");

    // Resume: recorded decisions replay from the per-floor logs, the
    // rest run fresh — and the artifact byte-matches the uninterrupted
    // build.
    let resumed = build_frontier_synthetic(
        LAYERS,
        SEED,
        1,
        SearchAlgo::Greedy,
        &FLOORS,
        Some(&prefix),
        true,
        None,
        None,
    )
    .unwrap();
    assert!(resumed.replayed_decisions > 0, "the killed build's decisions must replay");
    assert_eq!(resumed.artifact.to_json().to_string(), full_json, "resumed artifact diff");
    cleanup();
}

#[test]
fn pareto_set_matches_a_brute_force_filter() {
    let artifact = build(1).artifact;
    assert!(artifact.num_points() > FLOORS.len(), "trails should record intermediate points");

    // Independent brute force: keep the first point per distinct config,
    // then drop everything some other recorded point dominates.
    let mut seen = std::collections::HashSet::new();
    let mut distinct: Vec<&FrontierPoint> = Vec::new();
    for trail in &artifact.trails {
        for p in &trail.points {
            if seen.insert(p.config.key()) {
                distinct.push(p);
            }
        }
    }
    let brute: Vec<&FrontierPoint> = distinct
        .iter()
        .filter(|p| !distinct.iter().any(|q| q.dominates(p)))
        .copied()
        .collect();

    let pareto = artifact.pareto();
    assert!(!pareto.is_empty());
    assert_eq!(
        pareto.iter().map(|p| p.config.key()).collect::<Vec<_>>(),
        brute.iter().map(|p| p.config.key()).collect::<Vec<_>>(),
    );
    // And the defining property, point by point.
    for p in &distinct {
        let dominated = distinct.iter().any(|q| q.dominates(p));
        let kept = pareto.iter().any(|q| q.config.key() == p.config.key());
        assert_eq!(kept, !dominated);
    }
}

#[test]
fn frontier_pick_selects_the_most_accurate_point_within_budget() {
    let artifact = build(1).artifact;
    let spec: PickSpec = "latency<=0.7".parse().unwrap();
    let picked = artifact.pick(&spec).unwrap();
    assert!(picked.rel_latency <= 0.7);
    for p in artifact.pareto() {
        if p.rel_latency <= 0.7 {
            assert!(p.accuracy <= picked.accuracy, "pick must maximize accuracy");
        }
    }
    // An unsatisfiable constraint fails loudly instead of degrading.
    let impossible = artifact.pick(&"latency<=0.0001".parse().unwrap());
    assert!(impossible.unwrap_err().to_string().contains("no frontier point"));
}

#[test]
fn mismatched_or_stale_artifacts_are_rejected() {
    let artifact = build(1).artifact;
    let order: Vec<usize> = (0..LAYERS).collect();
    let env = format!("synthetic/n{LAYERS}/seed{SEED}");
    artifact.verify(SearchAlgo::Greedy, &order, &env).unwrap();
    // Wrong algorithm, order, or environment (e.g. another seed) all
    // change the fingerprint.
    for err in [
        artifact.verify(SearchAlgo::Bisection, &order, &env).unwrap_err(),
        artifact.verify(SearchAlgo::Greedy, &order, "synthetic/n20/seed8").unwrap_err(),
    ] {
        assert!(err.to_string().contains("different search"), "{err}");
    }

    // Save/load round-trips byte-identically; a tampered version is
    // refused at load.
    let path = std::env::temp_dir().join("mpq_frontier_roundtrip.json");
    artifact.save(&path).unwrap();
    let loaded = FrontierArtifact::load(&path).unwrap();
    assert_eq!(loaded.to_json().to_string(), artifact.to_json().to_string());
    let mut text = std::fs::read_to_string(&path).unwrap();
    text = text.replacen("\"version\":1", "\"version\":999", 1);
    std::fs::write(&path, text).unwrap();
    let err = FrontierArtifact::load(&path).unwrap_err();
    assert!(format!("{err:#}").contains("version"), "{err:#}");
    let _ = std::fs::remove_file(&path);

    // A floor the artifact never searched cannot be looked up.
    let g = SweepGrid { kind: BudgetKind::Latency, budgets: vec![0.7], floors: vec![0.95] };
    let err = budget_sweep_from_frontier(&artifact, &g, None).unwrap_err();
    assert!(err.to_string().contains("no trail for floor"), "{err}");
}
