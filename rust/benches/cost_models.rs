//! Benchmarks for the latency/size cost models — called once per search
//! step when ranking candidate configurations, and thousands of times when
//! regenerating the paper's tables.

mod harness;

use harness::{black_box, Bench};
use mpq::latency::{AccelModel, CostModel, DeployScale};
use mpq::model::Manifest;
use mpq::quant::QuantConfig;
use mpq::util::rng::Rng;

fn load_manifest() -> Option<Manifest> {
    let dir = mpq::artifacts_dir()?;
    Manifest::load(&dir.join("bert_s_manifest.json")).ok()
}

fn main() {
    let b = Bench::new("cost_models");
    let Some(manifest) = load_manifest() else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    };
    let accel = AccelModel::a100_like();

    b.bench_n("kernel_table_profile", 20, || {
        black_box(CostModel::new(&manifest, &accel));
    });

    let cm = CostModel::new(&manifest, &accel);
    let n = manifest.num_quant_layers;
    let mut rng = Rng::seed_from(3);
    let mut cfgs = Vec::new();
    for _ in 0..64 {
        let mut c = QuantConfig::float(n);
        for i in 0..n {
            c.set_layer(i, [4.0, 8.0, 16.0][rng.below(3)]);
        }
        cfgs.push(c);
    }
    let mut i = 0;
    b.bench("latency_lookup_per_config", || {
        black_box(cm.latency_s(&cfgs[i % cfgs.len()]));
        i += 1;
    });
    let mut j = 0;
    b.bench("size_per_config", || {
        black_box(cm.size_bytes(&cfgs[j % cfgs.len()]));
        j += 1;
    });
    b.bench("tile_efficiency", || {
        black_box(accel.tile_efficiency(black_box(96), black_box(768), black_box(3072)));
    });
    b.bench("deploy_scale_apply", || {
        let s = DeployScale::for_manifest(&manifest);
        black_box(s.apply(&manifest.layers[3]));
    });
}
