//! Tiny micro-benchmark harness (criterion-style output, no dependency).
//!
//! Each measurement warms up, then runs timed batches until the target
//! measurement time elapses, reporting mean per-iteration time with a
//! robust spread estimate. `MPQ_BENCH_FAST=1` shrinks the budget for CI.

use std::time::{Duration, Instant};

pub struct Bench {
    suite: String,
    measure_time: Duration,
    warmup_time: Duration,
}

pub struct Report {
    pub name: String,
    pub mean_ns: f64,
    pub spread_ns: f64,
    pub iters: u64,
}

impl Bench {
    pub fn new(suite: &str) -> Self {
        let fast = std::env::var_os("MPQ_BENCH_FAST").is_some();
        println!("== bench suite: {suite} ==");
        Self {
            suite: suite.to_string(),
            measure_time: if fast { Duration::from_millis(200) } else { Duration::from_secs(2) },
            warmup_time: if fast { Duration::from_millis(50) } else { Duration::from_millis(500) },
        }
    }

    /// Time `f` repeatedly; prints and returns the report.
    pub fn bench<F: FnMut()>(&self, name: &str, mut f: F) -> Report {
        // Warmup.
        let w0 = Instant::now();
        let mut warm_iters = 0u64;
        while w0.elapsed() < self.warmup_time {
            f();
            warm_iters += 1;
        }
        // Choose a batch size so each sample is ~1/50 of the budget.
        let per_iter = (w0.elapsed().as_nanos() as f64 / warm_iters.max(1) as f64).max(1.0);
        let batch = ((self.measure_time.as_nanos() as f64 / 50.0 / per_iter).ceil() as u64).max(1);

        let mut samples: Vec<f64> = Vec::new();
        let mut total_iters = 0u64;
        let m0 = Instant::now();
        while m0.elapsed() < self.measure_time {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t0.elapsed().as_nanos() as f64 / batch as f64);
            total_iters += batch;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let p10 = samples[samples.len() / 10];
        let p90 = samples[samples.len() * 9 / 10];
        let report = Report {
            name: format!("{}::{name}", self.suite),
            mean_ns: mean,
            spread_ns: (p90 - p10) / 2.0,
            iters: total_iters,
        };
        println!(
            "{:<52} {:>12}  (±{:>10}, {} iters)",
            report.name,
            fmt_ns(report.mean_ns),
            fmt_ns(report.spread_ns),
            report.iters
        );
        report
    }

    /// Time a fallible one-shot operation `n` times (for heavyweight
    /// end-to-end paths where the criterion-style loop is impractical).
    #[allow(dead_code)]
    pub fn bench_n<F: FnMut()>(&self, name: &str, n: u64, mut f: F) -> Report {
        let mut samples = Vec::with_capacity(n as usize);
        for _ in 0..n {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let spread = (samples[samples.len() - 1] - samples[0]) / 2.0;
        let report = Report {
            name: format!("{}::{name}", self.suite),
            mean_ns: mean,
            spread_ns: spread,
            iters: n,
        };
        println!(
            "{:<52} {:>12}  (±{:>10}, {} iters)",
            report.name,
            fmt_ns(report.mean_ns),
            fmt_ns(report.spread_ns),
            report.iters
        );
        report
    }
}

#[allow(dead_code)]
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Keep a value alive / defeat dead-code elimination.
#[allow(dead_code)]
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
