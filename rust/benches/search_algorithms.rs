//! Search-algorithm benchmarks over synthetic evaluation environments.
//!
//! Two sections:
//!
//! 1. **Decision complexity** (instant evals): isolates the coordination
//!    logic (Alg. 1 vs Alg. 2 evaluation budgets and overhead) from the
//!    PJRT execution cost, checking the paper's complexity claims —
//!    O(b log N) evals for bisection vs O(bN) for greedy.
//! 2. **Parallel batched engine** (simulated-latency evals): the same
//!    searches through [`ParallelEnv`] at 1/2/8 workers, measuring the
//!    wall-clock speedup of speculative frontier batching and asserting
//!    the final configurations are bit-identical at every worker count.
//! 3. **Partitioned vs monolithic** (simulated-latency evals): the same
//!    budgeted search on a deep model through [`PartitionedDriver`] at
//!    K ∈ {1, 2, 4} segments, comparing decision-eval counts and wall
//!    time — segments search concurrently, so wall time falls with K
//!    while the per-decision accounting stays visible.
//!
//! The report is also written as JSON (`BENCH_search.json` in the current
//! directory, or `$MPQ_BENCH_OUT`) so CI can archive baselines.

mod harness;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use harness::{black_box, fmt_ns, Bench};
use mpq::api::{ObjectiveSpec, Partition, PartitionedDriver, SharedSegmentEval, SyntheticCost};
use mpq::coordinator::{EvalResult, ParallelEnv, SearchAlgo, SyncSearchEnv};
use mpq::quant::QuantConfig;
use mpq::util::json::Value;
use mpq::util::rng::Rng;

/// Synthetic model: each layer has a quantization cost; accuracy is
/// 1 - sum(cost). Mirrors the mock environments the unit tests use but at
/// configurable scale, with an optional simulated per-eval device latency
/// (`work` iterations of a deterministic spin) so parallel speedups are
/// measurable. Seeded, shared-state (`&self`) and deterministic per
/// configuration, so any worker schedule produces identical results.
struct SynthEnv {
    penalty: Vec<f64>,
    work: u32,
    evals: AtomicUsize,
}

impl SynthEnv {
    fn new(n: usize, seed: u64, work: u32) -> Self {
        let mut rng = Rng::seed_from(seed);
        // A few ruinous layers, many cheap ones — the regime where guided
        // search pays off.
        let penalty = (0..n)
            .map(|_| if rng.uniform() < 0.2 { 0.05 } else { 0.0002 })
            .collect();
        Self { penalty, work, evals: AtomicUsize::new(0) }
    }

    fn order(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.penalty.len()).collect();
        idx.sort_by(|&a, &b| self.penalty[a].partial_cmp(&self.penalty[b]).unwrap());
        idx
    }
}

impl SyncSearchEnv for SynthEnv {
    fn num_layers(&self) -> usize {
        self.penalty.len()
    }

    fn eval(&self, cfg: &QuantConfig, _t: Option<f64>) -> mpq::Result<EvalResult> {
        self.evals.fetch_add(1, Ordering::Relaxed);
        if self.work > 0 {
            // Deterministic spin standing in for a device round-trip.
            let mut x = 0.0f64;
            for i in 0..self.work {
                x += f64::from(i ^ 0x5A5A).sqrt();
            }
            black_box(x);
        }
        let cost: f64 = cfg
            .bits_w
            .iter()
            .enumerate()
            .map(|(i, &b)| self.penalty[i] * f64::from(16.0 - b) / 12.0)
            .sum();
        Ok(EvalResult { loss: cost, accuracy: 1.0 - cost, exact: true })
    }
}

fn run_search(algo: SearchAlgo, env: &SynthEnv, workers: usize) -> mpq::coordinator::SearchOutcome {
    let order = env.order();
    let mut penv = ParallelEnv::new(env, workers);
    algo.run(&mut penv, &order, &[8.0, 4.0], 0.99).unwrap()
}

fn main() {
    let b = Bench::new("search_algorithms");
    let mut json_rows: Vec<Value> = Vec::new();

    // ---- 1. decision complexity (instant evals, sequential) -------------
    for n in [16usize, 64, 256] {
        for algo in [SearchAlgo::Greedy, SearchAlgo::Bisection] {
            let mut evals_used = 0usize;
            let report = b.bench(&format!("{}_n{n}", algo.label().to_lowercase()), || {
                let env = SynthEnv::new(n, 42, 0);
                let out = run_search(algo, &env, 1);
                evals_used = out.evals;
                black_box(out);
            });
            println!("    -> {evals_used} evals at N={n}");
            json_rows.push(Value::obj(vec![
                ("name", Value::Str(report.name.clone())),
                ("mean_ns", Value::Num(report.mean_ns)),
                ("spread_ns", Value::Num(report.spread_ns)),
                ("evals", Value::Num(evals_used as f64)),
            ]));
        }
    }

    // ---- 2. parallel batched engine (simulated device latency) ----------
    // ~0.2 ms per eval: long enough that scoped-thread fan-out overhead is
    // noise, short enough that the bench stays quick.
    let work: u32 = std::env::var("MPQ_BENCH_WORK")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(60_000);
    let n = 64;
    for algo in [SearchAlgo::Greedy, SearchAlgo::Bisection] {
        let mut sequential_ns = 0.0f64;
        let reference = {
            let env = SynthEnv::new(n, 42, 0);
            run_search(algo, &env, 1)
        };
        for workers in [1usize, 2, 8] {
            let label = format!("{}_slow_n{n}_w{workers}", algo.label().to_lowercase());
            // Bit-identical outcome at every worker count (same seed).
            let verify_env = SynthEnv::new(n, 42, 0);
            let out = run_search(algo, &verify_env, workers);
            assert_eq!(out.config, reference.config, "{label}: config drifted");
            assert_eq!(out.evals, reference.evals, "{label}: decision evals drifted");
            let raw_evals = verify_env.evals.load(Ordering::Relaxed);

            let env = SynthEnv::new(n, 42, work);
            let report = b.bench_n(&label, 3, || {
                let out = run_search(algo, &env, workers);
                black_box(out);
            });
            if workers == 1 {
                sequential_ns = report.mean_ns;
            }
            let speedup = sequential_ns / report.mean_ns;
            println!(
                "    -> {workers} worker(s): {} ({speedup:.2}x vs sequential, \
                 {raw_evals} raw evals)",
                fmt_ns(report.mean_ns),
            );
            json_rows.push(Value::obj(vec![
                ("name", Value::Str(report.name.clone())),
                ("mean_ns", Value::Num(report.mean_ns)),
                ("spread_ns", Value::Num(report.spread_ns)),
                ("workers", Value::Num(workers as f64)),
                ("speedup_vs_sequential", Value::Num(speedup)),
                ("decision_evals", Value::Num(out.evals as f64)),
                ("config_matches_sequential", Value::Bool(true)),
            ]));
        }
    }

    // ---- 3. partitioned vs monolithic (simulated device latency) ---------
    // A deep model, one latency-budget objective: K segments search their
    // slice of the order concurrently (one thread each), then one global
    // reconciliation eval composes the result.
    let n = 256;
    let spec = ObjectiveSpec::LatencyBudget { rel_latency: 0.7 };
    for algo in [SearchAlgo::Greedy, SearchAlgo::Bisection] {
        let mut monolithic_ns = 0.0f64;
        for k in [1usize, 2, 4] {
            // Decision-eval accounting on instant evals (deterministic,
            // identical to what the timed runs below decide).
            let env = SynthEnv::new(n, 42, 0);
            let order = env.order();
            let cost = Arc::new(SyntheticCost::new(n, 42));
            let driver = PartitionedDriver::new(
                algo,
                Partition::split(&order, k),
                1.0,
                cost.clone(),
                "bench/synthetic",
            );
            let out = driver.run(&SharedSegmentEval(&env), &spec, 0.99, None).unwrap();
            let decision_evals = out.outcome.evals;

            let label = format!("{}_part_n{n}_k{k}", algo.label().to_lowercase());
            let slow = SynthEnv::new(n, 42, work);
            let slow_driver = PartitionedDriver::new(
                algo,
                Partition::split(&order, k),
                1.0,
                cost,
                "bench/synthetic",
            );
            let report = b.bench_n(&label, 3, || {
                let out = slow_driver.run(&SharedSegmentEval(&slow), &spec, 0.99, None).unwrap();
                black_box(out);
            });
            if k == 1 {
                monolithic_ns = report.mean_ns;
            }
            let speedup = monolithic_ns / report.mean_ns;
            println!(
                "    -> K={k}: {} ({speedup:.2}x vs monolithic, {decision_evals} decision evals)",
                fmt_ns(report.mean_ns),
            );
            json_rows.push(Value::obj(vec![
                ("name", Value::Str(report.name.clone())),
                ("mean_ns", Value::Num(report.mean_ns)),
                ("spread_ns", Value::Num(report.spread_ns)),
                ("partitions", Value::Num(k as f64)),
                ("speedup_vs_monolithic", Value::Num(speedup)),
                ("decision_evals", Value::Num(decision_evals as f64)),
            ]));
        }
    }

    // ---- report ----------------------------------------------------------
    let out_path = std::env::var("MPQ_BENCH_OUT").unwrap_or_else(|_| "BENCH_search.json".into());
    let doc = Value::obj(vec![
        ("suite", Value::Str("search_algorithms".into())),
        ("spin_work", Value::Num(f64::from(work))),
        ("results", Value::Arr(json_rows)),
    ]);
    match std::fs::write(&out_path, doc.to_string()) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
}
