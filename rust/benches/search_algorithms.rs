//! Search-algorithm benchmarks over a synthetic evaluation environment —
//! isolates the coordination logic (Alg. 1 vs Alg. 2 evaluation budgets and
//! overhead) from the PJRT execution cost, and checks the complexity claims
//! of the paper: O(b log N) evals for bisection vs O(bN) for greedy.

mod harness;

use harness::{black_box, Bench};
use mpq::coordinator::{EvalResult, SearchAlgo, SearchEnv};
use mpq::quant::QuantConfig;
use mpq::util::rng::Rng;

/// Synthetic model: each layer has a quantization cost; accuracy is
/// 1 - sum(cost). Mirrors the mock environments the unit tests use but at
/// configurable scale.
struct SynthEnv {
    penalty: Vec<f64>,
    evals: usize,
}

impl SynthEnv {
    fn new(n: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from(seed);
        // A few ruinous layers, many cheap ones — the regime where guided
        // search pays off.
        let penalty = (0..n)
            .map(|_| if rng.uniform() < 0.2 { 0.05 } else { 0.0002 })
            .collect();
        Self { penalty, evals: 0 }
    }

    fn order(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.penalty.len()).collect();
        idx.sort_by(|&a, &b| self.penalty[a].partial_cmp(&self.penalty[b]).unwrap());
        idx
    }
}

impl SearchEnv for SynthEnv {
    fn num_layers(&self) -> usize {
        self.penalty.len()
    }

    fn eval(&mut self, cfg: &QuantConfig, _t: Option<f64>) -> mpq::Result<EvalResult> {
        self.evals += 1;
        let cost: f64 = cfg
            .bits_w
            .iter()
            .enumerate()
            .map(|(i, &b)| self.penalty[i] * f64::from(16.0 - b) / 12.0)
            .sum();
        Ok(EvalResult { loss: cost, accuracy: 1.0 - cost, exact: true })
    }
}

fn main() {
    let b = Bench::new("search_algorithms");
    for n in [16usize, 64, 256] {
        for algo in [SearchAlgo::Greedy, SearchAlgo::Bisection] {
            let mut evals_used = 0usize;
            b.bench(&format!("{}_n{n}", algo.label().to_lowercase()), || {
                let mut env = SynthEnv::new(n, 42);
                let order = env.order();
                let out = algo.run(&mut env, &order, &[8.0, 4.0], 0.99).unwrap();
                evals_used = out.evals;
                black_box(out);
            });
            println!("    -> {} evals at N={n}", evals_used);
        }
    }
}
