//! End-to-end benchmarks against the real AOT artifacts: configuration
//! evaluation throughput (the search inner loop) and full search cells.
//! These regenerate the performance-relevant rows of the paper's tables —
//! `mpq table --id N` produces the tables themselves.
//!
//! Requires `make artifacts`. Heavyweight; each measurement runs a fixed
//! small number of iterations.

mod harness;

use harness::{black_box, Bench};
use mpq::coordinator::SearchAlgo;
use mpq::quant::QuantConfig;
use mpq::report::experiments::{run_cell, ExperimentCtx, METRIC_TRIALS};
use mpq::sensitivity::{self, MetricKind};

fn main() -> mpq::Result<()> {
    let b = Bench::new("end_to_end");
    let Some(dir) = mpq::artifacts_dir() else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return Ok(());
    };

    for model in ["resnet_s", "bert_s"] {
        let mut ctx = ExperimentCtx::new(&dir, model)?;
        ctx.ensure_calibrated()?;
        let n = ctx.pipeline.num_quant_layers();

        // Eval throughput: full-validation evaluation of a fresh config.
        // Alternate bits slightly so the memo cache never hits.
        let mut flip = 0usize;
        b.bench_n(&format!("{model}_eval_full_val"), 6, || {
            let mut cfg = QuantConfig::uniform(n, 8.0);
            cfg.set_layer(flip % n, 4.0);
            cfg.bits_a[(flip + 1) % n] = 4.0; // unique key each iter
            flip += 1;
            black_box(ctx.pipeline.eval_config(&cfg, None).unwrap());
        });

        // Cached evaluation path (the search hits this constantly).
        let cfg8 = QuantConfig::uniform(n, 8.0);
        ctx.pipeline.eval_config(&cfg8, None)?;
        b.bench(&format!("{model}_eval_cached"), || {
            black_box(ctx.pipeline.eval_config(&cfg8, None).unwrap());
        });

        // Sensitivity metrics.
        b.bench_n(&format!("{model}_metric_qe"), 3, || {
            black_box(sensitivity::compute(&mut ctx.pipeline, MetricKind::Qe, 1, 0).unwrap());
        });
        b.bench_n(&format!("{model}_metric_hessian_1probe"), 2, || {
            black_box(
                sensitivity::compute(&mut ctx.pipeline, MetricKind::Hessian, 1, 0).unwrap(),
            );
        });

        // One full search cell per algorithm (QE ordering: cheap + stable).
        let sens = sensitivity::compute(&mut ctx.pipeline, MetricKind::Qe, METRIC_TRIALS, 0)?;
        for algo in [SearchAlgo::Bisection, SearchAlgo::Greedy] {
            b.bench_n(&format!("{model}_search_{}", algo.label().to_lowercase()), 1, || {
                black_box(run_cell(&mut ctx, algo, &sens, 0, 0.99).unwrap());
            });
        }
        let stats = ctx.pipeline.stats;
        println!(
            "    -> pipeline stats: {} evals, {} cache hits, {} executions, {} early exits",
            stats.evals, stats.cache_hits, stats.batch_execs, stats.early_exits
        );
    }
    Ok(())
}
