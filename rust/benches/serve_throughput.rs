//! Serving-engine throughput/latency benchmark over stub spin workers.
//!
//! Compares 1/2/4-worker configurations at the same offered loads (32
//! paced client threads) and reports achieved throughput plus p50/p95/
//! p99 request latency, batch fill, admission rejects and deadline
//! misses. Workers burn a deterministic CPU spin per batch (base cost +
//! per-row cost), so multi-worker scaling is real parallel work, not
//! sleeps — and the offered loads are self-calibrated against a measured
//! single-batch execution so results are comparable across machines.
//!
//! Load generation is paced, not strictly open-loop: each client blocks
//! on its in-flight request and skips missed ticks rather than building
//! a backlog, so under saturation the pool degrades toward closed-loop
//! at 32-way concurrency. `attempted_rps` records the submission rate
//! the clients actually generated (vs the `offered_rps` schedule), so
//! the JSON never claims a load that was not driven.
//!
//! Beyond the throughput trials, four data-plane sections measure the
//! serving hot path directly: `assembly` (copy vs zero-copy batch
//! build), `memo_t{N}` (lock-striped vs single-mutex eval-memo hits
//! under 1/2/4/8-thread contention), `multi_config` (two configs served
//! from one engine with zero cross-config answers) and `swap_under_load`
//! (drain-free config replacement with zero stale-after-swap answers).
//!
//! The report is written as JSON (`BENCH_serve.json`, or `$MPQ_BENCH_OUT`)
//! next to the search bench's `BENCH_search.json`. `MPQ_BENCH_FAST=1`
//! shrinks trial durations for CI smoke runs.

use std::collections::HashMap;
use std::hint::black_box;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use mpq::coordinator::{EvalResult, StripedMemo};
use mpq::quant::QuantConfig;
use mpq::runtime::{BatchArena, HostTensor, TensorData};
use mpq::server::{
    pad_batch, serve_multi_with_backend, serve_with_backend, BatchJob, InferOptions, ServeOptions,
    ServingBackend,
};
use mpq::util::json::Value;

/// Compiled batch-size buckets the stub pretends to have.
const BUCKETS: [usize; 5] = [2, 4, 8, 16, 32];
/// Enough concurrency that the heavy load saturates a single worker and
/// overflows the (deliberately shallow) submission queue.
const CLIENTS: usize = 32;

/// Deterministic CPU spin standing in for a device round-trip.
fn spin(work: u32) {
    let mut x = 0.0f64;
    for i in 0..work {
        x += f64::from(i ^ 0x5A5A).sqrt();
    }
    black_box(x);
}

fn base_work() -> u32 {
    std::env::var("MPQ_SERVE_WORK").ok().and_then(|v| v.parse().ok()).unwrap_or(150_000)
}

/// Per-batch spin: fixed launch overhead plus a per-row cost.
fn batch_work(base: u32, bucket: usize) -> u32 {
    base + (base / 10) * bucket as u32
}

struct SpinBackend {
    txs: Vec<mpsc::Sender<BatchJob>>,
    joins: Vec<thread::JoinHandle<()>>,
}

impl SpinBackend {
    fn new(workers: usize, base: u32) -> Self {
        let mut txs = Vec::new();
        let mut joins = Vec::new();
        for _ in 0..workers {
            let (tx, rx) = mpsc::channel::<BatchJob>();
            joins.push(thread::spawn(move || {
                while let Ok(job) = rx.recv() {
                    spin(batch_work(base, job.bucket()));
                    let flat = vec![1.0f32; job.bucket()];
                    job.complete(Ok(flat));
                }
            }));
            txs.push(tx);
        }
        Self { txs, joins }
    }
}

impl ServingBackend for SpinBackend {
    fn num_workers(&self) -> usize {
        self.txs.len()
    }

    fn batch_sizes(&self) -> Vec<usize> {
        BUCKETS.to_vec()
    }

    fn submit(&mut self, w: usize, job: BatchJob) {
        if let Err(mpsc::SendError(job)) = self.txs[w].send(job) {
            job.complete(Err(anyhow::anyhow!("spin worker gone")));
        }
    }
}

impl Drop for SpinBackend {
    fn drop(&mut self) {
        self.txs.clear();
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

struct Trial {
    workers: usize,
    offered_rps: f64,
    attempted_rps: f64,
    achieved_rps: f64,
    ok: usize,
    shed: usize,
    p50_us: u64,
    p95_us: u64,
    p99_us: u64,
    mean_fill: f64,
    batches: usize,
    rejected: usize,
    deadline_missed: usize,
}

fn run_trial(workers: usize, base: u32, offered_rps: f64, dur: Duration) -> Trial {
    let backend = SpinBackend::new(workers, base);
    // Shallow queue + short deadline so the heavy load visibly exercises
    // admission control and deadline shedding instead of hiding overload
    // in a deep buffer.
    let opts = ServeOptions {
        max_batch: 32,
        max_wait: Duration::from_micros(500),
        workers,
        queue_depth: 16,
        deadline: Some(Duration::from_millis(50)),
        max_inflight: 2,
        ..ServeOptions::default()
    };
    let (handle, join) = serve_with_backend(backend, &opts).expect("engine start");
    let interval = Duration::from_secs_f64(CLIENTS as f64 / offered_rps);
    let ok = AtomicUsize::new(0);
    let shed = AtomicUsize::new(0);
    let t0 = Instant::now();
    thread::scope(|s| {
        for _ in 0..CLIENTS {
            let handle = handle.clone();
            let (ok, shed) = (&ok, &shed);
            s.spawn(move || {
                let mut next = Instant::now();
                while t0.elapsed() < dur {
                    let now = Instant::now();
                    if now < next {
                        thread::sleep(next - now);
                    }
                    // Skip missed ticks instead of accumulating a backlog:
                    // a saturated server should not owe an infinite burst.
                    next = Instant::now().max(next + interval);
                    match handle.infer(HostTensor::f32(vec![1.0], vec![1, 1])) {
                        Ok(_) => {
                            ok.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(_) => {
                            shed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let stats = handle.stats();
    handle.shutdown();
    join.join().expect("dispatcher exits");
    let ok = ok.into_inner();
    let shed = shed.into_inner();
    Trial {
        workers,
        offered_rps,
        attempted_rps: (ok + shed) as f64 / wall,
        achieved_rps: ok as f64 / wall,
        ok,
        shed,
        p50_us: stats.percentile_us(0.50),
        p95_us: stats.percentile_us(0.95),
        p99_us: stats.percentile_us(0.99),
        mean_fill: stats.mean_batch_fill(),
        batches: stats.batches,
        rejected: stats.rejected,
        deadline_missed: stats.deadline_missed,
    }
}

/// §assembly — per-batch cost of the reference copy path (`pad_batch`)
/// vs zero-copy arena assembly at a full 32-row bucket.
fn bench_assembly(fast: bool) -> Vec<Value> {
    let iters = if fast { 2_000u32 } else { 20_000 };
    let x_shape = [64usize];
    let examples: Vec<HostTensor> =
        (0..32).map(|i| HostTensor::f32(vec![i as f32; 64], vec![1, 64])).collect();
    let mut sink = 0.0f32;
    let t0 = Instant::now();
    for _ in 0..iters {
        let padded = pad_batch(&examples, &x_shape, 32);
        if let Some(d) = padded.f32_data() {
            sink += d[0];
        }
    }
    let copy_ns = t0.elapsed().as_nanos() as f64 / f64::from(iters);
    let mut arena = BatchArena::new();
    let t0 = Instant::now();
    for _ in 0..iters {
        let view = arena.assemble(&examples, &x_shape, 32);
        if let TensorData::F32(d) = view.data() {
            sink += d[0];
        }
    }
    let arena_ns = t0.elapsed().as_nanos() as f64 / f64::from(iters);
    black_box(sink);
    let ratio = copy_ns / arena_ns.max(1.0);
    println!(
        "serve_throughput::assembly: copy {copy_ns:.0} ns/batch vs arena {arena_ns:.0} ns/batch \
         ({ratio:.2}x)"
    );
    vec![Value::obj(vec![
        ("name", Value::Str("serve_throughput::assembly".into())),
        ("copy_ns_per_batch", Value::Num(copy_ns)),
        ("arena_ns_per_batch", Value::Num(arena_ns)),
        ("copy_over_arena", Value::Num(ratio)),
    ])]
}

/// Run `threads` readers doing `per_thread` memo hits each; ns per hit.
fn timed_lookups<F>(threads: usize, per_thread: usize, keys: &[u64], hit: F) -> f64
where
    F: Fn(u64) -> bool + Sync,
{
    let t0 = Instant::now();
    thread::scope(|s| {
        for t in 0..threads {
            let hit = &hit;
            s.spawn(move || {
                let mut found = 0usize;
                for i in 0..per_thread {
                    found += usize::from(hit(keys[(t * 7 + i * 13) % keys.len()]));
                }
                assert_eq!(found, per_thread, "bench must stay on the hit path");
            });
        }
    });
    t0.elapsed().as_nanos() as f64 / (threads * per_thread) as f64
}

/// §memo_contention — hit-path cost of the lock-striped memo vs the old
/// single `Mutex<HashMap>` design under 1/2/4/8 concurrent readers.
fn bench_memo_contention(fast: bool) -> Vec<Value> {
    let per_thread: usize = if fast { 50_000 } else { 400_000 };
    let res = EvalResult { loss: 0.25, accuracy: 0.97, exact: true };
    let keys: Vec<u64> = (0..1024u64).map(|k| k.wrapping_mul(0x9E37_79B9_7F4A_7C15)).collect();
    let striped = StripedMemo::new();
    let single: Mutex<HashMap<u64, EvalResult>> = Mutex::new(HashMap::new());
    for &k in &keys {
        striped.insert(k, res);
        single.lock().unwrap().insert(k, res);
    }
    let mut rows = Vec::new();
    for threads in [1usize, 2, 4, 8] {
        let striped_ns = timed_lookups(threads, per_thread, &keys, |k| striped.lookup(k).is_some());
        let mutex_ns =
            timed_lookups(threads, per_thread, &keys, |k| single.lock().unwrap().contains_key(&k));
        let speedup = mutex_ns / striped_ns.max(1e-9);
        println!(
            "serve_throughput::memo_t{threads}: striped {striped_ns:.0} ns/hit vs single-mutex \
             {mutex_ns:.0} ns/hit ({speedup:.2}x)"
        );
        rows.push(Value::obj(vec![
            ("name", Value::Str(format!("serve_throughput::memo_t{threads}"))),
            ("threads", Value::Num(threads as f64)),
            ("striped_ns_per_hit", Value::Num(striped_ns)),
            ("mutex_ns_per_hit", Value::Num(mutex_ns)),
            ("mutex_over_striped", Value::Num(speedup)),
        ]));
    }
    rows
}

/// Stub backend whose responses echo the executing config's leading
/// weight width, so clients can detect wrong-config answers.
struct ConfigBackend {
    txs: Vec<mpsc::Sender<BatchJob>>,
    joins: Vec<thread::JoinHandle<()>>,
}

impl ConfigBackend {
    fn new(workers: usize, work: u32) -> Self {
        let mut txs = Vec::new();
        let mut joins = Vec::new();
        for _ in 0..workers {
            let (tx, rx) = mpsc::channel::<BatchJob>();
            joins.push(thread::spawn(move || {
                while let Ok(job) = rx.recv() {
                    spin(work);
                    let flat = vec![job.config().bits_w[0]; job.bucket()];
                    job.complete(Ok(flat));
                }
            }));
            txs.push(tx);
        }
        Self { txs, joins }
    }
}

impl ServingBackend for ConfigBackend {
    fn num_workers(&self) -> usize {
        self.txs.len()
    }

    fn batch_sizes(&self) -> Vec<usize> {
        BUCKETS.to_vec()
    }

    fn submit(&mut self, w: usize, job: BatchJob) {
        if let Err(mpsc::SendError(job)) = self.txs[w].send(job) {
            job.complete(Err(anyhow::anyhow!("config worker gone")));
        }
    }
}

impl Drop for ConfigBackend {
    fn drop(&mut self) {
        self.txs.clear();
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

/// §multi_config — two configs served concurrently from one engine:
/// dispatch must never co-batch them, and every answer must come from the
/// config the client asked for (`wrong_config` stays 0).
fn bench_multi_config(base: u32, fast: bool) -> Vec<Value> {
    let dur = if fast { Duration::from_millis(300) } else { Duration::from_millis(1200) };
    let backend = ConfigBackend::new(2, base / 20);
    let opts = ServeOptions {
        max_batch: 32,
        max_wait: Duration::from_micros(500),
        workers: 2,
        queue_depth: 256,
        deadline: None,
        ..ServeOptions::default()
    };
    let configs = vec![QuantConfig::uniform(4, 8.0), QuantConfig::uniform(4, 4.0)];
    let (handle, join) = serve_multi_with_backend(backend, configs, &opts).expect("engine start");
    let ok = AtomicUsize::new(0);
    let shed = AtomicUsize::new(0);
    let wrong = AtomicUsize::new(0);
    let t0 = Instant::now();
    thread::scope(|s| {
        for c in 0..8u32 {
            let handle = handle.clone();
            let (ok, shed, wrong) = (&ok, &shed, &wrong);
            s.spawn(move || {
                let mut n = c;
                while t0.elapsed() < dur {
                    let config = n % 2;
                    n += 1;
                    let opts = InferOptions { config: Some(config), ..Default::default() };
                    match handle.infer_with(HostTensor::f32(vec![1.0], vec![1, 1]), &opts) {
                        Ok(out) => {
                            ok.fetch_add(1, Ordering::Relaxed);
                            let expect = if config == 0 { 8.0f32 } else { 4.0 };
                            if out[0] != expect {
                                wrong.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        Err(_) => {
                            shed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let stats = handle.stats();
    handle.shutdown();
    join.join().expect("dispatcher exits");
    let (ok, shed, wrong) = (ok.into_inner(), shed.into_inner(), wrong.into_inner());
    let rps = ok as f64 / wall;
    println!(
        "serve_throughput::multi_config: {rps:.0} rps across 2 configs | ok {ok} shed {shed} \
         wrong_config {wrong} | {} per-config rows",
        stats.per_config.len()
    );
    vec![Value::obj(vec![
        ("name", Value::Str("serve_throughput::multi_config".into())),
        ("achieved_rps", Value::Num(rps)),
        ("ok", Value::Num(ok as f64)),
        ("shed", Value::Num(shed as f64)),
        ("wrong_config", Value::Num(wrong as f64)),
        ("configs_served", Value::Num(stats.per_config.len() as f64)),
    ])]
}

/// §swap_under_load — replace the active config mid-traffic without a
/// drain: no request may see a config that is neither the old nor the new
/// one, and requests admitted after the swap must all see the new one.
fn bench_swap_under_load(base: u32, fast: bool) -> Vec<Value> {
    let dur = if fast { Duration::from_millis(300) } else { Duration::from_millis(1200) };
    let backend = ConfigBackend::new(2, base / 20);
    let opts = ServeOptions {
        max_batch: 32,
        max_wait: Duration::from_micros(500),
        workers: 2,
        queue_depth: 256,
        deadline: None,
        ..ServeOptions::default()
    };
    let (handle, join) =
        serve_multi_with_backend(backend, vec![QuantConfig::uniform(4, 8.0)], &opts)
            .expect("engine start");
    let ok = AtomicUsize::new(0);
    let shed = AtomicUsize::new(0);
    let wrong = AtomicUsize::new(0);
    let stale = AtomicUsize::new(0);
    let swapped = AtomicBool::new(false);
    let t0 = Instant::now();
    thread::scope(|s| {
        for _ in 0..8 {
            let handle = handle.clone();
            let (ok, shed, wrong, stale, swapped) = (&ok, &shed, &wrong, &stale, &swapped);
            s.spawn(move || {
                while t0.elapsed() < dur {
                    let after_swap = swapped.load(Ordering::SeqCst);
                    match handle.infer(HostTensor::f32(vec![1.0], vec![1, 1])) {
                        Ok(out) => {
                            ok.fetch_add(1, Ordering::Relaxed);
                            if out[0] != 8.0 && out[0] != 4.0 {
                                wrong.fetch_add(1, Ordering::Relaxed);
                            } else if after_swap && out[0] == 8.0 {
                                stale.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        Err(_) => {
                            shed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
        thread::sleep(dur / 2);
        handle.swap_config(0, QuantConfig::uniform(4, 4.0)).expect("swap");
        swapped.store(true, Ordering::SeqCst);
    });
    let stats = handle.stats();
    handle.shutdown();
    join.join().expect("dispatcher exits");
    let (ok, shed) = (ok.into_inner(), shed.into_inner());
    let (wrong, stale) = (wrong.into_inner(), stale.into_inner());
    println!(
        "serve_throughput::swap_under_load: ok {ok} shed {shed} | wrong_config {wrong} \
         stale_after_swap {stale} (both must be 0) | rejected {}",
        stats.rejected
    );
    vec![Value::obj(vec![
        ("name", Value::Str("serve_throughput::swap_under_load".into())),
        ("ok", Value::Num(ok as f64)),
        ("shed", Value::Num(shed as f64)),
        ("rejected", Value::Num(stats.rejected as f64)),
        ("wrong_config", Value::Num(wrong as f64)),
        ("stale_after_swap", Value::Num(stale as f64)),
    ])]
}

fn main() {
    let fast = std::env::var_os("MPQ_BENCH_FAST").is_some();
    let dur = if fast { Duration::from_millis(400) } else { Duration::from_millis(1500) };
    println!("== bench suite: serve_throughput ==");

    // Self-calibrate: seconds per full-bucket batch on this machine.
    let base = base_work();
    spin(batch_work(base, 32)); // warm
    let t0 = Instant::now();
    let reps = 5u32;
    for _ in 0..reps {
        spin(batch_work(base, 32));
    }
    let batch_secs = t0.elapsed().as_secs_f64() / f64::from(reps);
    // Rows/sec one fully-batched worker can execute.
    let capacity_1w = 32.0 / batch_secs;
    println!(
        "calibration: {:.3} ms per 32-row batch -> ~{:.0} rows/s per worker",
        batch_secs * 1e3,
        capacity_1w
    );

    // Equal offered loads for every worker count: moderate (under one
    // worker's capacity) and heavy (past it — only multi-worker configs
    // can absorb it without shedding).
    let loads = [("moderate", 0.4 * capacity_1w), ("heavy", 1.6 * capacity_1w)];
    let mut rows: Vec<Value> = Vec::new();
    for (load_name, offered) in loads {
        let mut base_rps = 0.0f64;
        for workers in [1usize, 2, 4] {
            let t = run_trial(workers, base, offered, dur);
            if workers == 1 {
                base_rps = t.achieved_rps;
            }
            let speedup = if base_rps > 0.0 { t.achieved_rps / base_rps } else { 0.0 };
            println!(
                "serve_throughput::{load_name}_w{workers}: offered {:.0} (attempted {:.0}) \
                 rps -> achieved {:.0} rps ({speedup:.2}x vs 1w) | p50 {:.1} ms p95 {:.1} ms \
                 p99 {:.1} ms | fill {:.1} over {} batches | shed {} (rejected {}, deadline {})",
                t.offered_rps,
                t.attempted_rps,
                t.achieved_rps,
                t.p50_us as f64 / 1e3,
                t.p95_us as f64 / 1e3,
                t.p99_us as f64 / 1e3,
                t.mean_fill,
                t.batches,
                t.shed,
                t.rejected,
                t.deadline_missed,
            );
            rows.push(Value::obj(vec![
                ("name", Value::Str(format!("serve_throughput::{load_name}_w{workers}"))),
                ("load", Value::Str(load_name.into())),
                ("workers", Value::Num(t.workers as f64)),
                ("offered_rps", Value::Num(t.offered_rps)),
                ("attempted_rps", Value::Num(t.attempted_rps)),
                ("achieved_rps", Value::Num(t.achieved_rps)),
                ("speedup_vs_1w", Value::Num(speedup)),
                ("ok", Value::Num(t.ok as f64)),
                ("shed", Value::Num(t.shed as f64)),
                ("p50_us", Value::Num(t.p50_us as f64)),
                ("p95_us", Value::Num(t.p95_us as f64)),
                ("p99_us", Value::Num(t.p99_us as f64)),
                ("mean_batch_fill", Value::Num(t.mean_fill)),
                ("batches", Value::Num(t.batches as f64)),
                ("rejected", Value::Num(t.rejected as f64)),
                ("deadline_missed", Value::Num(t.deadline_missed as f64)),
            ]));
        }
    }

    println!("-- data-plane sections --");
    rows.extend(bench_assembly(fast));
    rows.extend(bench_memo_contention(fast));
    rows.extend(bench_multi_config(base, fast));
    rows.extend(bench_swap_under_load(base, fast));

    let out_path = std::env::var("MPQ_BENCH_OUT").unwrap_or_else(|_| "BENCH_serve.json".into());
    let doc = Value::obj(vec![
        ("suite", Value::Str("serve_throughput".into())),
        ("base_work", Value::Num(f64::from(base))),
        ("calibrated_batch_seconds", Value::Num(batch_secs)),
        ("capacity_rows_per_sec_1w", Value::Num(capacity_1w)),
        ("clients", Value::Num(CLIENTS as f64)),
        ("trial_seconds", Value::Num(dur.as_secs_f64())),
        ("results", Value::Arr(rows)),
    ]);
    match std::fs::write(&out_path, doc.to_string()) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
}
