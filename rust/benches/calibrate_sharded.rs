//! Sharded calibration & Hessian-trace benchmark over the synthetic stage
//! runner.
//!
//! Runs the real sharded driver — [`calibrate_sharded`] /
//! [`hessian_trace_sharded`] with scatter over scoped threads and
//! fixed-order host reduction — at 1/2/8 workers, with a deterministic
//! CPU spin per batch/probe standing in for the device round-trip, so
//! multi-worker scaling is real parallel work. Every configuration is
//! asserted bit-identical to the 1-worker reference before timing (the
//! sharded-determinism contract), and per-worker-count wall-clock fields
//! land in `BENCH_calib.json` (or `$MPQ_BENCH_CALIB_OUT`) next to
//! `BENCH_search.json` / `BENCH_serve.json`. `MPQ_BENCH_FAST=1` shrinks
//! the measurement budget for CI smoke runs.

mod harness;

use harness::{black_box, fmt_ns, Bench};
use mpq::api::SyntheticStage;
use mpq::coordinator::{calibrate_sharded, hessian_trace_sharded};
use mpq::quant::{CalibrationOptions, Scales};
use mpq::util::json::Value;

const LAYERS: usize = 24;
const BATCHES: usize = 32;
const TRIALS: usize = 16;
const SEED: u64 = 42;

fn opts() -> CalibrationOptions {
    CalibrationOptions { epochs: 2, grad_batches: 8, ..Default::default() }
}

fn stage(workers: usize, work: u32) -> SyntheticStage {
    SyntheticStage::new(LAYERS, BATCHES, workers, SEED).with_work(work)
}

fn scales_bits(s: &Scales) -> Vec<u32> {
    s.alpha_w
        .iter()
        .chain(&s.gamma_w)
        .chain(&s.alpha_a)
        .chain(&s.gamma_a)
        .map(|x| x.to_bits())
        .collect()
}

fn main() {
    let fast = std::env::var_os("MPQ_BENCH_FAST").is_some();
    let work: u32 = std::env::var("MPQ_CALIB_WORK")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if fast { 40_000 } else { 400_000 });
    let reps = if fast { 2 } else { 5 };
    let b = Bench::new("calibrate_sharded");

    // Spin-free references: parity must hold on the pure math.
    let (ref_scales, ref_report) =
        calibrate_sharded(&mut stage(1, 0), &opts(), None).expect("reference calibration");
    let ref_traces =
        hessian_trace_sharded(&mut stage(1, 0), TRIALS, SEED).expect("reference traces");

    let mut json_rows = Vec::new();
    let mut calib_base_ns = 0.0f64;
    let mut hvp_base_ns = 0.0f64;
    for workers in [1usize, 2, 8] {
        // Bit-identity at this worker count before timing anything.
        let (scales, report) =
            calibrate_sharded(&mut stage(workers, 0), &opts(), None).expect("calibration");
        assert_eq!(
            scales_bits(&scales),
            scales_bits(&ref_scales),
            "workers {workers}: scales drifted from the 1-worker reference"
        );
        assert_eq!(report.steps, ref_report.steps, "workers {workers}: steps drifted");
        let traces =
            hessian_trace_sharded(&mut stage(workers, 0), TRIALS, SEED).expect("traces");
        let tb = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(
            tb(&traces),
            tb(&ref_traces),
            "workers {workers}: traces drifted from the 1-worker reference"
        );

        let calib = b.bench_n(&format!("calibrate_n{LAYERS}_b{BATCHES}_w{workers}"), reps, || {
            let mut s = stage(workers, work);
            black_box(calibrate_sharded(&mut s, &opts(), None).expect("calibration"));
        });
        let hvp = b.bench_n(&format!("hessian_t{TRIALS}_w{workers}"), reps, || {
            let mut s = stage(workers, work);
            black_box(hessian_trace_sharded(&mut s, TRIALS, SEED).expect("traces"));
        });
        if workers == 1 {
            calib_base_ns = calib.mean_ns;
            hvp_base_ns = hvp.mean_ns;
        }
        let calib_speedup = calib_base_ns / calib.mean_ns;
        let hvp_speedup = hvp_base_ns / hvp.mean_ns;
        println!(
            "    -> {workers} worker(s): calibrate {} ({calib_speedup:.2}x), \
             hessian {} ({hvp_speedup:.2}x)",
            fmt_ns(calib.mean_ns),
            fmt_ns(hvp.mean_ns),
        );
        json_rows.push(Value::obj(vec![
            ("workers", Value::Num(workers as f64)),
            ("calibrate_wall_ns", Value::Num(calib.mean_ns)),
            ("calibrate_spread_ns", Value::Num(calib.spread_ns)),
            ("calibrate_speedup_vs_1", Value::Num(calib_speedup)),
            ("hessian_wall_ns", Value::Num(hvp.mean_ns)),
            ("hessian_spread_ns", Value::Num(hvp.spread_ns)),
            ("hessian_speedup_vs_1", Value::Num(hvp_speedup)),
            ("adam_steps", Value::Num(report.steps as f64)),
            ("scales_match_reference", Value::Bool(true)),
            ("traces_match_reference", Value::Bool(true)),
        ]));
    }

    let out_path =
        std::env::var("MPQ_BENCH_CALIB_OUT").unwrap_or_else(|_| "BENCH_calib.json".into());
    let doc = Value::obj(vec![
        ("suite", Value::Str("calibrate_sharded".into())),
        ("layers", Value::Num(LAYERS as f64)),
        ("batches", Value::Num(BATCHES as f64)),
        ("trials", Value::Num(TRIALS as f64)),
        ("spin_work", Value::Num(f64::from(work))),
        ("results", Value::Arr(json_rows)),
    ]);
    match std::fs::write(&out_path, doc.to_string()) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
}
