//! Micro-benchmarks for the host-side Eq. 1 quantizer mirror and ε_QE —
//! these run inside every sensitivity computation and size model, so they
//! must stay off the profile of a search.

mod harness;

use harness::{black_box, Bench};
use mpq::quant::{eps_qe, quantize, quantize_into};
use mpq::util::rng::Rng;

fn main() {
    let b = Bench::new("quantizer");
    let mut rng = Rng::seed_from(7);
    let x: Vec<f32> = (0..65536).map(|_| rng.gaussian() as f32).collect();
    let mut out = vec![0.0f32; x.len()];

    // A/B for the §Perf log: per-element scalar path (branch + exp2 per
    // element) vs the hoisted bulk path used everywhere.
    b.bench("quantize_scalar_loop_64k (pre-opt baseline)", || {
        let mut acc = 0.0f32;
        for &v in &x {
            acc += mpq::quant::quantize_scalar(v, 0.7, 1.4, 4.0);
        }
        black_box(acc);
    });
    b.bench("quantize_64k_alloc", || {
        black_box(quantize(black_box(&x), 0.7, 1.4, 4.0));
    });
    b.bench("quantize_into_64k", || {
        quantize_into(black_box(&x), 0.7, 1.4, 4.0, black_box(&mut out));
    });
    b.bench("eps_qe_64k", || {
        black_box(eps_qe(black_box(&x), 4.0));
    });
    let small: Vec<f32> = x[..256].to_vec();
    b.bench("eps_qe_256", || {
        black_box(eps_qe(black_box(&small), 4.0));
    });
}
