//! # mpq — sensitivity-guided mixed-precision post-training quantization
//!
//! Rust coordinator (L3) of the three-layer reproduction of *"Mixed Precision
//! Post Training Quantization of Neural Networks with Sensitivity Guided
//! Search"* (Schaefer et al., 2023). The JAX/Pallas layers (L2/L1) live under
//! `python/` and are AOT-compiled into `artifacts/*.hlo.txt`; this crate owns
//! everything on the request path:
//!
//! * [`runtime`] — PJRT client wrapper: load HLO text, compile, execute.
//! * [`model`] — artifact manifests, parameter store, dataset loaders.
//! * [`quant`] — Eq. 1 quantizer mirror, per-layer configurations, scale
//!   calibration + backprop adjustment drivers.
//! * [`sensitivity`] — the paper's three metrics (ε_QE, ε_N, ε_Hessian)
//!   plus the cross-layer inter-layer-augmented metric.
//! * [`coordinator`] — the evaluation pipeline, the bisection (Alg. 1)
//!   and greedy (Alg. 2) configuration searches, and the sharded
//!   calibration/sensitivity stage driver (`coordinator::shard`).
//! * [`api`] — the unified constrained-search front door: `SearchSpec` →
//!   `SearchSession`, pluggable objectives and cost models, typed search
//!   events, checkpoint/resume.
//! * [`experiment`] — the declarative experiment harness: YAML-subset
//!   suites, isolated multi-worker-count variant execution, typed-event
//!   metric extraction, and the baseline regression gate.
//! * [`latency`] — the roofline accelerator model + kernel latency table
//!   standing in for the paper's CUTLASS-profiled A100 measurements.
//! * [`report`] — regenerates every table and figure of the paper.
//! * [`server`] — a multi-worker batching inference engine with admission
//!   control, per-request deadlines and bounded stats.
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod api;
pub mod coordinator;
pub mod experiment;
pub mod latency;
pub mod model;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sensitivity;
pub mod server;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Locate the artifacts directory: `$MPQ_ARTIFACTS` or `./artifacts`,
/// walking up from the current directory so tests/examples work from
/// any workspace subdirectory.
pub fn artifacts_dir() -> Option<std::path::PathBuf> {
    if let Ok(dir) = std::env::var("MPQ_ARTIFACTS") {
        let p = std::path::PathBuf::from(dir);
        return p.is_dir().then_some(p);
    }
    let mut cur = std::env::current_dir().ok()?;
    loop {
        let cand = cur.join("artifacts");
        if cand.join("index.json").is_file() {
            return Some(cand);
        }
        if !cur.pop() {
            return None;
        }
    }
}
