//! The JSON manifest emitted by `python/compile/aot.py` — the contract
//! between the build path and this coordinator. Field names must stay in
//! sync with `export_model` (checked by `python/tests/test_aot.py` and the
//! integration tests here).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::util::json::{self, Value};

/// Manifest schema version this crate understands.
pub const SUPPORTED_VERSION: u32 = 4;

#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u32,
    pub model: String,
    pub task: String,
    pub num_quant_layers: usize,
    pub eval_batch: usize,
    pub calib_batch: usize,
    pub x_dtype: String,
    pub x_shape: Vec<usize>,
    pub y_shape: Vec<usize>,
    pub params_bin: String,
    pub params: Vec<ParamInfo>,
    pub layers: Vec<LayerInfo>,
    pub graphs: HashMap<String, String>,
    pub data: HashMap<String, SplitMeta>,
    pub float_val_loss: f64,
    pub float_val_acc: f64,
}

#[derive(Debug, Clone)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub numel: usize,
    /// Element (not byte) offset into the flat f32 parameter blob.
    pub offset: usize,
}

#[derive(Debug, Clone)]
pub struct LayerInfo {
    pub name: String,
    /// Weight parameter name; empty for non-parameterized kernels.
    pub param: String,
    /// `conv2d` | `gemm` | `attn_gemm` | `embed`.
    pub kind: String,
    pub quantizable: bool,
    /// Multiply-accumulates at inference batch 1.
    pub macs: u64,
    pub weight_numel: u64,
    pub act_in_numel: u64,
    pub out_numel: u64,
    /// GEMM-equivalent dimensions (convs via implicit GEMM).
    pub m: u64,
    pub n: u64,
    pub k: u64,
    /// Index into the quantization vectors; -1 if not quantizable.
    pub quant_index: i64,
}

#[derive(Debug, Clone)]
pub struct SplitMeta {
    pub count: usize,
    pub x_shape: Vec<usize>,
    pub x_dtype: String,
    pub y_shape: Vec<usize>,
    pub y_dtype: String,
    pub x_file: String,
    pub y_file: String,
}

fn parse_param(v: &Value) -> Result<ParamInfo> {
    Ok(ParamInfo {
        name: v.req("name")?.as_str()?.to_string(),
        shape: v.req("shape")?.as_usize_vec()?,
        numel: v.req("numel")?.as_usize()?,
        offset: v.req("offset")?.as_usize()?,
    })
}

fn parse_layer(v: &Value) -> Result<LayerInfo> {
    Ok(LayerInfo {
        name: v.req("name")?.as_str()?.to_string(),
        param: v.req("param")?.as_str()?.to_string(),
        kind: v.req("kind")?.as_str()?.to_string(),
        quantizable: v.req("quantizable")?.as_bool()?,
        macs: v.req("macs")?.as_u64()?,
        weight_numel: v.req("weight_numel")?.as_u64()?,
        act_in_numel: v.req("act_in_numel")?.as_u64()?,
        out_numel: v.req("out_numel")?.as_u64()?,
        m: v.req("m")?.as_u64()?,
        n: v.req("n")?.as_u64()?,
        k: v.req("k")?.as_u64()?,
        quant_index: v.req("quant_index")?.as_i64()?,
    })
}

fn parse_split(v: &Value) -> Result<SplitMeta> {
    Ok(SplitMeta {
        count: v.req("count")?.as_usize()?,
        x_shape: v.req("x_shape")?.as_usize_vec()?,
        x_dtype: v.req("x_dtype")?.as_str()?.to_string(),
        y_shape: v.req("y_shape")?.as_usize_vec()?,
        y_dtype: v.req("y_dtype")?.as_str()?.to_string(),
        x_file: v.req("x_file")?.as_str()?.to_string(),
        y_file: v.req("y_file")?.as_str()?.to_string(),
    })
}

impl Manifest {
    pub fn from_json(v: &Value) -> Result<Self> {
        let params = v.req("params")?.as_arr()?.iter().map(parse_param).collect::<Result<_>>()?;
        let layers = v.req("layers")?.as_arr()?.iter().map(parse_layer).collect::<Result<_>>()?;
        let graphs = match v.req("graphs")? {
            Value::Obj(m) => m
                .iter()
                .map(|(k, val)| Ok((k.clone(), val.as_str()?.to_string())))
                .collect::<Result<HashMap<_, _>>>()?,
            _ => anyhow::bail!("graphs must be an object"),
        };
        let data = match v.req("data")? {
            Value::Obj(m) => m
                .iter()
                .map(|(k, val)| Ok((k.clone(), parse_split(val)?)))
                .collect::<Result<HashMap<_, _>>>()?,
            _ => anyhow::bail!("data must be an object"),
        };
        let m = Manifest {
            version: v.req("version")?.as_usize()? as u32,
            model: v.req("model")?.as_str()?.to_string(),
            task: v.req("task")?.as_str()?.to_string(),
            num_quant_layers: v.req("num_quant_layers")?.as_usize()?,
            eval_batch: v.req("eval_batch")?.as_usize()?,
            calib_batch: v.req("calib_batch")?.as_usize()?,
            x_dtype: v.req("x_dtype")?.as_str()?.to_string(),
            x_shape: v.req("x_shape")?.as_usize_vec()?,
            y_shape: v.req("y_shape")?.as_usize_vec()?,
            params_bin: v.req("params_bin")?.as_str()?.to_string(),
            params,
            layers,
            graphs,
            data,
            float_val_loss: v.req("float_val_loss")?.as_f64()?,
            float_val_acc: v.req("float_val_acc")?.as_f64()?,
        };
        m.validate()?;
        Ok(m)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let v = json::parse(&text)
            .with_context(|| format!("parsing manifest {}", path.display()))?;
        Self::from_json(&v)
    }

    /// Internal consistency checks run at load time — fail fast on stale or
    /// hand-edited artifacts rather than deep inside a search.
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.version == SUPPORTED_VERSION,
            "manifest version {} != supported {}",
            self.version,
            SUPPORTED_VERSION
        );
        let nq = self.layers.iter().filter(|l| l.quantizable).count();
        ensure!(
            nq == self.num_quant_layers,
            "quantizable layer count {nq} != num_quant_layers {}",
            self.num_quant_layers
        );
        // quant_index must be exactly 0..nq in layer order.
        let mut expect = 0i64;
        for l in &self.layers {
            if l.quantizable {
                ensure!(l.quant_index == expect, "layer {} quant_index out of order", l.name);
                expect += 1;
            } else {
                ensure!(l.quant_index == -1, "non-quantizable layer {} has quant_index", l.name);
            }
        }
        // Parameter offsets must be monotone and tightly packed.
        let mut off = 0usize;
        for p in &self.params {
            ensure!(p.offset == off, "param {} offset {} != expected {off}", p.name, p.offset);
            ensure!(p.numel == p.shape.iter().product::<usize>(), "param {} numel", p.name);
            off += p.numel;
        }
        // Every quantizable layer's weight param must exist.
        for l in self.layers.iter().filter(|l| l.quantizable) {
            ensure!(
                self.params.iter().any(|p| p.name == l.param),
                "layer {} references missing param {}",
                l.name,
                l.param
            );
        }
        for graph in ["eval", "logits", "actstats", "scale_grad", "hvp"] {
            ensure!(self.graphs.contains_key(graph), "missing graph {graph}");
        }
        Ok(())
    }

    /// A schema-valid synthetic manifest with `layers` quantizable layers
    /// cycling through four GEMM-equivalent shape classes — the costing
    /// counterpart of the artifact-free synthetic search environment. No
    /// parameter blob, graphs, or data files exist on disk; the manifest is
    /// only ever consumed by cost models and kernel-table validation (the
    /// checked-in example tables under `rust/tables/` cover exactly these
    /// shape classes).
    pub fn synthetic(layers: usize) -> Self {
        const CLASSES: [(&str, u64, u64, u64); 4] = [
            ("gemm", 64, 256, 256),
            ("gemm", 64, 512, 256),
            ("attn_gemm", 64, 256, 64),
            ("conv2d", 196, 128, 576),
        ];
        let mut params = Vec::with_capacity(layers);
        let mut quant_layers = Vec::with_capacity(layers);
        let mut offset = 0usize;
        for i in 0..layers {
            let (kind, m, n, k) = CLASSES[i % CLASSES.len()];
            let weight_numel = (n * k) as usize;
            let param = format!("syn{i}_w");
            params.push(ParamInfo {
                name: param.clone(),
                shape: vec![k as usize, n as usize],
                numel: weight_numel,
                offset,
            });
            offset += weight_numel;
            quant_layers.push(LayerInfo {
                name: format!("syn{i}"),
                param,
                kind: kind.to_string(),
                quantizable: true,
                macs: m * n * k,
                weight_numel: weight_numel as u64,
                act_in_numel: m * k,
                out_numel: m * n,
                m,
                n,
                k,
                quant_index: i as i64,
            });
        }
        let graphs = ["eval", "logits", "actstats", "scale_grad", "hvp"]
            .into_iter()
            .map(|g| (g.to_string(), format!("synthetic_{g}.hlo.txt")))
            .collect();
        let m = Manifest {
            version: SUPPORTED_VERSION,
            model: "synthetic".to_string(),
            task: "synthetic".to_string(),
            num_quant_layers: layers,
            eval_batch: 8,
            calib_batch: 8,
            x_dtype: "f32".to_string(),
            x_shape: vec![64],
            y_shape: Vec::new(),
            params_bin: "synthetic_params.bin".to_string(),
            params,
            layers: quant_layers,
            graphs,
            data: HashMap::new(),
            float_val_loss: 0.0,
            float_val_acc: 1.0,
        };
        debug_assert!(m.validate().is_ok(), "synthetic manifest must validate");
        m
    }

    /// Total parameter elements (f32 blob length).
    pub fn total_param_elems(&self) -> usize {
        self.params.iter().map(|p| p.numel).sum()
    }

    /// Quantizable layers in quant-index order.
    pub fn quant_layers(&self) -> Vec<&LayerInfo> {
        self.layers.iter().filter(|l| l.quantizable).collect()
    }

    /// Parameter table index for a parameter name.
    pub fn param_index(&self, name: &str) -> Option<usize> {
        self.params.iter().position(|p| p.name == name)
    }
}

#[cfg(test)]
pub(crate) mod test_fixtures {
    /// A minimal well-formed manifest JSON for unit tests across modules.
    pub fn toy_manifest_json() -> String {
        r#"{
          "version": 4, "model": "toy", "task": "vision",
          "num_quant_layers": 2, "eval_batch": 4, "calib_batch": 4,
          "x_dtype": "f32", "x_shape": [4], "y_shape": [],
          "params_bin": "toy_params.bin",
          "params": [
            {"name": "l0_w", "shape": [4, 4], "numel": 16, "offset": 0},
            {"name": "l0_b", "shape": [4], "numel": 4, "offset": 16},
            {"name": "l1_w", "shape": [4, 2], "numel": 8, "offset": 20}
          ],
          "layers": [
            {"name": "l0", "param": "l0_w", "kind": "gemm", "quantizable": true,
             "macs": 16, "weight_numel": 16, "act_in_numel": 4, "out_numel": 4,
             "m": 1, "n": 4, "k": 4, "quant_index": 0},
            {"name": "mid", "param": "", "kind": "attn_gemm", "quantizable": false,
             "macs": 8, "weight_numel": 0, "act_in_numel": 4, "out_numel": 4,
             "m": 1, "n": 2, "k": 4, "quant_index": -1},
            {"name": "l1", "param": "l1_w", "kind": "gemm", "quantizable": true,
             "macs": 8, "weight_numel": 8, "act_in_numel": 4, "out_numel": 2,
             "m": 1, "n": 2, "k": 4, "quant_index": 1}
          ],
          "graphs": {"eval": "toy_eval.hlo.txt", "logits": "toy_logits.hlo.txt",
                      "actstats": "toy_actstats.hlo.txt",
                      "scale_grad": "toy_scale_grad.hlo.txt", "hvp": "toy_hvp.hlo.txt"},
          "data": {"val": {"count": 8, "x_shape": [8, 4], "x_dtype": "f32",
                            "y_shape": [8], "y_dtype": "i32",
                            "x_file": "toy_val_x.bin", "y_file": "toy_val_y.bin"}},
          "float_val_loss": 0.1, "float_val_acc": 0.97
        }"#
        .to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Manifest {
        let v = json::parse(&test_fixtures::toy_manifest_json()).unwrap();
        Manifest::from_json(&v).unwrap()
    }

    #[test]
    fn parses_and_validates() {
        let m = toy();
        assert_eq!(m.model, "toy");
        assert_eq!(m.num_quant_layers, 2);
        assert_eq!(m.total_param_elems(), 28);
        assert_eq!(m.quant_layers().len(), 2);
        assert_eq!(m.quant_layers()[1].name, "l1");
        assert_eq!(m.param_index("l1_w"), Some(2));
        assert_eq!(m.data["val"].count, 8);
    }

    #[test]
    fn synthetic_manifest_validates_and_cycles_shape_classes() {
        for layers in [1, 4, 6, 13] {
            let m = Manifest::synthetic(layers);
            m.validate().unwrap();
            assert_eq!(m.num_quant_layers, layers);
            assert_eq!(m.quant_layers().len(), layers);
            assert!((m.float_val_acc - 1.0).abs() < 1e-12);
        }
        let m = Manifest::synthetic(6);
        // Layers 0 and 4 share a shape class; 0..4 are all distinct.
        assert_eq!(m.layers[0].kind, m.layers[4].kind);
        assert_eq!(m.layers[0].n, m.layers[4].n);
        let classes: std::collections::HashSet<_> =
            m.layers[..4].iter().map(|l| (l.kind.clone(), l.m, l.n, l.k)).collect();
        assert_eq!(classes.len(), 4, "first four layers span four shape classes");
    }

    #[test]
    fn rejects_bad_version() {
        let text = test_fixtures::toy_manifest_json().replace("\"version\": 4", "\"version\": 99");
        let v = json::parse(&text).unwrap();
        assert!(Manifest::from_json(&v).is_err());
    }

    #[test]
    fn rejects_wrong_quant_count() {
        let text = test_fixtures::toy_manifest_json()
            .replace("\"num_quant_layers\": 2", "\"num_quant_layers\": 3");
        let v = json::parse(&text).unwrap();
        assert!(Manifest::from_json(&v).is_err());
    }

    #[test]
    fn rejects_gapped_offsets() {
        let text = test_fixtures::toy_manifest_json().replace("\"offset\": 16", "\"offset\": 17");
        let v = json::parse(&text).unwrap();
        assert!(Manifest::from_json(&v).is_err());
    }

    #[test]
    fn rejects_missing_graph() {
        let text = test_fixtures::toy_manifest_json()
            .replace("\"hvp\": \"toy_hvp.hlo.txt\"", "\"zzz\": \"x\"");
        let v = json::parse(&text).unwrap();
        assert!(Manifest::from_json(&v).is_err());
    }
}
