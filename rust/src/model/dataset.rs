//! Dataset splits written by `python/compile/data.py` as raw binaries.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use super::manifest::SplitMeta;
use crate::runtime::HostTensor;

/// One dataset split held in host memory.
pub struct Split {
    pub x: HostTensor,
    pub y: HostTensor,
    pub count: usize,
}

fn read_f32(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    ensure!(bytes.len() % 4 == 0, "{} not a multiple of 4 bytes", path.display());
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

fn read_i32(path: &Path) -> Result<Vec<i32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    ensure!(bytes.len() % 4 == 0, "{} not a multiple of 4 bytes", path.display());
    Ok(bytes.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

fn load_tensor(dir: &Path, file: &str, dtype: &str, dims: &[usize]) -> Result<HostTensor> {
    let path = dir.join(file);
    let numel: usize = dims.iter().product();
    let t = match dtype {
        "float32" | "f32" => {
            let data = read_f32(&path)?;
            ensure!(data.len() == numel, "{file}: {} elems, expected {numel}", data.len());
            HostTensor::f32(data, dims.to_vec())
        }
        "int32" | "i32" => {
            let data = read_i32(&path)?;
            ensure!(data.len() == numel, "{file}: {} elems, expected {numel}", data.len());
            HostTensor::i32(data, dims.to_vec())
        }
        other => anyhow::bail!("unsupported dtype {other}"),
    };
    Ok(t)
}

impl Split {
    pub fn load(dir: &Path, meta: &SplitMeta) -> Result<Self> {
        let x = load_tensor(dir, &meta.x_file, &meta.x_dtype, &meta.x_shape)?;
        let y = load_tensor(dir, &meta.y_file, &meta.y_dtype, &meta.y_shape)?;
        ensure!(x.dims()[0] == meta.count && y.dims()[0] == meta.count, "split count mismatch");
        Ok(Self { x, y, count: meta.count })
    }

    /// Number of full batches of size `batch` (trailing remainder dropped,
    /// matching the python-side evaluation convention).
    pub fn num_batches(&self, batch: usize) -> usize {
        self.count / batch
    }

    /// The `i`-th full batch as host tensors.
    pub fn batch(&self, i: usize, batch: usize) -> (HostTensor, HostTensor) {
        (self.x.slice_rows(i * batch, batch), self.y.slice_rows(i * batch, batch))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batching() {
        let s = Split {
            x: HostTensor::f32((0..20).map(|v| v as f32).collect(), vec![10, 2]),
            y: HostTensor::i32((0..10).collect(), vec![10]),
            count: 10,
        };
        assert_eq!(s.num_batches(4), 2);
        let (x, y) = s.batch(1, 4);
        assert_eq!(x.dims(), &[4, 2]);
        assert_eq!(y.dims(), &[4]);
        assert_eq!(y.i32_data().unwrap(), &[4, 5, 6, 7]);
    }
}
