//! Artifact model descriptions: manifests, parameters, datasets.

mod dataset;
mod manifest;
mod params;

pub use dataset::Split;
pub use manifest::{LayerInfo, Manifest, ParamInfo, SplitMeta};
pub use params::ParamStore;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json;

/// `artifacts/index.json` — the list of exported models.
#[derive(Debug)]
pub struct ArtifactIndex {
    pub version: u32,
    pub models: Vec<ArtifactEntry>,
}

#[derive(Debug)]
pub struct ArtifactEntry {
    pub model: String,
    pub manifest: String,
}

impl ArtifactIndex {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("index.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        let models = v
            .req("models")?
            .as_arr()?
            .iter()
            .map(|e| {
                Ok(ArtifactEntry {
                    model: e.req("model")?.as_str()?.to_string(),
                    manifest: e.req("manifest")?.as_str()?.to_string(),
                })
            })
            .collect::<Result<_>>()?;
        Ok(Self { version: v.req("version")?.as_usize()? as u32, models })
    }
}

/// Everything loaded from disk for one model: manifest + parameter blob +
/// the three data splits. Graph compilation happens lazily in the pipeline.
pub struct ModelArtifacts {
    pub dir: PathBuf,
    pub manifest: Manifest,
    pub params: ParamStore,
    pub calib_sens: Split,
    pub calib_adj: Split,
    pub val: Split,
}

impl ModelArtifacts {
    /// Load `{name}_manifest.json` and everything it references.
    pub fn load(dir: &Path, name: &str) -> Result<Self> {
        let manifest = Manifest::load(&dir.join(format!("{name}_manifest.json")))?;
        let params = ParamStore::load(dir, &manifest)?;
        let calib_sens = Split::load(dir, &manifest.data["calib_sens"])?;
        let calib_adj = Split::load(dir, &manifest.data["calib_adj"])?;
        let val = Split::load(dir, &manifest.data["val"])?;
        Ok(Self { dir: dir.to_path_buf(), manifest, params, calib_sens, calib_adj, val })
    }

    /// Absolute path of one of this model's HLO graph artifacts.
    pub fn graph_path(&self, graph: &str) -> Result<PathBuf> {
        let file = self
            .manifest
            .graphs
            .get(graph)
            .ok_or_else(|| anyhow::anyhow!("model {} has no graph {graph}", self.manifest.model))?;
        Ok(self.dir.join(file))
    }
}
