//! Flat f32 parameter blob + per-parameter views.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use super::manifest::Manifest;

/// The model's parameters as one contiguous little-endian f32 blob, in
/// manifest order — exactly the layout `aot.py` wrote and the AOT graphs
/// expect as their leading arguments.
#[derive(Clone)]
pub struct ParamStore {
    blob: Vec<f32>,
    /// (name, element offset, numel, dims) per parameter, manifest order.
    index: Vec<(String, usize, usize, Vec<usize>)>,
}

impl ParamStore {
    pub fn load(dir: &Path, manifest: &Manifest) -> Result<Self> {
        let path = dir.join(&manifest.params_bin);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading params blob {}", path.display()))?;
        ensure!(bytes.len() % 4 == 0, "params blob not a multiple of 4 bytes");
        let blob: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        ensure!(
            blob.len() == manifest.total_param_elems(),
            "params blob has {} elems, manifest expects {}",
            blob.len(),
            manifest.total_param_elems()
        );
        let index = manifest
            .params
            .iter()
            .map(|p| (p.name.clone(), p.offset, p.numel, p.shape.clone()))
            .collect();
        Ok(Self { blob, index })
    }

    /// Build directly from host data (tests / synthetic stores).
    pub fn from_parts(blob: Vec<f32>, index: Vec<(String, usize, usize, Vec<usize>)>) -> Self {
        Self { blob, index }
    }

    pub fn num_params(&self) -> usize {
        self.index.len()
    }

    pub fn name(&self, i: usize) -> &str {
        &self.index[i].0
    }

    pub fn dims(&self, i: usize) -> &[usize] {
        &self.index[i].3
    }

    /// View of parameter `i`'s elements.
    pub fn values(&self, i: usize) -> &[f32] {
        let (_, off, n, _) = &self.index[i];
        &self.blob[*off..*off + *n]
    }

    /// Mutable view (used by the noise metric's perturb-and-eval).
    pub fn values_mut(&mut self, i: usize) -> &mut [f32] {
        let (_, off, n, _) = self.index[i].clone();
        &mut self.blob[off..off + n]
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.index.iter().position(|(n, ..)| n == name)
    }

    /// `max |w|` of parameter `i` — the paper's weight calibration statistic.
    pub fn max_abs(&self, i: usize) -> f32 {
        self.values(i).iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> ParamStore {
        ParamStore::from_parts(
            vec![1.0, -2.0, 3.0, -4.5, 0.5, 0.0],
            vec![
                ("a".into(), 0, 4, vec![2, 2]),
                ("b".into(), 4, 2, vec![2]),
            ],
        )
    }

    #[test]
    fn views_and_maxabs() {
        let s = store();
        assert_eq!(s.values(0), &[1.0, -2.0, 3.0, -4.5]);
        assert_eq!(s.values(1), &[0.5, 0.0]);
        assert_eq!(s.max_abs(0), 4.5);
        assert_eq!(s.max_abs(1), 0.5);
        assert_eq!(s.index_of("b"), Some(1));
        assert_eq!(s.dims(0), &[2, 2]);
    }

    #[test]
    fn mutation_is_local() {
        let mut s = store();
        s.values_mut(1)[0] = 9.0;
        assert_eq!(s.values(0), &[1.0, -2.0, 3.0, -4.5]);
        assert_eq!(s.values(1), &[9.0, 0.0]);
    }
}
