//! Latency and size cost models.
//!
//! The paper estimates deployment latency by profiling gemm/conv kernels at
//! each precision on an A100 with CUTLASS (batch 1) and composing per-layer
//! kernel latencies. That profiler and hardware are not available here, so
//! we reproduce the *mechanism* exactly — a kernel-latency lookup table
//! composed per layer — and substitute the table's provenance with an
//! analytical roofline model of an A100-class accelerator (DESIGN.md §2).
//!
//! The model captures the effects that shape the paper's numbers:
//! * per-precision peak math throughput (int4 : int8 : fp16 = 4 : 2 : 1),
//! * HBM bandwidth bounding memory-bound layers (most of them at batch 1),
//! * fixed per-kernel launch overhead (diminishing returns at low bits),
//! * tile-quantization efficiency loss for shapes that fit the MXU poorly.

mod accel;
mod table;

pub use accel::{AccelModel, Precision};
pub use table::{KernelKey, KernelTable};

use crate::model::{LayerInfo, Manifest};
use crate::quant::{BitWidth, QuantConfig};

/// Reference fp16 deployment footprints of the architectures our stand-ins
/// represent (paper Table 1: ResNet50 51.00 MB, BERT 603.98 MB).
fn reference_fp16_bytes(task: &str) -> f64 {
    match task {
        "vision" => 51.00e6,
        "span" => 603.98e6,
        _ => 100.0e6,
    }
}

/// Channel/width multiplier mapping a stand-in architecture onto the
/// deployment-class model it represents.
///
/// The synthetic models are hundreds of times smaller than ResNet50/BERT so
/// that thousands of search evaluations stay tractable on CPU PJRT; at those
/// sizes a physical A100 latency model degenerates (launch overhead is 98%
/// of every kernel and precision stops mattering). The cost models therefore
/// evaluate each layer at *deployment scale*: channel-like dimensions (n, k)
/// grow by `s`, weights by `s^2`, activations by `s`, MACs by `s^2`, with
/// `s = sqrt(reference fp16 bytes / stand-in fp16 bytes)`. This preserves
/// the architecture's *shape* (depth, layer mix, relative widths) while the
/// absolute operating point matches the hardware the paper profiled.
#[derive(Debug, Clone, Copy)]
pub struct DeployScale {
    pub s: f64,
}

impl DeployScale {
    /// Identity (cost the stand-in as-is).
    pub fn native() -> Self {
        Self { s: 1.0 }
    }

    /// Match the reference deployment footprint for this manifest's task.
    pub fn for_manifest(manifest: &Manifest) -> Self {
        let fp16_bytes = manifest.total_param_elems() as f64 * 2.0;
        let s = (reference_fp16_bytes(&manifest.task) / fp16_bytes).sqrt();
        Self { s: s.max(1.0) }
    }

    /// Scale one layer's dimensions to deployment size.
    pub fn apply(&self, l: &LayerInfo) -> LayerInfo {
        let s = self.s;
        let s2 = s * s;
        let mut out = l.clone();
        // Embedding rows scale like d_model (s), not s^2 (vocab fixed).
        let wscale = if l.kind == "embed" { s } else { s2 };
        out.macs = (l.macs as f64 * s2) as u64;
        out.weight_numel = (l.weight_numel as f64 * wscale) as u64;
        out.act_in_numel = (l.act_in_numel as f64 * s) as u64;
        out.out_numel = (l.out_numel as f64 * s) as u64;
        out.n = (l.n as f64 * s).round().max(1.0) as u64;
        out.k = (l.k as f64 * s).round().max(1.0) as u64;
        out
    }
}

/// Composes per-layer kernel latencies + parameter bytes into model-level
/// latency/size, absolute and relative to the fp16 baseline.
///
/// The per-kernel numbers come either from the analytical roofline
/// ([`CostModel::with_scale`], the paper's substituted profiler) or from a
/// measured [`KernelTable`] file ([`CostModel::with_table`]); the
/// provenance of whichever source built the model travels into reports.
/// Implements [`crate::api::CostModel`], the trait objectives consume.
pub struct CostModel {
    table: KernelTable,
    layers: Vec<LayerInfo>,
    /// Total deployment-scale parameter elements for size accounting.
    total_param_elems: u64,
    /// fp16 baselines, computed once.
    base_latency_s: f64,
    base_size_bytes: f64,
    /// Where the kernel latencies come from (`analytical/<accel>` or
    /// `measured/<file>`).
    provenance: String,
}

impl CostModel {
    /// Cost model at deployment scale (see [`DeployScale`]).
    pub fn new(manifest: &Manifest, accel: &AccelModel) -> Self {
        Self::with_scale(manifest, accel, DeployScale::for_manifest(manifest))
    }

    pub fn with_scale(manifest: &Manifest, accel: &AccelModel, scale: DeployScale) -> Self {
        let layers: Vec<LayerInfo> = manifest.layers.iter().map(|l| scale.apply(l)).collect();
        let table = KernelTable::profile(accel, &layers);
        Self::assemble(manifest, table, layers, scale, format!("analytical/{}", accel.name))
    }

    /// Cost model over a measured kernel table (e.g. loaded with
    /// [`KernelTable::from_json`]). The table must cover every layer ×
    /// [`crate::quant::BitWidth`] pair at deployment scale — validated up
    /// front so a sparse file fails here, with the missing kernel named,
    /// instead of panicking mid-search.
    pub fn with_table(
        manifest: &Manifest,
        table: KernelTable,
        scale: DeployScale,
        provenance: impl Into<String>,
    ) -> crate::Result<Self> {
        let layers: Vec<LayerInfo> = manifest.layers.iter().map(|l| scale.apply(l)).collect();
        table.validate_for(&layers)?;
        Ok(Self::assemble(manifest, table, layers, scale, provenance.into()))
    }

    fn assemble(
        manifest: &Manifest,
        table: KernelTable,
        layers: Vec<LayerInfo>,
        scale: DeployScale,
        provenance: String,
    ) -> Self {
        // Non-layer parameters (biases, norms) scale like s; layer weights
        // like s^2 (already applied). Total = scaled weights + scaled rest.
        let weight_elems: u64 = manifest.layers.iter().map(|l| l.weight_numel).sum();
        let rest = manifest.total_param_elems() as f64 - weight_elems as f64;
        let scaled_weights: u64 = layers.iter().map(|l| l.weight_numel).sum();
        let total_param_elems = scaled_weights + (rest * scale.s) as u64;
        let mut cm = Self {
            table,
            layers,
            total_param_elems,
            base_latency_s: 0.0,
            base_size_bytes: 0.0,
            provenance,
        };
        let float_cfg = QuantConfig::float(manifest.num_quant_layers);
        cm.base_latency_s = cm.latency_s(&float_cfg);
        cm.base_size_bytes = cm.size_bytes(&float_cfg);
        cm
    }

    /// Where this model's kernel latencies come from.
    pub fn provenance(&self) -> &str {
        &self.provenance
    }

    /// End-to-end model latency (seconds, batch 1) for a configuration.
    pub fn latency_s(&self, cfg: &QuantConfig) -> f64 {
        self.layers
            .iter()
            .map(|l| {
                let (bw, ba) = if l.quant_index >= 0 {
                    let qi = l.quant_index as usize;
                    (BitWidth::from_bits(cfg.bits_w[qi]), BitWidth::from_bits(cfg.bits_a[qi]))
                } else {
                    (BitWidth::Fp16, BitWidth::Fp16)
                };
                self.table.lookup(l, bw, ba)
            })
            .sum()
    }

    /// Model size in bytes: quantizable weights at their configured width,
    /// everything else (biases, norms, unquantized tensors) at fp16.
    pub fn size_bytes(&self, cfg: &QuantConfig) -> f64 {
        let mut quant_elems = 0u64;
        let mut quant_bytes = 0.0f64;
        for l in &self.layers {
            if l.quant_index >= 0 {
                let bits = cfg.bits_w[l.quant_index as usize] as f64;
                quant_elems += l.weight_numel;
                quant_bytes += l.weight_numel as f64 * bits / 8.0;
            }
        }
        let other_elems = self.total_param_elems - quant_elems;
        quant_bytes + other_elems as f64 * 2.0
    }

    /// Latency relative to the fp16 baseline (the paper's table unit).
    pub fn rel_latency(&self, cfg: &QuantConfig) -> f64 {
        self.latency_s(cfg) / self.base_latency_s
    }

    /// Size relative to the fp16 baseline.
    pub fn rel_size(&self, cfg: &QuantConfig) -> f64 {
        self.size_bytes(cfg) / self.base_size_bytes
    }

    pub fn base_latency_ms(&self) -> f64 {
        self.base_latency_s * 1e3
    }

    pub fn base_size_mb(&self) -> f64 {
        self.base_size_bytes / 1e6
    }

    pub fn table(&self) -> &KernelTable {
        &self.table
    }
}

impl crate::api::CostModel for CostModel {
    fn rel_latency(&self, cfg: &QuantConfig) -> f64 {
        // Inherent methods take precedence, so these delegate to the
        // struct's own implementations above.
        self.rel_latency(cfg)
    }

    fn rel_size(&self, cfg: &QuantConfig) -> f64 {
        self.rel_size(cfg)
    }

    fn latency_s(&self, cfg: &QuantConfig) -> f64 {
        self.latency_s(cfg)
    }

    fn size_bytes(&self, cfg: &QuantConfig) -> f64 {
        self.size_bytes(cfg)
    }

    fn provenance(&self) -> &str {
        self.provenance()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LayerInfo;

    fn layer(name: &str, qi: i64, weight: u64, macs: u64) -> LayerInfo {
        LayerInfo {
            name: name.into(),
            param: format!("{name}_w"),
            kind: "gemm".into(),
            quantizable: qi >= 0,
            macs,
            weight_numel: weight,
            act_in_numel: 64,
            out_numel: 64,
            m: 16,
            n: 64,
            k: 64,
            quant_index: qi,
        }
    }

    fn manifest() -> Manifest {
        // Construct a minimal manifest via JSON to exercise the same path
        // as artifact loading.
        let layers = [layer("l0", 0, 4096, 65536), layer("l1", 1, 8192, 131072)];
        let layer_json: Vec<String> = layers
            .iter()
            .map(|l| {
                format!(
                    r#"{{"name": "{}", "param": "{}", "kind": "{}", "quantizable": {},
                        "macs": {}, "weight_numel": {}, "act_in_numel": {},
                        "out_numel": {}, "m": {}, "n": {}, "k": {}, "quant_index": {}}}"#,
                    l.name, l.param, l.kind, l.quantizable, l.macs, l.weight_numel,
                    l.act_in_numel, l.out_numel, l.m, l.n, l.k, l.quant_index
                )
            })
            .collect();
        let text = format!(
            r#"{{"version": 4, "model": "toy", "task": "vision",
                "num_quant_layers": 2, "eval_batch": 4, "calib_batch": 4,
                "x_dtype": "f32", "x_shape": [4], "y_shape": [],
                "params_bin": "none.bin",
                "params": [
                  {{"name": "l0_w", "shape": [64, 64], "numel": 4096, "offset": 0}},
                  {{"name": "l1_w", "shape": [64, 128], "numel": 8192, "offset": 4096}}
                ],
                "layers": [{}],
                "graphs": {{"eval": "x", "logits": "x", "actstats": "x",
                            "scale_grad": "x", "hvp": "x"}},
                "data": {{}}, "float_val_loss": 0.0, "float_val_acc": 1.0}}"#,
            layer_json.join(",")
        );
        Manifest::from_json(&crate::util::json::parse(&text).unwrap()).unwrap()
    }

    #[test]
    fn size_halves_with_bits() {
        let cm = CostModel::new(&manifest(), &AccelModel::a100_like());
        let n = 2;
        let s16 = cm.size_bytes(&QuantConfig::uniform(n, 16.0));
        let s8 = cm.size_bytes(&QuantConfig::uniform(n, 8.0));
        let s4 = cm.size_bytes(&QuantConfig::uniform(n, 4.0));
        assert!((s8 / s16 - 0.5).abs() < 1e-9);
        assert!((s4 / s16 - 0.25).abs() < 1e-9);
    }

    #[test]
    fn latency_monotone_in_bits() {
        let cm = CostModel::new(&manifest(), &AccelModel::a100_like());
        let n = 2;
        let l16 = cm.latency_s(&QuantConfig::uniform(n, 16.0));
        let l8 = cm.latency_s(&QuantConfig::uniform(n, 8.0));
        let l4 = cm.latency_s(&QuantConfig::uniform(n, 4.0));
        assert!(l4 <= l8 && l8 <= l16);
        // Launch overhead bounds the benefit away from the linear ratio.
        assert!(l4 / l16 > 0.25);
    }

    #[test]
    fn relative_baseline_is_one() {
        let cm = CostModel::new(&manifest(), &AccelModel::a100_like());
        let f = QuantConfig::float(2);
        assert!((cm.rel_latency(&f) - 1.0).abs() < 1e-12);
        assert!((cm.rel_size(&f) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn measured_table_drops_in_beside_analytical() {
        let m = manifest();
        let analytical = CostModel::new(&m, &AccelModel::a100_like());
        assert_eq!(analytical.provenance(), "analytical/a100-like");
        // Round-trip the analytical table through JSON and load it back as
        // a "measured" table: costs must be identical, provenance must
        // record the new source.
        let json = analytical.table().to_json().unwrap();
        let table = KernelTable::from_json(&json).unwrap();
        let measured =
            CostModel::with_table(&m, table, DeployScale::for_manifest(&m), "measured/t.json")
                .unwrap();
        assert_eq!(measured.provenance(), "measured/t.json");
        for bits in [4.0f32, 8.0, 16.0] {
            let cfg = QuantConfig::uniform(2, bits);
            assert_eq!(measured.latency_s(&cfg), analytical.latency_s(&cfg), "{bits}b");
            assert_eq!(measured.size_bytes(&cfg), analytical.size_bytes(&cfg), "{bits}b");
        }
    }

    #[test]
    fn sparse_measured_table_rejected_up_front() {
        let m = manifest();
        // A table profiled for a different kernel shape covers none of the
        // manifest's layers; the error must name the first uncovered one.
        let scale = DeployScale::for_manifest(&m);
        let mut foreign = scale.apply(&m.layers[0]);
        foreign.n += 1;
        let sparse = KernelTable::profile(&AccelModel::a100_like(), &[foreign]);
        let err = CostModel::with_table(&m, sparse, scale, "measured/sparse.json")
            .unwrap_err()
            .to_string();
        assert!(err.contains("`l0`"), "error should name the missing layer: {err}");
    }

    #[test]
    fn mixed_config_between_uniform_bounds() {
        let cm = CostModel::new(&manifest(), &AccelModel::a100_like());
        let mut mixed = QuantConfig::float(2);
        mixed.set_layer(0, 4.0);
        let l = cm.rel_latency(&mixed);
        assert!(l < 1.0 && l > cm.rel_latency(&QuantConfig::uniform(2, 4.0)));
    }
}
