//! Kernel latency table — the paper's CUTLASS-profiler output format.
//!
//! The paper: *"We capture these interactions by benchmarking the
//! performance of key kernels such as gemm and conv2d across different
//! numerical precisions … The best performing kernels for a given tensor
//! shape and precision were determined using the CUTLASS profiler."*
//!
//! [`KernelTable::profile`] plays the role of that profiler run: for every
//! distinct (kind, m, n, k, bytes) kernel shape in a model and every
//! precision, it records a latency produced by the [`AccelModel`]. The
//! [`super::CostModel`] then only ever *looks up* — exactly the paper's
//! two-phase methodology, and the natural place to drop in real measured
//! tables later (the JSON I/O below).

use std::collections::HashMap;

use super::accel::{AccelModel, Precision};
use crate::model::LayerInfo;
use crate::quant::BitWidth;

/// Table key: kernel shape + execution precision + storage widths (storage
/// affects HBM traffic even when the math pipeline is shared).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct KernelKey {
    pub kind: String,
    pub m: u64,
    pub n: u64,
    pub k: u64,
    pub weight_bits: u32,
    pub act_bits: u32,
}

/// Latency lookup table, serializable so a measured table can replace the
/// analytical one without touching any caller.
#[derive(Debug, Clone)]
pub struct KernelTable {
    entries: HashMap<KernelKey, f64>,
    /// Output activations are produced at fp16 (2 bytes/elem).
    pub out_bytes_per_elem: f64,
}

impl KernelTable {
    /// "Profile" every layer of a model at every supported precision pair.
    pub fn profile(accel: &AccelModel, layers: &[LayerInfo]) -> Self {
        let widths = [BitWidth::Int4, BitWidth::Int8, BitWidth::Fp16];
        let mut entries = HashMap::new();
        for layer in layers {
            for w in widths {
                for a in widths {
                    let key = Self::key_for(layer, w, a);
                    let lat = Self::model_latency(accel, layer, w, a);
                    entries.insert(key, lat);
                }
            }
        }
        Self { entries, out_bytes_per_elem: 2.0 }
    }

    fn key_for(layer: &LayerInfo, w: BitWidth, a: BitWidth) -> KernelKey {
        KernelKey {
            kind: layer.kind.clone(),
            m: layer.m,
            n: layer.n,
            k: layer.k,
            weight_bits: w.bits() as u32,
            act_bits: a.bits() as u32,
        }
    }

    fn model_latency(accel: &AccelModel, layer: &LayerInfo, w: BitWidth, a: BitWidth) -> f64 {
        let bytes = layer.weight_numel as f64 * w.bits() as f64 / 8.0
            + layer.act_in_numel as f64 * a.bits() as f64 / 8.0
            + layer.out_numel as f64 * 2.0;
        if layer.kind == "embed" {
            // Lookup kernels move one row per token — pure memory op. The
            // table row count (weight_numel) overstates traffic massively;
            // use act_in (tokens) * row bytes ≈ out_numel at storage width.
            let bytes = layer.out_numel as f64 * w.bits() as f64 / 8.0;
            return bytes / accel.hbm_bytes_per_s + accel.launch_overhead_s;
        }
        let prec = Precision::of_pair(w, a);
        accel.kernel_latency_s(layer.macs, (layer.m, layer.n, layer.k), bytes, prec)
    }

    /// Look up a layer's kernel latency at the given operand widths.
    /// Panics on a missing entry — the table is profiled for exactly the
    /// model it will serve, so a miss is a programming error.
    pub fn lookup(&self, layer: &LayerInfo, w: BitWidth, a: BitWidth) -> f64 {
        *self
            .entries
            .get(&Self::key_for(layer, w, a))
            .unwrap_or_else(|| panic!("kernel table miss: {} {:?}/{:?}", layer.name, w, a))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Serialize (e.g. to ship alongside artifacts, or to diff against a
    /// future measured table).
    pub fn to_json(&self) -> crate::Result<String> {
        use crate::util::json::Value;
        let mut rows: Vec<&KernelKey> = self.entries.keys().collect();
        rows.sort_by_key(|k| (k.kind.clone(), k.m, k.n, k.k, k.weight_bits, k.act_bits));
        let arr = Value::Arr(
            rows.into_iter()
                .map(|k| {
                    Value::obj(vec![
                        ("kind", Value::Str(k.kind.clone())),
                        ("m", Value::Num(k.m as f64)),
                        ("n", Value::Num(k.n as f64)),
                        ("k", Value::Num(k.k as f64)),
                        ("weight_bits", Value::Num(k.weight_bits as f64)),
                        ("act_bits", Value::Num(k.act_bits as f64)),
                        ("latency_s", Value::Num(self.entries[k])),
                    ])
                })
                .collect(),
        );
        Ok(arr.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gemm_layer() -> LayerInfo {
        LayerInfo {
            name: "g".into(),
            param: "g_w".into(),
            kind: "gemm".into(),
            quantizable: true,
            macs: 1 << 20,
            weight_numel: 16384,
            act_in_numel: 128,
            out_numel: 128,
            m: 1,
            n: 128,
            k: 128,
            quant_index: 0,
        }
    }

    #[test]
    fn profile_covers_all_pairs() {
        let t = KernelTable::profile(&AccelModel::a100_like(), &[gemm_layer()]);
        assert_eq!(t.len(), 9);
        let l = gemm_layer();
        for w in [BitWidth::Int4, BitWidth::Int8, BitWidth::Fp16] {
            for a in [BitWidth::Int4, BitWidth::Int8, BitWidth::Fp16] {
                assert!(t.lookup(&l, w, a) > 0.0);
            }
        }
    }

    #[test]
    fn narrower_weights_never_slower() {
        let t = KernelTable::profile(&AccelModel::a100_like(), &[gemm_layer()]);
        let l = gemm_layer();
        let l4 = t.lookup(&l, BitWidth::Int4, BitWidth::Int8);
        let l8 = t.lookup(&l, BitWidth::Int8, BitWidth::Int8);
        let l16 = t.lookup(&l, BitWidth::Fp16, BitWidth::Fp16);
        assert!(l4 <= l8 && l8 <= l16);
    }

    #[test]
    fn json_roundtrip_size() {
        let t = KernelTable::profile(&AccelModel::a100_like(), &[gemm_layer()]);
        let s = t.to_json().unwrap();
        assert!(s.contains("gemm"));
    }
}
