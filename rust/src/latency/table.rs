//! Kernel latency table — the paper's CUTLASS-profiler output format.
//!
//! The paper: *"We capture these interactions by benchmarking the
//! performance of key kernels such as gemm and conv2d across different
//! numerical precisions … The best performing kernels for a given tensor
//! shape and precision were determined using the CUTLASS profiler."*
//!
//! [`KernelTable::profile`] plays the role of that profiler run: for every
//! distinct (kind, m, n, k, bytes) kernel shape in a model and every
//! precision, it records a latency produced by the [`AccelModel`]. The
//! [`super::CostModel`] then only ever *looks up* — exactly the paper's
//! two-phase methodology, and the natural place to drop in real measured
//! tables later (the JSON I/O below).

use std::collections::HashMap;

use super::accel::{AccelModel, Precision};
use crate::model::LayerInfo;
use crate::quant::BitWidth;

/// Table key: kernel shape + execution precision + storage widths (storage
/// affects HBM traffic even when the math pipeline is shared).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct KernelKey {
    pub kind: String,
    pub m: u64,
    pub n: u64,
    pub k: u64,
    pub weight_bits: u32,
    pub act_bits: u32,
}

/// Latency lookup table, serializable so a measured table can replace the
/// analytical one without touching any caller.
#[derive(Debug, Clone)]
pub struct KernelTable {
    entries: HashMap<KernelKey, f64>,
    /// Output activations are produced at fp16 (2 bytes/elem).
    pub out_bytes_per_elem: f64,
}

impl KernelTable {
    /// "Profile" every layer of a model at every supported precision pair.
    pub fn profile(accel: &AccelModel, layers: &[LayerInfo]) -> Self {
        let widths = [BitWidth::Int4, BitWidth::Int8, BitWidth::Fp16];
        let mut entries = HashMap::new();
        for layer in layers {
            for w in widths {
                for a in widths {
                    let key = Self::key_for(layer, w, a);
                    let lat = Self::model_latency(accel, layer, w, a);
                    entries.insert(key, lat);
                }
            }
        }
        Self { entries, out_bytes_per_elem: 2.0 }
    }

    fn key_for(layer: &LayerInfo, w: BitWidth, a: BitWidth) -> KernelKey {
        KernelKey {
            kind: layer.kind.clone(),
            m: layer.m,
            n: layer.n,
            k: layer.k,
            weight_bits: w.bits() as u32,
            act_bits: a.bits() as u32,
        }
    }

    fn model_latency(accel: &AccelModel, layer: &LayerInfo, w: BitWidth, a: BitWidth) -> f64 {
        let bytes = layer.weight_numel as f64 * w.bits() as f64 / 8.0
            + layer.act_in_numel as f64 * a.bits() as f64 / 8.0
            + layer.out_numel as f64 * 2.0;
        if layer.kind == "embed" {
            // Lookup kernels move one row per token — pure memory op. The
            // table row count (weight_numel) overstates traffic massively;
            // use act_in (tokens) * row bytes ≈ out_numel at storage width.
            let bytes = layer.out_numel as f64 * w.bits() as f64 / 8.0;
            return bytes / accel.hbm_bytes_per_s + accel.launch_overhead_s;
        }
        let prec = Precision::of_pair(w, a);
        accel.kernel_latency_s(layer.macs, (layer.m, layer.n, layer.k), bytes, prec)
    }

    /// Look up a layer's kernel latency at the given operand widths.
    /// Panics on a missing entry — the table is profiled for exactly the
    /// model it will serve, so a miss is a programming error.
    pub fn lookup(&self, layer: &LayerInfo, w: BitWidth, a: BitWidth) -> f64 {
        *self
            .entries
            .get(&Self::key_for(layer, w, a))
            .unwrap_or_else(|| panic!("kernel table miss: {} {:?}/{:?}", layer.name, w, a))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Serialize (e.g. to ship alongside artifacts, or to diff against a
    /// measured table). Emits an object with `out_bytes_per_elem` and an
    /// `entries` array; [`KernelTable::from_json`] reads this form and the
    /// older bare-array form.
    pub fn to_json(&self) -> crate::Result<String> {
        use crate::util::json::Value;
        let mut rows: Vec<&KernelKey> = self.entries.keys().collect();
        rows.sort_by_key(|k| (k.kind.clone(), k.m, k.n, k.k, k.weight_bits, k.act_bits));
        let arr = Value::Arr(
            rows.into_iter()
                .map(|k| {
                    Value::obj(vec![
                        ("kind", Value::Str(k.kind.clone())),
                        ("m", Value::Num(k.m as f64)),
                        ("n", Value::Num(k.n as f64)),
                        ("k", Value::Num(k.k as f64)),
                        ("weight_bits", Value::Num(k.weight_bits as f64)),
                        ("act_bits", Value::Num(k.act_bits as f64)),
                        ("latency_s", Value::Num(self.entries[k])),
                    ])
                })
                .collect(),
        );
        let v = Value::obj(vec![
            ("out_bytes_per_elem", Value::Num(self.out_bytes_per_elem)),
            ("entries", arr),
        ]);
        Ok(v.to_string())
    }

    /// Parse a serialized kernel table — the object form written by
    /// [`KernelTable::to_json`], or a bare entry array (measured tables
    /// produced by external profilers). Every row must carry the full key
    /// plus a positive `latency_s`.
    pub fn from_json(text: &str) -> crate::Result<Self> {
        use crate::util::json;
        let v = json::parse(text)?;
        let (rows, out_bytes_per_elem) = match v.get("entries") {
            Some(entries) => (
                entries.as_arr()?,
                v.get("out_bytes_per_elem").map_or(Ok(2.0), |b| b.as_f64())?,
            ),
            None => (v.as_arr()?, 2.0),
        };
        let mut entries = HashMap::new();
        for row in rows {
            let key = KernelKey {
                kind: row.req("kind")?.as_str()?.to_string(),
                m: row.req("m")?.as_u64()?,
                n: row.req("n")?.as_u64()?,
                k: row.req("k")?.as_u64()?,
                weight_bits: row.req("weight_bits")?.as_u64()? as u32,
                act_bits: row.req("act_bits")?.as_u64()? as u32,
            };
            let lat = row.req("latency_s")?.as_f64()?;
            anyhow::ensure!(
                lat.is_finite() && lat > 0.0,
                "kernel table: non-positive latency {lat} for {} m={} n={} k={} w{}a{}",
                key.kind,
                key.m,
                key.n,
                key.k,
                key.weight_bits,
                key.act_bits
            );
            anyhow::ensure!(
                entries.insert(key.clone(), lat).is_none(),
                "kernel table: duplicate entry for {} m={} n={} k={} w{}a{}",
                key.kind,
                key.m,
                key.n,
                key.k,
                key.weight_bits,
                key.act_bits
            );
        }
        Ok(Self { entries, out_bytes_per_elem })
    }

    /// Check that this table covers every `layers` kernel shape at every
    /// supported [`BitWidth`] pair, with a clear error naming the first
    /// missing kernel. Run before a measured table replaces the analytical
    /// one, so a sparse file fails at load time instead of panicking
    /// mid-search.
    ///
    /// The full weight × activation grid is required deliberately: the
    /// searches assign `w == a` and the weight-only ablation prices
    /// `(w, fp16)`, but hand-built configurations (CLI evals, benches)
    /// may set any pair, and [`KernelTable::lookup`] panics on a miss —
    /// a partially covered table would turn those into runtime panics.
    pub fn validate_for(&self, layers: &[crate::model::LayerInfo]) -> crate::Result<()> {
        let widths = [BitWidth::Int4, BitWidth::Int8, BitWidth::Fp16];
        for layer in layers {
            for w in widths {
                for a in widths {
                    let key = Self::key_for(layer, w, a);
                    anyhow::ensure!(
                        self.entries.contains_key(&key),
                        "kernel table missing `{}` kernel for layer `{}` \
                         (m={} n={} k={}) at weight_bits={} act_bits={}",
                        key.kind,
                        layer.name,
                        key.m,
                        key.n,
                        key.k,
                        key.weight_bits,
                        key.act_bits
                    );
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gemm_layer() -> LayerInfo {
        LayerInfo {
            name: "g".into(),
            param: "g_w".into(),
            kind: "gemm".into(),
            quantizable: true,
            macs: 1 << 20,
            weight_numel: 16384,
            act_in_numel: 128,
            out_numel: 128,
            m: 1,
            n: 128,
            k: 128,
            quant_index: 0,
        }
    }

    #[test]
    fn profile_covers_all_pairs() {
        let t = KernelTable::profile(&AccelModel::a100_like(), &[gemm_layer()]);
        assert_eq!(t.len(), 9);
        let l = gemm_layer();
        for w in [BitWidth::Int4, BitWidth::Int8, BitWidth::Fp16] {
            for a in [BitWidth::Int4, BitWidth::Int8, BitWidth::Fp16] {
                assert!(t.lookup(&l, w, a) > 0.0);
            }
        }
    }

    #[test]
    fn narrower_weights_never_slower() {
        let t = KernelTable::profile(&AccelModel::a100_like(), &[gemm_layer()]);
        let l = gemm_layer();
        let l4 = t.lookup(&l, BitWidth::Int4, BitWidth::Int8);
        let l8 = t.lookup(&l, BitWidth::Int8, BitWidth::Int8);
        let l16 = t.lookup(&l, BitWidth::Fp16, BitWidth::Fp16);
        assert!(l4 <= l8 && l8 <= l16);
    }

    #[test]
    fn json_roundtrip_preserves_every_entry() {
        let t = KernelTable::profile(&AccelModel::a100_like(), &[gemm_layer()]);
        let s = t.to_json().unwrap();
        assert!(s.contains("gemm"));
        let re = KernelTable::from_json(&s).unwrap();
        assert_eq!(re.len(), t.len());
        assert_eq!(re.out_bytes_per_elem, t.out_bytes_per_elem);
        let l = gemm_layer();
        for w in [BitWidth::Int4, BitWidth::Int8, BitWidth::Fp16] {
            for a in [BitWidth::Int4, BitWidth::Int8, BitWidth::Fp16] {
                assert_eq!(re.lookup(&l, w, a), t.lookup(&l, w, a), "{w:?}/{a:?}");
            }
        }
        re.validate_for(&[gemm_layer()]).unwrap();
    }

    #[test]
    fn from_json_accepts_bare_array_and_rejects_bad_rows() {
        let row = r#"{"kind": "gemm", "m": 1, "n": 128, "k": 128,
                      "weight_bits": 8, "act_bits": 8, "latency_s": 1e-6}"#;
        let t = KernelTable::from_json(&format!("[{row}]")).unwrap();
        assert_eq!(t.len(), 1);
        assert_eq!(t.out_bytes_per_elem, 2.0);
        // Duplicate keys and non-positive latencies are rejected.
        assert!(KernelTable::from_json(&format!("[{row},{row}]")).is_err());
        let bad = row.replace("1e-6", "0.0");
        assert!(KernelTable::from_json(&format!("[{bad}]")).is_err());
    }

    #[test]
    fn validate_for_names_the_missing_kernel() {
        let t = KernelTable::profile(&AccelModel::a100_like(), &[gemm_layer()]);
        let mut other = gemm_layer();
        other.name = "uncovered_layer".into();
        other.n = 999;
        let err = t.validate_for(&[gemm_layer(), other]).unwrap_err().to_string();
        assert!(err.contains("uncovered_layer"), "error should name the layer: {err}");
        assert!(err.contains("weight_bits"), "error should name the precision pair: {err}");
    }
}
