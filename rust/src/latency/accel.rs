//! Analytical roofline model of an A100-class accelerator.

use crate::quant::BitWidth;

/// Execution precision of a kernel: the wider of its two operand widths
/// (int4 weights with int8 activations run in the int8 pipeline, matching
/// tensor-core / MXU operand-width semantics).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    Int4,
    Int8,
    Fp16,
}

impl Precision {
    pub fn of_pair(w: BitWidth, a: BitWidth) -> Self {
        let widest = w.bits().max(a.bits());
        match widest as u32 {
            0..=4 => Precision::Int4,
            5..=8 => Precision::Int8,
            _ => Precision::Fp16,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Precision::Int4 => "int4",
            Precision::Int8 => "int8",
            Precision::Fp16 => "fp16",
        }
    }
}

/// Roofline parameters. Defaults approximate an A100 SXM4-40GB, the
/// hardware the paper profiled with CUTLASS.
#[derive(Debug, Clone)]
pub struct AccelModel {
    /// Stable backend name, recorded in cost-model provenance.
    pub name: &'static str,
    /// Peak MACs/s at fp16 (A100: 312 TFLOPS ≈ 156e12 MAC/s dense).
    pub peak_mac_fp16: f64,
    /// Peak MACs/s at int8 (624 TOPS ≈ 312e12 MAC/s).
    pub peak_mac_int8: f64,
    /// Peak MACs/s at int4 (1248 TOPS ≈ 624e12 MAC/s).
    pub peak_mac_int4: f64,
    /// HBM bandwidth, bytes/s (A100-40GB: 1.555e12).
    pub hbm_bytes_per_s: f64,
    /// Fixed per-kernel launch + epilogue overhead, seconds.
    pub launch_overhead_s: f64,
    /// Math tile the systolic/tensor units consume (m, n, k granularity).
    pub tile: (u64, u64, u64),
}

impl AccelModel {
    /// The default substitution target (see DESIGN.md §2).
    pub fn a100_like() -> Self {
        Self {
            name: "a100-like",
            peak_mac_fp16: 156e12,
            peak_mac_int8: 312e12,
            peak_mac_int4: 624e12,
            hbm_bytes_per_s: 1.555e12,
            launch_overhead_s: 4.0e-6,
            tile: (128, 128, 32),
        }
    }

    /// A TPU-v4-like configuration (documented hardware adaptation; MXU is
    /// 128x128 bf16 with int8 support, no int4 math — int4 maps to int8
    /// compute but still enjoys int4 memory traffic).
    pub fn tpu_like() -> Self {
        Self {
            name: "tpu-like",
            peak_mac_fp16: 137.5e12,
            peak_mac_int8: 275e12,
            peak_mac_int4: 275e12,
            hbm_bytes_per_s: 1.2e12,
            launch_overhead_s: 2.0e-6,
            tile: (128, 128, 128),
        }
    }

    pub fn peak_mac(&self, p: Precision) -> f64 {
        match p {
            Precision::Int4 => self.peak_mac_int4,
            Precision::Int8 => self.peak_mac_int8,
            Precision::Fp16 => self.peak_mac_fp16,
        }
    }

    /// Tile-quantization efficiency: fraction of issued math that is useful
    /// for a GEMM of logical shape (m, n, k).
    pub fn tile_efficiency(&self, m: u64, n: u64, k: u64) -> f64 {
        let (tm, tn, tk) = self.tile;
        let pad = |x: u64, t: u64| -> f64 {
            let tiles = x.div_ceil(t);
            x as f64 / (tiles * t) as f64
        };
        pad(m, tm) * pad(n, tn) * pad(k, tk)
    }

    /// Roofline latency of one kernel.
    ///
    /// * `macs` — useful multiply-accumulates,
    /// * `(m, n, k)` — GEMM-equivalent shape (tile efficiency),
    /// * `bytes` — HBM traffic (weights at their storage width + I/O).
    pub fn kernel_latency_s(
        &self,
        macs: u64,
        mnk: (u64, u64, u64),
        bytes: f64,
        p: Precision,
    ) -> f64 {
        let eff = self.tile_efficiency(mnk.0, mnk.1, mnk.2).max(1e-3);
        let compute = macs as f64 / (self.peak_mac(p) * eff);
        let memory = bytes / self.hbm_bytes_per_s;
        compute.max(memory) + self.launch_overhead_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_of_pair_takes_widest() {
        assert_eq!(Precision::of_pair(BitWidth::Int4, BitWidth::Int8), Precision::Int8);
        assert_eq!(Precision::of_pair(BitWidth::Int4, BitWidth::Int4), Precision::Int4);
        assert_eq!(Precision::of_pair(BitWidth::Fp16, BitWidth::Int4), Precision::Fp16);
    }

    #[test]
    fn tile_efficiency_bounds() {
        let a = AccelModel::a100_like();
        assert_eq!(a.tile_efficiency(128, 128, 32), 1.0);
        let e = a.tile_efficiency(1, 10, 64);
        assert!(e > 0.0 && e < 0.05, "tiny shapes waste the tile: {e}");
    }

    #[test]
    fn memory_bound_kernel_scales_with_bytes() {
        let a = AccelModel::a100_like();
        // Tiny math, large traffic: halving bytes ~halves latency-minus-overhead.
        let l8 = a.kernel_latency_s(1000, (128, 128, 32), 1e6, Precision::Int8);
        let l4 = a.kernel_latency_s(1000, (128, 128, 32), 0.5e6, Precision::Int4);
        let r = (l4 - a.launch_overhead_s) / (l8 - a.launch_overhead_s);
        assert!((r - 0.5).abs() < 1e-6);
    }

    #[test]
    fn compute_bound_kernel_scales_with_precision() {
        let a = AccelModel::a100_like();
        let big = 1u64 << 40;
        let l16 = a.kernel_latency_s(big, (4096, 4096, 4096), 1e3, Precision::Fp16);
        let l8 = a.kernel_latency_s(big, (4096, 4096, 4096), 1e3, Precision::Int8);
        assert!((l16 / l8 - 2.0).abs() < 0.1);
    }
}
