//! The evaluation pipeline: device-resident state + graph drivers.
//!
//! One `Pipeline` = one model loaded on one PJRT engine. Construction
//! uploads parameters and all dataset batches to the device **once**;
//! every configuration evaluation afterwards only uploads the two tiny
//! per-layer bit vectors. Evaluations are memoized by configuration hash,
//! and — when the caller supplies an accuracy target — batches are
//! evaluated with two-sided early exit: the loop stops as soon as the
//! pass/fail decision is mathematically settled.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{ensure, Context};

use crate::model::ModelArtifacts;
use crate::quant::calibrate::{pair_at, pair_count, BatchGrad, NoiseSample, PairSample, TraceSample};
use crate::quant::{self, AdjustReport, CalibrationOptions, QuantConfig, Scales};
use crate::runtime::{
    scalar_f32, vec_f32, BatchArena, Engine, Executable, HostTensor, TensorData, TensorView,
};
use crate::util::rng::{noise_seed, pair_seed, probe_seed, Rng};
use crate::Result;

use super::shard::{self, StageRunner};
use super::{EvalCache, EvalResult, SearchEnv};

/// Counters for reports and the §Perf log.
#[derive(Debug, Default, Clone, Copy)]
pub struct PipelineStats {
    /// `eval` calls answered (cache hits included).
    pub evals: usize,
    /// `eval` calls answered from the in-memory memo cache.
    pub cache_hits: usize,
    /// `eval` calls answered from the persistent cross-run cache.
    pub persistent_hits: usize,
    /// `eval_many` frontiers submitted.
    pub batches: usize,
    /// Graph executions (batches actually run on the device).
    pub batch_execs: usize,
    /// Evaluations that stopped before the last batch.
    pub early_exits: usize,
}

/// Accuracy bounds of a (possibly partial) evaluation.
#[derive(Debug, Clone, Copy)]
struct CachedEval {
    loss: f64,
    /// Accuracy if every unevaluated example were wrong.
    lb: f64,
    /// Accuracy if every unevaluated example were correct.
    ub: f64,
}

impl CachedEval {
    fn exact(&self) -> bool {
        self.lb == self.ub
    }
}

/// Device-resident bit vectors for one serving configuration, uploaded
/// once per `(config id, table version)` and reused across batches (see
/// [`Pipeline::logits_keyed`]).
struct ConfigSlot {
    bw: xla::PjRtBuffer,
    ba: xla::PjRtBuffer,
}

/// Bound on retained [`ConfigSlot`]s per pipeline; two tiny vectors each,
/// so the bound is about hygiene under config churn, not memory pressure.
const MAX_CONFIG_SLOTS: usize = 64;

pub struct Pipeline {
    engine: Engine,
    pub artifacts: ModelArtifacts,
    pub scales: Scales,

    eval_exe: Executable,
    /// Serving executables keyed by compiled batch size (lazily built from
    /// the `logits` / `logits_b{N}` graphs).
    logits_exes: std::collections::HashMap<usize, Executable>,
    actstats_exe: Option<Executable>,
    scale_grad_exe: Option<Executable>,
    hvp_exe: Option<Executable>,

    param_bufs: Vec<xla::PjRtBuffer>,
    scale_bufs: Vec<xla::PjRtBuffer>, // [aw, gw, aa, ga]
    val_batches: Vec<(xla::PjRtBuffer, xla::PjRtBuffer)>,
    calib_sens_batches: Vec<(xla::PjRtBuffer, xla::PjRtBuffer)>, // eval-batch sized
    calib_adj_batches: Vec<(xla::PjRtBuffer, xla::PjRtBuffer)>,  // calib-batch sized

    cache: HashMap<u64, CachedEval>,
    /// Optional cross-run cache (see [`Pipeline::attach_eval_cache`]).
    eval_cache: Option<EvalCache>,
    /// Serving bits buffers keyed by `(config id, table version)` — the
    /// multi-config data plane uploads each configuration's bit vectors
    /// once and reuses them for every batch routed to that config.
    config_slots: HashMap<(u32, u64), ConfigSlot>,
    /// Reusable zero-copy batch-assembly buffer for the serving path.
    batch_arena: BatchArena,
    pub stats: PipelineStats,
}

impl Pipeline {
    /// Load a model's artifacts, compile its eval graph, and move all
    /// static state onto the device.
    pub fn new(artifacts_dir: &Path, model: &str) -> Result<Self> {
        let engine = Engine::cpu()?;
        let artifacts = ModelArtifacts::load(artifacts_dir, model)
            .with_context(|| format!("loading artifacts for {model}"))?;
        let eval_exe = engine.compile_hlo_file(&artifacts.graph_path("eval")?)?;

        let m = &artifacts.manifest;
        let mut param_bufs = Vec::with_capacity(m.params.len());
        for (i, p) in m.params.iter().enumerate() {
            let dims: Vec<usize> = p.shape.clone();
            param_bufs.push(engine.upload_f32(artifacts.params.values(i), &dims)?);
        }

        let eb = m.eval_batch;
        let upload_split = |split: &crate::model::Split, batch: usize| -> Result<Vec<_>> {
            (0..split.num_batches(batch))
                .map(|i| {
                    let (x, y) = split.batch(i, batch);
                    Ok((engine.upload(&x)?, engine.upload(&y)?))
                })
                .collect()
        };
        let val_batches = upload_split(&artifacts.val, eb)?;
        ensure!(!val_batches.is_empty(), "validation split smaller than a batch");
        let calib_sens_batches = upload_split(&artifacts.calib_sens, eb)?;
        let calib_adj_batches = upload_split(&artifacts.calib_adj, m.calib_batch)?;

        let scales = Scales::identity(m.num_quant_layers);
        let mut pipe = Self {
            engine,
            artifacts,
            scales,
            eval_exe,
            logits_exes: std::collections::HashMap::new(),
            actstats_exe: None,
            scale_grad_exe: None,
            hvp_exe: None,
            param_bufs,
            scale_bufs: Vec::new(),
            val_batches,
            calib_sens_batches,
            calib_adj_batches,
            cache: HashMap::new(),
            eval_cache: None,
            config_slots: HashMap::new(),
            batch_arena: BatchArena::new(),
            stats: PipelineStats::default(),
        };
        pipe.sync_scales()?;
        Ok(pipe)
    }

    pub fn num_quant_layers(&self) -> usize {
        self.artifacts.manifest.num_quant_layers
    }

    /// Float-baseline validation accuracy recorded at export time.
    pub fn float_val_acc(&self) -> f64 {
        self.artifacts.manifest.float_val_acc
    }

    /// Re-upload the scale vectors after a change (calibration/adjustment)
    /// and invalidate the evaluation caches — results depend on scales. A
    /// persistent cache attached for the previous scales is flushed and
    /// detached (its context fingerprint no longer matches); re-attach once
    /// the new scales are final.
    pub fn sync_scales(&mut self) -> Result<()> {
        let s = &self.scales;
        let n = s.num_layers();
        self.scale_bufs = vec![
            self.engine.upload_f32(&s.alpha_w, &[n])?,
            self.engine.upload_f32(&s.gamma_w, &[n])?,
            self.engine.upload_f32(&s.alpha_a, &[n])?,
            self.engine.upload_f32(&s.gamma_a, &[n])?,
        ];
        self.cache.clear();
        if let Some(mut cache) = self.eval_cache.take() {
            let _ = cache.save();
        }
        Ok(())
    }

    /// Fingerprint of everything an exact evaluation result depends on
    /// besides the configuration: model identity, the four scale vectors
    /// (bit-exact), and the validation data + trained parameters. The
    /// latter two are covered by the export-time float baselines (computed
    /// from both) plus the validation labels, so regenerated artifacts
    /// invalidate the cache even when the model name is unchanged.
    pub fn eval_context(&self) -> String {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        let s = &self.scales;
        for v in [&s.alpha_w, &s.gamma_w, &s.alpha_a, &s.gamma_a] {
            for &x in v {
                x.to_bits().hash(&mut h);
            }
        }
        let m = &self.artifacts.manifest;
        m.float_val_acc.to_bits().hash(&mut h);
        m.float_val_loss.to_bits().hash(&mut h);
        m.eval_batch.hash(&mut h);
        self.artifacts.val.count.hash(&mut h);
        match self.artifacts.val.y.data() {
            TensorData::F32(data) => {
                for v in data {
                    v.to_bits().hash(&mut h);
                }
            }
            TensorData::I32(data) => data.hash(&mut h),
        }
        format!("{}/v{}/state-{:016x}", m.model, m.version, h.finish())
    }

    /// Attach a persistent cross-run [`EvalCache`] at `path`, bound to the
    /// current [`Pipeline::eval_context`]. Call after calibration (scale
    /// changes flush and detach it). Exact results are looked up before
    /// touching the device and recorded after full evaluations; the cache
    /// is written back on [`Pipeline::flush_eval_cache`] and on drop.
    pub fn attach_eval_cache(&mut self, path: &Path) {
        self.attach_eval_cache_bounded(path, None);
    }

    /// [`Pipeline::attach_eval_cache`] with an entry bound: at most
    /// `capacity` results are kept, evicting least-recently-used ones.
    pub fn attach_eval_cache_bounded(&mut self, path: &Path, capacity: Option<usize>) {
        self.eval_cache = Some(EvalCache::with_capacity(path, &self.eval_context(), capacity));
    }

    /// Persist the attached eval cache, if any.
    pub fn flush_eval_cache(&mut self) -> Result<()> {
        match self.eval_cache.as_mut() {
            Some(cache) => cache.save(),
            None => Ok(()),
        }
    }

    /// Flush and detach the persistent cache. Use while another component
    /// (e.g. a [`super::PipelinePool`]) temporarily owns the cache file —
    /// a detached pipeline can no longer clobber it with a stale copy on
    /// flush or drop. Re-attach afterwards to pick the new contents up.
    pub fn detach_eval_cache(&mut self) -> Result<()> {
        match self.eval_cache.take() {
            Some(mut cache) => cache.save(),
            None => Ok(()),
        }
    }

    /// The attached eval cache, for stats/reporting.
    pub fn eval_cache(&self) -> Option<&EvalCache> {
        self.eval_cache.as_ref()
    }

    fn bits_bufs(&self, cfg: &QuantConfig) -> Result<(xla::PjRtBuffer, xla::PjRtBuffer)> {
        let n = cfg.num_layers();
        Ok((
            self.engine.upload_f32(&cfg.bits_w, &[n])?,
            self.engine.upload_f32(&cfg.bits_a, &[n])?,
        ))
    }

    /// Run the eval graph on one uploaded batch with given params; returns
    /// (mean loss, correct count).
    fn run_eval_batch(
        &mut self,
        params: &[xla::PjRtBuffer],
        bw: &xla::PjRtBuffer,
        ba: &xla::PjRtBuffer,
        batch: &(xla::PjRtBuffer, xla::PjRtBuffer),
    ) -> Result<(f64, f64)> {
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(params.len() + 8);
        args.extend(params.iter());
        args.extend(self.scale_bufs.iter());
        args.push(bw);
        args.push(ba);
        args.push(&batch.0);
        args.push(&batch.1);
        let out = self.eval_exe.run(&args)?;
        self.stats.batch_execs += 1;
        Ok((scalar_f32(&out[0])? as f64, scalar_f32(&out[1])? as f64))
    }

    /// Evaluate a configuration over a batch list with optional two-sided
    /// early exit against `target`. The batch vector is temporarily moved
    /// out of `self` so the executor can borrow `self` mutably.
    fn eval_on(
        &mut self,
        params: &[xla::PjRtBuffer],
        cfg: &QuantConfig,
        which: Which,
        target: Option<f64>,
    ) -> Result<CachedEval> {
        let batches = match which {
            Which::Val => std::mem::take(&mut self.val_batches),
            Which::CalibSens => std::mem::take(&mut self.calib_sens_batches),
        };
        let res = self.eval_on_batches(params, cfg, &batches, target);
        match which {
            Which::Val => self.val_batches = batches,
            Which::CalibSens => self.calib_sens_batches = batches,
        }
        res
    }

    fn eval_on_batches(
        &mut self,
        params: &[xla::PjRtBuffer],
        cfg: &QuantConfig,
        batches: &[(xla::PjRtBuffer, xla::PjRtBuffer)],
        target: Option<f64>,
    ) -> Result<CachedEval> {
        let (bw, ba) = self.bits_bufs(cfg)?;
        let batch_size = self.artifacts.manifest.eval_batch as f64;
        let total = batches.len() as f64 * batch_size;
        let mut correct = 0.0f64;
        let mut loss_sum = 0.0f64;
        let mut done = 0usize;
        for batch in batches {
            let (l, c) = self.run_eval_batch(params, &bw, &ba, batch)?;
            loss_sum += l;
            correct += c;
            done += 1;
            if let Some(t) = target {
                let remaining = total - done as f64 * batch_size;
                let lb = correct / total;
                let ub = (correct + remaining) / total;
                if (lb >= t || ub < t) && done < batches.len() {
                    self.stats.early_exits += 1;
                    return Ok(CachedEval { loss: loss_sum / done as f64, lb, ub });
                }
            }
        }
        let acc = correct / total;
        Ok(CachedEval { loss: loss_sum / done as f64, lb: acc, ub: acc })
    }

    /// Evaluate on the validation split (memoized, in-memory then
    /// persistent cross-run cache).
    pub fn eval_config(&mut self, cfg: &QuantConfig, target: Option<f64>) -> Result<EvalResult> {
        self.stats.evals += 1;
        let key = cfg.key();
        if let Some(hit) = self.cache.get(&key).copied() {
            let decisive = match target {
                None => hit.exact(),
                Some(t) => hit.exact() || hit.lb >= t || hit.ub < t,
            };
            if decisive {
                self.stats.cache_hits += 1;
                return Ok(to_result(hit, target));
            }
        }
        if let Some(hit) = self.eval_cache.as_mut().and_then(|c| c.lookup(key)) {
            // Exact persisted results answer any target decisively; seed
            // the memo cache so later lookups stay in memory.
            self.stats.persistent_hits += 1;
            let ce = CachedEval { loss: hit.loss, lb: hit.accuracy, ub: hit.accuracy };
            self.cache.insert(key, ce);
            return Ok(hit);
        }
        let params = std::mem::take(&mut self.param_bufs);
        let res = self.eval_on(&params, cfg, Which::Val, target);
        self.param_bufs = params;
        let ce = res?;
        // Keep the more precise of (old, new) bounds.
        let entry = self.cache.entry(key).or_insert(ce);
        if ce.ub - ce.lb < entry.ub - entry.lb {
            *entry = ce;
        }
        let result = to_result(ce, target);
        if let Some(cache) = self.eval_cache.as_mut() {
            cache.insert(key, &result);
        }
        Ok(result)
    }

    /// Mean float loss on the sensitivity split with the stock parameters.
    pub fn calib_loss_float(&mut self) -> Result<f64> {
        let cfg = QuantConfig::float(self.num_quant_layers());
        let params = std::mem::take(&mut self.param_bufs);
        let res = self.eval_on(&params, &cfg, Which::CalibSens, None);
        self.param_bufs = params;
        Ok(res?.loss)
    }

    /// Mean float calibration loss with one parameter tensor temporarily
    /// replaced by `perturbed` — the ε_N inner loop. Only the perturbed
    /// tensor is uploaded; all other parameters stay device-resident.
    pub fn calib_loss_with_perturbed(
        &mut self,
        param_index: usize,
        perturbed: &[f32],
    ) -> Result<f64> {
        let dims = self.artifacts.params.dims(param_index).to_vec();
        let new_buf = self.engine.upload_f32(perturbed, &dims)?;
        let old = std::mem::replace(&mut self.param_bufs[param_index], new_buf);
        let cfg = QuantConfig::float(self.num_quant_layers());
        let params = std::mem::take(&mut self.param_bufs);
        let res = self.eval_on(&params, &cfg, Which::CalibSens, None);
        self.param_bufs = params;
        self.param_bufs[param_index] = old;
        Ok(res?.loss)
    }

    /// Mean float calibration loss with *two* parameter tensors temporarily
    /// replaced — the paired-perturbation inner loop of the inter-layer
    /// metric. Only the two perturbed tensors are uploaded; all other
    /// parameters stay device-resident, and both originals are restored
    /// before returning.
    pub fn calib_loss_with_perturbed_pair(
        &mut self,
        param_a: usize,
        perturbed_a: &[f32],
        param_b: usize,
        perturbed_b: &[f32],
    ) -> Result<f64> {
        ensure!(param_a != param_b, "paired perturbation targets the same parameter tensor");
        let dims_a = self.artifacts.params.dims(param_a).to_vec();
        let dims_b = self.artifacts.params.dims(param_b).to_vec();
        let new_a = self.engine.upload_f32(perturbed_a, &dims_a)?;
        let new_b = self.engine.upload_f32(perturbed_b, &dims_b)?;
        let old_a = std::mem::replace(&mut self.param_bufs[param_a], new_a);
        let old_b = std::mem::replace(&mut self.param_bufs[param_b], new_b);
        let cfg = QuantConfig::float(self.num_quant_layers());
        let params = std::mem::take(&mut self.param_bufs);
        let res = self.eval_on(&params, &cfg, Which::CalibSens, None);
        self.param_bufs = params;
        self.param_bufs[param_a] = old_a;
        self.param_bufs[param_b] = old_b;
        Ok(res?.loss)
    }

    // ---------------------------------------------------------- calibration
    //
    // The calibration/sensitivity path is split into pure per-shard
    // kernels (`*_shard`, below) driven by [`super::shard`]: this pipeline
    // is the one-worker [`StageRunner`], [`super::PipelinePool`] fans the
    // same shards across its workers. Host-side reduction is fixed-order
    // ([`crate::quant::calibrate`]), so both produce bit-identical scales
    // and traces.

    /// Batches in the adjustment split — the shard domain for calibration.
    pub fn num_adjust_batches(&self) -> usize {
        self.calib_adj_batches.len()
    }

    /// Per-layer max|activation| over the listed adjustment batches
    /// (float model) — the pure act-stats shard kernel.
    // Indexing (not iterating) the batch list keeps `self` free for the
    // mutable stats updates inside the loop.
    pub fn act_stats_shard(&mut self, batches: &[usize]) -> Result<Vec<f32>> {
        for &bi in batches {
            ensure!(bi < self.calib_adj_batches.len(), "adjustment batch {bi} out of range");
        }
        if self.actstats_exe.is_none() {
            self.actstats_exe =
                Some(self.engine.compile_hlo_file(&self.artifacts.graph_path("actstats")?)?);
        }
        let exe = self.actstats_exe.take().unwrap();
        let n = self.num_quant_layers();
        let mut maxabs = vec![0.0f32; n];
        for &bi in batches {
            let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.param_bufs.len() + 1);
            args.extend(self.param_bufs.iter());
            args.push(&self.calib_adj_batches[bi].0);
            let out = exe.run(&args)?;
            self.stats.batch_execs += 1;
            let stats = vec_f32(&out[0])?;
            for (m, s) in maxabs.iter_mut().zip(stats) {
                *m = m.max(s);
            }
        }
        self.actstats_exe = Some(exe);
        Ok(maxabs)
    }

    /// Per-layer max|activation| over the whole adjustment split.
    pub fn act_stats(&mut self) -> Result<Vec<f32>> {
        shard::act_stats_sharded(self)
    }

    /// Per-batch scale gradients at fixed `scales` (quantization active at
    /// `bits`) for the listed adjustment batches — the pure shard kernel
    /// of calibration step 2. Does not touch `self.scales`: the driver
    /// owns the optimizer state and pushes updates via the
    /// [`StageRunner::broadcast_scales`] channel.
    pub fn adjust_grads_shard(
        &mut self,
        scales: &Scales,
        bits: f32,
        batches: &[usize],
    ) -> Result<Vec<BatchGrad>> {
        for &bi in batches {
            ensure!(bi < self.calib_adj_batches.len(), "adjustment batch {bi} out of range");
        }
        if self.scale_grad_exe.is_none() {
            self.scale_grad_exe =
                Some(self.engine.compile_hlo_file(&self.artifacts.graph_path("scale_grad")?)?);
        }
        let n = self.num_quant_layers();
        let cfg = QuantConfig::uniform(n, bits);
        let (bw, ba) = self.bits_bufs(&cfg)?;
        // One upload of the (fixed) scales covers the whole shard.
        let sb = [
            self.engine.upload_f32(&scales.alpha_w, &[n])?,
            self.engine.upload_f32(&scales.gamma_w, &[n])?,
            self.engine.upload_f32(&scales.alpha_a, &[n])?,
            self.engine.upload_f32(&scales.gamma_a, &[n])?,
        ];
        let exe = self.scale_grad_exe.take().unwrap();
        let mut out_grads = Vec::with_capacity(batches.len());
        for &bi in batches {
            let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.param_bufs.len() + 8);
            args.extend(self.param_bufs.iter());
            args.extend(sb.iter());
            args.push(&bw);
            args.push(&ba);
            args.push(&self.calib_adj_batches[bi].0);
            args.push(&self.calib_adj_batches[bi].1);
            let out = exe.run(&args)?;
            self.stats.batch_execs += 1;
            let loss = scalar_f32(&out[0])? as f64;
            let mut grads = Vec::with_capacity(n * 4);
            for g in &out[1..5] {
                grads.extend(vec_f32(g)?);
            }
            out_grads.push(BatchGrad { batch: bi, loss, grads });
        }
        self.scale_grad_exe = Some(exe);
        Ok(out_grads)
    }

    /// The paper's two-step scale estimation: max calibration for weights
    /// (host-side) and activations (`actstats` graph), then synchronous
    /// data-parallel backprop adjustment of the four scale vectors —
    /// driven through [`super::shard::calibrate_sharded`] at one worker,
    /// so the result is bit-identical to a [`super::PipelinePool`] run at
    /// any worker count.
    pub fn calibrate(&mut self, opts: &CalibrationOptions) -> Result<AdjustReport> {
        let (_scales, report) = shard::calibrate_sharded(self, opts, None)?;
        Ok(report)
    }

    // -------------------------------------------------------------- hessian

    /// Per-trial Hutchinson probes for the listed trial indices — the pure
    /// HVP shard kernel. Each trial's Rademacher probe is drawn from an
    /// RNG seeded by [`probe_seed`]`(seed, trial)` and runs on adjustment
    /// batch `trial % num_batches` (rotating through the split keeps the
    /// estimator unbiased at 1/nb the HVP cost of the full cross product —
    /// HVPs are the most expensive graph in the system, §Perf), so a
    /// sample depends only on `(seed, trial)`, never on shard layout.
    pub fn hvp_shard(&mut self, seed: u64, trials: &[usize]) -> Result<Vec<TraceSample>> {
        let nb = self.calib_adj_batches.len();
        ensure!(nb > 0, "no adjustment batches for Hessian probes");
        if self.hvp_exe.is_none() {
            self.hvp_exe = Some(self.engine.compile_hlo_file(&self.artifacts.graph_path("hvp")?)?);
        }
        let exe = self.hvp_exe.take().unwrap();
        let m = self.artifacts.manifest.clone();
        let qlayers = m.quant_layers();
        let n = qlayers.len();
        let mut samples = Vec::with_capacity(trials.len());
        for &trial in trials {
            // One full Rademacher probe across all quantizable tensors.
            let mut rng = Rng::seed_from(probe_seed(seed, trial as u64));
            let mut probe_bufs = Vec::with_capacity(n);
            for l in qlayers.iter() {
                let pi = self.artifacts.params.index_of(&l.param).ok_or_else(|| {
                    anyhow::anyhow!(
                        "hvp probe: param `{}` (quant layer `{}`) missing",
                        l.param,
                        l.name
                    )
                })?;
                let dims = self.artifacts.params.dims(pi).to_vec();
                let numel: usize = dims.iter().product();
                let v: Vec<f32> = (0..numel).map(|_| rng.rademacher()).collect();
                probe_bufs.push(self.engine.upload_f32(&v, &dims)?);
            }
            let bi = trial % nb;
            let mut args: Vec<&xla::PjRtBuffer> =
                Vec::with_capacity(self.param_bufs.len() + 2 + n);
            args.extend(self.param_bufs.iter());
            args.push(&self.calib_adj_batches[bi].0);
            args.push(&self.calib_adj_batches[bi].1);
            args.extend(probe_bufs.iter());
            let out = exe.run(&args)?;
            self.stats.batch_execs += 1;
            let vhv = vec_f32(&out[0])?;
            samples.push(TraceSample { trial, vhv: vhv.into_iter().map(f64::from).collect() });
        }
        self.hvp_exe = Some(exe);
        Ok(samples)
    }

    /// Hutchinson estimate of the per-layer mean Hessian trace of the float
    /// loss: `E[v^T H v] / numel` with per-trial-seeded Rademacher probes,
    /// averaged over `trials` probes — the one-worker instance of
    /// [`super::shard::hessian_trace_sharded`].
    pub fn hessian_trace(&mut self, trials: usize, seed: u64) -> Result<Vec<f64>> {
        shard::hessian_trace_sharded(self, trials, seed)
    }

    // ---------------------------------------------------------------- noise

    /// ε_N perturbation trials for the listed flattened `layer * trials +
    /// trial` items — the pure noise shard kernel. Each item draws its own
    /// ν ~ N(0, λ·max|w|) from an RNG seeded by
    /// [`noise_seed`]`(seed, layer, trial)`, uploads only the perturbed
    /// tensor, and measures the float calibration loss, so a sample
    /// depends only on `(seed, layer, trial)`, never on shard layout.
    pub fn noise_shard(
        &mut self,
        lambda: f64,
        trials: usize,
        seed: u64,
        items: &[usize],
    ) -> Result<Vec<NoiseSample>> {
        let trials = trials.max(1);
        let n = self.num_quant_layers();
        let mut samples = Vec::with_capacity(items.len());
        for &item in items {
            let (qi, trial) = (item / trials, item % trials);
            ensure!(qi < n, "noise item {item} outside the {n} x {trials} trial grid");
            let mut rng = Rng::seed_from(noise_seed(seed, qi as u64, trial as u64));
            let (pi, perturbed) = self.gaussian_perturbation(qi, lambda, &mut rng)?;
            let loss = self.calib_loss_with_perturbed(pi, &perturbed)?;
            samples.push(NoiseSample { item, loss });
        }
        Ok(samples)
    }

    /// Paired-perturbation trials for the listed flattened pair-major
    /// `pair * trials + trial` items — the pure inter-layer shard kernel.
    /// Layer `l`'s draw is seeded by [`pair_seed`]`(seed, l, l, trial)` in
    /// *every* cell, so a diagonal cell `(l, l)` measures the single-layer
    /// baseline and an off-diagonal cell `(i, j)` re-applies the exact
    /// same two draws jointly: the host-side finite difference
    /// `L_ij - L_i - L_j + clean` is then a per-trial interaction term,
    /// and a sample depends only on `(seed, i, j, trial)`, never on shard
    /// layout.
    pub fn pair_shard(
        &mut self,
        lambda: f64,
        trials: usize,
        seed: u64,
        items: &[usize],
    ) -> Result<Vec<PairSample>> {
        let trials = trials.max(1);
        let n = self.num_quant_layers();
        let pairs = pair_count(n);
        let mut samples = Vec::with_capacity(items.len());
        for &item in items {
            let (p, trial) = (item / trials, item % trials);
            ensure!(p < pairs, "pair item {item} outside the {pairs} x {trials} trial grid");
            let (i, j) = pair_at(n, p);
            let mut rng_i = Rng::seed_from(pair_seed(seed, i as u64, i as u64, trial as u64));
            let (pi, pert_i) = self.gaussian_perturbation(i, lambda, &mut rng_i)?;
            let loss = if i == j {
                self.calib_loss_with_perturbed(pi, &pert_i)?
            } else {
                let mut rng_j = Rng::seed_from(pair_seed(seed, j as u64, j as u64, trial as u64));
                let (pj, pert_j) = self.gaussian_perturbation(j, lambda, &mut rng_j)?;
                if pi == pj {
                    // Both quant layers read the same parameter tensor:
                    // compose the two deltas into one buffer.
                    let w = self.artifacts.params.values(pi);
                    let combined: Vec<f32> = pert_i
                        .iter()
                        .zip(&pert_j)
                        .zip(w)
                        .map(|((&a, &b), &base)| a + b - base)
                        .collect();
                    self.calib_loss_with_perturbed(pi, &combined)?
                } else {
                    self.calib_loss_with_perturbed_pair(pi, &pert_i, pj, &pert_j)?
                }
            };
            samples.push(PairSample { item, loss });
        }
        Ok(samples)
    }

    // --------------------------------------------------------------- logits

    /// Serving batch sizes available in the artifacts, ascending. Always
    /// includes the evaluation batch; smaller `logits_b{N}` variants are
    /// exported so the server can avoid padding tiny queues to the full
    /// batch (§Perf).
    pub fn logits_batch_sizes(&self) -> Vec<usize> {
        let mut sizes: Vec<usize> = self
            .artifacts
            .manifest
            .graphs
            .keys()
            .filter_map(|g| g.strip_prefix("logits_b").and_then(|n| n.parse().ok()))
            .collect();
        sizes.push(self.artifacts.manifest.eval_batch);
        sizes.sort_unstable();
        sizes.dedup();
        sizes
    }

    fn logits_exe_for(&mut self, batch: usize) -> Result<()> {
        if self.logits_exes.contains_key(&batch) {
            return Ok(());
        }
        let graph = if batch == self.artifacts.manifest.eval_batch {
            "logits".to_string()
        } else {
            format!("logits_b{batch}")
        };
        let exe = self.engine.compile_hlo_file(&self.artifacts.graph_path(&graph)?)?;
        self.logits_exes.insert(batch, exe);
        Ok(())
    }

    /// Compile (once per batch size) and return predictions for one batch —
    /// the serving path used by [`crate::server`]. The leading dimension of
    /// `x` must be one of [`Self::logits_batch_sizes`].
    pub fn logits(&mut self, cfg: &QuantConfig, x: &HostTensor) -> Result<Vec<f32>> {
        self.logits_view(cfg, &x.view())
    }

    /// [`Pipeline::logits`] over a borrowed [`TensorView`] — the zero-copy
    /// serving path: the device upload reads straight from the view (a
    /// batch arena or a window into shared tensor storage).
    pub fn logits_view(&mut self, cfg: &QuantConfig, x: &TensorView<'_>) -> Result<Vec<f32>> {
        let (bw, ba) = self.bits_bufs(cfg)?;
        self.logits_with_bits(&bw, &ba, x)
    }

    fn logits_with_bits(
        &mut self,
        bw: &xla::PjRtBuffer,
        ba: &xla::PjRtBuffer,
        x: &TensorView<'_>,
    ) -> Result<Vec<f32>> {
        let batch = x.dims()[0];
        self.logits_exe_for(batch)?;
        let xb = self.engine.upload_view(x)?;
        let exe = self.logits_exes.remove(&batch).expect("compiled above");
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.param_bufs.len() + 7);
        args.extend(self.param_bufs.iter());
        args.extend(self.scale_bufs.iter());
        args.push(bw);
        args.push(ba);
        args.push(&xb);
        let out = exe.run(&args);
        self.stats.batch_execs += 1;
        self.logits_exes.insert(batch, exe);
        Ok(vec_f32(&out?[0])?)
    }

    /// [`Pipeline::logits_view`] through the versioned per-config bits
    /// table: `cfg`'s bit vectors are uploaded once per `key` (a
    /// `(config id, table version)` pair from the serving config table)
    /// and reused for every later batch routed to that config. A config
    /// swap bumps the version, so a stale slot can never answer for the
    /// new configuration; slots are pruned past [`MAX_CONFIG_SLOTS`].
    pub fn logits_keyed(
        &mut self,
        key: (u32, u64),
        cfg: &QuantConfig,
        x: &TensorView<'_>,
    ) -> Result<Vec<f32>> {
        if !self.config_slots.contains_key(&key) {
            if self.config_slots.len() >= MAX_CONFIG_SLOTS {
                self.config_slots.clear();
            }
            let (bw, ba) = self.bits_bufs(cfg)?;
            self.config_slots.insert(key, ConfigSlot { bw, ba });
        }
        let slot = self.config_slots.remove(&key).expect("inserted above");
        let out = self.logits_with_bits(&slot.bw, &slot.ba, x);
        self.config_slots.insert(key, slot);
        out
    }

    /// Zero-copy batch serving: stack `xs` (one `[1, x_shape...]` tensor
    /// per request) into the pipeline's retained [`BatchArena`], zero-pad
    /// to the `bucket` rows of a compiled graph, and run the keyed logits
    /// path. Each request payload is written exactly once — no per-request
    /// `to_vec`, no per-batch concatenation, no steady-state allocation.
    pub fn logits_rows(
        &mut self,
        key: (u32, u64),
        cfg: &QuantConfig,
        xs: &[HostTensor],
        bucket: usize,
    ) -> Result<Vec<f32>> {
        let x_shape = self.artifacts.manifest.x_shape.clone();
        // Take the arena so its borrowed view and `&mut self` coexist.
        let mut arena = std::mem::take(&mut self.batch_arena);
        let out = {
            let view = arena.assemble(xs, &x_shape, bucket);
            self.logits_keyed(key, cfg, &view)
        };
        self.batch_arena = arena;
        out
    }

    /// Compile and execute every serving bucket once with zero inputs so
    /// the first real request never pays graph-compilation latency — the
    /// server warms each pool worker with this before taking traffic.
    pub fn warm_logits(&mut self, cfg: &QuantConfig) -> Result<()> {
        let x_shape = self.artifacts.manifest.x_shape.clone();
        let is_i32 = self.artifacts.manifest.x_dtype == "i32";
        for batch in self.logits_batch_sizes() {
            let mut dims = vec![batch];
            dims.extend(&x_shape);
            let numel: usize = dims.iter().product();
            let x = if is_i32 {
                HostTensor::i32(vec![0; numel], dims)
            } else {
                HostTensor::f32(vec![0.0; numel], dims)
            };
            self.logits(cfg, &x)?;
        }
        Ok(())
    }

    /// The engine (for uploads by metric drivers).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Gaussian perturbation ν ~ N(0, λ·max|w|) of one quant layer's weights.
    pub fn gaussian_perturbation(
        &self,
        quant_index: usize,
        lambda: f64,
        rng: &mut Rng,
    ) -> Result<(usize, Vec<f32>)> {
        let m = &self.artifacts.manifest;
        let layer = m.quant_layers()[quant_index].clone();
        let pi = self
            .artifacts
            .params
            .index_of(&layer.param)
            .ok_or_else(|| anyhow::anyhow!("missing param {}", layer.param))?;
        let w = self.artifacts.params.values(pi);
        let maxabs = w.iter().fold(0.0f32, |mx, &v| mx.max(v.abs()));
        let sigma = (lambda * maxabs as f64).max(1e-12);
        let perturbed: Vec<f32> =
            w.iter().map(|&v| v + (rng.gaussian() * sigma) as f32).collect();
        Ok((pi, perturbed))
    }
}

/// The one-worker stage backend: every shard runs back-to-back on this
/// pipeline's device. [`super::PipelinePool`] implements the same trait
/// with genuinely concurrent shards; the shared fixed-order reducers make
/// both bit-identical.
impl StageRunner for Pipeline {
    fn shard_workers(&self) -> usize {
        1
    }

    fn shard_layers(&self) -> usize {
        self.num_quant_layers()
    }

    fn adjust_batches(&self) -> usize {
        self.calib_adj_batches.len()
    }

    fn weight_numels(&self) -> Vec<u64> {
        self.artifacts.manifest.quant_layers().iter().map(|l| l.weight_numel).collect()
    }

    fn stage_weight_scales(&mut self) -> Result<Scales> {
        quant::calibrate::weight_scales(&self.artifacts.manifest, &self.artifacts.params)
    }

    fn stage_act_stats(&mut self, shards: &[Vec<usize>]) -> Result<Vec<Vec<f32>>> {
        shards.iter().map(|s| self.act_stats_shard(s)).collect()
    }

    fn stage_adjust_grads(
        &mut self,
        scales: &Scales,
        bits: f32,
        shards: &[Vec<usize>],
    ) -> Result<Vec<Vec<BatchGrad>>> {
        shards.iter().map(|s| self.adjust_grads_shard(scales, bits, s)).collect()
    }

    fn stage_hvp(&mut self, seed: u64, shards: &[Vec<usize>]) -> Result<Vec<Vec<TraceSample>>> {
        shards.iter().map(|s| self.hvp_shard(seed, s)).collect()
    }

    fn stage_clean_loss(&mut self) -> Result<f64> {
        self.calib_loss_float()
    }

    fn stage_noise(
        &mut self,
        lambda: f64,
        trials: usize,
        seed: u64,
        shards: &[Vec<usize>],
    ) -> Result<Vec<Vec<NoiseSample>>> {
        shards.iter().map(|s| self.noise_shard(lambda, trials, seed, s)).collect()
    }

    fn stage_pair(
        &mut self,
        lambda: f64,
        trials: usize,
        seed: u64,
        shards: &[Vec<usize>],
    ) -> Result<Vec<Vec<PairSample>>> {
        shards.iter().map(|s| self.pair_shard(lambda, trials, seed, s)).collect()
    }

    fn broadcast_scales(&mut self, scales: &Scales) -> Result<()> {
        self.scales = scales.clone();
        self.sync_scales()
    }
}

#[derive(Clone, Copy)]
enum Which {
    Val,
    CalibSens,
}

fn to_result(ce: CachedEval, target: Option<f64>) -> EvalResult {
    let exact = ce.exact();
    let accuracy = match target {
        _ if exact => ce.lb,
        Some(t) if ce.lb >= t => ce.lb, // decisive pass: report the bound
        _ => ce.ub,                     // decisive fail (or no target): upper bound
    };
    EvalResult { loss: ce.loss, accuracy, exact }
}

impl SearchEnv for Pipeline {
    fn num_layers(&self) -> usize {
        self.num_quant_layers()
    }

    fn eval(&mut self, cfg: &QuantConfig, target: Option<f64>) -> Result<EvalResult> {
        self.eval_config(cfg, target)
    }

    /// One device, so a frontier is expanded sequentially — duplicates and
    /// previously seen configurations are absorbed by the memo + persistent
    /// caches, which is where batch submission pays off on a single
    /// pipeline. True multi-worker fan-out is [`super::PipelinePool`].
    fn eval_many(&mut self, cfgs: &[QuantConfig], target: Option<f64>) -> Vec<Result<EvalResult>> {
        self.stats.batches += 1;
        cfgs.iter().map(|c| self.eval_config(c, target)).collect()
    }
}

impl Drop for Pipeline {
    fn drop(&mut self) {
        // Best-effort write-back of the cross-run cache.
        if let Some(cache) = self.eval_cache.as_mut() {
            let _ = cache.save();
        }
    }
}
