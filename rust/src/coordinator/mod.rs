//! The L3 coordination layer: configuration evaluation pipeline and the
//! paper's two guided search algorithms.
//!
//! [`Pipeline`] owns the PJRT engine, the compiled AOT graphs, the
//! device-resident parameters/scales/datasets, and an evaluation memo-cache;
//! [`greedy`] (Alg. 2) and [`bisection`] (Alg. 1) drive it through the
//! [`SearchEnv`] trait, which also lets property tests run the searches
//! against synthetic models with known optima.
//!
//! Batched evaluation: per-layer candidate scoring is embarrassingly
//! parallel (Pandey et al., "A Practical Mixed Precision Algorithm for
//! Post-Training Quantization"), so [`SearchEnv::eval_many`] lets a search
//! submit a whole candidate frontier at once. [`ParallelEnv`] fans such
//! batches out over a worker pool for thread-safe environments,
//! [`PipelinePool`] does the same with one device pipeline per worker, and
//! [`EvalCache`] persists exact results across runs. Both searches size
//! their speculative frontiers to [`SearchEnv::preferred_batch`] and replay
//! the sequential decision sequence against the batched results, so the
//! final configuration is bit-identical at every worker count.
//!
//! Sharded calibration & sensitivity: the two-step scale estimation and
//! the Hutchinson Hessian trace run as stage jobs over the same worker
//! pool through [`shard`] — per-shard kernels on [`Pipeline`], fixed-order
//! host reduction in [`crate::quant::calibrate`] — with the same
//! guarantee: bit-identical results at every worker count.

pub mod bisection;
mod cache;
pub mod greedy;
mod memo;
mod parallel;
mod pipeline;
mod pool;
pub mod shard;

pub use cache::EvalCache;
pub use memo::{PendingWrites, StripedMemo, STRIPES};
pub use parallel::{ParallelEnv, SyncSearchEnv};
pub use pipeline::{Pipeline, PipelineStats};
pub use pool::PipelinePool;
pub use shard::{
    act_stats_sharded, calibrate_sharded, hessian_trace_sharded, interlayer_reduction_sharded,
    interlayer_scores_sharded, noise_scores_sharded, shard_indices, StageRunner,
};

use crate::quant::QuantConfig;
use crate::Result;

/// Outcome of evaluating one configuration on the validation split.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalResult {
    /// Mean loss over evaluated batches.
    pub loss: f64,
    /// Fraction of correct predictions (top-1 / exact match).
    pub accuracy: f64,
    /// False if the evaluation early-exited once the accuracy target became
    /// unreachable; the accuracy is then a valid *upper bound*.
    pub exact: bool,
}

/// Anything a search can evaluate configurations against.
pub trait SearchEnv {
    fn num_layers(&self) -> usize;

    /// Evaluate; `target` enables early-exit (result stays decision-exact:
    /// `accuracy >= target` iff a full evaluation would satisfy it).
    fn eval(&mut self, cfg: &QuantConfig, target: Option<f64>) -> Result<EvalResult>;

    /// Evaluate a batch of candidate configurations, one result per input
    /// in order. The default falls back to sequential [`SearchEnv::eval`];
    /// parallel environments override it to score the whole frontier
    /// concurrently. Per-candidate errors are reported in place so callers
    /// decide which speculative failures matter.
    fn eval_many(&mut self, cfgs: &[QuantConfig], target: Option<f64>) -> Vec<Result<EvalResult>> {
        cfgs.iter().map(|c| self.eval(c, target)).collect()
    }

    /// How many candidates this environment can usefully evaluate at once
    /// (its worker count). Searches size speculative frontiers to this;
    /// `1` makes every batched search reduce exactly to its sequential
    /// form.
    fn preferred_batch(&self) -> usize {
        1
    }
}

/// Result of a configuration search.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    pub config: QuantConfig,
    /// Exact accuracy of the final configuration.
    pub accuracy: f64,
    /// Number of *decision* evaluations the search consumed — identical at
    /// every worker count. Speculative evaluations a batched run discards
    /// are visible in the environment's own counters instead.
    pub evals: usize,
    /// The accuracy floor the search guaranteed.
    pub target: f64,
}

/// Which search algorithm to run (CLI/report plumbing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SearchAlgo {
    Bisection,
    Greedy,
}

impl SearchAlgo {
    pub fn label(self) -> &'static str {
        match self {
            SearchAlgo::Bisection => "Bisection",
            SearchAlgo::Greedy => "Greedy",
        }
    }

    /// Run this algorithm with a sensitivity ordering (ascending — least
    /// sensitive first) over the quantized bit widths, under a plain
    /// accuracy floor (the paper's objective).
    pub fn run<E: SearchEnv>(
        self,
        env: &mut E,
        order: &[usize],
        quant_bits: &[f32],
        target: f64,
    ) -> Result<SearchOutcome> {
        match self {
            SearchAlgo::Bisection => bisection::search(env, order, quant_bits, target),
            SearchAlgo::Greedy => greedy::search(env, order, quant_bits, target),
        }
    }

    /// Run under an arbitrary objective/observer/checkpoint control
    /// surface (see [`crate::api::SearchCtl`] and
    /// [`crate::api::run_search`]).
    pub fn run_with<E: SearchEnv>(
        self,
        env: &mut E,
        order: &[usize],
        quant_bits: &[f32],
        ctl: &mut crate::api::SearchCtl<'_>,
    ) -> Result<SearchOutcome> {
        match self {
            SearchAlgo::Bisection => bisection::search_with(env, order, quant_bits, ctl),
            SearchAlgo::Greedy => greedy::search_with(env, order, quant_bits, ctl),
        }
    }

    /// Run scoped to a segment of the layer order, starting from `base`
    /// instead of the all-float config; layers outside `order` keep their
    /// `base` width (see `greedy::search_scoped` /
    /// `bisection::search_scoped`). With the full order and a float base
    /// this is exactly [`SearchAlgo::run_with`].
    pub fn run_scoped<E: SearchEnv>(
        self,
        env: &mut E,
        order: &[usize],
        base: &QuantConfig,
        quant_bits: &[f32],
        ctl: &mut crate::api::SearchCtl<'_>,
    ) -> Result<SearchOutcome> {
        match self {
            SearchAlgo::Bisection => bisection::search_scoped(env, order, base, quant_bits, ctl),
            SearchAlgo::Greedy => greedy::search_scoped(env, order, base, quant_bits, ctl),
        }
    }
}

impl std::str::FromStr for SearchAlgo {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "greedy" => Ok(SearchAlgo::Greedy),
            "bisection" => Ok(SearchAlgo::Bisection),
            other => anyhow::bail!("unknown algo `{other}` (greedy|bisection)"),
        }
    }
}
