//! Sharded data-parallel calibration & sensitivity: stage jobs fanned
//! across workers with deterministic host-side reduction.
//!
//! The paper's two-step scale estimation, the Hutchinson Hessian trace,
//! and the ε_N noise metric used to be monolithic single-device loops
//! inside [`Pipeline`](super::Pipeline). They are now split into *pure
//! per-shard kernels* (`Pipeline::{act_stats_shard, adjust_grads_shard,
//! hvp_shard, noise_shard, pair_shard}`) plus the host-side reducers in
//! [`crate::quant::calibrate`], driven by the functions in this module
//! over anything implementing [`StageRunner`]:
//!
//! * [`Pipeline`](super::Pipeline) — one device; shards run back-to-back.
//! * [`PipelinePool`](super::PipelinePool) — one device pipeline per
//!   worker; shards run concurrently via dedicated `WorkerJob` variants,
//!   with updated [`Scales`] broadcast to every worker between Adam steps.
//! * [`crate::api::SyntheticStage`] — device-free math fanned over scoped
//!   threads, so the driver runs in CI, tests and benches with no
//!   artifacts.
//!
//! **Determinism guarantee:** for a fixed model and
//! [`CalibrationOptions`], results are bit-identical at every worker
//! count. Shard kernels return *per-item* (per-batch / per-trial) results
//! tagged with their global index; all cross-shard reduction happens
//! host-side in global-index order (max-merge for act stats, fixed-order
//! f64 gradient averaging feeding a single
//! [`ScaleAdam`](crate::quant::calibrate::ScaleAdam), trial-ordered trace
//! and noise accumulation); and Monte-Carlo draws are item-seeded —
//! Hutchinson probes per trial ([`crate::util::rng::probe_seed`]), ε_N
//! perturbations per (layer, trial) ([`crate::util::rng::noise_seed`]),
//! inter-layer paired perturbations per (layer, layer, trial)
//! ([`crate::util::rng::pair_seed`]) — not from a sequentially shared
//! RNG. Nothing in the math depends on which worker computed what.

use anyhow::ensure;

use crate::api::SearchEvent;
use crate::quant::calibrate::{
    self, merge_act_stats, pair_count, reduce_grads, reduce_noise, reduce_pairs, reduce_traces,
    sync_groups, BatchGrad, InterLayerReduction, NoiseSample, PairSample, ScaleAdam, TraceSample,
};
use crate::quant::{AdjustReport, CalibrationOptions, Scales};
use crate::Result;

/// A backend able to run calibration/sensitivity stage jobs across
/// `shard_workers()` workers. Kernels are *pure* with respect to the
/// optimizer state: they evaluate at the scales they are handed and never
/// mutate them; the driver owns the optimizer and pushes updates through
/// [`StageRunner::broadcast_scales`].
pub trait StageRunner {
    /// Workers stage jobs can be fanned across (>= 1).
    fn shard_workers(&self) -> usize;
    /// Quantizable layers (the scale-vector dimension).
    fn shard_layers(&self) -> usize;
    /// Batches in the adjustment split — the shard domain for activation
    /// statistics and gradient jobs.
    fn adjust_batches(&self) -> usize;
    /// Per-quant-layer weight element counts (Hessian trace
    /// normalization).
    fn weight_numels(&self) -> Vec<u64>;
    /// Step-1 weight scales from the model parameters (host-side math; on
    /// a pool this runs on worker 0 — every worker holds identical
    /// parameters).
    fn stage_weight_scales(&mut self) -> Result<Scales>;
    /// Per-shard `max |activation|` over the given adjustment batches;
    /// one merged vector per input shard, gathered in shard order.
    fn stage_act_stats(&mut self, shards: &[Vec<usize>]) -> Result<Vec<Vec<f32>>>;
    /// Per-batch scale gradients at fixed `scales`, quantization active at
    /// `bits`; shard `i` covers the global batch indices in `shards[i]`.
    fn stage_adjust_grads(
        &mut self,
        scales: &Scales,
        bits: f32,
        shards: &[Vec<usize>],
    ) -> Result<Vec<Vec<BatchGrad>>>;
    /// Per-trial Hutchinson probes; shard `i` covers the trial indices in
    /// `shards[i]`, each probe seeded by
    /// [`crate::util::rng::probe_seed`]`(seed, trial)`.
    fn stage_hvp(&mut self, seed: u64, shards: &[Vec<usize>]) -> Result<Vec<Vec<TraceSample>>>;
    /// Mean float calibration loss of the *unperturbed* model — the ε_N
    /// baseline (Eq. 3). Identical on every worker; on a pool this runs on
    /// worker 0.
    fn stage_clean_loss(&mut self) -> Result<f64>;
    /// Per-item ε_N perturbation trials; shard `i` covers the flattened
    /// `layer * trials + trial` indices in `shards[i]`, each draw seeded by
    /// [`crate::util::rng::noise_seed`]`(seed, layer, trial)`.
    fn stage_noise(
        &mut self,
        lambda: f64,
        trials: usize,
        seed: u64,
        shards: &[Vec<usize>],
    ) -> Result<Vec<Vec<NoiseSample>>>;
    /// Per-item paired-perturbation trials for the inter-layer metric;
    /// shard `i` covers the flattened pair-major
    /// `pair_index(layers, i, j) * trials + trial` indices in `shards[i]`,
    /// each layer draw seeded by
    /// [`crate::util::rng::pair_seed`]`(seed, l, l, trial)` so the paired
    /// run reuses the exact single-layer draws of the diagonal cells.
    fn stage_pair(
        &mut self,
        lambda: f64,
        trials: usize,
        seed: u64,
        shards: &[Vec<usize>],
    ) -> Result<Vec<Vec<PairSample>>>;
    /// Install `scales` on every worker pipeline (device sync included).
    fn broadcast_scales(&mut self, scales: &Scales) -> Result<()>;
}

/// Contiguous partition of `items` into at most `shards` non-empty chunks
/// (fewer when there are fewer items than shards). Deterministic: depends
/// only on the item list and the shard count.
pub fn shard_indices(items: &[usize], shards: usize) -> Vec<Vec<usize>> {
    if items.is_empty() {
        return Vec::new();
    }
    let shards = shards.max(1).min(items.len());
    let base = items.len() / shards;
    let rem = items.len() % shards;
    let mut out = Vec::with_capacity(shards);
    let mut start = 0usize;
    for s in 0..shards {
        let len = base + usize::from(s < rem);
        out.push(items[start..start + len].to_vec());
        start += len;
    }
    out
}

/// Per-layer `max |activation|` over the whole adjustment split, sharded
/// across the runner's workers and max-merged host-side. Bit-identical to
/// the historical single-device loop at any worker count (max is exact
/// and order-independent).
pub fn act_stats_sharded<R: StageRunner + ?Sized>(runner: &mut R) -> Result<Vec<f32>> {
    let all: Vec<usize> = (0..runner.adjust_batches()).collect();
    let shards = shard_indices(&all, runner.shard_workers());
    if shards.is_empty() {
        return Ok(vec![0.0; runner.shard_layers()]);
    }
    let per_shard = runner.stage_act_stats(&shards)?;
    let merged = merge_act_stats(&per_shard);
    ensure!(
        merged.len() == runner.shard_layers(),
        "act stats returned {} layers, expected {}",
        merged.len(),
        runner.shard_layers()
    );
    Ok(merged)
}

/// The paper's two-step scale estimation as a sharded stage pipeline:
/// max calibration (weights host-side, activation stats sharded +
/// max-merged), then synchronous data-parallel adjustment — each Adam
/// step averages the gradients of one [`sync_groups`] batch group
/// (computed shard-parallel at fixed scales, reduced in batch order) and
/// broadcasts the updated scales to every worker. Returns the final
/// scales (already broadcast) and the adjustment report.
pub fn calibrate_sharded<R: StageRunner + ?Sized>(
    runner: &mut R,
    opts: &CalibrationOptions,
    mut observer: Option<&mut dyn FnMut(&SearchEvent)>,
) -> Result<(Scales, AdjustReport)> {
    let n = runner.shard_layers();
    let nb = runner.adjust_batches();
    let workers = runner.shard_workers();
    let mut emit = |ev: SearchEvent| {
        if let Some(obs) = observer.as_mut() {
            obs(&ev);
        }
    };
    emit(SearchEvent::CalibrationStarted {
        workers,
        batches: nb,
        grad_batches: opts.grad_batches.max(1),
        epochs: opts.epochs,
    });

    // Step 1: max calibration.
    let mut scales = runner.stage_weight_scales()?;
    ensure!(
        scales.num_layers() == n,
        "weight scales cover {} layers, expected {}",
        scales.num_layers(),
        n
    );
    let acts = act_stats_sharded(runner)?;
    calibrate::apply_act_stats(&mut scales, &acts);
    runner.broadcast_scales(&scales)?;

    // Step 2: synchronous data-parallel adjustment.
    let mut opt = ScaleAdam::new(n, opts.lr);
    let mut first_loss = None;
    let mut last_loss = 0.0f64;
    let mut steps = 0usize;
    if nb > 0 {
        for epoch in 0..opts.epochs {
            let mut epoch_loss = 0.0f64;
            let groups = sync_groups(nb, opts.grad_batches);
            for group in &groups {
                let shards = shard_indices(group, workers);
                let mut grads: Vec<BatchGrad> = runner
                    .stage_adjust_grads(&scales, opts.adjust_bits, &shards)?
                    .into_iter()
                    .flatten()
                    .collect();
                ensure!(
                    grads.len() == group.len(),
                    "adjustment shards returned {} gradients for a {}-batch group",
                    grads.len(),
                    group.len()
                );
                let (loss, mean) = reduce_grads(n, &mut grads)?;
                first_loss.get_or_insert(loss);
                last_loss = loss;
                epoch_loss += loss;
                opt.step(&mut scales, &mean);
                steps += 1;
                runner.broadcast_scales(&scales)?;
            }
            emit(SearchEvent::AdjustEpoch {
                epoch,
                loss: epoch_loss / groups.len().max(1) as f64,
                steps,
            });
        }
    }
    let report =
        AdjustReport { loss_before: first_loss.unwrap_or(0.0), loss_after: last_loss, steps };
    emit(SearchEvent::CalibrationFinished {
        loss_before: report.loss_before,
        loss_after: report.loss_after,
        steps: report.steps,
    });
    Ok((scales, report))
}

/// Hutchinson estimate of the per-layer mean Hessian trace, trials
/// sharded across workers. Each trial's Rademacher probe depends only on
/// `(seed, trial)`, and accumulation is host-side in trial order, so
/// every worker count produces bit-identical traces.
pub fn hessian_trace_sharded<R: StageRunner + ?Sized>(
    runner: &mut R,
    trials: usize,
    seed: u64,
) -> Result<Vec<f64>> {
    let trials = trials.max(1);
    let idx: Vec<usize> = (0..trials).collect();
    let shards = shard_indices(&idx, runner.shard_workers());
    let mut samples: Vec<TraceSample> =
        runner.stage_hvp(seed, &shards)?.into_iter().flatten().collect();
    ensure!(
        samples.len() == trials,
        "hvp shards returned {} samples for {} trials",
        samples.len(),
        trials
    );
    let numels = runner.weight_numels();
    ensure!(
        numels.len() == runner.shard_layers(),
        "weight numels cover {} layers, expected {}",
        numels.len(),
        runner.shard_layers()
    );
    reduce_traces(&mut samples, trials, &numels)
}

/// ε_N (Eqs. 3–5) as a sharded stage job: the `layer × trial` grid of
/// Gaussian perturbation trials is flattened layer-major, fanned across
/// the runner's workers, and reduced host-side in global item order
/// against the (worker-0) clean-model baseline loss. Each trial's draw is
/// seeded by [`crate::util::rng::noise_seed`]`(seed, layer, trial)`, so
/// scores are bit-identical at every worker count.
pub fn noise_scores_sharded<R: StageRunner + ?Sized>(
    runner: &mut R,
    lambda: f64,
    trials: usize,
    seed: u64,
) -> Result<Vec<f64>> {
    let trials = trials.max(1);
    let n = runner.shard_layers();
    let clean_loss = runner.stage_clean_loss()?;
    let items: Vec<usize> = (0..n * trials).collect();
    let shards = shard_indices(&items, runner.shard_workers());
    let mut samples: Vec<NoiseSample> =
        runner.stage_noise(lambda, trials, seed, &shards)?.into_iter().flatten().collect();
    ensure!(
        samples.len() == n * trials,
        "noise shards returned {} samples for a {n} x {trials} trial grid",
        samples.len()
    );
    reduce_noise(&mut samples, n, trials, clean_loss)
}

/// The inter-layer-augmented Hessian metric as a sharded stage job: the
/// symmetric `(layer, layer, trial)` grid of paired Gaussian perturbation
/// trials is flattened pair-major (upper triangle `i <= j`, row-major,
/// diagonal cells = single-layer baselines), fanned across the runner's
/// workers, and reduced host-side in global item order against the
/// clean-model baseline loss. Layer draws are addressed by
/// [`crate::util::rng::pair_seed`], so the full reduction — baselines,
/// coupling matrix, and augmented scores — is bit-identical at every
/// worker count. Returns the full [`InterLayerReduction`]; use
/// [`interlayer_scores_sharded`] for just the per-layer scores.
pub fn interlayer_reduction_sharded<R: StageRunner + ?Sized>(
    runner: &mut R,
    lambda: f64,
    trials: usize,
    seed: u64,
) -> Result<InterLayerReduction> {
    let trials = trials.max(1);
    let n = runner.shard_layers();
    let clean_loss = runner.stage_clean_loss()?;
    let total = pair_count(n) * trials;
    let items: Vec<usize> = (0..total).collect();
    let shards = shard_indices(&items, runner.shard_workers());
    let mut samples: Vec<PairSample> =
        runner.stage_pair(lambda, trials, seed, &shards)?.into_iter().flatten().collect();
    ensure!(
        samples.len() == total,
        "pair shards returned {} samples for a {} x {trials} pair grid",
        samples.len(),
        pair_count(n)
    );
    reduce_pairs(&mut samples, n, trials, clean_loss)
}

/// Per-layer inter-layer-augmented sensitivity scores (see
/// [`interlayer_reduction_sharded`]).
pub fn interlayer_scores_sharded<R: StageRunner + ?Sized>(
    runner: &mut R,
    lambda: f64,
    trials: usize,
    seed: u64,
) -> Result<Vec<f64>> {
    Ok(interlayer_reduction_sharded(runner, lambda, trials, seed)?.scores)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_indices_partition_contiguously() {
        let items: Vec<usize> = (0..10).collect();
        let shards = shard_indices(&items, 3);
        assert_eq!(shards, vec![vec![0, 1, 2, 3], vec![4, 5, 6], vec![7, 8, 9]]);
        // Never more shards than items; zero items -> zero shards.
        assert_eq!(shard_indices(&items[..2], 8).len(), 2);
        assert!(shard_indices(&[], 4).is_empty());
        // Flattening restores the original order at any worker count.
        for workers in [1usize, 2, 4, 7, 16] {
            let flat: Vec<usize> =
                shard_indices(&items, workers).into_iter().flatten().collect();
            assert_eq!(flat, items, "workers {workers}");
        }
    }
}
