//! Algorithm 1 — bisection configuration search, with batched speculation.
//!
//! Assumes a threshold sensitivity exists per bit width: layers less
//! sensitive than the threshold can run at that width. The search bisects
//! over the prefix length of the sensitivity-sorted layer list, per width,
//! using `O(b log N)` model evaluations. It inherits bisection's reliance
//! on ordering quality — a mis-ordered sensitive layer poisons whole
//! prefixes, which is exactly the behaviour the paper reports (bisection
//! leaving many more layers at 16 bits than greedy).
//!
//! # Batched speculation
//!
//! Each probe's outcome decides which half-interval is searched next, so
//! the upcoming probes form a binary decision tree rooted at the current
//! interval. A batched round enumerates that tree breadth-first up to
//! [`SearchEnv::preferred_batch`] nodes, evaluates all of their prefix
//! configurations in one [`SearchEnv::eval_many`] call, then replays the
//! sequential bisection against the batched results until it steps off the
//! evaluated subtree. Probes on untaken branches are discarded; consumed
//! probes are exactly the sequential sequence, so the final configuration
//! and decision-eval count are bit-identical at every worker count. With a
//! window of `w`, each round resolves ~`log2(w+1)` sequential decisions.

use std::collections::HashMap;

use crate::api::{AccuracyTarget, SearchCtl, SearchEvent};
use crate::quant::QuantConfig;
use crate::Result;

use super::{SearchEnv, SearchOutcome};

/// The paper's bisection search under a plain accuracy floor (the
/// historical entry point — a thin wrapper over [`search_with`]).
pub fn search<E: SearchEnv>(
    env: &mut E,
    order: &[usize],
    quant_bits: &[f32],
    target: f64,
) -> Result<SearchOutcome> {
    let objective = AccuracyTarget::new(target);
    let mut ctl = SearchCtl::new(&objective);
    search_with(env, order, quant_bits, &mut ctl)
}

/// Bisection search under an arbitrary [`crate::api::Objective`].
///
/// Checkpointed probe decisions replay without evaluating; live probes go
/// through `ctl.decide`. After every *passing* probe the committed prefix
/// (`lo` only ever grows within a width) is checked against the
/// objective's budgets, so a budgeted run stops the moment the budget is
/// met instead of bisecting toward a larger, lower-accuracy prefix. With
/// [`AccuracyTarget`] the trajectory is bit-identical to the
/// pre-objective implementation.
pub fn search_with<E: SearchEnv>(
    env: &mut E,
    order: &[usize],
    quant_bits: &[f32],
    ctl: &mut SearchCtl<'_>,
) -> Result<SearchOutcome> {
    assert_eq!(order.len(), env.num_layers(), "ordering must cover every quant layer");
    let base = QuantConfig::float(env.num_layers());
    search_scoped(env, order, &base, quant_bits, ctl)
}

/// Bisection search restricted to the layers in `order`, starting from
/// `base`.
///
/// Layers outside `order` keep whatever width `base` assigns them (the
/// partitioned driver freezes the complement at reference precision), so a
/// segment's probes depend only on its own prefix plus the fixed base.
/// With the full order and an all-float base this is exactly
/// [`search_with`] — the whole-model search is the K=1 special case.
pub fn search_scoped<E: SearchEnv>(
    env: &mut E,
    order: &[usize],
    base: &QuantConfig,
    quant_bits: &[f32],
    ctl: &mut SearchCtl<'_>,
) -> Result<SearchOutcome> {
    let n = env.num_layers();
    assert_eq!(base.num_layers(), n, "base config must cover every quant layer");
    assert!(order.len() <= n, "segment cannot exceed the layer count");
    assert!(order.iter().all(|&l| l < n), "segment layer out of range");
    let window = env.preferred_batch().max(1);
    let mut w = base.clone();
    if let Some(done) = ctl.baseline_outcome(env, &w)? {
        return Ok(done);
    }
    let mut evals = 0usize;
    let mut ll: Vec<usize> = order.to_vec();
    'widths: for &b in quant_bits {
        if ll.is_empty() {
            break;
        }
        // Alg. 1's threshold update ("thr ± (bound - thr)/2 until thr stops
        // changing") oscillates between adjacent pass/fail prefixes with
        // integer arithmetic; we implement the same bisection as a classic
        // largest-passing-prefix search with invariant: every evaluated
        // prefix <= lo passed, every evaluated prefix > hi failed.
        let mut lo = 0usize;
        let mut hi = ll.len();
        while lo < hi {
            // Checkpointed probes replay without evaluating; the bisection
            // trajectory is a deterministic function of the pass/fail
            // sequence, so replay reproduces (lo, hi) exactly.
            {
                let mid = lo + (hi - lo).div_ceil(2);
                if let Some(pass) = ctl.take_replay(b, mid) {
                    evals += 1;
                    if pass {
                        lo = mid;
                        // `lo` only ever grows, so a passing prefix is
                        // committed to the final config of this width; if
                        // it already meets the budget, stop here rather
                        // than bisect toward a larger (lower-accuracy)
                        // prefix.
                        let committed = with_prefix(&w, &ll, lo, b);
                        if ctl.satisfied(&committed) {
                            w = committed;
                            break 'widths;
                        }
                    } else {
                        hi = mid - 1;
                    }
                    continue;
                }
            }
            // Breadth-first frontier of the upcoming decision tree: the
            // sequential probe for (lo, hi) first, then the probes both of
            // its outcomes would lead to, and so on up to `window` nodes.
            // Probe prefixes from disjoint branches are distinct, so the
            // mid -> result map below cannot collide.
            let mut states = vec![(lo, hi)];
            let mut mids: Vec<usize> = Vec::new();
            let mut qi = 0usize;
            while qi < states.len() && mids.len() < window {
                let (l, h) = states[qi];
                qi += 1;
                if l >= h {
                    continue;
                }
                let mid = l + (h - l).div_ceil(2); // upper mid: never == l
                mids.push(mid);
                states.push((mid, h)); // pass branch
                states.push((l, mid - 1)); // fail branch
            }
            let cfgs: Vec<QuantConfig> = mids.iter().map(|&m| with_prefix(&w, &ll, m, b)).collect();
            ctl.emit(SearchEvent::FrontierSubmitted { bits: b, size: cfgs.len() });
            let results = env.eval_many(&cfgs, ctl.eval_target());
            let mut by_mid: HashMap<usize, _> =
                mids.into_iter().zip(cfgs.into_iter().zip(results)).collect();
            // Replay the sequential bisection against the batch; stop when
            // it needs a probe the speculation did not cover.
            while lo < hi {
                let mid = lo + (hi - lo).div_ceil(2);
                let Some((cfg, r)) = by_mid.remove(&mid) else { break };
                let r = r?;
                evals += 1;
                if ctl.decide(b, mid, &cfg, &r)? {
                    lo = mid;
                    // `cfg` is exactly the current config plus the passing
                    // prefix, which `lo`'s monotonicity commits to this
                    // width's outcome — budget met means stop now instead
                    // of bisecting toward a larger prefix.
                    if ctl.satisfied(&cfg) {
                        w = cfg;
                        break 'widths;
                    }
                } else {
                    hi = mid - 1;
                }
            }
        }
        // `lo` is the largest prefix meeting the target (0 if none does).
        for &layer in &ll[..lo] {
            w.set_layer(layer, b);
        }
        ll.truncate(lo);
    }
    let final_res = env.eval(&w, None)?;
    evals += 1;
    Ok(SearchOutcome {
        config: w,
        accuracy: final_res.accuracy,
        evals,
        target: ctl.objective().accuracy_floor(),
    })
}

/// `base` with the first `lo` layers of `ll` set to width `bits` — the
/// prefix configuration bisection probes and commits.
fn with_prefix(base: &QuantConfig, ll: &[usize], lo: usize, bits: f32) -> QuantConfig {
    let mut c = base.clone();
    for &layer in &ll[..lo] {
        c.set_layer(layer, bits);
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::EvalResult;

    /// Threshold model: the first `ok8` layers of the ordering tolerate
    /// 8 bits, the first `ok4` tolerate 4 bits (ok4 <= ok8). A prefix
    /// passes iff it stays within the tolerance — exactly bisection's
    /// structural assumption.
    struct Threshold {
        order_pos: Vec<usize>, // layer -> position in the ordering
        ok8: usize,
        ok4: usize,
    }

    impl SearchEnv for Threshold {
        fn num_layers(&self) -> usize {
            self.order_pos.len()
        }

        fn eval(&mut self, cfg: &QuantConfig, _t: Option<f64>) -> Result<EvalResult> {
            let ok = cfg.bits_w.iter().enumerate().all(|(layer, &b)| {
                let pos = self.order_pos[layer];
                if b <= 4.0 {
                    pos < self.ok4
                } else if b <= 8.0 {
                    pos < self.ok8
                } else {
                    true
                }
            });
            let acc = if ok { 1.0 } else { 0.5 };
            Ok(EvalResult { loss: 1.0 - acc, accuracy: acc, exact: true })
        }
    }

    /// A `Threshold` that advertises a batch window.
    struct BatchedThreshold {
        inner: Threshold,
        window: usize,
        raw_evals: usize,
    }

    impl SearchEnv for BatchedThreshold {
        fn num_layers(&self) -> usize {
            self.inner.num_layers()
        }

        fn eval(&mut self, cfg: &QuantConfig, t: Option<f64>) -> Result<EvalResult> {
            self.raw_evals += 1;
            self.inner.eval(cfg, t)
        }

        fn preferred_batch(&self) -> usize {
            self.window
        }
    }

    fn run(n: usize, ok8: usize, ok4: usize) -> SearchOutcome {
        let order: Vec<usize> = (0..n).collect();
        let mut env = Threshold { order_pos: order.clone(), ok8, ok4 };
        search(&mut env, &order, &[8.0, 4.0], 0.9).unwrap()
    }

    #[test]
    fn finds_exact_thresholds() {
        let out = run(16, 11, 5);
        for layer in 0..16 {
            let expect = if layer < 5 {
                4.0
            } else if layer < 11 {
                8.0
            } else {
                16.0
            };
            assert_eq!(out.config.layer_bits(layer), expect, "layer {layer}");
        }
        assert_eq!(out.accuracy, 1.0);
    }

    #[test]
    fn nothing_quantizable() {
        let out = run(8, 0, 0);
        assert_eq!(out.config, QuantConfig::float(8));
    }

    #[test]
    fn everything_quantizable() {
        let out = run(8, 8, 8);
        assert_eq!(out.config, QuantConfig::uniform(8, 4.0));
    }

    #[test]
    fn eval_budget_logarithmic() {
        let out = run(64, 40, 10);
        // b * (log2(64) + slack) + final eval
        assert!(out.evals <= 2 * 8 + 1, "used {} evals", out.evals);
    }

    #[test]
    fn single_layer_models() {
        assert_eq!(run(1, 1, 1).config, QuantConfig::uniform(1, 4.0));
        assert_eq!(run(1, 1, 0).config, QuantConfig::uniform(1, 8.0));
        assert_eq!(run(1, 0, 0).config, QuantConfig::float(1));
    }

    #[test]
    fn batched_windows_match_sequential_outcome() {
        for (n, ok8, ok4) in [(16, 11, 5), (33, 20, 0), (7, 7, 7), (24, 0, 0), (50, 49, 13)] {
            let order: Vec<usize> = (0..n).collect();
            let mut seq_env = Threshold { order_pos: order.clone(), ok8, ok4 };
            let seq = search(&mut seq_env, &order, &[8.0, 4.0], 0.9).unwrap();
            for window in [1usize, 2, 3, 7, 8, 64] {
                let mut env = BatchedThreshold {
                    inner: Threshold { order_pos: order.clone(), ok8, ok4 },
                    window,
                    raw_evals: 0,
                };
                let out = search(&mut env, &order, &[8.0, 4.0], 0.9).unwrap();
                assert_eq!(out.config, seq.config, "n={n} window={window}");
                assert_eq!(out.evals, seq.evals, "n={n} window={window}");
                assert!(env.raw_evals >= out.evals, "n={n} window={window}");
            }
        }
    }

    #[test]
    fn speculation_resolves_multiple_decisions_per_round() {
        // With a window of 7 (a full depth-3 tree) the replay consumes 3
        // sequential decisions per eval_many round, so the number of rounds
        // — visible as distinct raw-eval bursts — shrinks. Just bound total
        // raw work: at most window * ceil(decisions / depth) + final.
        let n = 64;
        let order: Vec<usize> = (0..n).collect();
        let mut env = BatchedThreshold {
            inner: Threshold { order_pos: order.clone(), ok8: 40, ok4: 10 },
            window: 7,
            raw_evals: 0,
        };
        let out = search(&mut env, &order, &[8.0, 4.0], 0.9).unwrap();
        let rounds_bound = out.evals.div_ceil(3) + 2;
        assert!(
            env.raw_evals <= 7 * rounds_bound + 1,
            "raw {} vs bound {}",
            env.raw_evals,
            7 * rounds_bound + 1
        );
    }
}
