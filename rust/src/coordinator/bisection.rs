//! Algorithm 1 — bisection configuration search.
//!
//! Assumes a threshold sensitivity exists per bit width: layers less
//! sensitive than the threshold can run at that width. The search bisects
//! over the prefix length of the sensitivity-sorted layer list, per width,
//! using `O(b log N)` model evaluations. It inherits bisection's reliance
//! on ordering quality — a mis-ordered sensitive layer poisons whole
//! prefixes, which is exactly the behaviour the paper reports (bisection
//! leaving many more layers at 16 bits than greedy).

use crate::quant::QuantConfig;
use crate::Result;

use super::{SearchEnv, SearchOutcome};

pub fn search<E: SearchEnv>(
    env: &mut E,
    order: &[usize],
    quant_bits: &[f32],
    target: f64,
) -> Result<SearchOutcome> {
    let n = env.num_layers();
    assert_eq!(order.len(), n, "ordering must cover every quant layer");
    let mut w = QuantConfig::float(n);
    let mut evals = 0usize;
    let mut ll: Vec<usize> = order.to_vec();
    for &b in quant_bits {
        if ll.is_empty() {
            break;
        }
        // Alg. 1's threshold update ("thr ± (bound - thr)/2 until thr stops
        // changing") oscillates between adjacent pass/fail prefixes with
        // integer arithmetic; we implement the same bisection as a classic
        // largest-passing-prefix search with invariant: every evaluated
        // prefix <= lo passed, every evaluated prefix > hi failed.
        let mut lo = 0usize;
        let mut hi = ll.len();
        while lo < hi {
            let mid = lo + (hi - lo).div_ceil(2); // upper mid: never == lo
            let mut lw = w.clone();
            for &layer in &ll[..mid] {
                lw.set_layer(layer, b);
            }
            let r = env.eval(&lw, Some(target))?;
            evals += 1;
            if r.accuracy >= target {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        // `lo` is the largest prefix meeting the target (0 if none does).
        for &layer in &ll[..lo] {
            w.set_layer(layer, b);
        }
        ll.truncate(lo);
    }
    let final_res = env.eval(&w, None)?;
    evals += 1;
    Ok(SearchOutcome { config: w, accuracy: final_res.accuracy, evals, target })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::EvalResult;

    /// Threshold model: the first `ok8` layers of the ordering tolerate
    /// 8 bits, the first `ok4` tolerate 4 bits (ok4 <= ok8). A prefix
    /// passes iff it stays within the tolerance — exactly bisection's
    /// structural assumption.
    struct Threshold {
        order_pos: Vec<usize>, // layer -> position in the ordering
        ok8: usize,
        ok4: usize,
    }

    impl SearchEnv for Threshold {
        fn num_layers(&self) -> usize {
            self.order_pos.len()
        }

        fn eval(&mut self, cfg: &QuantConfig, _t: Option<f64>) -> Result<EvalResult> {
            let ok = cfg.bits_w.iter().enumerate().all(|(layer, &b)| {
                let pos = self.order_pos[layer];
                if b <= 4.0 {
                    pos < self.ok4
                } else if b <= 8.0 {
                    pos < self.ok8
                } else {
                    true
                }
            });
            let acc = if ok { 1.0 } else { 0.5 };
            Ok(EvalResult { loss: 1.0 - acc, accuracy: acc, exact: true })
        }
    }

    fn run(n: usize, ok8: usize, ok4: usize) -> SearchOutcome {
        let order: Vec<usize> = (0..n).collect();
        let mut env = Threshold { order_pos: order.clone(), ok8, ok4 };
        search(&mut env, &order, &[8.0, 4.0], 0.9).unwrap()
    }

    #[test]
    fn finds_exact_thresholds() {
        let out = run(16, 11, 5);
        for layer in 0..16 {
            let expect = if layer < 5 {
                4.0
            } else if layer < 11 {
                8.0
            } else {
                16.0
            };
            assert_eq!(out.config.layer_bits(layer), expect, "layer {layer}");
        }
        assert_eq!(out.accuracy, 1.0);
    }

    #[test]
    fn nothing_quantizable() {
        let out = run(8, 0, 0);
        assert_eq!(out.config, QuantConfig::float(8));
    }

    #[test]
    fn everything_quantizable() {
        let out = run(8, 8, 8);
        assert_eq!(out.config, QuantConfig::uniform(8, 4.0));
    }

    #[test]
    fn eval_budget_logarithmic() {
        let out = run(64, 40, 10);
        // b * (log2(64) + slack) + final eval
        assert!(out.evals <= 2 * 8 + 1, "used {} evals", out.evals);
    }

    #[test]
    fn single_layer_models() {
        assert_eq!(run(1, 1, 1).config, QuantConfig::uniform(1, 4.0));
        assert_eq!(run(1, 1, 0).config, QuantConfig::uniform(1, 8.0));
        assert_eq!(run(1, 0, 0).config, QuantConfig::float(1));
    }
}
