//! Cross-run persistent evaluation cache.
//!
//! Report, ablation and search runs over the same model repeatedly evaluate
//! the same configurations (uniform baselines, search prefixes, frontier
//! candidates). The in-memory memo inside [`super::Pipeline`] only lives for
//! one process; this cache persists **exact** (full-validation) results to a
//! JSON file under the artifacts directory so later runs skip the device
//! entirely.
//!
//! Entries are keyed by [`crate::quant::QuantConfig::key`] and guarded by a
//! caller-supplied *context fingerprint* — everything an evaluation result
//! depends on besides the configuration (model name, scales, dataset). A
//! file whose fingerprint does not match is discarded wholesale rather than
//! risking stale hits. Only exact results are stored: they answer any
//! future target decisively, so the cache never changes a search decision —
//! it only removes device work.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::Context as _;

use crate::util::json::{self, Value};
use crate::Result;

use super::EvalResult;

/// Schema version of the on-disk format. Version 1 files without
/// recency/stats fields load fine (fields default to zero).
pub const EVAL_CACHE_VERSION: u64 = 1;

/// One stored result with its last-used tick (for LRU eviction).
#[derive(Debug, Clone, Copy)]
struct Entry {
    loss: f64,
    accuracy: f64,
    lu: u64,
}

/// One `{key, loss, accuracy[, lu]}` row of the on-disk entry array.
fn parse_row(row: &Value) -> Result<(u64, Entry)> {
    let key = u64::from_str_radix(row.req("key")?.as_str()?, 16).context("bad cache key")?;
    let lu = row.get("lu").and_then(|v| v.as_u64().ok()).unwrap_or(0);
    let loss = row.req("loss")?.as_f64()?;
    let accuracy = row.req("accuracy")?.as_f64()?;
    Ok((key, Entry { loss, accuracy, lu }))
}

/// A persistent config-key -> exact-[`EvalResult`] map with an optional
/// entry bound. When bounded, insertions beyond the capacity evict the
/// least-recently-used entries (lookups refresh recency, and recency
/// survives restarts via the persisted `lu` ticks). Cumulative hit and
/// eviction counts are persisted alongside the entries.
#[derive(Debug)]
pub struct EvalCache {
    path: PathBuf,
    context: String,
    entries: HashMap<u64, Entry>,
    /// Monotone recency clock; next tick to assign.
    tick: u64,
    /// Entry bound; `None` = unbounded.
    capacity: Option<usize>,
    hits: usize,
    evictions: usize,
    /// Lifetime counters loaded from disk (pre-this-process totals).
    prior_hits: u64,
    prior_evictions: u64,
    dirty: bool,
}

impl EvalCache {
    /// Canonical location of one model's cache inside the shared
    /// multi-model store layout: `<dir>/<model>/evalcache.json`. Grouping
    /// per-model state under one directory keeps a model's cached results
    /// enumerable (and removable) as a unit when several models share a
    /// cache directory.
    pub fn store_path(dir: &Path, model: &str) -> PathBuf {
        dir.join(model).join("evalcache.json")
    }

    /// Resolve the store path for `model` under `dir`, migrating the
    /// legacy flat layout (`<dir>/<model>_evalcache.json`) into the store
    /// on first use. The on-disk schema is unchanged (same
    /// [`EVAL_CACHE_VERSION`], same context guard) — only the location
    /// moves, so a migrated file loads exactly as it would have from the
    /// flat path. Best-effort: the store directory is created, an existing
    /// store file always wins (a stale flat file is left untouched), and
    /// any filesystem failure simply yields the store path — the loader
    /// degrades to an empty cache rather than erroring.
    pub fn migrate_flat_layout(dir: &Path, model: &str) -> PathBuf {
        let store = Self::store_path(dir, model);
        if let Some(parent) = store.parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        if !store.exists() {
            let flat = dir.join(format!("{model}_evalcache.json"));
            if flat.is_file() {
                let _ = std::fs::rename(&flat, &store);
            }
        }
        store
    }

    /// Open the cache at `path` for the given context fingerprint. A
    /// missing, unreadable, corrupt or context-mismatched file yields an
    /// empty cache (never an error — the cache is an optimization).
    pub fn load(path: &Path, context: &str) -> Self {
        Self::with_capacity(path, context, None)
    }

    /// [`EvalCache::load`] with an entry bound: the cache holds at most
    /// `capacity` entries, evicting least-recently-used ones on insert
    /// (applied immediately to an oversized loaded file too).
    pub fn with_capacity(path: &Path, context: &str, capacity: Option<usize>) -> Self {
        let mut cache = Self {
            path: path.to_path_buf(),
            context: context.to_string(),
            entries: HashMap::new(),
            tick: 1,
            capacity: None,
            hits: 0,
            evictions: 0,
            prior_hits: 0,
            prior_evictions: 0,
            dirty: false,
        };
        'parse: {
            let Ok(text) = std::fs::read_to_string(path) else {
                break 'parse;
            };
            let Ok(v) = json::parse(&text) else {
                break 'parse;
            };
            let version_ok = v.get("version").map(|x| x.as_u64().ok() == Some(EVAL_CACHE_VERSION));
            let context_ok = v.get("context").map(|x| x.as_str().ok() == Some(context));
            if version_ok != Some(true) || context_ok != Some(true) {
                break 'parse;
            }
            if let Some(stats) = v.get("stats") {
                cache.prior_hits = stats.get("hits").and_then(|x| x.as_u64().ok()).unwrap_or(0);
                cache.prior_evictions =
                    stats.get("evictions").and_then(|x| x.as_u64().ok()).unwrap_or(0);
            }
            let Some(Ok(rows)) = v.get("entries").map(|e| e.as_arr()) else {
                break 'parse;
            };
            for row in rows {
                if let Ok((key, entry)) = parse_row(row) {
                    cache.tick = cache.tick.max(entry.lu + 1);
                    cache.entries.insert(key, entry);
                }
            }
        }
        cache.set_capacity(capacity);
        cache
    }

    /// (Re)bound the cache; an over-capacity cache evicts immediately.
    pub fn set_capacity(&mut self, capacity: Option<usize>) {
        self.capacity = capacity;
        self.enforce_capacity();
    }

    /// The configured entry bound, if any.
    pub fn capacity(&self) -> Option<usize> {
        self.capacity
    }

    fn enforce_capacity(&mut self) {
        let Some(cap) = self.capacity else {
            return;
        };
        if self.entries.len() <= cap {
            return;
        }
        // Evict least-recently-used first; key breaks tick ties so the
        // result is deterministic for a given operation sequence.
        let excess = self.entries.len() - cap;
        if excess == 1 {
            // Steady-state insert path: one min-scan, no sort/allocation.
            if let Some((_, key)) = self.entries.iter().map(|(&k, e)| (e.lu, k)).min() {
                self.entries.remove(&key);
            }
        } else {
            // Bulk case (capacity applied to an oversized loaded file).
            let mut by_age: Vec<(u64, u64)> =
                self.entries.iter().map(|(&k, e)| (e.lu, k)).collect();
            by_age.sort_unstable();
            for &(_, key) in &by_age[..excess] {
                self.entries.remove(&key);
            }
        }
        self.evictions += excess;
        self.dirty = true;
    }

    /// Look up a configuration key; exact results satisfy any target.
    /// Hits refresh the entry's recency; for *bounded* caches the refresh
    /// is persisted (so cross-run LRU order survives restarts) — an
    /// unbounded cache never consults recency, so a fully-cached run
    /// stays clean and skips the file rewrite entirely.
    pub fn lookup(&mut self, key: u64) -> Option<EvalResult> {
        let tick = self.tick;
        let bounded = self.capacity.is_some();
        let entry = self.entries.get_mut(&key)?;
        entry.lu = tick;
        self.tick += 1;
        self.hits += 1;
        if bounded {
            self.dirty = true;
        }
        Some(EvalResult { loss: entry.loss, accuracy: entry.accuracy, exact: true })
    }

    /// Record a result. Inexact (early-exited) results are ignored — their
    /// bounds are only valid for the target they were produced under.
    pub fn insert(&mut self, key: u64, result: &EvalResult) {
        if !result.exact {
            return;
        }
        let tick = self.tick;
        self.tick += 1;
        let bounded = self.capacity.is_some();
        match self.entries.get_mut(&key) {
            // Identical re-insert only refreshes recency: the entry set is
            // unchanged, so an unbounded cache stays clean (a bounded one
            // persists the refresh — LRU order matters there).
            Some(e) if e.loss == result.loss && e.accuracy == result.accuracy => {
                e.lu = tick;
                if bounded {
                    self.dirty = true;
                }
            }
            _ => {
                let entry = Entry { loss: result.loss, accuracy: result.accuracy, lu: tick };
                self.entries.insert(key, entry);
                self.dirty = true;
                self.enforce_capacity();
            }
        }
    }

    /// Number of stored results.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Lookups answered from this cache since load.
    pub fn hits(&self) -> usize {
        self.hits
    }

    /// Entries evicted by the capacity bound since load.
    pub fn evictions(&self) -> usize {
        self.evictions
    }

    /// Lifetime hits across all runs (persisted stats + this run).
    pub fn lifetime_hits(&self) -> u64 {
        self.prior_hits + self.hits as u64
    }

    /// Lifetime evictions across all runs (persisted stats + this run).
    pub fn lifetime_evictions(&self) -> u64 {
        self.prior_evictions + self.evictions as u64
    }

    /// The context fingerprint this cache is bound to.
    pub fn context(&self) -> &str {
        &self.context
    }

    /// Write back if anything changed. Keys are emitted in sorted order so
    /// the file is deterministic for a given operation sequence. The write
    /// goes to a temp file in the same directory followed by an atomic
    /// rename, so a crash mid-write leaves either the old file or the new
    /// one — never a truncated cache that poisons every later run.
    pub fn save(&mut self) -> Result<()> {
        if !self.dirty {
            return Ok(());
        }
        let mut keys: Vec<u64> = self.entries.keys().copied().collect();
        keys.sort_unstable();
        let rows: Vec<Value> = keys
            .into_iter()
            .map(|k| {
                let e = self.entries[&k];
                Value::obj(vec![
                    ("key", Value::Str(format!("{k:016x}"))),
                    ("loss", Value::Num(e.loss)),
                    ("accuracy", Value::Num(e.accuracy)),
                    ("lu", Value::Num(e.lu as f64)),
                ])
            })
            .collect();
        let v = Value::obj(vec![
            ("version", Value::Num(EVAL_CACHE_VERSION as f64)),
            ("context", Value::Str(self.context.clone())),
            (
                "stats",
                Value::obj(vec![
                    ("hits", Value::Num(self.lifetime_hits() as f64)),
                    ("evictions", Value::Num(self.lifetime_evictions() as f64)),
                ]),
            ),
            ("entries", Value::Arr(rows)),
        ]);
        crate::util::fs::atomic_write_text(&self.path, &v.to_string())
            .with_context(|| format!("saving eval cache {}", self.path.display()))?;
        self.dirty = false;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("mpq_evalcache_{name}.json"))
    }

    fn exact(loss: f64, acc: f64) -> EvalResult {
        EvalResult { loss, accuracy: acc, exact: true }
    }

    #[test]
    fn miss_insert_hit() {
        let path = tmp("mih");
        let _ = std::fs::remove_file(&path);
        let mut c = EvalCache::load(&path, "ctx");
        assert!(c.lookup(42).is_none());
        c.insert(42, &exact(0.5, 0.9));
        let hit = c.lookup(42).unwrap();
        assert_eq!(hit, exact(0.5, 0.9));
        assert_eq!(c.hits(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn inexact_results_not_stored() {
        let path = tmp("inexact");
        let _ = std::fs::remove_file(&path);
        let mut c = EvalCache::load(&path, "ctx");
        c.insert(7, &EvalResult { loss: 0.1, accuracy: 0.8, exact: false });
        assert!(c.lookup(7).is_none());
        assert!(c.is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn persistence_roundtrip_and_context_guard() {
        let path = tmp("roundtrip");
        let _ = std::fs::remove_file(&path);
        let mut c = EvalCache::load(&path, "model-a/scales-1");
        c.insert(u64::MAX, &exact(1.25, 0.75));
        c.insert(3, &exact(0.0, 1.0));
        c.save().unwrap();

        let mut re = EvalCache::load(&path, "model-a/scales-1");
        assert_eq!(re.len(), 2);
        assert_eq!(re.lookup(u64::MAX).unwrap(), exact(1.25, 0.75));
        assert_eq!(re.lookup(3).unwrap(), exact(0.0, 1.0));

        // A different context must not see the entries.
        let other = EvalCache::load(&path, "model-a/scales-2");
        assert!(other.is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn truncated_file_loads_as_empty_and_no_temp_left_behind() {
        let path = tmp("truncated");
        let _ = std::fs::remove_file(&path);
        let mut c = EvalCache::load(&path, "ctx");
        c.insert(1, &exact(0.5, 0.9));
        c.insert(2, &exact(0.25, 0.95));
        c.save().unwrap();
        // Simulate a crash mid-write of the *old* non-atomic path: chop
        // the file in half. The loader must degrade to empty, not error.
        let full = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        let re = EvalCache::load(&path, "ctx");
        assert!(re.is_empty());
        // The atomic save leaves no temp droppings next to the cache.
        let dir = path.parent().unwrap();
        let leftovers = std::fs::read_dir(dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| {
                let n = e.file_name().to_string_lossy().into_owned();
                n.contains("mpq_evalcache_truncated") && n.contains(".tmp.")
            })
            .count();
        assert_eq!(leftovers, 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_file_degrades_to_empty() {
        let path = tmp("corrupt");
        std::fs::write(&path, "{not json").unwrap();
        let c = EvalCache::load(&path, "ctx");
        assert!(c.is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn capacity_bound_evicts_least_recently_used() {
        let path = tmp("lru");
        let _ = std::fs::remove_file(&path);
        let mut c = EvalCache::with_capacity(&path, "ctx", Some(2));
        c.insert(1, &exact(0.1, 0.9));
        c.insert(2, &exact(0.2, 0.8));
        // Refresh 1, then insert 3: 2 is now the least recently used.
        assert!(c.lookup(1).is_some());
        c.insert(3, &exact(0.3, 0.7));
        assert_eq!(c.len(), 2);
        assert!(c.lookup(2).is_none(), "LRU entry should have been evicted");
        assert!(c.lookup(1).is_some());
        assert!(c.lookup(3).is_some());
        assert_eq!(c.evictions(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn recency_and_stats_survive_reload() {
        let path = tmp("lru_persist");
        let _ = std::fs::remove_file(&path);
        let mut c = EvalCache::with_capacity(&path, "ctx", Some(2));
        c.insert(1, &exact(0.1, 0.9));
        c.insert(2, &exact(0.2, 0.8));
        assert!(c.lookup(1).is_some()); // 1 newer than 2 on disk
        c.save().unwrap();
        assert_eq!(c.lifetime_hits(), 1);

        let mut re = EvalCache::with_capacity(&path, "ctx", Some(2));
        assert_eq!(re.lifetime_hits(), 1, "persisted hit stats should reload");
        re.insert(3, &exact(0.3, 0.7));
        assert!(re.lookup(2).is_none(), "cross-run LRU order should evict 2");
        assert!(re.lookup(1).is_some());
        re.save().unwrap();

        let re2 = EvalCache::load(&path, "ctx");
        assert_eq!(re2.lifetime_evictions(), 1, "persisted eviction stats should reload");
        assert_eq!(re2.lifetime_hits(), 2, "1 persisted + 1 from the second run");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn oversized_file_trimmed_at_load_and_unbounded_by_default() {
        let path = tmp("trim");
        let _ = std::fs::remove_file(&path);
        let mut c = EvalCache::load(&path, "ctx");
        for k in 0..10u64 {
            c.insert(k, &exact(0.0, 1.0));
        }
        assert_eq!(c.len(), 10, "unbounded by default");
        c.save().unwrap();
        let trimmed = EvalCache::with_capacity(&path, "ctx", Some(4));
        assert_eq!(trimmed.len(), 4);
        assert_eq!(trimmed.evictions(), 6);
        // The newest inserts survive (ticks 7..10 beat 1..6).
        let mut trimmed = trimmed;
        for k in 6..10u64 {
            assert!(trimmed.lookup(k).is_some(), "key {k} should survive");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn store_layout_migrates_the_flat_file_once() {
        let dir = std::env::temp_dir().join("mpq_evalcache_store_migrate");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // Seed a legacy flat-layout cache with one entry.
        let flat = dir.join("bert_s_evalcache.json");
        let mut old = EvalCache::load(&flat, "ctx");
        old.insert(42, &exact(0.5, 0.9));
        old.save().unwrap();

        let store = EvalCache::migrate_flat_layout(&dir, "bert_s");
        assert_eq!(store, EvalCache::store_path(&dir, "bert_s"));
        assert_eq!(store, dir.join("bert_s").join("evalcache.json"));
        assert!(store.is_file(), "flat file should move into the store");
        assert!(!flat.exists(), "flat file should be gone after migration");
        let mut migrated = EvalCache::load(&store, "ctx");
        assert_eq!(migrated.lookup(42).unwrap(), exact(0.5, 0.9));

        // Idempotent: a second resolve keeps the store file as-is, and a
        // freshly appearing flat file never overwrites an existing store.
        std::fs::write(&flat, "{stale}").unwrap();
        let again = EvalCache::migrate_flat_layout(&dir, "bert_s");
        assert_eq!(again, store);
        assert!(flat.is_file(), "existing store must win over a flat file");
        let mut re = EvalCache::load(&store, "ctx");
        assert!(re.lookup(42).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn store_layout_resolves_without_a_flat_file() {
        let dir = std::env::temp_dir().join("mpq_evalcache_store_fresh");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let store = EvalCache::migrate_flat_layout(&dir, "resnet_s");
        assert!(!store.exists(), "nothing to migrate");
        assert!(store.parent().unwrap().is_dir(), "store dir is created for the first save");
        // A cache saved at the resolved path loads back from the store.
        let mut c = EvalCache::load(&store, "ctx");
        c.insert(7, &exact(0.25, 0.5));
        c.save().unwrap();
        let mut re = EvalCache::load(&EvalCache::store_path(&dir, "resnet_s"), "ctx");
        assert_eq!(re.lookup(7).unwrap(), exact(0.25, 0.5));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn save_skips_when_clean_and_is_deterministic() {
        let path = tmp("determ");
        let _ = std::fs::remove_file(&path);
        let mut c = EvalCache::load(&path, "ctx");
        c.insert(10, &exact(0.25, 0.5));
        c.insert(2, &exact(0.75, 0.25));
        c.save().unwrap();
        let first = std::fs::read_to_string(&path).unwrap();
        // Re-inserting identical entries keeps the cache clean.
        c.insert(10, &exact(0.25, 0.5));
        c.save().unwrap();
        let second = std::fs::read_to_string(&path).unwrap();
        assert_eq!(first, second);
        let _ = std::fs::remove_file(&path);
    }
}
