//! Cross-run persistent evaluation cache.
//!
//! Report, ablation and search runs over the same model repeatedly evaluate
//! the same configurations (uniform baselines, search prefixes, frontier
//! candidates). The in-memory memo inside [`super::Pipeline`] only lives for
//! one process; this cache persists **exact** (full-validation) results to a
//! JSON file under the artifacts directory so later runs skip the device
//! entirely.
//!
//! Entries are keyed by [`crate::quant::QuantConfig::key`] and guarded by a
//! caller-supplied *context fingerprint* — everything an evaluation result
//! depends on besides the configuration (model name, scales, dataset). A
//! file whose fingerprint does not match is discarded wholesale rather than
//! risking stale hits. Only exact results are stored: they answer any
//! future target decisively, so the cache never changes a search decision —
//! it only removes device work.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::Context as _;

use crate::util::json::{self, Value};
use crate::Result;

use super::EvalResult;

/// Schema version of the on-disk format.
pub const EVAL_CACHE_VERSION: u64 = 1;

/// One `{key, loss, accuracy}` row of the on-disk entry array.
fn parse_row(row: &Value) -> Result<(u64, f64, f64)> {
    let key = u64::from_str_radix(row.req("key")?.as_str()?, 16).context("bad cache key")?;
    Ok((key, row.req("loss")?.as_f64()?, row.req("accuracy")?.as_f64()?))
}

/// A persistent config-key -> exact-[`EvalResult`] map.
#[derive(Debug)]
pub struct EvalCache {
    path: PathBuf,
    context: String,
    entries: HashMap<u64, (f64, f64)>, // key -> (loss, accuracy)
    hits: usize,
    dirty: bool,
}

impl EvalCache {
    /// Open the cache at `path` for the given context fingerprint. A
    /// missing, unreadable, corrupt or context-mismatched file yields an
    /// empty cache (never an error — the cache is an optimization).
    pub fn load(path: &Path, context: &str) -> Self {
        let mut cache = Self {
            path: path.to_path_buf(),
            context: context.to_string(),
            entries: HashMap::new(),
            hits: 0,
            dirty: false,
        };
        let Ok(text) = std::fs::read_to_string(path) else {
            return cache;
        };
        let Ok(v) = json::parse(&text) else {
            return cache;
        };
        let version_ok = v.get("version").map(|x| x.as_u64().ok() == Some(EVAL_CACHE_VERSION));
        let context_ok = v.get("context").map(|x| x.as_str().ok() == Some(context));
        if version_ok != Some(true) || context_ok != Some(true) {
            return cache;
        }
        let Some(Ok(rows)) = v.get("entries").map(|e| e.as_arr()) else {
            return cache;
        };
        for row in rows {
            if let Ok((key, loss, acc)) = parse_row(row) {
                cache.entries.insert(key, (loss, acc));
            }
        }
        cache
    }

    /// Look up a configuration key; exact results satisfy any target.
    pub fn lookup(&mut self, key: u64) -> Option<EvalResult> {
        let &(loss, accuracy) = self.entries.get(&key)?;
        self.hits += 1;
        Some(EvalResult { loss, accuracy, exact: true })
    }

    /// Record a result. Inexact (early-exited) results are ignored — their
    /// bounds are only valid for the target they were produced under.
    pub fn insert(&mut self, key: u64, result: &EvalResult) {
        if !result.exact {
            return;
        }
        let entry = (result.loss, result.accuracy);
        if self.entries.insert(key, entry) != Some(entry) {
            self.dirty = true;
        }
    }

    /// Number of stored results.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Lookups answered from this cache since load.
    pub fn hits(&self) -> usize {
        self.hits
    }

    /// The context fingerprint this cache is bound to.
    pub fn context(&self) -> &str {
        &self.context
    }

    /// Write back if anything changed. Keys are emitted in sorted order so
    /// the file is deterministic for a given entry set. The write goes to
    /// a temp file in the same directory followed by an atomic rename, so
    /// a crash mid-write leaves either the old file or the new one —
    /// never a truncated cache that poisons every later run.
    pub fn save(&mut self) -> Result<()> {
        if !self.dirty {
            return Ok(());
        }
        let mut keys: Vec<u64> = self.entries.keys().copied().collect();
        keys.sort_unstable();
        let rows: Vec<Value> = keys
            .into_iter()
            .map(|k| {
                let (loss, acc) = self.entries[&k];
                Value::obj(vec![
                    ("key", Value::Str(format!("{k:016x}"))),
                    ("loss", Value::Num(loss)),
                    ("accuracy", Value::Num(acc)),
                ])
            })
            .collect();
        let v = Value::obj(vec![
            ("version", Value::Num(EVAL_CACHE_VERSION as f64)),
            ("context", Value::Str(self.context.clone())),
            ("entries", Value::Arr(rows)),
        ]);
        let file_name = self
            .path
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_else(|| "evalcache".to_string());
        let tmp = self.path.with_file_name(format!(".{file_name}.tmp.{}", std::process::id()));
        std::fs::write(&tmp, v.to_string())
            .with_context(|| format!("writing eval cache temp {}", tmp.display()))?;
        if let Err(e) = std::fs::rename(&tmp, &self.path) {
            let _ = std::fs::remove_file(&tmp);
            return Err(anyhow::Error::new(e)
                .context(format!("committing eval cache {}", self.path.display())));
        }
        self.dirty = false;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("mpq_evalcache_{name}.json"))
    }

    fn exact(loss: f64, acc: f64) -> EvalResult {
        EvalResult { loss, accuracy: acc, exact: true }
    }

    #[test]
    fn miss_insert_hit() {
        let path = tmp("mih");
        let _ = std::fs::remove_file(&path);
        let mut c = EvalCache::load(&path, "ctx");
        assert!(c.lookup(42).is_none());
        c.insert(42, &exact(0.5, 0.9));
        let hit = c.lookup(42).unwrap();
        assert_eq!(hit, exact(0.5, 0.9));
        assert_eq!(c.hits(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn inexact_results_not_stored() {
        let path = tmp("inexact");
        let _ = std::fs::remove_file(&path);
        let mut c = EvalCache::load(&path, "ctx");
        c.insert(7, &EvalResult { loss: 0.1, accuracy: 0.8, exact: false });
        assert!(c.lookup(7).is_none());
        assert!(c.is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn persistence_roundtrip_and_context_guard() {
        let path = tmp("roundtrip");
        let _ = std::fs::remove_file(&path);
        let mut c = EvalCache::load(&path, "model-a/scales-1");
        c.insert(u64::MAX, &exact(1.25, 0.75));
        c.insert(3, &exact(0.0, 1.0));
        c.save().unwrap();

        let mut re = EvalCache::load(&path, "model-a/scales-1");
        assert_eq!(re.len(), 2);
        assert_eq!(re.lookup(u64::MAX).unwrap(), exact(1.25, 0.75));
        assert_eq!(re.lookup(3).unwrap(), exact(0.0, 1.0));

        // A different context must not see the entries.
        let other = EvalCache::load(&path, "model-a/scales-2");
        assert!(other.is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn truncated_file_loads_as_empty_and_no_temp_left_behind() {
        let path = tmp("truncated");
        let _ = std::fs::remove_file(&path);
        let mut c = EvalCache::load(&path, "ctx");
        c.insert(1, &exact(0.5, 0.9));
        c.insert(2, &exact(0.25, 0.95));
        c.save().unwrap();
        // Simulate a crash mid-write of the *old* non-atomic path: chop
        // the file in half. The loader must degrade to empty, not error.
        let full = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        let re = EvalCache::load(&path, "ctx");
        assert!(re.is_empty());
        // The atomic save leaves no temp droppings next to the cache.
        let dir = path.parent().unwrap();
        let leftovers = std::fs::read_dir(dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| {
                let n = e.file_name().to_string_lossy().into_owned();
                n.contains("mpq_evalcache_truncated") && n.contains(".tmp.")
            })
            .count();
        assert_eq!(leftovers, 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_file_degrades_to_empty() {
        let path = tmp("corrupt");
        std::fs::write(&path, "{not json").unwrap();
        let c = EvalCache::load(&path, "ctx");
        assert!(c.is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn save_skips_when_clean_and_is_deterministic() {
        let path = tmp("determ");
        let _ = std::fs::remove_file(&path);
        let mut c = EvalCache::load(&path, "ctx");
        c.insert(10, &exact(0.25, 0.5));
        c.insert(2, &exact(0.75, 0.25));
        c.save().unwrap();
        let first = std::fs::read_to_string(&path).unwrap();
        // Re-inserting identical entries keeps the cache clean.
        c.insert(10, &exact(0.25, 0.5));
        c.save().unwrap();
        let second = std::fs::read_to_string(&path).unwrap();
        assert_eq!(first, second);
        let _ = std::fs::remove_file(&path);
    }
}
