//! Lock-striped evaluation memo and deferred persistent-cache writes —
//! the contention-free shared-cache primitives behind
//! [`super::PipelinePool`].
//!
//! The pool's original memo was one `Mutex<HashMap>`: every worker of an
//! 8-way pool serialized on a single lock, and a hit could take up to
//! three acquisitions (memo, persistent cache, memo re-insert).
//! [`StripedMemo`] splits the map into [`STRIPES`] shards keyed by the
//! config hash, so a hit takes exactly **one** mutex acquisition — of a
//! stripe only same-hash keys contend on — and [`PendingWrites`] moves
//! persistent [`super::EvalCache`] updates off the eval hot path entirely:
//! publishes append to a tiny buffer, and an interval flusher (owned by
//! the pool) drains them into the cache and persists dirty state in the
//! background. Crash semantics are unchanged — the cache file is still
//! written via atomic rename, and detach/shutdown flush synchronously.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use super::EvalResult;

/// Stripe count; a power of two so the stripe index is a mask of the key.
pub const STRIPES: usize = 16;

/// A lock-striped `config key -> EvalResult` memo.
///
/// The single-acquisition hit path is a tested contract
/// (`hit_takes_exactly_one_lock_acquisition`): [`StripedMemo::lookup`]
/// locks the one stripe owning the key and nothing else.
#[derive(Debug)]
pub struct StripedMemo {
    stripes: Vec<Mutex<HashMap<u64, EvalResult>>>,
    hits: AtomicUsize,
    /// Total stripe-mutex acquisitions — diagnostics only, but it is what
    /// pins the one-acquisition hit path in tests.
    acquisitions: AtomicUsize,
}

impl Default for StripedMemo {
    fn default() -> Self {
        Self::new()
    }
}

impl StripedMemo {
    pub fn new() -> Self {
        Self {
            stripes: (0..STRIPES).map(|_| Mutex::new(HashMap::new())).collect(),
            hits: AtomicUsize::new(0),
            acquisitions: AtomicUsize::new(0),
        }
    }

    /// The stripe owning `key`, counting the acquisition the caller is
    /// about to perform.
    fn stripe(&self, key: u64) -> &Mutex<HashMap<u64, EvalResult>> {
        self.acquisitions.fetch_add(1, Ordering::Relaxed);
        &self.stripes[(key as usize) & (STRIPES - 1)]
    }

    /// One stripe lock; counts a memo hit when the key is present.
    pub fn lookup(&self, key: u64) -> Option<EvalResult> {
        let hit = self.stripe(key).lock().unwrap().get(&key).copied();
        if hit.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// One stripe lock; last write wins (results for a key are identical).
    pub fn insert(&self, key: u64, result: EvalResult) {
        self.stripe(key).lock().unwrap().insert(key, result);
    }

    /// Drop every entry (scale changes invalidate all results).
    pub fn clear(&self) {
        for s in &self.stripes {
            self.acquisitions.fetch_add(1, Ordering::Relaxed);
            s.lock().unwrap().clear();
        }
    }

    /// Lookups answered from the memo.
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Stripe-mutex acquisitions performed so far.
    pub fn lock_acquisitions(&self) -> usize {
        self.acquisitions.load(Ordering::Relaxed)
    }
}

/// Deferred persistent-cache writes: the publish path appends under a
/// short dedicated lock instead of updating the [`super::EvalCache`] (and
/// contending with every reader of its mutex); the owner drains in the
/// background or at flush points.
#[derive(Debug, Default)]
pub struct PendingWrites {
    buf: Mutex<Vec<(u64, EvalResult)>>,
}

impl PendingWrites {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&self, key: u64, result: EvalResult) {
        self.buf.lock().unwrap().push((key, result));
    }

    /// Take everything queued so far (oldest first).
    pub fn drain(&self) -> Vec<(u64, EvalResult)> {
        std::mem::take(&mut *self.buf.lock().unwrap())
    }

    pub fn is_empty(&self) -> bool {
        self.buf.lock().unwrap().is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn res(accuracy: f64) -> EvalResult {
        EvalResult { loss: 1.0 - accuracy, accuracy, exact: true }
    }

    #[test]
    fn hit_takes_exactly_one_lock_acquisition() {
        let memo = StripedMemo::new();
        memo.insert(7, res(0.9));
        let before = memo.lock_acquisitions();
        for _ in 0..10 {
            assert_eq!(memo.lookup(7).unwrap().accuracy, 0.9);
        }
        // The hit path is ONE stripe acquisition per lookup — no second
        // map, no re-insert. This pins the triple-lock fix.
        assert_eq!(memo.lock_acquisitions() - before, 10);
        assert_eq!(memo.hits(), 10);
    }

    #[test]
    fn miss_is_also_single_acquisition_and_uncounted() {
        let memo = StripedMemo::new();
        let before = memo.lock_acquisitions();
        assert!(memo.lookup(42).is_none());
        assert_eq!(memo.lock_acquisitions() - before, 1);
        assert_eq!(memo.hits(), 0);
    }

    #[test]
    fn keys_spread_over_stripes_and_clear_empties_all() {
        let memo = StripedMemo::new();
        for k in 0..(STRIPES as u64 * 4) {
            memo.insert(k, res(0.5));
        }
        for k in 0..(STRIPES as u64 * 4) {
            assert!(memo.lookup(k).is_some(), "key {k} lost");
        }
        memo.clear();
        for k in 0..(STRIPES as u64 * 4) {
            assert!(memo.lookup(k).is_none(), "key {k} survived clear");
        }
    }

    #[test]
    fn concurrent_hammering_is_consistent() {
        let memo = std::sync::Arc::new(StripedMemo::new());
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let memo = memo.clone();
                s.spawn(move || {
                    for i in 0..500u64 {
                        let key = t * 10_000 + i;
                        memo.insert(key, res(0.25));
                        assert_eq!(memo.lookup(key).unwrap().accuracy, 0.25);
                    }
                });
            }
        });
        assert_eq!(memo.hits(), 8 * 500);
    }

    #[test]
    fn pending_writes_drain_in_order() {
        let pending = PendingWrites::new();
        assert!(pending.is_empty());
        pending.push(1, res(0.1));
        pending.push(2, res(0.2));
        let drained = pending.drain();
        assert_eq!(drained.iter().map(|(k, _)| *k).collect::<Vec<_>>(), vec![1, 2]);
        assert!(pending.is_empty() && pending.drain().is_empty());
    }
}
