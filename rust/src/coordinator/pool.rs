//! A worker pool of device pipelines for parallel candidate evaluation.
//!
//! PJRT handles are not `Send`, so — exactly like [`crate::server`] — each
//! worker thread constructs and owns its *own* [`Pipeline`] (engine,
//! compiled graphs, device-resident state). Candidate configurations from
//! [`SearchEnv::eval_many`] are scattered round-robin across the workers
//! and gathered slot-indexed, so result order (and every search decision
//! replayed from it) is independent of scheduling.
//!
//! The workers share two interior-mutability-safe caches:
//!
//! * a memo map (`Mutex<HashMap>`) of exact results, so no configuration is
//!   evaluated twice anywhere in the pool, and
//! * an optional persistent [`EvalCache`], giving cross-run reuse identical
//!   to a single pipeline's (see [`PipelinePool::attach_eval_cache`]).
//!
//! Only *exact* results enter the shared maps — they answer any accuracy
//! target decisively, so sharing never changes a decision. Memory cost is
//! one full device pipeline per worker; worth it when candidate evaluation
//! dominates search wall-clock (every model in this repo).
//!
//! Beyond candidate evaluation, the pool is a [`StageRunner`]: sharded
//! calibration, Hessian-trace, and ε_N noise jobs ([`WorkerJob::ActStats`],
//! [`WorkerJob::AdjustGrads`], [`WorkerJob::Hvp`],
//! [`WorkerJob::NoisePerturb`]) scatter over the same worker pipelines and
//! gather in shard order, with scale updates pushed to every worker via
//! [`WorkerJob::SetScales`] — see [`super::shard`] for the drivers and the
//! determinism guarantee.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{mpsc, Arc, Mutex};

use anyhow::{anyhow, Context as _};

use crate::quant::calibrate::{self, BatchGrad, NoiseSample, TraceSample};
use crate::quant::{QuantConfig, Scales};
use crate::Result;

use super::shard::StageRunner;
use super::{EvalCache, EvalResult, Pipeline, SearchEnv};

/// Shared state all workers consult before touching their device.
struct SharedCache {
    /// Exact results by configuration key.
    memo: Mutex<HashMap<u64, EvalResult>>,
    /// Optional cross-run cache (exact results only, context-guarded).
    persistent: Mutex<Option<EvalCache>>,
    /// Lookups answered by the shared memo (persistent hits are counted
    /// by the [`EvalCache`] itself).
    memo_hits: std::sync::atomic::AtomicUsize,
}

impl SharedCache {
    fn lookup(&self, key: u64) -> Option<EvalResult> {
        if let Some(hit) = self.memo.lock().unwrap().get(&key).copied() {
            self.memo_hits.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            return Some(hit);
        }
        let mut guard = self.persistent.lock().unwrap();
        let hit = guard.as_mut().and_then(|c| c.lookup(key))?;
        self.memo.lock().unwrap().insert(key, hit);
        Some(hit)
    }

    fn publish(&self, key: u64, result: &EvalResult) {
        if !result.exact {
            return;
        }
        self.memo.lock().unwrap().insert(key, *result);
        if let Some(cache) = self.persistent.lock().unwrap().as_mut() {
            cache.insert(key, result);
        }
    }
}

struct Job {
    cfg: QuantConfig,
    target: Option<f64>,
    slot: usize,
    resp: mpsc::Sender<(usize, Result<EvalResult>)>,
}

/// What a worker thread can be asked to do with its pipeline.
enum WorkerJob {
    /// Evaluate a candidate configuration (search path).
    Eval(Job),
    /// Run an arbitrary task against the worker's pipeline — the serving
    /// engine submits formed batches this way. Called with `None` if the
    /// worker is gone, so the task can answer its callers with an error.
    Task(Box<dyn FnOnce(Option<&mut Pipeline>) + Send>),
    /// Sharded-calibration stage: per-layer max|activation| over the
    /// listed adjustment batches ([`Pipeline::act_stats_shard`]).
    ActStats { batches: Vec<usize>, resp: mpsc::Sender<Result<Vec<f32>>> },
    /// Sharded-calibration stage: per-batch scale gradients at fixed
    /// scales ([`Pipeline::adjust_grads_shard`]).
    AdjustGrads {
        scales: Scales,
        bits: f32,
        batches: Vec<usize>,
        resp: mpsc::Sender<Result<Vec<BatchGrad>>>,
    },
    /// Sharded-sensitivity stage: per-trial Hutchinson probes
    /// ([`Pipeline::hvp_shard`]).
    Hvp { seed: u64, trials: Vec<usize>, resp: mpsc::Sender<Result<Vec<TraceSample>>> },
    /// Sharded-sensitivity stage: ε_N perturbation trials for the listed
    /// flattened (layer, trial) items ([`Pipeline::noise_shard`]).
    NoisePerturb {
        lambda: f64,
        trials: usize,
        seed: u64,
        items: Vec<usize>,
        resp: mpsc::Sender<Result<Vec<NoiseSample>>>,
    },
    /// ε_N baseline: float calibration loss of the unperturbed model
    /// ([`Pipeline::calib_loss_float`]; identical on every worker).
    CleanLoss { resp: mpsc::Sender<Result<f64>> },
    /// Install updated scales on the worker's pipeline (broadcast between
    /// Adam steps and after calibration).
    SetScales { scales: Scales, resp: mpsc::Sender<Result<()>> },
    /// Step-1 weight scales from the worker's (identical) parameters.
    WeightScales { resp: mpsc::Sender<Result<Scales>> },
}

struct Worker {
    tx: mpsc::Sender<WorkerJob>,
    join: Option<std::thread::JoinHandle<()>>,
}

/// Facts gathered from each worker pipeline at construction (identical on
/// every worker — same artifacts).
struct WorkerInfo {
    num_layers: usize,
    batch_sizes: Vec<usize>,
    adjust_batches: usize,
    weight_numels: Vec<u64>,
}

/// A pool of `workers` device pipelines implementing [`SearchEnv`] with
/// genuinely parallel `eval_many`.
pub struct PipelinePool {
    workers: Vec<Worker>,
    shared: Arc<SharedCache>,
    num_layers: usize,
    /// Compiled serving batch sizes, ascending (identical on every
    /// worker — same artifacts), gathered at construction.
    batch_sizes: Vec<usize>,
    /// Adjustment-split batch count (shard domain for calibration).
    adjust_batches: usize,
    /// Per-quant-layer weight element counts (trace normalization).
    weight_numels: Vec<u64>,
    /// Evaluations dispatched to workers (shared-cache hits excluded).
    /// Atomic so concurrent segment drivers can submit through `&self`.
    dispatched: std::sync::atomic::AtomicUsize,
}

impl PipelinePool {
    /// Build `workers` pipelines for `model`, running `configure` on each
    /// freshly constructed pipeline (scale loading / calibration) before it
    /// starts serving. Construction fails if any worker fails to build.
    pub fn new(
        artifacts_dir: &Path,
        model: &str,
        workers: usize,
        configure: impl Fn(&mut Pipeline) -> Result<()> + Send + Sync + 'static,
    ) -> Result<Self> {
        let workers = workers.max(1);
        let shared = Arc::new(SharedCache {
            memo: Mutex::new(HashMap::new()),
            persistent: Mutex::new(None),
            memo_hits: std::sync::atomic::AtomicUsize::new(0),
        });
        let configure: Arc<dyn Fn(&mut Pipeline) -> Result<()> + Send + Sync> = Arc::new(configure);
        // Spawn every worker before waiting on any readiness signal, so the
        // expensive per-worker construction (graph compilation, scale
        // loading) runs concurrently rather than serially.
        let mut built = Vec::with_capacity(workers);
        let mut readies = Vec::with_capacity(workers);
        for wi in 0..workers {
            let (tx, rx) = mpsc::channel::<WorkerJob>();
            let (ready_tx, ready_rx) = mpsc::channel::<Result<WorkerInfo>>();
            let dir: PathBuf = artifacts_dir.to_path_buf();
            let model = model.to_string();
            let shared = shared.clone();
            let configure = configure.clone();
            let join = std::thread::spawn(move || {
                let mut pipeline = match Pipeline::new(&dir, &model) {
                    Ok(p) => p,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e.context(format!("pool worker {wi}"))));
                        return;
                    }
                };
                if let Err(e) = configure(&mut pipeline) {
                    let _ = ready_tx.send(Err(e.context(format!("configuring pool worker {wi}"))));
                    return;
                }
                let info = WorkerInfo {
                    num_layers: pipeline.num_quant_layers(),
                    batch_sizes: pipeline.logits_batch_sizes(),
                    adjust_batches: pipeline.num_adjust_batches(),
                    weight_numels: pipeline.weight_numels(),
                };
                let _ = ready_tx.send(Ok(info));
                worker_loop(&mut pipeline, &shared, &rx);
            });
            built.push(Worker { tx, join: Some(join) });
            readies.push((wi, ready_rx));
        }
        let mut info: Option<WorkerInfo> = None;
        for (wi, ready_rx) in readies {
            info = Some(
                ready_rx
                    .recv()
                    .map_err(|_| anyhow!("pool worker {wi} died during construction"))?
                    .with_context(|| format!("building pipeline pool for {model}"))?,
            );
        }
        let info = info.expect("workers >= 1");
        Ok(Self {
            workers: built,
            shared,
            num_layers: info.num_layers,
            batch_sizes: info.batch_sizes,
            adjust_batches: info.adjust_batches,
            weight_numels: info.weight_numels,
            dispatched: std::sync::atomic::AtomicUsize::new(0),
        })
    }

    /// Number of worker pipelines in the pool.
    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    /// Compiled serving batch sizes (ascending), as reported by the
    /// workers' artifacts at construction.
    pub fn logits_batch_sizes(&self) -> Vec<usize> {
        self.batch_sizes.clone()
    }

    /// Submit an arbitrary task to worker `worker % num_workers()`'s
    /// thread; it runs with exclusive access to that worker's pipeline,
    /// after any already-queued work. If the worker is gone, the task is
    /// invoked inline with `None` so it can report the failure itself.
    /// Returns whether the worker accepted the task.
    pub fn run_on(
        &self,
        worker: usize,
        task: impl FnOnce(Option<&mut Pipeline>) + Send + 'static,
    ) -> bool {
        let w = &self.workers[worker % self.workers.len()];
        match w.tx.send(WorkerJob::Task(Box::new(task))) {
            Ok(()) => true,
            Err(mpsc::SendError(job)) => {
                if let WorkerJob::Task(t) = job {
                    t(None);
                }
                false
            }
        }
    }

    /// Attach a persistent cross-run cache shared by all workers, with an
    /// optional entry bound (LRU eviction). The context fingerprint must
    /// come from one of the (identically configured) worker pipelines; use
    /// [`Pipeline::eval_context`] on a scratch pipeline, or pass any
    /// stable string covering model + scales.
    pub fn attach_eval_cache(&self, path: &Path, context: &str, capacity: Option<usize>) {
        *self.shared.persistent.lock().unwrap() =
            Some(EvalCache::with_capacity(path, context, capacity));
    }

    /// Persist the shared cache, if attached.
    pub fn flush_eval_cache(&self) -> Result<()> {
        match self.shared.persistent.lock().unwrap().as_mut() {
            Some(cache) => cache.save(),
            None => Ok(()),
        }
    }

    /// Entries currently in the shared persistent cache (0 if detached).
    pub fn eval_cache_len(&self) -> usize {
        self.shared.persistent.lock().unwrap().as_ref().map_or(0, EvalCache::len)
    }

    /// Scatter one calibration/sensitivity stage over the workers —
    /// `make(shard, resp)` builds the [`WorkerJob`] for each shard, shard
    /// `i` goes to worker `i` — and gather the per-shard results in shard
    /// (worker-index) order.
    fn scatter_stage<T: Send + 'static>(
        &self,
        what: &str,
        shards: &[Vec<usize>],
        make: impl Fn(Vec<usize>, mpsc::Sender<Result<T>>) -> WorkerJob,
    ) -> Result<Vec<T>> {
        let mut rxs = Vec::with_capacity(shards.len());
        for (i, shard) in shards.iter().enumerate() {
            let wi = i % self.workers.len();
            let (tx, rx) = mpsc::channel();
            self.workers[wi]
                .tx
                .send(make(shard.clone(), tx))
                .map_err(|_| anyhow!("pool worker {wi} exited during {what}"))?;
            rxs.push(rx);
        }
        rxs.into_iter()
            .enumerate()
            .map(|(i, rx)| {
                rx.recv()
                    .map_err(|_| anyhow!("pool worker died during {what} (shard {i})"))?
                    .with_context(|| format!("{what} shard {i}"))
            })
            .collect()
    }

    /// Evaluations that actually reached a worker (cache misses).
    pub fn dispatched(&self) -> usize {
        self.dispatched.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Lookups answered without touching a device:
    /// `(shared memo hits, persistent cross-run cache hits)`.
    pub fn cache_hits(&self) -> (usize, usize) {
        let memo = self.shared.memo_hits.load(std::sync::atomic::Ordering::Relaxed);
        let persistent =
            self.shared.persistent.lock().unwrap().as_ref().map_or(0, EvalCache::hits);
        (memo, persistent)
    }

    /// Evaluate a batch on one specific worker pipeline
    /// (`worker % num_workers()`) instead of scattering slots round-robin.
    /// The partitioned driver pins each segment to its own worker this
    /// way, so segments proceed concurrently without interleaving on a
    /// single pipeline. Shared-cache hits still short-circuit, and exact
    /// hits are target-independent, so affinity never changes a decision.
    pub fn eval_on(
        &self,
        worker: usize,
        cfgs: &[QuantConfig],
        target: Option<f64>,
    ) -> Vec<Result<EvalResult>> {
        self.submit_inner(cfgs, target, Some(worker))
    }

    fn submit(&self, cfgs: &[QuantConfig], target: Option<f64>) -> Vec<Result<EvalResult>> {
        self.submit_inner(cfgs, target, None)
    }

    fn submit_inner(
        &self,
        cfgs: &[QuantConfig],
        target: Option<f64>,
        affinity: Option<usize>,
    ) -> Vec<Result<EvalResult>> {
        let mut slots: Vec<Option<Result<EvalResult>>> = Vec::new();
        slots.resize_with(cfgs.len(), || None);
        let (resp_tx, resp_rx) = mpsc::channel();
        let mut outstanding = 0usize;
        for (slot, cfg) in cfgs.iter().enumerate() {
            // Shared-cache hits short-circuit without touching a worker.
            // Exact hits are target-independent, so this never changes a
            // decision relative to a fresh evaluation.
            if let Some(hit) = self.shared.lookup(cfg.key()) {
                slots[slot] = Some(Ok(hit));
                continue;
            }
            let worker = &self.workers[affinity.unwrap_or(slot) % self.workers.len()];
            let job = Job { cfg: cfg.clone(), target, slot, resp: resp_tx.clone() };
            if worker.tx.send(WorkerJob::Eval(job)).is_err() {
                slots[slot] = Some(Err(anyhow!("pool worker exited")));
                continue;
            }
            self.dispatched.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            outstanding += 1;
        }
        drop(resp_tx);
        for _ in 0..outstanding {
            match resp_rx.recv() {
                Ok((slot, result)) => slots[slot] = Some(result),
                Err(_) => break,
            }
        }
        slots
            .into_iter()
            .map(|o| o.unwrap_or_else(|| Err(anyhow!("pool worker dropped a job"))))
            .collect()
    }
}

fn worker_loop(pipeline: &mut Pipeline, shared: &SharedCache, rx: &mpsc::Receiver<WorkerJob>) {
    while let Ok(job) = rx.recv() {
        match job {
            WorkerJob::Eval(job) => {
                let key = job.cfg.key();
                let result = match shared.lookup(key) {
                    Some(hit) => Ok(hit),
                    None => {
                        let r = pipeline.eval_config(&job.cfg, job.target);
                        if let Ok(res) = &r {
                            shared.publish(key, res);
                        }
                        r
                    }
                };
                let _ = job.resp.send((job.slot, result));
            }
            WorkerJob::Task(task) => task(Some(pipeline)),
            WorkerJob::ActStats { batches, resp } => {
                let _ = resp.send(pipeline.act_stats_shard(&batches));
            }
            WorkerJob::AdjustGrads { scales, bits, batches, resp } => {
                let _ = resp.send(pipeline.adjust_grads_shard(&scales, bits, &batches));
            }
            WorkerJob::Hvp { seed, trials, resp } => {
                let _ = resp.send(pipeline.hvp_shard(seed, &trials));
            }
            WorkerJob::NoisePerturb { lambda, trials, seed, items, resp } => {
                let _ = resp.send(pipeline.noise_shard(lambda, trials, seed, &items));
            }
            WorkerJob::CleanLoss { resp } => {
                let _ = resp.send(pipeline.calib_loss_float());
            }
            WorkerJob::SetScales { scales, resp } => {
                pipeline.scales = scales;
                let _ = resp.send(pipeline.sync_scales());
            }
            WorkerJob::WeightScales { resp } => {
                let _ = resp.send(calibrate::weight_scales(
                    &pipeline.artifacts.manifest,
                    &pipeline.artifacts.params,
                ));
            }
        }
    }
}

/// The multi-worker stage backend: shards run concurrently, one per
/// worker pipeline, gathered in shard order. Combined with the
/// fixed-order host reducers this is bit-identical to the one-worker
/// [`Pipeline`] backend at every pool size.
impl StageRunner for PipelinePool {
    fn shard_workers(&self) -> usize {
        self.workers.len()
    }

    fn shard_layers(&self) -> usize {
        self.num_layers
    }

    fn adjust_batches(&self) -> usize {
        self.adjust_batches
    }

    fn weight_numels(&self) -> Vec<u64> {
        self.weight_numels.clone()
    }

    fn stage_weight_scales(&mut self) -> Result<Scales> {
        let (tx, rx) = mpsc::channel();
        self.workers[0]
            .tx
            .send(WorkerJob::WeightScales { resp: tx })
            .map_err(|_| anyhow!("pool worker 0 exited during weight calibration"))?;
        rx.recv().map_err(|_| anyhow!("pool worker 0 died during weight calibration"))?
    }

    fn stage_act_stats(&mut self, shards: &[Vec<usize>]) -> Result<Vec<Vec<f32>>> {
        self.scatter_stage("act stats", shards, |batches, resp| WorkerJob::ActStats {
            batches,
            resp,
        })
    }

    fn stage_adjust_grads(
        &mut self,
        scales: &Scales,
        bits: f32,
        shards: &[Vec<usize>],
    ) -> Result<Vec<Vec<BatchGrad>>> {
        self.scatter_stage("scale adjustment", shards, |batches, resp| {
            WorkerJob::AdjustGrads { scales: scales.clone(), bits, batches, resp }
        })
    }

    fn stage_hvp(&mut self, seed: u64, shards: &[Vec<usize>]) -> Result<Vec<Vec<TraceSample>>> {
        self.scatter_stage("hessian probes", shards, |trials, resp| WorkerJob::Hvp {
            seed,
            trials,
            resp,
        })
    }

    fn stage_clean_loss(&mut self) -> Result<f64> {
        // Identical on every worker (same parameters and splits); run on 0.
        let (tx, rx) = mpsc::channel();
        self.workers[0]
            .tx
            .send(WorkerJob::CleanLoss { resp: tx })
            .map_err(|_| anyhow!("pool worker 0 exited during noise baseline"))?;
        rx.recv().map_err(|_| anyhow!("pool worker 0 died during noise baseline"))?
    }

    fn stage_noise(
        &mut self,
        lambda: f64,
        trials: usize,
        seed: u64,
        shards: &[Vec<usize>],
    ) -> Result<Vec<Vec<NoiseSample>>> {
        self.scatter_stage("noise perturbations", shards, |items, resp| {
            WorkerJob::NoisePerturb { lambda, trials, seed, items, resp }
        })
    }

    fn broadcast_scales(&mut self, scales: &Scales) -> Result<()> {
        // Results depend on scales: invalidate the shared caches exactly
        // like [`Pipeline::sync_scales`] invalidates its per-pipeline
        // ones — the memo is cleared, a persistent cache (whose context
        // fingerprint no longer matches) is flushed and detached. The
        // owner re-attaches once the new scales are final
        // (`ModelContext` does so after calibration).
        self.shared.memo.lock().unwrap().clear();
        if let Some(mut cache) = self.shared.persistent.lock().unwrap().take() {
            let _ = cache.save();
        }
        let mut rxs = Vec::with_capacity(self.workers.len());
        for (wi, w) in self.workers.iter().enumerate() {
            let (tx, rx) = mpsc::channel();
            w.tx.send(WorkerJob::SetScales { scales: scales.clone(), resp: tx })
                .map_err(|_| anyhow!("pool worker {wi} exited during scale broadcast"))?;
            rxs.push(rx);
        }
        for (wi, rx) in rxs.into_iter().enumerate() {
            rx.recv().map_err(|_| anyhow!("pool worker {wi} died during scale broadcast"))??;
        }
        Ok(())
    }
}

impl SearchEnv for PipelinePool {
    fn num_layers(&self) -> usize {
        self.num_layers
    }

    fn eval(&mut self, cfg: &QuantConfig, target: Option<f64>) -> Result<EvalResult> {
        self.submit(std::slice::from_ref(cfg), target).pop().expect("one result per config")
    }

    fn preferred_batch(&self) -> usize {
        self.workers.len()
    }

    fn eval_many(&mut self, cfgs: &[QuantConfig], target: Option<f64>) -> Vec<Result<EvalResult>> {
        self.submit(cfgs, target)
    }
}

impl Drop for PipelinePool {
    fn drop(&mut self) {
        let _ = self.flush_eval_cache();
        // Closing the job channels ends each worker loop; then reap.
        let workers: Vec<Worker> = self.workers.drain(..).collect();
        let mut joins = Vec::with_capacity(workers.len());
        for mut w in workers {
            joins.extend(w.join.take());
            drop(w); // drops the sender
        }
        for join in joins {
            let _ = join.join();
        }
    }
}
