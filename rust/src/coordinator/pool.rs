//! A worker pool of device pipelines for parallel candidate evaluation.
//!
//! PJRT handles are not `Send`, so — exactly like [`crate::server`] — each
//! worker thread constructs and owns its *own* [`Pipeline`] (engine,
//! compiled graphs, device-resident state). Candidate configurations from
//! [`SearchEnv::eval_many`] are scattered round-robin across the workers
//! and gathered slot-indexed, so result order (and every search decision
//! replayed from it) is independent of scheduling.
//!
//! The workers share two interior-mutability-safe caches:
//!
//! * a lock-striped memo ([`StripedMemo`]) of exact results, so no
//!   configuration is evaluated twice anywhere in the pool — a hit costs
//!   exactly one stripe-mutex acquisition, and distinct config hashes
//!   never contend, and
//! * an optional persistent [`EvalCache`], giving cross-run reuse identical
//!   to a single pipeline's (see [`PipelinePool::attach_eval_cache`]).
//!   Publishes never write it on the hot path: they queue on
//!   [`PendingWrites`] and a background interval flusher drains them into
//!   the cache and persists dirty state (atomic rename, exactly as
//!   before); detach and shutdown still flush synchronously, so crash
//!   semantics are unchanged.
//!
//! Only *exact* results enter the shared maps — they answer any accuracy
//! target decisively, so sharing never changes a decision. Memory cost is
//! one full device pipeline per worker; worth it when candidate evaluation
//! dominates search wall-clock (every model in this repo).
//!
//! Beyond candidate evaluation, the pool is a [`StageRunner`]: sharded
//! calibration, Hessian-trace, and ε_N noise jobs ([`WorkerJob::ActStats`],
//! [`WorkerJob::AdjustGrads`], [`WorkerJob::Hvp`],
//! [`WorkerJob::NoisePerturb`]) scatter over the same worker pipelines and
//! gather in shard order, with scale updates pushed to every worker via
//! [`WorkerJob::SetScales`] — see [`super::shard`] for the drivers and the
//! determinism guarantee.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Context as _};

use crate::quant::calibrate::{self, BatchGrad, NoiseSample, PairSample, TraceSample};
use crate::quant::{QuantConfig, Scales};
use crate::Result;

use super::memo::{PendingWrites, StripedMemo};
use super::shard::StageRunner;
use super::{EvalCache, EvalResult, Pipeline, SearchEnv};

/// How often the background flusher drains deferred writes into the
/// persistent cache and saves dirty state.
const EVAL_CACHE_FLUSH_INTERVAL: Duration = Duration::from_millis(200);

/// Shared state all workers consult before touching their device.
struct SharedCache {
    /// Exact results by configuration key — one stripe lock per hit.
    memo: StripedMemo,
    /// Publishes destined for the persistent cache, deferred off the eval
    /// hot path; drained by the interval flusher and at flush points.
    pending: PendingWrites,
    /// Optional cross-run cache (exact results only, context-guarded).
    persistent: Mutex<Option<EvalCache>>,
    /// Cheap hot-path gate: whether a persistent cache is attached (so
    /// publishes skip the pending queue entirely when there is none).
    attached: AtomicBool,
}

impl SharedCache {
    fn new() -> Self {
        Self {
            memo: StripedMemo::new(),
            pending: PendingWrites::new(),
            persistent: Mutex::new(None),
            attached: AtomicBool::new(false),
        }
    }

    fn lookup(&self, key: u64) -> Option<EvalResult> {
        // Hit path: exactly one stripe-mutex acquisition.
        if let Some(hit) = self.memo.lookup(key) {
            return Some(hit);
        }
        // Miss path: consult the persistent cache and seed the memo so
        // later lookups stay on the one-lock path.
        let hit = self.persistent.lock().unwrap().as_mut().and_then(|c| c.lookup(key))?;
        self.memo.insert(key, hit);
        Some(hit)
    }

    fn publish(&self, key: u64, result: &EvalResult) {
        if !result.exact {
            return;
        }
        self.memo.insert(key, *result);
        // The persistent write leaves the hot path: queue it for the
        // background flusher instead of taking the cache mutex here.
        if self.attached.load(Ordering::Relaxed) {
            self.pending.push(key, *result);
        }
    }

    /// Drain deferred writes into the attached cache and persist dirty
    /// state (the dirty flag makes clean saves free; writes go through
    /// the same atomic temp-file rename as always).
    fn flush(&self) -> Result<()> {
        let mut guard = self.persistent.lock().unwrap();
        let entries = self.pending.drain();
        match guard.as_mut() {
            Some(cache) => {
                for (k, r) in &entries {
                    cache.insert(*k, r);
                }
                cache.save()
            }
            None => Ok(()),
        }
    }

    /// Flush, then detach the cache — the scale-change/shutdown path.
    /// Deferred writes were computed under the scales the detaching
    /// cache's fingerprint covers, so they are committed to it first.
    fn detach(&self) {
        self.attached.store(false, Ordering::Relaxed);
        let mut guard = self.persistent.lock().unwrap();
        let entries = self.pending.drain();
        if let Some(mut cache) = guard.take() {
            for (k, r) in &entries {
                cache.insert(*k, r);
            }
            let _ = cache.save();
        }
    }
}

/// Background interval flusher for the shared persistent cache: wakes
/// every [`EVAL_CACHE_FLUSH_INTERVAL`], drains [`PendingWrites`] and
/// saves. Stopped (and joined) on detach, re-attach and pool drop —
/// always followed by a synchronous flush, so no deferred write outlives
/// the pool.
struct Flusher {
    stop: Arc<(Mutex<bool>, Condvar)>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Flusher {
    fn spawn(shared: Arc<SharedCache>) -> Self {
        let stop = Arc::new((Mutex::new(false), Condvar::new()));
        let signal = stop.clone();
        let join = std::thread::spawn(move || {
            let (lock, cvar) = &*signal;
            let mut stopped = lock.lock().unwrap();
            while !*stopped {
                let (guard, _) = cvar.wait_timeout(stopped, EVAL_CACHE_FLUSH_INTERVAL).unwrap();
                stopped = guard;
                if *stopped {
                    break;
                }
                drop(stopped);
                let _ = shared.flush();
                stopped = lock.lock().unwrap();
            }
        });
        Self { stop, join: Some(join) }
    }

    fn shutdown(&mut self) {
        *self.stop.0.lock().unwrap() = true;
        self.stop.1.notify_all();
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

impl Drop for Flusher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

struct Job {
    cfg: QuantConfig,
    target: Option<f64>,
    slot: usize,
    resp: mpsc::Sender<(usize, Result<EvalResult>)>,
}

/// What a worker thread can be asked to do with its pipeline.
enum WorkerJob {
    /// Evaluate a candidate configuration (search path).
    Eval(Job),
    /// Run an arbitrary task against the worker's pipeline — the serving
    /// engine submits formed batches this way. Called with `None` if the
    /// worker is gone, so the task can answer its callers with an error.
    Task(Box<dyn FnOnce(Option<&mut Pipeline>) + Send>),
    /// Sharded-calibration stage: per-layer max|activation| over the
    /// listed adjustment batches ([`Pipeline::act_stats_shard`]).
    ActStats { batches: Vec<usize>, resp: mpsc::Sender<Result<Vec<f32>>> },
    /// Sharded-calibration stage: per-batch scale gradients at fixed
    /// scales ([`Pipeline::adjust_grads_shard`]).
    AdjustGrads {
        scales: Scales,
        bits: f32,
        batches: Vec<usize>,
        resp: mpsc::Sender<Result<Vec<BatchGrad>>>,
    },
    /// Sharded-sensitivity stage: per-trial Hutchinson probes
    /// ([`Pipeline::hvp_shard`]).
    Hvp { seed: u64, trials: Vec<usize>, resp: mpsc::Sender<Result<Vec<TraceSample>>> },
    /// Sharded-sensitivity stage: ε_N perturbation trials for the listed
    /// flattened (layer, trial) items ([`Pipeline::noise_shard`]).
    NoisePerturb {
        lambda: f64,
        trials: usize,
        seed: u64,
        items: Vec<usize>,
        resp: mpsc::Sender<Result<Vec<NoiseSample>>>,
    },
    /// Sharded-sensitivity stage: inter-layer paired-perturbation trials
    /// for the listed flattened pair-major (pair, trial) items
    /// ([`Pipeline::pair_shard`]).
    PairPerturb {
        lambda: f64,
        trials: usize,
        seed: u64,
        items: Vec<usize>,
        resp: mpsc::Sender<Result<Vec<PairSample>>>,
    },
    /// ε_N baseline: float calibration loss of the unperturbed model
    /// ([`Pipeline::calib_loss_float`]; identical on every worker).
    CleanLoss { resp: mpsc::Sender<Result<f64>> },
    /// Install updated scales on the worker's pipeline (broadcast between
    /// Adam steps and after calibration).
    SetScales { scales: Scales, resp: mpsc::Sender<Result<()>> },
    /// Step-1 weight scales from the worker's (identical) parameters.
    WeightScales { resp: mpsc::Sender<Result<Scales>> },
}

struct Worker {
    tx: mpsc::Sender<WorkerJob>,
    join: Option<std::thread::JoinHandle<()>>,
}

/// Facts gathered from each worker pipeline at construction (identical on
/// every worker — same artifacts).
struct WorkerInfo {
    num_layers: usize,
    batch_sizes: Vec<usize>,
    adjust_batches: usize,
    weight_numels: Vec<u64>,
}

/// A pool of `workers` device pipelines implementing [`SearchEnv`] with
/// genuinely parallel `eval_many`.
pub struct PipelinePool {
    workers: Vec<Worker>,
    shared: Arc<SharedCache>,
    num_layers: usize,
    /// Compiled serving batch sizes, ascending (identical on every
    /// worker — same artifacts), gathered at construction.
    batch_sizes: Vec<usize>,
    /// Adjustment-split batch count (shard domain for calibration).
    adjust_batches: usize,
    /// Per-quant-layer weight element counts (trace normalization).
    weight_numels: Vec<u64>,
    /// Evaluations dispatched to workers (shared-cache hits excluded).
    /// Atomic so concurrent segment drivers can submit through `&self`.
    dispatched: AtomicUsize,
    /// Background persistent-cache flusher; present exactly while a cache
    /// is attached. In a `Mutex<Option<..>>` because attachment happens
    /// through `&self` (the pool is shared behind `Arc` while serving).
    flusher: Mutex<Option<Flusher>>,
}

impl PipelinePool {
    /// Build `workers` pipelines for `model`, running `configure` on each
    /// freshly constructed pipeline (scale loading / calibration) before it
    /// starts serving. Construction fails if any worker fails to build.
    pub fn new(
        artifacts_dir: &Path,
        model: &str,
        workers: usize,
        configure: impl Fn(&mut Pipeline) -> Result<()> + Send + Sync + 'static,
    ) -> Result<Self> {
        let workers = workers.max(1);
        let shared = Arc::new(SharedCache::new());
        let configure: Arc<dyn Fn(&mut Pipeline) -> Result<()> + Send + Sync> = Arc::new(configure);
        // Spawn every worker before waiting on any readiness signal, so the
        // expensive per-worker construction (graph compilation, scale
        // loading) runs concurrently rather than serially.
        let mut built = Vec::with_capacity(workers);
        let mut readies = Vec::with_capacity(workers);
        for wi in 0..workers {
            let (tx, rx) = mpsc::channel::<WorkerJob>();
            let (ready_tx, ready_rx) = mpsc::channel::<Result<WorkerInfo>>();
            let dir: PathBuf = artifacts_dir.to_path_buf();
            let model = model.to_string();
            let shared = shared.clone();
            let configure = configure.clone();
            let join = std::thread::spawn(move || {
                let mut pipeline = match Pipeline::new(&dir, &model) {
                    Ok(p) => p,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e.context(format!("pool worker {wi}"))));
                        return;
                    }
                };
                if let Err(e) = configure(&mut pipeline) {
                    let _ = ready_tx.send(Err(e.context(format!("configuring pool worker {wi}"))));
                    return;
                }
                let info = WorkerInfo {
                    num_layers: pipeline.num_quant_layers(),
                    batch_sizes: pipeline.logits_batch_sizes(),
                    adjust_batches: pipeline.num_adjust_batches(),
                    weight_numels: pipeline.weight_numels(),
                };
                let _ = ready_tx.send(Ok(info));
                worker_loop(&mut pipeline, &shared, &rx);
            });
            built.push(Worker { tx, join: Some(join) });
            readies.push((wi, ready_rx));
        }
        let mut info: Option<WorkerInfo> = None;
        for (wi, ready_rx) in readies {
            info = Some(
                ready_rx
                    .recv()
                    .map_err(|_| anyhow!("pool worker {wi} died during construction"))?
                    .with_context(|| format!("building pipeline pool for {model}"))?,
            );
        }
        let info = info.expect("workers >= 1");
        Ok(Self {
            workers: built,
            shared,
            num_layers: info.num_layers,
            batch_sizes: info.batch_sizes,
            adjust_batches: info.adjust_batches,
            weight_numels: info.weight_numels,
            dispatched: AtomicUsize::new(0),
            flusher: Mutex::new(None),
        })
    }

    /// Number of worker pipelines in the pool.
    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    /// Compiled serving batch sizes (ascending), as reported by the
    /// workers' artifacts at construction.
    pub fn logits_batch_sizes(&self) -> Vec<usize> {
        self.batch_sizes.clone()
    }

    /// Submit an arbitrary task to worker `worker % num_workers()`'s
    /// thread; it runs with exclusive access to that worker's pipeline,
    /// after any already-queued work. If the worker is gone, the task is
    /// invoked inline with `None` so it can report the failure itself.
    /// Returns whether the worker accepted the task.
    pub fn run_on(
        &self,
        worker: usize,
        task: impl FnOnce(Option<&mut Pipeline>) + Send + 'static,
    ) -> bool {
        let w = &self.workers[worker % self.workers.len()];
        match w.tx.send(WorkerJob::Task(Box::new(task))) {
            Ok(()) => true,
            Err(mpsc::SendError(job)) => {
                if let WorkerJob::Task(t) = job {
                    t(None);
                }
                false
            }
        }
    }

    /// Attach a persistent cross-run cache shared by all workers, with an
    /// optional entry bound (LRU eviction). The context fingerprint must
    /// come from one of the (identically configured) worker pipelines; use
    /// [`Pipeline::eval_context`] on a scratch pipeline, or pass any
    /// stable string covering model + scales.
    pub fn attach_eval_cache(&self, path: &Path, context: &str, capacity: Option<usize>) {
        // Settle any previous attachment first: stop its flusher and
        // commit its deferred writes to *its own* cache. A stray entry
        // queued against the old scales must never land in the new cache
        // (the contexts differ), so anything still pending after the
        // detach is discarded, not carried over.
        self.stop_flusher();
        self.shared.detach();
        let _ = self.shared.pending.drain();
        *self.shared.persistent.lock().unwrap() =
            Some(EvalCache::with_capacity(path, context, capacity));
        self.shared.attached.store(true, Ordering::Relaxed);
        *self.flusher.lock().unwrap() = Some(Flusher::spawn(self.shared.clone()));
    }

    /// Apply deferred writes and persist the shared cache, if attached.
    pub fn flush_eval_cache(&self) -> Result<()> {
        self.shared.flush()
    }

    /// Entries currently in the shared persistent cache (0 if detached),
    /// counting deferred writes the flusher has not drained yet.
    pub fn eval_cache_len(&self) -> usize {
        let mut guard = self.shared.persistent.lock().unwrap();
        match guard.as_mut() {
            Some(cache) => {
                for (k, r) in self.shared.pending.drain() {
                    cache.insert(k, &r);
                }
                cache.len()
            }
            None => 0,
        }
    }

    /// Stop and join the background flusher, if one is running.
    fn stop_flusher(&self) {
        if let Some(mut f) = self.flusher.lock().unwrap().take() {
            f.shutdown();
        }
    }

    /// Scatter one calibration/sensitivity stage over the workers —
    /// `make(shard, resp)` builds the [`WorkerJob`] for each shard, shard
    /// `i` goes to worker `i` — and gather the per-shard results in shard
    /// (worker-index) order.
    fn scatter_stage<T: Send + 'static>(
        &self,
        what: &str,
        shards: &[Vec<usize>],
        make: impl Fn(Vec<usize>, mpsc::Sender<Result<T>>) -> WorkerJob,
    ) -> Result<Vec<T>> {
        let mut rxs = Vec::with_capacity(shards.len());
        for (i, shard) in shards.iter().enumerate() {
            let wi = i % self.workers.len();
            let (tx, rx) = mpsc::channel();
            self.workers[wi]
                .tx
                .send(make(shard.clone(), tx))
                .map_err(|_| anyhow!("pool worker {wi} exited during {what}"))?;
            rxs.push(rx);
        }
        rxs.into_iter()
            .enumerate()
            .map(|(i, rx)| {
                rx.recv()
                    .map_err(|_| anyhow!("pool worker died during {what} (shard {i})"))?
                    .with_context(|| format!("{what} shard {i}"))
            })
            .collect()
    }

    /// Evaluations that actually reached a worker (cache misses).
    pub fn dispatched(&self) -> usize {
        self.dispatched.load(Ordering::Relaxed)
    }

    /// Lookups answered without touching a device:
    /// `(shared memo hits, persistent cross-run cache hits)`.
    pub fn cache_hits(&self) -> (usize, usize) {
        let memo = self.shared.memo.hits();
        let persistent =
            self.shared.persistent.lock().unwrap().as_ref().map_or(0, EvalCache::hits);
        (memo, persistent)
    }

    /// Evaluate a batch on one specific worker pipeline
    /// (`worker % num_workers()`) instead of scattering slots round-robin.
    /// The partitioned driver pins each segment to its own worker this
    /// way, so segments proceed concurrently without interleaving on a
    /// single pipeline. Shared-cache hits still short-circuit, and exact
    /// hits are target-independent, so affinity never changes a decision.
    pub fn eval_on(
        &self,
        worker: usize,
        cfgs: &[QuantConfig],
        target: Option<f64>,
    ) -> Vec<Result<EvalResult>> {
        self.submit_inner(cfgs, target, Some(worker))
    }

    fn submit(&self, cfgs: &[QuantConfig], target: Option<f64>) -> Vec<Result<EvalResult>> {
        self.submit_inner(cfgs, target, None)
    }

    fn submit_inner(
        &self,
        cfgs: &[QuantConfig],
        target: Option<f64>,
        affinity: Option<usize>,
    ) -> Vec<Result<EvalResult>> {
        let mut slots: Vec<Option<Result<EvalResult>>> = Vec::new();
        slots.resize_with(cfgs.len(), || None);
        let (resp_tx, resp_rx) = mpsc::channel();
        let mut outstanding = 0usize;
        for (slot, cfg) in cfgs.iter().enumerate() {
            // Shared-cache hits short-circuit without touching a worker.
            // Exact hits are target-independent, so this never changes a
            // decision relative to a fresh evaluation.
            if let Some(hit) = self.shared.lookup(cfg.key()) {
                slots[slot] = Some(Ok(hit));
                continue;
            }
            let worker = &self.workers[affinity.unwrap_or(slot) % self.workers.len()];
            let job = Job { cfg: cfg.clone(), target, slot, resp: resp_tx.clone() };
            if worker.tx.send(WorkerJob::Eval(job)).is_err() {
                slots[slot] = Some(Err(anyhow!("pool worker exited")));
                continue;
            }
            self.dispatched.fetch_add(1, Ordering::Relaxed);
            outstanding += 1;
        }
        drop(resp_tx);
        for _ in 0..outstanding {
            match resp_rx.recv() {
                Ok((slot, result)) => slots[slot] = Some(result),
                Err(_) => break,
            }
        }
        slots
            .into_iter()
            .map(|o| o.unwrap_or_else(|| Err(anyhow!("pool worker dropped a job"))))
            .collect()
    }
}

fn worker_loop(pipeline: &mut Pipeline, shared: &SharedCache, rx: &mpsc::Receiver<WorkerJob>) {
    while let Ok(job) = rx.recv() {
        match job {
            WorkerJob::Eval(job) => {
                let key = job.cfg.key();
                let result = match shared.lookup(key) {
                    Some(hit) => Ok(hit),
                    None => {
                        let r = pipeline.eval_config(&job.cfg, job.target);
                        if let Ok(res) = &r {
                            shared.publish(key, res);
                        }
                        r
                    }
                };
                let _ = job.resp.send((job.slot, result));
            }
            WorkerJob::Task(task) => task(Some(pipeline)),
            WorkerJob::ActStats { batches, resp } => {
                let _ = resp.send(pipeline.act_stats_shard(&batches));
            }
            WorkerJob::AdjustGrads { scales, bits, batches, resp } => {
                let _ = resp.send(pipeline.adjust_grads_shard(&scales, bits, &batches));
            }
            WorkerJob::Hvp { seed, trials, resp } => {
                let _ = resp.send(pipeline.hvp_shard(seed, &trials));
            }
            WorkerJob::NoisePerturb { lambda, trials, seed, items, resp } => {
                let _ = resp.send(pipeline.noise_shard(lambda, trials, seed, &items));
            }
            WorkerJob::PairPerturb { lambda, trials, seed, items, resp } => {
                let _ = resp.send(pipeline.pair_shard(lambda, trials, seed, &items));
            }
            WorkerJob::CleanLoss { resp } => {
                let _ = resp.send(pipeline.calib_loss_float());
            }
            WorkerJob::SetScales { scales, resp } => {
                pipeline.scales = scales;
                let _ = resp.send(pipeline.sync_scales());
            }
            WorkerJob::WeightScales { resp } => {
                let _ = resp.send(calibrate::weight_scales(
                    &pipeline.artifacts.manifest,
                    &pipeline.artifacts.params,
                ));
            }
        }
    }
}

/// The multi-worker stage backend: shards run concurrently, one per
/// worker pipeline, gathered in shard order. Combined with the
/// fixed-order host reducers this is bit-identical to the one-worker
/// [`Pipeline`] backend at every pool size.
impl StageRunner for PipelinePool {
    fn shard_workers(&self) -> usize {
        self.workers.len()
    }

    fn shard_layers(&self) -> usize {
        self.num_layers
    }

    fn adjust_batches(&self) -> usize {
        self.adjust_batches
    }

    fn weight_numels(&self) -> Vec<u64> {
        self.weight_numels.clone()
    }

    fn stage_weight_scales(&mut self) -> Result<Scales> {
        let (tx, rx) = mpsc::channel();
        self.workers[0]
            .tx
            .send(WorkerJob::WeightScales { resp: tx })
            .map_err(|_| anyhow!("pool worker 0 exited during weight calibration"))?;
        rx.recv().map_err(|_| anyhow!("pool worker 0 died during weight calibration"))?
    }

    fn stage_act_stats(&mut self, shards: &[Vec<usize>]) -> Result<Vec<Vec<f32>>> {
        self.scatter_stage("act stats", shards, |batches, resp| WorkerJob::ActStats {
            batches,
            resp,
        })
    }

    fn stage_adjust_grads(
        &mut self,
        scales: &Scales,
        bits: f32,
        shards: &[Vec<usize>],
    ) -> Result<Vec<Vec<BatchGrad>>> {
        self.scatter_stage("scale adjustment", shards, |batches, resp| {
            WorkerJob::AdjustGrads { scales: scales.clone(), bits, batches, resp }
        })
    }

    fn stage_hvp(&mut self, seed: u64, shards: &[Vec<usize>]) -> Result<Vec<Vec<TraceSample>>> {
        self.scatter_stage("hessian probes", shards, |trials, resp| WorkerJob::Hvp {
            seed,
            trials,
            resp,
        })
    }

    fn stage_clean_loss(&mut self) -> Result<f64> {
        // Identical on every worker (same parameters and splits); run on 0.
        let (tx, rx) = mpsc::channel();
        self.workers[0]
            .tx
            .send(WorkerJob::CleanLoss { resp: tx })
            .map_err(|_| anyhow!("pool worker 0 exited during noise baseline"))?;
        rx.recv().map_err(|_| anyhow!("pool worker 0 died during noise baseline"))?
    }

    fn stage_noise(
        &mut self,
        lambda: f64,
        trials: usize,
        seed: u64,
        shards: &[Vec<usize>],
    ) -> Result<Vec<Vec<NoiseSample>>> {
        self.scatter_stage("noise perturbations", shards, |items, resp| {
            WorkerJob::NoisePerturb { lambda, trials, seed, items, resp }
        })
    }

    fn stage_pair(
        &mut self,
        lambda: f64,
        trials: usize,
        seed: u64,
        shards: &[Vec<usize>],
    ) -> Result<Vec<Vec<PairSample>>> {
        self.scatter_stage("pair perturbations", shards, |items, resp| {
            WorkerJob::PairPerturb { lambda, trials, seed, items, resp }
        })
    }

    fn broadcast_scales(&mut self, scales: &Scales) -> Result<()> {
        // Results depend on scales: invalidate the shared caches exactly
        // like [`Pipeline::sync_scales`] invalidates its per-pipeline
        // ones — the memo is cleared, a persistent cache (whose context
        // fingerprint no longer matches) is flushed and detached — its
        // deferred writes were computed under the *old* scales, which is
        // exactly what its fingerprint covers, so they are committed to
        // it before the detach. The owner re-attaches once the new scales
        // are final (`ModelContext` does so after calibration).
        self.stop_flusher();
        self.shared.memo.clear();
        self.shared.detach();
        let mut rxs = Vec::with_capacity(self.workers.len());
        for (wi, w) in self.workers.iter().enumerate() {
            let (tx, rx) = mpsc::channel();
            w.tx.send(WorkerJob::SetScales { scales: scales.clone(), resp: tx })
                .map_err(|_| anyhow!("pool worker {wi} exited during scale broadcast"))?;
            rxs.push(rx);
        }
        for (wi, rx) in rxs.into_iter().enumerate() {
            rx.recv().map_err(|_| anyhow!("pool worker {wi} died during scale broadcast"))??;
        }
        Ok(())
    }
}

impl SearchEnv for PipelinePool {
    fn num_layers(&self) -> usize {
        self.num_layers
    }

    fn eval(&mut self, cfg: &QuantConfig, target: Option<f64>) -> Result<EvalResult> {
        self.submit(std::slice::from_ref(cfg), target).pop().expect("one result per config")
    }

    fn preferred_batch(&self) -> usize {
        self.workers.len()
    }

    fn eval_many(&mut self, cfgs: &[QuantConfig], target: Option<f64>) -> Vec<Result<EvalResult>> {
        self.submit(cfgs, target)
    }
}

impl Drop for PipelinePool {
    fn drop(&mut self) {
        self.stop_flusher();
        let _ = self.flush_eval_cache();
        // Closing the job channels ends each worker loop; then reap.
        let workers: Vec<Worker> = self.workers.drain(..).collect();
        let mut joins = Vec::with_capacity(workers.len());
        for mut w in workers {
            joins.extend(w.join.take());
            drop(w); // drops the sender
        }
        for join in joins {
            let _ = join.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("mpq_poolcache_{name}.json"))
    }

    fn res(accuracy: f64) -> EvalResult {
        EvalResult { loss: 1.0 - accuracy, accuracy, exact: true }
    }

    fn attach(shared: &SharedCache, path: &Path, context: &str) {
        *shared.persistent.lock().unwrap() = Some(EvalCache::with_capacity(path, context, None));
        shared.attached.store(true, Ordering::Relaxed);
    }

    #[test]
    fn memo_hit_is_one_acquisition_and_skips_persistent() {
        let path = tmp("memo_hit");
        let _ = std::fs::remove_file(&path);
        let shared = SharedCache::new();
        attach(&shared, &path, "ctx");
        shared.publish(11, &res(0.8));
        assert!(!shared.pending.is_empty());
        let before = shared.memo.lock_acquisitions();
        for _ in 0..5 {
            assert_eq!(shared.lookup(11).unwrap().accuracy, 0.8);
        }
        // Five hits, five stripe acquisitions — the persistent mutex and
        // the old re-insert acquisition are both off the hit path.
        assert_eq!(shared.memo.lock_acquisitions() - before, 5);
        assert_eq!(shared.memo.hits(), 5);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn persistent_hit_seeds_memo_for_one_lock_rereads() {
        let path = tmp("seed_memo");
        let _ = std::fs::remove_file(&path);
        {
            let mut cache = EvalCache::with_capacity(&path, "ctx", None);
            cache.insert(42, &res(0.7));
            cache.save().unwrap();
        }
        let shared = SharedCache::new();
        attach(&shared, &path, "ctx");
        // First lookup misses the memo, hits the persistent cache...
        assert_eq!(shared.lookup(42).unwrap().accuracy, 0.7);
        assert_eq!(shared.memo.hits(), 0);
        // ...and seeds the memo: the re-read is a one-acquisition hit.
        let before = shared.memo.lock_acquisitions();
        assert_eq!(shared.lookup(42).unwrap().accuracy, 0.7);
        assert_eq!(shared.memo.lock_acquisitions() - before, 1);
        assert_eq!(shared.memo.hits(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn publish_defers_persistent_write_until_flush() {
        let path = tmp("deferred");
        let _ = std::fs::remove_file(&path);
        let shared = SharedCache::new();
        attach(&shared, &path, "ctx");
        shared.publish(1, &res(0.9));
        shared.publish(2, &EvalResult { loss: 0.5, accuracy: 0.5, exact: false });
        // The exact result is queued, the inexact one dropped; neither has
        // touched the EvalCache yet.
        assert_eq!(shared.persistent.lock().unwrap().as_ref().unwrap().len(), 0);
        shared.flush().unwrap();
        assert!(shared.pending.is_empty());
        let guard = shared.persistent.lock().unwrap();
        let cache = guard.as_ref().unwrap();
        assert_eq!(cache.len(), 1);
        // And the flush persisted to disk (atomic rename, as before).
        drop(guard);
        let mut reloaded = EvalCache::with_capacity(&path, "ctx", None);
        assert_eq!(reloaded.lookup(1).unwrap().accuracy, 0.9);
        assert!(reloaded.lookup(2).is_none());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn detach_commits_pending_to_the_old_cache() {
        let path = tmp("detach");
        let _ = std::fs::remove_file(&path);
        let shared = SharedCache::new();
        attach(&shared, &path, "ctx");
        shared.publish(7, &res(0.6));
        shared.detach();
        assert!(shared.persistent.lock().unwrap().is_none());
        assert!(shared.pending.is_empty());
        // Publishes while detached go to the memo only — nothing queues.
        shared.publish(8, &res(0.4));
        assert!(shared.pending.is_empty());
        let mut reloaded = EvalCache::with_capacity(&path, "ctx", None);
        assert_eq!(reloaded.lookup(7).unwrap().accuracy, 0.6);
        assert!(reloaded.lookup(8).is_none());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn background_flusher_drains_without_explicit_flush() {
        let path = tmp("flusher");
        let _ = std::fs::remove_file(&path);
        let shared = Arc::new(SharedCache::new());
        attach(&shared, &path, "ctx");
        let mut flusher = Flusher::spawn(shared.clone());
        shared.publish(3, &res(0.3));
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while !shared.pending.is_empty() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(shared.pending.is_empty(), "flusher never drained the pending queue");
        flusher.shutdown();
        assert_eq!(shared.persistent.lock().unwrap().as_ref().unwrap().len(), 1);
        let _ = std::fs::remove_file(&path);
    }
}
