//! Algorithm 2 — greedy configuration search, with batched speculation.
//!
//! Walks the layers in sensitivity order (least sensitive first), trial-
//! quantizing one layer at a time and keeping the change only if the model
//! still meets the accuracy target. Layers that survive a bit width remain
//! candidates for the next, lower width. Average complexity
//! `O((2 - 2^-(b-1)) N)` evaluations, worst case `O(bN)`.
//!
//! # Batched speculation
//!
//! The sequential decision chain is data-dependent (an accepted layer
//! changes the base configuration every later candidate builds on), so the
//! search speculates like a branch predictor: it submits a frontier of
//! [`SearchEnv::preferred_batch`] candidates per [`SearchEnv::eval_many`]
//! call, built under one of two assumptions about the upcoming decisions —
//!
//! * **cumulative** (predicting accepts): candidate `k` quantizes the next
//!   `k+1` pending layers on top of the current config, so a run of
//!   accepts consumes the entire frontier;
//! * **independent** (predicting rejects): candidate `k` quantizes only
//!   the `k`-th pending layer, so a run of rejects consumes the entire
//!   frontier.
//!
//! Candidate 0 is the same configuration in both modes — exactly the one
//! the sequential algorithm would evaluate next — so every batch decides at
//! least one layer. The replay consumes results while the predicted
//! direction holds, flips the mode on the first mispredict, and re-batches.
//! Consumed candidates are configurations the sequential search would have
//! evaluated with identical results, which makes the final configuration,
//! accuracy and decision-eval count bit-identical at every worker count;
//! only discarded speculative work varies.

use crate::api::{AccuracyTarget, SearchCtl, SearchEvent};
use crate::quant::QuantConfig;
use crate::Result;

use super::{EvalResult, SearchEnv, SearchOutcome};

/// Speculation mode for the next frontier: mirror of the last decision.
#[derive(Clone, Copy, PartialEq)]
enum Spec {
    /// Assume upcoming candidates are accepted (stacked prefixes).
    Cumulative,
    /// Assume upcoming candidates are rejected (isolated single-layer
    /// trials against a fixed base).
    Independent,
}

/// The paper's greedy search under a plain accuracy floor (the historical
/// entry point — a thin wrapper over [`search_with`]).
pub fn search<E: SearchEnv>(
    env: &mut E,
    order: &[usize],
    quant_bits: &[f32],
    target: f64,
) -> Result<SearchOutcome> {
    let objective = AccuracyTarget::new(target);
    let mut ctl = SearchCtl::new(&objective);
    search_with(env, order, quant_bits, &mut ctl)
}

/// Greedy search under an arbitrary [`crate::api::Objective`].
///
/// Every decision point consults the control surface: recorded checkpoint
/// decisions are replayed without touching the environment, live decisions
/// go through `ctl.decide` (objective accept test + checkpoint append +
/// event), and after each accepted layer `ctl.satisfied` may stop the
/// search once the objective's budgets are met. With
/// [`AccuracyTarget`] (never satisfied, accept == the accuracy test) the
/// trajectory is bit-identical to the pre-objective implementation.
pub fn search_with<E: SearchEnv>(
    env: &mut E,
    order: &[usize],
    quant_bits: &[f32],
    ctl: &mut SearchCtl<'_>,
) -> Result<SearchOutcome> {
    assert_eq!(order.len(), env.num_layers(), "ordering must cover every quant layer");
    let base = QuantConfig::float(env.num_layers());
    search_scoped(env, order, &base, quant_bits, ctl)
}

/// Greedy search restricted to the layers in `order`, starting from `base`.
///
/// Layers outside `order` keep whatever width `base` assigns them (the
/// partitioned driver freezes the complement at reference precision), so a
/// segment's decisions depend only on its own layers plus the fixed base.
/// With the full order and an all-float base this is exactly
/// [`search_with`] — the whole-model search is the K=1 special case.
pub fn search_scoped<E: SearchEnv>(
    env: &mut E,
    order: &[usize],
    base: &QuantConfig,
    quant_bits: &[f32],
    ctl: &mut SearchCtl<'_>,
) -> Result<SearchOutcome> {
    let n = env.num_layers();
    assert_eq!(base.num_layers(), n, "base config must cover every quant layer");
    assert!(order.len() <= n, "segment cannot exceed the layer count");
    assert!(order.iter().all(|&l| l < n), "segment layer out of range");
    let window = env.preferred_batch().max(1);
    let mut w = base.clone();
    if let Some(done) = ctl.baseline_outcome(env, &w)? {
        return Ok(done);
    }
    let mut evals = 0usize;
    // ll: layers still eligible for further quantization, sensitivity order.
    let mut ll: Vec<usize> = order.to_vec();
    // Most layers survive the first (highest) width, so start optimistic.
    let mut mode = Spec::Cumulative;
    'widths: for &b in quant_bits {
        let mut ql = Vec::with_capacity(ll.len());
        let mut i = 0usize;
        while i < ll.len() {
            // Checkpointed decisions replay without evaluating; they count
            // as decision evals so resumed totals match uninterrupted runs.
            if let Some(pass) = ctl.take_replay(b, ll[i]) {
                evals += 1;
                if pass {
                    w.set_layer(ll[i], b);
                    ql.push(ll[i]);
                }
                mode = if pass { Spec::Cumulative } else { Spec::Independent };
                i += 1;
                if pass && ctl.satisfied(&w) {
                    break 'widths;
                }
                continue;
            }
            let pending = &ll[i..(i + window).min(ll.len())];
            let cfgs = speculate(&w, pending, b, mode);
            ctl.emit(SearchEvent::FrontierSubmitted { bits: b, size: cfgs.len() });
            let results = env.eval_many(&cfgs, ctl.eval_target());
            let mut consumed = 0usize;
            for (j, r) in results.into_iter().enumerate() {
                let r = r?;
                evals += 1;
                consumed = j + 1;
                // Consumed candidates are exactly the configurations the
                // sequential search would have evaluated, so `cfgs[j]` is
                // the sequential config at this decision.
                let pass = ctl.decide(b, pending[j], &cfgs[j], &r)?;
                if pass {
                    // The sequential config at this decision includes the
                    // layer (and, in cumulative mode, its predecessors —
                    // already applied on their own accepts).
                    w.set_layer(pending[j], b);
                    ql.push(pending[j]);
                }
                if pass && ctl.satisfied(&w) {
                    break 'widths;
                }
                // A result at j+1 is only sequential-valid if decision j
                // went the way the speculation mode assumed.
                let predicted = match mode {
                    Spec::Cumulative => pass,
                    Spec::Independent => !pass,
                };
                if !predicted {
                    mode = if pass { Spec::Cumulative } else { Spec::Independent };
                    break;
                }
            }
            i += consumed;
        }
        ll = ql;
    }
    let final_res: EvalResult = env.eval(&w, None)?;
    evals += 1;
    Ok(SearchOutcome {
        config: w,
        accuracy: final_res.accuracy,
        evals,
        target: ctl.objective().accuracy_floor(),
    })
}

/// Build one speculative frontier over `pending` layers at width `bits`.
fn speculate(base: &QuantConfig, pending: &[usize], bits: f32, mode: Spec) -> Vec<QuantConfig> {
    let mut out = Vec::with_capacity(pending.len());
    let mut stacked = base.clone();
    for &layer in pending {
        let cfg = match mode {
            Spec::Cumulative => {
                stacked.set_layer(layer, bits);
                stacked.clone()
            }
            Spec::Independent => {
                let mut c = base.clone();
                c.set_layer(layer, bits);
                c
            }
        };
        out.push(cfg);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::EvalResult;

    /// Mock model: quantizing layer `i` to width `b` costs `penalty[i] *
    /// (16 - b) / 12`; accuracy = 1 - total cost. Monotone and separable,
    /// so the greedy optimum is known in closed form.
    struct Mock {
        penalty: Vec<f64>,
    }

    impl SearchEnv for Mock {
        fn num_layers(&self) -> usize {
            self.penalty.len()
        }

        fn eval(&mut self, cfg: &QuantConfig, _t: Option<f64>) -> Result<EvalResult> {
            let cost: f64 = cfg
                .bits_w
                .iter()
                .enumerate()
                .map(|(i, &b)| self.penalty[i] * f64::from(16.0 - b) / 12.0)
                .sum();
            Ok(EvalResult { loss: cost, accuracy: 1.0 - cost, exact: true })
        }
    }

    /// A `Mock` that advertises a batch window, to exercise speculation.
    struct BatchedMock {
        inner: Mock,
        window: usize,
        raw_evals: usize,
    }

    impl SearchEnv for BatchedMock {
        fn num_layers(&self) -> usize {
            self.inner.num_layers()
        }

        fn eval(&mut self, cfg: &QuantConfig, t: Option<f64>) -> Result<EvalResult> {
            self.raw_evals += 1;
            self.inner.eval(cfg, t)
        }

        fn preferred_batch(&self) -> usize {
            self.window
        }
    }

    #[test]
    fn quantizes_cheap_layers_and_protects_expensive() {
        // Layer 0 free, layer 1 cheap, layer 2 ruinous.
        let mut env = Mock { penalty: vec![0.0, 0.004, 1.0] };
        let order = vec![0, 1, 2];
        let out = search(&mut env, &order, &[8.0, 4.0], 0.99).unwrap();
        assert_eq!(out.config.layer_bits(0), 4.0);
        assert_eq!(out.config.layer_bits(2), 16.0);
        assert!(out.accuracy >= 0.99);
    }

    #[test]
    fn target_one_keeps_everything_float_when_any_cost() {
        let mut env = Mock { penalty: vec![0.1, 0.1] };
        let out = search(&mut env, &[0, 1], &[8.0, 4.0], 1.0).unwrap();
        assert_eq!(out.config, QuantConfig::float(2));
        assert_eq!(out.accuracy, 1.0);
    }

    #[test]
    fn eval_budget_within_bound() {
        // Worst case b*N + 1 final eval.
        let mut env = Mock { penalty: vec![0.0; 10] };
        let out = search(&mut env, &(0..10).collect::<Vec<_>>(), &[8.0, 4.0], 0.5).unwrap();
        assert!(out.evals <= 2 * 10 + 1);
    }

    #[test]
    fn layers_failing_high_width_not_retried_lower() {
        // Layer 1 fails already at 8 bits; the 4-bit pass must skip it.
        struct Counting {
            inner: Mock,
            evals_of_layer1_at4: usize,
        }
        impl SearchEnv for Counting {
            fn num_layers(&self) -> usize {
                self.inner.num_layers()
            }
            fn eval(&mut self, cfg: &QuantConfig, t: Option<f64>) -> Result<EvalResult> {
                if cfg.layer_bits(1) == 4.0 {
                    self.evals_of_layer1_at4 += 1;
                }
                self.inner.eval(cfg, t)
            }
        }
        let mut env = Counting { inner: Mock { penalty: vec![0.0, 1.0] }, evals_of_layer1_at4: 0 };
        let out = search(&mut env, &[0, 1], &[8.0, 4.0], 0.99).unwrap();
        assert_eq!(out.config.layer_bits(1), 16.0);
        assert_eq!(env.evals_of_layer1_at4, 0);
    }

    #[test]
    fn batched_windows_match_sequential_outcome() {
        // Mixed accept/reject pattern; every window size must reproduce the
        // sequential configuration, accuracy and decision-eval count.
        let penalty = vec![0.0, 0.004, 0.5, 0.0001, 0.2, 0.0, 0.003, 0.9];
        let order: Vec<usize> = (0..penalty.len()).collect();
        let mut seq_env = Mock { penalty: penalty.clone() };
        let seq = search(&mut seq_env, &order, &[8.0, 4.0], 0.99).unwrap();
        for window in [1usize, 2, 3, 8, 64] {
            let mut env =
                BatchedMock { inner: Mock { penalty: penalty.clone() }, window, raw_evals: 0 };
            let out = search(&mut env, &order, &[8.0, 4.0], 0.99).unwrap();
            assert_eq!(out.config, seq.config, "window {window}");
            assert_eq!(out.accuracy, seq.accuracy, "window {window}");
            assert_eq!(out.evals, seq.evals, "window {window}");
            // Speculation may add raw evals but never drops decisions.
            assert!(env.raw_evals >= out.evals, "window {window}");
        }
    }

    #[test]
    fn cumulative_runs_consume_whole_windows() {
        // All-accept model: with window W the search must issue about N/W
        // batches, i.e. raw evals stay ~N (no quadratic re-batching).
        let n = 32;
        let mut env =
            BatchedMock { inner: Mock { penalty: vec![0.0; n] }, window: 8, raw_evals: 0 };
        let order: Vec<usize> = (0..n).collect();
        let out = search(&mut env, &order, &[8.0, 4.0], 0.5).unwrap();
        assert_eq!(out.config, QuantConfig::uniform(n, 4.0));
        // Sequential would use 2n+1 evals; perfect speculation issues the
        // same raw count (every speculative result gets consumed).
        assert_eq!(env.raw_evals, 2 * n + 1);
    }
}
