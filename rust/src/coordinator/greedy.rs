//! Algorithm 2 — greedy configuration search.
//!
//! Walks the layers in sensitivity order (least sensitive first), trial-
//! quantizing one layer at a time and keeping the change only if the model
//! still meets the accuracy target. Layers that survive a bit width remain
//! candidates for the next, lower width. Average complexity
//! `O((2 - 2^-(b-1)) N)` evaluations, worst case `O(bN)`.

use crate::quant::QuantConfig;
use crate::Result;

use super::{EvalResult, SearchEnv, SearchOutcome};

pub fn search<E: SearchEnv>(
    env: &mut E,
    order: &[usize],
    quant_bits: &[f32],
    target: f64,
) -> Result<SearchOutcome> {
    let n = env.num_layers();
    assert_eq!(order.len(), n, "ordering must cover every quant layer");
    let mut w = QuantConfig::float(n);
    let mut evals = 0usize;
    // ll: layers still eligible for further quantization, sensitivity order.
    let mut ll: Vec<usize> = order.to_vec();
    for &b in quant_bits {
        let mut ql = Vec::with_capacity(ll.len());
        for &layer in &ll {
            let prev = w.layer_bits(layer);
            w.set_layer(layer, b);
            let r = env.eval(&w, Some(target))?;
            evals += 1;
            if r.accuracy >= target {
                ql.push(layer);
            } else {
                w.set_layer(layer, prev);
            }
        }
        ll = ql;
    }
    let final_res: EvalResult = env.eval(&w, None)?;
    evals += 1;
    Ok(SearchOutcome { config: w, accuracy: final_res.accuracy, evals, target })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::EvalResult;

    /// Mock model: quantizing layer `i` to width `b` costs `penalty[i] *
    /// (16 - b) / 12`; accuracy = 1 - total cost. Monotone and separable,
    /// so the greedy optimum is known in closed form.
    struct Mock {
        penalty: Vec<f64>,
    }

    impl SearchEnv for Mock {
        fn num_layers(&self) -> usize {
            self.penalty.len()
        }

        fn eval(&mut self, cfg: &QuantConfig, _t: Option<f64>) -> Result<EvalResult> {
            let cost: f64 = cfg
                .bits_w
                .iter()
                .enumerate()
                .map(|(i, &b)| self.penalty[i] * f64::from(16.0 - b) / 12.0)
                .sum();
            Ok(EvalResult { loss: cost, accuracy: 1.0 - cost, exact: true })
        }
    }

    #[test]
    fn quantizes_cheap_layers_and_protects_expensive() {
        // Layer 0 free, layer 1 cheap, layer 2 ruinous.
        let mut env = Mock { penalty: vec![0.0, 0.004, 1.0] };
        let order = vec![0, 1, 2];
        let out = search(&mut env, &order, &[8.0, 4.0], 0.99).unwrap();
        assert_eq!(out.config.layer_bits(0), 4.0);
        assert_eq!(out.config.layer_bits(2), 16.0);
        assert!(out.accuracy >= 0.99);
    }

    #[test]
    fn target_one_keeps_everything_float_when_any_cost() {
        let mut env = Mock { penalty: vec![0.1, 0.1] };
        let out = search(&mut env, &[0, 1], &[8.0, 4.0], 1.0).unwrap();
        assert_eq!(out.config, QuantConfig::float(2));
        assert_eq!(out.accuracy, 1.0);
    }

    #[test]
    fn eval_budget_within_bound() {
        // Worst case b*N + 1 final eval.
        let mut env = Mock { penalty: vec![0.0; 10] };
        let out = search(&mut env, &(0..10).collect::<Vec<_>>(), &[8.0, 4.0], 0.5).unwrap();
        assert!(out.evals <= 2 * 10 + 1);
    }

    #[test]
    fn layers_failing_high_width_not_retried_lower() {
        // Layer 1 fails already at 8 bits; the 4-bit pass must skip it.
        struct Counting {
            inner: Mock,
            evals_of_layer1_at4: usize,
        }
        impl SearchEnv for Counting {
            fn num_layers(&self) -> usize {
                self.inner.num_layers()
            }
            fn eval(&mut self, cfg: &QuantConfig, t: Option<f64>) -> Result<EvalResult> {
                if cfg.layer_bits(1) == 4.0 {
                    self.evals_of_layer1_at4 += 1;
                }
                self.inner.eval(cfg, t)
            }
        }
        let mut env = Counting { inner: Mock { penalty: vec![0.0, 1.0] }, evals_of_layer1_at4: 0 };
        let out = search(&mut env, &[0, 1], &[8.0, 4.0], 0.99).unwrap();
        assert_eq!(out.config.layer_bits(1), 16.0);
        assert_eq!(env.evals_of_layer1_at4, 0);
    }
}
