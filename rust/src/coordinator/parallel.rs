//! Parallel candidate fan-out for thread-safe evaluation environments.
//!
//! [`ParallelEnv`] adapts anything implementing [`SyncSearchEnv`] (shared
//! `&self` evaluation) into a [`SearchEnv`] whose `eval_many` scatters the
//! batch over `workers` scoped threads. Results come back slot-indexed, so
//! the output order — and therefore every decision a search replays — is
//! independent of worker scheduling: outcomes are bit-identical at any
//! worker count, only wall-clock changes.
//!
//! The device [`super::Pipeline`] is *not* `Sync` (PJRT handles are
//! single-threaded); its multi-worker counterpart is
//! [`super::PipelinePool`], which owns one pipeline per worker thread.
//! This adapter parallelizes *independent candidates*; the other shape of
//! fan-out — shards of one dataset with deterministic reduction
//! (calibration, Hessian probes) — lives in [`super::shard`].

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::quant::QuantConfig;
use crate::Result;

use super::{EvalResult, SearchEnv};

/// A thread-safe evaluation environment: evaluation borrows `&self`, so
/// many candidates can be scored concurrently.
pub trait SyncSearchEnv: Sync {
    fn num_layers(&self) -> usize;

    /// Evaluate one configuration. Must be deterministic for a given
    /// configuration (any seeding derived from inputs, not call order) so
    /// that parallel schedules reproduce sequential results bit-exactly.
    fn eval(&self, cfg: &QuantConfig, target: Option<f64>) -> Result<EvalResult>;
}

/// [`SearchEnv`] adapter fanning `eval_many` batches over scoped threads.
pub struct ParallelEnv<'e, E: SyncSearchEnv> {
    env: &'e E,
    workers: usize,
    /// Evaluations issued, speculative ones included (contrast with
    /// [`super::SearchOutcome::evals`], which counts consumed decisions).
    raw_evals: usize,
}

impl<'e, E: SyncSearchEnv> ParallelEnv<'e, E> {
    pub fn new(env: &'e E, workers: usize) -> Self {
        Self { env, workers: workers.max(1), raw_evals: 0 }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Total evaluations issued so far, including discarded speculation.
    pub fn raw_evals(&self) -> usize {
        self.raw_evals
    }
}

impl<E: SyncSearchEnv> SearchEnv for ParallelEnv<'_, E> {
    fn num_layers(&self) -> usize {
        self.env.num_layers()
    }

    fn eval(&mut self, cfg: &QuantConfig, target: Option<f64>) -> Result<EvalResult> {
        self.raw_evals += 1;
        self.env.eval(cfg, target)
    }

    fn preferred_batch(&self) -> usize {
        self.workers
    }

    fn eval_many(&mut self, cfgs: &[QuantConfig], target: Option<f64>) -> Vec<Result<EvalResult>> {
        self.raw_evals += cfgs.len();
        if self.workers == 1 || cfgs.len() <= 1 {
            return cfgs.iter().map(|c| self.env.eval(c, target)).collect();
        }
        let env = self.env;
        let next = AtomicUsize::new(0);
        let mut slots: Vec<Option<Result<EvalResult>>> = Vec::new();
        slots.resize_with(cfgs.len(), || None);
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..self.workers.min(cfgs.len()))
                .map(|_| {
                    let next = &next;
                    s.spawn(move || {
                        // Work-stealing by atomic index: assignment order
                        // varies between runs, slot order never does.
                        let mut done = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= cfgs.len() {
                                break;
                            }
                            done.push((i, env.eval(&cfgs[i], target)));
                        }
                        done
                    })
                })
                .collect();
            for h in handles {
                for (i, r) in h.join().expect("eval worker panicked") {
                    slots[i] = Some(r);
                }
            }
        });
        slots.into_iter().map(|o| o.expect("every slot filled")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SearchAlgo;
    use crate::quant::QUANT_BITS;

    /// Separable monotone environment with shared-state eval.
    struct Separable {
        penalty: Vec<f64>,
        evals: AtomicUsize,
    }

    impl SyncSearchEnv for Separable {
        fn num_layers(&self) -> usize {
            self.penalty.len()
        }

        fn eval(&self, cfg: &QuantConfig, _t: Option<f64>) -> Result<EvalResult> {
            self.evals.fetch_add(1, Ordering::Relaxed);
            let cost: f64 = cfg
                .bits_w
                .iter()
                .enumerate()
                .map(|(i, &b)| self.penalty[i] * f64::from(16.0 - b) / 12.0)
                .sum();
            Ok(EvalResult { loss: cost, accuracy: 1.0 - cost, exact: true })
        }
    }

    #[test]
    fn batch_results_are_slot_ordered() {
        let env = Separable { penalty: vec![0.0, 0.5, 0.0, 0.9], evals: AtomicUsize::new(0) };
        let mut p = ParallelEnv::new(&env, 4);
        let cfgs: Vec<QuantConfig> = (0..4)
            .map(|i| {
                let mut c = QuantConfig::float(4);
                c.set_layer(i, 4.0);
                c
            })
            .collect();
        let batched = p.eval_many(&cfgs, None);
        for (i, r) in batched.iter().enumerate() {
            let direct = env.eval(&cfgs[i], None).unwrap();
            assert_eq!(*r.as_ref().unwrap(), direct, "slot {i}");
        }
        assert_eq!(p.raw_evals(), 4);
    }

    #[test]
    fn search_outcomes_identical_across_worker_counts() {
        let penalty = vec![0.0, 0.004, 0.5, 0.0001, 0.2, 0.0, 0.003, 0.9, 0.0, 0.0];
        let order: Vec<usize> = (0..penalty.len()).collect();
        let mut reference = None;
        for workers in [1usize, 2, 8] {
            let env = Separable { penalty: penalty.clone(), evals: AtomicUsize::new(0) };
            let mut p = ParallelEnv::new(&env, workers);
            let out = SearchAlgo::Greedy.run(&mut p, &order, &QUANT_BITS, 0.99).unwrap();
            match &reference {
                None => reference = Some(out),
                Some(r) => {
                    assert_eq!(out.config, r.config, "workers {workers}");
                    assert_eq!(out.accuracy, r.accuracy, "workers {workers}");
                    assert_eq!(out.evals, r.evals, "workers {workers}");
                }
            }
        }
    }
}
