//! Minimal JSON parser + writer.
//!
//! Handles the machine-generated JSON this system exchanges (manifests from
//! `aot.py`, scale/result files we write ourselves): objects, arrays,
//! strings with escapes, f64 numbers, booleans, null. Not a general-purpose
//! validator — malformed input fails with a position-annotated error.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value. Object keys keep a sorted map (order-independent
/// lookup; writer emits sorted keys, which is fine for our consumers).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    // ------------------------------------------------------------ accessors

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Required-key lookup with a useful error.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow!("missing JSON key `{key}`"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            v => bail!("expected string, got {v:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            v => bail!("expected number, got {v:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 {
            bail!("expected integer, got {n}");
        }
        Ok(n as i64)
    }

    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_usize()? as u64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            v => bail!("expected bool, got {v:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            v => bail!("expected array, got {v:?}"),
        }
    }

    /// `[1, 2, 3]` -> `Vec<usize>`.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_f64()? as f32)).collect()
    }

    // ------------------------------------------------------------- builders

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f32(v: &[f32]) -> Value {
        Value::Arr(v.iter().map(|&x| Value::Num(x as f64)).collect())
    }

    pub fn arr_str(v: &[String]) -> Value {
        Value::Arr(v.iter().map(|s| Value::Str(s.clone())).collect())
    }

    // --------------------------------------------------------------- writer

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact (non-pretty) serialization; `value.to_string()` comes for free.
impl std::fmt::Display for Value {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// -------------------------------------------------------------------- parse

pub fn parse(text: &str) -> Result<Value> {
    let bytes = text.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        bail!("trailing content at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn err(&self, msg: &str) -> anyhow::Error {
        anyhow!("JSON parse error at byte {}: {msg}", self.pos)
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| self.err("unexpected end"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump()? != b {
            self.pos -= 1;
            return Err(self.err(&format!("expected `{}`", b as char)));
        }
        Ok(())
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{lit}`")))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'n' => self.literal("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte `{}`", c as char))),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Value::Obj(map)),
                _ => {
                    self.pos -= 1;
                    return Err(self.err("expected `,` or `}`"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Value::Arr(arr)),
                _ => {
                    self.pos -= 1;
                    return Err(self.err("expected `,` or `]`"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16).ok_or_else(|| self.err("bad \\u escape"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-assemble UTF-8 multibyte sequences byte-by-byte.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated UTF-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid UTF-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Value::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{"version": 3, "model": "resnet_s", "ok": true, "x": null,
                        "shape": [1, 2.5, -3e2], "nested": {"a": "b\n\"c\""}}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.req("version").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.req("model").unwrap().as_str().unwrap(), "resnet_s");
        assert!(v.req("ok").unwrap().as_bool().unwrap());
        assert_eq!(v.req("x").unwrap(), &Value::Null);
        let shape = v.req("shape").unwrap().as_arr().unwrap();
        assert_eq!(shape[2].as_f64().unwrap(), -300.0);
        assert_eq!(v.req("nested").unwrap().req("a").unwrap().as_str().unwrap(), "b\n\"c\"");
        // write -> parse -> equal
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"\\q\"").is_err());
    }

    #[test]
    fn unicode_strings() {
        let v = parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ☕");
    }

    #[test]
    fn integer_formatting_stable() {
        assert_eq!(Value::Num(42.0).to_string(), "42");
        assert_eq!(Value::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn usize_vec() {
        let v = parse("[256, 32, 32, 3]").unwrap();
        assert_eq!(v.as_usize_vec().unwrap(), vec![256, 32, 32, 3]);
        assert!(parse("[1.5]").unwrap().as_usize_vec().is_err());
    }
}
