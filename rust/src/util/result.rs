//! The one `RESULT {json}` emitter every subcommand shares.
//!
//! Scripts (CI byte-diffs, the experiment harness) parse exactly one
//! machine line per run: `RESULT {...}` on stdout. Historically each
//! subcommand hand-built its own object, so the shapes drifted —
//! `search` had no subcommand tag, `calibrate` no worker count, and an
//! extractor had to special-case all of them. [`ResultLine`] fixes the
//! envelope: every line is an object with a `cmd` tag, the run identity
//! fields that were actually set (`seed`, `algo`, `metric`, `workers`),
//! and the subcommand's own summary under `payload`. Keys serialize in
//! sorted order (see [`crate::util::json::Value`]), so a line is
//! byte-stable for a given set of fields.
//!
//! Determinism caveat baked into the schema: CI diffs RESULT lines
//! *across worker counts* to prove sharded determinism, so callers on
//! those paths must pass the envelope only fields that are themselves
//! worker-independent — or let CI normalize `"workers":N` before
//! diffing (the workflow does exactly that).

use super::json::Value;
use std::collections::BTreeMap;

/// Builder for one stable `RESULT {json}` stdout line.
#[derive(Debug, Clone)]
pub struct ResultLine {
    fields: BTreeMap<String, Value>,
}

impl ResultLine {
    /// Start a line for subcommand `cmd` (the envelope's `cmd` key).
    pub fn new(cmd: &str) -> Self {
        let mut fields = BTreeMap::new();
        fields.insert("cmd".to_string(), Value::Str(cmd.to_string()));
        Self { fields }
    }

    pub fn seed(self, seed: u64) -> Self {
        self.field("seed", Value::Num(seed as f64))
    }

    /// Algorithm label (e.g. `Greedy`), as printed by `SearchAlgo::label`.
    pub fn algo(self, algo: &str) -> Self {
        self.field("algo", Value::Str(algo.to_string()))
    }

    /// Sensitivity metric label (e.g. `Hessian`).
    pub fn metric(self, metric: &str) -> Self {
        self.field("metric", Value::Str(metric.to_string()))
    }

    pub fn workers(self, workers: usize) -> Self {
        self.field("workers", Value::Num(workers as f64))
    }

    /// The subcommand's own summary object.
    pub fn payload(self, payload: Value) -> Self {
        self.field("payload", payload)
    }

    fn field(mut self, key: &str, value: Value) -> Self {
        self.fields.insert(key.to_string(), value);
        self
    }

    /// The full line, exactly as printed (no trailing newline).
    pub fn render(&self) -> String {
        format!("RESULT {}", Value::Obj(self.fields.clone()))
    }

    /// Print the line to stdout.
    pub fn emit(&self) {
        println!("{}", self.render());
    }
}

/// Parse a rendered `RESULT {json}` line back into its JSON envelope —
/// the extractor-side inverse of [`ResultLine::render`].
pub fn parse_result_line(line: &str) -> crate::Result<Value> {
    let rest = line
        .strip_prefix("RESULT ")
        .ok_or_else(|| anyhow::anyhow!("not a RESULT line: `{line}`"))?;
    super::json::parse(rest)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn envelope_is_sorted_and_tagged() {
        let line = ResultLine::new("search")
            .workers(2)
            .seed(7)
            .algo("Greedy")
            .payload(Value::obj(vec![("evals", Value::Num(12.0))]))
            .render();
        assert_eq!(
            line,
            "RESULT {\"algo\":\"Greedy\",\"cmd\":\"search\",\"payload\":{\"evals\":12},\
             \"seed\":7,\"workers\":2}"
        );
    }

    #[test]
    fn roundtrips_through_the_parser() {
        let line = ResultLine::new("pareto").seed(3).metric("Hessian").render();
        let v = parse_result_line(&line).unwrap();
        assert_eq!(v.req("cmd").unwrap().as_str().unwrap(), "pareto");
        assert_eq!(v.req("seed").unwrap().as_u64().unwrap(), 3);
        assert_eq!(v.req("metric").unwrap().as_str().unwrap(), "Hessian");
        assert!(parse_result_line("nope {}").is_err());
    }

    #[test]
    fn unset_fields_stay_absent() {
        let v = parse_result_line(&ResultLine::new("experiment").render()).unwrap();
        assert!(v.get("seed").is_none());
        assert!(v.get("workers").is_none());
    }
}
