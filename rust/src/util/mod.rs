//! In-tree replacements for the support crates this offline build cannot
//! pull from crates.io (serde/clap/rand equivalents). Small, tested, and
//! scoped to exactly what the coordinator needs.

pub mod cli;
pub mod fs;
pub mod json;
pub mod result;
pub mod rng;
