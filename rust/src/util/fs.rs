//! Crash-safe file writes shared by every persistent store.

use std::path::Path;

use anyhow::Context as _;

use crate::Result;

/// Write `text` to `path` atomically: the bytes go to a
/// `.<name>.tmp.<pid>` sibling first, then an atomic rename commits them.
/// A crash mid-write leaves either the old file or the new one — never a
/// truncated file that poisons every later load. Used by the eval cache,
/// the search decision log, and the sweep checkpoint.
pub fn atomic_write_text(path: &Path, text: &str) -> Result<()> {
    let file_name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "file".to_string());
    let tmp = path.with_file_name(format!(".{file_name}.tmp.{}", std::process::id()));
    std::fs::write(&tmp, text).with_context(|| format!("writing temp file {}", tmp.display()))?;
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        return Err(anyhow::Error::new(e).context(format!("committing {}", path.display())));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_replaces_without_temp_droppings() {
        let dir = std::env::temp_dir();
        let path = dir.join("mpq_atomic_write_test.json");
        let _ = std::fs::remove_file(&path);
        atomic_write_text(&path, "one").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "one");
        atomic_write_text(&path, "two").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "two");
        let leftovers = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| {
                let n = e.file_name().to_string_lossy().into_owned();
                n.contains("mpq_atomic_write_test") && n.contains(".tmp.")
            })
            .count();
        assert_eq!(leftovers, 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rename_failure_cleans_up_the_temp() {
        // Committing into a missing directory fails at rename (the temp
        // write targets the same missing dir, so it fails first there) —
        // either way no temp file survives and the error names the path.
        let path = std::env::temp_dir().join("mpq_no_such_dir").join("x.json");
        assert!(atomic_write_text(&path, "data").is_err());
    }
}
