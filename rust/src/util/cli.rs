//! Tiny command-line parser: `mpq <subcommand> [--key value | --flag]...`.

use std::collections::HashMap;
use std::str::FromStr;

use anyhow::{anyhow, bail, Result};

/// Parsed invocation: one subcommand plus `--key value` options and any
/// positional operands (`mpq experiment run suite.yaml`). Subcommands
/// that take no operands must call [`Args::reject_positionals`] so a
/// stray token still fails loudly.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub cmd: String,
    opts: HashMap<String, String>,
    flags: Vec<String>,
    pos: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (program name excluded).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self> {
        let mut it = args.into_iter().peekable();
        let cmd = it.next().unwrap_or_default();
        let mut opts = HashMap::new();
        let mut flags = Vec::new();
        let mut pos = Vec::new();
        while let Some(a) = it.next() {
            let Some(key) = a.strip_prefix("--") else {
                pos.push(a);
                continue;
            };
            // --key=value or --key value or boolean --flag
            if let Some((k, v)) = key.split_once('=') {
                opts.insert(k.to_string(), v.to_string());
            } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                opts.insert(key.to_string(), it.next().unwrap());
            } else {
                flags.push(key.to_string());
            }
        }
        Ok(Self { cmd, opts, flags, pos })
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Positional operands in order (after the subcommand, non-`--` tokens
    /// not consumed as option values).
    pub fn positionals(&self) -> &[String] {
        &self.pos
    }

    /// The `i`-th positional operand, if given.
    pub fn positional(&self, i: usize) -> Option<&str> {
        self.pos.get(i).map(|s| s.as_str())
    }

    /// Fail if any positional operand was given — the historical contract
    /// for every subcommand that only takes `--key value` options.
    pub fn reject_positionals(&self) -> Result<()> {
        if let Some(p) = self.pos.first() {
            bail!("unexpected positional argument `{p}`");
        }
        Ok(())
    }

    pub fn get_str(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// Required string option.
    pub fn req_str(&self, name: &str) -> Result<&str> {
        self.get_str(name).ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    /// Typed option with default.
    pub fn get_or<T: FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get_str(name) {
            None => Ok(default),
            Some(s) => s.parse::<T>().map_err(|e| anyhow!("bad --{name} `{s}`: {e}")),
        }
    }

    /// Typed required option.
    pub fn req<T: FromStr>(&self, name: &str) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        let s = self.req_str(name)?;
        s.parse::<T>().map_err(|e| anyhow!("bad --{name} `{s}`: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("search --model bert_s --target 0.99 --verbose");
        assert_eq!(a.cmd, "search");
        assert_eq!(a.req_str("model").unwrap(), "bert_s");
        assert_eq!(a.req::<f64>("target").unwrap(), 0.99);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.get_or("seed", 7u64).unwrap(), 7);
    }

    #[test]
    fn equals_form() {
        let a = parse("eval --bits=4 --model=resnet_s");
        assert_eq!(a.req::<f32>("bits").unwrap(), 4.0);
        assert_eq!(a.req_str("model").unwrap(), "resnet_s");
    }

    #[test]
    fn negative_number_values() {
        let a = parse("eval --lr -1e-5");
        // `-1e-5` does not start with `--`, so it is consumed as a value.
        assert_eq!(a.req::<f64>("lr").unwrap(), -1e-5);
    }

    #[test]
    fn positionals_are_collected_and_rejectable() {
        let a = parse("experiment run suite.yaml --out exp --update-baseline");
        assert_eq!(a.positionals(), ["run".to_string(), "suite.yaml".to_string()]);
        assert_eq!(a.positional(0), Some("run"));
        assert_eq!(a.positional(2), None);
        assert_eq!(a.req_str("out").unwrap(), "exp");
        assert!(a.flag("update-baseline"));
        assert!(a.reject_positionals().is_err());
        // Option-only invocations still pass the no-positional check.
        assert!(parse("eval --bits 4").reject_positionals().is_ok());
    }

    #[test]
    fn missing_required_errors() {
        let a = parse("eval");
        assert!(a.req_str("model").is_err());
        assert!(a.req::<f32>("bits").is_err());
    }
}
