//! Seeded PRNG: xoshiro256** with splitmix64 seeding, plus the Gaussian
//! sampling and shuffling the sensitivity metrics need. Deterministic for
//! a given seed across platforms — sensitivity orderings and random
//! baselines must be reproducible run-to-run.

/// xoshiro256** (Blackman & Vigna), seeded via splitmix64.
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller sample.
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Trial-addressable sub-seed: mixes a base seed with a trial index so
/// independent Monte-Carlo draws (e.g. Hutchinson probes) can be generated
/// in any order — and on any worker — yet depend only on `(seed, trial)`.
/// Distinct trials land in distinct splitmix64 streams.
pub fn probe_seed(seed: u64, trial: u64) -> u64 {
    let mut state = seed ^ trial.wrapping_add(1).wrapping_mul(0x9E3779B97F4A7C15);
    splitmix64(&mut state)
}

/// (layer, trial)-addressable sub-seed for the ε_N noise metric: every
/// perturbation draw depends only on `(seed, layer, trial)`, never on
/// which worker runs it or in what order — the property that makes the
/// sharded noise metric bit-identical at any worker count. Domain-tagged
/// so noise draws and Hessian probes never share a splitmix64 stream even
/// under the same base seed.
pub fn noise_seed(seed: u64, layer: u64, trial: u64) -> u64 {
    probe_seed(probe_seed(seed ^ 0x906e_5eed_0b57_ac1e, layer), trial)
}

/// (layer, layer, trial)-addressable sub-seed for the inter-layer metric:
/// symmetric in `(i, j)` (the pair is sorted before mixing) so the
/// perturbation stream for the unordered pair `{i, j}` is well defined,
/// and domain-tagged so pair draws never share a splitmix64 stream with
/// Hessian probes or ε_N noise draws under the same base seed. The
/// diagonal entries `pair_seed(seed, l, l, trial)` seed the single-layer
/// draws that the paired runs reuse, making the interaction term an exact
/// per-trial finite difference.
pub fn pair_seed(seed: u64, i: u64, j: u64, trial: u64) -> u64 {
    let (lo, hi) = if i <= j { (i, j) } else { (j, i) };
    probe_seed(probe_seed(probe_seed(seed ^ 0x9a17_5eed_ca55_b1e5, lo), hi), trial)
}

impl Rng {
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s, spare: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Rejection-free for our n << 2^64 use cases; bias is negligible.
        (self.next_u64() % n as u64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Rademacher ±1.0 (Hutchinson probes).
    pub fn rademacher(&mut self) -> f32 {
        if self.bool() {
            1.0
        } else {
            -1.0
        }
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        // u in (0, 1] to keep ln finite.
        let u = 1.0 - self.uniform();
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        let mut c = Rng::seed_from(43);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Rng::seed_from(1);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::seed_from(2);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn probe_seeds_are_stable_and_distinct() {
        assert_eq!(probe_seed(7, 3), probe_seed(7, 3));
        let seeds: Vec<u64> = (0..64).map(|t| probe_seed(42, t)).collect();
        let mut unique = seeds.clone();
        unique.sort_unstable();
        unique.dedup();
        assert_eq!(unique.len(), seeds.len(), "trial seeds collided");
        assert_ne!(probe_seed(1, 0), probe_seed(2, 0));
    }

    #[test]
    fn noise_seeds_are_stable_distinct_and_domain_separated() {
        assert_eq!(noise_seed(7, 3, 1), noise_seed(7, 3, 1));
        // Distinct across the (layer, trial) grid.
        let mut seeds: Vec<u64> = (0..8)
            .flat_map(|l| (0..8).map(move |t| noise_seed(42, l, t)))
            .collect();
        let total = seeds.len();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), total, "noise seeds collided");
        // Never the same stream as a Hessian probe with the same indices.
        assert_ne!(noise_seed(42, 0, 3), probe_seed(42, 3));
        assert_ne!(noise_seed(1, 2, 3), noise_seed(2, 2, 3));
    }

    #[test]
    fn pair_seeds_are_symmetric_distinct_and_domain_separated() {
        assert_eq!(pair_seed(7, 1, 3, 2), pair_seed(7, 1, 3, 2));
        // Symmetric in the unordered pair.
        assert_eq!(pair_seed(42, 2, 5, 1), pair_seed(42, 5, 2, 1));
        // Distinct across the sorted (i <= j, trial) grid.
        let mut seeds: Vec<u64> = (0..8u64)
            .flat_map(|i| (i..8).flat_map(move |j| (0..8).map(move |t| pair_seed(42, i, j, t))))
            .collect();
        let total = seeds.len();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), total, "pair seeds collided");
        // Never the same stream as a Hessian probe or an ε_N noise draw
        // with the same indices under the same base seed.
        assert_ne!(pair_seed(42, 0, 0, 3), probe_seed(42, 3));
        assert_ne!(pair_seed(42, 2, 2, 3), noise_seed(42, 2, 3));
        assert_ne!(pair_seed(1, 2, 3, 4), pair_seed(2, 2, 3, 4));
    }

    #[test]
    fn rademacher_balanced() {
        let mut r = Rng::seed_from(4);
        let n = 10_000;
        let pos = (0..n).filter(|_| r.rademacher() > 0.0).count();
        assert!((pos as f64 / n as f64 - 0.5).abs() < 0.03);
    }
}
