//! ε_N (Eqs. 3–5): loss degradation under Gaussian weight perturbation.
//!
//! For each quantizable tensor w_i, sample ν ~ N(0, λ·max|w_i|), replace
//! w_i by w_i + ν, and measure the calibration loss increase relative to
//! the clean model. Averaged over `trials` draws; the per-trial scatter is
//! the source of this metric's instability the paper highlights in Fig. 4.

use crate::coordinator::Pipeline;
use crate::util::rng::Rng;
use crate::Result;

use super::{MetricKind, Sensitivity};

#[derive(Debug, Clone)]
pub struct NoiseOptions {
    /// Perturbation scale λ relative to max|w| (Eq. 5).
    pub lambda: f64,
    /// Independent perturbation draws per layer.
    pub trials: usize,
}

impl Default for NoiseOptions {
    fn default() -> Self {
        Self { lambda: 0.05, trials: 3 }
    }
}

pub fn noise_sensitivity(
    pipeline: &mut Pipeline,
    opts: &NoiseOptions,
    seed: u64,
) -> Result<Sensitivity> {
    let n = pipeline.num_quant_layers();
    // ε_N isolates parameter perturbation from quantization: the model
    // itself stays unquantized (Eq. 3).
    let clean_loss = pipeline.calib_loss_float()?;
    let mut rng = Rng::seed_from(seed);
    let mut scores = vec![0.0f64; n];
    for qi in 0..n {
        let mut acc = 0.0f64;
        for _ in 0..opts.trials {
            let (pi, perturbed) = pipeline.gaussian_perturbation(qi, opts.lambda, &mut rng)?;
            let loss = pipeline.calib_loss_with_perturbed(pi, &perturbed)?;
            acc += loss - clean_loss;
        }
        scores[qi] = acc / opts.trials as f64;
    }
    Ok(Sensitivity::from_scores(MetricKind::Noise, scores))
}
