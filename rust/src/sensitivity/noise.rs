//! ε_N (Eqs. 3–5): loss degradation under Gaussian weight perturbation.
//!
//! For each quantizable tensor w_i, sample ν ~ N(0, λ·max|w_i|), replace
//! w_i by w_i + ν, and measure the calibration loss increase relative to
//! the clean model. Averaged over `trials` draws; the per-trial scatter is
//! the source of this metric's instability the paper highlights in Fig. 4.
//!
//! The `layer × trial` grid runs through the sharded stage driver
//! ([`crate::coordinator::shard::noise_scores_sharded`]): every draw is
//! seeded by [`crate::util::rng::noise_seed`]`(seed, layer, trial)` and
//! reduction is host-side in item order, so [`noise_sensitivity`] (one
//! pipeline) and [`noise_sensitivity_pooled`] (trials fanned across a
//! [`PipelinePool`]) are bit-identical at every worker count.

use crate::coordinator::{noise_scores_sharded, Pipeline, PipelinePool};
use crate::Result;

use super::{MetricKind, Sensitivity};

#[derive(Debug, Clone)]
pub struct NoiseOptions {
    /// Perturbation scale λ relative to max|w| (Eq. 5).
    pub lambda: f64,
    /// Independent perturbation draws per layer.
    pub trials: usize,
}

impl Default for NoiseOptions {
    fn default() -> Self {
        Self { lambda: 0.05, trials: 3 }
    }
}

/// Single-pipeline estimate (one worker; perturbation trials run
/// back-to-back).
pub fn noise_sensitivity(
    pipeline: &mut Pipeline,
    opts: &NoiseOptions,
    seed: u64,
) -> Result<Sensitivity> {
    let scores = noise_scores_sharded(pipeline, opts.lambda, opts.trials.max(1), seed)?;
    Ok(Sensitivity::from_scores(MetricKind::Noise, scores))
}

/// Pool-sharded estimate: the (layer, trial) perturbation grid fans
/// across the pool's worker pipelines — each worker uploads only its own
/// perturbed tensors, closing the last serial sensitivity loop.
/// Bit-identical to [`noise_sensitivity`] at every worker count (both run
/// through the sharded driver's (layer, trial)-addressed draws).
pub fn noise_sensitivity_pooled(
    pool: &mut PipelinePool,
    opts: &NoiseOptions,
    seed: u64,
) -> Result<Sensitivity> {
    let scores = noise_scores_sharded(pool, opts.lambda, opts.trials.max(1), seed)?;
    Ok(Sensitivity::from_scores(MetricKind::Noise, scores))
}
