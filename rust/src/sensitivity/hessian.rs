//! ε_Hessian (Eq. 6): per-layer mean Hessian trace via Hutchinson probes.
//!
//! The heavy lifting (the Hessian-vector products) happens in the AOT
//! `hvp` graph — `grad` composed with `jvp` over the float loss — driven by
//! [`Pipeline::hessian_trace`]. This wrapper just shapes the result into a
//! [`Sensitivity`] ordering. Larger trace ⇒ sharper local curvature ⇒ more
//! sensitive to quantization (Dong et al., 2019; 2020).

use crate::coordinator::Pipeline;
use crate::Result;

use super::{MetricKind, Sensitivity};

pub fn hessian_sensitivity(
    pipeline: &mut Pipeline,
    trials: usize,
    seed: u64,
) -> Result<Sensitivity> {
    let scores = pipeline.hessian_trace(trials.max(1), seed)?;
    Ok(Sensitivity::from_scores(MetricKind::Hessian, scores))
}
