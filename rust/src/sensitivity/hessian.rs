//! ε_Hessian (Eq. 6): per-layer mean Hessian trace via Hutchinson probes.
//!
//! The heavy lifting (the Hessian-vector products) happens in the AOT
//! `hvp` graph — `grad` composed with `jvp` over the float loss — driven
//! by the sharded stage driver [`crate::coordinator::shard`]: probes are
//! seeded per trial, fanned across workers, and reduced host-side in
//! trial order. These wrappers just shape the result into a
//! [`Sensitivity`] ordering. Larger trace ⇒ sharper local curvature ⇒
//! more sensitive to quantization (Dong et al., 2019; 2020).

use crate::coordinator::{hessian_trace_sharded, Pipeline, PipelinePool};
use crate::Result;

use super::{MetricKind, Sensitivity};

/// Single-pipeline estimate (one worker; HVPs run back-to-back).
pub fn hessian_sensitivity(
    pipeline: &mut Pipeline,
    trials: usize,
    seed: u64,
) -> Result<Sensitivity> {
    let scores = pipeline.hessian_trace(trials.max(1), seed)?;
    Ok(Sensitivity::from_scores(MetricKind::Hessian, scores))
}

/// Pool-sharded estimate: trials fan across the pool's worker pipelines —
/// HVPs are the most expensive graph in the system, so this is where
/// sensitivity-guided search gains the most from `--workers`. Bit-identical
/// to [`hessian_sensitivity`] at every worker count (both run through the
/// sharded driver's trial-addressed probes).
pub fn hessian_sensitivity_pooled(
    pool: &mut PipelinePool,
    trials: usize,
    seed: u64,
) -> Result<Sensitivity> {
    let scores = hessian_trace_sharded(pool, trials.max(1), seed)?;
    Ok(Sensitivity::from_scores(MetricKind::Hessian, scores))
}
