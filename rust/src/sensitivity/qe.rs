//! ε_QE (Eq. 2): max-normalized RMS quantization error per weight tensor.
//!
//! Computed host-side with the native Eq. 1 mirror (bit-exact with the
//! Pallas `qe_stats` kernel — cross-checked in the integration tests), at
//! the most aggressive supported width: the more a tensor distorts at the
//! harshest precision, the more sensitive it is assumed to be.

use crate::coordinator::Pipeline;
use crate::quant::{eps_qe, QUANT_BITS};

use super::{MetricKind, Sensitivity};

/// Bit width the error is probed at (the lowest searchable precision).
pub const PROBE_BITS: f32 = QUANT_BITS[QUANT_BITS.len() - 1];

pub fn qe_sensitivity(pipeline: &Pipeline) -> Sensitivity {
    let manifest = &pipeline.artifacts.manifest;
    let params = &pipeline.artifacts.params;
    let scores: Vec<f64> = manifest
        .quant_layers()
        .iter()
        .map(|layer| {
            let pi = params.index_of(&layer.param).expect("validated at load");
            eps_qe(params.values(pi), PROBE_BITS)
        })
        .collect();
    Sensitivity::from_scores(MetricKind::Qe, scores)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::FLOAT_BITS;

    #[test]
    fn probe_bits_is_the_harshest_candidate() {
        assert_eq!(PROBE_BITS, QUANT_BITS[QUANT_BITS.len() - 1]);
        assert!(QUANT_BITS.iter().all(|&b| b >= PROBE_BITS));
        assert!(PROBE_BITS < FLOAT_BITS);
    }

    #[test]
    fn grid_aligned_tensors_have_zero_error() {
        // Multiples of maxabs / 2^(bits-1) are exactly representable at
        // the probe width, so the max-normalized RMSE vanishes.
        let step = (PROBE_BITS - 1.0).exp2();
        let x = [0.0f32, 1.0, -1.0, 1.0 / step, -3.0 / step];
        assert_eq!(eps_qe(&x, PROBE_BITS), 0.0);
        // Off-grid values must not.
        let rough = [0.37f32, -0.91, 0.053, 1.0];
        assert!(eps_qe(&rough, PROBE_BITS) > 0.0);
    }

    #[test]
    fn error_is_max_normalized_scale_invariant() {
        let x = [0.37f32, -0.91, 0.053, 1.0, -0.42];
        let base = eps_qe(&x, PROBE_BITS);
        // Power-of-two rescaling is bit-exact through the normalization.
        let doubled: Vec<f32> = x.iter().map(|&v| 2.0 * v).collect();
        assert_eq!(eps_qe(&doubled, PROBE_BITS).to_bits(), base.to_bits());
        // Arbitrary positive rescaling agrees to rounding error.
        let scaled: Vec<f32> = x.iter().map(|&v| 3.7 * v).collect();
        assert!((eps_qe(&scaled, PROBE_BITS) - base).abs() < 1e-6);
    }

    #[test]
    fn error_grows_as_bits_shrink() {
        let x = [0.37f32, -0.91, 0.053, 1.0, -0.42];
        let harsh = eps_qe(&x, PROBE_BITS);
        let mild = eps_qe(&x, QUANT_BITS[0]);
        assert!(harsh > mild, "harsh {harsh} vs mild {mild}");
        assert_eq!(eps_qe(&x, FLOAT_BITS), 0.0, "float width is lossless");
    }

    #[test]
    fn scores_rank_rough_tensors_more_sensitive() {
        // The same per-tensor scoring qe_sensitivity applies, without the
        // artifact plumbing: a grid-aligned tensor ranks least sensitive,
        // rougher tensors rank later.
        let layers: [&[f32]; 3] = [
            &[0.37, -0.91, 0.053, 1.0],
            &[0.5, -0.25, 1.0, 0.0],
            &[0.333, 0.777, -0.123, 0.9],
        ];
        let scores: Vec<f64> = layers.iter().map(|w| eps_qe(w, PROBE_BITS)).collect();
        let sens = Sensitivity::from_scores(MetricKind::Qe, scores.clone());
        assert_eq!(sens.metric, MetricKind::Qe);
        assert_eq!(sens.order[0], 1, "grid-aligned tensor must rank first: {scores:?}");
        assert!(scores.iter().all(|s| s.is_finite() && *s >= 0.0));
    }
}
