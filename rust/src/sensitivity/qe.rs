//! ε_QE (Eq. 2): max-normalized RMS quantization error per weight tensor.
//!
//! Computed host-side with the native Eq. 1 mirror (bit-exact with the
//! Pallas `qe_stats` kernel — cross-checked in the integration tests), at
//! the most aggressive supported width: the more a tensor distorts at the
//! harshest precision, the more sensitive it is assumed to be.

use crate::coordinator::Pipeline;
use crate::quant::{eps_qe, QUANT_BITS};

use super::{MetricKind, Sensitivity};

/// Bit width the error is probed at (the lowest searchable precision).
pub const PROBE_BITS: f32 = QUANT_BITS[QUANT_BITS.len() - 1];

pub fn qe_sensitivity(pipeline: &Pipeline) -> Sensitivity {
    let manifest = &pipeline.artifacts.manifest;
    let params = &pipeline.artifacts.params;
    let scores: Vec<f64> = manifest
        .quant_layers()
        .iter()
        .map(|layer| {
            let pi = params.index_of(&layer.param).expect("validated at load");
            eps_qe(params.values(pi), PROBE_BITS)
        })
        .collect();
    Sensitivity::from_scores(MetricKind::Qe, scores)
}
