//! Inter-layer-augmented Hessian score: cross-layer sensitivity from
//! paired perturbations.
//!
//! The paper's three metrics score layers independently, which misses
//! quantization-error interactions between layers (a δᵢᵀHδⱼ cross term
//! in the second-order loss expansion). This metric estimates that term
//! directly with finite differences: for every layer pair (i, j) and
//! trial t, perturb both layers with the *same* Gaussian draws used in
//! their single-layer baseline cells and measure
//!
//! ```text
//! I(i, j, t) = L(w + δᵢ + δⱼ) − L(w + δᵢ) − L(w + δⱼ) + L(w)
//! ```
//!
//! which is an exact per-trial estimate of the interaction term (the
//! first-order and diagonal second-order contributions cancel). A layer's
//! score is its mean diagonal degradation plus the summed magnitudes of
//! its mean interactions with every other layer, so strongly coupled
//! pairs are ranked more sensitive than their diagonal terms alone would
//! suggest.
//!
//! The symmetric (layer, layer, trial) grid is flattened pair-major
//! (upper triangle, [`crate::quant::calibrate::pair_index`]) and runs
//! through the sharded stage driver
//! ([`crate::coordinator::shard::interlayer_scores_sharded`]): every draw
//! is seeded by [`crate::util::rng::pair_seed`]`(seed, l, l, trial)` and
//! reduction is host-side in fixed order, so [`interlayer_sensitivity`]
//! (one pipeline) and [`interlayer_sensitivity_pooled`] (pairs fanned
//! across a [`PipelinePool`]) are bit-identical at every worker count.

use crate::coordinator::{interlayer_scores_sharded, Pipeline, PipelinePool};
use crate::Result;

use super::{MetricKind, Sensitivity};

#[derive(Debug, Clone)]
pub struct InterLayerOptions {
    /// Perturbation scale λ relative to max|w|, matching ε_N (Eq. 5) so
    /// the diagonal cells reproduce the noise metric's degradation scale.
    pub lambda: f64,
    /// Independent paired draws per (i, j) cell.
    pub trials: usize,
}

impl Default for InterLayerOptions {
    fn default() -> Self {
        Self { lambda: 0.05, trials: 3 }
    }
}

/// Single-pipeline estimate (one worker; pair cells run back-to-back).
pub fn interlayer_sensitivity(
    pipeline: &mut Pipeline,
    opts: &InterLayerOptions,
    seed: u64,
) -> Result<Sensitivity> {
    let scores = interlayer_scores_sharded(pipeline, opts.lambda, opts.trials.max(1), seed)?;
    Ok(Sensitivity::from_scores(MetricKind::InterLayer, scores))
}

/// Pool-sharded estimate: the pair-major (pair, trial) grid fans across
/// the pool's worker pipelines. Bit-identical to
/// [`interlayer_sensitivity`] at every worker count (both run through the
/// sharded driver's pair-addressed draws and fixed-order reduction).
pub fn interlayer_sensitivity_pooled(
    pool: &mut PipelinePool,
    opts: &InterLayerOptions,
    seed: u64,
) -> Result<Sensitivity> {
    let scores = interlayer_scores_sharded(pool, opts.lambda, opts.trials.max(1), seed)?;
    Ok(Sensitivity::from_scores(MetricKind::InterLayer, scores))
}
