//! The paper's three sensitivity metrics (§3.2), the cross-layer
//! inter-layer-augmented Hessian metric, and the uninformed (random)
//! baseline, each producing per-layer scores and an ascending ordering
//! (least sensitive first) for the configuration searches.
//!
//! The device-driven metrics run through the sharded stage driver
//! ([`crate::coordinator::shard`]): [`hessian_sensitivity_pooled`] fans
//! Hutchinson trials, [`noise_sensitivity_pooled`] fans the ε_N
//! (layer, trial) perturbation grid, and
//! [`interlayer_sensitivity_pooled`] fans the symmetric
//! (layer, layer, trial) paired-perturbation grid across a
//! [`crate::coordinator::PipelinePool`]; all are bit-identical to their
//! single-pipeline counterparts at every worker count because every
//! Monte-Carlo draw is item-seeded and reduction is host-side in global
//! item order. ε_QE is host-side math.

mod hessian;
mod interlayer;
mod noise;
mod qe;

pub use hessian::{hessian_sensitivity, hessian_sensitivity_pooled};
pub use interlayer::{interlayer_sensitivity, interlayer_sensitivity_pooled, InterLayerOptions};
pub use noise::{noise_sensitivity, noise_sensitivity_pooled, NoiseOptions};
pub use qe::qe_sensitivity;

use std::path::Path;

use crate::coordinator::Pipeline;
use crate::util::json::{self, Value};
use crate::util::rng::Rng;
use crate::Result;

/// Which metric guides the search.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MetricKind {
    /// Uninformed baseline: a seeded random permutation.
    Random,
    /// ε_QE — quantization error (Eq. 2).
    Qe,
    /// ε_N — accuracy degradation from Gaussian noise (Eqs. 3–5).
    Noise,
    /// ε_Hessian — Hutchinson mean Hessian trace (Eq. 6).
    Hessian,
    /// Inter-layer-augmented Hessian score: the diagonal ε_N-style term
    /// plus the summed pairwise finite-difference interaction magnitudes
    /// (the follow-up paper's cross-layer correction).
    InterLayer,
}

impl MetricKind {
    pub fn label(self) -> &'static str {
        match self {
            MetricKind::Random => "Random",
            MetricKind::Qe => "QE",
            MetricKind::Noise => "Noise",
            MetricKind::Hessian => "Hessian",
            MetricKind::InterLayer => "InterLayer",
        }
    }

    pub const ALL: [MetricKind; 5] = [
        MetricKind::Random,
        MetricKind::Qe,
        MetricKind::Noise,
        MetricKind::Hessian,
        MetricKind::InterLayer,
    ];
}

impl std::str::FromStr for MetricKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "random" => Ok(MetricKind::Random),
            "qe" => Ok(MetricKind::Qe),
            "noise" => Ok(MetricKind::Noise),
            "hessian" => Ok(MetricKind::Hessian),
            "interlayer" => Ok(MetricKind::InterLayer),
            other => anyhow::bail!("unknown metric `{other}` (random|qe|noise|hessian|interlayer)"),
        }
    }
}

/// Per-layer sensitivity scores and the ordering they induce.
#[derive(Debug, Clone)]
pub struct Sensitivity {
    pub metric: MetricKind,
    pub scores: Vec<f64>,
    /// Layer indices sorted by score ascending — least sensitive first,
    /// the order both search algorithms consume.
    pub order: Vec<usize>,
}

impl Sensitivity {
    pub fn from_scores(metric: MetricKind, scores: Vec<f64>) -> Self {
        let mut order: Vec<usize> = (0..scores.len()).collect();
        order.sort_by(|&a, &b| {
            scores[a].partial_cmp(&scores[b]).unwrap_or(std::cmp::Ordering::Equal)
        });
        Self { metric, scores, order }
    }

    /// Random "scores": a shuffled ranking, matching the paper's uninformed
    /// guidance baseline (5 seeds in the tables).
    pub fn random(num_layers: usize, seed: u64) -> Self {
        let mut order: Vec<usize> = (0..num_layers).collect();
        let mut rng = Rng::seed_from(seed);
        rng.shuffle(&mut order);
        let mut scores = vec![0.0f64; num_layers];
        for (rank, &layer) in order.iter().enumerate() {
            scores[layer] = rank as f64;
        }
        Self { metric: MetricKind::Random, scores, order }
    }
}

/// Compute a metric against a live pipeline.
pub fn compute(
    pipeline: &mut Pipeline,
    metric: MetricKind,
    trials: usize,
    seed: u64,
) -> Result<Sensitivity> {
    match metric {
        MetricKind::Random => Ok(Sensitivity::random(pipeline.num_quant_layers(), seed)),
        MetricKind::Qe => Ok(qe_sensitivity(pipeline)),
        MetricKind::Noise => {
            noise_sensitivity(pipeline, &NoiseOptions { trials, ..Default::default() }, seed)
        }
        MetricKind::Hessian => hessian_sensitivity(pipeline, trials, seed),
        MetricKind::InterLayer => {
            let opts = InterLayerOptions { trials, ..Default::default() };
            interlayer_sensitivity(pipeline, &opts, seed)
        }
    }
}

/// A versioned on-disk sensitivity score cache: one struct owns the path
/// layout and the schema gating that used to live in free
/// `load_score_cache`/`save_score_cache` helpers, so the sensitivity
/// cache and the frontier artifact share one versioned-cache idiom.
#[derive(Debug, Clone)]
pub struct ScoreCache {
    path: std::path::PathBuf,
    version: usize,
    /// Oldest file version still trusted for this entry. Version bumps
    /// that leave a metric's draw scheme untouched raise `version` (what
    /// [`ScoreCache::save`] stamps) without raising that metric's
    /// `min_version`, so existing caches survive the upgrade and only
    /// metrics whose math actually changed are recomputed.
    min_version: usize,
}

impl ScoreCache {
    /// Current schema version. History: v1 wrote unversioned files from
    /// the sequentially shared Hessian RNG; v2 moved the Hessian to
    /// trial-addressed seeds but kept serial shared-RNG noise; v3 is the
    /// sharded (layer, trial)-addressed noise metric; v4 adds the
    /// pair-addressed inter-layer metric. v4 changed no existing metric's
    /// draws, so v3 Hessian/noise/QE files are still accepted (see
    /// [`ScoreCache::min_version_for`]); v1/v2 files are always rejected
    /// and recomputed.
    pub const VERSION: usize = 4;

    /// A cache at an explicit `path` gated on exactly `version` (tests
    /// use this to fabricate stale files; production callers want
    /// [`ScoreCache::for_model`], which applies the per-metric minimum).
    pub fn new(path: impl Into<std::path::PathBuf>, version: usize) -> Self {
        Self { path: path.into(), version, min_version: version }
    }

    /// Oldest schema version whose files are still bit-identical to what
    /// the current code computes for `metric`. The inter-layer metric was
    /// introduced in v4; every other metric's draw scheme has been stable
    /// since v3.
    pub fn min_version_for(metric: MetricKind) -> usize {
        match metric {
            MetricKind::InterLayer => 4,
            MetricKind::Random
            | MetricKind::Qe
            | MetricKind::Noise
            | MetricKind::Hessian => 3,
        }
    }

    /// The canonical per-model layout at the current version:
    /// `<dir>/<model>_sens_<metric>_<trials>_<seed>.json`.
    pub fn for_model(
        dir: &Path,
        model: &str,
        metric: MetricKind,
        trials: usize,
        seed: u64,
    ) -> Self {
        let name = format!("{model}_sens_{}_{trials}_{seed}.json", metric.label().to_lowercase());
        Self {
            path: dir.join(name),
            version: Self::VERSION,
            min_version: Self::min_version_for(metric),
        }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Read the cached scores, returning them only when the file's schema
    /// version is in the accepted `[min_version, version]` window and the
    /// layer count matches. Anything else — missing file, unparsable
    /// JSON, an unversioned v1 file, a score vector for a different model
    /// shape — yields `None` so stale scores are recomputed, never
    /// trusted.
    pub fn load(&self, layers: usize) -> Option<Vec<f64>> {
        let v = json::parse(&std::fs::read_to_string(&self.path).ok()?).ok()?;
        let file_version = v.req("version").ok().and_then(|x| x.as_usize().ok()).unwrap_or(1);
        if file_version < self.min_version || file_version > self.version {
            return None;
        }
        let scores: Vec<f64> =
            v.req("scores").ok()?.as_arr().ok()?.iter().filter_map(|x| x.as_f64().ok()).collect();
        (scores.len() == layers).then_some(scores)
    }

    /// Write scores [`ScoreCache::load`] will accept back. Best-effort:
    /// the cache is an optimization, so write failures are swallowed.
    pub fn save(&self, scores: &[f64]) {
        let v = Value::obj(vec![
            ("version", Value::Num(self.version as f64)),
            ("scores", Value::Arr(scores.iter().map(|&s| Value::Num(s)).collect())),
        ]);
        let _ = std::fs::write(&self.path, v.to_string());
    }
}

/// Levenshtein (edit) distance between two orderings — the paper's measure
/// of how differently the metrics rank layers (§4.1).
pub fn levenshtein(a: &[usize], b: &[usize]) -> usize {
    let (la, lb) = (a.len(), b.len());
    let mut prev: Vec<usize> = (0..=lb).collect();
    let mut cur = vec![0usize; lb + 1];
    for i in 1..=la {
        cur[0] = i;
        for j in 1..=lb {
            let cost = usize::from(a[i - 1] != b[j - 1]);
            cur[j] = (prev[j] + 1).min(cur[j - 1] + 1).min(prev[j - 1] + cost);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[lb]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_ascending() {
        let s = Sensitivity::from_scores(MetricKind::Qe, vec![3.0, 1.0, 2.0]);
        assert_eq!(s.order, vec![1, 2, 0]);
    }

    #[test]
    fn random_is_seeded_permutation() {
        let a = Sensitivity::random(10, 7);
        let b = Sensitivity::random(10, 7);
        let c = Sensitivity::random(10, 8);
        assert_eq!(a.order, b.order);
        assert_ne!(a.order, c.order);
        let mut sorted = a.order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..10).collect::<Vec<_>>());
        // scores must induce the same order
        let re = Sensitivity::from_scores(MetricKind::Random, a.scores.clone());
        assert_eq!(re.order, a.order);
    }

    #[test]
    fn levenshtein_basics() {
        assert_eq!(levenshtein(&[1, 2, 3], &[1, 2, 3]), 0);
        assert_eq!(levenshtein(&[1, 2, 3], &[3, 2, 1]), 2);
        assert_eq!(levenshtein(&[], &[1, 2]), 2);
        assert_eq!(levenshtein(&[1, 2, 3, 4], &[2, 3, 4, 5]), 2);
    }
}
