//! One-pass Pareto frontier over the bit-assignment space.
//!
//! Every cell of `mpq report --sweep` used to re-run a full constrained
//! search. [`ParetoFront`] exploits the monotonicity baked into the
//! budgeted objectives (see `objective.rs`: quantization only ever
//! lowers modeled cost, so budgets choose *where to stop*, never *which
//! layer to accept*) to answer the whole budget × accuracy-floor grid
//! from one search per floor:
//!
//! 1. For each accuracy floor, run the search to *accuracy exhaustion*
//!    under a recording objective that never reports a budget as
//!    satisfied. The trail of committed configurations — float baseline
//!    included — is exactly the trajectory every budgeted search at that
//!    floor walks before stopping.
//! 2. Re-evaluate each trail point exactly (decision evals can be
//!    early-exited and replayed decisions carry no accuracy), attach
//!    modeled costs, and persist everything as a fingerprint-guarded
//!    `<model>_frontier.json` artifact.
//! 3. Any (budget, floor) cell is then the *first* trail point whose
//!    relative cost meets the budget — an O(1) read
//!    ([`crate::report::budget_sweep_from_frontier`]) that reproduces
//!    the re-searching sweep byte for byte.
//!
//! The driver shares the whole `api/` control surface with
//! [`super::run_search`]: the same [`SearchEvent`] stream, the same
//! per-floor decision-log [`Checkpoint`]s (so a killed build resumes
//! bit-identically), and — through [`super::SearchSession::run_pareto`]
//! — the same `EvalCache`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, ensure, Context as _};

use crate::coordinator::{ParallelEnv, SearchAlgo, SearchEnv};
use crate::quant::{QuantConfig, QUANT_BITS};
use crate::util::json::{self, Value};
use crate::Result;

use super::checkpoint::{checkpoint_fingerprint, Checkpoint};
use super::cost::CostModel;
use super::driver::run_search;
use super::events::SearchEvent;
use super::objective::{CellMetrics, Objective};
use super::synthetic::{SyntheticCost, SyntheticEnv};

/// Version gate for `<model>_frontier.json`. Bump when the schema or the
/// trail semantics change so stale artifacts are rejected, not misread.
pub const FRONTIER_VERSION: u64 = 1;

// ------------------------------------------------------------- artifact

/// One configuration on a floor's search trajectory, with its exact
/// accuracy and modeled costs.
#[derive(Debug, Clone, PartialEq)]
pub struct FrontierPoint {
    /// The committed bit assignment.
    pub config: QuantConfig,
    /// Exact accuracy of `config` (full evaluation, no early exit).
    pub accuracy: f64,
    /// Modeled latency relative to the float baseline.
    pub rel_latency: f64,
    /// Modeled size relative to the float baseline.
    pub rel_size: f64,
    /// Where the cost numbers came from (mirrors
    /// [`CostModel::provenance`]).
    pub cost_provenance: String,
    /// Decision evaluations consumed up to (and including) committing
    /// this point. A budgeted search stopping here reports
    /// `decisions + 1` evals (the `+1` is its final exact evaluation).
    pub decisions: usize,
}

impl FrontierPoint {
    /// True when `self` is at least as good as `other` on every axis and
    /// strictly better on at least one.
    pub fn dominates(&self, other: &FrontierPoint) -> bool {
        let no_worse = self.accuracy >= other.accuracy
            && self.rel_latency <= other.rel_latency
            && self.rel_size <= other.rel_size;
        let better = self.accuracy > other.accuracy
            || self.rel_latency < other.rel_latency
            || self.rel_size < other.rel_size;
        no_worse && better
    }

    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("bits_w", Value::arr_f32(&self.config.bits_w)),
            ("bits_a", Value::arr_f32(&self.config.bits_a)),
            ("accuracy", Value::Num(self.accuracy)),
            ("rel_latency", Value::Num(self.rel_latency)),
            ("rel_size", Value::Num(self.rel_size)),
            ("cost_provenance", Value::Str(self.cost_provenance.clone())),
            ("decisions", Value::Num(self.decisions as f64)),
        ])
    }

    fn from_json(v: &Value) -> Result<Self> {
        let bits_w = v.req("bits_w")?.as_f32_vec()?;
        let bits_a = v.req("bits_a")?.as_f32_vec()?;
        ensure!(bits_w.len() == bits_a.len(), "bits_w/bits_a length mismatch");
        Ok(FrontierPoint {
            config: QuantConfig { bits_w, bits_a },
            accuracy: v.req("accuracy")?.as_f64()?,
            rel_latency: v.req("rel_latency")?.as_f64()?,
            rel_size: v.req("rel_size")?.as_f64()?,
            cost_provenance: v.req("cost_provenance")?.as_str()?.to_string(),
            decisions: v.req("decisions")?.as_usize()?,
        })
    }
}

/// The full committed-configuration trajectory of one accuracy floor's
/// exhaustion search, float baseline first.
#[derive(Debug, Clone, PartialEq)]
pub struct FloorTrail {
    /// The floor as a fraction of the float baseline accuracy.
    pub floor: f64,
    /// The absolute accuracy floor the search guaranteed.
    pub abs_floor: f64,
    /// Total decision evaluations the exhaustion search consumed.
    pub decisions: usize,
    /// Committed configurations in commit order.
    pub points: Vec<FrontierPoint>,
}

impl FloorTrail {
    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("floor", Value::Num(self.floor)),
            ("abs_floor", Value::Num(self.abs_floor)),
            ("decisions", Value::Num(self.decisions as f64)),
            ("points", Value::Arr(self.points.iter().map(FrontierPoint::to_json).collect())),
        ])
    }

    fn from_json(v: &Value) -> Result<Self> {
        let points = v
            .req("points")?
            .as_arr()?
            .iter()
            .map(FrontierPoint::from_json)
            .collect::<Result<Vec<_>>>()?;
        ensure!(!points.is_empty(), "empty frontier trail");
        Ok(FloorTrail {
            floor: v.req("floor")?.as_f64()?,
            abs_floor: v.req("abs_floor")?.as_f64()?,
            decisions: v.req("decisions")?.as_usize()?,
            points,
        })
    }
}

/// The serializable frontier: per-floor trails plus enough provenance to
/// refuse lookups against the wrong search. Written atomically via
/// [`crate::util::fs::atomic_write_text`] and version/fingerprint-gated
/// like the decision-log checkpoint.
#[derive(Debug, Clone, PartialEq)]
pub struct FrontierArtifact {
    /// Algorithm that produced every trail.
    pub algo: SearchAlgo,
    /// Build fingerprint (see [`frontier_fingerprint`]).
    pub fingerprint: String,
    /// Float baseline accuracy all floors are relative to.
    pub float_accuracy: f64,
    /// Cost-model provenance shared by every point.
    pub cost_provenance: String,
    /// How many segments the layer order was partitioned into when the
    /// trails were built (1 = the monolithic whole-model search). K=1
    /// artifacts serialize without the field, so pre-partition artifacts
    /// load unchanged and K=1 builds stay byte-identical to them.
    pub partitions: usize,
    /// One trail per requested floor, in build order.
    pub trails: Vec<FloorTrail>,
}

impl FrontierArtifact {
    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("version", Value::Num(FRONTIER_VERSION as f64)),
            ("algo", Value::Str(self.algo.label().to_string())),
            ("fingerprint", Value::Str(self.fingerprint.clone())),
            ("float_accuracy", Value::Num(self.float_accuracy)),
            ("cost_provenance", Value::Str(self.cost_provenance.clone())),
        ];
        if self.partitions > 1 {
            fields.push(("partitions", Value::Num(self.partitions as f64)));
        }
        fields.push(("trails", Value::Arr(self.trails.iter().map(FloorTrail::to_json).collect())));
        Value::obj(fields)
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let trails = v
            .req("trails")?
            .as_arr()?
            .iter()
            .map(FloorTrail::from_json)
            .collect::<Result<Vec<_>>>()?;
        ensure!(!trails.is_empty(), "frontier artifact has no trails");
        let partitions = match v.get("partitions") {
            Some(p) => p.as_usize()?,
            None => 1,
        };
        ensure!(partitions >= 1, "frontier artifact has zero partitions");
        Ok(FrontierArtifact {
            algo: v.req("algo")?.as_str()?.parse()?,
            fingerprint: v.req("fingerprint")?.as_str()?.to_string(),
            float_accuracy: v.req("float_accuracy")?.as_f64()?,
            cost_provenance: v.req("cost_provenance")?.as_str()?.to_string(),
            partitions,
            trails,
        })
    }

    /// Write the artifact atomically (crash leaves old or new, never a
    /// truncated file).
    pub fn save(&self, path: &Path) -> Result<()> {
        crate::util::fs::atomic_write_text(path, &self.to_json().to_string())
            .with_context(|| format!("saving frontier artifact {}", path.display()))
    }

    /// Load and version-gate an artifact.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading frontier artifact {}", path.display()))?;
        let v = json::parse(&text)
            .with_context(|| format!("parsing frontier artifact {}", path.display()))?;
        let version = v.req("version")?.as_u64()?;
        ensure!(
            version == FRONTIER_VERSION,
            "frontier artifact {} is version {version}, this build reads {FRONTIER_VERSION}",
            path.display()
        );
        Self::from_json(&v)
            .with_context(|| format!("decoding frontier artifact {}", path.display()))
    }

    /// Refuse to serve lookups for a different search: the artifact must
    /// have been built by the same algorithm over the same floors, layer
    /// order, and evaluation environment.
    pub fn verify(&self, algo: SearchAlgo, order: &[usize], env_context: &str) -> Result<()> {
        let expected = partitioned_frontier_fingerprint(
            algo,
            &self.floors(),
            order,
            env_context,
            self.partitions,
        );
        ensure!(
            self.fingerprint == expected,
            "frontier artifact was built by a different search:\n  recorded: {}\n  expected: \
             {expected}",
            self.fingerprint
        );
        Ok(())
    }

    /// The floors this artifact has trails for, in build order.
    pub fn floors(&self) -> Vec<f64> {
        self.trails.iter().map(|t| t.floor).collect()
    }

    /// The trail built for exactly this floor (bit-exact match — floors
    /// come from the same parsed CLI/grid values on both sides).
    pub fn trail_for(&self, floor: f64) -> Option<&FloorTrail> {
        self.trails.iter().find(|t| t.floor.to_bits() == floor.to_bits())
    }

    /// Total number of recorded trail points across all floors.
    pub fn num_points(&self) -> usize {
        self.trails.iter().map(|t| t.points.len()).sum()
    }

    /// The dominated-filtered frontier: every distinct configuration no
    /// other recorded configuration beats on accuracy, latency, *and*
    /// size at once.
    pub fn pareto(&self) -> Vec<&FrontierPoint> {
        let mut seen = std::collections::HashSet::new();
        let mut distinct: Vec<&FrontierPoint> = Vec::new();
        for trail in &self.trails {
            for p in &trail.points {
                if seen.insert(p.config.key()) {
                    distinct.push(p);
                }
            }
        }
        distinct.iter().filter(|p| !distinct.iter().any(|q| q.dominates(p))).copied().collect()
    }

    /// Select the most accurate Pareto point satisfying `spec` (ties
    /// broken by lower latency, then lower size). Errors when no point
    /// qualifies — the caller should relax the constraints or rebuild
    /// the frontier with more floors.
    pub fn pick(&self, spec: &PickSpec) -> Result<&FrontierPoint> {
        self.pareto()
            .into_iter()
            .filter(|p| {
                spec.max_rel_latency.is_none_or(|b| p.rel_latency <= b)
                    && spec.max_rel_size.is_none_or(|b| p.rel_size <= b)
                    && spec.min_accuracy.is_none_or(|f| p.accuracy >= f * self.float_accuracy)
            })
            .max_by(|a, b| {
                let eq = std::cmp::Ordering::Equal;
                a.accuracy
                    .partial_cmp(&b.accuracy)
                    .unwrap_or(eq)
                    .then(b.rel_latency.partial_cmp(&a.rel_latency).unwrap_or(eq))
                    .then(b.rel_size.partial_cmp(&a.rel_size).unwrap_or(eq))
            })
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no frontier point satisfies --pick {} ({} Pareto points recorded)",
                    spec.describe(),
                    self.pareto().len()
                )
            })
    }

    /// Rank the Pareto set with an [`Objective`]'s scalarized
    /// [`Objective::score`] — `None` scores are infeasible and skipped.
    pub fn best_for(&self, objective: &dyn Objective) -> Option<&FrontierPoint> {
        self.pareto()
            .into_iter()
            .filter_map(|p| {
                let metrics = CellMetrics {
                    accuracy: p.accuracy,
                    rel_latency: p.rel_latency,
                    rel_size: p.rel_size,
                };
                objective.score(&metrics).map(|s| (p, s))
            })
            .max_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(p, _)| p)
    }
}

/// Identity of a frontier build: algorithm, floors (bit-exact), layer
/// order, and evaluation environment. Same scheme as
/// [`checkpoint_fingerprint`]; a lookup against a mismatching artifact
/// fails loudly instead of silently serving another model's trade-off.
pub fn frontier_fingerprint(
    algo: SearchAlgo,
    floors: &[f64],
    order: &[usize],
    env_context: &str,
) -> String {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    floors.len().hash(&mut h);
    for &f in floors {
        f.to_bits().hash(&mut h);
    }
    order.hash(&mut h);
    format!("frontier/{}/floors+order-{:016x}/{env_context}", algo.label(), h.finish())
}

/// [`frontier_fingerprint`] extended with the partition count: a composed
/// K>1 frontier must never be mistaken for (or resumed against) the
/// monolithic build, while K=1 keeps the exact historical fingerprint.
pub fn partitioned_frontier_fingerprint(
    algo: SearchAlgo,
    floors: &[f64],
    order: &[usize],
    env_context: &str,
    partitions: usize,
) -> String {
    let mut fp = frontier_fingerprint(algo, floors, order, env_context);
    if partitions > 1 {
        fp.push_str(&format!("/K{partitions}"));
    }
    fp
}

// ------------------------------------------------------------- pick spec

/// Serve-time constraints for [`FrontierArtifact::pick`], parsed from
/// `--pick latency<=B,size<=B,acc>=F`. The accuracy bound is a fraction
/// of the artifact's float baseline, matching how sweep floors are
/// specified everywhere else.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct PickSpec {
    pub max_rel_latency: Option<f64>,
    pub max_rel_size: Option<f64>,
    pub min_accuracy: Option<f64>,
}

impl PickSpec {
    /// Human-readable round-trip of the constraint terms.
    pub fn describe(&self) -> String {
        let mut terms = Vec::new();
        if let Some(b) = self.max_rel_latency {
            terms.push(format!("latency<={b}"));
        }
        if let Some(b) = self.max_rel_size {
            terms.push(format!("size<={b}"));
        }
        if let Some(f) = self.min_accuracy {
            terms.push(format!("acc>={f}"));
        }
        if terms.is_empty() {
            "(unconstrained)".to_string()
        } else {
            terms.join(",")
        }
    }
}

impl std::str::FromStr for PickSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        let mut spec = PickSpec::default();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            if let Some(v) = part.strip_prefix("latency<=") {
                spec.max_rel_latency = Some(v.trim().parse()?);
            } else if let Some(v) = part.strip_prefix("size<=") {
                spec.max_rel_size = Some(v.trim().parse()?);
            } else if let Some(v) = part.strip_prefix("acc>=") {
                spec.min_accuracy = Some(v.trim().parse()?);
            } else {
                bail!("bad --pick term `{part}` (latency<=F, size<=F, acc>=F)");
            }
        }
        Ok(spec)
    }
}

// -------------------------------------------------------------- recorder

/// The exhaustion objective: an accuracy floor whose `satisfied` records
/// every committed configuration (with the decision count at that
/// instant) and always answers "keep going" — so the search walks the
/// full accuracy-only trajectory every budgeted objective at this floor
/// shares a prefix of. `satisfied` fires on replayed decisions too (the
/// `Decision` event precedes the check), so resumed builds record the
/// same trail.
pub(crate) struct FrontierRecorder {
    pub(crate) abs_floor: f64,
    pub(crate) decisions: Arc<AtomicUsize>,
    pub(crate) trail: Mutex<Vec<(QuantConfig, usize)>>,
}

impl Objective for FrontierRecorder {
    fn accuracy_floor(&self) -> f64 {
        self.abs_floor
    }

    fn satisfied(&self, cfg: &QuantConfig) -> bool {
        let mut trail = self.trail.lock().expect("frontier trail poisoned");
        if trail.last().is_none_or(|(c, _)| c.key() != cfg.key()) {
            trail.push((cfg.clone(), self.decisions.load(Ordering::Relaxed)));
        }
        false
    }

    fn describe(&self) -> String {
        format!("frontier accuracy>={}", self.abs_floor)
    }
}

// ---------------------------------------------------------------- driver

/// One-pass frontier builder. Configure with [`ParetoFront::new`] (plus
/// the optional per-floor [`ParetoFront::checkpoint`] prefix), then
/// [`ParetoFront::build`] against any [`SearchEnv`].
pub struct ParetoFront {
    algo: SearchAlgo,
    order: Vec<usize>,
    floors: Vec<f64>,
    float_accuracy: f64,
    cost: Arc<dyn CostModel>,
    env_context: String,
    checkpoint_prefix: Option<PathBuf>,
    resume: bool,
}

/// What [`ParetoFront::build`] hands back: the serializable artifact
/// plus build accounting (exactly one exhaustion search per floor).
#[derive(Debug, Clone)]
pub struct FrontierReport {
    pub artifact: FrontierArtifact,
    /// Where the artifact was persisted, when the caller saved it.
    pub path: Option<PathBuf>,
    /// Total decision evaluations across all floors — "one search's
    /// worth" per floor; frontier lookups afterwards consume zero.
    pub decision_evals: usize,
    /// Decisions answered from per-floor checkpoints instead of evals.
    pub replayed_decisions: usize,
    pub build_seconds: f64,
}

impl ParetoFront {
    pub fn new(
        algo: SearchAlgo,
        order: Vec<usize>,
        floors: Vec<f64>,
        float_accuracy: f64,
        cost: Arc<dyn CostModel>,
        env_context: String,
    ) -> Self {
        ParetoFront {
            algo,
            order,
            floors,
            float_accuracy,
            cost,
            env_context,
            checkpoint_prefix: None,
            resume: false,
        }
    }

    /// Persist each floor's decision log to `<prefix>.floor<i>` so a
    /// killed build resumes bit-identically.
    pub fn checkpoint(mut self, prefix: impl Into<PathBuf>) -> Self {
        self.checkpoint_prefix = Some(prefix.into());
        self
    }

    /// Replay existing per-floor logs instead of starting clean. Floors
    /// the interrupted build never reached have no log yet and attach
    /// fresh.
    pub fn resume(mut self, resume: bool) -> Self {
        self.resume = resume;
        self
    }

    /// Run one exhaustion search per floor and assemble the artifact.
    /// Every [`SearchEvent`] is forwarded to `observer`, prefixed per
    /// floor with [`SearchEvent::FrontierFloor`].
    pub fn build<E: SearchEnv>(
        &self,
        env: &mut E,
        mut observer: Option<&mut dyn FnMut(&SearchEvent)>,
    ) -> Result<FrontierReport> {
        ensure!(!self.floors.is_empty(), "frontier needs at least one accuracy floor");
        ensure!(self.float_accuracy > 0.0, "float baseline accuracy must be positive");
        for (i, &f) in self.floors.iter().enumerate() {
            ensure!(f.is_finite() && f > 0.0 && f <= 1.0, "floor {f} out of (0, 1]");
            ensure!(
                !self.floors[..i].iter().any(|&g| g.to_bits() == f.to_bits()),
                "duplicate floor {f} would re-run an identical search"
            );
        }

        let t0 = Instant::now();
        let total = self.floors.len();
        let mut trails = Vec::with_capacity(total);
        let mut decision_evals = 0usize;
        let mut replayed_decisions = 0usize;
        // Exact accuracies are pure functions of the config, so dedupe
        // them across floors (the float baseline opens every trail).
        let mut exact: HashMap<u64, f64> = HashMap::new();

        for (i, &floor) in self.floors.iter().enumerate() {
            let abs_floor = floor * self.float_accuracy;
            if let Some(obs) = observer.as_mut() {
                obs(&SearchEvent::FrontierFloor { floor, index: i, total });
            }
            let decisions = Arc::new(AtomicUsize::new(0));
            let recorder = FrontierRecorder {
                abs_floor,
                decisions: decisions.clone(),
                trail: Mutex::new(Vec::new()),
            };
            let mut checkpoint = match &self.checkpoint_prefix {
                Some(prefix) => {
                    let path = PathBuf::from(format!("{}.floor{i}", prefix.display()));
                    let fp = checkpoint_fingerprint(
                        self.algo,
                        &QUANT_BITS,
                        &recorder.describe(),
                        &self.order,
                        &self.env_context,
                    );
                    let resume = self.resume && path.is_file();
                    Some(Checkpoint::attach(&path, &fp, resume)?)
                }
                None => None,
            };
            let counter = decisions.clone();
            let mut counting = |ev: &SearchEvent| {
                if matches!(ev, SearchEvent::Decision { .. }) {
                    counter.fetch_add(1, Ordering::Relaxed);
                }
                if let Some(obs) = observer.as_mut() {
                    obs(ev);
                }
            };
            let outcome = run_search(
                self.algo,
                env,
                &self.order,
                &QUANT_BITS,
                &recorder,
                Some(&mut counting),
                checkpoint.as_mut(),
            )?;
            drop(counting);
            replayed_decisions += checkpoint.as_ref().map_or(0, |ck| ck.replayed());
            let floor_decisions = decisions.load(Ordering::Relaxed);
            decision_evals += floor_decisions;
            ensure!(
                floor_decisions + 1 == outcome.evals,
                "frontier decision count out of sync at floor {floor}: {floor_decisions} \
                 decisions vs {} evals",
                outcome.evals
            );

            let trail = recorder.trail.into_inner().expect("frontier trail poisoned");
            ensure!(
                trail.last().is_some_and(|(c, _)| c.key() == outcome.config.key()),
                "frontier trail out of sync with the search outcome at floor {floor}"
            );
            let last = trail.len() - 1;
            let mut points = Vec::with_capacity(trail.len());
            for (j, (config, dec)) in trail.into_iter().enumerate() {
                let accuracy = if j == last {
                    // The search's own final evaluation is already exact.
                    exact.insert(config.key(), outcome.accuracy);
                    outcome.accuracy
                } else {
                    match exact.get(&config.key()) {
                        Some(&a) => a,
                        None => {
                            let a = env.eval(&config, None)?.accuracy;
                            exact.insert(config.key(), a);
                            a
                        }
                    }
                };
                points.push(FrontierPoint {
                    accuracy,
                    rel_latency: self.cost.rel_latency(&config),
                    rel_size: self.cost.rel_size(&config),
                    cost_provenance: self.cost.provenance().to_string(),
                    decisions: dec,
                    config,
                });
            }
            trails.push(FloorTrail { floor, abs_floor, decisions: floor_decisions, points });
        }

        let artifact = FrontierArtifact {
            algo: self.algo,
            fingerprint: frontier_fingerprint(
                self.algo,
                &self.floors,
                &self.order,
                &self.env_context,
            ),
            float_accuracy: self.float_accuracy,
            cost_provenance: self.cost.provenance().to_string(),
            partitions: 1,
            trails,
        };
        Ok(FrontierReport {
            artifact,
            path: None,
            decision_evals,
            replayed_decisions,
            build_seconds: t0.elapsed().as_secs_f64(),
        })
    }
}

/// Build a frontier over the seeded [`SyntheticEnv`] — the same harness
/// `mpq pareto --synthetic` and the CI smoke use. One environment serves
/// every floor (evaluation is pure, so this matches per-floor fresh
/// environments bit for bit).
#[allow(clippy::too_many_arguments)]
pub fn build_frontier_synthetic(
    layers: usize,
    seed: u64,
    workers: usize,
    algo: SearchAlgo,
    floors: &[f64],
    checkpoint_prefix: Option<&Path>,
    resume: bool,
    abort_after: Option<usize>,
    observer: Option<&mut dyn FnMut(&SearchEvent)>,
) -> Result<FrontierReport> {
    let mut env = SyntheticEnv::new(layers, seed);
    if let Some(n) = abort_after {
        env = env.abort_after(n);
    }
    let order = env.order();
    let mut front = ParetoFront::new(
        algo,
        order,
        floors.to_vec(),
        1.0,
        Arc::new(SyntheticCost::new(layers, seed)),
        format!("synthetic/n{layers}/seed{seed}"),
    )
    .resume(resume);
    if let Some(prefix) = checkpoint_prefix {
        front = front.checkpoint(prefix);
    }
    let mut penv = ParallelEnv::new(&env, workers.max(1));
    front.build(&mut penv, observer)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(acc: f64, lat: f64, size: f64) -> FrontierPoint {
        FrontierPoint {
            config: QuantConfig::uniform(2, (acc * 1000.0) as f32),
            accuracy: acc,
            rel_latency: lat,
            rel_size: size,
            cost_provenance: "test".to_string(),
            decisions: 0,
        }
    }

    #[test]
    fn dominance_needs_no_worse_everywhere_and_better_somewhere() {
        let a = point(0.9, 0.5, 0.5);
        assert!(point(0.9, 0.4, 0.5).dominates(&a));
        assert!(point(0.95, 0.5, 0.5).dominates(&a));
        assert!(!a.dominates(&a), "equal points never dominate");
        assert!(!point(0.95, 0.6, 0.5).dominates(&a), "trade-offs are incomparable");
        assert!(!point(0.8, 0.4, 0.4).dominates(&a));
    }

    #[test]
    fn pick_spec_parses_and_round_trips() {
        let spec: PickSpec = "latency<=0.7, acc>=0.99".parse().unwrap();
        assert_eq!(spec.max_rel_latency, Some(0.7));
        assert_eq!(spec.min_accuracy, Some(0.99));
        assert_eq!(spec.max_rel_size, None);
        let full: PickSpec = "latency<=0.7,size<=0.8,acc>=0.9".parse().unwrap();
        assert_eq!(full.describe(), "latency<=0.7,size<=0.8,acc>=0.9");
        assert_eq!(full.describe().parse::<PickSpec>().unwrap(), full);
        assert_eq!("".parse::<PickSpec>().unwrap(), PickSpec::default());
        assert!("latency<0.7".parse::<PickSpec>().is_err());
        assert!("acc>=fast".parse::<PickSpec>().is_err());
    }

    #[test]
    fn fingerprint_separates_algo_floors_order_and_env() {
        let base = frontier_fingerprint(SearchAlgo::Greedy, &[0.9, 0.99], &[0, 1, 2], "env/a");
        assert_eq!(
            base,
            frontier_fingerprint(SearchAlgo::Greedy, &[0.9, 0.99], &[0, 1, 2], "env/a")
        );
        for other in [
            frontier_fingerprint(SearchAlgo::Bisection, &[0.9, 0.99], &[0, 1, 2], "env/a"),
            frontier_fingerprint(SearchAlgo::Greedy, &[0.9], &[0, 1, 2], "env/a"),
            frontier_fingerprint(SearchAlgo::Greedy, &[0.99, 0.9], &[0, 1, 2], "env/a"),
            frontier_fingerprint(SearchAlgo::Greedy, &[0.9, 0.99], &[2, 1, 0], "env/a"),
            frontier_fingerprint(SearchAlgo::Greedy, &[0.9, 0.99], &[0, 1, 2], "env/b"),
        ] {
            assert_ne!(base, other);
        }
    }

    fn sample_artifact() -> FrontierArtifact {
        let points = vec![point(1.0, 1.0, 1.0), point(0.97, 0.6, 0.55), point(0.91, 0.45, 0.4)];
        FrontierArtifact {
            algo: SearchAlgo::Greedy,
            fingerprint: frontier_fingerprint(SearchAlgo::Greedy, &[0.9], &[0, 1], "env/t"),
            float_accuracy: 1.0,
            cost_provenance: "test".to_string(),
            partitions: 1,
            trails: vec![FloorTrail { floor: 0.9, abs_floor: 0.9, decisions: 4, points }],
        }
    }

    #[test]
    fn artifact_json_round_trip_is_byte_stable() {
        let a = sample_artifact();
        let text = a.to_json().to_string();
        let b = FrontierArtifact::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(a, b);
        assert_eq!(b.to_json().to_string(), text, "re-serialization must be byte-identical");
    }

    #[test]
    fn partitions_field_round_trips_and_defaults_to_one() {
        let mut a = sample_artifact();
        assert!(!a.to_json().to_string().contains("partitions"), "K=1 omits the field");
        a.partitions = 3;
        a.fingerprint =
            partitioned_frontier_fingerprint(SearchAlgo::Greedy, &[0.9], &[0, 1], "env/t", 3);
        let text = a.to_json().to_string();
        let b = FrontierArtifact::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(b.partitions, 3);
        assert_eq!(b.to_json().to_string(), text, "re-serialization must be byte-identical");
        b.verify(SearchAlgo::Greedy, &[0, 1], "env/t").unwrap();
        // A K=1 verify against the same inputs must reject the composed
        // artifact (and vice versa): the /K suffix separates them.
        let mono = sample_artifact();
        assert_ne!(mono.fingerprint, b.fingerprint);
    }

    #[test]
    fn verify_accepts_matching_and_rejects_mismatched_builds() {
        let a = sample_artifact();
        a.verify(SearchAlgo::Greedy, &[0, 1], "env/t").unwrap();
        for err in [
            a.verify(SearchAlgo::Bisection, &[0, 1], "env/t").unwrap_err(),
            a.verify(SearchAlgo::Greedy, &[1, 0], "env/t").unwrap_err(),
            a.verify(SearchAlgo::Greedy, &[0, 1], "env/other").unwrap_err(),
        ] {
            assert!(err.to_string().contains("different search"), "{err}");
        }
    }

    #[test]
    fn pareto_filters_dominated_and_pick_respects_constraints() {
        let mut a = sample_artifact();
        // A strictly dominated extra point must be filtered out.
        a.trails[0].points.push(point(0.90, 0.6, 0.6));
        assert_eq!(a.pareto().len(), 3);
        let picked = a.pick(&"latency<=0.7".parse().unwrap()).unwrap();
        assert_eq!(picked.accuracy, 0.97, "most accurate point within budget");
        let tight = a.pick(&"latency<=0.5,acc>=0.99".parse().unwrap());
        assert!(tight.unwrap_err().to_string().contains("no frontier point"));
        assert_eq!(a.pick(&PickSpec::default()).unwrap().accuracy, 1.0);
    }
}
