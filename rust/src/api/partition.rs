//! Subgraph-partitioned search: segment-scoped drivers, concurrent
//! per-segment frontiers, and global budget reconciliation.
//!
//! Big models make even the one-pass frontier expensive: every decision
//! still evaluates the *whole* model, and the decision sequence is as long
//! as the layer order. Following the sequential sub-graph evaluation of
//! Markovich-Golan et al. and the loss-budget splitting of Pandey et al.,
//! [`Partition::split`] cuts the sensitivity-sorted order into `K`
//! contiguous segments and [`PartitionedDriver`] searches them
//! *concurrently* — each segment scoped by [`SearchAlgo::run_scoped`] with
//! the complement frozen at reference (float) precision and a pro-rated
//! share of the budget and accuracy slack:
//!
//! * scoped budget `B_s = 1 − (1 − B)·w_s` where `w_s` is the segment's
//!   layer-count share — modeled costs are per-layer sums, so if every
//!   segment meets its scoped budget the composed config meets `B`;
//! * scoped floor `F_s = A0 − (A0 − F)·w_s` — accuracy degradation is
//!   additive on the synthetic model and approximately additive on real
//!   ones (Pandey et al.), so per-segment slack shares compose.
//!
//! A deterministic **global budget reconciliation** pass then composes the
//! per-segment results into one whole-model configuration, evaluates it
//! exactly once, and reports the composed cost
//! ([`SearchEvent::Reconciled`]). Per-segment event streams are buffered
//! and replayed in fixed segment order, per-segment decision logs
//! checkpoint to `<prefix>.seg<s>` (`<prefix>.floor<i>.seg<s>` for
//! frontier builds), and `K = 1` delegates to the monolithic driver — so
//! `--partitions 1` is bit-identical to the whole-model search and a
//! killed `K > 1` run resumes byte-identically.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, ensure};

use crate::coordinator::{
    EvalResult, PipelinePool, SearchAlgo, SearchEnv, SearchOutcome, SyncSearchEnv,
};
use crate::quant::{QuantConfig, QUANT_BITS};
use crate::Result;

use super::checkpoint::{checkpoint_fingerprint, Checkpoint};
use super::cost::CostModel;
use super::driver::{run_search, SearchCtl};
use super::events::SearchEvent;
use super::objective::{AccuracyTarget, FootprintBudget, LatencyBudget, Objective};
use super::pareto::{
    partitioned_frontier_fingerprint, FloorTrail, FrontierArtifact, FrontierPoint,
    FrontierRecorder, FrontierReport, ParetoFront,
};
use super::spec::ObjectiveSpec;
use super::synthetic::{SyntheticCost, SyntheticEnv};

// ------------------------------------------------------------- partition

/// One contiguous segment of the sensitivity-sorted layer order.
#[derive(Debug, Clone, PartialEq)]
pub struct SegmentView {
    /// Position in the partition — also the pool worker that owns the
    /// segment in a concurrent run.
    pub index: usize,
    /// Global layer ids, in sensitivity order.
    pub layers: Vec<usize>,
    /// This segment's layer-count share of the whole order, in `(0, 1]`.
    pub share: f64,
}

/// The sensitivity order split into `K` contiguous segments. Segments
/// cover the order exactly once, in order; the first `len % K` segments
/// are one layer longer, so shares differ by at most one layer.
#[derive(Debug, Clone, PartialEq)]
pub struct Partition {
    segments: Vec<SegmentView>,
}

impl Partition {
    /// Split `order` into `k` contiguous segments (`k` is clamped to
    /// `[1, order.len()]` so no segment is ever empty).
    pub fn split(order: &[usize], k: usize) -> Self {
        let n = order.len();
        let k = k.clamp(1, n.max(1));
        let base = n / k;
        let extra = n % k;
        let mut segments = Vec::with_capacity(k);
        let mut start = 0usize;
        for index in 0..k {
            let len = base + usize::from(index < extra);
            let layers = order[start..start + len].to_vec();
            start += len;
            segments.push(SegmentView { index, layers, share: len as f64 / n.max(1) as f64 });
        }
        debug_assert_eq!(start, n, "segments must cover the order exactly");
        Partition { segments }
    }

    pub fn segments(&self) -> &[SegmentView] {
        &self.segments
    }

    pub fn num_segments(&self) -> usize {
        self.segments.len()
    }

    /// Total layers across all segments (== the original order length).
    pub fn num_layers(&self) -> usize {
        self.segments.iter().map(|s| s.layers.len()).sum()
    }

    /// The original order, reassembled from the segments.
    pub fn order(&self) -> Vec<usize> {
        self.segments.iter().flat_map(|s| s.layers.iter().copied()).collect()
    }
}

/// Pro-rate a relative cost budget by a segment's layer share: the
/// complement stays at reference cost, so the segment may spend
/// `(1 − B)·w_s` of the global headroom. Costs are per-layer sums, so if
/// every segment satisfies its scoped budget the composed configuration
/// satisfies `B` exactly.
pub fn scoped_budget(budget: f64, share: f64) -> f64 {
    1.0 - (1.0 - budget) * share
}

/// Pro-rate an absolute accuracy floor by a segment's layer share: the
/// segment may spend `(A0 − F)·w_s` of the global accuracy slack.
pub fn scoped_floor(float_accuracy: f64, abs_floor: f64, share: f64) -> f64 {
    float_accuracy - (float_accuracy - abs_floor) * share
}

/// Build the segment-scoped instance of a global objective.
fn scoped_objective(
    spec: &ObjectiveSpec,
    floor_s: f64,
    share: f64,
    cost: Arc<dyn CostModel>,
) -> Box<dyn Objective> {
    match *spec {
        ObjectiveSpec::AccuracyTarget => Box::new(AccuracyTarget::new(floor_s)),
        ObjectiveSpec::LatencyBudget { rel_latency } => {
            Box::new(LatencyBudget::new(floor_s, scoped_budget(rel_latency, share), cost))
        }
        ObjectiveSpec::FootprintBudget { rel_size } => {
            Box::new(FootprintBudget::new(floor_s, scoped_budget(rel_size, share), cost))
        }
    }
}

// ----------------------------------------------------------- environment

/// A search environment whose evaluations can be shared by several
/// concurrent segment searches through `&self`. Implementations must
/// answer each segment's evaluations deterministically — shared caches are
/// fine because exact results are target-independent, so a cache hit never
/// changes a decision.
pub trait SegmentEval {
    fn num_layers(&self) -> usize;

    /// Evaluate a batch on behalf of `segment`'s scoped search, one result
    /// per config in order.
    fn eval_segment(
        &self,
        segment: usize,
        cfgs: &[QuantConfig],
        target: Option<f64>,
    ) -> Vec<Result<EvalResult>>;

    /// Speculation window for one segment's search (its
    /// [`SearchEnv::preferred_batch`]). Decisions are window-independent,
    /// so this only affects wasted speculative work, never the outcome.
    fn segment_window(&self) -> usize {
        1
    }
}

/// Share one thread-safe environment across all segments (each segment
/// evaluates sequentially on its own thread).
pub struct SharedSegmentEval<'a, E: SyncSearchEnv>(pub &'a E);

impl<E: SyncSearchEnv> SegmentEval for SharedSegmentEval<'_, E> {
    fn num_layers(&self) -> usize {
        self.0.num_layers()
    }

    fn eval_segment(
        &self,
        _segment: usize,
        cfgs: &[QuantConfig],
        target: Option<f64>,
    ) -> Vec<Result<EvalResult>> {
        cfgs.iter().map(|c| self.0.eval(c, target)).collect()
    }
}

/// Each segment owns one pool worker: segment `s` pins its evaluations to
/// worker `s % workers` ([`PipelinePool::eval_on`]), so concurrent segment
/// searches never contend for the same device pipeline. The shared
/// memo/persistent caches stay safe — they publish exact results only.
impl SegmentEval for PipelinePool {
    fn num_layers(&self) -> usize {
        SearchEnv::num_layers(self)
    }

    fn eval_segment(
        &self,
        segment: usize,
        cfgs: &[QuantConfig],
        target: Option<f64>,
    ) -> Vec<Result<EvalResult>> {
        self.eval_on(segment, cfgs, target)
    }
}

/// Adapter presenting one segment's slice of a [`SegmentEval`] as a
/// [`SearchEnv`], so the scoped search algorithms run unchanged.
struct SegmentEnv<'a, E: SegmentEval + ?Sized> {
    eval: &'a E,
    segment: usize,
}

impl<E: SegmentEval + ?Sized> SearchEnv for SegmentEnv<'_, E> {
    fn num_layers(&self) -> usize {
        self.eval.num_layers()
    }

    fn eval(&mut self, cfg: &QuantConfig, target: Option<f64>) -> Result<EvalResult> {
        self.eval
            .eval_segment(self.segment, std::slice::from_ref(cfg), target)
            .pop()
            .unwrap_or_else(|| Err(anyhow!("segment evaluation returned no result")))
    }

    fn eval_many(&mut self, cfgs: &[QuantConfig], target: Option<f64>) -> Vec<Result<EvalResult>> {
        self.eval.eval_segment(self.segment, cfgs, target)
    }

    fn preferred_batch(&self) -> usize {
        self.eval.segment_window().max(1)
    }
}

// -------------------------------------------------------- segment worker

/// Everything one segment's scoped search needs, prepared deterministically
/// (checkpoint attaches happen in segment order before any search runs).
struct SegTask<'a> {
    seg: &'a SegmentView,
    objective: &'a dyn Objective,
    /// Live decision counter for frontier recorders (must tick *during*
    /// the search — trail entries snapshot it at commit time).
    counter: Option<Arc<AtomicUsize>>,
    checkpoint: Option<Checkpoint>,
}

/// One segment search's results: outcome, buffered event stream (replayed
/// later in fixed segment order), and checkpoint-replay accounting.
struct SegRun {
    outcome: SearchOutcome,
    events: Vec<SearchEvent>,
    replayed: usize,
    checkpointed: usize,
}

fn run_segment<E: SearchEnv>(
    algo: SearchAlgo,
    env: &mut E,
    base: &QuantConfig,
    task: SegTask<'_>,
) -> Result<SegRun> {
    let mut events: Vec<SearchEvent> = Vec::new();
    let mut checkpoint = task.checkpoint;
    let outcome = {
        let counter = task.counter;
        let mut buffer = |ev: &SearchEvent| {
            if let Some(c) = &counter {
                if matches!(ev, SearchEvent::Decision { .. }) {
                    c.fetch_add(1, Ordering::Relaxed);
                }
            }
            events.push(ev.clone());
        };
        let mut ctl = SearchCtl::new(task.objective).with_observer(&mut buffer);
        if let Some(ck) = checkpoint.as_mut() {
            ctl = ctl.with_checkpoint(ck);
        }
        algo.run_scoped(env, &task.seg.layers, base, &QUANT_BITS, &mut ctl)?
    };
    let replayed = checkpoint.as_ref().map_or(0, |ck| ck.replayed());
    let checkpointed = checkpoint.as_ref().map_or(0, |ck| ck.len());
    Ok(SegRun { outcome, events, replayed, checkpointed })
}

/// How segment searches actually execute: concurrently over a shared
/// [`SegmentEval`] (one scoped thread per segment) or sequentially over a
/// single-owner [`SearchEnv`] (e.g. a `!Send` device context). Decisions
/// are identical either way — each segment's search depends only on its
/// own configurations.
trait SegmentExec {
    fn run_tasks(
        &mut self,
        algo: SearchAlgo,
        base: &QuantConfig,
        tasks: Vec<SegTask<'_>>,
    ) -> Vec<Result<SegRun>>;

    fn eval_exact(&mut self, cfg: &QuantConfig) -> Result<EvalResult>;

    fn monolithic_search(
        &mut self,
        algo: SearchAlgo,
        order: &[usize],
        objective: &dyn Objective,
        observer: Option<&mut dyn FnMut(&SearchEvent)>,
        checkpoint: Option<&mut Checkpoint>,
    ) -> Result<SearchOutcome>;

    fn monolithic_frontier(
        &mut self,
        front: &ParetoFront,
        observer: Option<&mut dyn FnMut(&SearchEvent)>,
    ) -> Result<FrontierReport>;
}

struct ConcurrentExec<'a, E: SegmentEval + Sync + ?Sized>(&'a E);

impl<E: SegmentEval + Sync + ?Sized> SegmentExec for ConcurrentExec<'_, E> {
    fn run_tasks(
        &mut self,
        algo: SearchAlgo,
        base: &QuantConfig,
        tasks: Vec<SegTask<'_>>,
    ) -> Vec<Result<SegRun>> {
        let env = self.0;
        std::thread::scope(|s| {
            let handles: Vec<_> = tasks
                .into_iter()
                .map(|task| {
                    s.spawn(move || {
                        let mut senv = SegmentEnv { eval: env, segment: task.seg.index };
                        run_segment(algo, &mut senv, base, task)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| Err(anyhow!("segment search thread panicked")))
                })
                .collect()
        })
    }

    fn eval_exact(&mut self, cfg: &QuantConfig) -> Result<EvalResult> {
        self.0
            .eval_segment(0, std::slice::from_ref(cfg), None)
            .pop()
            .unwrap_or_else(|| Err(anyhow!("segment evaluation returned no result")))
    }

    fn monolithic_search(
        &mut self,
        algo: SearchAlgo,
        order: &[usize],
        objective: &dyn Objective,
        observer: Option<&mut dyn FnMut(&SearchEvent)>,
        checkpoint: Option<&mut Checkpoint>,
    ) -> Result<SearchOutcome> {
        let mut senv = SegmentEnv { eval: self.0, segment: 0 };
        run_search(algo, &mut senv, order, &QUANT_BITS, objective, observer, checkpoint)
    }

    fn monolithic_frontier(
        &mut self,
        front: &ParetoFront,
        observer: Option<&mut dyn FnMut(&SearchEvent)>,
    ) -> Result<FrontierReport> {
        let mut senv = SegmentEnv { eval: self.0, segment: 0 };
        front.build(&mut senv, observer)
    }
}

struct SerialExec<'a, E: SearchEnv>(&'a mut E);

impl<E: SearchEnv> SegmentExec for SerialExec<'_, E> {
    fn run_tasks(
        &mut self,
        algo: SearchAlgo,
        base: &QuantConfig,
        tasks: Vec<SegTask<'_>>,
    ) -> Vec<Result<SegRun>> {
        tasks.into_iter().map(|task| run_segment(algo, self.0, base, task)).collect()
    }

    fn eval_exact(&mut self, cfg: &QuantConfig) -> Result<EvalResult> {
        self.0.eval(cfg, None)
    }

    fn monolithic_search(
        &mut self,
        algo: SearchAlgo,
        order: &[usize],
        objective: &dyn Objective,
        observer: Option<&mut dyn FnMut(&SearchEvent)>,
        checkpoint: Option<&mut Checkpoint>,
    ) -> Result<SearchOutcome> {
        run_search(algo, self.0, order, &QUANT_BITS, objective, observer, checkpoint)
    }

    fn monolithic_frontier(
        &mut self,
        front: &ParetoFront,
        observer: Option<&mut dyn FnMut(&SearchEvent)>,
    ) -> Result<FrontierReport> {
        front.build(self.0, observer)
    }
}

fn emit(observer: &mut Option<&mut dyn FnMut(&SearchEvent)>, ev: SearchEvent) {
    if let Some(obs) = observer.as_mut() {
        obs(&ev);
    }
}

// ---------------------------------------------------------------- driver

/// Drives `K` concurrent segment-scoped searches and reconciles them into
/// one whole-model result. `K = 1` delegates to the monolithic
/// [`run_search`] / [`ParetoFront`] drivers — same decisions, same
/// checkpoint files, byte-identical artifacts.
pub struct PartitionedDriver {
    algo: SearchAlgo,
    partition: Partition,
    float_accuracy: f64,
    cost: Arc<dyn CostModel>,
    env_context: String,
    checkpoint_prefix: Option<PathBuf>,
    resume: bool,
}

/// What a partitioned constrained search hands back.
#[derive(Debug, Clone)]
pub struct PartitionedOutcome {
    /// The reconciled whole-model result; `evals` sums every segment's
    /// decision evaluations plus the one reconciliation evaluation.
    pub outcome: SearchOutcome,
    /// Per-segment outcomes in segment order (empty for `K = 1`, where the
    /// run *was* the monolithic search).
    pub segments: Vec<SearchOutcome>,
    /// Whether each segment met its scoped budget (for `K = 1`, the global
    /// objective's own `satisfied`). Always `false` under a pure accuracy
    /// target — exhaustion searches have no budget to meet.
    pub satisfied: Vec<bool>,
    /// Decisions answered from per-segment checkpoints instead of evals.
    pub replayed_decisions: usize,
    /// Total decisions on disk across all segment checkpoints after the
    /// run (0 if no checkpoint prefix was configured).
    pub checkpointed_decisions: usize,
}

impl PartitionedOutcome {
    /// True when every segment met its scoped budget — the precondition
    /// under which the composed configuration provably meets the global
    /// budget (cost additivity).
    pub fn all_satisfied(&self) -> bool {
        !self.satisfied.is_empty() && self.satisfied.iter().all(|&s| s)
    }
}

impl PartitionedDriver {
    pub fn new(
        algo: SearchAlgo,
        partition: Partition,
        float_accuracy: f64,
        cost: Arc<dyn CostModel>,
        env_context: impl Into<String>,
    ) -> Self {
        PartitionedDriver {
            algo,
            partition,
            float_accuracy,
            cost,
            env_context: env_context.into(),
            checkpoint_prefix: None,
            resume: false,
        }
    }

    /// Persist per-segment decision logs to `<prefix>.seg<s>`
    /// (`<prefix>.floor<i>.seg<s>` for frontier builds; the bare `<prefix>`
    /// for `K = 1`, matching the monolithic drivers).
    pub fn checkpoint(mut self, prefix: impl Into<PathBuf>) -> Self {
        self.checkpoint_prefix = Some(prefix.into());
        self
    }

    /// Replay existing decision logs instead of starting clean. Segments
    /// (or floors) the interrupted run never reached attach fresh.
    pub fn resume(mut self, resume: bool) -> Self {
        self.resume = resume;
        self
    }

    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    fn attach(
        &self,
        suffix: &str,
        describe: &str,
        order: &[usize],
    ) -> Result<Option<Checkpoint>> {
        let Some(prefix) = &self.checkpoint_prefix else { return Ok(None) };
        let path = PathBuf::from(format!("{}{suffix}", prefix.display()));
        let fp =
            checkpoint_fingerprint(self.algo, &QUANT_BITS, describe, order, &self.env_context);
        let resume = self.resume && path.is_file();
        Ok(Some(Checkpoint::attach(&path, &fp, resume)?))
    }

    /// Run one constrained search per segment concurrently and reconcile.
    /// `floor` is the *absolute* accuracy floor of the global objective.
    pub fn run<E: SegmentEval + Sync + ?Sized>(
        &self,
        env: &E,
        spec: &ObjectiveSpec,
        floor: f64,
        observer: Option<&mut dyn FnMut(&SearchEvent)>,
    ) -> Result<PartitionedOutcome> {
        let layers = env.num_layers();
        self.run_exec(&mut ConcurrentExec(env), layers, spec, floor, observer)
    }

    /// Sequential variant for single-owner environments (no worker pool /
    /// `!Send` device contexts). Segment searches depend only on their own
    /// configurations, so the results are identical to [`Self::run`].
    pub fn run_serial<E: SearchEnv>(
        &self,
        env: &mut E,
        spec: &ObjectiveSpec,
        floor: f64,
        observer: Option<&mut dyn FnMut(&SearchEvent)>,
    ) -> Result<PartitionedOutcome> {
        let layers = env.num_layers();
        self.run_exec(&mut SerialExec(env), layers, spec, floor, observer)
    }

    fn run_exec<X: SegmentExec>(
        &self,
        x: &mut X,
        num_layers: usize,
        spec: &ObjectiveSpec,
        floor: f64,
        mut observer: Option<&mut dyn FnMut(&SearchEvent)>,
    ) -> Result<PartitionedOutcome> {
        ensure!(
            self.partition.num_layers() == num_layers,
            "partition covers {} layers, environment has {num_layers}",
            self.partition.num_layers()
        );
        let k = self.partition.num_segments();
        let global = spec.build(floor, self.cost.clone());

        if k == 1 {
            let seg = &self.partition.segments[0];
            let fp_describe = global.describe();
            let mut checkpoint = self.attach("", &fp_describe, &seg.layers)?;
            let outcome = x.monolithic_search(
                self.algo,
                &seg.layers,
                global.as_ref(),
                observer,
                checkpoint.as_mut(),
            )?;
            let replayed_decisions = checkpoint.as_ref().map_or(0, |ck| ck.replayed());
            let checkpointed_decisions = checkpoint.as_ref().map_or(0, |ck| ck.len());
            let satisfied = vec![global.satisfied(&outcome.config)];
            return Ok(PartitionedOutcome {
                outcome,
                segments: Vec::new(),
                satisfied,
                replayed_decisions,
                checkpointed_decisions,
            });
        }

        emit(
            &mut observer,
            SearchEvent::Started {
                algo: self.algo.label(),
                layers: num_layers,
                objective: global.describe(),
            },
        );
        let base = QuantConfig::float(num_layers);
        let objectives: Vec<Box<dyn Objective>> = self
            .partition
            .segments()
            .iter()
            .map(|seg| {
                scoped_objective(
                    spec,
                    scoped_floor(self.float_accuracy, floor, seg.share),
                    seg.share,
                    self.cost.clone(),
                )
            })
            .collect();
        let mut tasks = Vec::with_capacity(k);
        for (seg, objective) in self.partition.segments().iter().zip(&objectives) {
            let checkpoint =
                self.attach(&format!(".seg{}", seg.index), &objective.describe(), &seg.layers)?;
            tasks.push(SegTask { seg, objective: objective.as_ref(), counter: None, checkpoint });
        }
        let runs = x.run_tasks(self.algo, &base, tasks);
        let mut outs = Vec::with_capacity(k);
        let mut replayed_decisions = 0usize;
        let mut checkpointed_decisions = 0usize;
        for run in runs {
            // Propagate the first failure in segment order — deterministic
            // even when several concurrent segments abort at once.
            let run = run?;
            replayed_decisions += run.replayed;
            checkpointed_decisions += run.checkpointed;
            outs.push(run);
        }

        for (seg, run) in self.partition.segments().iter().zip(&outs) {
            emit(
                &mut observer,
                SearchEvent::SegmentStarted {
                    segment: seg.index,
                    segments: k,
                    layers: seg.layers.len(),
                },
            );
            if let Some(obs) = observer.as_mut() {
                for ev in &run.events {
                    obs(ev);
                }
            }
            emit(
                &mut observer,
                SearchEvent::SegmentFinished {
                    segment: seg.index,
                    accuracy: run.outcome.accuracy,
                    evals: run.outcome.evals,
                },
            );
        }

        // Global budget reconciliation: compose the per-segment bit
        // assignments and evaluate the whole-model config exactly once.
        let mut composed = base.clone();
        for (seg, run) in self.partition.segments().iter().zip(&outs) {
            for &l in &seg.layers {
                composed.set_layer(l, run.outcome.config.layer_bits(l));
            }
        }
        let final_res = x.eval_exact(&composed)?;
        let evals = outs.iter().map(|r| r.outcome.evals).sum::<usize>() + 1;
        emit(
            &mut observer,
            SearchEvent::Reconciled {
                segments: k,
                accuracy: final_res.accuracy,
                cost: global.cost_of(&composed),
                evals,
            },
        );
        emit(&mut observer, SearchEvent::Finished { accuracy: final_res.accuracy, evals });

        let satisfied =
            objectives.iter().zip(&outs).map(|(o, r)| o.satisfied(&r.outcome.config)).collect();
        let segments: Vec<SearchOutcome> = outs.into_iter().map(|r| r.outcome).collect();
        Ok(PartitionedOutcome {
            outcome: SearchOutcome {
                config: composed,
                accuracy: final_res.accuracy,
                evals,
                target: floor,
            },
            segments,
            satisfied,
            replayed_decisions,
            checkpointed_decisions,
        })
    }

    /// Build a composed Pareto frontier: per floor, one concurrent
    /// exhaustion search per segment, then a deterministic composition of
    /// the per-segment trails into one whole-model trail (prefix segments
    /// at their final bits, the current segment walking its trail).
    pub fn build_frontier<E: SegmentEval + Sync + ?Sized>(
        &self,
        env: &E,
        floors: &[f64],
        observer: Option<&mut dyn FnMut(&SearchEvent)>,
    ) -> Result<FrontierReport> {
        let layers = env.num_layers();
        self.frontier_exec(&mut ConcurrentExec(env), layers, floors, observer)
    }

    /// Sequential variant of [`Self::build_frontier`] (see
    /// [`Self::run_serial`]).
    pub fn build_frontier_serial<E: SearchEnv>(
        &self,
        env: &mut E,
        floors: &[f64],
        observer: Option<&mut dyn FnMut(&SearchEvent)>,
    ) -> Result<FrontierReport> {
        let layers = env.num_layers();
        self.frontier_exec(&mut SerialExec(env), layers, floors, observer)
    }

    fn frontier_exec<X: SegmentExec>(
        &self,
        x: &mut X,
        num_layers: usize,
        floors: &[f64],
        mut observer: Option<&mut dyn FnMut(&SearchEvent)>,
    ) -> Result<FrontierReport> {
        ensure!(
            self.partition.num_layers() == num_layers,
            "partition covers {} layers, environment has {num_layers}",
            self.partition.num_layers()
        );
        let order = self.partition.order();
        if self.partition.num_segments() == 1 {
            let mut front = ParetoFront::new(
                self.algo,
                order,
                floors.to_vec(),
                self.float_accuracy,
                self.cost.clone(),
                self.env_context.clone(),
            )
            .resume(self.resume);
            if let Some(prefix) = &self.checkpoint_prefix {
                front = front.checkpoint(prefix);
            }
            return x.monolithic_frontier(&front, observer);
        }

        ensure!(!floors.is_empty(), "frontier needs at least one accuracy floor");
        ensure!(self.float_accuracy > 0.0, "float baseline accuracy must be positive");
        for (i, &f) in floors.iter().enumerate() {
            ensure!(f.is_finite() && f > 0.0 && f <= 1.0, "floor {f} out of (0, 1]");
            ensure!(
                !floors[..i].iter().any(|&g| g.to_bits() == f.to_bits()),
                "duplicate floor {f} would re-run an identical search"
            );
        }

        let t0 = Instant::now();
        let k = self.partition.num_segments();
        let total = floors.len();
        let base = QuantConfig::float(num_layers);
        let mut trails = Vec::with_capacity(total);
        let mut decision_evals = 0usize;
        let mut replayed_decisions = 0usize;
        // Exact accuracies are pure functions of the config; dedupe across
        // floors and composed points.
        let mut exact: HashMap<u64, f64> = HashMap::new();

        for (i, &floor) in floors.iter().enumerate() {
            let abs_floor = floor * self.float_accuracy;
            emit(&mut observer, SearchEvent::FrontierFloor { floor, index: i, total });
            let recorders: Vec<FrontierRecorder> = self
                .partition
                .segments()
                .iter()
                .map(|seg| FrontierRecorder {
                    abs_floor: scoped_floor(self.float_accuracy, abs_floor, seg.share),
                    decisions: Arc::new(AtomicUsize::new(0)),
                    trail: Mutex::new(Vec::new()),
                })
                .collect();
            let mut tasks = Vec::with_capacity(k);
            for (seg, recorder) in self.partition.segments().iter().zip(&recorders) {
                let checkpoint = self.attach(
                    &format!(".floor{i}.seg{}", seg.index),
                    &recorder.describe(),
                    &seg.layers,
                )?;
                tasks.push(SegTask {
                    seg,
                    objective: recorder,
                    counter: Some(recorder.decisions.clone()),
                    checkpoint,
                });
            }
            let runs = x.run_tasks(self.algo, &base, tasks);
            let mut outs = Vec::with_capacity(k);
            for run in runs {
                let run = run?;
                replayed_decisions += run.replayed;
                outs.push(run);
            }

            for (seg, run) in self.partition.segments().iter().zip(&outs) {
                emit(
                    &mut observer,
                    SearchEvent::SegmentStarted {
                        segment: seg.index,
                        segments: k,
                        layers: seg.layers.len(),
                    },
                );
                if let Some(obs) = observer.as_mut() {
                    for ev in &run.events {
                        obs(ev);
                    }
                }
                emit(
                    &mut observer,
                    SearchEvent::SegmentFinished {
                        segment: seg.index,
                        accuracy: run.outcome.accuracy,
                        evals: run.outcome.evals,
                    },
                );
            }

            // Compose: walk the segments in order; earlier segments sit at
            // their final bits while the current one replays its trail.
            // This is exactly the trajectory a sequential whole-model
            // search over the scoped floors would commit.
            let mut prefix_cfg = base.clone();
            let mut prefix_decisions = 0usize;
            let mut raw: Vec<(QuantConfig, usize)> = Vec::new();
            for ((seg, recorder), run) in
                self.partition.segments().iter().zip(recorders).zip(&outs)
            {
                let seg_decisions = recorder.decisions.load(Ordering::Relaxed);
                ensure!(
                    seg_decisions + 1 == run.outcome.evals,
                    "segment decision count out of sync at floor {floor}, segment {}: \
                     {seg_decisions} decisions vs {} evals",
                    seg.index,
                    run.outcome.evals
                );
                let trail = recorder.trail.into_inner().expect("frontier trail poisoned");
                ensure!(
                    trail.last().is_some_and(|(c, _)| c.key() == run.outcome.config.key()),
                    "segment trail out of sync with its outcome at floor {floor}, segment {}",
                    seg.index
                );
                // The segment's own final evaluation is already exact; for
                // segment 0 its configs coincide with the composed ones.
                exact.insert(run.outcome.config.key(), run.outcome.accuracy);
                for (cfg_s, dec) in trail {
                    let mut point = prefix_cfg.clone();
                    for &l in &seg.layers {
                        point.set_layer(l, cfg_s.layer_bits(l));
                    }
                    if raw.last().is_none_or(|(c, _)| c.key() != point.key()) {
                        raw.push((point, prefix_decisions + dec));
                    }
                }
                for &l in &seg.layers {
                    prefix_cfg.set_layer(l, run.outcome.config.layer_bits(l));
                }
                prefix_decisions += seg_decisions;
            }
            let floor_decisions = prefix_decisions;
            decision_evals += floor_decisions;

            let mut points = Vec::with_capacity(raw.len());
            for (config, dec) in raw {
                let accuracy = match exact.get(&config.key()) {
                    Some(&a) => a,
                    None => {
                        let a = x.eval_exact(&config)?.accuracy;
                        exact.insert(config.key(), a);
                        a
                    }
                };
                points.push(FrontierPoint {
                    accuracy,
                    rel_latency: self.cost.rel_latency(&config),
                    rel_size: self.cost.rel_size(&config),
                    cost_provenance: self.cost.provenance().to_string(),
                    decisions: dec,
                    config,
                });
            }
            let last = points.last().expect("composed trail cannot be empty");
            emit(
                &mut observer,
                SearchEvent::Reconciled {
                    segments: k,
                    accuracy: last.accuracy,
                    cost: None,
                    evals: floor_decisions,
                },
            );
            trails.push(FloorTrail { floor, abs_floor, decisions: floor_decisions, points });
        }

        let artifact = FrontierArtifact {
            algo: self.algo,
            fingerprint: partitioned_frontier_fingerprint(
                self.algo,
                floors,
                &order,
                &self.env_context,
                k,
            ),
            float_accuracy: self.float_accuracy,
            cost_provenance: self.cost.provenance().to_string(),
            partitions: k,
            trails,
        };
        Ok(FrontierReport {
            artifact,
            path: None,
            decision_evals,
            replayed_decisions,
            build_seconds: t0.elapsed().as_secs_f64(),
        })
    }
}

// -------------------------------------------------------- synthetic glue

/// Partitioned variant of [`super::build_frontier_synthetic`] — the
/// harness behind `mpq pareto --synthetic --partitions K` and the CI
/// kill/resume smoke. `partitions <= 1` delegates to the monolithic
/// builder (bit-identical artifact); for `K > 1` the build runs one scoped
/// thread per segment, so `workers` only affects the delegated case.
#[allow(clippy::too_many_arguments)]
pub fn build_frontier_synthetic_partitioned(
    layers: usize,
    seed: u64,
    workers: usize,
    algo: SearchAlgo,
    floors: &[f64],
    partitions: usize,
    checkpoint_prefix: Option<&std::path::Path>,
    resume: bool,
    abort_after: Option<usize>,
    observer: Option<&mut dyn FnMut(&SearchEvent)>,
) -> Result<FrontierReport> {
    if partitions <= 1 {
        return super::pareto::build_frontier_synthetic(
            layers,
            seed,
            workers,
            algo,
            floors,
            checkpoint_prefix,
            resume,
            abort_after,
            observer,
        );
    }
    let mut env = SyntheticEnv::new(layers, seed);
    if let Some(n) = abort_after {
        env = env.abort_after(n);
    }
    let order = env.order();
    let mut driver = PartitionedDriver::new(
        algo,
        Partition::split(&order, partitions),
        1.0,
        Arc::new(SyntheticCost::new(layers, seed)),
        format!("synthetic/n{layers}/seed{seed}"),
    )
    .resume(resume);
    if let Some(prefix) = checkpoint_prefix {
        driver = driver.checkpoint(prefix);
    }
    driver.build_frontier(&SharedSegmentEval(&env), floors, observer)
}

/// Partitioned constrained search over the seeded [`SyntheticEnv`] — the
/// harness behind `mpq search --synthetic --partitions K`. The returned
/// outcome's `target` is the absolute floor (`target` itself — the
/// synthetic float baseline is exactly 1.0).
#[allow(clippy::too_many_arguments)]
pub fn partitioned_search_synthetic(
    layers: usize,
    seed: u64,
    algo: SearchAlgo,
    spec: &ObjectiveSpec,
    target: f64,
    partitions: usize,
    checkpoint: Option<&std::path::Path>,
    resume: bool,
    abort_after: Option<usize>,
    observer: Option<&mut dyn FnMut(&SearchEvent)>,
) -> Result<PartitionedOutcome> {
    let mut env = SyntheticEnv::new(layers, seed);
    if let Some(n) = abort_after {
        env = env.abort_after(n);
    }
    let order = env.order();
    let mut driver = PartitionedDriver::new(
        algo,
        Partition::split(&order, partitions),
        1.0,
        Arc::new(SyntheticCost::new(layers, seed)),
        format!("synthetic/n{layers}/seed{seed}"),
    )
    .resume(resume);
    if let Some(path) = checkpoint {
        driver = driver.checkpoint(path);
    }
    driver.run(&SharedSegmentEval(&env), spec, target, observer)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_covers_the_order_exactly_once_and_stays_contiguous() {
        let order: Vec<usize> = vec![4, 2, 7, 0, 1, 6, 3, 5];
        for k in 1..=10 {
            let p = Partition::split(&order, k);
            assert_eq!(p.num_segments(), k.min(order.len()));
            assert_eq!(p.order(), order, "K={k} must reassemble the order");
            assert_eq!(p.num_layers(), order.len());
            let share: f64 = p.segments().iter().map(|s| s.share).sum();
            assert!((share - 1.0).abs() < 1e-12, "shares must sum to 1, got {share}");
            let max = p.segments().iter().map(|s| s.layers.len()).max().unwrap();
            let min = p.segments().iter().map(|s| s.layers.len()).min().unwrap();
            assert!(max - min <= 1, "balanced split: {min}..{max}");
        }
    }

    #[test]
    fn split_clamps_k_and_handles_empty_orders() {
        let p = Partition::split(&[3, 1], 5);
        assert_eq!(p.num_segments(), 2);
        let empty = Partition::split(&[], 4);
        assert_eq!(empty.num_segments(), 1);
        assert_eq!(empty.num_layers(), 0);
    }

    #[test]
    fn scoped_budgets_compose_exactly() {
        // Full share reproduces the global budget; shares sum the headroom.
        assert!((scoped_budget(0.7, 1.0) - 0.7).abs() < 1e-12);
        assert!((scoped_budget(0.7, 0.5) - 0.85).abs() < 1e-12);
        let spent: f64 = [0.5, 0.25, 0.25].iter().map(|&w| 1.0 - scoped_budget(0.7, w)).sum();
        assert!((spent - 0.3).abs() < 1e-12, "scoped headroom must sum to the global headroom");
        assert!((scoped_floor(1.0, 0.9, 1.0) - 0.9).abs() < 1e-12);
        let slack: f64 = [0.5, 0.5].iter().map(|&w| 1.0 - scoped_floor(1.0, 0.9, w)).sum();
        assert!((slack - 0.1).abs() < 1e-12, "scoped slack must sum to the global slack");
    }

    #[test]
    fn k1_run_matches_the_monolithic_search() {
        let layers = 16;
        for algo in [SearchAlgo::Greedy, SearchAlgo::Bisection] {
            let env = SyntheticEnv::new(layers, 11);
            let order = env.order();
            let target = 0.9;
            let mono = {
                let mut env = SyntheticEnv::new(layers, 11);
                algo.run(&mut env, &order, &QUANT_BITS, target).unwrap()
            };
            let cost: Arc<dyn CostModel> = Arc::new(SyntheticCost::new(layers, 11));
            let driver = PartitionedDriver::new(
                algo,
                Partition::split(&order, 1),
                1.0,
                cost,
                "synthetic/test",
            );
            let out = driver
                .run(&SharedSegmentEval(&env), &ObjectiveSpec::AccuracyTarget, target, None)
                .unwrap();
            assert_eq!(out.outcome.config, mono.config, "{algo:?}");
            assert_eq!(out.outcome.evals, mono.evals, "{algo:?}");
            assert!(out.segments.is_empty());
        }
    }

    #[test]
    fn partitioned_run_reconciles_and_respects_scoped_budgets() {
        let layers = 24;
        let env = SyntheticEnv::new(layers, 7);
        let order = env.order();
        let cost = Arc::new(SyntheticCost::new(layers, 7));
        let budget = 0.7;
        let driver = PartitionedDriver::new(
            SearchAlgo::Greedy,
            Partition::split(&order, 3),
            1.0,
            cost.clone(),
            "synthetic/test",
        );
        let mut events = Vec::new();
        let mut obs = |ev: &SearchEvent| events.push(ev.clone());
        let out = driver
            .run(
                &SharedSegmentEval(&env),
                &ObjectiveSpec::LatencyBudget { rel_latency: budget },
                0.5,
                Some(&mut obs),
            )
            .unwrap();
        assert_eq!(out.segments.len(), 3);
        assert_eq!(out.satisfied.len(), 3);
        let seg_evals: usize = out.segments.iter().map(|s| s.evals).sum();
        assert_eq!(out.outcome.evals, seg_evals + 1, "reconciliation adds exactly one eval");
        if out.all_satisfied() {
            assert!(
                cost.rel_latency(&out.outcome.config) <= budget + 1e-12,
                "scoped budgets must compose into the global budget"
            );
        }
        let starts = events
            .iter()
            .filter_map(|e| match e {
                SearchEvent::SegmentStarted { segment, .. } => Some(*segment),
                _ => None,
            })
            .collect::<Vec<_>>();
        assert_eq!(starts, vec![0, 1, 2], "segment events replay in fixed order");
        assert!(
            events.iter().any(|e| matches!(e, SearchEvent::Reconciled { segments: 3, .. })),
            "reconciliation must be announced"
        );
    }

    #[test]
    fn serial_and_concurrent_partitioned_runs_agree() {
        let layers = 20;
        for algo in [SearchAlgo::Greedy, SearchAlgo::Bisection] {
            let env = SyntheticEnv::new(layers, 3);
            let order = env.order();
            let cost: Arc<dyn CostModel> = Arc::new(SyntheticCost::new(layers, 3));
            let driver = PartitionedDriver::new(
                algo,
                Partition::split(&order, 4),
                1.0,
                cost,
                "synthetic/test",
            );
            let spec = ObjectiveSpec::FootprintBudget { rel_size: 0.6 };
            let conc =
                driver.run(&SharedSegmentEval(&env), &spec, 0.5, None).unwrap();
            let mut serial_env = SyntheticEnv::new(layers, 3);
            let serial = driver.run_serial(&mut serial_env, &spec, 0.5, None).unwrap();
            assert_eq!(conc.outcome.config, serial.outcome.config, "{algo:?}");
            assert_eq!(conc.outcome.evals, serial.outcome.evals, "{algo:?}");
            assert_eq!(conc.satisfied, serial.satisfied, "{algo:?}");
        }
    }

    #[test]
    fn k1_frontier_delegates_byte_identically() {
        let layers = 12;
        let floors = [0.9, 0.99];
        let mono = super::super::pareto::build_frontier_synthetic(
            layers,
            5,
            1,
            SearchAlgo::Greedy,
            &floors,
            None,
            false,
            None,
            None,
        )
        .unwrap();
        let part = build_frontier_synthetic_partitioned(
            layers,
            5,
            1,
            SearchAlgo::Greedy,
            &floors,
            1,
            None,
            false,
            None,
            None,
        )
        .unwrap();
        assert_eq!(
            part.artifact.to_json().to_string(),
            mono.artifact.to_json().to_string(),
            "K=1 must reproduce the monolithic artifact byte for byte"
        );
    }

    #[test]
    fn composed_frontier_is_deterministic_and_monotone() {
        let layers = 24;
        let floors = [0.9, 0.99];
        let a = build_frontier_synthetic_partitioned(
            layers, 7, 1, SearchAlgo::Greedy, &floors, 4, None, false, None, None,
        )
        .unwrap();
        let b = build_frontier_synthetic_partitioned(
            layers, 7, 2, SearchAlgo::Greedy, &floors, 4, None, false, None, None,
        )
        .unwrap();
        assert_eq!(
            a.artifact.to_json().to_string(),
            b.artifact.to_json().to_string(),
            "composed artifact must not depend on concurrency"
        );
        assert_eq!(a.artifact.partitions, 4);
        for trail in &a.artifact.trails {
            let first = &trail.points[0];
            assert_eq!(first.decisions, 0, "trail opens with the float baseline");
            assert!((first.rel_latency - 1.0).abs() < 1e-12);
            for pair in trail.points.windows(2) {
                assert!(pair[0].decisions < pair[1].decisions, "decision counts must increase");
                assert!(
                    pair[1].rel_size <= pair[0].rel_size + 1e-12,
                    "composed trail walks toward smaller configs"
                );
            }
        }
    }
}
