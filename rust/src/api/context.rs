//! [`ModelContext`] — one model's pipeline + cost model + calibration
//! state, built from a [`SearchSpec`].
//!
//! This is the former `report::experiments::ExperimentCtx`, moved behind
//! the API front door so every entry point (CLI, reports, examples,
//! serving startup) constructs pipelines, cost backends, and eval caches
//! the same way. `report::experiments` re-exports it under its old name.
//!
//! With `workers > 1` the context owns a shared [`PipelinePool`]: sharded
//! calibration, Hessian-trace, and ε_N noise jobs run on it through
//! [`crate::coordinator::shard`], and the context's [`SearchEnv`] impl
//! evaluates through it — so searches, report grids, and `mpq
//! calibrate`/`mpq sensitivity` all acquire scales and results from one
//! pool, built once. [`ModelContext::take_pool`] hands that same warm
//! pool to the serving engine at `mpq serve` startup.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::Context as _;

use crate::coordinator::{
    shard, EvalCache, EvalResult, Pipeline, PipelinePool, SearchEnv, StageRunner,
};
use crate::latency::{AccelModel, CostModel, DeployScale, KernelTable};
use crate::model::Manifest;
use crate::quant::{AdjustReport, CalibrationOptions, QuantConfig, Scales};
use crate::sensitivity::{self, InterLayerOptions, MetricKind, NoiseOptions, Sensitivity};
use crate::Result;

use super::{log_event, BackendSpec, CacheSpec, ObjectiveSpec, ScaleSpec, SearchEvent, SearchSpec};

impl BackendSpec {
    /// Build the cost model this backend describes for `manifest`.
    pub fn cost_model(&self, manifest: &Manifest, scale: ScaleSpec) -> Result<CostModel> {
        let deploy = match scale {
            ScaleSpec::Reference => DeployScale::for_manifest(manifest),
            ScaleSpec::Native => DeployScale::native(),
        };
        match self {
            BackendSpec::A100Like => {
                Ok(CostModel::with_scale(manifest, &AccelModel::a100_like(), deploy))
            }
            BackendSpec::TpuLike => {
                Ok(CostModel::with_scale(manifest, &AccelModel::tpu_like(), deploy))
            }
            BackendSpec::MeasuredTable(path) => {
                let text = std::fs::read_to_string(path)
                    .with_context(|| format!("reading kernel table {}", path.display()))?;
                let table = KernelTable::from_json(&text)
                    .with_context(|| format!("parsing kernel table {}", path.display()))?;
                let name = path
                    .file_name()
                    .map(|n| n.to_string_lossy().into_owned())
                    .unwrap_or_else(|| path.display().to_string());
                CostModel::with_table(manifest, table, deploy, format!("measured/{name}"))
            }
        }
    }
}

/// A model pipeline + its cost model + calibration state (and, at
/// `workers > 1`, the shared worker pool every stage fans across).
pub struct ModelContext {
    pub pipeline: Pipeline,
    pub cost: Arc<CostModel>,
    /// Objective the spec asked for; report cells build it per target.
    pub objective: ObjectiveSpec,
    cache: CacheSpec,
    calibrated: bool,
    workers: usize,
    pool: Option<PipelinePool>,
}

impl ModelContext {
    /// On-disk sensitivity cache schema version. Bumped to 2 when Hessian
    /// probes became trial-addressable (`probe_seed(seed, trial)`), to 3
    /// when ε_N perturbations became (layer, trial)-addressable
    /// (`noise_seed(seed, layer, trial)`), and to 4 when the
    /// pair-addressed inter-layer metric landed: v1/v2 files carry scores
    /// drawn from a sequentially shared RNG and would order layers
    /// differently, so they are recomputed rather than trusted. The v3→v4
    /// bump changed no existing metric's draws, so the gate is
    /// per-metric ([`sensitivity::ScoreCache::min_version_for`]): v3
    /// Hessian/noise/QE files survive the upgrade, only inter-layer
    /// entries require v4. The version itself lives with the cache type
    /// ([`sensitivity::ScoreCache::VERSION`]); this alias keeps the
    /// long-standing `ModelContext` spelling.
    pub const SENS_CACHE_VERSION: usize = sensitivity::ScoreCache::VERSION;

    /// Context with default spec settings (A100-like analytical costing,
    /// reference deploy scale, unbounded cache, one worker).
    pub fn new(artifacts_dir: &Path, model: &str) -> Result<Self> {
        Self::from_spec(&SearchSpec::new(model).artifacts_dir(artifacts_dir))
    }

    /// Build the context a [`SearchSpec`] describes. The worker pool (for
    /// `workers > 1`) is built lazily on first calibration.
    pub fn from_spec(spec: &SearchSpec) -> Result<Self> {
        spec.validate()?;
        let dir = spec.resolved_artifacts_dir()?;
        let pipeline = Pipeline::new(&dir, &spec.model)
            .with_context(|| format!("building pipeline for {}", spec.model))?;
        let cost =
            Arc::new(spec.backend.cost_model(&pipeline.artifacts.manifest, spec.deploy_scale)?);
        Ok(Self {
            pipeline,
            cost,
            objective: spec.objective,
            cache: spec.cache.clone(),
            calibrated: false,
            workers: spec.workers.max(1),
            pool: None,
        })
    }

    /// Worker pipelines evaluation and calibration fan across (1 = the
    /// single context pipeline).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The shared worker pool, if one has been built (`workers > 1` and
    /// calibration has run).
    pub fn pool(&self) -> Option<&PipelinePool> {
        self.pool.as_ref()
    }

    /// Move the shared worker pool out of the context — the warm-pool
    /// handover [`crate::api::SearchSession::into_server`] uses so serving
    /// reuses the already-built, already-calibrated worker pipelines
    /// instead of constructing a second pool (and re-uploading every
    /// weight). The context falls back to its single pipeline for any
    /// later evaluation.
    pub fn take_pool(&mut self) -> Option<PipelinePool> {
        self.pool.take()
    }

    /// Where this context's persistent eval cache lives: the spec's
    /// explicit override, or the shared multi-model store layout
    /// `<artifacts>/<model>/evalcache.json`
    /// ([`EvalCache::store_path`]). A legacy flat
    /// `<model>_evalcache.json` file is migrated into the store when the
    /// cache is attached.
    pub fn eval_cache_path(&self) -> PathBuf {
        self.cache.path.clone().unwrap_or_else(|| {
            EvalCache::store_path(
                &self.pipeline.artifacts.dir,
                &self.pipeline.artifacts.manifest.model,
            )
        })
    }

    /// [`Self::eval_cache_path`] with the legacy flat layout migrated into
    /// the store (attach-time only — path resolution itself stays pure).
    fn eval_cache_attach_path(&self) -> PathBuf {
        match &self.cache.path {
            Some(path) => path.clone(),
            None => EvalCache::migrate_flat_layout(
                &self.pipeline.artifacts.dir,
                &self.pipeline.artifacts.manifest.model,
            ),
        }
    }

    /// The configured eval-cache entry bound, if any.
    pub fn eval_cache_capacity(&self) -> Option<usize> {
        self.cache.capacity
    }

    /// Whether the persistent eval cache is enabled for this context.
    pub fn eval_cache_enabled(&self) -> bool {
        self.cache.enabled
    }

    /// Where this context persists calibrated scales.
    pub fn scales_path(&self) -> PathBuf {
        self.pipeline
            .artifacts
            .dir
            .join(format!("{}_scales.json", self.pipeline.artifacts.manifest.model))
    }

    /// Build the shared pool on first use (`workers > 1`). Workers load
    /// persisted scales when present; otherwise they start at identity
    /// and receive the calibrated scales by broadcast.
    fn ensure_pool(&mut self) -> Result<()> {
        if self.workers <= 1 || self.pool.is_some() {
            return Ok(());
        }
        let dir = self.pipeline.artifacts.dir.clone();
        let model = self.pipeline.artifacts.manifest.model.clone();
        let scales_path = self.scales_path();
        let pool = PipelinePool::new(&dir, &model, self.workers, move |p| {
            if scales_path.is_file() {
                let scales = Scales::load(&scales_path)?;
                if scales.num_layers() == p.num_quant_layers() {
                    p.scales = scales;
                    return p.sync_scales();
                }
            }
            Ok(())
        })?;
        self.pool = Some(pool);
        Ok(())
    }

    /// Calibrate scales once per context; reuse a cached scale file when
    /// the artifacts directory already holds one from a previous run.
    /// Calibration runs through the sharded stage driver — on the shared
    /// [`PipelinePool`] when `workers > 1`, on the context pipeline
    /// otherwise; both are bit-identical. Once the scales are final, the
    /// persistent cross-run eval cache is attached wherever evaluations
    /// run (pool or pipeline, honoring the spec's path/capacity), so
    /// repeated table/ablation runs skip already-measured configurations
    /// entirely.
    pub fn ensure_calibrated(&mut self) -> Result<()> {
        self.ensure_calibrated_with(None)
    }

    /// [`Self::ensure_calibrated`] with a typed [`SearchEvent`] observer;
    /// `None` falls back to the stderr renderer
    /// [`crate::api::log_event`].
    pub fn ensure_calibrated_with(
        &mut self,
        observer: Option<&mut dyn FnMut(&SearchEvent)>,
    ) -> Result<()> {
        if self.calibrated {
            return Ok(());
        }
        let mut fallback = log_event;
        let obs: &mut dyn FnMut(&SearchEvent) = match observer {
            Some(o) => o,
            None => &mut fallback,
        };
        self.ensure_pool()?;
        let path = self.scales_path();
        let mut loaded = false;
        if path.is_file() {
            let scales = Scales::load(&path)?;
            if scales.num_layers() == self.pipeline.num_quant_layers() {
                self.pipeline.scales = scales;
                self.pipeline.sync_scales()?;
                // Pool workers load the same file at construction; the
                // re-broadcast covers a pool built before the file existed.
                if let Some(pool) = self.pool.as_mut() {
                    pool.broadcast_scales(&self.pipeline.scales)?;
                }
                obs(&SearchEvent::ScalesLoaded { path: path.display().to_string() });
                loaded = true;
            }
        }
        if !loaded {
            self.calibrate_now(&CalibrationOptions::default(), &mut *obs)?;
        }
        if self.cache.enabled {
            let cache_path = self.eval_cache_attach_path();
            match self.pool.as_mut() {
                Some(pool) => pool.attach_eval_cache(
                    &cache_path,
                    &self.pipeline.eval_context(),
                    self.cache.capacity,
                ),
                None => self.pipeline.attach_eval_cache_bounded(&cache_path, self.cache.capacity),
            }
            let entries = match self.pool.as_ref() {
                Some(pool) => pool.eval_cache_len(),
                None => self.pipeline.eval_cache().map_or(0, EvalCache::len),
            };
            if entries > 0 {
                obs(&SearchEvent::EvalCacheAttached {
                    entries,
                    path: cache_path.display().to_string(),
                });
            }
        }
        self.calibrated = true;
        Ok(())
    }

    /// Force a fresh two-step scale estimation through the sharded driver
    /// (ignoring any cached scale file), install the final scales on the
    /// context pipeline and every pool worker, and persist them next to
    /// the artifacts — the `mpq calibrate` entry point.
    pub fn calibrate_with(
        &mut self,
        opts: &CalibrationOptions,
        observer: Option<&mut dyn FnMut(&SearchEvent)>,
    ) -> Result<AdjustReport> {
        let mut fallback = log_event;
        let obs: &mut dyn FnMut(&SearchEvent) = match observer {
            Some(o) => o,
            None => &mut fallback,
        };
        self.ensure_pool()?;
        self.calibrate_now(opts, obs)
    }

    fn calibrate_now(
        &mut self,
        opts: &CalibrationOptions,
        obs: &mut dyn FnMut(&SearchEvent),
    ) -> Result<AdjustReport> {
        let (scales, report) = match self.pool.as_mut() {
            Some(pool) => shard::calibrate_sharded(pool, opts, Some(obs))?,
            None => shard::calibrate_sharded(&mut self.pipeline, opts, Some(obs))?,
        };
        if self.pool.is_some() {
            // The pool workers received the final scales by broadcast;
            // mirror them onto the context pipeline.
            self.pipeline.scales = scales;
            self.pipeline.sync_scales()?;
        }
        self.pipeline.scales.save(&self.scales_path()).context("saving scales")?;
        if self.calibrated && self.cache.enabled {
            // Recalibration after ensure_calibrated: the scale change
            // flushed and detached the previously attached eval cache
            // (its context fingerprint no longer matched). Re-attach it
            // under the new scales so the session keeps its cross-run
            // caching.
            let cache_path = self.eval_cache_attach_path();
            match self.pool.as_mut() {
                Some(pool) => pool.attach_eval_cache(
                    &cache_path,
                    &self.pipeline.eval_context(),
                    self.cache.capacity,
                ),
                None => self.pipeline.attach_eval_cache_bounded(&cache_path, self.cache.capacity),
            }
        }
        Ok(report)
    }

    /// Persist whatever eval cache the active environment holds.
    pub fn flush_eval_cache(&mut self) -> Result<()> {
        match self.pool.as_ref() {
            Some(pool) => pool.flush_eval_cache(),
            None => self.pipeline.flush_eval_cache(),
        }
    }

    /// Lookups the active environment answered without touching a device:
    /// `(memo hits, persistent cross-run cache hits)`.
    pub fn cache_hits(&self) -> (usize, usize) {
        match self.pool.as_ref() {
            Some(pool) => pool.cache_hits(),
            None => (self.pipeline.stats.cache_hits, self.pipeline.stats.persistent_hits),
        }
    }

    pub fn model(&self) -> String {
        self.pipeline.artifacts.manifest.model.clone()
    }

    /// The sensitivity ordering a spec asks for (Random is seeded, not
    /// disk-cached; informed metrics go through [`Self::cached_sensitivity`]).
    pub fn sensitivity_for(&mut self, spec: &SearchSpec) -> Result<Sensitivity> {
        if spec.metric == MetricKind::Random {
            return Ok(Sensitivity::random(self.pipeline.num_quant_layers(), spec.seed));
        }
        self.cached_sensitivity(spec.metric, spec.trials, spec.seed)
    }

    /// Compute a sensitivity metric, caching scores on disk keyed by
    /// (model, metric, trials, seed) — Hessian/Noise are the most expensive
    /// steps of a table run and are identical across invocations (§Perf).
    /// Both device-driven metrics run through the sharded stage driver
    /// (pool when present): every path draws item-seeded probes/
    /// perturbations, so the cached scores are worker-count independent.
    /// Cache files carry [`Self::SENS_CACHE_VERSION`]; files written under
    /// an older draw scheme (v1: shared Hessian RNG; v2: serial shared-RNG
    /// noise) are recomputed via [`sensitivity::ScoreCache`], so a
    /// stale cache can never break cross-machine determinism.
    pub fn cached_sensitivity(
        &mut self,
        metric: MetricKind,
        trials: usize,
        seed: u64,
    ) -> Result<Sensitivity> {
        let cache = sensitivity::ScoreCache::for_model(
            &self.pipeline.artifacts.dir,
            &self.model(),
            metric,
            trials,
            seed,
        );
        if metric != MetricKind::Random {
            if let Some(scores) = cache.load(self.pipeline.num_quant_layers()) {
                return Ok(Sensitivity::from_scores(metric, scores));
            }
        }
        let sens = match (metric, self.pool.as_mut()) {
            (MetricKind::Hessian, Some(pool)) => {
                sensitivity::hessian_sensitivity_pooled(pool, trials, seed)?
            }
            (MetricKind::Noise, Some(pool)) => sensitivity::noise_sensitivity_pooled(
                pool,
                &NoiseOptions { trials: trials.max(1), ..Default::default() },
                seed,
            )?,
            (MetricKind::InterLayer, Some(pool)) => sensitivity::interlayer_sensitivity_pooled(
                pool,
                &InterLayerOptions { trials: trials.max(1), ..Default::default() },
                seed,
            )?,
            _ => sensitivity::compute(&mut self.pipeline, metric, trials, seed)?,
        };
        if metric != MetricKind::Random {
            cache.save(&sens.scores);
        }
        Ok(sens)
    }
}

/// Evaluation routes through the shared pool when one exists, the context
/// pipeline otherwise — so searches and report grids use the pool path
/// end to end simply by driving the context.
impl SearchEnv for ModelContext {
    fn num_layers(&self) -> usize {
        self.pipeline.num_quant_layers()
    }

    fn eval(&mut self, cfg: &QuantConfig, target: Option<f64>) -> Result<EvalResult> {
        match self.pool.as_mut() {
            Some(pool) => pool.eval(cfg, target),
            None => self.pipeline.eval_config(cfg, target),
        }
    }

    fn eval_many(&mut self, cfgs: &[QuantConfig], target: Option<f64>) -> Vec<Result<EvalResult>> {
        match self.pool.as_mut() {
            Some(pool) => pool.eval_many(cfgs, target),
            None => self.pipeline.eval_many(cfgs, target),
        }
    }

    fn preferred_batch(&self) -> usize {
        self.pool.as_ref().map_or(1, |pool| pool.preferred_batch())
    }
}
