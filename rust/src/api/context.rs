//! [`ModelContext`] — one model's pipeline + cost model + calibration
//! state, built from a [`SearchSpec`].
//!
//! This is the former `report::experiments::ExperimentCtx`, moved behind
//! the API front door so every entry point (CLI, reports, examples,
//! serving startup) constructs pipelines, cost backends, and eval caches
//! the same way. `report::experiments` re-exports it under its old name.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::Context as _;

use crate::coordinator::Pipeline;
use crate::latency::{AccelModel, CostModel, DeployScale, KernelTable};
use crate::model::Manifest;
use crate::quant::{CalibrationOptions, Scales};
use crate::sensitivity::{self, MetricKind, Sensitivity};
use crate::Result;

use super::{BackendSpec, CacheSpec, ScaleSpec, SearchSpec};

impl BackendSpec {
    /// Build the cost model this backend describes for `manifest`.
    pub fn cost_model(&self, manifest: &Manifest, scale: ScaleSpec) -> Result<CostModel> {
        let deploy = match scale {
            ScaleSpec::Reference => DeployScale::for_manifest(manifest),
            ScaleSpec::Native => DeployScale::native(),
        };
        match self {
            BackendSpec::A100Like => {
                Ok(CostModel::with_scale(manifest, &AccelModel::a100_like(), deploy))
            }
            BackendSpec::TpuLike => {
                Ok(CostModel::with_scale(manifest, &AccelModel::tpu_like(), deploy))
            }
            BackendSpec::MeasuredTable(path) => {
                let text = std::fs::read_to_string(path)
                    .with_context(|| format!("reading kernel table {}", path.display()))?;
                let table = KernelTable::from_json(&text)
                    .with_context(|| format!("parsing kernel table {}", path.display()))?;
                let name = path
                    .file_name()
                    .map(|n| n.to_string_lossy().into_owned())
                    .unwrap_or_else(|| path.display().to_string());
                CostModel::with_table(manifest, table, deploy, format!("measured/{name}"))
            }
        }
    }
}

/// A model pipeline + its cost model + calibration state.
pub struct ModelContext {
    pub pipeline: Pipeline,
    pub cost: Arc<CostModel>,
    cache: CacheSpec,
    calibrated: bool,
}

impl ModelContext {
    /// Context with default spec settings (A100-like analytical costing,
    /// reference deploy scale, unbounded cache).
    pub fn new(artifacts_dir: &Path, model: &str) -> Result<Self> {
        Self::from_spec(&SearchSpec::new(model).artifacts_dir(artifacts_dir))
    }

    /// Build the context a [`SearchSpec`] describes.
    pub fn from_spec(spec: &SearchSpec) -> Result<Self> {
        spec.validate()?;
        let dir = spec.resolved_artifacts_dir()?;
        let pipeline = Pipeline::new(&dir, &spec.model)
            .with_context(|| format!("building pipeline for {}", spec.model))?;
        let cost =
            Arc::new(spec.backend.cost_model(&pipeline.artifacts.manifest, spec.deploy_scale)?);
        Ok(Self { pipeline, cost, cache: spec.cache.clone(), calibrated: false })
    }

    /// Where this context's persistent eval cache lives.
    pub fn eval_cache_path(&self) -> PathBuf {
        self.cache.path.clone().unwrap_or_else(|| {
            self.pipeline
                .artifacts
                .dir
                .join(format!("{}_evalcache.json", self.pipeline.artifacts.manifest.model))
        })
    }

    /// The configured eval-cache entry bound, if any.
    pub fn eval_cache_capacity(&self) -> Option<usize> {
        self.cache.capacity
    }

    /// Whether the persistent eval cache is enabled for this context.
    pub fn eval_cache_enabled(&self) -> bool {
        self.cache.enabled
    }

    /// Calibrate scales once per context; reuse a cached scale file when
    /// the artifacts directory already holds one from a previous run. Once
    /// the scales are final, the persistent cross-run eval cache is
    /// attached (honoring the spec's path/capacity), so repeated
    /// table/ablation runs skip already-measured configurations entirely.
    pub fn ensure_calibrated(&mut self) -> Result<()> {
        if self.calibrated {
            return Ok(());
        }
        let path = self
            .pipeline
            .artifacts
            .dir
            .join(format!("{}_scales.json", self.pipeline.artifacts.manifest.model));
        let mut loaded = false;
        if path.is_file() {
            let scales = Scales::load(&path)?;
            if scales.num_layers() == self.pipeline.num_quant_layers() {
                self.pipeline.scales = scales;
                self.pipeline.sync_scales()?;
                eprintln!("[calibration] loaded cached scales from {}", path.display());
                loaded = true;
            }
        }
        if !loaded {
            let report = self.pipeline.calibrate(&CalibrationOptions::default())?;
            eprintln!(
                "[calibration] adjusted scales over {} steps: loss {:.4} -> {:.4}",
                report.steps, report.loss_before, report.loss_after
            );
            self.pipeline.scales.save(&path)?;
        }
        if self.cache.enabled {
            let cache_path = self.eval_cache_path();
            self.pipeline.attach_eval_cache_bounded(&cache_path, self.cache.capacity);
            if let Some(cache) = self.pipeline.eval_cache() {
                if !cache.is_empty() {
                    eprintln!(
                        "[eval-cache] loaded {} exact results from {}",
                        cache.len(),
                        cache_path.display()
                    );
                }
            }
        }
        self.calibrated = true;
        Ok(())
    }

    pub fn model(&self) -> String {
        self.pipeline.artifacts.manifest.model.clone()
    }

    /// The sensitivity ordering a spec asks for (Random is seeded, not
    /// disk-cached; informed metrics go through [`Self::cached_sensitivity`]).
    pub fn sensitivity_for(&mut self, spec: &SearchSpec) -> Result<Sensitivity> {
        if spec.metric == MetricKind::Random {
            return Ok(Sensitivity::random(self.pipeline.num_quant_layers(), spec.seed));
        }
        self.cached_sensitivity(spec.metric, spec.trials, spec.seed)
    }

    /// Compute a sensitivity metric, caching scores on disk keyed by
    /// (model, metric, trials, seed) — Hessian/Noise are the most expensive
    /// steps of a table run and are identical across invocations (§Perf).
    pub fn cached_sensitivity(
        &mut self,
        metric: MetricKind,
        trials: usize,
        seed: u64,
    ) -> Result<Sensitivity> {
        use crate::util::json::{self, Value};
        let path = self.pipeline.artifacts.dir.join(format!(
            "{}_sens_{}_{}_{}.json",
            self.model(),
            metric.label().to_lowercase(),
            trials,
            seed
        ));
        if metric != MetricKind::Random && path.is_file() {
            if let Ok(v) = json::parse(&std::fs::read_to_string(&path)?) {
                let scores: Option<Vec<f64>> = v
                    .req("scores")
                    .ok()
                    .and_then(|s| s.as_arr().ok())
                    .map(|arr| arr.iter().filter_map(|x| x.as_f64().ok()).collect());
                if let Some(scores) = scores {
                    if scores.len() == self.pipeline.num_quant_layers() {
                        return Ok(Sensitivity::from_scores(metric, scores));
                    }
                }
            }
        }
        let sens = sensitivity::compute(&mut self.pipeline, metric, trials, seed)?;
        if metric != MetricKind::Random {
            let v = Value::obj(vec![(
                "scores",
                Value::Arr(sens.scores.iter().map(|&s| Value::Num(s)).collect()),
            )]);
            let _ = std::fs::write(&path, v.to_string());
        }
        Ok(sens)
    }
}
