//! The deployment cost contract consumed by objectives and reports.

use crate::quant::QuantConfig;

/// Anything that can price a configuration for deployment.
///
/// The paper's methodology is two-phase: profile per-kernel latencies once,
/// then *look up and compose* during search. This trait is the composed
/// side of that contract, abstracted over where the per-kernel numbers come
/// from — [`crate::latency::CostModel`] implements it for the analytical
/// rooflines ([`crate::latency::AccelModel`]) and for measured
/// [`crate::latency::KernelTable`] files alike, and synthetic
/// implementations ([`super::SyntheticCost`]) let objective logic be tested
/// without artifacts. [`provenance`](CostModel::provenance) travels into
/// reports so every table says which cost source produced it.
pub trait CostModel: Send + Sync {
    /// End-to-end latency relative to the fp16 baseline (1.0 = baseline).
    fn rel_latency(&self, cfg: &QuantConfig) -> f64;

    /// Model size relative to the fp16 baseline (1.0 = baseline).
    fn rel_size(&self, cfg: &QuantConfig) -> f64;

    /// Absolute end-to-end latency, seconds (batch 1).
    fn latency_s(&self, cfg: &QuantConfig) -> f64;

    /// Absolute model size, bytes.
    fn size_bytes(&self, cfg: &QuantConfig) -> f64;

    /// Where the numbers come from: `analytical/a100-like`,
    /// `measured/<file>`, `synthetic`, ... Recorded in reports.
    fn provenance(&self) -> &str;
}
