//! Pluggable search objectives — constrained optimization over
//! configurations.
//!
//! The paper's searches maximize compression subject to a hard accuracy
//! floor; that test used to be hard-coded as `accuracy >= target` inside
//! both algorithms. [`Objective`] generalizes it: every candidate decision
//! asks [`Objective::accept`], and after every accepted decision the search
//! asks [`Objective::satisfied`] whether its budgets are already met — at
//! which point it stops quantizing further, preserving maximal accuracy
//! (Markovich-Golan et al., "time gained under a constrained loss").
//!
//! [`AccuracyTarget`] reproduces the historical behaviour bit-identically:
//! `accept` is exactly the old accuracy test and `satisfied` is always
//! false, so the search runs to exhaustion. [`LatencyBudget`] and
//! [`FootprintBudget`] add a deployment budget from a [`CostModel`];
//! quantization only ever lowers modeled cost, so the trajectory up to the
//! stopping point is identical to the accuracy-only trajectory — budgets
//! choose *where to stop*, never *which layer to accept*.

use std::sync::Arc;

use crate::coordinator::EvalResult;
use crate::quant::QuantConfig;

use super::CostModel;

/// The realized metrics of a finished configuration — what a frontier
/// point or a sweep cell knows about itself. [`Objective::score`] ranks
/// these without re-running any search, which is how
/// [`super::FrontierArtifact::best_for`] selects from a Pareto set
/// without downcasting the objective.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CellMetrics {
    /// Exact accuracy of the configuration.
    pub accuracy: f64,
    /// Modeled latency relative to the float baseline.
    pub rel_latency: f64,
    /// Modeled size relative to the float baseline.
    pub rel_size: f64,
}

/// A constrained search objective: hard accuracy floor plus optional
/// deployment budgets.
pub trait Objective: Send + Sync {
    /// The hard accuracy floor. Drives accept/reject decisions and is
    /// passed to evaluations as the early-exit target, so results only
    /// need to be decisive against this bound.
    fn accuracy_floor(&self) -> f64;

    /// Accept or reject a candidate configuration given its evaluation.
    /// `result.accuracy` may be a bound from an early-exited evaluation;
    /// it is guaranteed decisive against [`Objective::accuracy_floor`], so
    /// implementations must compare against that floor only (any cost
    /// terms must be deterministic functions of `cfg`).
    fn accept(&self, cfg: &QuantConfig, result: &EvalResult) -> bool {
        let _ = cfg;
        result.accuracy >= self.accuracy_floor()
    }

    /// True once every budget is met by `cfg`; the search then stops
    /// quantizing further. The default (no budgets) never stops early.
    fn satisfied(&self, _cfg: &QuantConfig) -> bool {
        false
    }

    /// The budgeted relative cost of `cfg`, if this objective tracks one
    /// (for events and reports).
    fn cost_of(&self, _cfg: &QuantConfig) -> Option<f64> {
        None
    }

    /// Scalarize a finished configuration's metrics: `Some(score)` when
    /// the metrics satisfy this objective's constraints (higher is
    /// better), `None` when they are infeasible. This is the ranking
    /// half of the constraint/score split — it never influences search
    /// decisions (those go through [`Objective::accept`] and
    /// [`Objective::satisfied`]), only post-hoc selection over already
    /// evaluated candidates. The default objective ranks nothing.
    fn score(&self, _metrics: &CellMetrics) -> Option<f64> {
        None
    }

    /// Stable human-readable description; also part of checkpoint
    /// fingerprints, so resumed runs reject objective changes.
    fn describe(&self) -> String;
}

/// The paper's original objective: accuracy ≥ floor, compress to
/// exhaustion. Reproduces pre-objective search decisions bit-identically.
#[derive(Debug, Clone, Copy)]
pub struct AccuracyTarget {
    floor: f64,
}

impl AccuracyTarget {
    pub fn new(floor: f64) -> Self {
        Self { floor }
    }
}

impl Objective for AccuracyTarget {
    fn accuracy_floor(&self) -> f64 {
        self.floor
    }

    fn score(&self, metrics: &CellMetrics) -> Option<f64> {
        (metrics.accuracy >= self.floor).then_some(metrics.accuracy)
    }

    fn describe(&self) -> String {
        format!("accuracy>={}", self.floor)
    }
}

/// Accuracy floor plus a relative-latency budget: stop quantizing as soon
/// as modeled latency drops to `budget` × fp16 baseline.
pub struct LatencyBudget {
    floor: f64,
    budget: f64,
    cost: Arc<dyn CostModel>,
}

impl LatencyBudget {
    pub fn new(floor: f64, budget: f64, cost: Arc<dyn CostModel>) -> Self {
        Self { floor, budget, cost }
    }
}

impl Objective for LatencyBudget {
    fn accuracy_floor(&self) -> f64 {
        self.floor
    }

    fn satisfied(&self, cfg: &QuantConfig) -> bool {
        self.cost.rel_latency(cfg) <= self.budget
    }

    fn cost_of(&self, cfg: &QuantConfig) -> Option<f64> {
        Some(self.cost.rel_latency(cfg))
    }

    fn score(&self, metrics: &CellMetrics) -> Option<f64> {
        (metrics.accuracy >= self.floor && metrics.rel_latency <= self.budget)
            .then_some(metrics.accuracy)
    }

    fn describe(&self) -> String {
        format!(
            "accuracy>={} rel_latency<={} ({})",
            self.floor,
            self.budget,
            self.cost.provenance()
        )
    }
}

/// Accuracy floor plus a relative-size budget: stop quantizing as soon as
/// model size drops to `budget` × fp16 baseline.
pub struct FootprintBudget {
    floor: f64,
    budget: f64,
    cost: Arc<dyn CostModel>,
}

impl FootprintBudget {
    pub fn new(floor: f64, budget: f64, cost: Arc<dyn CostModel>) -> Self {
        Self { floor, budget, cost }
    }
}

impl Objective for FootprintBudget {
    fn accuracy_floor(&self) -> f64 {
        self.floor
    }

    fn satisfied(&self, cfg: &QuantConfig) -> bool {
        self.cost.rel_size(cfg) <= self.budget
    }

    fn cost_of(&self, cfg: &QuantConfig) -> Option<f64> {
        Some(self.cost.rel_size(cfg))
    }

    fn score(&self, metrics: &CellMetrics) -> Option<f64> {
        (metrics.accuracy >= self.floor && metrics.rel_size <= self.budget)
            .then_some(metrics.accuracy)
    }

    fn describe(&self) -> String {
        format!(
            "accuracy>={} rel_size<={} ({})",
            self.floor,
            self.budget,
            self.cost.provenance()
        )
    }
}
