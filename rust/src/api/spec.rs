//! [`SearchSpec`] — the validated, declarative description of one search
//! run, and the single front door to the system.
//!
//! Every entry point (CLI subcommands, report drivers, examples, serving
//! startup) builds a `SearchSpec` and opens it into a
//! [`super::ModelContext`] or [`super::SearchSession`] instead of
//! hand-wiring `Pipeline`/`PipelinePool`/`EvalCache` combinations.
//!
//! ```no_run
//! use mpq::api::SearchSpec;
//! use mpq::coordinator::SearchAlgo;
//!
//! let report = SearchSpec::new("bert_s")
//!     .algo(SearchAlgo::Greedy)
//!     .target(0.99)
//!     .latency_budget(0.7) // stop once modeled latency ≤ 70% of fp16
//!     .workers(4)
//!     .checkpoint("bert_s_search.ck.json")
//!     .open()?
//!     .run()?;
//! println!("rel latency {:.1}%", report.rel_latency * 100.0);
//! # Ok::<(), anyhow::Error>(())
//! ```

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::ensure;

use crate::coordinator::SearchAlgo;
use crate::sensitivity::MetricKind;
use crate::Result;

use super::{
    AccuracyTarget, CostModel, FootprintBudget, LatencyBudget, ModelContext, Objective, PickSpec,
    SearchSession,
};

/// Default Hutchinson/noise trials for metric computations (the paper's 5).
pub const DEFAULT_TRIALS: usize = 5;

/// Which objective drives the search (data form; built into a live
/// [`Objective`] once the accuracy floor and cost model are known).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ObjectiveSpec {
    /// Accuracy floor only; compress to exhaustion (the paper's setting).
    AccuracyTarget,
    /// Accuracy floor + relative latency budget; stop once met.
    LatencyBudget { rel_latency: f64 },
    /// Accuracy floor + relative size budget; stop once met.
    FootprintBudget { rel_size: f64 },
}

impl ObjectiveSpec {
    /// Instantiate with a concrete accuracy floor and cost model.
    pub fn build(&self, floor: f64, cost: Arc<dyn CostModel>) -> Box<dyn Objective> {
        match *self {
            ObjectiveSpec::AccuracyTarget => Box::new(AccuracyTarget::new(floor)),
            ObjectiveSpec::LatencyBudget { rel_latency } => {
                Box::new(LatencyBudget::new(floor, rel_latency, cost))
            }
            ObjectiveSpec::FootprintBudget { rel_size } => {
                Box::new(FootprintBudget::new(floor, rel_size, cost))
            }
        }
    }
}

/// Where per-kernel latencies come from.
#[derive(Debug, Clone, PartialEq)]
pub enum BackendSpec {
    /// Analytical A100-like roofline (the paper's profiled hardware).
    A100Like,
    /// Analytical TPU-v4-like roofline (no int4 math pipeline).
    TpuLike,
    /// A measured kernel table (JSON, see
    /// [`crate::latency::KernelTable::from_json`]); validated at open time
    /// against the model's layers.
    MeasuredTable(PathBuf),
}

/// How stand-in models are scaled for costing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleSpec {
    /// Scale to the reference deployment footprint
    /// ([`crate::latency::DeployScale::for_manifest`]).
    Reference,
    /// Cost the stand-in architecture as-is.
    Native,
}

/// Persistent eval-cache configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheSpec {
    pub enabled: bool,
    /// Override path; default is the shared multi-model store layout
    /// `<artifacts>/<model>/evalcache.json` (legacy flat
    /// `<model>_evalcache.json` files migrate in on first attach).
    pub path: Option<PathBuf>,
    /// Entry bound with last-used-ordered eviction; `None` = unbounded.
    pub capacity: Option<usize>,
}

impl Default for CacheSpec {
    fn default() -> Self {
        Self { enabled: true, path: None, capacity: None }
    }
}

/// A validated description of one search run.
#[derive(Debug, Clone)]
pub struct SearchSpec {
    pub model: String,
    pub artifacts_dir: Option<PathBuf>,
    pub algo: SearchAlgo,
    pub metric: MetricKind,
    /// Accuracy floor as a fraction of the float baseline, in `(0, 1]`.
    pub target: f64,
    pub seed: u64,
    pub trials: usize,
    /// Worker pipelines; `1` = single-pipeline sequential-equivalent path.
    pub workers: usize,
    pub objective: ObjectiveSpec,
    pub backend: BackendSpec,
    pub deploy_scale: ScaleSpec,
    pub cache: CacheSpec,
    pub checkpoint: Option<PathBuf>,
    pub resume: bool,
    /// Contiguous segments the sensitivity order is split into; `1` = the
    /// monolithic whole-model search (bit-identical to the pre-partition
    /// behaviour), `K>1` searches segments concurrently and composes the
    /// results with a global budget reconciliation pass.
    pub partitions: usize,
}

impl SearchSpec {
    /// A spec with the paper's defaults: greedy, Hessian guidance, 99%
    /// relative accuracy target, A100-like analytical costing.
    pub fn new(model: impl Into<String>) -> Self {
        Self {
            model: model.into(),
            artifacts_dir: None,
            algo: SearchAlgo::Greedy,
            metric: MetricKind::Hessian,
            target: 0.99,
            seed: 0,
            trials: DEFAULT_TRIALS,
            workers: 1,
            objective: ObjectiveSpec::AccuracyTarget,
            backend: BackendSpec::A100Like,
            deploy_scale: ScaleSpec::Reference,
            cache: CacheSpec::default(),
            checkpoint: None,
            resume: false,
            partitions: 1,
        }
    }

    pub fn artifacts_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts_dir = Some(dir.into());
        self
    }

    pub fn algo(mut self, algo: SearchAlgo) -> Self {
        self.algo = algo;
        self
    }

    pub fn metric(mut self, metric: MetricKind) -> Self {
        self.metric = metric;
        self
    }

    pub fn target(mut self, target: f64) -> Self {
        self.target = target;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn trials(mut self, trials: usize) -> Self {
        self.trials = trials;
        self
    }

    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    pub fn objective(mut self, objective: ObjectiveSpec) -> Self {
        self.objective = objective;
        self
    }

    /// Shorthand for [`ObjectiveSpec::LatencyBudget`].
    pub fn latency_budget(self, rel_latency: f64) -> Self {
        self.objective(ObjectiveSpec::LatencyBudget { rel_latency })
    }

    /// Shorthand for [`ObjectiveSpec::FootprintBudget`].
    pub fn footprint_budget(self, rel_size: f64) -> Self {
        self.objective(ObjectiveSpec::FootprintBudget { rel_size })
    }

    pub fn backend(mut self, backend: BackendSpec) -> Self {
        self.backend = backend;
        self
    }

    /// Use a measured kernel table instead of the analytical roofline.
    pub fn measured_table(self, path: impl Into<PathBuf>) -> Self {
        self.backend(BackendSpec::MeasuredTable(path.into()))
    }

    pub fn deploy_scale(mut self, scale: ScaleSpec) -> Self {
        self.deploy_scale = scale;
        self
    }

    pub fn cache_path(mut self, path: impl Into<PathBuf>) -> Self {
        self.cache.path = Some(path.into());
        self
    }

    /// Bound the persistent eval cache to `capacity` entries
    /// (last-used-ordered eviction).
    pub fn cache_capacity(mut self, capacity: usize) -> Self {
        self.cache.capacity = Some(capacity);
        self
    }

    /// Disable the persistent cross-run eval cache.
    pub fn no_cache(mut self) -> Self {
        self.cache.enabled = false;
        self
    }

    /// Write decision checkpoints to `path` (enables `--resume`).
    pub fn checkpoint(mut self, path: impl Into<PathBuf>) -> Self {
        self.checkpoint = Some(path.into());
        self
    }

    /// Resume from the checkpoint instead of starting fresh.
    pub fn resume(mut self, resume: bool) -> Self {
        self.resume = resume;
        self
    }

    /// Split the sensitivity order into `partitions` contiguous segments
    /// searched concurrently (see [`crate::api::PartitionedDriver`]).
    pub fn partitions(mut self, partitions: usize) -> Self {
        self.partitions = partitions;
        self
    }

    /// Check everything that can be checked without touching disk.
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.model.is_empty(), "SearchSpec: model name must not be empty");
        ensure!(
            self.target.is_finite() && self.target > 0.0 && self.target <= 1.0,
            "SearchSpec: target must be in (0, 1], got {}",
            self.target
        );
        ensure!(self.workers >= 1, "SearchSpec: workers must be >= 1");
        ensure!(self.trials >= 1, "SearchSpec: trials must be >= 1");
        match self.objective {
            ObjectiveSpec::AccuracyTarget => {}
            ObjectiveSpec::LatencyBudget { rel_latency } => ensure!(
                rel_latency.is_finite() && rel_latency > 0.0 && rel_latency <= 1.0,
                "SearchSpec: latency budget must be in (0, 1], got {rel_latency}"
            ),
            ObjectiveSpec::FootprintBudget { rel_size } => ensure!(
                rel_size.is_finite() && rel_size > 0.0 && rel_size <= 1.0,
                "SearchSpec: footprint budget must be in (0, 1], got {rel_size}"
            ),
        }
        ensure!(
            self.cache.capacity != Some(0),
            "SearchSpec: cache capacity must be >= 1 (use no_cache() to disable caching)"
        );
        ensure!(
            !self.resume || self.checkpoint.is_some(),
            "SearchSpec: resume requires a checkpoint path"
        );
        ensure!(self.partitions >= 1, "SearchSpec: partitions must be >= 1");
        Ok(())
    }

    /// The artifacts directory this spec resolves to: the explicit one, or
    /// the workspace discovery of [`crate::artifacts_dir`].
    pub fn resolved_artifacts_dir(&self) -> Result<PathBuf> {
        if let Some(dir) = &self.artifacts_dir {
            return Ok(dir.clone());
        }
        crate::artifacts_dir()
            .ok_or_else(|| anyhow::anyhow!("no artifacts directory found — run `make artifacts`"))
    }

    /// Open the model context this spec describes (pipeline + cost model +
    /// cache configuration), without search bookkeeping.
    pub fn open_context(self) -> Result<ModelContext> {
        ModelContext::from_spec(&self)
    }

    /// Open a full [`SearchSession`].
    pub fn open(self) -> Result<SearchSession> {
        SearchSession::open(self)
    }
}

// --------------------------------------------------------------- tenants

/// One serving tenant: a name plus the frontier [`PickSpec`] that selects
/// its quantization config. Parsed from `name:latency<=B,acc>=F` (the
/// constraint grammar is exactly `--pick`'s).
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSpec {
    pub name: String,
    pub pick: PickSpec,
}

impl std::str::FromStr for TenantSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        let (name, constraints) = s
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("bad tenant `{s}` (want name:latency<=B,acc>=F)"))?;
        let name = name.trim();
        ensure!(!name.is_empty(), "bad tenant `{s}`: empty name");
        Ok(Self { name: name.to_string(), pick: constraints.parse()? })
    }
}

/// Parse a `--tenants` list: `;`-separated [`TenantSpec`]s with unique
/// names, e.g. `gold:latency<=0.6,acc>=0.99;bronze:latency<=0.4`.
pub fn parse_tenants(s: &str) -> Result<Vec<TenantSpec>> {
    let mut tenants: Vec<TenantSpec> = Vec::new();
    for part in s.split(';').map(str::trim).filter(|p| !p.is_empty()) {
        let t: TenantSpec = part.parse()?;
        ensure!(
            tenants.iter().all(|seen| seen.name != t.name),
            "duplicate tenant name `{}`",
            t.name
        );
        tenants.push(t);
    }
    ensure!(!tenants.is_empty(), "no tenants in `{s}`");
    Ok(tenants)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        SearchSpec::new("resnet_s").validate().unwrap();
        SearchSpec::new("resnet_s")
            .latency_budget(0.7)
            .workers(8)
            .cache_capacity(1000)
            .checkpoint("ck.json")
            .resume(true)
            .validate()
            .unwrap();
    }

    #[test]
    fn invalid_specs_are_rejected() {
        for (spec, what) in [
            (SearchSpec::new(""), "empty model"),
            (SearchSpec::new("m").target(0.0), "target 0"),
            (SearchSpec::new("m").target(1.5), "target > 1"),
            (SearchSpec::new("m").target(f64::NAN), "NaN target"),
            (SearchSpec::new("m").workers(0), "0 workers"),
            (SearchSpec::new("m").trials(0), "0 trials"),
            (SearchSpec::new("m").latency_budget(0.0), "0 latency budget"),
            (SearchSpec::new("m").latency_budget(2.0), "latency budget > 1"),
            (SearchSpec::new("m").footprint_budget(-0.5), "negative size budget"),
            (SearchSpec::new("m").cache_capacity(0), "0 cache capacity"),
            (SearchSpec::new("m").resume(true), "resume without checkpoint"),
            (SearchSpec::new("m").partitions(0), "0 partitions"),
        ] {
            assert!(spec.validate().is_err(), "{what} should be rejected");
        }
    }

    #[test]
    fn tenants_parse() {
        let ts = parse_tenants("gold:latency<=0.6,acc>=0.99; bronze:latency<=0.4").unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].name, "gold");
        assert_eq!(ts[0].pick.max_rel_latency, Some(0.6));
        assert_eq!(ts[0].pick.min_accuracy, Some(0.99));
        assert_eq!(ts[1].name, "bronze");
        assert_eq!(ts[1].pick, PickSpec { max_rel_latency: Some(0.4), ..PickSpec::default() });
    }

    #[test]
    fn bad_tenants_are_rejected() {
        for (s, what) in [
            ("", "empty list"),
            ("gold", "missing constraints separator"),
            (":latency<=0.5", "empty name"),
            ("gold:wat<=1", "unknown constraint"),
            ("gold:latency<=0.5;gold:acc>=0.9", "duplicate name"),
        ] {
            assert!(parse_tenants(s).is_err(), "{what} should be rejected");
        }
    }
}
