//! The unified constrained-search API — the single front door to the
//! system.
//!
//! * [`SearchSpec`] — validated builder describing one search run (model,
//!   algorithm, metric, workers, objective, cost backend, cache bounds,
//!   checkpoint path).
//! * [`Objective`] — pluggable constrained-optimization objectives:
//!   [`AccuracyTarget`] (the paper's accuracy-floor search, bit-identical
//!   to the historical behaviour), [`LatencyBudget`] and
//!   [`FootprintBudget`] (stop quantizing once a deployment budget is
//!   met).
//! * [`CostModel`] — the deployment-cost contract objectives and reports
//!   consume; implemented by the analytical rooflines, measured kernel
//!   tables, and [`SyntheticCost`].
//! * [`SearchSession`] — drives either algorithm through
//!   [`crate::coordinator::SearchEnv`] (single pipeline or a worker
//!   pool), emitting a typed [`SearchEvent`] stream and writing atomic
//!   decision [`Checkpoint`]s so interrupted runs resume bit-identically.
//! * [`ModelContext`] — pipeline + cost model + calibration state (the
//!   former `ExperimentCtx`), shared by reports and the CLI.
//! * [`ParetoFront`] — one-pass frontier builder: one exhaustion search
//!   per accuracy floor yields a serializable [`FrontierArtifact`] that
//!   answers every (budget, floor) sweep cell and serve-time
//!   [`PickSpec`] selection without another search.
//! * [`PartitionedDriver`] — subgraph-partitioned search:
//!   [`Partition::split`] cuts the sensitivity order into `K` contiguous
//!   segments with pro-rated budgets/accuracy slack, segments search
//!   concurrently (each pool worker owns one), and a deterministic global
//!   budget reconciliation pass composes the per-segment results — or
//!   per-segment frontier trails — into one whole-model answer.
//! * [`SyntheticEnv`]/[`SyntheticCost`] — artifact-free environments so
//!   the whole API (budgets, checkpoints, worker fan-out) runs in CI.

mod checkpoint;
mod context;
mod cost;
mod driver;
mod events;
mod objective;
mod pareto;
mod partition;
mod session;
mod spec;
mod synthetic;

pub use checkpoint::{checkpoint_fingerprint, Checkpoint, CHECKPOINT_VERSION};
pub use context::ModelContext;
pub use cost::CostModel;
pub use driver::{run_search, SearchCtl};
pub use events::{event_json, log_event, EventSink, SearchEvent};
pub use objective::{AccuracyTarget, CellMetrics, FootprintBudget, LatencyBudget, Objective};
pub use pareto::{
    build_frontier_synthetic, frontier_fingerprint, partitioned_frontier_fingerprint, FloorTrail,
    FrontierArtifact, FrontierPoint, FrontierReport, ParetoFront, PickSpec, FRONTIER_VERSION,
};
pub use partition::{
    build_frontier_synthetic_partitioned, partitioned_search_synthetic, scoped_budget,
    scoped_floor, Partition, PartitionedDriver, PartitionedOutcome, SegmentEval, SegmentView,
    SharedSegmentEval,
};
pub use session::{SearchReport, SearchSession};
pub use spec::{
    parse_tenants, BackendSpec, CacheSpec, ObjectiveSpec, ScaleSpec, SearchSpec, TenantSpec,
    DEFAULT_TRIALS,
};
pub use synthetic::{synthetic_sensitivity, SyntheticCost, SyntheticEnv, SyntheticStage};

/// The versioned sensitivity score cache lives with the metric code but
/// is part of the API's cache surface (same idiom as the frontier
/// artifact and the decision-log checkpoint).
pub use crate::sensitivity::ScoreCache;
