//! The objective-aware search driver: [`SearchCtl`] is the handle the two
//! search algorithms consult at every decision point, and [`run_search`]
//! wires an objective, an optional observer, and an optional checkpoint
//! into one call usable with *any* [`SearchEnv`] — the artifact-backed
//! [`crate::coordinator::Pipeline`]/[`crate::coordinator::PipelinePool`]
//! or synthetic test environments.

use crate::coordinator::{EvalResult, SearchAlgo, SearchEnv, SearchOutcome};
use crate::quant::QuantConfig;
use crate::Result;

use super::{Checkpoint, Objective, SearchEvent};

/// Per-run control surface handed to `greedy::search_with` /
/// `bisection::search_with`: the objective deciding accept/reject and
/// budget satisfaction, an optional [`SearchEvent`] observer, and an
/// optional [`Checkpoint`] that records live decisions and replays
/// recorded ones on resume.
pub struct SearchCtl<'a> {
    objective: &'a dyn Objective,
    observer: Option<&'a mut dyn FnMut(&SearchEvent)>,
    checkpoint: Option<&'a mut Checkpoint>,
    satisfied_seen: bool,
}

impl<'a> SearchCtl<'a> {
    pub fn new(objective: &'a dyn Objective) -> Self {
        Self { objective, observer: None, checkpoint: None, satisfied_seen: false }
    }

    pub fn with_observer(mut self, observer: &'a mut dyn FnMut(&SearchEvent)) -> Self {
        self.observer = Some(observer);
        self
    }

    pub fn with_checkpoint(mut self, checkpoint: &'a mut Checkpoint) -> Self {
        self.checkpoint = Some(checkpoint);
        self
    }

    pub fn objective(&self) -> &dyn Objective {
        self.objective
    }

    /// The early-exit target evaluations should be decisive against.
    pub fn eval_target(&self) -> Option<f64> {
        Some(self.objective.accuracy_floor())
    }

    /// Emit one event to the observer, if any.
    pub fn emit(&mut self, ev: SearchEvent) {
        if let Some(obs) = self.observer.as_mut() {
            obs(&ev);
        }
    }

    /// Next checkpointed decision to replay (without evaluating), if any.
    pub fn take_replay(&mut self, bits: f32, index: usize) -> Option<bool> {
        let pass = self.checkpoint.as_mut()?.take_replay()?;
        self.emit(SearchEvent::Decision {
            bits,
            index,
            accepted: pass,
            accuracy: f64::NAN,
            cost: None,
            replayed: true,
        });
        Some(pass)
    }

    /// Decide one live candidate: ask the objective, record the decision
    /// in the checkpoint (atomic write), emit the event.
    pub fn decide(
        &mut self,
        bits: f32,
        index: usize,
        cfg: &QuantConfig,
        result: &EvalResult,
    ) -> Result<bool> {
        let pass = self.objective.accept(cfg, result);
        if let Some(ck) = self.checkpoint.as_mut() {
            ck.record(pass)?;
            let decisions = ck.len();
            self.emit(SearchEvent::CheckpointWritten { decisions });
        }
        self.emit(SearchEvent::Decision {
            bits,
            index,
            accepted: pass,
            accuracy: result.accuracy,
            cost: self.objective.cost_of(cfg),
            replayed: false,
        });
        Ok(pass)
    }

    /// Whether the objective's budgets are met by `cfg`; emits
    /// [`SearchEvent::BudgetSatisfied`] the first time it turns true.
    pub fn satisfied(&mut self, cfg: &QuantConfig) -> bool {
        if !self.objective.satisfied(cfg) {
            return false;
        }
        if !self.satisfied_seen {
            self.satisfied_seen = true;
            let cost = self.objective.cost_of(cfg).unwrap_or(f64::NAN);
            self.emit(SearchEvent::BudgetSatisfied { cost });
        }
        true
    }

    /// Shared baseline short-circuit: if the objective's budgets are
    /// already met by `cfg` (e.g. a budget of 1.0 at the float baseline),
    /// evaluate it exactly and return the finished outcome — there is
    /// nothing to quantize. Never fires for accuracy-only objectives.
    pub(crate) fn baseline_outcome<E: SearchEnv>(
        &mut self,
        env: &mut E,
        cfg: &QuantConfig,
    ) -> Result<Option<SearchOutcome>> {
        if !self.satisfied(cfg) {
            return Ok(None);
        }
        let r = env.eval(cfg, None)?;
        Ok(Some(SearchOutcome {
            config: cfg.clone(),
            accuracy: r.accuracy,
            evals: 1,
            target: self.objective.accuracy_floor(),
        }))
    }
}

/// Run `algo` over `env` under `objective`, with optional event observer
/// and checkpoint. With [`super::AccuracyTarget`] this produces outcomes
/// bit-identical to [`SearchAlgo::run`] at every worker count; budgeted
/// objectives stop early once satisfied. On resume, decisions already in
/// `checkpoint` are replayed without touching the environment.
pub fn run_search<E: SearchEnv>(
    algo: SearchAlgo,
    env: &mut E,
    order: &[usize],
    quant_bits: &[f32],
    objective: &dyn Objective,
    observer: Option<&mut dyn FnMut(&SearchEvent)>,
    checkpoint: Option<&mut Checkpoint>,
) -> Result<SearchOutcome> {
    let mut ctl = SearchCtl::new(objective);
    if let Some(obs) = observer {
        ctl = ctl.with_observer(obs);
    }
    if let Some(ck) = checkpoint {
        ctl = ctl.with_checkpoint(ck);
    }
    ctl.emit(SearchEvent::Started {
        algo: algo.label(),
        layers: env.num_layers(),
        objective: objective.describe(),
    });
    let outcome = algo.run_with(env, order, quant_bits, &mut ctl)?;
    ctl.emit(SearchEvent::Finished { accuracy: outcome.accuracy, evals: outcome.evals });
    Ok(outcome)
}
