//! [`SearchSession`] — a [`SearchSpec`] opened against real artifacts.
//!
//! The session owns the [`ModelContext`], multiplexes [`SearchEvent`]
//! observers, builds the objective over the context's cost model, drives
//! either algorithm through [`crate::coordinator::SearchEnv`] (one
//! pipeline, or a [`PipelinePool`] when `workers > 1`), and wires atomic
//! decision checkpoints + the persistent eval cache so interrupted runs
//! resume bit-identically.

use std::time::Instant;

use crate::coordinator::{PipelinePool, SearchAlgo, SearchOutcome};
use crate::quant::{QuantConfig, Scales, QUANT_BITS};
use crate::sensitivity::MetricKind;
use crate::server::{ServeOptions, ServerHandle};
use crate::Result;

use super::{
    checkpoint_fingerprint, run_search, Checkpoint, ModelContext, SearchEvent, SearchSpec,
};

/// Everything a finished search run reports.
#[derive(Debug)]
pub struct SearchReport {
    pub outcome: SearchOutcome,
    pub algo: SearchAlgo,
    pub metric: MetricKind,
    /// Final size relative to fp16 (fraction).
    pub rel_size: f64,
    /// Final modeled latency relative to fp16 (fraction).
    pub rel_latency: f64,
    /// Cost-model provenance recorded for the tables.
    pub cost_provenance: String,
    pub search_seconds: f64,
    pub workers: usize,
    /// Decisions replayed from a checkpoint (0 for fresh runs).
    pub replayed_decisions: usize,
    /// Total decisions in the checkpoint after the run (0 if none).
    pub checkpointed_decisions: usize,
}

/// A live search session over one model's artifacts.
pub struct SearchSession {
    spec: SearchSpec,
    pub ctx: ModelContext,
    observers: Vec<Box<dyn FnMut(&SearchEvent)>>,
}

impl SearchSession {
    /// Open `spec` (validates, loads artifacts, builds the cost model).
    pub fn open(spec: SearchSpec) -> Result<Self> {
        let ctx = ModelContext::from_spec(&spec)?;
        Ok(Self { spec, ctx, observers: Vec::new() })
    }

    pub fn spec(&self) -> &SearchSpec {
        &self.spec
    }

    /// Attach a [`SearchEvent`] observer (multiple observers all fire).
    pub fn on_event(&mut self, observer: impl FnMut(&SearchEvent) + 'static) -> &mut Self {
        self.observers.push(Box::new(observer));
        self
    }

    /// Run the spec's algorithm.
    pub fn run(&mut self) -> Result<SearchReport> {
        self.run_algo(self.spec.algo)
    }

    /// Run with `algo` overriding the spec (same objective, metric, and
    /// caches) — lets one session compare algorithms without rebuilding
    /// the pipeline.
    pub fn run_algo(&mut self, algo: SearchAlgo) -> Result<SearchReport> {
        self.ctx.ensure_calibrated()?;
        let spec = self.spec.clone();
        let sens = self.ctx.sensitivity_for(&spec)?;
        let floor = spec.target * self.ctx.pipeline.float_val_acc();
        let objective = spec.objective.build(floor, self.ctx.cost.clone());

        let mut checkpoint = match &spec.checkpoint {
            Some(path) => {
                let fp = checkpoint_fingerprint(
                    algo,
                    &QUANT_BITS,
                    &objective.describe(),
                    &sens.order,
                    &self.ctx.pipeline.eval_context(),
                );
                Some(Checkpoint::attach(path, &fp, spec.resume)?)
            }
            None => None,
        };
        let replayable = checkpoint.as_ref().map_or(0, Checkpoint::loaded);

        // Build the worker pool up front — every fallible step stays
        // before the observer list is taken, so an error here cannot lose
        // registered observers. The pool owns the cache file for the
        // duration of the run: the context pipeline's copy is detached
        // first so its stale state can never overwrite the pool's results,
        // and re-attached (reloading the pool's writes) after teardown.
        let mut pool = None;
        if spec.workers > 1 {
            let dir = self.ctx.pipeline.artifacts.dir.clone();
            let model = spec.model.clone();
            let scales_path = dir.join(format!("{model}_scales.json"));
            let p = PipelinePool::new(&dir, &model, spec.workers, move |p| {
                p.scales = Scales::load(&scales_path)?;
                p.sync_scales()
            })?;
            if self.ctx.eval_cache_enabled() {
                self.ctx.pipeline.detach_eval_cache()?;
                p.attach_eval_cache(
                    &self.ctx.eval_cache_path(),
                    &self.ctx.pipeline.eval_context(),
                    self.ctx.eval_cache_capacity(),
                );
            }
            pool = Some(p);
        }

        let mut observers = std::mem::take(&mut self.observers);
        let mut fan = |ev: &SearchEvent| {
            for obs in observers.iter_mut() {
                obs(ev);
            }
        };
        let t0 = Instant::now();
        let outcome = match pool.as_mut() {
            None => run_search(
                algo,
                &mut self.ctx.pipeline,
                &sens.order,
                &QUANT_BITS,
                objective.as_ref(),
                Some(&mut fan),
                checkpoint.as_mut(),
            ),
            Some(pool) => run_search(
                algo,
                pool,
                &sens.order,
                &QUANT_BITS,
                objective.as_ref(),
                Some(&mut fan),
                checkpoint.as_mut(),
            ),
        };
        let search_seconds = t0.elapsed().as_secs_f64();
        if outcome.is_ok() {
            let (memo_hits, persistent_hits) = match pool.as_ref() {
                Some(pool) => pool.cache_hits(),
                None => {
                    let stats = self.ctx.pipeline.stats;
                    (stats.cache_hits, stats.persistent_hits)
                }
            };
            fan(&SearchEvent::CacheReport { memo_hits, persistent_hits });
        }
        drop(fan);
        self.observers = observers;
        // Pool teardown (fallible, but observers are already restored):
        // persist its shared cache, then re-attach the pipeline's copy.
        let teardown = match pool {
            Some(pool) => {
                let flushed = pool.flush_eval_cache();
                drop(pool);
                if self.ctx.eval_cache_enabled() {
                    let cache_path = self.ctx.eval_cache_path();
                    let capacity = self.ctx.eval_cache_capacity();
                    self.ctx.pipeline.attach_eval_cache_bounded(&cache_path, capacity);
                }
                flushed
            }
            None => Ok(()),
        };
        let outcome = outcome?;
        teardown?;
        self.ctx.pipeline.flush_eval_cache()?;
        Ok(SearchReport {
            rel_size: self.ctx.cost.rel_size(&outcome.config),
            rel_latency: self.ctx.cost.rel_latency(&outcome.config),
            cost_provenance: self.ctx.cost.provenance().to_string(),
            algo,
            metric: spec.metric,
            search_seconds,
            workers: spec.workers,
            replayed_decisions: checkpoint.as_ref().map_or(replayable, Checkpoint::replayed),
            checkpointed_decisions: checkpoint.as_ref().map_or(0, Checkpoint::len),
            outcome,
        })
    }

    /// Consume the session into a running inference server over `cfg`:
    /// calibration is ensured (and persisted) first, the session's search
    /// pipeline is dropped to free its device state, then a
    /// [`PipelinePool`]-backed server is spawned with `spec.workers`
    /// workers loading the persisted scales.
    pub fn into_server(
        mut self,
        cfg: QuantConfig,
        mut opts: ServeOptions,
    ) -> Result<(ServerHandle, std::thread::JoinHandle<()>)> {
        self.ctx.ensure_calibrated()?;
        let dir = self.ctx.pipeline.artifacts.dir.clone();
        let model = self.spec.model.clone();
        opts.workers = self.spec.workers.max(1);
        drop(self);
        let scales_path = dir.join(format!("{model}_scales.json"));
        crate::server::spawn(dir, model, cfg, opts, move |p| {
            p.scales = Scales::load(&scales_path)?;
            p.sync_scales()
        })
    }
}
