//! [`SearchSession`] — a [`SearchSpec`] opened against real artifacts.
//!
//! The session owns the [`ModelContext`], multiplexes [`SearchEvent`]
//! observers (calibration progress included), builds the objective over
//! the context's cost model, and drives either algorithm through the
//! context's [`crate::coordinator::SearchEnv`] impl — the context's
//! shared [`crate::coordinator::PipelinePool`] when `workers > 1`, its
//! single pipeline otherwise. Sharded calibration, sensitivity, and the
//! search itself all run on that one pool (built once), and atomic
//! decision checkpoints + the persistent eval cache make interrupted runs
//! resume bit-identically.

use std::time::Instant;

use crate::coordinator::{SearchAlgo, SearchOutcome};
use crate::quant::{QuantConfig, Scales, QUANT_BITS};
use crate::sensitivity::MetricKind;
use crate::server::{ServeOptions, ServerHandle};
use crate::Result;

use super::{
    checkpoint_fingerprint, run_search, Checkpoint, FrontierReport, ModelContext, ParetoFront,
    Partition, PartitionedDriver, SearchEvent, SearchSpec,
};

/// Everything a finished search run reports.
#[derive(Debug)]
pub struct SearchReport {
    pub outcome: SearchOutcome,
    pub algo: SearchAlgo,
    pub metric: MetricKind,
    /// Final size relative to fp16 (fraction).
    pub rel_size: f64,
    /// Final modeled latency relative to fp16 (fraction).
    pub rel_latency: f64,
    /// Cost-model provenance recorded for the tables.
    pub cost_provenance: String,
    pub search_seconds: f64,
    pub workers: usize,
    /// Decisions replayed from a checkpoint (0 for fresh runs).
    pub replayed_decisions: usize,
    /// Total decisions in the checkpoint after the run (0 if none).
    pub checkpointed_decisions: usize,
}

/// A live search session over one model's artifacts.
pub struct SearchSession {
    spec: SearchSpec,
    pub ctx: ModelContext,
    observers: Vec<Box<dyn FnMut(&SearchEvent)>>,
}

impl SearchSession {
    /// Open `spec` (validates, loads artifacts, builds the cost model).
    pub fn open(spec: SearchSpec) -> Result<Self> {
        let ctx = ModelContext::from_spec(&spec)?;
        Ok(Self { spec, ctx, observers: Vec::new() })
    }

    pub fn spec(&self) -> &SearchSpec {
        &self.spec
    }

    /// Attach a [`SearchEvent`] observer (multiple observers all fire).
    pub fn on_event(&mut self, observer: impl FnMut(&SearchEvent) + 'static) -> &mut Self {
        self.observers.push(Box::new(observer));
        self
    }

    /// Run the spec's algorithm.
    pub fn run(&mut self) -> Result<SearchReport> {
        self.run_algo(self.spec.algo)
    }

    /// Run with `algo` overriding the spec (same objective, metric,
    /// caches, and worker pool) — lets one session compare algorithms
    /// without rebuilding pipelines.
    pub fn run_algo(&mut self, algo: SearchAlgo) -> Result<SearchReport> {
        let spec = self.spec.clone();
        // Observers are taken for the whole run — calibration events
        // included — and restored before returning, error or not.
        let mut observers = std::mem::take(&mut self.observers);
        let result = run_session(&mut self.ctx, &spec, algo, &mut observers);
        self.observers = observers;
        result
    }

    /// Build the one-pass Pareto frontier over `floors` (fractions of
    /// the float baseline) with the spec's algorithm, metric, caches,
    /// and worker pool — one accuracy-exhaustion search per floor, then
    /// every (budget, floor) sweep cell is an O(1) artifact read. The
    /// artifact is persisted as `<model>_frontier.json` next to the
    /// other artifacts; the spec's `checkpoint` path doubles as the
    /// per-floor decision-log prefix so killed builds resume
    /// bit-identically.
    pub fn run_pareto(&mut self, floors: &[f64]) -> Result<FrontierReport> {
        let spec = self.spec.clone();
        let mut observers = std::mem::take(&mut self.observers);
        let result = run_pareto_session(&mut self.ctx, &spec, floors, &mut observers);
        self.observers = observers;
        result
    }

    /// Consume the session into a running inference server over `cfg`:
    /// calibration is ensured (and persisted) first — sharded across the
    /// context's pool when `workers > 1` — then the context's already-warm
    /// [`crate::coordinator::PipelinePool`] is handed to the serving
    /// engine ([`crate::server::serve_with_pool`]): the calibrated worker
    /// pipelines serve directly, with no second pool build and no
    /// duplicate weight upload. At `workers == 1` no pool exists yet, so
    /// the process's single pool is spawned fresh with the persisted
    /// scales — either way, `mpq serve` builds exactly one pool per
    /// process.
    pub fn into_server(
        self,
        cfg: QuantConfig,
        opts: ServeOptions,
    ) -> Result<(ServerHandle, std::thread::JoinHandle<()>)> {
        self.into_multi_server(vec![cfg], opts)
    }

    /// [`SearchSession::into_server`] with a multi-config serving table:
    /// all configs (e.g. one frontier pick per tenant) are served from
    /// the same warm pool, routed per request by
    /// [`crate::server::InferOptions::config`].
    pub fn into_multi_server(
        mut self,
        configs: Vec<QuantConfig>,
        mut opts: ServeOptions,
    ) -> Result<(ServerHandle, std::thread::JoinHandle<()>)> {
        anyhow::ensure!(!configs.is_empty(), "serving needs at least one config");
        self.ctx.ensure_calibrated()?;
        opts.workers = self.spec.workers.max(1);
        if let Some(pool) = self.ctx.take_pool() {
            // Write back any calibration-time eval-cache state before the
            // pool changes hands; serving never touches the eval cache.
            pool.flush_eval_cache()?;
            // Drop the context pipeline's device state before warmup: the
            // pool is this process's one remaining device owner.
            drop(self);
            return crate::server::serve_multi_with_pool(pool, configs, opts);
        }
        let dir = self.ctx.pipeline.artifacts.dir.clone();
        let model = self.spec.model.clone();
        drop(self);
        let scales_path = dir.join(format!("{model}_scales.json"));
        let mut configs = configs;
        let first = configs.remove(0);
        let (handle, join) = crate::server::spawn(dir, model, first, opts, move |p| {
            p.scales = Scales::load(&scales_path)?;
            p.sync_scales()
        })?;
        // Register the remaining configs; their bits buffers upload
        // lazily, once per worker, on first routed batch.
        for cfg in configs {
            handle.add_config(cfg)?;
        }
        Ok((handle, join))
    }
}

/// The body of [`SearchSession::run_pareto`], with observers already
/// taken so an error cannot lose registered observers.
fn run_pareto_session(
    ctx: &mut ModelContext,
    spec: &SearchSpec,
    floors: &[f64],
    observers: &mut Vec<Box<dyn FnMut(&SearchEvent)>>,
) -> Result<FrontierReport> {
    let mut fan = |ev: &SearchEvent| {
        for obs in observers.iter_mut() {
            obs(ev);
        }
    };
    ctx.ensure_calibrated_with(Some(&mut fan))?;
    let sens = ctx.sensitivity_for(spec)?;
    let float_accuracy = ctx.pipeline.float_val_acc();
    let mut report = if spec.partitions > 1 {
        // Partitioned build: per floor, one scoped exhaustion search per
        // segment (fanned across the pool when one exists, each worker
        // owning a segment), composed into one whole-model trail.
        let mut driver = PartitionedDriver::new(
            spec.algo,
            Partition::split(&sens.order, spec.partitions),
            float_accuracy,
            ctx.cost.clone(),
            ctx.pipeline.eval_context(),
        )
        .resume(spec.resume);
        if let Some(prefix) = &spec.checkpoint {
            driver = driver.checkpoint(prefix);
        }
        match ctx.pool() {
            Some(pool) => driver.build_frontier(pool, floors, Some(&mut fan))?,
            None => driver.build_frontier_serial(ctx, floors, Some(&mut fan))?,
        }
    } else {
        let mut front = ParetoFront::new(
            spec.algo,
            sens.order.clone(),
            floors.to_vec(),
            float_accuracy,
            ctx.cost.clone(),
            ctx.pipeline.eval_context(),
        )
        .resume(spec.resume);
        if let Some(prefix) = &spec.checkpoint {
            front = front.checkpoint(prefix);
        }
        front.build(ctx, Some(&mut fan))?
    };
    let (memo_hits, persistent_hits) = ctx.cache_hits();
    fan(&SearchEvent::CacheReport { memo_hits, persistent_hits });
    ctx.flush_eval_cache()?;
    let path = ctx.pipeline.artifacts.dir.join(format!("{}_frontier.json", ctx.model()));
    report.artifact.save(&path)?;
    fan(&SearchEvent::FrontierWritten {
        points: report.artifact.num_points(),
        pareto: report.artifact.pareto().len(),
        path: path.display().to_string(),
    });
    report.path = Some(path);
    Ok(report)
}

/// The body of [`SearchSession::run_algo`], with observers already taken
/// so an error cannot lose registered observers.
fn run_session(
    ctx: &mut ModelContext,
    spec: &SearchSpec,
    algo: SearchAlgo,
    observers: &mut Vec<Box<dyn FnMut(&SearchEvent)>>,
) -> Result<SearchReport> {
    let mut fan = |ev: &SearchEvent| {
        for obs in observers.iter_mut() {
            obs(ev);
        }
    };
    // Calibration (sharded across the context pool at workers > 1),
    // sensitivity, and eval-cache attachment all report through the same
    // observer stream the search uses.
    ctx.ensure_calibrated_with(Some(&mut fan))?;
    let sens = ctx.sensitivity_for(spec)?;
    let floor = spec.target * ctx.pipeline.float_val_acc();
    if spec.partitions > 1 {
        return run_partitioned_session(ctx, spec, algo, floor, &sens.order, &mut fan);
    }
    let objective = spec.objective.build(floor, ctx.cost.clone());

    let mut checkpoint = match &spec.checkpoint {
        Some(path) => {
            let fp = checkpoint_fingerprint(
                algo,
                &QUANT_BITS,
                &objective.describe(),
                &sens.order,
                &ctx.pipeline.eval_context(),
            );
            Some(Checkpoint::attach(path, &fp, spec.resume)?)
        }
        None => None,
    };
    let replayable = checkpoint.as_ref().map_or(0, Checkpoint::loaded);

    let t0 = Instant::now();
    let outcome = run_search(
        algo,
        ctx,
        &sens.order,
        &QUANT_BITS,
        objective.as_ref(),
        Some(&mut fan),
        checkpoint.as_mut(),
    )?;
    let search_seconds = t0.elapsed().as_secs_f64();
    let (memo_hits, persistent_hits) = ctx.cache_hits();
    fan(&SearchEvent::CacheReport { memo_hits, persistent_hits });
    ctx.flush_eval_cache()?;
    Ok(SearchReport {
        rel_size: ctx.cost.rel_size(&outcome.config),
        rel_latency: ctx.cost.rel_latency(&outcome.config),
        cost_provenance: ctx.cost.provenance().to_string(),
        algo,
        metric: spec.metric,
        search_seconds,
        workers: spec.workers,
        replayed_decisions: checkpoint.as_ref().map_or(replayable, Checkpoint::replayed),
        checkpointed_decisions: checkpoint.as_ref().map_or(0, Checkpoint::len),
        outcome,
    })
}

/// The `--partitions K > 1` body of [`SearchSession::run_algo`]: the
/// sensitivity order is split into `K` contiguous segments searched under
/// pro-rated budgets — fanned across the context's worker pool when one
/// exists (each worker owns a segment), sequentially on the context
/// otherwise (identical decisions either way) — then reconciled into one
/// whole-model configuration.
fn run_partitioned_session(
    ctx: &mut ModelContext,
    spec: &SearchSpec,
    algo: SearchAlgo,
    floor: f64,
    order: &[usize],
    fan: &mut dyn FnMut(&SearchEvent),
) -> Result<SearchReport> {
    let mut driver = PartitionedDriver::new(
        algo,
        Partition::split(order, spec.partitions),
        ctx.pipeline.float_val_acc(),
        ctx.cost.clone(),
        ctx.pipeline.eval_context(),
    )
    .resume(spec.resume);
    if let Some(prefix) = &spec.checkpoint {
        driver = driver.checkpoint(prefix);
    }
    let t0 = Instant::now();
    let out = match ctx.pool() {
        Some(pool) => driver.run(pool, &spec.objective, floor, Some(&mut *fan))?,
        None => driver.run_serial(ctx, &spec.objective, floor, Some(&mut *fan))?,
    };
    let search_seconds = t0.elapsed().as_secs_f64();
    let (memo_hits, persistent_hits) = ctx.cache_hits();
    fan(&SearchEvent::CacheReport { memo_hits, persistent_hits });
    ctx.flush_eval_cache()?;
    Ok(SearchReport {
        rel_size: ctx.cost.rel_size(&out.outcome.config),
        rel_latency: ctx.cost.rel_latency(&out.outcome.config),
        cost_provenance: ctx.cost.provenance().to_string(),
        algo,
        metric: spec.metric,
        search_seconds,
        workers: spec.workers,
        replayed_decisions: out.replayed_decisions,
        checkpointed_decisions: out.checkpointed_decisions,
        outcome: out.outcome,
    })
}
