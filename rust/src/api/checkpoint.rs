//! Atomic decision-log checkpoints for resumable searches.
//!
//! Both search algorithms are deterministic functions of their decision
//! sequence: given (algorithm, ordering, bit widths, objective), the
//! accept/reject outcomes alone reproduce the exact trajectory. A
//! [`Checkpoint`] therefore persists just that boolean sequence (plus a
//! fingerprint binding it to the search that wrote it). On resume, the
//! search replays the recorded decisions without touching the environment
//! — bit-identical, and counted as decision evaluations so a resumed run
//! reports the same totals as an uninterrupted one — then continues live
//! from the first unrecorded decision. Any configuration the interrupted
//! run fully evaluated is answered by the persistent
//! [`crate::coordinator::EvalCache`], so resumption also wastes no device
//! work.
//!
//! Writes go to a temp file followed by an atomic rename (same discipline
//! as the eval cache): a crash leaves either the old checkpoint or the new
//! one, never a truncated log.

use std::path::{Path, PathBuf};

use anyhow::{ensure, Context as _};

use crate::coordinator::SearchAlgo;
use crate::util::json::{self, Value};
use crate::Result;

/// Schema version of the on-disk checkpoint format.
pub const CHECKPOINT_VERSION: u64 = 1;

/// Fingerprint binding a checkpoint to one exact search: algorithm, bit
/// widths, objective description, layer ordering, and the environment
/// context (e.g. [`crate::coordinator::Pipeline::eval_context`]). Resuming
/// with a different fingerprint is rejected instead of silently replaying
/// foreign decisions.
pub fn checkpoint_fingerprint(
    algo: SearchAlgo,
    quant_bits: &[f32],
    objective: &str,
    order: &[usize],
    env_context: &str,
) -> String {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    for &b in quant_bits {
        b.to_bits().hash(&mut h);
    }
    order.hash(&mut h);
    format!("{}/bits+order-{:016x}/{objective}/{env_context}", algo.label(), h.finish())
}

/// A persistent, atomically written accept/reject decision log.
#[derive(Debug)]
pub struct Checkpoint {
    path: PathBuf,
    fingerprint: String,
    decisions: Vec<bool>,
    /// Next decision to replay; equals `decisions.len()` once live.
    cursor: usize,
    /// Decisions loaded from disk at attach time (for reporting).
    loaded: usize,
}

impl Checkpoint {
    /// Attach a checkpoint at `path`. With `resume == false` a fresh empty
    /// log is written immediately (truncating any stale file). With
    /// `resume == true` the existing file is loaded and its decisions are
    /// replayed by the next search; a missing, corrupt, or
    /// fingerprint-mismatched file is an error — resuming the wrong search
    /// must fail loudly, not diverge quietly.
    pub fn attach(path: &Path, fingerprint: &str, resume: bool) -> Result<Self> {
        if !resume {
            let ck = Self {
                path: path.to_path_buf(),
                fingerprint: fingerprint.to_string(),
                decisions: Vec::new(),
                cursor: 0,
                loaded: 0,
            };
            ck.save()?;
            return Ok(ck);
        }
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading checkpoint {} for resume", path.display()))?;
        let v = json::parse(&text)
            .with_context(|| format!("parsing checkpoint {}", path.display()))?;
        ensure!(
            v.req("version")?.as_u64()? == CHECKPOINT_VERSION,
            "unsupported checkpoint version in {}",
            path.display()
        );
        let fp = v.req("fingerprint")?.as_str()?;
        ensure!(
            fp == fingerprint,
            "checkpoint {} was written by a different search:\n  recorded: {fp}\n  \
             expected: {fingerprint}",
            path.display()
        );
        let decisions: Vec<bool> =
            v.req("decisions")?.as_arr()?.iter().map(|d| d.as_bool()).collect::<Result<_>>()?;
        let loaded = decisions.len();
        Ok(Self {
            path: path.to_path_buf(),
            fingerprint: fingerprint.to_string(),
            decisions,
            cursor: 0,
            loaded,
        })
    }

    /// Total decisions in the log.
    pub fn len(&self) -> usize {
        self.decisions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.decisions.is_empty()
    }

    /// Decisions loaded from disk at attach time (the replayable prefix).
    pub fn loaded(&self) -> usize {
        self.loaded
    }

    /// Decisions actually replayed so far.
    pub fn replayed(&self) -> usize {
        self.cursor.min(self.loaded)
    }

    /// Next recorded decision to replay, if any.
    pub(crate) fn take_replay(&mut self) -> Option<bool> {
        if self.cursor < self.decisions.len() {
            let pass = self.decisions[self.cursor];
            self.cursor += 1;
            Some(pass)
        } else {
            None
        }
    }

    /// Append a live decision and persist the log atomically.
    pub(crate) fn record(&mut self, pass: bool) -> Result<()> {
        self.decisions.push(pass);
        self.cursor = self.decisions.len();
        self.save()
    }

    fn save(&self) -> Result<()> {
        let v = Value::obj(vec![
            ("version", Value::Num(CHECKPOINT_VERSION as f64)),
            ("fingerprint", Value::Str(self.fingerprint.clone())),
            ("decisions", Value::Arr(self.decisions.iter().map(|&d| Value::Bool(d)).collect())),
        ]);
        crate::util::fs::atomic_write_text(&self.path, &v.to_string())
            .with_context(|| format!("saving checkpoint {}", self.path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("mpq_checkpoint_{name}.json"))
    }

    #[test]
    fn fresh_record_resume_roundtrip() {
        let path = tmp("roundtrip");
        let _ = std::fs::remove_file(&path);
        let mut ck = Checkpoint::attach(&path, "fp-a", false).unwrap();
        assert!(ck.is_empty());
        assert_eq!(ck.take_replay(), None);
        ck.record(true).unwrap();
        ck.record(false).unwrap();
        ck.record(true).unwrap();

        let mut re = Checkpoint::attach(&path, "fp-a", true).unwrap();
        assert_eq!(re.len(), 3);
        assert_eq!(re.loaded(), 3);
        assert_eq!(re.take_replay(), Some(true));
        assert_eq!(re.take_replay(), Some(false));
        // Live decisions append after the replayed prefix.
        re.record(false).unwrap();
        assert_eq!(re.take_replay(), Some(true));
        assert_eq!(re.take_replay(), None);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn resume_rejects_fingerprint_mismatch_and_missing_file() {
        let path = tmp("mismatch");
        let _ = std::fs::remove_file(&path);
        assert!(Checkpoint::attach(&path, "fp-a", true).is_err());
        let mut ck = Checkpoint::attach(&path, "fp-a", false).unwrap();
        ck.record(true).unwrap();
        let err = Checkpoint::attach(&path, "fp-b", true).unwrap_err();
        assert!(err.to_string().contains("different search"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fresh_attach_truncates_stale_log() {
        let path = tmp("truncate");
        let _ = std::fs::remove_file(&path);
        let mut ck = Checkpoint::attach(&path, "fp-a", false).unwrap();
        ck.record(true).unwrap();
        let fresh = Checkpoint::attach(&path, "fp-a", false).unwrap();
        assert!(fresh.is_empty());
        let re = Checkpoint::attach(&path, "fp-a", true).unwrap();
        assert_eq!(re.len(), 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fingerprint_changes_with_inputs() {
        let a = checkpoint_fingerprint(SearchAlgo::Greedy, &[8.0, 4.0], "obj", &[0, 1], "ctx");
        let b = checkpoint_fingerprint(SearchAlgo::Bisection, &[8.0, 4.0], "obj", &[0, 1], "ctx");
        let c = checkpoint_fingerprint(SearchAlgo::Greedy, &[8.0], "obj", &[0, 1], "ctx");
        let d = checkpoint_fingerprint(SearchAlgo::Greedy, &[8.0, 4.0], "obj", &[1, 0], "ctx");
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, d);
    }
}
