//! Typed observer stream for search progress.
//!
//! A [`SearchEvent`] is emitted at every externally meaningful step of a
//! search — frontier submissions, accept/reject decisions with their
//! objective scores, budget satisfaction, checkpoint writes — replacing
//! ad-hoc stderr prints. Observers are plain `FnMut(&SearchEvent)`
//! callbacks attached through [`super::SearchCtl`] or
//! [`super::SearchSession::on_event`]; the default CLI observer renders
//! them as progress lines, tests use them to assert trajectories.

/// One step of a running search.
#[derive(Debug, Clone)]
pub enum SearchEvent {
    /// A search started: algorithm, layer count, objective description.
    Started { algo: &'static str, layers: usize, objective: String },
    /// A speculative candidate frontier was submitted for evaluation.
    FrontierSubmitted { bits: f32, size: usize },
    /// One sequential decision was made. `index` is the layer id (greedy)
    /// or the probed prefix length (bisection). `accuracy` is `NaN` for
    /// decisions replayed from a checkpoint (nothing was evaluated);
    /// `cost` is the objective's tracked relative cost, when it has one.
    Decision {
        bits: f32,
        index: usize,
        accepted: bool,
        accuracy: f64,
        cost: Option<f64>,
        replayed: bool,
    },
    /// The objective's budgets are met; the search stops quantizing.
    BudgetSatisfied { cost: f64 },
    /// The decision log was checkpointed (`decisions` entries on disk).
    CheckpointWritten { decisions: usize },
    /// The search finished with its final exact evaluation.
    Finished { accuracy: f64, evals: usize },
    /// Cache effectiveness for the finished run (emitted after
    /// [`SearchEvent::Finished`] by [`super::SearchSession`]): evaluations
    /// answered by the in-memory memo and by the persistent cross-run
    /// [`crate::coordinator::EvalCache`].
    CacheReport { memo_hits: usize, persistent_hits: usize },
}
