//! Typed observer stream for search + calibration progress.
//!
//! A [`SearchEvent`] is emitted at every externally meaningful step of a
//! search — frontier submissions, accept/reject decisions with their
//! objective scores, budget satisfaction, checkpoint writes — and of the
//! sharded calibration driver (stage start, per-epoch adjustment loss,
//! final report), replacing ad-hoc stderr prints. Observers are plain
//! `FnMut(&SearchEvent)` callbacks attached through [`super::SearchCtl`]
//! or [`super::SearchSession::on_event`]; the default CLI observer is
//! [`log_event`], tests use observers to assert trajectories.
//!
//! Two renderers share the stream: [`log_event`] writes the human stderr
//! line, and [`event_json`] is the one machine serializer — the
//! `--events-out events.jsonl` sink ([`EventSink`]) and the experiment
//! harness's metric extractor both consume it, so structured tools never
//! scrape stderr text.

use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::util::json::Value;
use crate::Result;

/// One step of a running search or calibration.
#[derive(Debug, Clone)]
pub enum SearchEvent {
    /// A search started: algorithm, layer count, objective description.
    Started { algo: &'static str, layers: usize, objective: String },
    /// A speculative candidate frontier was submitted for evaluation.
    FrontierSubmitted { bits: f32, size: usize },
    /// One sequential decision was made. `index` is the layer id (greedy)
    /// or the probed prefix length (bisection). `accuracy` is `NaN` for
    /// decisions replayed from a checkpoint (nothing was evaluated);
    /// `cost` is the objective's tracked relative cost, when it has one.
    Decision {
        bits: f32,
        index: usize,
        accepted: bool,
        accuracy: f64,
        cost: Option<f64>,
        replayed: bool,
    },
    /// The objective's budgets are met; the search stops quantizing.
    BudgetSatisfied { cost: f64 },
    /// The decision log was checkpointed (`decisions` entries on disk).
    CheckpointWritten { decisions: usize },
    /// The search finished with its final exact evaluation.
    Finished { accuracy: f64, evals: usize },
    /// Cache effectiveness for the finished run (emitted after
    /// [`SearchEvent::Finished`] by [`super::SearchSession`]): evaluations
    /// answered by the in-memory memo and by the persistent cross-run
    /// [`crate::coordinator::EvalCache`].
    CacheReport { memo_hits: usize, persistent_hits: usize },
    /// Sharded two-step calibration started: adjustment-split batch count,
    /// sync-group size (batches averaged per Adam step), and the worker
    /// count the batches are fanned across.
    CalibrationStarted { workers: usize, batches: usize, grad_batches: usize, epochs: usize },
    /// One adjustment epoch finished: mean sync-group loss over the epoch
    /// and total Adam steps taken so far.
    AdjustEpoch { epoch: usize, loss: f64, steps: usize },
    /// Calibration finished; fields mirror [`crate::quant::AdjustReport`].
    CalibrationFinished { loss_before: f64, loss_after: f64, steps: usize },
    /// Cached scales were loaded from disk instead of calibrating.
    ScalesLoaded { path: String },
    /// The persistent eval cache was attached with `entries` prior results.
    EvalCacheAttached { entries: usize, path: String },
    /// A frontier build started the exhaustion search for one accuracy
    /// floor (`index` of `total`). Unrelated to
    /// [`SearchEvent::FrontierSubmitted`], which reports a speculative
    /// *evaluation* frontier inside a single search.
    FrontierFloor { floor: f64, index: usize, total: usize },
    /// A Pareto-frontier artifact was persisted: `points` trail points,
    /// of which `pareto` survive dominated-filtering.
    FrontierWritten { points: usize, pareto: usize, path: String },
    /// A partitioned run started searching one segment of the layer order
    /// (`segment` of `segments`, owning `layers` layers). Segment events
    /// are replayed in fixed segment order after the concurrent searches
    /// finish, so the stream is deterministic at every worker count.
    SegmentStarted { segment: usize, segments: usize, layers: usize },
    /// One segment's scoped search finished.
    SegmentFinished { segment: usize, accuracy: f64, evals: usize },
    /// The global budget reconciliation pass composed the per-segment
    /// results into one whole-model configuration and evaluated it
    /// exactly; `cost` is the composed relative cost under a budgeted
    /// objective.
    Reconciled { segments: usize, accuracy: f64, cost: Option<f64>, evals: usize },
}

/// Render one [`SearchEvent`] as a stderr progress line — the default
/// observer used by the CLI and by
/// [`super::ModelContext::ensure_calibrated`] when no observer is given.
pub fn log_event(ev: &SearchEvent) {
    match ev {
        SearchEvent::Started { algo, layers, objective } => {
            eprintln!("[search] {algo} over {layers} layers: {objective}");
        }
        SearchEvent::Decision { bits, index, accepted, accuracy, cost, replayed } => {
            let verdict = if *accepted { "accept" } else { "reject" };
            let mut line = format!("[search] {bits}b #{index}: {verdict}");
            if *replayed {
                line.push_str(" (replayed)");
            } else {
                line.push_str(&format!(" acc={:.2}%", accuracy * 100.0));
            }
            if let Some(c) = cost {
                line.push_str(&format!(" cost={:.1}%", c * 100.0));
            }
            eprintln!("{line}");
        }
        SearchEvent::BudgetSatisfied { cost } => {
            eprintln!("[search] budget satisfied at rel cost {:.1}% — stopping", cost * 100.0);
        }
        SearchEvent::Finished { accuracy, evals } => {
            eprintln!(
                "[search] finished: accuracy {:.2}% after {evals} decision evals",
                accuracy * 100.0
            );
        }
        SearchEvent::CacheReport { memo_hits, persistent_hits } => {
            eprintln!("[search] cache: {memo_hits} memo hits, {persistent_hits} persistent hits");
        }
        SearchEvent::CalibrationStarted { workers, batches, grad_batches, epochs } => {
            eprintln!(
                "[calibration] adjusting scales: {batches} batches x {epochs} epochs in \
                 {grad_batches}-batch sync groups across {workers} worker(s)"
            );
        }
        SearchEvent::AdjustEpoch { epoch, loss, steps } => {
            eprintln!("[calibration] epoch {epoch}: mean loss {loss:.4} ({steps} steps so far)");
        }
        SearchEvent::CalibrationFinished { loss_before, loss_after, steps } => {
            eprintln!(
                "[calibration] adjusted scales over {steps} steps: loss \
                 {loss_before:.4} -> {loss_after:.4}"
            );
        }
        SearchEvent::ScalesLoaded { path } => {
            eprintln!("[calibration] loaded cached scales from {path}");
        }
        SearchEvent::EvalCacheAttached { entries, path } => {
            eprintln!("[eval-cache] loaded {entries} exact results from {path}");
        }
        SearchEvent::FrontierFloor { floor, index, total } => {
            eprintln!(
                "[frontier] floor {}/{total}: accuracy >= {:.2}% of baseline",
                index + 1,
                floor * 100.0
            );
        }
        SearchEvent::FrontierWritten { points, pareto, path } => {
            eprintln!("[frontier] {points} points ({pareto} Pareto-optimal) -> {path}");
        }
        SearchEvent::SegmentStarted { segment, segments, layers } => {
            eprintln!("[partition] segment {}/{segments}: {layers} layers", segment + 1);
        }
        SearchEvent::SegmentFinished { segment, accuracy, evals } => {
            eprintln!(
                "[partition] segment {} done: accuracy {:.2}% after {evals} decision evals",
                segment + 1,
                accuracy * 100.0
            );
        }
        SearchEvent::Reconciled { segments, accuracy, cost, evals } => {
            let mut line = format!(
                "[partition] reconciled {segments} segments: accuracy {:.2}%",
                accuracy * 100.0
            );
            if let Some(c) = cost {
                line.push_str(&format!(" cost={:.1}%", c * 100.0));
            }
            line.push_str(&format!(" ({evals} decision evals)"));
            eprintln!("{line}");
        }
        SearchEvent::FrontierSubmitted { .. } | SearchEvent::CheckpointWritten { .. } => {}
    }
}

/// `NaN`/infinite floats have no JSON representation; they only occur on
/// replayed decisions (nothing was evaluated), so serialize them as null.
fn finite(x: f64) -> Value {
    if x.is_finite() {
        Value::Num(x)
    } else {
        Value::Null
    }
}

fn opt(x: Option<f64>) -> Value {
    x.map_or(Value::Null, finite)
}

/// Serialize one [`SearchEvent`] as a JSON object — the machine twin of
/// [`log_event`]. Every variant carries an `event` tag (snake_case) plus
/// its fields under their Rust names; keys come out sorted (see
/// [`Value`]), so a given event always serializes to the same bytes.
pub fn event_json(ev: &SearchEvent) -> Value {
    match ev {
        SearchEvent::Started { algo, layers, objective } => Value::obj(vec![
            ("event", Value::Str("started".into())),
            ("algo", Value::Str((*algo).to_string())),
            ("layers", Value::Num(*layers as f64)),
            ("objective", Value::Str(objective.clone())),
        ]),
        SearchEvent::FrontierSubmitted { bits, size } => Value::obj(vec![
            ("event", Value::Str("frontier_submitted".into())),
            ("bits", Value::Num(f64::from(*bits))),
            ("size", Value::Num(*size as f64)),
        ]),
        SearchEvent::Decision { bits, index, accepted, accuracy, cost, replayed } => {
            Value::obj(vec![
                ("event", Value::Str("decision".into())),
                ("bits", Value::Num(f64::from(*bits))),
                ("index", Value::Num(*index as f64)),
                ("accepted", Value::Bool(*accepted)),
                ("accuracy", finite(*accuracy)),
                ("cost", opt(*cost)),
                ("replayed", Value::Bool(*replayed)),
            ])
        }
        SearchEvent::BudgetSatisfied { cost } => Value::obj(vec![
            ("event", Value::Str("budget_satisfied".into())),
            ("cost", finite(*cost)),
        ]),
        SearchEvent::CheckpointWritten { decisions } => Value::obj(vec![
            ("event", Value::Str("checkpoint_written".into())),
            ("decisions", Value::Num(*decisions as f64)),
        ]),
        SearchEvent::Finished { accuracy, evals } => Value::obj(vec![
            ("event", Value::Str("finished".into())),
            ("accuracy", finite(*accuracy)),
            ("evals", Value::Num(*evals as f64)),
        ]),
        SearchEvent::CacheReport { memo_hits, persistent_hits } => Value::obj(vec![
            ("event", Value::Str("cache_report".into())),
            ("memo_hits", Value::Num(*memo_hits as f64)),
            ("persistent_hits", Value::Num(*persistent_hits as f64)),
        ]),
        SearchEvent::CalibrationStarted { workers, batches, grad_batches, epochs } => {
            Value::obj(vec![
                ("event", Value::Str("calibration_started".into())),
                ("workers", Value::Num(*workers as f64)),
                ("batches", Value::Num(*batches as f64)),
                ("grad_batches", Value::Num(*grad_batches as f64)),
                ("epochs", Value::Num(*epochs as f64)),
            ])
        }
        SearchEvent::AdjustEpoch { epoch, loss, steps } => Value::obj(vec![
            ("event", Value::Str("adjust_epoch".into())),
            ("epoch", Value::Num(*epoch as f64)),
            ("loss", finite(*loss)),
            ("steps", Value::Num(*steps as f64)),
        ]),
        SearchEvent::CalibrationFinished { loss_before, loss_after, steps } => Value::obj(vec![
            ("event", Value::Str("calibration_finished".into())),
            ("loss_before", finite(*loss_before)),
            ("loss_after", finite(*loss_after)),
            ("steps", Value::Num(*steps as f64)),
        ]),
        SearchEvent::ScalesLoaded { path } => Value::obj(vec![
            ("event", Value::Str("scales_loaded".into())),
            ("path", Value::Str(path.clone())),
        ]),
        SearchEvent::EvalCacheAttached { entries, path } => Value::obj(vec![
            ("event", Value::Str("eval_cache_attached".into())),
            ("entries", Value::Num(*entries as f64)),
            ("path", Value::Str(path.clone())),
        ]),
        SearchEvent::FrontierFloor { floor, index, total } => Value::obj(vec![
            ("event", Value::Str("frontier_floor".into())),
            ("floor", finite(*floor)),
            ("index", Value::Num(*index as f64)),
            ("total", Value::Num(*total as f64)),
        ]),
        SearchEvent::FrontierWritten { points, pareto, path } => Value::obj(vec![
            ("event", Value::Str("frontier_written".into())),
            ("points", Value::Num(*points as f64)),
            ("pareto", Value::Num(*pareto as f64)),
            ("path", Value::Str(path.clone())),
        ]),
        SearchEvent::SegmentStarted { segment, segments, layers } => Value::obj(vec![
            ("event", Value::Str("segment_started".into())),
            ("segment", Value::Num(*segment as f64)),
            ("segments", Value::Num(*segments as f64)),
            ("layers", Value::Num(*layers as f64)),
        ]),
        SearchEvent::SegmentFinished { segment, accuracy, evals } => Value::obj(vec![
            ("event", Value::Str("segment_finished".into())),
            ("segment", Value::Num(*segment as f64)),
            ("accuracy", finite(*accuracy)),
            ("evals", Value::Num(*evals as f64)),
        ]),
        SearchEvent::Reconciled { segments, accuracy, cost, evals } => Value::obj(vec![
            ("event", Value::Str("reconciled".into())),
            ("segments", Value::Num(*segments as f64)),
            ("accuracy", finite(*accuracy)),
            ("cost", opt(*cost)),
            ("evals", Value::Num(*evals as f64)),
        ]),
    }
}

struct SinkInner {
    out: std::io::BufWriter<std::fs::File>,
    path: PathBuf,
    error: Option<String>,
    events: usize,
}

/// A JSONL file sink for the [`SearchEvent`] stream (`--events-out`):
/// one [`event_json`] object per line, in emission order.
///
/// Observers are `'static` closures on some paths
/// ([`super::SearchSession::on_event`]), so the sink is clonable and
/// internally locked; any clone can record. Write errors are deferred —
/// recording never panics mid-search — and surfaced by [`EventSink::finish`].
#[derive(Clone)]
pub struct EventSink {
    inner: Arc<Mutex<SinkInner>>,
}

impl EventSink {
    /// Create (truncate) the JSONL file at `path`.
    pub fn create(path: &Path) -> Result<Self> {
        if let Some(parent) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
            std::fs::create_dir_all(parent)?;
        }
        let file = std::fs::File::create(path)?;
        Ok(Self {
            inner: Arc::new(Mutex::new(SinkInner {
                out: std::io::BufWriter::new(file),
                path: path.to_path_buf(),
                error: None,
                events: 0,
            })),
        })
    }

    /// Append one event line. Errors are held until [`EventSink::finish`].
    pub fn record(&self, ev: &SearchEvent) {
        let mut inner = self.inner.lock().expect("event sink poisoned");
        if inner.error.is_some() {
            return;
        }
        let line = event_json(ev).to_string();
        if let Err(e) = writeln!(inner.out, "{line}") {
            inner.error = Some(e.to_string());
        } else {
            inner.events += 1;
        }
    }

    /// A `'static` observer closure writing into this sink — compose it
    /// with [`log_event`] or attach it directly.
    pub fn observer(&self) -> impl FnMut(&SearchEvent) + Send + 'static {
        let sink = self.clone();
        move |ev: &SearchEvent| sink.record(ev)
    }

    /// The JSONL file this sink writes to.
    pub fn path(&self) -> PathBuf {
        self.inner.lock().expect("event sink poisoned").path.clone()
    }

    /// Flush and surface any deferred write error, reporting how many
    /// events landed in the file.
    pub fn finish(&self) -> Result<usize> {
        let mut inner = self.inner.lock().expect("event sink poisoned");
        if let Some(e) = &inner.error {
            anyhow::bail!("event sink {}: {e}", inner.path.display());
        }
        inner.out.flush()?;
        Ok(inner.events)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replayed_decisions_serialize_nan_as_null() {
        let v = event_json(&SearchEvent::Decision {
            bits: 4.0,
            index: 3,
            accepted: true,
            accuracy: f64::NAN,
            cost: None,
            replayed: true,
        });
        assert_eq!(
            v.to_string(),
            "{\"accepted\":true,\"accuracy\":null,\"bits\":4,\"cost\":null,\
             \"event\":\"decision\",\"index\":3,\"replayed\":true}"
        );
    }

    #[test]
    fn every_variant_serializes_with_an_event_tag() {
        let events = vec![
            SearchEvent::Started { algo: "Greedy", layers: 4, objective: "acc".into() },
            SearchEvent::FrontierSubmitted { bits: 8.0, size: 2 },
            SearchEvent::Decision {
                bits: 8.0,
                index: 0,
                accepted: false,
                accuracy: 0.5,
                cost: Some(0.25),
                replayed: false,
            },
            SearchEvent::BudgetSatisfied { cost: 0.7 },
            SearchEvent::CheckpointWritten { decisions: 9 },
            SearchEvent::Finished { accuracy: 0.99, evals: 12 },
            SearchEvent::CacheReport { memo_hits: 1, persistent_hits: 2 },
            SearchEvent::CalibrationStarted { workers: 2, batches: 4, grad_batches: 2, epochs: 1 },
            SearchEvent::AdjustEpoch { epoch: 0, loss: 1.5, steps: 2 },
            SearchEvent::CalibrationFinished { loss_before: 2.0, loss_after: 1.0, steps: 4 },
            SearchEvent::ScalesLoaded { path: "p".into() },
            SearchEvent::EvalCacheAttached { entries: 3, path: "q".into() },
            SearchEvent::FrontierFloor { floor: 0.9, index: 0, total: 2 },
            SearchEvent::FrontierWritten { points: 5, pareto: 3, path: "f".into() },
            SearchEvent::SegmentStarted { segment: 0, segments: 2, layers: 12 },
            SearchEvent::SegmentFinished { segment: 0, accuracy: 0.95, evals: 7 },
            SearchEvent::Reconciled { segments: 2, accuracy: 0.94, cost: None, evals: 15 },
        ];
        let mut tags = std::collections::BTreeSet::new();
        for ev in &events {
            let v = event_json(ev);
            let tag = v.req("event").unwrap().as_str().unwrap().to_string();
            // Serialization is stable: same event -> same bytes.
            assert_eq!(v.to_string(), event_json(ev).to_string());
            tags.insert(tag);
        }
        assert_eq!(tags.len(), events.len(), "every variant has a distinct tag");
    }

    #[test]
    fn sink_writes_one_line_per_event() {
        let dir = std::env::temp_dir().join(format!("mpq_sink_{}", std::process::id()));
        let path = dir.join("events.jsonl");
        let sink = EventSink::create(&path).unwrap();
        let mut obs = sink.observer();
        obs(&SearchEvent::Finished { accuracy: 1.0, evals: 3 });
        sink.record(&SearchEvent::BudgetSatisfied { cost: 0.5 });
        assert_eq!(sink.finish().unwrap(), 2);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = crate::util::json::parse(lines[0]).unwrap();
        assert_eq!(first.req("event").unwrap().as_str().unwrap(), "finished");
        std::fs::remove_dir_all(&dir).ok();
    }
}

