//! Typed observer stream for search + calibration progress.
//!
//! A [`SearchEvent`] is emitted at every externally meaningful step of a
//! search — frontier submissions, accept/reject decisions with their
//! objective scores, budget satisfaction, checkpoint writes — and of the
//! sharded calibration driver (stage start, per-epoch adjustment loss,
//! final report), replacing ad-hoc stderr prints. Observers are plain
//! `FnMut(&SearchEvent)` callbacks attached through [`super::SearchCtl`]
//! or [`super::SearchSession::on_event`]; the default CLI observer is
//! [`log_event`], tests use observers to assert trajectories.

/// One step of a running search or calibration.
#[derive(Debug, Clone)]
pub enum SearchEvent {
    /// A search started: algorithm, layer count, objective description.
    Started { algo: &'static str, layers: usize, objective: String },
    /// A speculative candidate frontier was submitted for evaluation.
    FrontierSubmitted { bits: f32, size: usize },
    /// One sequential decision was made. `index` is the layer id (greedy)
    /// or the probed prefix length (bisection). `accuracy` is `NaN` for
    /// decisions replayed from a checkpoint (nothing was evaluated);
    /// `cost` is the objective's tracked relative cost, when it has one.
    Decision {
        bits: f32,
        index: usize,
        accepted: bool,
        accuracy: f64,
        cost: Option<f64>,
        replayed: bool,
    },
    /// The objective's budgets are met; the search stops quantizing.
    BudgetSatisfied { cost: f64 },
    /// The decision log was checkpointed (`decisions` entries on disk).
    CheckpointWritten { decisions: usize },
    /// The search finished with its final exact evaluation.
    Finished { accuracy: f64, evals: usize },
    /// Cache effectiveness for the finished run (emitted after
    /// [`SearchEvent::Finished`] by [`super::SearchSession`]): evaluations
    /// answered by the in-memory memo and by the persistent cross-run
    /// [`crate::coordinator::EvalCache`].
    CacheReport { memo_hits: usize, persistent_hits: usize },
    /// Sharded two-step calibration started: adjustment-split batch count,
    /// sync-group size (batches averaged per Adam step), and the worker
    /// count the batches are fanned across.
    CalibrationStarted { workers: usize, batches: usize, grad_batches: usize, epochs: usize },
    /// One adjustment epoch finished: mean sync-group loss over the epoch
    /// and total Adam steps taken so far.
    AdjustEpoch { epoch: usize, loss: f64, steps: usize },
    /// Calibration finished; fields mirror [`crate::quant::AdjustReport`].
    CalibrationFinished { loss_before: f64, loss_after: f64, steps: usize },
    /// Cached scales were loaded from disk instead of calibrating.
    ScalesLoaded { path: String },
    /// The persistent eval cache was attached with `entries` prior results.
    EvalCacheAttached { entries: usize, path: String },
    /// A frontier build started the exhaustion search for one accuracy
    /// floor (`index` of `total`). Unrelated to
    /// [`SearchEvent::FrontierSubmitted`], which reports a speculative
    /// *evaluation* frontier inside a single search.
    FrontierFloor { floor: f64, index: usize, total: usize },
    /// A Pareto-frontier artifact was persisted: `points` trail points,
    /// of which `pareto` survive dominated-filtering.
    FrontierWritten { points: usize, pareto: usize, path: String },
    /// A partitioned run started searching one segment of the layer order
    /// (`segment` of `segments`, owning `layers` layers). Segment events
    /// are replayed in fixed segment order after the concurrent searches
    /// finish, so the stream is deterministic at every worker count.
    SegmentStarted { segment: usize, segments: usize, layers: usize },
    /// One segment's scoped search finished.
    SegmentFinished { segment: usize, accuracy: f64, evals: usize },
    /// The global budget reconciliation pass composed the per-segment
    /// results into one whole-model configuration and evaluated it
    /// exactly; `cost` is the composed relative cost under a budgeted
    /// objective.
    Reconciled { segments: usize, accuracy: f64, cost: Option<f64>, evals: usize },
}

/// Render one [`SearchEvent`] as a stderr progress line — the default
/// observer used by the CLI and by
/// [`super::ModelContext::ensure_calibrated`] when no observer is given.
pub fn log_event(ev: &SearchEvent) {
    match ev {
        SearchEvent::Started { algo, layers, objective } => {
            eprintln!("[search] {algo} over {layers} layers: {objective}");
        }
        SearchEvent::Decision { bits, index, accepted, accuracy, cost, replayed } => {
            let verdict = if *accepted { "accept" } else { "reject" };
            let mut line = format!("[search] {bits}b #{index}: {verdict}");
            if *replayed {
                line.push_str(" (replayed)");
            } else {
                line.push_str(&format!(" acc={:.2}%", accuracy * 100.0));
            }
            if let Some(c) = cost {
                line.push_str(&format!(" cost={:.1}%", c * 100.0));
            }
            eprintln!("{line}");
        }
        SearchEvent::BudgetSatisfied { cost } => {
            eprintln!("[search] budget satisfied at rel cost {:.1}% — stopping", cost * 100.0);
        }
        SearchEvent::Finished { accuracy, evals } => {
            eprintln!(
                "[search] finished: accuracy {:.2}% after {evals} decision evals",
                accuracy * 100.0
            );
        }
        SearchEvent::CacheReport { memo_hits, persistent_hits } => {
            eprintln!("[search] cache: {memo_hits} memo hits, {persistent_hits} persistent hits");
        }
        SearchEvent::CalibrationStarted { workers, batches, grad_batches, epochs } => {
            eprintln!(
                "[calibration] adjusting scales: {batches} batches x {epochs} epochs in \
                 {grad_batches}-batch sync groups across {workers} worker(s)"
            );
        }
        SearchEvent::AdjustEpoch { epoch, loss, steps } => {
            eprintln!("[calibration] epoch {epoch}: mean loss {loss:.4} ({steps} steps so far)");
        }
        SearchEvent::CalibrationFinished { loss_before, loss_after, steps } => {
            eprintln!(
                "[calibration] adjusted scales over {steps} steps: loss \
                 {loss_before:.4} -> {loss_after:.4}"
            );
        }
        SearchEvent::ScalesLoaded { path } => {
            eprintln!("[calibration] loaded cached scales from {path}");
        }
        SearchEvent::EvalCacheAttached { entries, path } => {
            eprintln!("[eval-cache] loaded {entries} exact results from {path}");
        }
        SearchEvent::FrontierFloor { floor, index, total } => {
            eprintln!(
                "[frontier] floor {}/{total}: accuracy >= {:.2}% of baseline",
                index + 1,
                floor * 100.0
            );
        }
        SearchEvent::FrontierWritten { points, pareto, path } => {
            eprintln!("[frontier] {points} points ({pareto} Pareto-optimal) -> {path}");
        }
        SearchEvent::SegmentStarted { segment, segments, layers } => {
            eprintln!("[partition] segment {}/{segments}: {layers} layers", segment + 1);
        }
        SearchEvent::SegmentFinished { segment, accuracy, evals } => {
            eprintln!(
                "[partition] segment {} done: accuracy {:.2}% after {evals} decision evals",
                segment + 1,
                accuracy * 100.0
            );
        }
        SearchEvent::Reconciled { segments, accuracy, cost, evals } => {
            let mut line = format!(
                "[partition] reconciled {segments} segments: accuracy {:.2}%",
                accuracy * 100.0
            );
            if let Some(c) = cost {
                line.push_str(&format!(" cost={:.1}%", c * 100.0));
            }
            line.push_str(&format!(" ({evals} decision evals)"));
            eprintln!("{line}");
        }
        SearchEvent::FrontierSubmitted { .. } | SearchEvent::CheckpointWritten { .. } => {}
    }
}
