//! Seeded synthetic search environment + cost model.
//!
//! Lets the full search API — objectives, budgets, checkpoints, worker
//! fan-out — run with no artifacts and no device: `mpq search --synthetic
//! N` uses it for CI smoke runs (including the kill-then-resume step), and
//! the API tests use it for parity and monotonicity properties.
//!
//! The accuracy model is the separable monotone family from the engine's
//! property tests: quantizing layer `i` to width `b` costs
//! `penalty[i] * (16 - b) / 12`, accuracy is `1 - Σ cost`. A seeded mix of
//! mostly-cheap and a few expensive layers produces realistic
//! accept/reject patterns for both algorithms.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::coordinator::{EvalResult, SyncSearchEnv};
use crate::quant::QuantConfig;
use crate::util::rng::Rng;
use crate::Result;

use super::CostModel;

/// Thread-safe synthetic environment with a known accuracy model.
pub struct SyntheticEnv {
    penalty: Vec<f64>,
    evals: AtomicUsize,
    /// Error out after this many raw evaluations (simulated interruption).
    abort_after: Option<usize>,
}

impl SyntheticEnv {
    /// `layers` layers with seeded penalties: ~30% expensive (up to 0.2),
    /// the rest nearly free — the mix that exercises both accept and
    /// reject chains.
    pub fn new(layers: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from(seed ^ 0x5e17_ca5e);
        let penalty = (0..layers)
            .map(|_| if rng.uniform() < 0.3 { rng.uniform() * 0.2 } else { rng.uniform() * 1e-3 })
            .collect();
        Self { penalty, evals: AtomicUsize::new(0), abort_after: None }
    }

    /// Make every evaluation past the `n`-th fail — a deterministic stand-in
    /// for killing the process mid-search (checkpoint/resume testing).
    pub fn abort_after(mut self, n: usize) -> Self {
        self.abort_after = Some(n);
        self
    }

    /// Raw evaluations issued so far (speculation included).
    pub fn evals(&self) -> usize {
        self.evals.load(Ordering::Relaxed)
    }

    /// Identity ordering (the synthetic penalties are not sorted, so this
    /// behaves like a plausible — imperfect — sensitivity ranking).
    pub fn order(&self) -> Vec<usize> {
        (0..self.penalty.len()).collect()
    }

    fn accuracy(&self, cfg: &QuantConfig) -> f64 {
        let cost: f64 = cfg
            .bits_w
            .iter()
            .enumerate()
            .map(|(i, &b)| self.penalty[i] * f64::from(16.0 - b) / 12.0)
            .sum();
        1.0 - cost
    }
}

impl SyncSearchEnv for SyntheticEnv {
    fn num_layers(&self) -> usize {
        self.penalty.len()
    }

    fn eval(&self, cfg: &QuantConfig, _target: Option<f64>) -> Result<EvalResult> {
        let n = self.evals.fetch_add(1, Ordering::Relaxed);
        if let Some(limit) = self.abort_after {
            if n >= limit {
                anyhow::bail!("synthetic environment aborted after {limit} evaluations");
            }
        }
        let acc = self.accuracy(cfg);
        Ok(EvalResult { loss: 1.0 - acc, accuracy: acc, exact: true })
    }
}

/// Synthetic deployment cost: per-layer weighted mean of the configured
/// bit widths relative to fp16. Strictly monotone — lowering any layer's
/// precision lowers both costs — which is exactly the property budget
/// objectives rely on.
pub struct SyntheticCost {
    weights: Vec<f64>,
}

impl SyntheticCost {
    /// Seeded per-layer weights in `[0.5, 1.5)`.
    pub fn new(layers: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from(seed ^ 0xc0_57);
        Self { weights: (0..layers).map(|_| 0.5 + rng.uniform()).collect() }
    }

    fn weighted_rel(&self, bits: impl Iterator<Item = f64>) -> f64 {
        let total: f64 = self.weights.iter().sum();
        if total <= 0.0 {
            return 1.0;
        }
        let cost: f64 = bits.zip(&self.weights).map(|(b, &w)| w * b / 16.0).sum();
        cost / total
    }
}

impl CostModel for SyntheticCost {
    fn rel_latency(&self, cfg: &QuantConfig) -> f64 {
        // Latency sees both operand widths (weights stream + activations).
        self.weighted_rel(
            cfg.bits_w.iter().zip(&cfg.bits_a).map(|(&w, &a)| (f64::from(w) + f64::from(a)) / 2.0),
        )
    }

    fn rel_size(&self, cfg: &QuantConfig) -> f64 {
        // Size is weights only.
        self.weighted_rel(cfg.bits_w.iter().map(|&w| f64::from(w)))
    }

    fn latency_s(&self, cfg: &QuantConfig) -> f64 {
        self.rel_latency(cfg) * 1e-3
    }

    fn size_bytes(&self, cfg: &QuantConfig) -> f64 {
        self.rel_size(cfg) * 1e6
    }

    fn provenance(&self) -> &str {
        "synthetic"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_is_deterministic_and_monotone() {
        let a = SyntheticEnv::new(12, 7);
        let b = SyntheticEnv::new(12, 7);
        let float = QuantConfig::float(12);
        let q8 = QuantConfig::uniform(12, 8.0);
        assert_eq!(a.eval(&float, None).unwrap(), b.eval(&float, None).unwrap());
        assert!(a.eval(&q8, None).unwrap().accuracy <= a.eval(&float, None).unwrap().accuracy);
        assert_eq!(a.evals(), 3);
    }

    #[test]
    fn abort_after_fails_deterministically() {
        let env = SyntheticEnv::new(4, 0).abort_after(2);
        let cfg = QuantConfig::float(4);
        assert!(env.eval(&cfg, None).is_ok());
        assert!(env.eval(&cfg, None).is_ok());
        assert!(env.eval(&cfg, None).is_err());
    }

    #[test]
    fn cost_is_monotone_and_normalized() {
        let cost = SyntheticCost::new(8, 3);
        let float = QuantConfig::float(8);
        assert!((cost.rel_latency(&float) - 1.0).abs() < 1e-12);
        assert!((cost.rel_size(&float) - 1.0).abs() < 1e-12);
        let mut one = float.clone();
        one.set_layer(3, 4.0);
        assert!(cost.rel_latency(&one) < 1.0);
        assert!(cost.rel_size(&one) < 1.0);
        let q4 = QuantConfig::uniform(8, 4.0);
        assert!((cost.rel_size(&q4) - 0.25).abs() < 1e-12);
        assert!(cost.rel_latency(&q4) < cost.rel_latency(&one));
    }
}
