//! Seeded synthetic search environment, cost model, and calibration stage
//! runner.
//!
//! Lets the full search + calibration API — objectives, budgets,
//! checkpoints, worker fan-out, sharded calibration — run with no
//! artifacts and no device: `mpq search --synthetic N` uses it for CI
//! smoke runs (including the kill-then-resume step), `mpq calibrate
//! --synthetic N` for the 1- vs 2-worker scale-parity smoke, and the
//! API/parity tests for their properties.
//!
//! The accuracy model is the separable monotone family from the engine's
//! property tests: quantizing layer `i` to width `b` costs
//! `penalty[i] * (16 - b) / 12`, accuracy is `1 - Σ cost`. A seeded mix of
//! mostly-cheap and a few expensive layers produces realistic
//! accept/reject patterns for both algorithms.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::coordinator::{
    hessian_trace_sharded, interlayer_scores_sharded, noise_scores_sharded, EvalResult,
    StageRunner, SyncSearchEnv,
};
use crate::quant::calibrate::{
    merge_act_stats, pair_at, pair_count, BatchGrad, NoiseSample, PairSample, TraceSample,
};
use crate::quant::{eps_qe, QuantConfig, Scales, QUANT_BITS};
use crate::sensitivity::{InterLayerOptions, MetricKind, NoiseOptions, Sensitivity};
use crate::util::rng::{noise_seed, pair_seed, probe_seed, Rng};
use crate::Result;

use super::CostModel;

/// Thread-safe synthetic environment with a known accuracy model.
pub struct SyntheticEnv {
    penalty: Vec<f64>,
    evals: AtomicUsize,
    /// Error out after this many raw evaluations (simulated interruption).
    abort_after: Option<usize>,
}

impl SyntheticEnv {
    /// `layers` layers with seeded penalties: ~30% expensive (up to 0.2),
    /// the rest nearly free — the mix that exercises both accept and
    /// reject chains.
    pub fn new(layers: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from(seed ^ 0x5e17_ca5e);
        let penalty = (0..layers)
            .map(|_| if rng.uniform() < 0.3 { rng.uniform() * 0.2 } else { rng.uniform() * 1e-3 })
            .collect();
        Self { penalty, evals: AtomicUsize::new(0), abort_after: None }
    }

    /// Make every evaluation past the `n`-th fail — a deterministic stand-in
    /// for killing the process mid-search (checkpoint/resume testing).
    pub fn abort_after(mut self, n: usize) -> Self {
        self.abort_after = Some(n);
        self
    }

    /// Raw evaluations issued so far (speculation included).
    pub fn evals(&self) -> usize {
        self.evals.load(Ordering::Relaxed)
    }

    /// Identity ordering (the synthetic penalties are not sorted, so this
    /// behaves like a plausible — imperfect — sensitivity ranking).
    pub fn order(&self) -> Vec<usize> {
        (0..self.penalty.len()).collect()
    }

    fn accuracy(&self, cfg: &QuantConfig) -> f64 {
        let cost: f64 = cfg
            .bits_w
            .iter()
            .enumerate()
            .map(|(i, &b)| self.penalty[i] * f64::from(16.0 - b) / 12.0)
            .sum();
        1.0 - cost
    }
}

impl SyncSearchEnv for SyntheticEnv {
    fn num_layers(&self) -> usize {
        self.penalty.len()
    }

    fn eval(&self, cfg: &QuantConfig, _target: Option<f64>) -> Result<EvalResult> {
        let n = self.evals.fetch_add(1, Ordering::Relaxed);
        if let Some(limit) = self.abort_after {
            if n >= limit {
                anyhow::bail!("synthetic environment aborted after {limit} evaluations");
            }
        }
        let acc = self.accuracy(cfg);
        Ok(EvalResult { loss: 1.0 - acc, accuracy: acc, exact: true })
    }
}

/// Synthetic deployment cost: per-layer weighted mean of the configured
/// bit widths relative to fp16. Strictly monotone — lowering any layer's
/// precision lowers both costs — which is exactly the property budget
/// objectives rely on.
pub struct SyntheticCost {
    weights: Vec<f64>,
}

impl SyntheticCost {
    /// Seeded per-layer weights in `[0.5, 1.5)`.
    pub fn new(layers: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from(seed ^ 0xc0_57);
        Self { weights: (0..layers).map(|_| 0.5 + rng.uniform()).collect() }
    }

    fn weighted_rel(&self, bits: impl Iterator<Item = f64>) -> f64 {
        let total: f64 = self.weights.iter().sum();
        if total <= 0.0 {
            return 1.0;
        }
        let cost: f64 = bits.zip(&self.weights).map(|(b, &w)| w * b / 16.0).sum();
        cost / total
    }
}

impl CostModel for SyntheticCost {
    fn rel_latency(&self, cfg: &QuantConfig) -> f64 {
        // Latency sees both operand widths (weights stream + activations).
        self.weighted_rel(
            cfg.bits_w.iter().zip(&cfg.bits_a).map(|(&w, &a)| (f64::from(w) + f64::from(a)) / 2.0),
        )
    }

    fn rel_size(&self, cfg: &QuantConfig) -> f64 {
        // Size is weights only.
        self.weighted_rel(cfg.bits_w.iter().map(|&w| f64::from(w)))
    }

    fn latency_s(&self, cfg: &QuantConfig) -> f64 {
        self.rel_latency(cfg) * 1e-3
    }

    fn size_bytes(&self, cfg: &QuantConfig) -> f64 {
        self.rel_size(cfg) * 1e6
    }

    fn provenance(&self) -> &str {
        "synthetic"
    }
}

/// Device-free [`StageRunner`]: deterministic per-batch / per-trial math
/// fanned over real scoped threads — the synthetic mirror of
/// [`crate::coordinator::PipelinePool`]'s stage path. Powers the
/// `rust/tests/sharded_calibration.rs` parity suite,
/// `benches/calibrate_sharded.rs`, and `mpq calibrate --synthetic` (the CI
/// smoke that diffs 1- vs 2-worker scales). Every kernel is a pure
/// function of `(seed, global item index, inputs)`, so — exactly like the
/// device path — any worker count produces bit-identical results; an
/// optional CPU spin per batch/probe stands in for device latency so
/// multi-worker speedups are real parallel work.
pub struct SyntheticStage {
    layers: usize,
    batches: usize,
    workers: usize,
    seed: u64,
    /// Spin iterations per simulated batch/probe (0 = pure math).
    work: u32,
    /// Quadratic targets for the four scale vectors (seeded, fixed).
    targets: Vec<f32>,
    /// Scales installed by the last broadcast.
    current: Scales,
    broadcasts: usize,
}

impl SyntheticStage {
    pub fn new(layers: usize, batches: usize, workers: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from(probe_seed(seed ^ 0x7A26, 0));
        let targets = (0..layers * 4).map(|_| (0.5 + 2.0 * rng.uniform()) as f32).collect();
        Self {
            layers,
            batches,
            workers: workers.max(1),
            seed,
            work: 0,
            targets,
            current: Scales::identity(layers),
            broadcasts: 0,
        }
    }

    /// Burn `work` deterministic spin iterations per simulated
    /// batch/probe (benchmark mode).
    pub fn with_work(mut self, work: u32) -> Self {
        self.work = work;
        self
    }

    /// Broadcasts received so far (one per Adam step plus the step-1
    /// install).
    pub fn broadcasts(&self) -> usize {
        self.broadcasts
    }

    /// Scales installed by the last broadcast.
    pub fn current_scales(&self) -> &Scales {
        &self.current
    }

    fn spin(work: u32) {
        let mut x = 0.0f64;
        for i in 0..work {
            x += f64::from(i ^ 0xA5A5).sqrt();
        }
        std::hint::black_box(x);
    }

    /// Fan `f` over the shards with one scoped thread per shard, gathering
    /// per-item results in shard order.
    fn fan<T: Send>(
        &self,
        shards: &[Vec<usize>],
        f: impl Fn(usize) -> T + Sync,
    ) -> Vec<Vec<T>> {
        std::thread::scope(|s| {
            let handles: Vec<_> = shards
                .iter()
                .map(|shard| {
                    let f = &f;
                    s.spawn(move || shard.iter().map(|&i| f(i)).collect::<Vec<T>>())
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("synthetic stage shard panicked"))
                .collect()
        })
    }

    /// Per-batch activation maxima — pure in `(seed, batch)`.
    fn act_batch(&self, batch: usize) -> Vec<f32> {
        Self::spin(self.work);
        let mut rng = Rng::seed_from(probe_seed(self.seed ^ 0xAC7, batch as u64));
        (0..self.layers).map(|_| (0.25 + 4.0 * rng.uniform()) as f32).collect()
    }

    /// Per-batch gradient of a jittered quadratic `w_b * Σ (s - t)^2` —
    /// pure in `(seed, batch, scales, bits)`.
    fn grad_batch(&self, scales: &Scales, bits: f32, batch: usize) -> BatchGrad {
        Self::spin(self.work);
        let mut rng = Rng::seed_from(probe_seed(self.seed ^ 0x96AD, batch as u64));
        // Harsher probed widths sharpen the curvature slightly, keeping
        // the kernel sensitive to `bits` like the real scale_grad graph.
        let w = (1.0 + 0.25 * rng.uniform()) as f32 * (1.0 + (16.0 - bits) / 64.0);
        let n = self.layers;
        let views = [&scales.alpha_w, &scales.gamma_w, &scales.alpha_a, &scales.gamma_a];
        let mut grads = Vec::with_capacity(n * 4);
        let mut loss = 0.0f64;
        for (vi, vec) in views.into_iter().enumerate() {
            for (i, &s) in vec.iter().enumerate() {
                let t = self.targets[vi * n + i];
                grads.push(w * 2.0 * (s - t));
                loss += f64::from(w * (s - t) * (s - t));
            }
        }
        BatchGrad { batch, loss, grads }
    }

    /// Per-trial probe sample — pure in `(seed, trial)`.
    fn hvp_trial(&self, seed: u64, trial: usize) -> TraceSample {
        Self::spin(self.work);
        let mut rng = Rng::seed_from(probe_seed(seed, trial as u64));
        let vhv = (0..self.layers).map(|l| rng.gaussian().abs() * (1.0 + l as f64)).collect();
        TraceSample { trial, vhv }
    }

    /// The unperturbed model's pseudo calibration loss — pure in `seed`,
    /// shared by [`StageRunner::stage_clean_loss`] and every noise item.
    fn clean_loss(&self) -> f64 {
        let mut rng = Rng::seed_from(probe_seed(self.seed ^ 0xC1EA, 0));
        1.0 + rng.uniform()
    }

    /// One ε_N perturbation trial — pure in `(seed, layer, trial)`, with a
    /// per-layer curvature so scores order the layers deterministically.
    fn noise_item(&self, lambda: f64, trials: usize, seed: u64, item: usize) -> NoiseSample {
        Self::spin(self.work);
        let trials = trials.max(1);
        let (layer, trial) = (item / trials, item % trials);
        let mut rng = Rng::seed_from(noise_seed(seed, layer as u64, trial as u64));
        let degradation = lambda * (1.0 + layer as f64) * rng.gaussian().abs();
        NoiseSample { item, loss: self.clean_loss() + degradation }
    }

    /// Planted pairwise coupling strength for the inter-layer metric:
    /// layers 0 and 1 interact strongly, every other pair is independent.
    /// The coupling is large enough that the cross-layer score must rank
    /// both coupled layers above the independently-noisier high-index
    /// layers, while diagonal-only metrics (Hessian/noise) order strictly
    /// by layer index — an analytically checkable reordering.
    fn planted_coupling(i: usize, j: usize) -> f64 {
        let (lo, hi) = if i <= j { (i, j) } else { (j, i) };
        if (lo, hi) == (0, 1) {
            8.0
        } else {
            0.0
        }
    }

    /// One paired-perturbation cell — pure in `(seed, pair, trial)`.
    /// Diagonal cells (l, l) reproduce a per-layer degradation seeded
    /// `pair_seed(seed, l, l, trial)`; off-diagonal cells (i, j) add the
    /// two diagonal degradations (the separable part, which the
    /// finite-difference interaction cancels exactly) plus the planted
    /// coupling drawn from the off-diagonal seed.
    fn pair_item(&self, lambda: f64, trials: usize, seed: u64, item: usize) -> PairSample {
        Self::spin(self.work);
        let trials = trials.max(1);
        let (pair, trial) = (item / trials, item % trials);
        let (i, j) = pair_at(self.layers, pair);
        let diag = |l: usize| {
            let mut rng = Rng::seed_from(pair_seed(seed, l as u64, l as u64, trial as u64));
            lambda * (1.0 + l as f64) * rng.gaussian().abs()
        };
        let loss = if i == j {
            self.clean_loss() + diag(i)
        } else {
            let mut rng = Rng::seed_from(pair_seed(seed, i as u64, j as u64, trial as u64));
            let interaction = Self::planted_coupling(i, j) * lambda * rng.gaussian().abs();
            self.clean_loss() + diag(i) + diag(j) + interaction
        };
        PairSample { item, loss }
    }
}

impl StageRunner for SyntheticStage {
    fn shard_workers(&self) -> usize {
        self.workers
    }

    fn shard_layers(&self) -> usize {
        self.layers
    }

    fn adjust_batches(&self) -> usize {
        self.batches
    }

    fn weight_numels(&self) -> Vec<u64> {
        (0..self.layers).map(|l| 16 * (l as u64 + 1)).collect()
    }

    fn stage_weight_scales(&mut self) -> Result<Scales> {
        let mut scales = Scales::identity(self.layers);
        let mut rng = Rng::seed_from(probe_seed(self.seed ^ 0x57A7E, 0));
        for qi in 0..self.layers {
            let maxabs = (0.5 + rng.uniform()) as f32;
            scales.alpha_w[qi] = 1.0 / maxabs;
            scales.gamma_w[qi] = maxabs;
        }
        Ok(scales)
    }

    fn stage_act_stats(&mut self, shards: &[Vec<usize>]) -> Result<Vec<Vec<f32>>> {
        let per_batch = self.fan(shards, |b| self.act_batch(b));
        // Mirror the device kernel: each shard returns its merged maxima.
        Ok(per_batch.into_iter().map(|stats| merge_act_stats(&stats)).collect())
    }

    fn stage_adjust_grads(
        &mut self,
        scales: &Scales,
        bits: f32,
        shards: &[Vec<usize>],
    ) -> Result<Vec<Vec<BatchGrad>>> {
        Ok(self.fan(shards, |b| self.grad_batch(scales, bits, b)))
    }

    fn stage_hvp(&mut self, seed: u64, shards: &[Vec<usize>]) -> Result<Vec<Vec<TraceSample>>> {
        Ok(self.fan(shards, |t| self.hvp_trial(seed, t)))
    }

    fn stage_clean_loss(&mut self) -> Result<f64> {
        Ok(self.clean_loss())
    }

    fn stage_noise(
        &mut self,
        lambda: f64,
        trials: usize,
        seed: u64,
        shards: &[Vec<usize>],
    ) -> Result<Vec<Vec<NoiseSample>>> {
        Ok(self.fan(shards, |item| self.noise_item(lambda, trials, seed, item)))
    }

    fn stage_pair(
        &mut self,
        lambda: f64,
        trials: usize,
        seed: u64,
        shards: &[Vec<usize>],
    ) -> Result<Vec<Vec<PairSample>>> {
        Ok(self.fan(shards, |item| self.pair_item(lambda, trials, seed, item)))
    }

    fn broadcast_scales(&mut self, scales: &Scales) -> Result<()> {
        self.current = scales.clone();
        self.broadcasts += 1;
        Ok(())
    }
}

/// Calibration batches behind the synthetic stage runner (sensitivity
/// probes); results are worker-count-independent, so this is a fixed
/// constant rather than a caller knob.
const STAGE_BATCHES: usize = 8;

/// Domain tag for the synthetic ε_QE probe weights, so they never share
/// a splitmix64 stream with the env/cost/stage constructions.
const QE_SALT: u64 = 0x9e5a_17_e5;

/// Probe tensor length per layer for the synthetic ε_QE stand-in.
const QE_PROBE_LEN: usize = 256;

/// The synthetic stand-in for every sensitivity metric: Hessian, noise,
/// and inter-layer run the real sharded metric drivers over
/// [`SyntheticStage`] (bit-identical at every worker count); ε_QE scores
/// seeded per-layer probe tensors with [`eps_qe`] at the harshest
/// candidate width; random is the paper's uninformed baseline. Shared by
/// the experiment harness, `mpq search --synthetic --metric`, and the
/// metric-agreement report, so all three agree byte-for-byte.
pub fn synthetic_sensitivity(
    metric: MetricKind,
    layers: usize,
    trials: usize,
    seed: u64,
    workers: usize,
) -> Result<Sensitivity> {
    Ok(match metric {
        MetricKind::Random => Sensitivity::random(layers, seed),
        MetricKind::Hessian => {
            let mut stage = SyntheticStage::new(layers, STAGE_BATCHES, workers, seed);
            let scores = hessian_trace_sharded(&mut stage, trials, seed)?;
            Sensitivity::from_scores(MetricKind::Hessian, scores)
        }
        MetricKind::Noise => {
            let mut stage = SyntheticStage::new(layers, STAGE_BATCHES, workers, seed);
            let lambda = NoiseOptions::default().lambda;
            let scores = noise_scores_sharded(&mut stage, lambda, trials, seed)?;
            Sensitivity::from_scores(MetricKind::Noise, scores)
        }
        MetricKind::InterLayer => {
            let mut stage = SyntheticStage::new(layers, STAGE_BATCHES, workers, seed);
            let lambda = InterLayerOptions::default().lambda;
            let scores = interlayer_scores_sharded(&mut stage, lambda, trials, seed)?;
            Sensitivity::from_scores(MetricKind::InterLayer, scores)
        }
        MetricKind::Qe => {
            let probe_bits = QUANT_BITS[QUANT_BITS.len() - 1];
            let scores = (0..layers)
                .map(|layer| {
                    let mut rng = Rng::seed_from(probe_seed(seed ^ QE_SALT, layer as u64));
                    let w: Vec<f32> = (0..QE_PROBE_LEN).map(|_| rng.gaussian() as f32).collect();
                    eps_qe(&w, probe_bits)
                })
                .collect();
            Sensitivity::from_scores(MetricKind::Qe, scores)
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_is_deterministic_and_monotone() {
        let a = SyntheticEnv::new(12, 7);
        let b = SyntheticEnv::new(12, 7);
        let float = QuantConfig::float(12);
        let q8 = QuantConfig::uniform(12, 8.0);
        assert_eq!(a.eval(&float, None).unwrap(), b.eval(&float, None).unwrap());
        assert!(a.eval(&q8, None).unwrap().accuracy <= a.eval(&float, None).unwrap().accuracy);
        assert_eq!(a.evals(), 3);
    }

    #[test]
    fn abort_after_fails_deterministically() {
        let env = SyntheticEnv::new(4, 0).abort_after(2);
        let cfg = QuantConfig::float(4);
        assert!(env.eval(&cfg, None).is_ok());
        assert!(env.eval(&cfg, None).is_ok());
        assert!(env.eval(&cfg, None).is_err());
    }

    #[test]
    fn cost_is_monotone_and_normalized() {
        let cost = SyntheticCost::new(8, 3);
        let float = QuantConfig::float(8);
        assert!((cost.rel_latency(&float) - 1.0).abs() < 1e-12);
        assert!((cost.rel_size(&float) - 1.0).abs() < 1e-12);
        let mut one = float.clone();
        one.set_layer(3, 4.0);
        assert!(cost.rel_latency(&one) < 1.0);
        assert!(cost.rel_size(&one) < 1.0);
        let q4 = QuantConfig::uniform(8, 4.0);
        assert!((cost.rel_size(&q4) - 0.25).abs() < 1e-12);
        assert!(cost.rel_latency(&q4) < cost.rel_latency(&one));
    }
}
