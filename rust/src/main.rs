//! `mpq` — the coordinator CLI.
//!
//! Everything the paper's evaluation does is reachable from here:
//!
//! ```text
//! mpq info                         # list exported models + baselines
//! mpq calibrate --model resnet_s --workers 4   # sharded two-step scale estimation
//! mpq calibrate --synthetic 12 --workers 2     # device-free parity smoke (CI)
//! mpq eval --model resnet_s --bits 8
//! mpq sensitivity --model bert_s --metric hessian
//! mpq search --model bert_s --algo greedy --metric hessian --target 0.99
//! mpq search --synthetic 24 --budget-latency 0.7 --checkpoint ck.json
//! mpq table --id 1|2|3 [--model M] [--out DIR]   # regenerate paper tables
//! mpq figure --id 1|3|4 [--model M] [--out DIR]  # regenerate figure data
//! mpq report --sweep --model M --budgets 0.5,0.7 --floors 0.99,0.999
//! mpq report --sweep --synthetic 24 --checkpoint sweep.ck.json --resume
//! mpq report --agreement --synthetic 16 --target 0.95
//! mpq pareto --model M --floors 0.9,0.99       # one-pass frontier -> <M>_frontier.json
//! mpq report --sweep --model M --from-frontier artifacts/M_frontier.json
//! mpq serve --model resnet_s --bits 8 --requests 256
//! mpq serve --model M --frontier artifacts/M_frontier.json --pick latency<=0.7,acc>=0.99
//! mpq experiment run experiments/paper_repro.yaml --baseline experiments/baseline.json
//! ```
//!
//! Each subcommand parses into a typed argument struct
//! ([`SearchCmd`], [`ServeCmd`], ...) and runs through the
//! [`mpq::api::SearchSpec`] front door — the only string matching left is
//! the one `<command> -> struct` dispatch in [`Command::parse`].

use std::path::{Path, PathBuf};
use std::sync::Arc;

use mpq::api::{
    build_frontier_synthetic_partitioned, log_event, parse_tenants, run_search,
    synthetic_sensitivity, BackendSpec, Checkpoint, CostModel, EventSink, FrontierArtifact,
    FrontierReport, ObjectiveSpec, PickSpec, SearchEvent, SearchSpec, SyntheticCost, SyntheticEnv,
    SyntheticStage, TenantSpec,
};
use mpq::coordinator::{
    calibrate_sharded, hessian_trace_sharded, noise_scores_sharded, ParallelEnv, SearchAlgo,
};
use mpq::model::ArtifactIndex;
use mpq::quant::{CalibrationOptions, QuantConfig, QUANT_BITS};
use mpq::report::experiments::{self, ExperimentCtx, METRIC_TRIALS};
use mpq::report::{
    budget_sweep_from_frontier, budget_sweep_synthetic, budget_sweep_synthetic_costed,
    cells_to_json, render_sweep, sweep_cells_json, sweep_fingerprint, synthetic_table_cost,
    AgreementReport, BudgetKind, Driver, SweepCheckpoint, SweepGrid,
};
use mpq::experiment::{gate, load_bench, run_suite, Baseline, ExperimentSuite, RunOptions};
use mpq::sensitivity::{MetricKind, NoiseOptions};
use mpq::util::cli::Args;
use mpq::util::json::Value;
use mpq::util::result::ResultLine;
use mpq::Result;

const USAGE: &str = "\
mpq — sensitivity-guided mixed-precision PTQ coordinator

USAGE: mpq <command> [options]

COMMANDS
  info                                       list exported models
  calibrate   --model M | --synthetic N
              [--workers 1] [--adjust-bits 8] [--lr 1e-5] [--epochs 2]
              [--grad-batches 8] [--seed 0]
              [--batches 16] [--trials 8]  (synthetic only)
  eval        --model M [--bits 8]
  sensitivity --model M --metric random|qe|noise|hessian|interlayer
              [--trials N] [--seed S] [--workers 1]
  search      --model M | --synthetic N
              [--algo greedy|bisection] [--metric hessian] [--target 0.99]
              [--seed 0] [--workers 1] [--trials 5]
              [--budget-latency F | --budget-size F]
              [--backend a100|tpu | --table kernels.json] [--native-scale]
              [--partitions K]  (segment-scoped search + reconciliation)
              [--checkpoint ck.json [--resume]] [--cache-capacity N]
              [--no-cache] [--abort-after N (synthetic only)]
                (--metric also works with --synthetic: rank layers via
                 the shared synthetic sensitivity stand-in)
  table       --id 1|2|3 [--model M] [--out DIR] [--workers 1]
              [--budget-latency F | --budget-size F]
  report      --sweep (--model M | --synthetic N)
              [--budget-kind latency|size] [--budgets 0.5,0.7,0.9]
              [--floors 0.9,0.99] [--algo greedy|bisection]
              [--metric hessian] [--seed 0] [--trials 5] [--workers 1]
              [--backend a100|tpu | --table kernels.json]
                (--table also works with --synthetic: per-backend variant)
              [--checkpoint sweep.ck.json [--resume]] [--out DIR]
              [--from-frontier frontier.json]  (O(1) lookups, no searches)
              [--abort-after N (synthetic only)]
  report      --agreement (--model M | --synthetic N)
              [--target 0.99] [--seed 0] [--trials 5] [--workers 1]
              [--backend a100|tpu | --table kernels.json] [--out DIR]
                (all four informed metrics x both algorithms: rank
                 correlation, edit distance, and outcome deltas)
  pareto      --model M | --synthetic N
              [--floors 0.9,0.99] [--algo greedy|bisection]
              [--metric hessian] [--seed 0] [--trials 5] [--workers 1]
              [--backend a100|tpu | --table kernels.json]
              [--partitions K]  (concurrent per-segment frontiers)
              [--checkpoint front.ck [--resume]] [--out frontier.json]
              [--abort-after N (synthetic only)]
  figure      --id 1|3|4 [--model M] [--out DIR]
  ablation    --model M [--target 0.99] [--out DIR]
  serve       --model M [--bits 8] [--requests 256] [--concurrency 8]
              [--workers 2] [--queue-depth 256] [--deadline-ms 0]
              [--max-batch 32] [--wait-us 500] [--priority 0]
              [--frontier frontier.json [--pick latency<=B,size<=B,acc>=F]]
              [--frontier frontier.json --tenants \"gold:latency<=B,acc>=F;...\"]
  experiment  run <suite.yaml> [--out DIR] [--workers N]
              [--baseline baseline.json [--update-baseline [--record-measured]]]
              [--bench BENCH_a.json,BENCH_b.json] [--band 2.0]

GLOBAL
  --artifacts DIR    artifacts directory (default: $MPQ_ARTIFACTS or ./artifacts)
  --events-out F     stream typed search events to F as JSONL
                     (search / calibrate --synthetic / pareto)
";

fn artifacts_dir(args: &Args) -> Result<PathBuf> {
    if let Some(d) = args.get_str("artifacts") {
        return Ok(PathBuf::from(d));
    }
    mpq::artifacts_dir()
        .ok_or_else(|| anyhow::anyhow!("no artifacts directory found — run `make artifacts` first"))
}

fn all_models(dir: &Path, only: Option<&str>) -> Result<Vec<String>> {
    let index = ArtifactIndex::load(dir)?;
    Ok(index
        .models
        .iter()
        .map(|m| m.model.clone())
        .filter(|m| only.is_none_or(|o| o == m))
        .collect())
}

/// One parsed invocation: typed per-subcommand argument structs.
enum Command {
    Info,
    Calibrate(CalibrateCmd),
    Eval(EvalCmd),
    Sensitivity(SensitivityCmd),
    Search(SearchCmd),
    Table(TableCmd),
    Report(ReportCmd),
    Pareto(ParetoCmd),
    Figure(FigureCmd),
    Ablation(AblationCmd),
    Serve(ServeCmd),
    Experiment(ExperimentCmd),
}

impl Command {
    fn parse(args: &Args) -> Result<Self> {
        // Only `experiment` takes positional operands (`run <suite.yaml>`);
        // everywhere else a stray operand is a usage error.
        if args.cmd != "experiment" {
            args.reject_positionals()?;
        }
        match args.cmd.as_str() {
            "info" => Ok(Command::Info),
            "calibrate" => Ok(Command::Calibrate(CalibrateCmd::parse(args)?)),
            "eval" => Ok(Command::Eval(EvalCmd::parse(args)?)),
            "sensitivity" => Ok(Command::Sensitivity(SensitivityCmd::parse(args)?)),
            "search" => Ok(Command::Search(SearchCmd::parse(args)?)),
            "table" => Ok(Command::Table(TableCmd::parse(args)?)),
            "report" => Ok(Command::Report(ReportCmd::parse(args)?)),
            "pareto" => Ok(Command::Pareto(ParetoCmd::parse(args)?)),
            "figure" => Ok(Command::Figure(FigureCmd::parse(args)?)),
            "ablation" => Ok(Command::Ablation(AblationCmd::parse(args)?)),
            "serve" => Ok(Command::Serve(ServeCmd::parse(args)?)),
            "experiment" => Ok(Command::Experiment(ExperimentCmd::parse(args)?)),
            other => anyhow::bail!("unknown command `{other}`"),
        }
    }

    /// Whether `cmd` names a subcommand at all (usage errors exit 2,
    /// run-time failures exit 1 — the historical contract).
    fn is_known(cmd: &str) -> bool {
        matches!(
            cmd,
            "info"
                | "calibrate"
                | "eval"
                | "sensitivity"
                | "search"
                | "table"
                | "report"
                | "pareto"
                | "figure"
                | "ablation"
                | "serve"
                | "experiment"
        )
    }

    fn run(self, args: &Args) -> Result<()> {
        match self {
            Command::Info => cmd_info(&artifacts_dir(args)?),
            // Synthetic calibration needs no artifacts at all.
            Command::Calibrate(c) if c.synthetic.is_some() => c.run_synthetic(),
            Command::Calibrate(c) => c.run(&artifacts_dir(args)?),
            Command::Eval(c) => c.run(&artifacts_dir(args)?),
            Command::Sensitivity(c) => c.run(&artifacts_dir(args)?),
            // Synthetic searches need no artifacts at all.
            Command::Search(c) if c.synthetic.is_some() => c.run_synthetic(),
            Command::Search(c) => {
                let dir = artifacts_dir(args)?;
                c.run_artifacts(&dir)
            }
            Command::Table(c) => c.run(&artifacts_dir(args)?),
            // Synthetic sweeps need no artifacts at all.
            Command::Report(c) if c.synthetic.is_some() => c.run_synthetic(),
            Command::Report(c) => c.run(&artifacts_dir(args)?),
            // Synthetic frontier builds need no artifacts at all.
            Command::Pareto(c) if c.synthetic.is_some() => c.run_synthetic(),
            Command::Pareto(c) => c.run(&artifacts_dir(args)?),
            Command::Figure(c) => c.run(&artifacts_dir(args)?),
            Command::Ablation(c) => c.run(&artifacts_dir(args)?),
            Command::Serve(c) => c.run(&artifacts_dir(args)?),
            // Experiment suites manage their own per-variant artifact dirs.
            Command::Experiment(c) => c.run(),
        }
    }
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    if args.cmd.is_empty() || args.cmd == "help" || args.flag("help") {
        print!("{USAGE}");
        return Ok(());
    }
    if !Command::is_known(&args.cmd) {
        eprint!("unknown command `{}`\n\n{USAGE}", args.cmd);
        std::process::exit(2);
    }
    Command::parse(&args)?.run(&args)
}

fn cmd_info(dir: &Path) -> Result<()> {
    let index = ArtifactIndex::load(dir)?;
    println!("artifacts: {} (schema v{})", dir.display(), index.version);
    for entry in &index.models {
        let ctx = ExperimentCtx::new(dir, &entry.model)?;
        let m = &ctx.pipeline.artifacts.manifest;
        println!(
            "  {:>10}: task={} layers={} (quant {}) eval_batch={} float acc={:.2}% \
             size(fp16)={:.2}MB latency(fp16)={:.3}ms",
            m.model,
            m.task,
            m.layers.len(),
            m.num_quant_layers,
            m.eval_batch,
            m.float_val_acc * 100.0,
            ctx.cost.base_size_mb(),
            ctx.cost.base_latency_ms(),
        );
    }
    Ok(())
}

// ------------------------------------------------------------- calibrate

struct CalibrateCmd {
    model: Option<String>,
    synthetic: Option<usize>,
    workers: usize,
    seed: u64,
    /// Synthetic only: Hutchinson trials for the trace parity line.
    trials: usize,
    /// Synthetic only: simulated adjustment-split batches.
    batches: usize,
    opts: CalibrationOptions,
    /// Stream typed calibration events to this JSONL file (synthetic only).
    events_out: Option<PathBuf>,
}

impl CalibrateCmd {
    fn parse(args: &Args) -> Result<Self> {
        let defaults = CalibrationOptions::default();
        let cmd = Self {
            model: args.get_str("model").map(String::from),
            synthetic: args.get_str("synthetic").map(str::parse).transpose()?,
            workers: args.get_or("workers", 1usize)?.max(1),
            seed: args.get_or("seed", 0u64)?,
            trials: args.get_or("trials", 8usize)?,
            batches: args.get_or("batches", 16usize)?,
            opts: CalibrationOptions {
                adjust_bits: args.get_or("adjust-bits", defaults.adjust_bits)?,
                lr: args.get_or("lr", defaults.lr)?,
                epochs: args.get_or("epochs", defaults.epochs)?,
                grad_batches: args.get_or("grad-batches", defaults.grad_batches)?,
            },
            events_out: args.get_str("events-out").map(PathBuf::from),
        };
        anyhow::ensure!(
            cmd.model.is_some() != cmd.synthetic.is_some(),
            "calibrate needs exactly one of --model M or --synthetic N"
        );
        if cmd.synthetic.is_none() {
            for flag in ["trials", "batches", "events-out"] {
                anyhow::ensure!(
                    args.get_str(flag).is_none(),
                    "--{flag} only applies to --synthetic calibration"
                );
            }
        }
        Ok(cmd)
    }

    /// Artifact-backed calibration through the sharded stage driver (pool
    /// fan-out at `--workers > 1`); persists the scales for later runs.
    fn run(self, dir: &Path) -> Result<()> {
        let model = self.model.clone().expect("checked in parse");
        let spec = SearchSpec::new(model.as_str()).artifacts_dir(dir).workers(self.workers);
        let mut ctx = spec.open_context()?;
        let report = ctx.calibrate_with(&self.opts, None)?;
        println!(
            "calibrated {model} ({} worker(s)): adjustment loss {:.4} -> {:.4} over {} steps",
            ctx.workers(),
            report.loss_before,
            report.loss_after,
            report.steps
        );
        Ok(())
    }

    /// Artifact-free sharded calibration + Hessian trace + ε_N noise over
    /// the seeded synthetic stage runner — CI runs this at 1 and 2 workers
    /// and diffs the RESULT lines (they must be byte-identical).
    fn run_synthetic(self) -> Result<()> {
        let layers = self.synthetic.expect("checked in parse");
        let mut stage = SyntheticStage::new(layers, self.batches, self.workers, self.seed);
        let sink = match &self.events_out {
            Some(path) => Some(EventSink::create(path)?),
            None => None,
        };
        let mut sink_obs = sink.as_ref().map(|s| s.observer());
        let mut obs = |ev: &SearchEvent| {
            log_event(ev);
            if let Some(record) = sink_obs.as_mut() {
                record(ev);
            }
        };
        let (scales, report) = calibrate_sharded(&mut stage, &self.opts, Some(&mut obs))?;
        let traces = hessian_trace_sharded(&mut stage, self.trials, self.seed)?;
        let noise = noise_scores_sharded(
            &mut stage,
            NoiseOptions::default().lambda,
            self.trials,
            self.seed,
        )?;
        eprintln!(
            "[calibration] synthetic run: {} layers x {} batches, {} worker(s), {} broadcasts",
            layers,
            self.batches,
            self.workers,
            stage.broadcasts(),
        );
        // Stable single-line summary for scripts: identical at every
        // worker count (the sharded-determinism contract).
        let summary = Value::obj(vec![
            ("alpha_w", Value::arr_f32(&scales.alpha_w)),
            ("gamma_w", Value::arr_f32(&scales.gamma_w)),
            ("alpha_a", Value::arr_f32(&scales.alpha_a)),
            ("gamma_a", Value::arr_f32(&scales.gamma_a)),
            ("hessian", Value::Arr(traces.iter().map(|&t| Value::Num(t)).collect())),
            ("noise", Value::Arr(noise.iter().map(|&s| Value::Num(s)).collect())),
            ("loss_before", Value::Num(report.loss_before)),
            ("loss_after", Value::Num(report.loss_after)),
            ("steps", Value::Num(report.steps as f64)),
        ]);
        if let Some(sink) = &sink {
            let events = sink.finish()?;
            eprintln!("[events] {events} events -> {}", sink.path().display());
        }
        ResultLine::new("calibrate")
            .seed(self.seed)
            .workers(self.workers)
            .payload(summary)
            .emit();
        Ok(())
    }
}

// ------------------------------------------------------------------ eval

struct EvalCmd {
    model: String,
    bits: f32,
}

impl EvalCmd {
    fn parse(args: &Args) -> Result<Self> {
        Ok(Self {
            model: args.req_str("model")?.to_string(),
            bits: args.get_or("bits", 8.0f32)?,
        })
    }

    fn run(self, dir: &Path) -> Result<()> {
        let mut ctx = ExperimentCtx::new(dir, &self.model)?;
        ctx.ensure_calibrated()?;
        let n = ctx.pipeline.num_quant_layers();
        let cfg = QuantConfig::uniform(n, self.bits);
        let r = ctx.pipeline.eval_config(&cfg, None)?;
        println!(
            "{} @ uniform {}b: loss={:.4} accuracy={:.2}% (float {:.2}%) \
             rel_size={:.2}% rel_latency={:.2}%",
            self.model,
            self.bits,
            r.loss,
            r.accuracy * 100.0,
            ctx.pipeline.float_val_acc() * 100.0,
            ctx.cost.rel_size(&cfg) * 100.0,
            ctx.cost.rel_latency(&cfg) * 100.0,
        );
        Ok(())
    }
}

// ----------------------------------------------------------- sensitivity

struct SensitivityCmd {
    model: String,
    metric: MetricKind,
    trials: usize,
    seed: u64,
    workers: usize,
}

impl SensitivityCmd {
    fn parse(args: &Args) -> Result<Self> {
        Ok(Self {
            model: args.req_str("model")?.to_string(),
            metric: args.req("metric")?,
            trials: args.get_or("trials", METRIC_TRIALS)?,
            seed: args.get_or("seed", 0u64)?,
            workers: args.get_or("workers", 1usize)?.max(1),
        })
    }

    /// Calibrate (sharded at `--workers > 1`), then compute the metric
    /// through the context — Hessian trials and ε_N perturbations fan
    /// across the same pool, and informed scores land in the on-disk
    /// sensitivity cache.
    fn run(self, dir: &Path) -> Result<()> {
        let spec = SearchSpec::new(self.model.as_str())
            .artifacts_dir(dir)
            .workers(self.workers)
            .metric(self.metric)
            .trials(self.trials.max(1))
            .seed(self.seed);
        let mut ctx = spec.open_context()?;
        ctx.ensure_calibrated()?;
        let sens = ctx.cached_sensitivity(self.metric, self.trials, self.seed)?;
        let names: Vec<String> = ctx
            .pipeline
            .artifacts
            .manifest
            .quant_layers()
            .iter()
            .map(|l| l.name.clone())
            .collect();
        println!(
            "{} sensitivity for {} (least sensitive first):",
            self.metric.label(),
            self.model
        );
        for &layer in &sens.order {
            println!("  {:>20}  score={:.4e}", names[layer], sens.scores[layer]);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------- search

struct SearchCmd {
    model: Option<String>,
    synthetic: Option<usize>,
    algo: SearchAlgo,
    metric: MetricKind,
    /// Whether `--metric` was given on the command line. Synthetic runs
    /// historically ignored metrics (the env's identity order); an
    /// explicit flag now routes through [`synthetic_sensitivity`] — and
    /// only an explicit flag, so default synthetic runs (and their
    /// checkpoints/CI byte-diffs) are literally unchanged.
    metric_explicit: bool,
    target: f64,
    seed: u64,
    trials: usize,
    workers: usize,
    objective: ObjectiveSpec,
    backend: BackendSpec,
    native_scale: bool,
    checkpoint: Option<PathBuf>,
    resume: bool,
    cache_capacity: Option<usize>,
    no_cache: bool,
    /// Split the sensitivity order into K segments searched concurrently
    /// with pro-rated budgets, then reconciled (1 = whole-model search).
    partitions: usize,
    /// Synthetic only: error out after N raw evals (simulated kill).
    abort_after: Option<usize>,
    /// Stream the typed search-event stream to this JSONL file.
    events_out: Option<PathBuf>,
}

/// Parse the shared `--backend a100|tpu` / `--table kernels.json` flags
/// (mutually exclusive) into a cost backend.
fn parse_backend(args: &Args) -> Result<BackendSpec> {
    match (args.get_str("backend"), args.get_str("table")) {
        (Some(_), Some(_)) => anyhow::bail!("--backend and --table are mutually exclusive"),
        (None, Some(path)) => Ok(BackendSpec::MeasuredTable(PathBuf::from(path))),
        (Some("a100"), None) | (None, None) => Ok(BackendSpec::A100Like),
        (Some("tpu"), None) => Ok(BackendSpec::TpuLike),
        (Some(other), None) => anyhow::bail!("unknown backend `{other}` (a100|tpu)"),
    }
}

/// Parse a `--budgets 0.5,0.7`-style comma-separated fraction list.
fn parse_f64_list(args: &Args, name: &str, default: &[f64]) -> Result<Vec<f64>> {
    match args.get_str(name) {
        None => Ok(default.to_vec()),
        Some(s) => s
            .split(',')
            .map(|part| {
                part.trim()
                    .parse::<f64>()
                    .map_err(|e| anyhow::anyhow!("bad --{name} entry `{part}`: {e}"))
            })
            .collect(),
    }
}

/// Parse the shared `--budget-latency`/`--budget-size` flags (mutually
/// exclusive) into an objective.
fn parse_objective(args: &Args) -> Result<ObjectiveSpec> {
    let budget_latency = args.get_str("budget-latency").map(str::parse).transpose()?;
    let budget_size = args.get_str("budget-size").map(str::parse).transpose()?;
    match (budget_latency, budget_size) {
        (Some(_), Some(_)) => {
            anyhow::bail!("--budget-latency and --budget-size are mutually exclusive")
        }
        (Some(rel_latency), None) => Ok(ObjectiveSpec::LatencyBudget { rel_latency }),
        (None, Some(rel_size)) => Ok(ObjectiveSpec::FootprintBudget { rel_size }),
        (None, None) => Ok(ObjectiveSpec::AccuracyTarget),
    }
}

impl SearchCmd {
    fn parse(args: &Args) -> Result<Self> {
        let objective = parse_objective(args)?;
        let backend = parse_backend(args)?;
        let cmd = Self {
            model: args.get_str("model").map(String::from),
            synthetic: args.get_str("synthetic").map(str::parse).transpose()?,
            algo: args.get_str("algo").unwrap_or("greedy").parse()?,
            metric: args.get_or("metric", MetricKind::Hessian)?,
            metric_explicit: args.get_str("metric").is_some(),
            target: args.get_or("target", 0.99f64)?,
            seed: args.get_or("seed", 0u64)?,
            trials: args.get_or("trials", METRIC_TRIALS)?,
            workers: args.get_or("workers", 1usize)?,
            objective,
            backend,
            native_scale: args.flag("native-scale"),
            checkpoint: args.get_str("checkpoint").map(PathBuf::from),
            resume: args.flag("resume"),
            cache_capacity: args.get_str("cache-capacity").map(str::parse).transpose()?,
            no_cache: args.flag("no-cache"),
            partitions: args.get_or("partitions", 1usize)?.max(1),
            abort_after: args.get_str("abort-after").map(str::parse).transpose()?,
            events_out: args.get_str("events-out").map(PathBuf::from),
        };
        anyhow::ensure!(
            cmd.model.is_some() != cmd.synthetic.is_some(),
            "search needs exactly one of --model M or --synthetic N"
        );
        anyhow::ensure!(
            cmd.abort_after.is_none() || cmd.synthetic.is_some(),
            "--abort-after only applies to --synthetic runs"
        );
        if cmd.synthetic.is_some() {
            // Reject flags the synthetic path would otherwise silently
            // ignore (it has no cost backends or persistent eval cache).
            // `--metric`/`--trials` *do* apply: an explicit metric ranks
            // the synthetic layers through the shared sensitivity
            // stand-in instead of the env's identity order.
            for flag in ["backend", "table", "cache-capacity"] {
                anyhow::ensure!(
                    args.get_str(flag).is_none(),
                    "--{flag} does not apply to --synthetic runs"
                );
            }
            anyhow::ensure!(
                !args.flag("no-cache") && !args.flag("native-scale"),
                "--no-cache/--native-scale do not apply to --synthetic runs"
            );
            anyhow::ensure!(
                cmd.metric_explicit || args.get_str("trials").is_none(),
                "--trials on --synthetic runs requires --metric"
            );
            anyhow::ensure!(
                !cmd.metric_explicit || cmd.partitions == 1,
                "--metric with --synthetic requires --partitions 1"
            );
        }
        Ok(cmd)
    }

    /// The spec this invocation describes (synthetic runs use it for
    /// validation and objective construction only).
    fn to_spec(&self, model: &str) -> SearchSpec {
        let mut spec = SearchSpec::new(model)
            .algo(self.algo)
            .metric(self.metric)
            .target(self.target)
            .seed(self.seed)
            .trials(self.trials)
            .workers(self.workers)
            .objective(self.objective)
            .backend(self.backend.clone())
            .partitions(self.partitions)
            .resume(self.resume);
        if self.native_scale {
            spec = spec.deploy_scale(mpq::api::ScaleSpec::Native);
        }
        if let Some(ck) = &self.checkpoint {
            spec = spec.checkpoint(ck.clone());
        }
        if let Some(cap) = self.cache_capacity {
            spec = spec.cache_capacity(cap);
        }
        if self.no_cache {
            spec = spec.no_cache();
        }
        spec
    }

    /// Artifact-backed search through a [`mpq::api::SearchSession`].
    fn run_artifacts(self, dir: &Path) -> Result<()> {
        let model = self.model.clone().expect("checked in parse");
        let spec = self.to_spec(&model).artifacts_dir(dir);
        let mut session = spec.open()?;
        session.on_event(log_event);
        let sink = match &self.events_out {
            Some(path) => {
                let sink = EventSink::create(path)?;
                session.on_event(sink.observer());
                Some(sink)
            }
            None => None,
        };
        let report = session.run()?;
        if let Some(sink) = &sink {
            let events = sink.finish()?;
            eprintln!("[events] {events} events -> {}", sink.path().display());
        }
        let out = &report.outcome;
        println!(
            "{model} {}/{} target {:.1}%: accuracy={:.2}% size={:.2}% latency={:.2}% \
             ({} evals, {:.1}s, cost {})",
            report.algo.label(),
            report.metric.label(),
            self.target * 100.0,
            out.accuracy * 100.0,
            report.rel_size * 100.0,
            report.rel_latency * 100.0,
            out.evals,
            report.search_seconds,
            report.cost_provenance,
        );
        let bits: Vec<u32> = out.config.bits_w.iter().map(|&b| b as u32).collect();
        println!("per-layer bits: {bits:?}");
        if report.checkpointed_decisions > 0 {
            println!(
                "checkpoint: {} decisions recorded ({} replayed on resume)",
                report.checkpointed_decisions, report.replayed_decisions
            );
        }
        if report.workers <= 1 {
            let stats = session.ctx.pipeline.stats;
            println!(
                "pipeline: {} evals, {} cache hits, {} batch execs, {} early exits",
                stats.evals, stats.cache_hits, stats.batch_execs, stats.early_exits
            );
        } else {
            // With workers > 1 the search ran on the context's shared
            // PipelinePool; the context pipeline's counters only cover
            // calibration/sensitivity, so don't present them as the
            // search's stats (cache hits arrive via the CacheReport
            // event).
            println!(
                "search ran on the context's {}-worker pipeline pool \
                 (shared eval cache persisted to disk)",
                report.workers
            );
        }
        Ok(())
    }

    /// Artifact-free search over the seeded synthetic environment — the
    /// zero-setup path CI uses to smoke the full API (objectives, budgets,
    /// worker fan-out, checkpoint kill/resume).
    fn run_synthetic(self) -> Result<()> {
        let n = self.synthetic.expect("checked in parse");
        let spec = self.to_spec("synthetic").no_cache();
        spec.validate()?;
        // `--partitions 1` stays on the monolithic code path below, so the
        // default CLI behaviour is literally unchanged.
        if self.partitions > 1 {
            return self.run_synthetic_partitioned(n);
        }
        let mut env = SyntheticEnv::new(n, self.seed);
        if let Some(limit) = self.abort_after {
            env = env.abort_after(limit);
        }
        // An explicit `--metric` ranks the synthetic layers through the
        // shared sensitivity stand-in (worker-count independent); the
        // historical default stays the env's identity order, keeping
        // existing checkpoints and CI byte-diffs valid.
        let order = if self.metric_explicit {
            synthetic_sensitivity(self.metric, n, self.trials, self.seed, self.workers)?.order
        } else {
            env.order()
        };
        let cost = Arc::new(SyntheticCost::new(n, self.seed));
        // The synthetic float baseline is exactly 1.0, so the floor is the
        // target itself.
        let objective = self.objective.build(self.target, cost.clone());
        let mut checkpoint = match &self.checkpoint {
            Some(path) => {
                let context = if self.metric_explicit {
                    format!(
                        "synthetic/n{n}/seed{}/metric{}/trials{}",
                        self.seed,
                        self.metric.label(),
                        self.trials
                    )
                } else {
                    format!("synthetic/n{n}/seed{}", self.seed)
                };
                let fp = mpq::api::checkpoint_fingerprint(
                    self.algo,
                    &QUANT_BITS,
                    &objective.describe(),
                    &order,
                    &context,
                );
                Some(Checkpoint::attach(path, &fp, self.resume)?)
            }
            None => None,
        };
        let mut penv = ParallelEnv::new(&env, self.workers);
        let sink = match &self.events_out {
            Some(path) => Some(EventSink::create(path)?),
            None => None,
        };
        let mut sink_obs = sink.as_ref().map(|s| s.observer());
        let mut observer = |ev: &SearchEvent| {
            log_event(ev);
            if let Some(record) = sink_obs.as_mut() {
                record(ev);
            }
        };
        let outcome = run_search(
            self.algo,
            &mut penv,
            &order,
            &QUANT_BITS,
            objective.as_ref(),
            Some(&mut observer),
            checkpoint.as_mut(),
        )?;
        let replayed = checkpoint.as_ref().map_or(0, Checkpoint::replayed);
        eprintln!(
            "[search] synthetic run: {} raw evals, {} decisions checkpointed, {} replayed",
            env.evals(),
            checkpoint.as_ref().map_or(0, Checkpoint::len),
            replayed,
        );
        // Stable single-line summary for scripts (identical for a fresh
        // run and a resumed one — resume state is reported on stderr).
        let summary = Value::obj(vec![
            ("accuracy", Value::Num(outcome.accuracy)),
            ("config", Value::arr_f32(&outcome.config.bits_w)),
            ("evals", Value::Num(outcome.evals as f64)),
            ("rel_latency", Value::Num(cost.rel_latency(&outcome.config))),
            ("rel_size", Value::Num(cost.rel_size(&outcome.config))),
        ]);
        if let Some(sink) = &sink {
            let events = sink.finish()?;
            eprintln!("[events] {events} events -> {}", sink.path().display());
        }
        let mut line = ResultLine::new("search")
            .seed(self.seed)
            .algo(self.algo.label())
            .workers(self.workers)
            .payload(summary);
        if self.metric_explicit {
            line = line.metric(self.metric.label());
        }
        line.emit();
        Ok(())
    }

    /// Synthetic search split into `--partitions K` segments: each segment
    /// searches under a pro-rated budget concurrently, then one global
    /// reconciliation evaluation prices and validates the composed
    /// configuration (see `api/partition.rs`).
    fn run_synthetic_partitioned(self, n: usize) -> Result<()> {
        let sink = match &self.events_out {
            Some(path) => Some(EventSink::create(path)?),
            None => None,
        };
        let mut sink_obs = sink.as_ref().map(|s| s.observer());
        let mut observer = |ev: &SearchEvent| {
            log_event(ev);
            if let Some(record) = sink_obs.as_mut() {
                record(ev);
            }
        };
        let out = mpq::api::partitioned_search_synthetic(
            n,
            self.seed,
            self.algo,
            &self.objective,
            self.target,
            self.partitions,
            self.checkpoint.as_deref(),
            self.resume,
            self.abort_after,
            Some(&mut observer),
        )?;
        let cost = SyntheticCost::new(n, self.seed);
        eprintln!(
            "[search] partitioned synthetic run: {} segments, {} decisions checkpointed, \
             {} replayed, scoped budgets satisfied: {:?}",
            out.segments.len(),
            out.checkpointed_decisions,
            out.replayed_decisions,
            out.satisfied,
        );
        // Same RESULT shape as the monolithic synthetic run, so scripts
        // parse both uniformly (segment detail goes to stderr).
        let summary = Value::obj(vec![
            ("accuracy", Value::Num(out.outcome.accuracy)),
            ("config", Value::arr_f32(&out.outcome.config.bits_w)),
            ("evals", Value::Num(out.outcome.evals as f64)),
            ("rel_latency", Value::Num(cost.rel_latency(&out.outcome.config))),
            ("rel_size", Value::Num(cost.rel_size(&out.outcome.config))),
        ]);
        if let Some(sink) = &sink {
            let events = sink.finish()?;
            eprintln!("[events] {events} events -> {}", sink.path().display());
        }
        ResultLine::new("search")
            .seed(self.seed)
            .algo(self.algo.label())
            .workers(self.workers)
            .payload(summary)
            .emit();
        Ok(())
    }
}

// ----------------------------------------------------------------- table

struct TableCmd {
    id: u32,
    model: Option<String>,
    out: Option<PathBuf>,
    workers: usize,
    objective: ObjectiveSpec,
}

impl TableCmd {
    fn parse(args: &Args) -> Result<Self> {
        Ok(Self {
            id: args.req::<u32>("id")?,
            model: args.get_str("model").map(String::from),
            out: args.get_str("out").map(PathBuf::from),
            workers: args.get_or("workers", 1usize)?.max(1),
            objective: parse_objective(args)?,
        })
    }

    /// Regenerate paper tables through the [`Driver`] front door: one
    /// open [`mpq::api::SearchSession`] per model supplies the context,
    /// pool, and caches; with `--workers > 1` every grid cell calibrates
    /// and evaluates on the shared pipeline pool, and
    /// `--budget-latency`/`--budget-size` turn the grid into its
    /// latency-budgeted variant.
    fn run(self, dir: &Path) -> Result<()> {
        let models = all_models(dir, self.model.as_deref())?;
        let mut rendered = String::new();
        for m in &models {
            let mut session = SearchSpec::new(m.as_str())
                .artifacts_dir(dir)
                .workers(self.workers)
                .objective(self.objective)
                .open()?;
            let mut driver = Driver::new(&mut session);
            if let Some(dir_out) = &self.out {
                driver = driver.sink(dir_out);
            }
            let text = match self.id {
                1 => driver.table1()?.render(),
                2 | 3 => {
                    let targets: &[f64] = if self.id == 2 { &[0.99, 0.999] } else { &[0.90] };
                    let (table, cells) = driver.search_table(self.id, targets, 0)?;
                    driver.write_artifact(
                        &format!("table{}_{m}.json", self.id),
                        &cells_to_json(&cells),
                    )?;
                    table.render()
                }
                _ => anyhow::bail!("unknown table id {} (1, 2 or 3)", self.id),
            };
            println!("{text}");
            rendered.push_str(&text);
        }
        if let Some(dir_out) = &self.out {
            std::fs::create_dir_all(dir_out)?;
            std::fs::write(dir_out.join(format!("table{}.txt", self.id)), rendered)?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------- report

struct ReportCmd {
    model: Option<String>,
    synthetic: Option<usize>,
    /// `--agreement`: run every informed metric + both algorithms and
    /// report rank correlation / edit distance / outcome deltas instead
    /// of the budget × accuracy-floor sweep.
    agreement: bool,
    grid: SweepGrid,
    algo: SearchAlgo,
    metric: MetricKind,
    seed: u64,
    trials: usize,
    workers: usize,
    /// Agreement mode only: the accuracy target every grid cell searches
    /// under.
    target: f64,
    backend: BackendSpec,
    checkpoint: Option<PathBuf>,
    resume: bool,
    out: Option<PathBuf>,
    /// Synthetic only: error out after N freshly computed cells.
    abort_after: Option<usize>,
    /// Answer every cell from a prebuilt frontier artifact — no searches.
    from_frontier: Option<PathBuf>,
    /// Whether `--algo` was given explicitly (a frontier lookup defaults
    /// to the artifact's own algorithm instead of greedy).
    algo_explicit: bool,
}

impl ReportCmd {
    fn parse(args: &Args) -> Result<Self> {
        let agreement = args.flag("agreement");
        anyhow::ensure!(
            args.flag("sweep") != agreement,
            "report needs exactly one mode: --sweep (budget x accuracy-floor grid) or \
             --agreement (metric-agreement report)"
        );
        let cmd = Self {
            model: args.get_str("model").map(String::from),
            synthetic: args.get_str("synthetic").map(str::parse).transpose()?,
            agreement,
            grid: SweepGrid {
                kind: args.get_or("budget-kind", BudgetKind::Latency)?,
                budgets: parse_f64_list(args, "budgets", &[0.5, 0.7, 0.9])?,
                floors: parse_f64_list(args, "floors", &[0.9, 0.99])?,
            },
            algo: args.get_str("algo").unwrap_or("greedy").parse()?,
            metric: args.get_or("metric", MetricKind::Hessian)?,
            seed: args.get_or("seed", 0u64)?,
            trials: args.get_or("trials", METRIC_TRIALS)?,
            workers: args.get_or("workers", 1usize)?.max(1),
            target: args.get_or("target", 0.99f64)?,
            backend: parse_backend(args)?,
            checkpoint: args.get_str("checkpoint").map(PathBuf::from),
            resume: args.flag("resume"),
            out: args.get_str("out").map(PathBuf::from),
            abort_after: args.get_str("abort-after").map(str::parse).transpose()?,
            from_frontier: args.get_str("from-frontier").map(PathBuf::from),
            algo_explicit: args.get_str("algo").is_some(),
        };
        cmd.grid.validate()?;
        anyhow::ensure!(
            cmd.model.is_some() != cmd.synthetic.is_some(),
            "report needs exactly one of --model M or --synthetic N"
        );
        if cmd.agreement {
            // The agreement report runs every informed metric through
            // both algorithms at one accuracy target — the sweep-only
            // knobs (and any single-metric/-algo selection) don't apply.
            for flag in [
                "budget-kind",
                "budgets",
                "floors",
                "from-frontier",
                "checkpoint",
                "abort-after",
                "algo",
                "metric",
            ] {
                anyhow::ensure!(
                    args.get_str(flag).is_none(),
                    "--{flag} does not apply to --agreement reports"
                );
            }
            anyhow::ensure!(!cmd.resume, "--resume does not apply to --agreement reports");
            if cmd.synthetic.is_some() {
                anyhow::ensure!(
                    args.get_str("backend").is_none() && args.get_str("table").is_none(),
                    "--backend/--table do not apply to synthetic --agreement reports"
                );
            }
            return Ok(cmd);
        }
        anyhow::ensure!(
            args.get_str("target").is_none(),
            "--target only applies to --agreement reports (sweeps take --floors)"
        );
        anyhow::ensure!(
            cmd.abort_after.is_none() || cmd.synthetic.is_some(),
            "--abort-after only applies to --synthetic sweeps"
        );
        anyhow::ensure!(
            cmd.abort_after.is_none() || cmd.from_frontier.is_none(),
            "--abort-after does not apply to --from-frontier lookups (no cell runs a search)"
        );
        anyhow::ensure!(
            !cmd.resume || cmd.checkpoint.is_some(),
            "--resume requires a --checkpoint path"
        );
        anyhow::ensure!(
            cmd.from_frontier.is_none() || args.get_str("table").is_none(),
            "--table does not apply to --from-frontier lookups (cells are priced by the artifact)"
        );
        if cmd.synthetic.is_some() {
            // --table IS allowed with --synthetic: it prices the synthetic
            // model's shapes with a measured kernel table (the per-backend
            // Table-2 variant).
            for flag in ["metric", "trials", "backend"] {
                anyhow::ensure!(
                    args.get_str(flag).is_none(),
                    "--{flag} does not apply to --synthetic sweeps"
                );
            }
        }
        Ok(cmd)
    }

    /// The algorithm a `--from-frontier` sweep reports under: the
    /// artifact's own, with an explicit `--algo` acting as an assertion.
    fn frontier_algo(&self, artifact: &FrontierArtifact) -> Result<SearchAlgo> {
        if self.algo_explicit {
            anyhow::ensure!(
                self.algo == artifact.algo,
                "--algo {} does not match the frontier artifact (built with {})",
                self.algo.label(),
                artifact.algo.label()
            );
        }
        Ok(artifact.algo)
    }

    /// Answer the grid from a frontier artifact: zero searches, byte-
    /// identical output. The sweep checkpoint (if any) is fingerprinted
    /// on the *artifact's* fingerprint — which already pins the
    /// algorithm, floors, layer order, and environment — so frontier
    /// sweep logs never mix with re-searching sweep logs.
    fn run_from_frontier(mut self, artifact: &FrontierArtifact, label: &str) -> Result<()> {
        self.algo = self.frontier_algo(artifact)?;
        let mut ck = self.attach_checkpoint(&[], &artifact.fingerprint)?;
        let cells = budget_sweep_from_frontier(artifact, &self.grid, ck.as_mut())?;
        eprintln!("[sweep] answered {} cells from the frontier artifact (0 searches)", cells.len());
        self.emit(label, &cells)
    }

    /// Render + emit one finished sweep: the Table-2-style grid on stdout,
    /// a stable `RESULT` line for scripts (byte-identical across worker
    /// counts and across kill/resume), and optional `--out` artifacts.
    fn emit(&self, label: &str, cells: &[mpq::report::SweepCell]) -> Result<()> {
        let title = format!(
            "Budget x accuracy-floor sweep — {label} ({} budgets, {} guided)",
            self.grid.kind.label(),
            self.algo.label()
        );
        let table = render_sweep(&title, &self.grid, cells);
        println!("{}", table.render());
        ResultLine::new("report")
            .seed(self.seed)
            .algo(self.algo.label())
            .workers(self.workers)
            .payload(Value::Arr(cells.iter().map(|c| c.to_json()).collect()))
            .emit();
        if let Some(dir_out) = &self.out {
            std::fs::create_dir_all(dir_out)?;
            std::fs::write(dir_out.join(format!("sweep_{label}.txt")), table.render())?;
            std::fs::write(dir_out.join(format!("sweep_{label}.json")), sweep_cells_json(cells))?;
        }
        Ok(())
    }

    /// Attach the sweep checkpoint, fingerprint-bound to everything a
    /// cell result depends on: the algorithm/kind/grid/ordering (hashed in
    /// [`sweep_fingerprint`]) plus the caller-supplied environment context
    /// — resuming under a different metric, seed, cost backend, or model
    /// state must fail loudly, not mix incompatible cells.
    fn attach_checkpoint(
        &self,
        order: &[usize],
        env_context: &str,
    ) -> Result<Option<SweepCheckpoint>> {
        match &self.checkpoint {
            Some(path) => {
                let fp = sweep_fingerprint(self.algo, &self.grid, order, env_context);
                let ck = SweepCheckpoint::attach(path, &fp, self.resume)?;
                if ck.loaded() > 0 {
                    eprintln!("[sweep] resuming: {} cells already completed", ck.loaded());
                }
                Ok(Some(ck))
            }
            None => Ok(None),
        }
    }

    /// Artifact-backed sweep through the [`Driver`] front door:
    /// calibration, sensitivity ordering, and every cell's search all run
    /// on the session's context (its shared pool at `--workers > 1`).
    /// With `--from-frontier` no context is even opened: the grid is
    /// answered entirely from the artifact.
    fn run(self, dir: &Path) -> Result<()> {
        let model = self.model.clone().expect("checked in parse");
        if self.agreement {
            return self.run_agreement_model(dir, &model);
        }
        if let Some(path) = self.from_frontier.clone() {
            let artifact = FrontierArtifact::load(&path)?;
            return self.run_from_frontier(&artifact, &model);
        }
        let mut session = SearchSpec::new(model.as_str())
            .artifacts_dir(dir)
            .workers(self.workers)
            .algo(self.algo)
            .metric(self.metric)
            .trials(self.trials.max(1))
            .seed(self.seed)
            .backend(self.backend.clone())
            .open()?;
        let mut driver = Driver::new(&mut session);
        let cells = driver.sweep_with(&self.grid, |order, env_context| {
            self.attach_checkpoint(order, env_context)
        })?;
        self.emit(&model, &cells)
    }

    /// Artifact-free sweep over the seeded synthetic environment — the CI
    /// smoke path, including the kill (`--abort-after`) / `--resume` loop
    /// and the `--from-frontier` byte-identity check.
    fn run_synthetic(self) -> Result<()> {
        let layers = self.synthetic.expect("checked in parse");
        if self.agreement {
            let report = AgreementReport::synthetic(
                layers,
                self.trials.max(1),
                self.seed,
                self.workers,
                self.target,
            )?;
            return self.emit_agreement("synthetic", &report);
        }
        // The synthetic ordering is the identity permutation; layer count
        // and seed (which fully determine the environment) are in the
        // context string.
        let order: Vec<usize> = (0..layers).collect();
        if let Some(path) = self.from_frontier.clone() {
            let artifact = FrontierArtifact::load(&path)?;
            let algo = self.frontier_algo(&artifact)?;
            artifact.verify(algo, &order, &format!("synthetic/n{layers}/seed{}", self.seed))?;
            return self.run_from_frontier(&artifact, "synthetic");
        }
        // `--table kernels.json` swaps the synthetic roofline for a
        // measured kernel table over the synthetic manifest's shapes: the
        // per-backend Table-2 variant (see the checked-in `tables/`).
        if let BackendSpec::MeasuredTable(path) = self.backend.clone() {
            let cost = synthetic_table_cost(layers, &path)?;
            let backend = path.file_stem().and_then(|s| s.to_str()).unwrap_or("table").to_string();
            let env_context =
                format!("synthetic/n{layers}/seed{}/{}", self.seed, cost.provenance());
            let mut ck = self.attach_checkpoint(&order, &env_context)?;
            let cells = budget_sweep_synthetic_costed(
                layers,
                self.seed,
                self.workers,
                self.algo,
                &self.grid,
                cost,
                ck.as_mut(),
                self.abort_after,
            )?;
            return self.emit(&format!("synthetic_{backend}"), &cells);
        }
        let mut ck =
            self.attach_checkpoint(&order, &format!("synthetic/n{layers}/seed{}", self.seed))?;
        let cells = budget_sweep_synthetic(
            layers,
            self.seed,
            self.workers,
            self.algo,
            &self.grid,
            ck.as_mut(),
            self.abort_after,
        )?;
        self.emit("synthetic", &cells)
    }

    /// Artifact-backed agreement report: every informed metric through
    /// the context's disk-cached sensitivity path, every (algo, metric)
    /// cell through the shared pool at `--workers > 1`.
    fn run_agreement_model(self, dir: &Path, model: &str) -> Result<()> {
        let spec = SearchSpec::new(model)
            .artifacts_dir(dir)
            .workers(self.workers)
            .trials(self.trials.max(1))
            .seed(self.seed)
            .backend(self.backend.clone());
        let mut ctx = spec.open_context()?;
        let report =
            AgreementReport::for_model(&mut ctx, self.trials.max(1), self.seed, self.target)?;
        self.emit_agreement(model, &report)
    }

    /// Render + emit one agreement report: the human-readable summary on
    /// stdout, the worker-independent RESULT payload for scripts, and
    /// optional `--out` artifacts.
    fn emit_agreement(&self, label: &str, report: &AgreementReport) -> Result<()> {
        let text = report.render();
        println!("{text}");
        ResultLine::new("report")
            .seed(self.seed)
            .workers(self.workers)
            .payload(report.to_json())
            .emit();
        if let Some(dir_out) = &self.out {
            std::fs::create_dir_all(dir_out)?;
            std::fs::write(dir_out.join(format!("agreement_{label}.txt")), &text)?;
            std::fs::write(
                dir_out.join(format!("agreement_{label}.json")),
                report.to_json().to_string(),
            )?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------- pareto

/// `mpq pareto` — build the one-pass Pareto frontier artifact: one
/// accuracy-exhaustion search per `--floors` entry, emitted as
/// `<model>_frontier.json` so every later `report --sweep
/// --from-frontier` cell and `serve --frontier --pick` selection is an
/// O(1) read.
struct ParetoCmd {
    model: Option<String>,
    synthetic: Option<usize>,
    floors: Vec<f64>,
    algo: SearchAlgo,
    metric: MetricKind,
    seed: u64,
    trials: usize,
    workers: usize,
    backend: BackendSpec,
    checkpoint: Option<PathBuf>,
    resume: bool,
    out: Option<PathBuf>,
    /// Split each floor's search into K concurrently searched segments,
    /// composing per-segment trails into one frontier (1 = whole-model).
    partitions: usize,
    /// Synthetic only: error out after N decision evaluations (the CI
    /// kill/resume smoke).
    abort_after: Option<usize>,
    /// Stream the typed search-event stream to this JSONL file.
    events_out: Option<PathBuf>,
}

impl ParetoCmd {
    fn parse(args: &Args) -> Result<Self> {
        let cmd = Self {
            model: args.get_str("model").map(String::from),
            synthetic: args.get_str("synthetic").map(str::parse).transpose()?,
            floors: parse_f64_list(args, "floors", &[0.9, 0.99])?,
            algo: args.get_str("algo").unwrap_or("greedy").parse()?,
            metric: args.get_or("metric", MetricKind::Hessian)?,
            seed: args.get_or("seed", 0u64)?,
            trials: args.get_or("trials", METRIC_TRIALS)?,
            workers: args.get_or("workers", 1usize)?.max(1),
            backend: parse_backend(args)?,
            checkpoint: args.get_str("checkpoint").map(PathBuf::from),
            resume: args.flag("resume"),
            out: args.get_str("out").map(PathBuf::from),
            partitions: args.get_or("partitions", 1usize)?.max(1),
            abort_after: args.get_str("abort-after").map(str::parse).transpose()?,
            events_out: args.get_str("events-out").map(PathBuf::from),
        };
        anyhow::ensure!(
            cmd.model.is_some() != cmd.synthetic.is_some(),
            "pareto needs exactly one of --model M or --synthetic N"
        );
        anyhow::ensure!(
            cmd.abort_after.is_none() || cmd.synthetic.is_some(),
            "--abort-after only applies to --synthetic frontier builds"
        );
        anyhow::ensure!(
            !cmd.resume || cmd.checkpoint.is_some(),
            "--resume requires a --checkpoint path"
        );
        if cmd.synthetic.is_some() {
            for flag in ["metric", "trials", "backend"] {
                anyhow::ensure!(
                    args.get_str(flag).is_none(),
                    "--{flag} does not apply to --synthetic frontier builds"
                );
            }
        }
        Ok(cmd)
    }

    /// Stable single-line summary for scripts: artifact-derived fields
    /// only, so a fresh build and a kill/resumed one print the same
    /// `RESULT` line (build stats go to stderr).
    fn emit(&self, report: &FrontierReport, path: &Path) {
        eprintln!(
            "[frontier] built in {:.2}s: {} decision evals ({} replayed from checkpoint) -> {}",
            report.build_seconds,
            report.decision_evals,
            report.replayed_decisions,
            path.display()
        );
        let summary = Value::obj(vec![
            ("fingerprint", Value::Str(report.artifact.fingerprint.clone())),
            ("floors", Value::Num(report.artifact.trails.len() as f64)),
            ("points", Value::Num(report.artifact.num_points() as f64)),
            ("pareto", Value::Num(report.artifact.pareto().len() as f64)),
        ]);
        ResultLine::new("pareto")
            .seed(self.seed)
            .algo(self.algo.label())
            .workers(self.workers)
            .payload(summary)
            .emit();
    }

    /// Artifact-backed frontier build through
    /// [`mpq::api::SearchSession::run_pareto`]: calibration, sensitivity,
    /// and every floor's exhaustion search share the session's context,
    /// pool, and eval cache.
    fn run(self, dir: &Path) -> Result<()> {
        let model = self.model.clone().expect("checked in parse");
        let mut spec = SearchSpec::new(model.as_str())
            .artifacts_dir(dir)
            .workers(self.workers)
            .algo(self.algo)
            .metric(self.metric)
            .trials(self.trials.max(1))
            .seed(self.seed)
            .backend(self.backend.clone())
            .partitions(self.partitions)
            .resume(self.resume);
        if let Some(ck) = &self.checkpoint {
            spec = spec.checkpoint(ck);
        }
        let mut session = spec.open()?;
        session.on_event(log_event);
        let sink = match &self.events_out {
            Some(path) => {
                let sink = EventSink::create(path)?;
                session.on_event(sink.observer());
                Some(sink)
            }
            None => None,
        };
        let report = session.run_pareto(&self.floors)?;
        if let Some(sink) = &sink {
            let events = sink.finish()?;
            eprintln!("[events] {events} events -> {}", sink.path().display());
        }
        let path = match &self.out {
            // --out re-saves the identical artifact at the requested path
            // (the canonical copy stays next to the model artifacts).
            Some(out) => {
                report.artifact.save(out)?;
                out.clone()
            }
            None => report.path.clone().expect("run_pareto always persists"),
        };
        self.emit(&report, &path);
        Ok(())
    }

    /// Artifact-free frontier build over the seeded synthetic
    /// environment — the CI smoke path, including the kill
    /// (`--abort-after`) / `--resume` loop.
    fn run_synthetic(self) -> Result<()> {
        let layers = self.synthetic.expect("checked in parse");
        let sink = match &self.events_out {
            Some(path) => Some(EventSink::create(path)?),
            None => None,
        };
        let mut sink_obs = sink.as_ref().map(|s| s.observer());
        let mut observer = |ev: &SearchEvent| {
            log_event(ev);
            if let Some(record) = sink_obs.as_mut() {
                record(ev);
            }
        };
        // `--partitions 1` delegates straight to the monolithic builder
        // inside, so the default path (and its artifacts) are unchanged.
        let report = build_frontier_synthetic_partitioned(
            layers,
            self.seed,
            self.workers,
            self.algo,
            &self.floors,
            self.partitions,
            self.checkpoint.as_deref(),
            self.resume,
            self.abort_after,
            Some(&mut observer),
        )?;
        let path = self.out.clone().unwrap_or_else(|| PathBuf::from("synthetic_frontier.json"));
        report.artifact.save(&path)?;
        if let Some(sink) = &sink {
            let events = sink.finish()?;
            eprintln!("[events] {events} events -> {}", sink.path().display());
        }
        self.emit(&report, &path);
        Ok(())
    }
}

// ---------------------------------------------------------------- figure

struct FigureCmd {
    id: u32,
    model: Option<String>,
    out: Option<PathBuf>,
}

impl FigureCmd {
    fn parse(args: &Args) -> Result<Self> {
        Ok(Self {
            id: args.req::<u32>("id")?,
            model: args.get_str("model").map(String::from),
            out: args.get_str("out").map(PathBuf::from),
        })
    }

    fn run(self, dir: &Path) -> Result<()> {
        let models = all_models(dir, self.model.as_deref())?;
        let mut rendered = String::new();
        for m in &models {
            let mut ctx = ExperimentCtx::new(dir, m)?;
            let text = match self.id {
                1 => {
                    // Best (Hessian-greedy) cells at 99% and 99.9%.
                    let sens = ctx.cached_sensitivity(MetricKind::Hessian, METRIC_TRIALS, 0)?;
                    let mut cells = Vec::new();
                    for t in [0.99, 0.999] {
                        cells.push(experiments::run_cell(
                            &mut ctx,
                            SearchAlgo::Greedy,
                            &sens,
                            0,
                            t,
                        )?);
                    }
                    let float_acc = vec![(m.clone(), ctx.pipeline.float_val_acc())];
                    experiments::fig1(&cells, &float_acc).render()
                }
                3 => {
                    let sensh = ctx.cached_sensitivity(MetricKind::Hessian, METRIC_TRIALS, 0)?;
                    let mut cells = Vec::new();
                    for algo in [SearchAlgo::Bisection, SearchAlgo::Greedy] {
                        cells.push(experiments::run_cell(&mut ctx, algo, &sensh, 0, 0.99)?);
                    }
                    cells.push(experiments::run_cell(
                        &mut ctx,
                        SearchAlgo::Greedy,
                        &sensh,
                        0,
                        0.999,
                    )?);
                    let names: Vec<String> = ctx
                        .pipeline
                        .artifacts
                        .manifest
                        .quant_layers()
                        .iter()
                        .map(|l| l.name.clone())
                        .collect();
                    experiments::fig3(&cells, &names).render()
                }
                4 => {
                    let (curves, dist) = experiments::fig4(&mut ctx, 5)?;
                    format!("{}\n{}", curves.render(), dist.render())
                }
                _ => anyhow::bail!("unknown figure id {} (1, 3 or 4)", self.id),
            };
            println!("{text}");
            rendered.push_str(&text);
        }
        if let Some(dir_out) = &self.out {
            std::fs::create_dir_all(dir_out)?;
            std::fs::write(dir_out.join(format!("figure{}.txt", self.id)), rendered)?;
        }
        Ok(())
    }
}

// -------------------------------------------------------------- ablation

struct AblationCmd {
    model: String,
    target: f64,
    out: Option<PathBuf>,
}

impl AblationCmd {
    fn parse(args: &Args) -> Result<Self> {
        Ok(Self {
            model: args.req_str("model")?.to_string(),
            target: args.get_or("target", 0.99f64)?,
            out: args.get_str("out").map(PathBuf::from),
        })
    }

    fn run(self, dir: &Path) -> Result<()> {
        let mut session = SearchSpec::new(self.model.as_str()).artifacts_dir(dir).open()?;
        let mut driver = Driver::new(&mut session);
        let mut rendered = String::new();
        for table in driver.ablation(self.target)? {
            let text = table.render();
            println!("{text}");
            rendered.push_str(&text);
        }
        if let Some(dir_out) = &self.out {
            std::fs::create_dir_all(dir_out)?;
            std::fs::write(dir_out.join(format!("ablation_{}.txt", self.model)), rendered)?;
        }
        Ok(())
    }
}

// ----------------------------------------------------------------- serve

struct ServeCmd {
    model: String,
    bits: f32,
    requests: usize,
    concurrency: usize,
    /// Serve a frontier-picked mixed-precision config instead of a
    /// uniform bit-width.
    frontier: Option<PathBuf>,
    pick: Option<PickSpec>,
    /// Multi-tenant serving: one frontier pick per tenant, all tenants
    /// served concurrently from one warm pool.
    tenants: Option<Vec<TenantSpec>>,
    /// Admission priority for every generated request (higher pops
    /// first; ties stay FIFO).
    priority: i32,
    opts: mpq::server::ServeOptions,
}

impl ServeCmd {
    fn parse(args: &Args) -> Result<Self> {
        let deadline_ms = args.get_or("deadline-ms", 0u64)?;
        let cmd = Self {
            model: args.req_str("model")?.to_string(),
            bits: args.get_or("bits", 8.0f32)?,
            requests: args.get_or("requests", 256usize)?,
            concurrency: args.get_or("concurrency", 8usize)?.max(1),
            frontier: args.get_str("frontier").map(PathBuf::from),
            pick: args.get_str("pick").map(str::parse).transpose()?,
            tenants: args.get_str("tenants").map(parse_tenants).transpose()?,
            priority: args.get_or("priority", 0i32)?,
            opts: mpq::server::ServeOptions {
                max_batch: args.get_or("max-batch", 32usize)?,
                max_wait: std::time::Duration::from_micros(args.get_or("wait-us", 500u64)?),
                workers: args.get_or("workers", 2usize)?,
                queue_depth: args.get_or("queue-depth", 256usize)?,
                deadline: (deadline_ms > 0).then(|| std::time::Duration::from_millis(deadline_ms)),
                ..Default::default()
            },
        };
        anyhow::ensure!(
            cmd.pick.is_none() || cmd.frontier.is_some(),
            "--pick requires --frontier frontier.json"
        );
        anyhow::ensure!(
            args.get_str("bits").is_none() || cmd.frontier.is_none(),
            "--bits and --frontier are mutually exclusive (the frontier picks the config)"
        );
        anyhow::ensure!(
            cmd.tenants.is_none() || cmd.frontier.is_some(),
            "--tenants requires --frontier frontier.json"
        );
        anyhow::ensure!(
            cmd.tenants.is_none() || cmd.pick.is_none(),
            "--tenants and --pick are mutually exclusive (each tenant carries its own pick)"
        );
        Ok(cmd)
    }

    /// Drive the batched multi-worker server with concurrent clients and
    /// print latency percentiles — the QoS view the paper optimizes for.
    fn run(self, dir: &Path) -> Result<()> {
        let model = self.model.clone();
        let concurrency = self.concurrency;
        // Build the serving session through the front door: one context to
        // learn shapes, produce examples from val, and calibrate a single
        // time (persisting the scales). At --workers > 1 the calibrated
        // pool itself becomes the serving backend (no second pool build);
        // at 1 worker the single serving pool loads the persisted scales.
        let spec = SearchSpec::new(model.as_str()).artifacts_dir(dir).workers(self.opts.workers);
        let mut session = spec.open()?;
        session.ctx.ensure_calibrated()?;
        let n = session.ctx.pipeline.num_quant_layers();
        let val = &session.ctx.pipeline.artifacts.val;
        let val_count = val.count;
        let examples: Vec<mpq::runtime::HostTensor> =
            (0..self.requests).map(|i| val.x.slice_rows(i % val_count, 1)).collect();

        // Config selection: per-tenant frontier picks (one config per
        // tenant, all served from one warm pool), a single frontier pick
        // (best accuracy under the --pick constraints, straight from the
        // artifact — no search at serve time), or the uniform --bits
        // fallback.
        let mut tenant_labels: Vec<String> = Vec::new();
        let (configs, cfg_desc) = match (&self.frontier, &self.tenants) {
            (Some(path), Some(tenants)) => {
                let artifact = FrontierArtifact::load(path)?;
                let mut configs = Vec::new();
                for t in tenants {
                    let point = artifact.pick(&t.pick)?;
                    anyhow::ensure!(
                        point.config.bits_w.len() == n,
                        "frontier config has {} layers but {model} has {n}",
                        point.config.bits_w.len()
                    );
                    eprintln!(
                        "[serve] tenant {} ({}): accuracy={:.2}% rel_latency={:.2}%",
                        t.name,
                        t.pick.describe(),
                        point.accuracy * 100.0,
                        point.rel_latency * 100.0,
                    );
                    tenant_labels.push(t.name.clone());
                    configs.push(point.config.clone());
                }
                (configs, format!("{} tenant picks", tenants.len()))
            }
            (Some(path), None) => {
                let artifact = FrontierArtifact::load(path)?;
                let pick = self.pick.unwrap_or_default();
                let point = artifact.pick(&pick)?;
                anyhow::ensure!(
                    point.config.bits_w.len() == n,
                    "frontier config has {} layers but {model} has {n}",
                    point.config.bits_w.len()
                );
                eprintln!(
                    "[serve] frontier pick {}: accuracy={:.2}% rel_latency={:.2}% \
                     rel_size={:.2}% ({})",
                    pick.describe(),
                    point.accuracy * 100.0,
                    point.rel_latency * 100.0,
                    point.rel_size * 100.0,
                    point.cost_provenance,
                );
                (vec![point.config.clone()], "frontier pick".to_string())
            }
            (None, _) => {
                (vec![QuantConfig::uniform(n, self.bits)], format!("uniform {}b", self.bits))
            }
        };
        let (handle, join) = session.into_multi_server(configs, self.opts)?;

        let tenant_count = tenant_labels.len();
        let priority = self.priority;
        let t0 = std::time::Instant::now();
        std::thread::scope(|s| {
            for c in 0..concurrency {
                let handle = handle.clone();
                let examples = &examples;
                s.spawn(move || {
                    for (i, ex) in examples.iter().enumerate() {
                        if i % concurrency == c {
                            let opts = mpq::server::InferOptions {
                                priority,
                                config: (tenant_count > 0).then(|| (i % tenant_count) as u32),
                                ..Default::default()
                            };
                            let _ = handle.infer_with(ex.clone(), &opts);
                        }
                    }
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let stats = handle.stats();
        handle.shutdown();
        join.join().map_err(|_| anyhow::anyhow!("serve dispatcher panicked"))?;
        println!(
            "served {} requests in {wall:.2}s ({:.1} req/s) @ {cfg_desc} \
             x{concurrency} clients ({} batches)",
            stats.requests,
            stats.requests as f64 / wall,
            stats.batches,
        );
        println!(
            "latency: mean={:.1}ms p50={:.1}ms p95={:.1}ms p99={:.1}ms",
            stats.mean_us() / 1e3,
            stats.percentile_us(0.5) as f64 / 1e3,
            stats.percentile_us(0.95) as f64 / 1e3,
            stats.percentile_us(0.99) as f64 / 1e3,
        );
        println!(
            "admission: rejected={} deadline_missed={} errors={} max_queue_depth={}",
            stats.rejected, stats.deadline_missed, stats.errors, stats.max_queue_depth
        );
        for w in &stats.per_worker {
            println!(
                "worker {}: {} batches, {} requests, mean fill {:.2}",
                w.worker,
                w.batches,
                w.requests,
                w.mean_batch_fill()
            );
        }
        if stats.per_config.len() > 1 {
            for cs in &stats.per_config {
                let label = tenant_labels
                    .get(cs.config as usize)
                    .map(String::as_str)
                    .unwrap_or("config");
                println!(
                    "config {} ({label}): {} batches, {} requests",
                    cs.config, cs.batches, cs.requests
                );
            }
        }
        Ok(())
    }
}

// ------------------------------------------------------------ experiment

/// `mpq experiment run suite.yaml` — the declarative harness: execute
/// every suite variant through the search front door in isolated
/// artifact dirs (at ≥2 worker counts, bit-identity asserted), render
/// the comparison table, and gate against a checked-in baseline.
struct ExperimentCmd {
    suite: PathBuf,
    out: PathBuf,
    baseline: Option<PathBuf>,
    update_baseline: bool,
    record_measured: bool,
    bench: Vec<PathBuf>,
    band: f64,
    workers: Option<usize>,
}

impl ExperimentCmd {
    fn parse(args: &Args) -> Result<Self> {
        match args.positional(0) {
            Some("run") => {}
            Some(other) => {
                anyhow::bail!("unknown experiment subcommand `{other}` (expected `run`)")
            }
            None => {
                anyhow::bail!("usage: mpq experiment run <suite.yaml> [--out DIR] [--baseline FILE]")
            }
        }
        let suite = args
            .positional(1)
            .map(PathBuf::from)
            .ok_or_else(|| anyhow::anyhow!("experiment run needs a suite file operand"))?;
        if let Some(extra) = args.positional(2) {
            anyhow::bail!("unexpected operand `{extra}` after the suite file");
        }
        let cmd = Self {
            suite,
            out: PathBuf::from(args.get_str("out").unwrap_or("experiments_out")),
            baseline: args.get_str("baseline").map(PathBuf::from),
            update_baseline: args.flag("update-baseline"),
            record_measured: args.flag("record-measured"),
            bench: args
                .get_str("bench")
                .map(|s| s.split(',').filter(|p| !p.is_empty()).map(PathBuf::from).collect())
                .unwrap_or_default(),
            band: args.get_or("band", 2.0f64)?,
            workers: args.get_str("workers").map(str::parse).transpose()?,
        };
        anyhow::ensure!(cmd.band >= 1.0, "--band must be >= 1.0 (got {})", cmd.band);
        anyhow::ensure!(
            !cmd.update_baseline || cmd.baseline.is_some(),
            "--update-baseline requires --baseline FILE"
        );
        anyhow::ensure!(
            !cmd.record_measured || cmd.update_baseline,
            "--record-measured only applies with --update-baseline"
        );
        Ok(cmd)
    }

    fn run(self) -> Result<()> {
        let suite = ExperimentSuite::load(&self.suite)?;
        let opts = RunOptions { out_dir: self.out.clone(), workers_override: self.workers };
        let mut cmp = run_suite(&suite, &opts)?;
        cmp.bench = load_bench(&self.bench)?;
        let table = cmp.render();
        print!("{table}");
        std::fs::create_dir_all(&self.out)?;
        mpq::util::fs::atomic_write_text(
            &self.out.join("comparison.json"),
            &format!("{}\n", cmp.deterministic_json()),
        )?;
        std::fs::write(self.out.join("comparison.txt"), &table)?;
        let mut gate_report = None;
        if let Some(bpath) = &self.baseline {
            let prev = if bpath.is_file() { Some(Baseline::load(bpath)?) } else { None };
            if self.update_baseline {
                let updated = cmp.to_baseline(prev.as_ref(), self.record_measured);
                updated.save(bpath)?;
                eprintln!("[experiment] baseline updated -> {}", bpath.display());
            } else {
                let base = prev.ok_or_else(|| {
                    anyhow::anyhow!(
                        "baseline {} not found (create it with --update-baseline)",
                        bpath.display()
                    )
                })?;
                let report = gate(&cmp, &base, self.band);
                print!("{}", report.render());
                gate_report = Some(report);
            }
        }
        // The RESULT envelope is deliberately free of worker counts and
        // wall-time: CI byte-diffs it across `--workers 1` and `2`.
        ResultLine::new("experiment")
            .payload(Value::obj(vec![
                ("suite", Value::Str(cmp.suite.clone())),
                ("variants", Value::Num(cmp.rows.len() as f64)),
                ("digest", Value::Str(cmp.digest())),
                ("gate", match &gate_report {
                    None => Value::Null,
                    Some(r) => Value::obj(vec![
                        ("checked", Value::Num(r.checked as f64)),
                        ("violations", Value::Num(r.violations.len() as f64)),
                        ("flags", Value::Num(r.flags.len() as f64)),
                        ("passed", Value::Bool(r.passed())),
                    ]),
                }),
            ]))
            .emit();
        if let Some(r) = gate_report {
            anyhow::ensure!(
                r.passed(),
                "experiment regression gate failed: {} violation(s) (see report above)",
                r.violations.len()
            );
        }
        Ok(())
    }
}
