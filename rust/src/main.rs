//! `mpq` — the coordinator CLI.
//!
//! Everything the paper's evaluation does is reachable from here:
//!
//! ```text
//! mpq info                         # list exported models + baselines
//! mpq calibrate --model resnet_s   # two-step scale estimation
//! mpq eval --model resnet_s --bits 8
//! mpq sensitivity --model bert_s --metric hessian
//! mpq search --model bert_s --algo greedy --metric hessian --target 0.99
//! mpq table --id 1|2|3 [--model M] [--out DIR]   # regenerate paper tables
//! mpq figure --id 1|3|4 [--model M] [--out DIR]  # regenerate figure data
//! mpq serve --model resnet_s --bits 8 --requests 256
//! ```

use std::path::{Path, PathBuf};

use anyhow::Context;

use mpq::coordinator::SearchAlgo;
use mpq::model::ArtifactIndex;
use mpq::quant::{CalibrationOptions, QuantConfig, Scales};
use mpq::report::experiments::{
    self, render_search_table, search_grid, ExperimentCtx, METRIC_TRIALS,
};
use mpq::report::cells_to_json;
use mpq::sensitivity::{self, MetricKind};
use mpq::util::cli::Args;
use mpq::Result;

const USAGE: &str = "\
mpq — sensitivity-guided mixed-precision PTQ coordinator

USAGE: mpq <command> [options]

COMMANDS
  info                                       list exported models
  calibrate   --model M [--adjust-bits 8] [--lr 1e-5] [--epochs 2]
  eval        --model M [--bits 8]
  sensitivity --model M --metric random|qe|noise|hessian [--trials N] [--seed S]
  search      --model M [--algo greedy|bisection] [--metric hessian]
              [--target 0.99] [--seed 0]
  table       --id 1|2|3 [--model M] [--out DIR]
  figure      --id 1|3|4 [--model M] [--out DIR]
  ablation    --model M [--target 0.99] [--out DIR]
  serve       --model M [--bits 8] [--requests 256] [--concurrency 8]
              [--workers 2] [--queue-depth 256] [--deadline-ms 0]
              [--max-batch 32] [--wait-us 500]

GLOBAL
  --artifacts DIR    artifacts directory (default: $MPQ_ARTIFACTS or ./artifacts)
";

fn artifacts_dir(args: &Args) -> Result<PathBuf> {
    if let Some(d) = args.get_str("artifacts") {
        return Ok(PathBuf::from(d));
    }
    mpq::artifacts_dir()
        .ok_or_else(|| anyhow::anyhow!("no artifacts directory found — run `make artifacts` first"))
}

fn all_models(dir: &Path, only: Option<&str>) -> Result<Vec<String>> {
    let index = ArtifactIndex::load(dir)?;
    Ok(index
        .models
        .iter()
        .map(|m| m.model.clone())
        .filter(|m| only.is_none_or(|o| o == m))
        .collect())
}

fn parse_algo(s: &str) -> Result<SearchAlgo> {
    match s.to_ascii_lowercase().as_str() {
        "greedy" => Ok(SearchAlgo::Greedy),
        "bisection" => Ok(SearchAlgo::Bisection),
        other => anyhow::bail!("unknown algo `{other}` (greedy|bisection)"),
    }
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    if args.cmd.is_empty() || args.cmd == "help" || args.flag("help") {
        print!("{USAGE}");
        return Ok(());
    }
    let dir = artifacts_dir(&args)?;
    match args.cmd.as_str() {
        "info" => cmd_info(&dir),
        "calibrate" => cmd_calibrate(&dir, &args),
        "eval" => cmd_eval(&dir, &args),
        "sensitivity" => cmd_sensitivity(&dir, &args),
        "search" => cmd_search(&dir, &args),
        "table" => cmd_table(&dir, &args),
        "figure" => cmd_figure(&dir, &args),
        "ablation" => cmd_ablation(&dir, &args),
        "serve" => cmd_serve(&dir, &args),
        other => {
            eprint!("unknown command `{other}`\n\n{USAGE}");
            std::process::exit(2);
        }
    }
}

fn cmd_info(dir: &Path) -> Result<()> {
    let index = ArtifactIndex::load(dir)?;
    println!("artifacts: {} (schema v{})", dir.display(), index.version);
    for entry in &index.models {
        let ctx = ExperimentCtx::new(dir, &entry.model)?;
        let m = &ctx.pipeline.artifacts.manifest;
        println!(
            "  {:>10}: task={} layers={} (quant {}) eval_batch={} float acc={:.2}% \
             size(fp16)={:.2}MB latency(fp16)={:.3}ms",
            m.model,
            m.task,
            m.layers.len(),
            m.num_quant_layers,
            m.eval_batch,
            m.float_val_acc * 100.0,
            ctx.cost.base_size_mb(),
            ctx.cost.base_latency_ms(),
        );
    }
    Ok(())
}

fn cmd_calibrate(dir: &Path, args: &Args) -> Result<()> {
    let model = args.req_str("model")?;
    let mut ctx = ExperimentCtx::new(dir, model)?;
    let opts = CalibrationOptions {
        adjust_bits: args.get_or("adjust-bits", 8.0f32)?,
        lr: args.get_or("lr", 1e-5f32)?,
        epochs: args.get_or("epochs", 2usize)?,
    };
    let report = ctx.pipeline.calibrate(&opts)?;
    ctx.pipeline
        .scales
        .save(&dir.join(format!("{model}_scales.json")))
        .context("saving scales")?;
    println!(
        "calibrated {model}: adjustment loss {:.4} -> {:.4} over {} steps",
        report.loss_before, report.loss_after, report.steps
    );
    Ok(())
}

fn cmd_eval(dir: &Path, args: &Args) -> Result<()> {
    let model = args.req_str("model")?;
    let bits = args.get_or("bits", 8.0f32)?;
    let mut ctx = ExperimentCtx::new(dir, model)?;
    ctx.ensure_calibrated()?;
    let n = ctx.pipeline.num_quant_layers();
    let cfg = QuantConfig::uniform(n, bits);
    let r = ctx.pipeline.eval_config(&cfg, None)?;
    println!(
        "{model} @ uniform {bits}b: loss={:.4} accuracy={:.2}% (float {:.2}%) \
         rel_size={:.2}% rel_latency={:.2}%",
        r.loss,
        r.accuracy * 100.0,
        ctx.pipeline.float_val_acc() * 100.0,
        ctx.cost.rel_size(&cfg) * 100.0,
        ctx.cost.rel_latency(&cfg) * 100.0,
    );
    Ok(())
}

fn cmd_sensitivity(dir: &Path, args: &Args) -> Result<()> {
    let model = args.req_str("model")?;
    let metric: MetricKind = args.req("metric")?;
    let trials = args.get_or("trials", METRIC_TRIALS)?;
    let seed = args.get_or("seed", 0u64)?;
    let mut ctx = ExperimentCtx::new(dir, model)?;
    ctx.ensure_calibrated()?;
    let sens = sensitivity::compute(&mut ctx.pipeline, metric, trials, seed)?;
    let names: Vec<String> = ctx
        .pipeline
        .artifacts
        .manifest
        .quant_layers()
        .iter()
        .map(|l| l.name.clone())
        .collect();
    println!("{} sensitivity for {model} (least sensitive first):", metric.label());
    for &layer in &sens.order {
        println!("  {:>20}  score={:.4e}", names[layer], sens.scores[layer]);
    }
    Ok(())
}

fn cmd_search(dir: &Path, args: &Args) -> Result<()> {
    let model = args.req_str("model")?;
    let algo = parse_algo(args.get_str("algo").unwrap_or("greedy"))?;
    let metric: MetricKind = args.get_or("metric", MetricKind::Hessian)?;
    let target = args.get_or("target", 0.99f64)?;
    let seed = args.get_or("seed", 0u64)?;
    let mut ctx = ExperimentCtx::new(dir, model)?;
    ctx.ensure_calibrated()?;
    let sens = ctx.cached_sensitivity(metric, METRIC_TRIALS, seed)?;
    let cell = experiments::run_cell(&mut ctx, algo, &sens, seed, target)?;
    println!(
        "{model} {}/{} target {:.1}%: accuracy={:.2}% size={:.2}% latency={:.2}% \
         ({} evals, {:.1}s)",
        cell.algo.label(),
        cell.metric.label(),
        target * 100.0,
        cell.accuracy * 100.0,
        cell.rel_size_pct,
        cell.rel_latency_pct,
        cell.evals,
        cell.search_seconds,
    );
    let bits: Vec<u32> = cell.config.bits_w.iter().map(|&b| b as u32).collect();
    println!("per-layer bits: {bits:?}");
    let stats = ctx.pipeline.stats;
    println!(
        "pipeline: {} evals, {} cache hits, {} batch execs, {} early exits",
        stats.evals, stats.cache_hits, stats.batch_execs, stats.early_exits
    );
    Ok(())
}

fn cmd_table(dir: &Path, args: &Args) -> Result<()> {
    let id = args.req::<u32>("id")?;
    let out = args.get_str("out").map(PathBuf::from);
    let models = all_models(dir, args.get_str("model"))?;
    let mut rendered = String::new();
    for m in &models {
        let mut ctx = ExperimentCtx::new(dir, m)?;
        let text = match id {
            1 => experiments::table1(&mut ctx)?.render(),
            2 | 3 => {
                let targets: &[f64] = if id == 2 { &[0.99, 0.999] } else { &[0.90] };
                let cells = search_grid(&mut ctx, targets, 0)?;
                if let Some(dir_out) = &out {
                    std::fs::create_dir_all(dir_out)?;
                    let cell_path = dir_out.join(format!("table{id}_{m}.json"));
                    std::fs::write(cell_path, cells_to_json(&cells))?;
                }
                render_search_table(
                    &format!("Table {id} — {m} (relative to fp16 baseline)"),
                    &cells,
                    targets,
                )
                .render()
            }
            _ => anyhow::bail!("unknown table id {id} (1, 2 or 3)"),
        };
        println!("{text}");
        rendered.push_str(&text);
    }
    if let Some(dir_out) = &out {
        std::fs::create_dir_all(dir_out)?;
        std::fs::write(dir_out.join(format!("table{id}.txt")), rendered)?;
    }
    Ok(())
}

fn cmd_figure(dir: &Path, args: &Args) -> Result<()> {
    let id = args.req::<u32>("id")?;
    let out = args.get_str("out").map(PathBuf::from);
    let models = all_models(dir, args.get_str("model"))?;
    let mut rendered = String::new();
    for m in &models {
        let mut ctx = ExperimentCtx::new(dir, m)?;
        let text = match id {
            1 => {
                // Best (Hessian-greedy) cells at 99% and 99.9%.
                let sens = ctx.cached_sensitivity(MetricKind::Hessian, METRIC_TRIALS, 0)?;
                let mut cells = Vec::new();
                for t in [0.99, 0.999] {
                    cells.push(experiments::run_cell(&mut ctx, SearchAlgo::Greedy, &sens, 0, t)?);
                }
                let float_acc = vec![(m.clone(), ctx.pipeline.float_val_acc())];
                experiments::fig1(&cells, &float_acc).render()
            }
            3 => {
                let sensh = ctx.cached_sensitivity(MetricKind::Hessian, METRIC_TRIALS, 0)?;
                let mut cells = Vec::new();
                for algo in [SearchAlgo::Bisection, SearchAlgo::Greedy] {
                    cells.push(experiments::run_cell(&mut ctx, algo, &sensh, 0, 0.99)?);
                }
                cells.push(experiments::run_cell(&mut ctx, SearchAlgo::Greedy, &sensh, 0, 0.999)?);
                let names: Vec<String> = ctx
                    .pipeline
                    .artifacts
                    .manifest
                    .quant_layers()
                    .iter()
                    .map(|l| l.name.clone())
                    .collect();
                experiments::fig3(&cells, &names).render()
            }
            4 => {
                let (curves, dist) = experiments::fig4(&mut ctx, 5)?;
                format!("{}\n{}", curves.render(), dist.render())
            }
            _ => anyhow::bail!("unknown figure id {id} (1, 3 or 4)"),
        };
        println!("{text}");
        rendered.push_str(&text);
    }
    if let Some(dir_out) = &out {
        std::fs::create_dir_all(dir_out)?;
        std::fs::write(dir_out.join(format!("figure{id}.txt")), rendered)?;
    }
    Ok(())
}

fn cmd_ablation(dir: &Path, args: &Args) -> Result<()> {
    let model = args.req_str("model")?;
    let target = args.get_or("target", 0.99f64)?;
    let out = args.get_str("out").map(PathBuf::from);
    let mut ctx = ExperimentCtx::new(dir, model)?;
    let mut rendered = String::new();
    for table in [
        mpq::report::ablation::weight_only(&mut ctx, target)?,
        mpq::report::ablation::accelerators(&mut ctx)?,
        mpq::report::ablation::adjustment(dir, model)?,
    ] {
        let text = table.render();
        println!("{text}");
        rendered.push_str(&text);
    }
    if let Some(dir_out) = &out {
        std::fs::create_dir_all(dir_out)?;
        std::fs::write(dir_out.join(format!("ablation_{model}.txt")), rendered)?;
    }
    Ok(())
}

/// Drive the batched multi-worker server with concurrent clients and
/// print latency percentiles — the QoS view the paper optimizes for.
fn cmd_serve(dir: &Path, args: &Args) -> Result<()> {
    let model = args.req_str("model")?.to_string();
    let bits = args.get_or("bits", 8.0f32)?;
    let requests = args.get_or("requests", 256usize)?;
    let concurrency = args.get_or("concurrency", 8usize)?.max(1);
    let deadline_ms = args.get_or("deadline-ms", 0u64)?;
    let opts = mpq::server::ServeOptions {
        max_batch: args.get_or("max-batch", 32usize)?,
        max_wait: std::time::Duration::from_micros(args.get_or("wait-us", 500u64)?),
        workers: args.get_or("workers", 2usize)?,
        queue_depth: args.get_or("queue-depth", 256usize)?,
        deadline: (deadline_ms > 0).then(|| std::time::Duration::from_millis(deadline_ms)),
        ..Default::default()
    };

    // Build a pipeline once to learn shapes, produce examples from val,
    // and calibrate a single time (saving the scales file) — so the pool
    // workers below all load the same scales instead of each re-running
    // the full calibration pass.
    let mut ctx = ExperimentCtx::new(dir, &model)?;
    ctx.ensure_calibrated()?;
    let n = ctx.pipeline.num_quant_layers();
    let val_count = ctx.pipeline.artifacts.val.count;
    let examples: Vec<mpq::runtime::HostTensor> =
        (0..requests).map(|i| ctx.pipeline.artifacts.val.x.slice_rows(i % val_count, 1)).collect();
    drop(ctx);

    let cfg = QuantConfig::uniform(n, bits);
    let scales_path = dir.join(format!("{model}_scales.json"));
    let (handle, join) = mpq::server::spawn(
        dir.to_path_buf(),
        model.clone(),
        cfg,
        opts,
        move |p| {
            p.scales = Scales::load(&scales_path)?;
            p.sync_scales()
        },
    )?;

    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for c in 0..concurrency {
            let handle = handle.clone();
            let examples = &examples;
            s.spawn(move || {
                for (i, ex) in examples.iter().enumerate() {
                    if i % concurrency == c {
                        let _ = handle.infer(ex.clone());
                    }
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let stats = handle.stats();
    handle.shutdown();
    join.join().map_err(|_| anyhow::anyhow!("serve dispatcher panicked"))?;
    println!(
        "served {} requests in {wall:.2}s ({:.1} req/s) @ uniform {bits}b \
         x{concurrency} clients ({} batches)",
        stats.requests,
        stats.requests as f64 / wall,
        stats.batches,
    );
    println!(
        "latency: mean={:.1}ms p50={:.1}ms p95={:.1}ms p99={:.1}ms",
        stats.mean_us() / 1e3,
        stats.percentile_us(0.5) as f64 / 1e3,
        stats.percentile_us(0.95) as f64 / 1e3,
        stats.percentile_us(0.99) as f64 / 1e3,
    );
    println!(
        "admission: rejected={} deadline_missed={} errors={} max_queue_depth={}",
        stats.rejected, stats.deadline_missed, stats.errors, stats.max_queue_depth
    );
    for w in &stats.per_worker {
        println!(
            "worker {}: {} batches, {} requests, mean fill {:.2}",
            w.worker,
            w.batches,
            w.requests,
            w.mean_batch_fill()
        );
    }
    Ok(())
}
