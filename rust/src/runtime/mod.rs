//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! The interchange format is **HLO text**, not serialized `HloModuleProto`:
//! jax >= 0.5 emits protos with 64-bit instruction ids that the
//! xla_extension 0.5.1 behind the `xla` crate rejects; the text parser
//! reassigns ids and round-trips cleanly (see `python/compile/aot.py`).
//!
//! Hot-path design: arguments live as device-resident [`xla::PjRtBuffer`]s
//! (parameters, scales and validation batches are uploaded **once**), and
//! every execution goes through [`Executable::run`] with borrowed buffers —
//! the only per-call host↔device traffic is the tiny bits vectors that
//! change between configurations and the scalar outputs.

mod tensor;

pub use tensor::{BatchArena, HostTensor, TensorData, TensorView};

use std::path::Path;

use anyhow::{Context, Result};

/// A PJRT client plus compilation entry points.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    /// Create a CPU PJRT engine (the only backend in this environment).
    pub fn cpu() -> Result<Self> {
        // Silence the TFRT client's INFO chatter unless the user overrides.
        if std::env::var_os("TF_CPP_MIN_LOG_LEVEL").is_none() {
            std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
        }
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client })
    }

    /// Platform string, e.g. `"cpu"` — used in logs and reports.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it for this engine.
    pub fn compile_hlo_file(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, name: path.display().to_string() })
    }

    /// Upload an f32 tensor to the device.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload an i32 tensor to the device.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload a [`HostTensor`] (f32 or i32).
    pub fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        self.upload_view(&t.view())
    }

    /// Upload a borrowed [`TensorView`] — the zero-copy serving path:
    /// the device reads straight from the view's slice (a batch arena or
    /// a window into shared tensor storage), no owned tensor is built.
    pub fn upload_view(&self, v: &TensorView<'_>) -> Result<xla::PjRtBuffer> {
        match v.data() {
            TensorData::F32(d) => self.upload_f32(d, v.dims()),
            TensorData::I32(d) => self.upload_i32(d, v.dims()),
        }
    }
}

/// A compiled artifact. All AOT graphs are lowered with `return_tuple=True`,
/// so execution returns one tuple buffer that [`Executable::run`] flattens
/// into per-output host literals.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Executable {
    /// Execute with device-resident arguments; fetch all outputs to host.
    pub fn run(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let outs = self
            .exe
            .execute_b(args)
            .with_context(|| format!("executing {}", self.name))?;
        let tuple = outs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching outputs of {}", self.name))?;
        Ok(tuple.to_tuple()?)
    }

    /// Name (artifact path) of this executable, for logs.
    pub fn name(&self) -> &str {
        &self.name
    }
}

/// Read back a scalar f32 output.
pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

/// Read back an f32 vector output.
pub fn vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}
