//! Minimal host-side tensor used at the Rust/PJRT boundary.

/// A dense host tensor, either f32 or i32 — the only dtypes crossing the
/// AOT boundary in this system.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32 { data: Vec<f32>, dims: Vec<usize> },
    I32 { data: Vec<i32>, dims: Vec<usize> },
}

impl HostTensor {
    pub fn f32(data: Vec<f32>, dims: Vec<usize>) -> Self {
        debug_assert_eq!(data.len(), dims.iter().product::<usize>());
        HostTensor::F32 { data, dims }
    }

    pub fn i32(data: Vec<i32>, dims: Vec<usize>) -> Self {
        debug_assert_eq!(data.len(), dims.iter().product::<usize>());
        HostTensor::I32 { data, dims }
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            HostTensor::F32 { dims, .. } | HostTensor::I32 { dims, .. } => dims,
        }
    }

    pub fn numel(&self) -> usize {
        self.dims().iter().product()
    }

    /// Leading-axis slice `[start, start+len)` — used for batching.
    /// The row stride is the product of the trailing dims.
    pub fn slice_rows(&self, start: usize, len: usize) -> HostTensor {
        let dims = self.dims();
        assert!(!dims.is_empty() && start + len <= dims[0], "slice out of range");
        let stride: usize = dims[1..].iter().product::<usize>().max(1);
        let mut new_dims = dims.to_vec();
        new_dims[0] = len;
        match self {
            HostTensor::F32 { data, .. } => HostTensor::F32 {
                data: data[start * stride..(start + len) * stride].to_vec(),
                dims: new_dims,
            },
            HostTensor::I32 { data, .. } => HostTensor::I32 {
                data: data[start * stride..(start + len) * stride].to_vec(),
                dims: new_dims,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_rows_f32() {
        let t = HostTensor::f32((0..12).map(|i| i as f32).collect(), vec![4, 3]);
        let s = t.slice_rows(1, 2);
        assert_eq!(s.dims(), &[2, 3]);
        match s {
            HostTensor::F32 { data, .. } => assert_eq!(data, vec![3., 4., 5., 6., 7., 8.]),
            _ => panic!(),
        }
    }

    #[test]
    fn slice_rows_1d_labels() {
        let t = HostTensor::i32(vec![7, 8, 9, 10], vec![4]);
        let s = t.slice_rows(2, 2);
        assert_eq!(s.dims(), &[2]);
        match s {
            HostTensor::I32 { data, .. } => assert_eq!(data, vec![9, 10]),
            _ => panic!(),
        }
    }

    #[test]
    #[should_panic(expected = "slice out of range")]
    fn slice_rows_oob_panics() {
        HostTensor::f32(vec![0.0; 6], vec![2, 3]).slice_rows(1, 2);
    }
}
