//! Host-side tensors at the Rust/PJRT boundary.
//!
//! Storage is `Arc`-backed and immutable: cloning a [`HostTensor`] or
//! slicing rows off one ([`HostTensor::slice_rows`]) bumps a refcount and
//! adjusts an offset — it never copies elements. The serving data plane
//! builds on two zero-copy primitives on top of that:
//!
//! * [`TensorView`] / [`HostTensor::view_rows`] — a borrowed, dtype-tagged
//!   window (elements + dims) the execution path consumes directly; device
//!   uploads read straight from the view.
//! * [`BatchArena`] — a reusable batch-assembly buffer: request rows are
//!   written exactly once into a retained allocation (zero-padded to the
//!   batch bucket), so steady-state batch formation performs no per-request
//!   `to_vec` and no per-batch re-concatenation or allocation.

use std::sync::Arc;

/// Shared immutable element storage behind [`HostTensor`].
#[derive(Clone, Debug)]
enum Storage {
    F32(Arc<[f32]>),
    I32(Arc<[i32]>),
}

/// A dense host tensor, either f32 or i32 — the only dtypes crossing the
/// AOT boundary in this system. `clone` and [`HostTensor::slice_rows`] are
/// O(1): the element storage is shared, never copied.
#[derive(Clone, Debug)]
pub struct HostTensor {
    storage: Storage,
    /// Element offset of this tensor's first element within `storage`.
    offset: usize,
    dims: Vec<usize>,
}

/// Borrowed, dtype-tagged elements of a tensor, view, or arena buffer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TensorData<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl TensorData<'_> {
    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(d) => d.len(),
            TensorData::I32(d) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A borrowed view (elements + dims) of tensor data — what the execution
/// hot path consumes. Views are produced without copying by
/// [`HostTensor::view`], [`HostTensor::view_rows`] and
/// [`BatchArena::assemble`]; device uploads read the borrowed slice
/// directly.
#[derive(Clone, Debug)]
pub struct TensorView<'a> {
    data: TensorData<'a>,
    dims: Vec<usize>,
}

impl<'a> TensorView<'a> {
    pub fn new(data: TensorData<'a>, dims: Vec<usize>) -> Self {
        debug_assert_eq!(data.len(), dims.iter().product::<usize>().max(1));
        Self { data, dims }
    }

    pub fn data(&self) -> TensorData<'a> {
        self.data
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

impl HostTensor {
    pub fn f32(data: Vec<f32>, dims: Vec<usize>) -> Self {
        debug_assert_eq!(data.len(), dims.iter().product::<usize>());
        Self { storage: Storage::F32(data.into()), offset: 0, dims }
    }

    pub fn i32(data: Vec<i32>, dims: Vec<usize>) -> Self {
        debug_assert_eq!(data.len(), dims.iter().product::<usize>());
        Self { storage: Storage::I32(data.into()), offset: 0, dims }
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }

    /// This tensor's elements, dtype-tagged. Borrowed straight from the
    /// shared storage — no copy.
    pub fn data(&self) -> TensorData<'_> {
        let n = self.numel();
        match &self.storage {
            Storage::F32(d) => TensorData::F32(&d[self.offset..self.offset + n]),
            Storage::I32(d) => TensorData::I32(&d[self.offset..self.offset + n]),
        }
    }

    /// The elements as f32, if this is an f32 tensor.
    pub fn f32_data(&self) -> Option<&[f32]> {
        match self.data() {
            TensorData::F32(d) => Some(d),
            TensorData::I32(_) => None,
        }
    }

    /// The elements as i32, if this is an i32 tensor.
    pub fn i32_data(&self) -> Option<&[i32]> {
        match self.data() {
            TensorData::I32(d) => Some(d),
            TensorData::F32(_) => None,
        }
    }

    pub fn is_i32(&self) -> bool {
        matches!(self.storage, Storage::I32(_))
    }

    /// Borrowed view of the whole tensor.
    pub fn view(&self) -> TensorView<'_> {
        TensorView { data: self.data(), dims: self.dims.clone() }
    }

    /// Borrowed leading-axis view `[start, start+len)` — the zero-copy
    /// form of [`HostTensor::slice_rows`]. The row stride is the product
    /// of the trailing dims.
    pub fn view_rows(&self, start: usize, len: usize) -> TensorView<'_> {
        let (lo, n, dims) = self.row_range(start, len);
        let data = match &self.storage {
            Storage::F32(d) => TensorData::F32(&d[lo..lo + n]),
            Storage::I32(d) => TensorData::I32(&d[lo..lo + n]),
        };
        TensorView { data, dims }
    }

    /// Leading-axis slice `[start, start+len)` — used for batching. O(1):
    /// the returned tensor shares this tensor's storage at an offset.
    pub fn slice_rows(&self, start: usize, len: usize) -> HostTensor {
        let (lo, _, dims) = self.row_range(start, len);
        Self { storage: self.storage.clone(), offset: lo, dims }
    }

    /// Bounds-checked `(start element, element count, sliced dims)` for a
    /// `[start, start+len)` row window.
    fn row_range(&self, start: usize, len: usize) -> (usize, usize, Vec<usize>) {
        let dims = &self.dims;
        assert!(!dims.is_empty() && start + len <= dims[0], "slice out of range");
        let stride: usize = dims[1..].iter().product::<usize>().max(1);
        let mut new_dims = dims.clone();
        new_dims[0] = len;
        (self.offset + start * stride, len * stride, new_dims)
    }
}

/// Structural equality: dtype, dims and element values (offsets and
/// storage sharing are invisible).
impl PartialEq for HostTensor {
    fn eq(&self, other: &Self) -> bool {
        self.dims == other.dims && self.data() == other.data()
    }
}

/// Reusable batch-assembly arena. [`BatchArena::assemble`] stacks request
/// rows into a retained buffer, zero-pads to the batch bucket, and hands
/// back a borrowed [`TensorView`] — each request payload is written
/// exactly once, and steady-state assembly allocates nothing beyond the
/// first (largest-bucket) call.
#[derive(Debug, Default)]
pub struct BatchArena {
    f32_buf: Vec<f32>,
    i32_buf: Vec<i32>,
}

impl BatchArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Stack `examples` (leading dim 1 each, trailing dims `x_shape`) and
    /// zero-pad to `batch` rows; dtype follows the examples. The returned
    /// view has dims `[batch, x_shape...]` and borrows the arena, so it
    /// must be dropped before the next `assemble`.
    pub fn assemble(
        &mut self,
        examples: &[HostTensor],
        x_shape: &[usize],
        batch: usize,
    ) -> TensorView<'_> {
        assert!(!examples.is_empty() && examples.len() <= batch, "batch arena overflow");
        let per: usize = x_shape.iter().product::<usize>().max(1);
        let mut dims = vec![batch];
        dims.extend_from_slice(x_shape);
        if examples[0].is_i32() {
            let data = fill_rows(&mut self.i32_buf, examples, HostTensor::i32_data, per, batch);
            TensorView { data: TensorData::I32(data), dims }
        } else {
            let data = fill_rows(&mut self.f32_buf, examples, HostTensor::f32_data, per, batch);
            TensorView { data: TensorData::F32(data), dims }
        }
    }
}

/// Write each example's row into `buf` and zero the padding tail. Only
/// grows the buffer; retained capacity makes repeat batches allocation-free.
fn fill_rows<'b, T: Copy + Default>(
    buf: &'b mut Vec<T>,
    examples: &[HostTensor],
    row: impl Fn(&HostTensor) -> Option<&[T]>,
    per: usize,
    batch: usize,
) -> &'b [T] {
    buf.resize(batch * per, T::default());
    for (i, e) in examples.iter().enumerate() {
        if let Some(d) = row(e) {
            buf[i * per..(i + 1) * per].copy_from_slice(d);
        }
    }
    // Rows 0..len were overwritten above; only the tail needs zeroing
    // (it may hold data from a previous, fuller batch).
    buf[examples.len() * per..batch * per].fill(T::default());
    &buf[..batch * per]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_rows_f32() {
        let t = HostTensor::f32((0..12).map(|i| i as f32).collect(), vec![4, 3]);
        let s = t.slice_rows(1, 2);
        assert_eq!(s.dims(), &[2, 3]);
        assert_eq!(s.f32_data().unwrap(), &[3., 4., 5., 6., 7., 8.]);
    }

    #[test]
    fn slice_rows_1d_labels() {
        let t = HostTensor::i32(vec![7, 8, 9, 10], vec![4]);
        let s = t.slice_rows(2, 2);
        assert_eq!(s.dims(), &[2]);
        assert_eq!(s.i32_data().unwrap(), &[9, 10]);
    }

    #[test]
    #[should_panic(expected = "slice out of range")]
    fn slice_rows_oob_panics() {
        HostTensor::f32(vec![0.0; 6], vec![2, 3]).slice_rows(1, 2);
    }

    #[test]
    fn slice_rows_shares_storage() {
        let t = HostTensor::f32((0..12).map(|i| i as f32).collect(), vec![4, 3]);
        let s = t.slice_rows(1, 2);
        // Zero-copy: the slice's elements alias the parent's storage.
        let parent = t.f32_data().unwrap();
        assert!(std::ptr::eq(&parent[3], &s.f32_data().unwrap()[0]));
        // Slices of slices compose.
        let s2 = s.slice_rows(1, 1);
        assert_eq!(s2.f32_data().unwrap(), &[6., 7., 8.]);
        assert_eq!(t, t.clone());
        assert_eq!(s.slice_rows(0, 2), s);
    }

    #[test]
    fn view_rows_borrows_without_copy() {
        let t = HostTensor::i32(vec![1, 2, 3, 4, 5, 6], vec![3, 2]);
        let v = t.view_rows(1, 2);
        assert_eq!(v.dims(), &[2, 2]);
        assert_eq!(v.numel(), 4);
        match v.data() {
            TensorData::I32(d) => {
                assert_eq!(d, &[3, 4, 5, 6]);
                assert!(std::ptr::eq(&t.i32_data().unwrap()[2], &d[0]));
            }
            TensorData::F32(_) => panic!("dtype preserved"),
        }
        assert_eq!(t.view().dims(), t.dims());
    }

    #[test]
    fn arena_matches_fresh_padding_and_reuses_buffer() {
        let mut arena = BatchArena::new();
        let a = HostTensor::f32(vec![1.0, 2.0], vec![1, 2]);
        let b = HostTensor::f32(vec![3.0, 4.0], vec![1, 2]);
        {
            let v = arena.assemble(&[a.clone(), b], &[2], 4);
            assert_eq!(v.dims(), &[4, 2]);
            match v.data() {
                TensorData::F32(d) => {
                    assert_eq!(d, &[1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
                }
                TensorData::I32(_) => panic!(),
            }
        }
        // A smaller follow-up batch must not see rows from the first one.
        let v = arena.assemble(&[a], &[2], 2);
        match v.data() {
            TensorData::F32(d) => assert_eq!(d, &[1.0, 2.0, 0.0, 0.0]),
            TensorData::I32(_) => panic!(),
        }
    }

    #[test]
    fn arena_handles_i32_examples() {
        let mut arena = BatchArena::new();
        let a = HostTensor::i32(vec![7, 8], vec![1, 2]);
        let v = arena.assemble(&[a], &[2], 2);
        assert_eq!(v.dims(), &[2, 2]);
        match v.data() {
            TensorData::I32(d) => assert_eq!(d, &[7, 8, 0, 0]),
            TensorData::F32(_) => panic!(),
        }
    }
}
