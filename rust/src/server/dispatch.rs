//! Batch formation and fan-out across serving workers.
//!
//! The dispatcher thread pulls request batches from the
//! [`super::queue::SubmitQueue`], picks the smallest compiled batch-size
//! bucket covering the batch, and hands the work to the least-loaded
//! worker of a [`ServingBackend`]. In-flight batches per worker are
//! bounded by [`InflightGate`], so saturated workers push backpressure
//! into the submission queue — which is where admission control and
//! deadline expiry live. Because a blocked dispatcher can hold a batch
//! past its deadline, expiry is re-checked after the gate and before
//! submission: expired requests are answered and dropped from the batch
//! rather than executed. As a last line, [`BatchJob`] re-checks each
//! request's deadline when delivering results, so an `Ok` response is
//! never a late success even if the deadline passed while the batch sat
//! in a worker's channel.

use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::ensure;

use crate::quant::QuantConfig;
use crate::runtime::HostTensor;
use crate::Result;

use super::queue::{Request, SubmitQueue};
use super::stats::ServeRecorder;
use super::ConfigTable;

/// A set of serving workers the dispatcher can fan batches across.
///
/// Implementations run each submitted [`BatchJob`] on the given worker
/// (typically on a thread owning a device pipeline) and MUST ensure
/// [`BatchJob::complete`] is eventually called — dropping a job answers
/// its requests with an error, so even a lost job cannot hang clients.
/// Dropping the backend must block until in-flight jobs finish: that is
/// what makes [`crate::server::ServerHandle::shutdown`] a drain.
pub trait ServingBackend: Send + 'static {
    fn num_workers(&self) -> usize;

    /// Compiled batch-size buckets, in any order; the engine validates and
    /// sorts them once at startup (an unsorted manifest must not shrink
    /// the effective batch cap).
    fn batch_sizes(&self) -> Vec<usize>;

    /// Hand a formed batch to worker `w` (`w < num_workers()`).
    fn submit(&mut self, w: usize, job: BatchJob);
}

/// Sort, dedup and validate backend-reported batch sizes.
pub(crate) fn normalize_batch_sizes(raw: &[usize]) -> Result<Vec<usize>> {
    let mut sizes: Vec<usize> = raw.iter().copied().filter(|&s| s > 0).collect();
    sizes.sort_unstable();
    sizes.dedup();
    ensure!(!sizes.is_empty(), "backend reports no serving batch sizes");
    Ok(sizes)
}

/// Smallest compiled bucket covering `n` requests (`sizes` ascending).
pub(crate) fn bucket_for(sizes: &[usize], n: usize) -> usize {
    *sizes.iter().find(|&&s| s >= n).unwrap_or_else(|| sizes.last().expect("non-empty"))
}

/// Per-worker in-flight batch counters with a shared limit. `acquire`
/// blocks until some worker has a free slot and returns the least-loaded
/// one; `release` is called from worker threads as batches complete.
#[derive(Debug)]
pub(crate) struct InflightGate {
    counts: Mutex<Vec<usize>>,
    cond: Condvar,
    limit: usize,
}

impl InflightGate {
    pub fn new(workers: usize, limit: usize) -> Self {
        Self {
            counts: Mutex::new(vec![0; workers.max(1)]),
            cond: Condvar::new(),
            limit: limit.max(1),
        }
    }

    pub fn acquire(&self) -> usize {
        let mut counts = self.counts.lock().unwrap();
        loop {
            let mut best = 0;
            for (i, &c) in counts.iter().enumerate() {
                if c < counts[best] {
                    best = i;
                }
            }
            if counts[best] < self.limit {
                counts[best] += 1;
                return best;
            }
            counts = self.cond.wait(counts).unwrap();
        }
    }

    pub fn release(&self, worker: usize) {
        let mut counts = self.counts.lock().unwrap();
        counts[worker] = counts[worker].saturating_sub(1);
        drop(counts);
        self.cond.notify_one();
    }
}

/// A formed batch travelling from the dispatcher to a worker.
///
/// Completing (or dropping) the job answers every request, records stats
/// on the owning worker's shard, and frees the worker's in-flight slot.
pub struct BatchJob {
    xs: Vec<HostTensor>,
    bucket: usize,
    /// Serving config id this batch was formed for (batches never mix
    /// configs — see [`super::queue::SubmitQueue::next_batch`]).
    config: u32,
    /// Config-table version at dispatch time: a swap after dispatch does
    /// not retarget this batch, which is what makes swaps drain-free.
    version: u64,
    /// The resolved configuration, shared with the table.
    cfg: Arc<QuantConfig>,
    state: Option<JobState>,
}

/// Response channel paired with the request's enqueue time and deadline.
type RespSlot = (mpsc::Sender<Result<Vec<f32>>>, Instant, Option<Instant>);

struct JobState {
    resp: Vec<RespSlot>,
    worker: usize,
    recorder: Arc<ServeRecorder>,
    gate: Arc<InflightGate>,
    queue: Arc<SubmitQueue>,
}

impl BatchJob {
    /// The live examples (leading dim 1 each); `len() <= bucket()`.
    pub fn xs(&self) -> &[HostTensor] {
        &self.xs
    }

    /// Compiled batch size the examples must be padded to.
    pub fn bucket(&self) -> usize {
        self.bucket
    }

    /// Serving config id this batch executes under.
    pub fn config_id(&self) -> u32 {
        self.config
    }

    /// Config-table version resolved at dispatch time.
    pub fn config_version(&self) -> u64 {
        self.version
    }

    /// The quantization configuration this batch executes under.
    pub fn config(&self) -> &QuantConfig {
        &self.cfg
    }

    /// Deliver a flat output vector covering all `bucket()` rows (or an
    /// execution error) to every requester.
    pub fn complete(mut self, result: Result<Vec<f32>>) {
        self.finish(result);
    }

    /// Run the real serving path: assemble the batch zero-copy in the
    /// pipeline's arena, execute the `logits` graph under this job's
    /// config (bits buffers cached per `(config, version)` on the
    /// worker), scatter per-request outputs.
    pub fn run_logits(self, pipeline: &mut crate::coordinator::Pipeline) {
        let key = (self.config, self.version);
        let cfg = self.cfg.clone();
        let result = pipeline.logits_rows(key, &cfg, self.xs(), self.bucket());
        self.complete(result);
    }

    fn finish(&mut self, result: Result<Vec<f32>>) {
        let Some(st) = self.state.take() else { return };
        let now = Instant::now();
        let lats: Vec<u64> = st
            .resp
            .iter()
            .map(|(_, t, _)| now.saturating_duration_since(*t).as_micros() as u64)
            .collect();
        // A deadline that passed while the batch sat in the worker's
        // channel (or executed) must not surface as a late success: an
        // `Ok` is always within deadline.
        let late: Vec<bool> =
            st.resp.iter().map(|(_, _, d)| d.is_some_and(|d| d <= now)).collect();
        let errors = if result.is_ok() {
            late.iter().filter(|&&l| l).count()
        } else {
            st.resp.len()
        };
        // Record before answering: a caller that reads `stats()` the
        // moment its response arrives must already see this batch.
        st.recorder.record_batch(st.worker, &lats, errors);
        st.recorder.note_config(self.config, st.resp.len());
        match result {
            Ok(flat) => {
                let per = flat.len() / self.bucket.max(1);
                for (i, (tx, _, _)) in st.resp.iter().enumerate() {
                    if late[i] {
                        st.queue.note_expired();
                        let _ = tx
                            .send(Err(anyhow::anyhow!("deadline exceeded during execution")));
                    } else {
                        let _ = tx.send(Ok(flat[i * per..(i + 1) * per].to_vec()));
                    }
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for (tx, _, _) in &st.resp {
                    let _ = tx.send(Err(anyhow::anyhow!("{msg}")));
                }
            }
        }
        st.gate.release(st.worker);
    }
}

impl Drop for BatchJob {
    fn drop(&mut self) {
        // Backend dropped the job without completing it: answer the
        // requests and free the slot so nothing hangs.
        self.finish(Err(anyhow::anyhow!("batch dropped by serving backend")));
    }
}

/// The dispatcher loop state; owns the backend for its whole life.
pub(crate) struct Dispatcher<B: ServingBackend> {
    pub backend: B,
    pub queue: Arc<SubmitQueue>,
    pub recorder: Arc<ServeRecorder>,
    pub gate: Arc<InflightGate>,
    /// Serving config table; each batch resolves its `(version, config)`
    /// here at dispatch time, so a swap retargets only later batches.
    pub table: Arc<ConfigTable>,
    /// Normalized ascending compiled batch sizes.
    pub sizes: Vec<usize>,
    /// Max live requests folded into one batch.
    pub batch_cap: usize,
    pub max_wait: Duration,
}

impl<B: ServingBackend> Dispatcher<B> {
    pub fn run(mut self) {
        // If the loop unwinds (a panicking ServingBackend impl — the
        // trait is public), close the queue and answer everything still
        // queued: blocked and future `infer` calls must error out, not
        // hang forever. On the normal exit path the queue is already
        // closed and drained, so the guard is a no-op beyond `close`.
        struct FailPending(Arc<SubmitQueue>);
        impl Drop for FailPending {
            fn drop(&mut self) {
                self.0.fail_pending("serving dispatcher died");
            }
        }
        let _guard = FailPending(self.queue.clone());
        while let Some((config, batch)) = self.queue.next_batch(self.batch_cap, self.max_wait) {
            self.dispatch(config, batch);
        }
        // Queue closed and drained. Dropping the backend joins the worker
        // threads after their channels drain, so in-flight batches still
        // complete before the dispatcher thread (and thus `join`) returns.
    }

    fn dispatch(&mut self, config: u32, batch: Vec<Request>) {
        let worker = self.gate.acquire();
        // The gate may have blocked on saturated workers; re-check
        // deadlines so stale requests are answered, not executed.
        let now = Instant::now();
        let mut live = Vec::with_capacity(batch.len());
        for req in batch {
            if req.deadline.is_some_and(|d| d <= now) {
                self.queue.expire(req);
            } else {
                live.push(req);
            }
        }
        if live.is_empty() {
            self.gate.release(worker);
            return;
        }
        let bucket = bucket_for(&self.sizes, live.len());
        let mut xs = Vec::with_capacity(live.len());
        let mut resp = Vec::with_capacity(live.len());
        for req in live {
            xs.push(req.x);
            resp.push((req.resp, req.enqueued, req.deadline));
        }
        // Resolve the config NOW: the batch is pinned to this version for
        // its whole life, so a concurrent swap never retargets it.
        let (version, cfg) = self.table.resolve(config);
        let job = BatchJob {
            xs,
            bucket,
            config,
            version,
            cfg,
            state: Some(JobState {
                resp,
                worker,
                recorder: self.recorder.clone(),
                gate: self.gate.clone(),
                queue: self.queue.clone(),
            }),
        };
        self.backend.submit(worker, job);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unsorted_sizes_are_normalized() {
        // The pre-rework serve loop trusted manifest order and took
        // `last()` as the max — an unsorted list silently capped batches
        // at 8 and could trip `pad_batch`'s size assert.
        let sizes = normalize_batch_sizes(&[32, 8, 16, 8, 0]).unwrap();
        assert_eq!(sizes, vec![8, 16, 32]);
        assert!(normalize_batch_sizes(&[]).is_err());
        assert!(normalize_batch_sizes(&[0]).is_err());
    }

    #[test]
    fn bucket_picks_smallest_cover() {
        let sizes = normalize_batch_sizes(&[32, 8, 16]).unwrap();
        assert_eq!(bucket_for(&sizes, 1), 8);
        assert_eq!(bucket_for(&sizes, 8), 8);
        assert_eq!(bucket_for(&sizes, 9), 16);
        assert_eq!(bucket_for(&sizes, 32), 32);
        // Oversized batches clamp to the true max, not the list tail.
        assert_eq!(bucket_for(&sizes, 33), 32);
    }

    #[test]
    fn gate_prefers_least_loaded_and_blocks_at_limit() {
        let gate = Arc::new(InflightGate::new(2, 2));
        assert_eq!(gate.acquire(), 0);
        assert_eq!(gate.acquire(), 1);
        assert_eq!(gate.acquire(), 0);
        let w = gate.acquire();
        assert_eq!(w, 1);
        // All slots taken: acquire now blocks until a release.
        let g2 = gate.clone();
        let t = std::thread::spawn(move || g2.acquire());
        std::thread::sleep(Duration::from_millis(20));
        assert!(!t.is_finished());
        gate.release(0);
        assert_eq!(t.join().unwrap(), 0);
    }
}
