//! Bounded, sharded serving statistics.
//!
//! Each worker records into its own shard (no cross-worker contention on
//! the hot path) and latency samples live in fixed-capacity rings, so a
//! server under sustained heavy traffic holds O(capacity) memory instead
//! of growing linearly with request count. [`ServeStats`] is a merged
//! point-in-time snapshot; percentiles use linear interpolation between
//! the two nearest ranks (p50 of `[10, 20, 30, 40]` is 25, not 30).

use std::collections::HashMap;
use std::sync::Mutex;

/// Default total latency-sample capacity across all shards.
pub const DEFAULT_LATENCY_SAMPLES: usize = 4096;

/// Fixed-capacity ring of the most recent latency samples.
#[derive(Debug, Clone)]
pub struct LatencyRing {
    buf: Vec<u64>,
    cap: usize,
    next: usize,
    total: usize,
}

impl LatencyRing {
    pub fn new(cap: usize) -> Self {
        Self { buf: Vec::new(), cap: cap.max(1), next: 0, total: 0 }
    }

    /// O(1) push; once full, overwrites the oldest sample.
    pub fn push(&mut self, v: u64) {
        if self.buf.len() < self.cap {
            self.buf.push(v);
        } else {
            self.buf[self.next] = v;
        }
        self.next = (self.next + 1) % self.cap;
        self.total += 1;
    }

    /// The retained samples, unordered.
    pub fn samples(&self) -> &[u64] {
        &self.buf
    }

    /// Samples ever pushed (retained or evicted).
    pub fn total(&self) -> usize {
        self.total
    }
}

/// One worker's counters.
#[derive(Debug)]
struct Shard {
    batches: usize,
    requests: usize,
    errors: usize,
    latencies: LatencyRing,
}

/// Shard-per-worker recorder shared between the dispatcher, the workers
/// and every [`crate::server::ServerHandle`] clone.
#[derive(Debug)]
pub struct ServeRecorder {
    shards: Vec<Mutex<Shard>>,
    /// Per-config `(batches, requests)` tallies — off the per-worker
    /// shards so `record_batch` (and its counters) stay byte-identical
    /// for single-config serving.
    per_config: Mutex<HashMap<u32, (usize, usize)>>,
}

impl ServeRecorder {
    /// `latency_samples` is the total sample budget, split across shards;
    /// each shard keeps at least 64 samples so percentiles stay usable,
    /// which can stretch a very small budget to `64 * workers`.
    pub fn new(workers: usize, latency_samples: usize) -> Self {
        let workers = workers.max(1);
        let per = (latency_samples / workers).max(64);
        let shards = (0..workers)
            .map(|_| {
                Mutex::new(Shard {
                    batches: 0,
                    requests: 0,
                    errors: 0,
                    latencies: LatencyRing::new(per),
                })
            })
            .collect();
        Self { shards, per_config: Mutex::new(HashMap::new()) }
    }

    /// Record one completed batch on `worker`: per-request latencies plus
    /// how many of its requests were answered with an error (execution
    /// failure or a deadline that expired before delivery). A failed
    /// batch still answers — and counts — every request in it.
    pub fn record_batch(&self, worker: usize, latencies_us: &[u64], errors: usize) {
        let mut s = self.shards[worker % self.shards.len()].lock().unwrap();
        s.batches += 1;
        s.requests += latencies_us.len();
        s.errors += errors.min(latencies_us.len());
        for &l in latencies_us {
            s.latencies.push(l);
        }
    }

    /// Tally one executed batch against its serving config. Separate from
    /// [`ServeRecorder::record_batch`] so the per-worker hot-path counters
    /// are untouched by the multi-config extension.
    pub fn note_config(&self, config: u32, requests: usize) {
        let mut m = self.per_config.lock().unwrap();
        let e = m.entry(config).or_insert((0, 0));
        e.0 += 1;
        e.1 += requests;
    }

    /// Merge all shards into a snapshot. Admission-side counters (rejects,
    /// deadline misses, queue depth) are filled in by the caller.
    pub fn snapshot(&self) -> ServeStats {
        let mut stats = ServeStats::default();
        for (i, shard) in self.shards.iter().enumerate() {
            let s = shard.lock().unwrap();
            stats.requests += s.requests;
            stats.batches += s.batches;
            stats.errors += s.errors;
            stats.latencies_us.extend_from_slice(s.latencies.samples());
            stats.per_worker.push(WorkerStats {
                worker: i,
                batches: s.batches,
                requests: s.requests,
            });
        }
        stats.per_config = self
            .per_config
            .lock()
            .unwrap()
            .iter()
            .map(|(&config, &(batches, requests))| ConfigStats { config, batches, requests })
            .collect();
        stats.per_config.sort_by_key(|c| c.config);
        stats
    }
}

/// Per-config slice of a [`ServeStats`] snapshot (multi-config serving).
#[derive(Debug, Default, Clone)]
pub struct ConfigStats {
    /// Serving config id (index into the server's config table).
    pub config: u32,
    pub batches: usize,
    pub requests: usize,
}

/// Per-worker slice of a [`ServeStats`] snapshot.
#[derive(Debug, Default, Clone)]
pub struct WorkerStats {
    pub worker: usize,
    pub batches: usize,
    pub requests: usize,
}

impl WorkerStats {
    /// Mean requests per executed batch on this worker.
    pub fn mean_batch_fill(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

/// Point-in-time serving statistics (microsecond latencies).
#[derive(Debug, Default, Clone)]
pub struct ServeStats {
    /// Requests answered through an executed batch (including ones
    /// answered with an error — successes are `requests - errors`).
    pub requests: usize,
    /// Batches executed.
    pub batches: usize,
    /// Requests answered with an error through an executed batch: a batch
    /// execution failure, or a deadline that expired before delivery.
    pub errors: usize,
    /// Admissions rejected because the submission queue was full.
    pub rejected: usize,
    /// Requests answered with a deadline error — usually before occupying
    /// a batch slot; a batch finishing past a request's deadline counts
    /// here too (and in `errors`).
    pub deadline_missed: usize,
    /// Highest submission-queue depth observed.
    pub max_queue_depth: usize,
    pub per_worker: Vec<WorkerStats>,
    /// Per-config batch/request tallies, ascending by config id. A single
    /// entry (config 0) for classic single-config serving.
    pub per_config: Vec<ConfigStats>,
    latencies_us: Vec<u64>,
}

impl ServeStats {
    /// Linear-interpolation percentile over the retained latency samples.
    pub fn percentile_us(&self, p: f64) -> u64 {
        percentile(&self.latencies_us, p)
    }

    pub fn mean_us(&self) -> f64 {
        if self.latencies_us.is_empty() {
            return 0.0;
        }
        self.latencies_us.iter().sum::<u64>() as f64 / self.latencies_us.len() as f64
    }

    /// Mean requests per executed batch across all workers.
    pub fn mean_batch_fill(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

/// Percentile with linear interpolation between the two nearest ranks.
pub(crate) fn percentile(samples: &[u64], p: f64) -> u64 {
    if samples.is_empty() {
        return 0;
    }
    let mut v = samples.to_vec();
    v.sort_unstable();
    let rank = (v.len() - 1) as f64 * p.clamp(0.0, 1.0);
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    (v[lo] as f64 + (v[hi] as f64 - v[lo] as f64) * frac).round() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_is_bounded() {
        let mut r = LatencyRing::new(8);
        for i in 0..10_000u64 {
            r.push(i);
        }
        assert_eq!(r.samples().len(), 8);
        assert_eq!(r.total(), 10_000);
        // Retains exactly the most recent 8 samples.
        let mut kept: Vec<u64> = r.samples().to_vec();
        kept.sort_unstable();
        assert_eq!(kept, (9992..10_000).collect::<Vec<u64>>());
    }

    #[test]
    fn percentile_interpolates() {
        let v = [10u64, 20, 30, 40];
        assert_eq!(percentile(&v, 0.0), 10);
        assert_eq!(percentile(&v, 1.0), 40);
        // Rank 1.5 interpolates 20..30 — not the rounded-rank 30.
        assert_eq!(percentile(&v, 0.5), 25);
        assert_eq!(percentile(&v, 0.25), 18); // round(17.5)
        assert_eq!(percentile(&[7], 0.99), 7);
        assert_eq!(percentile(&[], 0.5), 0);
    }

    #[test]
    fn recorder_shards_and_merges() {
        let rec = ServeRecorder::new(2, 1024);
        rec.record_batch(0, &[10, 20], 0);
        rec.record_batch(1, &[30], 0);
        rec.record_batch(1, &[40, 50, 60], 3);
        let s = rec.snapshot();
        assert_eq!(s.requests, 6);
        assert_eq!(s.batches, 3);
        assert_eq!(s.errors, 3);
        assert_eq!(s.per_worker.len(), 2);
        assert_eq!(s.per_worker[0].batches, 1);
        assert_eq!(s.per_worker[1].batches, 2);
        assert_eq!(s.per_worker[1].requests, 4);
        assert!((s.per_worker[1].mean_batch_fill() - 2.0).abs() < 1e-12);
        assert_eq!(s.percentile_us(0.0), 10);
        assert_eq!(s.percentile_us(1.0), 60);
        assert_eq!(s.mean_batch_fill(), 2.0);
        assert!((s.mean_us() - 35.0).abs() < 1e-9);
    }

    #[test]
    fn per_config_tallies_are_additive() {
        let rec = ServeRecorder::new(1, 128);
        rec.record_batch(0, &[10, 20], 0);
        rec.note_config(1, 2);
        rec.record_batch(0, &[30], 0);
        rec.note_config(0, 1);
        rec.note_config(1, 4);
        let s = rec.snapshot();
        // Worker counters are untouched by the per-config tallies.
        assert_eq!((s.requests, s.batches), (3, 2));
        let rows: Vec<(u32, usize, usize)> =
            s.per_config.iter().map(|c| (c.config, c.batches, c.requests)).collect();
        assert_eq!(rows, vec![(0, 1, 1), (1, 2, 6)]);
    }

    #[test]
    fn stats_stay_bounded_under_load() {
        let rec = ServeRecorder::new(1, 128);
        for i in 0..100_000u64 {
            rec.record_batch(0, &[i], 0);
        }
        let s = rec.snapshot();
        assert_eq!(s.requests, 100_000);
        // The snapshot's sample buffer is capped, not linear in traffic.
        assert!(s.latencies_us.len() <= 128);
    }
}
