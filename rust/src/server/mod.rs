//! Multi-worker batching inference server over quantized models.
//!
//! The paper motivates mixed-precision PTQ with serving latency/QoS; this
//! module closes the loop by serving the quantized model from the Rust hot
//! path. PJRT handles are not `Send`, so each worker thread of a
//! [`crate::coordinator::PipelinePool`] owns its *own* [`Pipeline`];
//! callers talk to the engine through a cloneable [`ServerHandle`].
//!
//! Request path:
//!
//! 1. **Admission** ([`queue`]): a bounded submission queue; a full queue
//!    rejects immediately with an error instead of blocking or growing.
//!    Requests carry a priority (higher pops first, FIFO among equals)
//!    and a serving-config id; the queue holds one priority heap per
//!    config so a batch is always formed from a single config.
//! 2. **Batching** ([`dispatch`]): the dispatcher collects same-config
//!    requests until `max_batch` or `max_wait` elapses, expires requests
//!    past their deadline (they are answered, never executed), picks the
//!    smallest compiled batch-size bucket covering the batch, resolves
//!    the config id against the versioned [`ConfigTable`], and fans the
//!    batch to the least-loaded worker. In-flight batches per worker are
//!    bounded, so backpressure lands in the submission queue where
//!    admission control and deadlines are enforced.
//! 3. **Execution**: the worker assembles the batch **zero-copy** in its
//!    pipeline's retained [`crate::runtime::BatchArena`] (each request
//!    payload is written exactly once; no per-request `to_vec`, no
//!    per-batch concatenation), runs the `logits` graph once under the
//!    batch's config — bits buffers are uploaded once per
//!    `(config, version)` and reused — scatters per-request outputs, and
//!    records latency into its own stats shard ([`stats`]).
//!
//! Multi-config serving: [`serve_multi_with_pool`] serves several
//! [`QuantConfig`]s (e.g. per-tenant frontier picks) from ONE warm pool.
//! [`ServerHandle::swap_config`] replaces a config **drain-free**: the
//! table entry's version is bumped, new admissions resolve to the new
//! configuration, and in-flight batches finish under the version they
//! resolved at dispatch time — no request is dropped or retargeted.
//!
//! Shutdown: [`ServerHandle::shutdown`] (or dropping the last handle)
//! closes the queue; the dispatcher drains everything already admitted,
//! then drops the worker pool — which joins the worker threads — and the
//! `JoinHandle` returned by [`spawn`] becomes joinable.
//!
//! Config selection at startup is the caller's job: `mpq serve` either
//! takes a uniform `--bits` width or resolves `--frontier f.json --pick
//! latency<=B,acc>=F` through [`crate::api::FrontierArtifact::pick`] —
//! the best Pareto point under the constraints, read straight from the
//! frontier artifact with no search at serve time (`--tenants` resolves
//! one pick per tenant into a multi-config table). The engine itself is
//! config-agnostic: it serves whatever [`QuantConfig`]s it is handed.

mod dispatch;
mod queue;
mod stats;

pub use dispatch::{BatchJob, ServingBackend};
pub use stats::{
    ConfigStats, LatencyRing, ServeRecorder, ServeStats, WorkerStats, DEFAULT_LATENCY_SAMPLES,
};

use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::{Pipeline, PipelinePool};
use crate::quant::QuantConfig;
use crate::runtime::HostTensor;
use crate::Result;

use dispatch::{Dispatcher, InflightGate};
use queue::{Request, SubmitQueue};

#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Max requests folded into one execution (≤ largest compiled bucket).
    pub max_batch: usize,
    /// Max time the batcher waits for more requests.
    pub max_wait: Duration,
    /// Worker pipelines [`spawn`] builds into its pool.
    /// [`serve_with_backend`] ignores this and sizes the engine from
    /// [`ServingBackend::num_workers`] instead.
    pub workers: usize,
    /// Submission-queue depth; admissions beyond it are rejected.
    pub queue_depth: usize,
    /// Default per-request deadline ([`ServerHandle::infer`]).
    pub deadline: Option<Duration>,
    /// In-flight batches allowed per worker before backpressure.
    pub max_inflight: usize,
    /// Total latency samples retained for percentile stats.
    pub latency_samples: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            max_batch: 32,
            max_wait: Duration::from_micros(500),
            workers: 2,
            queue_depth: 256,
            deadline: None,
            max_inflight: 2,
            latency_samples: DEFAULT_LATENCY_SAMPLES,
        }
    }
}

/// How a request's deadline is derived ([`InferOptions::deadline`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum DeadlinePolicy {
    /// Use the server's default deadline ([`ServeOptions::deadline`]).
    #[default]
    Server,
    /// No deadline, even if the server has a default.
    None,
    /// Deadline this long after submission.
    After(Duration),
}

/// Per-request options for [`ServerHandle::infer_with`].
#[derive(Debug, Clone, Default)]
pub struct InferOptions {
    pub deadline: DeadlinePolicy,
    /// Higher pops first; FIFO among equals. Default 0.
    pub priority: i32,
    /// Serving config id; `None` routes to the server's active config.
    pub config: Option<u32>,
}

/// Versioned serving-config table — the source of truth for config-keyed
/// dispatch. Entry `id` holds `(version, config)`; [`ConfigTable::swap`]
/// bumps the version so worker-side cached bits buffers (keyed by
/// `(id, version)`) can never answer for the new configuration, while
/// batches already dispatched keep the `Arc` they resolved — which is
/// what makes a swap drain-free.
pub(crate) struct ConfigTable {
    entries: Mutex<Vec<(u64, Arc<QuantConfig>)>>,
    /// Default config for requests that don't pick one.
    active: AtomicU32,
}

impl ConfigTable {
    fn new(configs: Vec<QuantConfig>) -> Self {
        Self {
            entries: Mutex::new(configs.into_iter().map(|c| (0, Arc::new(c))).collect()),
            active: AtomicU32::new(0),
        }
    }

    /// The `(version, config)` currently installed for `id`, resolved at
    /// dispatch time; ids are validated at admission.
    pub fn resolve(&self, id: u32) -> (u64, Arc<QuantConfig>) {
        let entries = self.entries.lock().unwrap();
        let e = &entries[(id as usize).min(entries.len() - 1)];
        (e.0, e.1.clone())
    }

    fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    fn active(&self) -> u32 {
        self.active.load(Ordering::Relaxed)
    }

    /// Replace entry `id`, bumping its version. The layer count must
    /// match the table's existing configs (same model).
    fn swap(&self, id: u32, cfg: QuantConfig) -> Result<u64> {
        let mut entries = self.entries.lock().unwrap();
        let n = entries.len();
        let e = entries
            .get_mut(id as usize)
            .ok_or_else(|| anyhow::anyhow!("unknown serving config {id} ({n} configs)"))?;
        anyhow::ensure!(
            cfg.num_layers() == e.1.num_layers(),
            "config swap layer mismatch: {} vs {}",
            cfg.num_layers(),
            e.1.num_layers()
        );
        e.0 += 1;
        e.1 = Arc::new(cfg);
        Ok(e.0)
    }

    /// Append a new config; returns its id.
    fn add(&self, cfg: QuantConfig) -> Result<u32> {
        let mut entries = self.entries.lock().unwrap();
        anyhow::ensure!(
            cfg.num_layers() == entries[0].1.num_layers(),
            "added config layer mismatch: {} vs {}",
            cfg.num_layers(),
            entries[0].1.num_layers()
        );
        entries.push((0, Arc::new(cfg)));
        Ok((entries.len() - 1) as u32)
    }
}

/// Closes the submission queue when the last handle clone drops, so a
/// leaked server cannot outlive its clients.
struct HandleToken {
    queue: Arc<SubmitQueue>,
}

impl Drop for HandleToken {
    fn drop(&mut self) {
        self.queue.close();
    }
}

/// Cloneable, thread-safe handle to a running server.
#[derive(Clone)]
pub struct ServerHandle {
    queue: Arc<SubmitQueue>,
    recorder: Arc<ServeRecorder>,
    table: Arc<ConfigTable>,
    deadline: Option<Duration>,
    shut: Arc<AtomicBool>,
    _token: Arc<HandleToken>,
}

impl ServerHandle {
    /// Submit one example (leading dim == 1) with the server's default
    /// deadline, priority 0, and the active config; blocks until its
    /// predictions (or an admission/deadline/execution error) return.
    pub fn infer(&self, x: HostTensor) -> Result<Vec<f32>> {
        self.infer_with(x, &InferOptions::default())
    }

    /// Submit with an explicit deadline override (`None` = no deadline).
    pub fn infer_with_deadline(
        &self,
        x: HostTensor,
        deadline: Option<Duration>,
    ) -> Result<Vec<f32>> {
        let deadline = match deadline {
            Some(d) => DeadlinePolicy::After(d),
            None => DeadlinePolicy::None,
        };
        self.infer_with(x, &InferOptions { deadline, ..InferOptions::default() })
    }

    /// Submit with full per-request options: deadline policy, priority,
    /// and serving-config routing.
    pub fn infer_with(&self, x: HostTensor, opts: &InferOptions) -> Result<Vec<f32>> {
        let deadline = match opts.deadline {
            DeadlinePolicy::Server => self.deadline,
            DeadlinePolicy::None => None,
            DeadlinePolicy::After(d) => Some(d),
        };
        let config = opts.config.unwrap_or_else(|| self.table.active());
        anyhow::ensure!(
            (config as usize) < self.table.len(),
            "unknown serving config {config} ({} configs)",
            self.table.len()
        );
        let (tx, rx) = mpsc::channel();
        let now = Instant::now();
        self.queue.push(Request {
            x,
            resp: tx,
            enqueued: now,
            deadline: deadline.map(|d| now + d),
            priority: opts.priority,
            config,
        })?;
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped request"))?
    }

    /// Drain-free config replacement: bump config `id` to `cfg`. Requests
    /// admitted after this call execute under `cfg`; batches already
    /// dispatched finish under the configuration they resolved — nothing
    /// is dropped or retargeted. Returns the new table version for `id`.
    pub fn swap_config(&self, id: u32, cfg: QuantConfig) -> Result<u64> {
        self.table.swap(id, cfg)
    }

    /// Add a serving config to the table; returns its id for
    /// [`InferOptions::config`] routing.
    pub fn add_config(&self, cfg: QuantConfig) -> Result<u32> {
        self.table.add(cfg)
    }

    /// The config id requests route to when they don't pick one.
    pub fn active_config(&self) -> u32 {
        self.table.active()
    }

    /// Number of configs in the serving table.
    pub fn num_configs(&self) -> usize {
        self.table.len()
    }

    /// Merged snapshot of serving statistics.
    pub fn stats(&self) -> ServeStats {
        let mut s = self.recorder.snapshot();
        s.rejected = self.queue.rejected();
        s.deadline_missed = self.queue.expired();
        s.max_queue_depth = self.queue.max_depth();
        s
    }

    /// Graceful shutdown: stop admissions and wake the dispatcher, which
    /// drains already-admitted requests and in-flight batches, joins the
    /// workers, and exits — making the `JoinHandle` from [`spawn`] return.
    /// Idempotent; safe to call from any handle clone.
    pub fn shutdown(&self) {
        self.shut.store(true, Ordering::Relaxed);
        self.queue.close();
    }

    /// Whether `shutdown` has been requested on any clone.
    pub fn is_shutdown(&self) -> bool {
        self.shut.load(Ordering::Relaxed)
    }
}

/// Start the serving engine over an already-built backend with a
/// single-entry config table. Exposed so integration tests and benches
/// can drive the dispatcher against stub workers without artifacts or a
/// PJRT device (stub backends never read the config, so a placeholder is
/// installed).
pub fn serve_with_backend<B: ServingBackend>(
    backend: B,
    opts: &ServeOptions,
) -> Result<(ServerHandle, std::thread::JoinHandle<()>)> {
    serve_multi_with_backend(backend, vec![QuantConfig::float(0)], opts)
}

/// [`serve_with_backend`] with an explicit multi-config table: entry `i`
/// serves requests routed to config id `i`.
pub fn serve_multi_with_backend<B: ServingBackend>(
    backend: B,
    configs: Vec<QuantConfig>,
    opts: &ServeOptions,
) -> Result<(ServerHandle, std::thread::JoinHandle<()>)> {
    anyhow::ensure!(!configs.is_empty(), "serving needs at least one config");
    let sizes = dispatch::normalize_batch_sizes(&backend.batch_sizes())?;
    let workers = backend.num_workers().max(1);
    let batch_cap = opts.max_batch.max(1).min(*sizes.last().expect("non-empty"));
    let queue = Arc::new(SubmitQueue::new(opts.queue_depth));
    let recorder = Arc::new(ServeRecorder::new(workers, opts.latency_samples));
    let gate = Arc::new(InflightGate::new(workers, opts.max_inflight));
    let table = Arc::new(ConfigTable::new(configs));
    let dispatcher = Dispatcher {
        backend,
        queue: queue.clone(),
        recorder: recorder.clone(),
        gate,
        table: table.clone(),
        sizes,
        batch_cap,
        max_wait: opts.max_wait,
    };
    let join = std::thread::Builder::new()
        .name("mpq-serve-dispatch".into())
        .spawn(move || dispatcher.run())?;
    let handle = ServerHandle {
        queue: queue.clone(),
        recorder,
        table,
        deadline: opts.deadline,
        shut: Arc::new(AtomicBool::new(false)),
        _token: Arc::new(HandleToken { queue }),
    };
    Ok((handle, join))
}

/// [`ServingBackend`] over a [`PipelinePool`]: one device pipeline per
/// worker thread, batches executed via the pool's per-worker submission.
/// Each [`BatchJob`] carries its own resolved config, so the backend is
/// config-agnostic.
struct PoolBackend {
    pool: PipelinePool,
}

impl ServingBackend for PoolBackend {
    fn num_workers(&self) -> usize {
        self.pool.num_workers()
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.pool.logits_batch_sizes()
    }

    fn submit(&mut self, w: usize, job: BatchJob) {
        self.pool.run_on(w, move |p| match p {
            Some(pipeline) => job.run_logits(pipeline),
            None => job.complete(Err(anyhow::anyhow!("serving worker exited"))),
        });
    }
}

/// Start the serving engine over an already-built (and already
/// calibrated) [`PipelinePool`] — the warm-pool handover path
/// [`crate::api::SearchSession::into_server`] uses. Every compiled
/// serving bucket is warmed on each worker before the dispatcher takes
/// traffic, exactly like [`spawn`], but no second pool is constructed and
/// no weights are re-uploaded: the process keeps exactly one pool.
pub fn serve_with_pool(
    pool: PipelinePool,
    cfg: QuantConfig,
    opts: ServeOptions,
) -> Result<(ServerHandle, std::thread::JoinHandle<()>)> {
    serve_multi_with_pool(pool, vec![cfg], opts)
}

/// [`serve_with_pool`] with a multi-config table: all configs (e.g. one
/// frontier pick per tenant) are served from the SAME warm pool, batched
/// separately and routed by [`InferOptions::config`].
pub fn serve_multi_with_pool(
    pool: PipelinePool,
    configs: Vec<QuantConfig>,
    opts: ServeOptions,
) -> Result<(ServerHandle, std::thread::JoinHandle<()>)> {
    anyhow::ensure!(!configs.is_empty(), "serving needs at least one config");
    let (tx, rx) = mpsc::channel::<Result<()>>();
    for w in 0..pool.num_workers() {
        let tx = tx.clone();
        let warm_cfg = configs[0].clone();
        pool.run_on(w, move |p| {
            let result = match p {
                Some(pipeline) => pipeline
                    .warm_logits(&warm_cfg)
                    .map_err(|e| e.context(format!("warming serving worker {w}"))),
                None => Err(anyhow::anyhow!("serving worker {w} exited before warmup")),
            };
            let _ = tx.send(result);
        });
    }
    drop(tx);
    for result in rx {
        result?;
    }
    serve_multi_with_backend(PoolBackend { pool }, configs, &opts)
}

/// Spawn the serving engine: build `opts.workers` pipelines for `model`
/// (running `configure` — calibration, scale loading — then warming every
/// compiled serving bucket on each), and start the dispatcher. Returns
/// once all workers are ready; the `JoinHandle` is the dispatcher thread,
/// joinable after [`ServerHandle::shutdown`]. Callers holding an
/// already-built pool should hand it to [`serve_with_pool`] instead of
/// paying a second construction.
pub fn spawn(
    artifacts_dir: std::path::PathBuf,
    model: String,
    cfg: QuantConfig,
    opts: ServeOptions,
    configure: impl Fn(&mut Pipeline) -> Result<()> + Send + Sync + 'static,
) -> Result<(ServerHandle, std::thread::JoinHandle<()>)> {
    let warm_cfg = cfg.clone();
    let pool = PipelinePool::new(&artifacts_dir, &model, opts.workers, move |p| {
        configure(p)?;
        // Warm every serving-batch executable before taking traffic.
        p.warm_logits(&warm_cfg)
    })?;
    serve_multi_with_backend(PoolBackend { pool }, vec![cfg], &opts)
}

/// Stack examples (leading dim 1 each, trailing dims `x_shape`) and
/// zero-pad to `batch` rows, allocating a fresh owned tensor.
///
/// This is the **reference copy path**: the serving hot path assembles
/// batches zero-copy through [`crate::runtime::BatchArena`] instead, and
/// the parity tests + `serve_throughput` bench compare the two
/// element-for-element.
pub fn pad_batch(examples: &[HostTensor], x_shape: &[usize], batch: usize) -> HostTensor {
    debug_assert!(!examples.is_empty() && examples.len() <= batch);
    let per: usize = x_shape.iter().product::<usize>().max(1);
    let mut dims = vec![batch];
    dims.extend(x_shape);
    if examples[0].is_i32() {
        let mut data = vec![0i32; batch * per];
        for (i, e) in examples.iter().enumerate() {
            if let Some(d) = e.i32_data() {
                data[i * per..(i + 1) * per].copy_from_slice(d);
            }
        }
        HostTensor::i32(data, dims)
    } else {
        let mut data = vec![0.0f32; batch * per];
        for (i, e) in examples.iter().enumerate() {
            if let Some(d) = e.f32_data() {
                data[i * per..(i + 1) * per].copy_from_slice(d);
            }
        }
        HostTensor::f32(data, dims)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{BatchArena, TensorData};

    #[test]
    fn pad_batch_zero_fills_tail_rows() {
        let a = HostTensor::f32(vec![1.0, 2.0], vec![1, 2]);
        let b = HostTensor::f32(vec![3.0, 4.0], vec![1, 2]);
        let padded = pad_batch(&[a, b], &[2], 4);
        assert_eq!(padded.dims(), &[4, 2]);
        assert_eq!(padded.f32_data().unwrap(), &[1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn arena_assembly_matches_pad_batch_exactly() {
        // The zero-copy path must be byte-identical to the reference copy
        // path for every fill level of every bucket.
        let x_shape = [3usize];
        let mut arena = BatchArena::new();
        for bucket in [1usize, 2, 4, 8] {
            for fill in 1..=bucket {
                let examples: Vec<HostTensor> = (0..fill)
                    .map(|i| {
                        let base = (bucket * 100 + i) as f32;
                        HostTensor::f32(vec![base, base + 0.5, -base], vec![1, 3])
                    })
                    .collect();
                let padded = pad_batch(&examples, &x_shape, bucket);
                let view = arena.assemble(&examples, &x_shape, bucket);
                assert_eq!(view.dims(), padded.dims());
                match view.data() {
                    TensorData::F32(d) => assert_eq!(d, padded.f32_data().unwrap()),
                    TensorData::I32(_) => panic!("dtype follows the examples"),
                }
            }
        }
    }

    #[test]
    fn config_table_swap_bumps_version_and_checks_layers() {
        let table = ConfigTable::new(vec![QuantConfig::uniform(4, 8.0)]);
        let (v0, c0) = table.resolve(0);
        assert_eq!(v0, 0);
        assert_eq!(c0.bits_w[0], 8.0);
        let v1 = table.swap(0, QuantConfig::uniform(4, 4.0)).unwrap();
        assert_eq!(v1, 1);
        let (v, c) = table.resolve(0);
        assert_eq!((v, c.bits_w[0]), (1, 4.0));
        // Wrong layer count and unknown id are rejected.
        assert!(table.swap(0, QuantConfig::uniform(3, 4.0)).is_err());
        assert!(table.swap(7, QuantConfig::uniform(4, 4.0)).is_err());
        // Adding starts the new entry at version 0.
        let id = table.add(QuantConfig::uniform(4, 2.0)).unwrap();
        assert_eq!(id, 1);
        assert_eq!(table.resolve(1).0, 0);
        assert!(table.add(QuantConfig::uniform(5, 2.0)).is_err());
        assert_eq!(table.len(), 2);
        assert_eq!(table.active(), 0);
    }
}
