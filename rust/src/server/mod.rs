//! Multi-worker batching inference server over a quantized model.
//!
//! The paper motivates mixed-precision PTQ with serving latency/QoS; this
//! module closes the loop by serving the quantized model from the Rust hot
//! path. PJRT handles are not `Send`, so each worker thread of a
//! [`crate::coordinator::PipelinePool`] owns its *own* [`Pipeline`];
//! callers talk to the engine through a cloneable [`ServerHandle`].
//!
//! Request path:
//!
//! 1. **Admission** ([`queue`]): a bounded submission queue; a full queue
//!    rejects immediately with an error instead of blocking or growing.
//! 2. **Batching** ([`dispatch`]): the dispatcher collects requests until
//!    `max_batch` or `max_wait` elapses, expires requests past their
//!    deadline (they are answered, never executed), picks the smallest
//!    compiled batch-size bucket covering the batch, and fans it to the
//!    least-loaded worker. In-flight batches per worker are bounded, so
//!    backpressure lands in the submission queue where admission control
//!    and deadlines are enforced.
//! 3. **Execution**: the worker pads the batch to its bucket, runs the
//!    `logits` graph once, scatters per-request outputs, and records
//!    latency into its own stats shard ([`stats`] — bounded memory).
//!
//! Shutdown: [`ServerHandle::shutdown`] (or dropping the last handle)
//! closes the queue; the dispatcher drains everything already admitted,
//! then drops the worker pool — which joins the worker threads — and the
//! `JoinHandle` returned by [`spawn`] becomes joinable.
//!
//! Config selection at startup is the caller's job: `mpq serve` either
//! takes a uniform `--bits` width or resolves `--frontier f.json --pick
//! latency<=B,acc>=F` through [`crate::api::FrontierArtifact::pick`] —
//! the best Pareto point under the constraints, read straight from the
//! frontier artifact with no search at serve time. The engine itself is
//! config-agnostic: it serves whatever [`QuantConfig`] it is handed.

mod dispatch;
mod queue;
mod stats;

pub use dispatch::{BatchJob, ServingBackend};
pub use stats::{LatencyRing, ServeRecorder, ServeStats, WorkerStats, DEFAULT_LATENCY_SAMPLES};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::coordinator::{Pipeline, PipelinePool};
use crate::quant::QuantConfig;
use crate::runtime::HostTensor;
use crate::Result;

use dispatch::{Dispatcher, InflightGate};
use queue::{Request, SubmitQueue};

#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Max requests folded into one execution (≤ largest compiled bucket).
    pub max_batch: usize,
    /// Max time the batcher waits for more requests.
    pub max_wait: Duration,
    /// Worker pipelines [`spawn`] builds into its pool.
    /// [`serve_with_backend`] ignores this and sizes the engine from
    /// [`ServingBackend::num_workers`] instead.
    pub workers: usize,
    /// Submission-queue depth; admissions beyond it are rejected.
    pub queue_depth: usize,
    /// Default per-request deadline ([`ServerHandle::infer`]).
    pub deadline: Option<Duration>,
    /// In-flight batches allowed per worker before backpressure.
    pub max_inflight: usize,
    /// Total latency samples retained for percentile stats.
    pub latency_samples: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            max_batch: 32,
            max_wait: Duration::from_micros(500),
            workers: 2,
            queue_depth: 256,
            deadline: None,
            max_inflight: 2,
            latency_samples: DEFAULT_LATENCY_SAMPLES,
        }
    }
}

/// Closes the submission queue when the last handle clone drops, so a
/// leaked server cannot outlive its clients.
struct HandleToken {
    queue: Arc<SubmitQueue>,
}

impl Drop for HandleToken {
    fn drop(&mut self) {
        self.queue.close();
    }
}

/// Cloneable, thread-safe handle to a running server.
#[derive(Clone)]
pub struct ServerHandle {
    queue: Arc<SubmitQueue>,
    recorder: Arc<ServeRecorder>,
    deadline: Option<Duration>,
    shut: Arc<AtomicBool>,
    _token: Arc<HandleToken>,
}

impl ServerHandle {
    /// Submit one example (leading dim == 1) with the server's default
    /// deadline; blocks until its predictions (or an admission/deadline/
    /// execution error) return.
    pub fn infer(&self, x: HostTensor) -> Result<Vec<f32>> {
        self.infer_with_deadline(x, self.deadline)
    }

    /// Submit with an explicit deadline override (`None` = no deadline).
    pub fn infer_with_deadline(
        &self,
        x: HostTensor,
        deadline: Option<Duration>,
    ) -> Result<Vec<f32>> {
        let (tx, rx) = mpsc::channel();
        let now = Instant::now();
        self.queue.push(Request {
            x,
            resp: tx,
            enqueued: now,
            deadline: deadline.map(|d| now + d),
        })?;
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped request"))?
    }

    /// Merged snapshot of serving statistics.
    pub fn stats(&self) -> ServeStats {
        let mut s = self.recorder.snapshot();
        s.rejected = self.queue.rejected();
        s.deadline_missed = self.queue.expired();
        s.max_queue_depth = self.queue.max_depth();
        s
    }

    /// Graceful shutdown: stop admissions and wake the dispatcher, which
    /// drains already-admitted requests and in-flight batches, joins the
    /// workers, and exits — making the `JoinHandle` from [`spawn`] return.
    /// Idempotent; safe to call from any handle clone.
    pub fn shutdown(&self) {
        self.shut.store(true, Ordering::Relaxed);
        self.queue.close();
    }

    /// Whether `shutdown` has been requested on any clone.
    pub fn is_shutdown(&self) -> bool {
        self.shut.load(Ordering::Relaxed)
    }
}

/// Start the serving engine over an already-built backend. Exposed so
/// integration tests and benches can drive the dispatcher against stub
/// workers without artifacts or a PJRT device.
pub fn serve_with_backend<B: ServingBackend>(
    backend: B,
    opts: &ServeOptions,
) -> Result<(ServerHandle, std::thread::JoinHandle<()>)> {
    let sizes = dispatch::normalize_batch_sizes(&backend.batch_sizes())?;
    let workers = backend.num_workers().max(1);
    let batch_cap = opts.max_batch.max(1).min(*sizes.last().expect("non-empty"));
    let queue = Arc::new(SubmitQueue::new(opts.queue_depth));
    let recorder = Arc::new(ServeRecorder::new(workers, opts.latency_samples));
    let gate = Arc::new(InflightGate::new(workers, opts.max_inflight));
    let dispatcher = Dispatcher {
        backend,
        queue: queue.clone(),
        recorder: recorder.clone(),
        gate,
        sizes,
        batch_cap,
        max_wait: opts.max_wait,
    };
    let join = std::thread::Builder::new()
        .name("mpq-serve-dispatch".into())
        .spawn(move || dispatcher.run())?;
    let handle = ServerHandle {
        queue: queue.clone(),
        recorder,
        deadline: opts.deadline,
        shut: Arc::new(AtomicBool::new(false)),
        _token: Arc::new(HandleToken { queue }),
    };
    Ok((handle, join))
}

/// [`ServingBackend`] over a [`PipelinePool`]: one device pipeline per
/// worker thread, batches executed via the pool's per-worker submission.
struct PoolBackend {
    pool: PipelinePool,
    cfg: QuantConfig,
}

impl ServingBackend for PoolBackend {
    fn num_workers(&self) -> usize {
        self.pool.num_workers()
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.pool.logits_batch_sizes()
    }

    fn submit(&mut self, w: usize, job: BatchJob) {
        let cfg = self.cfg.clone();
        self.pool.run_on(w, move |p| match p {
            Some(pipeline) => job.run_logits(pipeline, &cfg),
            None => job.complete(Err(anyhow::anyhow!("serving worker exited"))),
        });
    }
}

/// Start the serving engine over an already-built (and already
/// calibrated) [`PipelinePool`] — the warm-pool handover path
/// [`crate::api::SearchSession::into_server`] uses. Every compiled
/// serving bucket is warmed on each worker before the dispatcher takes
/// traffic, exactly like [`spawn`], but no second pool is constructed and
/// no weights are re-uploaded: the process keeps exactly one pool.
pub fn serve_with_pool(
    pool: PipelinePool,
    cfg: QuantConfig,
    opts: ServeOptions,
) -> Result<(ServerHandle, std::thread::JoinHandle<()>)> {
    let (tx, rx) = mpsc::channel::<Result<()>>();
    for w in 0..pool.num_workers() {
        let tx = tx.clone();
        let warm_cfg = cfg.clone();
        pool.run_on(w, move |p| {
            let result = match p {
                Some(pipeline) => pipeline
                    .warm_logits(&warm_cfg)
                    .map_err(|e| e.context(format!("warming serving worker {w}"))),
                None => Err(anyhow::anyhow!("serving worker {w} exited before warmup")),
            };
            let _ = tx.send(result);
        });
    }
    drop(tx);
    for result in rx {
        result?;
    }
    serve_with_backend(PoolBackend { pool, cfg }, &opts)
}

/// Spawn the serving engine: build `opts.workers` pipelines for `model`
/// (running `configure` — calibration, scale loading — then warming every
/// compiled serving bucket on each), and start the dispatcher. Returns
/// once all workers are ready; the `JoinHandle` is the dispatcher thread,
/// joinable after [`ServerHandle::shutdown`]. Callers holding an
/// already-built pool should hand it to [`serve_with_pool`] instead of
/// paying a second construction.
pub fn spawn(
    artifacts_dir: std::path::PathBuf,
    model: String,
    cfg: QuantConfig,
    opts: ServeOptions,
    configure: impl Fn(&mut Pipeline) -> Result<()> + Send + Sync + 'static,
) -> Result<(ServerHandle, std::thread::JoinHandle<()>)> {
    let warm_cfg = cfg.clone();
    let pool = PipelinePool::new(&artifacts_dir, &model, opts.workers, move |p| {
        configure(p)?;
        // Warm every serving-batch executable before taking traffic.
        p.warm_logits(&warm_cfg)
    })?;
    serve_with_backend(PoolBackend { pool, cfg }, &opts)
}

/// Stack examples (leading dim 1 each, trailing dims `x_shape`) and
/// zero-pad to `batch` rows.
pub(crate) fn pad_batch(examples: &[HostTensor], x_shape: &[usize], batch: usize) -> HostTensor {
    debug_assert!(!examples.is_empty() && examples.len() <= batch);
    let per: usize = x_shape.iter().product::<usize>().max(1);
    let mut dims = vec![batch];
    dims.extend(x_shape);
    match examples[0] {
        HostTensor::F32 { .. } => {
            let mut data = vec![0.0f32; batch * per];
            for (i, e) in examples.iter().enumerate() {
                if let HostTensor::F32 { data: d, .. } = e {
                    data[i * per..(i + 1) * per].copy_from_slice(d);
                }
            }
            HostTensor::f32(data, dims)
        }
        HostTensor::I32 { .. } => {
            let mut data = vec![0i32; batch * per];
            for (i, e) in examples.iter().enumerate() {
                if let HostTensor::I32 { data: d, .. } = e {
                    data[i * per..(i + 1) * per].copy_from_slice(d);
                }
            }
            HostTensor::i32(data, dims)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_batch_zero_fills_tail_rows() {
        let a = HostTensor::f32(vec![1.0, 2.0], vec![1, 2]);
        let b = HostTensor::f32(vec![3.0, 4.0], vec![1, 2]);
        let padded = pad_batch(&[a, b], &[2], 4);
        assert_eq!(padded.dims(), &[4, 2]);
        match padded {
            HostTensor::F32 { data, .. } => {
                assert_eq!(data, vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
            }
            _ => panic!("dtype follows the examples"),
        }
    }
}
