//! Minimal batched inference server over a quantized model.
//!
//! The paper motivates mixed-precision PTQ with serving latency/QoS; this
//! module closes the loop by actually serving the quantized model from the
//! Rust hot path. PJRT handles are not `Send`, so the server owns its
//! [`Pipeline`] on a dedicated executor thread; callers talk to it through
//! a cloneable [`ServerHandle`] (thread-safe, usable from tokio tasks via
//! `spawn_blocking`).
//!
//! Batching policy: collect requests until `max_batch` or `max_wait_us`
//! elapses, pad the batch to the compiled batch size, run the `logits`
//! graph once, scatter per-request outputs.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::Pipeline;
use crate::quant::QuantConfig;
use crate::runtime::HostTensor;
use crate::Result;

#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Max requests folded into one execution (≤ compiled batch size).
    pub max_batch: usize,
    /// Max time the batcher waits for more requests.
    pub max_wait: Duration,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self { max_batch: 32, max_wait: Duration::from_micros(500) }
    }
}

struct Request {
    /// One example (leading dim == 1).
    x: HostTensor,
    resp: mpsc::Sender<Result<Vec<f32>>>,
    enqueued: Instant,
}

/// Latency statistics collected by the server (microseconds).
#[derive(Debug, Default, Clone)]
pub struct ServeStats {
    pub requests: usize,
    pub batches: usize,
    latencies_us: Vec<u64>,
}

impl ServeStats {
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.latencies_us.is_empty() {
            return 0;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let idx = ((v.len() as f64 - 1.0) * p).round() as usize;
        v[idx]
    }

    pub fn mean_us(&self) -> f64 {
        if self.latencies_us.is_empty() {
            return 0.0;
        }
        self.latencies_us.iter().sum::<u64>() as f64 / self.latencies_us.len() as f64
    }

    pub fn mean_batch_fill(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.requests as f64 / self.batches as f64
    }
}

/// Cloneable, thread-safe handle to a running server.
#[derive(Clone)]
pub struct ServerHandle {
    tx: mpsc::Sender<Request>,
    stats: Arc<Mutex<ServeStats>>,
}

impl ServerHandle {
    /// Submit one example; blocks until its predictions return.
    pub fn infer(&self, x: HostTensor) -> Result<Vec<f32>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Request { x, resp: tx, enqueued: Instant::now() })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped request"))?
    }

    pub fn stats(&self) -> ServeStats {
        self.stats.lock().unwrap().clone()
    }
}

/// Spawn the server thread. `configure` runs on the freshly built pipeline
/// (calibration, scale loading) before serving starts.
pub fn spawn(
    artifacts_dir: std::path::PathBuf,
    model: String,
    cfg: QuantConfig,
    opts: ServeOptions,
    configure: impl FnOnce(&mut Pipeline) -> Result<()> + Send + 'static,
) -> Result<(ServerHandle, std::thread::JoinHandle<()>)> {
    let (tx, rx) = mpsc::channel::<Request>();
    let stats = Arc::new(Mutex::new(ServeStats::default()));
    let stats2 = stats.clone();
    let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
    let join = std::thread::spawn(move || {
        let mut pipeline = match Pipeline::new(&artifacts_dir, &model) {
            Ok(p) => p,
            Err(e) => {
                let _ = ready_tx.send(Err(e));
                return;
            }
        };
        if let Err(e) = configure(&mut pipeline) {
            let _ = ready_tx.send(Err(e));
            return;
        }
        // Warm every serving-batch executable before declaring readiness.
        let warm = single_zero_example(&pipeline);
        for batch in pipeline.logits_batch_sizes() {
            if let Err(e) = pipeline.logits(&cfg, &pad_batch(&[warm.clone()], &pipeline, batch)) {
                let _ = ready_tx.send(Err(e));
                return;
            }
        }
        let _ = ready_tx.send(Ok(()));
        serve_loop(&mut pipeline, &cfg, &opts, &rx, &stats2);
    });
    ready_rx.recv().map_err(|_| anyhow::anyhow!("server thread died"))??;
    Ok((ServerHandle { tx, stats }, join))
}

fn single_zero_example(pipeline: &Pipeline) -> HostTensor {
    let m = &pipeline.artifacts.manifest;
    let mut dims = vec![1usize];
    dims.extend(&m.x_shape);
    let numel: usize = dims.iter().product();
    if m.x_dtype == "i32" {
        HostTensor::i32(vec![0; numel], dims)
    } else {
        HostTensor::f32(vec![0.0; numel], dims)
    }
}

/// Stack examples (leading dim 1 each) and zero-pad to `batch` rows.
fn pad_batch(examples: &[HostTensor], pipeline: &Pipeline, batch: usize) -> HostTensor {
    let m = &pipeline.artifacts.manifest;
    debug_assert!(examples.len() <= batch);
    let per: usize = m.x_shape.iter().product::<usize>().max(1);
    let mut dims = vec![batch];
    dims.extend(&m.x_shape);
    match examples[0] {
        HostTensor::F32 { .. } => {
            let mut data = vec![0.0f32; batch * per];
            for (i, e) in examples.iter().enumerate() {
                if let HostTensor::F32 { data: d, .. } = e {
                    data[i * per..(i + 1) * per].copy_from_slice(d);
                }
            }
            HostTensor::f32(data, dims)
        }
        HostTensor::I32 { .. } => {
            let mut data = vec![0i32; batch * per];
            for (i, e) in examples.iter().enumerate() {
                if let HostTensor::I32 { data: d, .. } = e {
                    data[i * per..(i + 1) * per].copy_from_slice(d);
                }
            }
            HostTensor::i32(data, dims)
        }
    }
}

fn serve_loop(
    pipeline: &mut Pipeline,
    cfg: &QuantConfig,
    opts: &ServeOptions,
    rx: &mpsc::Receiver<Request>,
    stats: &Arc<Mutex<ServeStats>>,
) {
    let sizes = pipeline.logits_batch_sizes();
    let batch_cap = opts.max_batch.min(*sizes.last().unwrap());
    while let Ok(first) = rx.recv() {
        let mut pending = vec![first];
        let deadline = Instant::now() + opts.max_wait;
        while pending.len() < batch_cap {
            match rx.recv_timeout(deadline.saturating_duration_since(Instant::now())) {
                Ok(r) => pending.push(r),
                Err(_) => break,
            }
        }
        // Smallest compiled batch covering the queue — padding a queue of 3
        // to batch 8 costs far less than padding it to the eval batch.
        let batch_size = *sizes
            .iter()
            .find(|&&s| s >= pending.len())
            .unwrap_or(sizes.last().unwrap());
        let xs: Vec<HostTensor> = pending.iter().map(|r| r.x.clone()).collect();
        let batch = pad_batch(&xs, pipeline, batch_size);
        let result = pipeline.logits(cfg, &batch);
        let total_out = match &result {
            Ok(v) => v.len(),
            Err(_) => 0,
        };
        let per_out = total_out / batch_size.max(1);
        let now = Instant::now();
        {
            let mut s = stats.lock().unwrap();
            s.batches += 1;
            s.requests += pending.len();
            for r in &pending {
                s.latencies_us.push(now.duration_since(r.enqueued).as_micros() as u64);
            }
        }
        match result {
            Ok(values) => {
                for (i, r) in pending.into_iter().enumerate() {
                    let out = values[i * per_out..(i + 1) * per_out].to_vec();
                    let _ = r.resp.send(Ok(out));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for r in pending {
                    let _ = r.resp.send(Err(anyhow::anyhow!("{msg}")));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles() {
        let s = ServeStats { requests: 4, batches: 2, latencies_us: vec![10, 20, 30, 40] };
        assert_eq!(s.percentile_us(0.0), 10);
        assert_eq!(s.percentile_us(1.0), 40);
        assert_eq!(s.percentile_us(0.5), 30); // round(1.5)=2 -> 30
        assert_eq!(s.mean_us(), 25.0);
        assert_eq!(s.mean_batch_fill(), 2.0);
    }
}
