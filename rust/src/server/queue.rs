//! Bounded submission queue with admission control and per-request
//! deadlines.
//!
//! Producers ([`crate::server::ServerHandle::infer`]) push under a mutex
//! and are *never* blocked by a full queue — admission control answers
//! immediately with a queue-full error so callers can shed load or retry.
//! The single dispatcher consumes via [`SubmitQueue::next_batch`], which
//! blocks for the first live request and then gathers more until the
//! batch cap or the formation wait elapses. Requests whose deadline has
//! already passed are answered with a deadline error during the pop, so
//! they never occupy a batch slot.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::runtime::HostTensor;
use crate::Result;

/// One queued inference request.
pub(crate) struct Request {
    /// One example, leading dim == 1.
    pub x: HostTensor,
    pub resp: mpsc::Sender<Result<Vec<f32>>>,
    pub enqueued: Instant,
    /// Absolute deadline; expired requests are answered with an error.
    pub deadline: Option<Instant>,
}

#[derive(Default)]
struct State {
    queue: VecDeque<Request>,
    closed: bool,
    max_depth: usize,
}

/// Mutex+condvar bounded MPSC queue shared by all handle clones and the
/// dispatcher.
pub(crate) struct SubmitQueue {
    state: Mutex<State>,
    cond: Condvar,
    capacity: usize,
    rejected: AtomicUsize,
    expired: AtomicUsize,
}

impl SubmitQueue {
    pub fn new(capacity: usize) -> Self {
        Self {
            state: Mutex::new(State::default()),
            cond: Condvar::new(),
            capacity: capacity.max(1),
            rejected: AtomicUsize::new(0),
            expired: AtomicUsize::new(0),
        }
    }

    /// Admit a request, or answer immediately: queue-full rejections and
    /// submissions after shutdown never block the caller.
    pub fn push(&self, req: Request) -> Result<()> {
        let mut state = self.state.lock().unwrap();
        if state.closed {
            anyhow::bail!("server stopped");
        }
        if state.queue.len() >= self.capacity {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            anyhow::bail!("server queue full ({} pending)", state.queue.len());
        }
        state.queue.push_back(req);
        state.max_depth = state.max_depth.max(state.queue.len());
        drop(state);
        self.cond.notify_one();
        Ok(())
    }

    /// Close the queue: no new admissions, wake the dispatcher. Requests
    /// already queued are still drained into batches.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cond.notify_all();
    }

    /// Close the queue AND answer everything still queued with `msg` —
    /// the dispatcher's panic path. After a normal drain the queue is
    /// empty and this reduces to [`SubmitQueue::close`]; after a panic it
    /// turns would-be-forever hangs into immediate errors.
    pub fn fail_pending(&self, msg: &str) {
        let mut state = self.state.lock().unwrap();
        state.closed = true;
        while let Some(req) = state.queue.pop_front() {
            let _ = req.resp.send(Err(anyhow::anyhow!("{msg}")));
        }
        drop(state);
        self.cond.notify_all();
    }

    /// Count one deadline miss (the caller answers the request itself) —
    /// used by [`crate::server::BatchJob`] when a deadline expires after
    /// execution already started.
    pub fn note_expired(&self) {
        self.expired.fetch_add(1, Ordering::Relaxed);
    }

    /// Answer `req` with a deadline error and count the miss. Used both by
    /// the pop path and by the dispatcher's pre-submit sweep.
    pub fn expire(&self, req: Request) {
        self.note_expired();
        let _ = req.resp.send(Err(anyhow::anyhow!(
            "deadline exceeded before execution ({:?} in queue)",
            req.enqueued.elapsed()
        )));
    }

    /// Pop the oldest request whose deadline has not passed, expiring the
    /// rest. `None` when the queue is momentarily empty.
    fn pop_live(&self, state: &mut State) -> Option<Request> {
        let now = Instant::now();
        while let Some(req) = state.queue.pop_front() {
            if req.deadline.is_some_and(|d| d <= now) {
                self.expire(req);
                continue;
            }
            return Some(req);
        }
        None
    }

    /// Block for the first live request, then gather up to `max` total
    /// until `max_wait` elapses. Returns `None` once the queue is closed
    /// *and* drained — the dispatcher's exit condition.
    pub fn next_batch(&self, max: usize, max_wait: Duration) -> Option<Vec<Request>> {
        let mut state = self.state.lock().unwrap();
        let first = loop {
            if let Some(req) = self.pop_live(&mut state) {
                break req;
            }
            if state.closed {
                return None;
            }
            state = self.cond.wait(state).unwrap();
        };
        let formed_by = Instant::now() + max_wait;
        let mut batch = vec![first];
        while batch.len() < max {
            if let Some(req) = self.pop_live(&mut state) {
                batch.push(req);
                continue;
            }
            if state.closed {
                break;
            }
            let now = Instant::now();
            if now >= formed_by {
                break;
            }
            let (guard, timeout) = self.cond.wait_timeout(state, formed_by - now).unwrap();
            state = guard;
            if timeout.timed_out() {
                // One final sweep for anything that raced the timeout.
                while batch.len() < max {
                    match self.pop_live(&mut state) {
                        Some(req) => batch.push(req),
                        None => break,
                    }
                }
                break;
            }
        }
        Some(batch)
    }

    /// Admissions rejected because the queue was full.
    pub fn rejected(&self) -> usize {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Requests answered with a deadline error.
    pub fn expired(&self) -> usize {
        self.expired.load(Ordering::Relaxed)
    }

    /// Highest queue depth observed since startup.
    pub fn max_depth(&self) -> usize {
        self.state.lock().unwrap().max_depth
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(deadline: Option<Instant>) -> (Request, mpsc::Receiver<Result<Vec<f32>>>) {
        let (tx, rx) = mpsc::channel();
        let r = Request {
            x: HostTensor::f32(vec![0.0], vec![1, 1]),
            resp: tx,
            enqueued: Instant::now(),
            deadline,
        };
        (r, rx)
    }

    #[test]
    fn admission_rejects_when_full() {
        let q = SubmitQueue::new(2);
        let (a, _ra) = req(None);
        let (b, _rb) = req(None);
        let (c, _rc) = req(None);
        q.push(a).unwrap();
        q.push(b).unwrap();
        let err = q.push(c).unwrap_err();
        assert!(format!("{err:#}").contains("queue full"), "{err:#}");
        assert_eq!(q.rejected(), 1);
        assert_eq!(q.max_depth(), 2);
    }

    #[test]
    fn push_after_close_errors_and_next_batch_drains() {
        let q = SubmitQueue::new(8);
        let (a, _ra) = req(None);
        let (b, _rb) = req(None);
        q.push(a).unwrap();
        q.push(b).unwrap();
        q.close();
        let (c, _rc) = req(None);
        assert!(format!("{:#}", q.push(c).unwrap_err()).contains("stopped"));
        // Queued-before-close requests still come out, then None.
        let batch = q.next_batch(8, Duration::from_millis(1)).unwrap();
        assert_eq!(batch.len(), 2);
        assert!(q.next_batch(8, Duration::from_millis(1)).is_none());
    }

    #[test]
    fn expired_requests_are_answered_not_batched() {
        let q = SubmitQueue::new(8);
        let past = Instant::now() - Duration::from_millis(5);
        let (a, ra) = req(Some(past));
        let (b, _rb) = req(None);
        q.push(a).unwrap();
        q.push(b).unwrap();
        let batch = q.next_batch(8, Duration::from_millis(1)).unwrap();
        assert_eq!(batch.len(), 1, "expired request must not occupy a slot");
        assert_eq!(q.expired(), 1);
        let answer = ra.recv().unwrap();
        assert!(format!("{:#}", answer.unwrap_err()).contains("deadline"));
    }

    #[test]
    fn next_batch_caps_at_max() {
        let q = SubmitQueue::new(16);
        let mut rxs = Vec::new();
        for _ in 0..5 {
            let (r, rx) = req(None);
            q.push(r).unwrap();
            rxs.push(rx);
        }
        let batch = q.next_batch(3, Duration::from_millis(1)).unwrap();
        assert_eq!(batch.len(), 3);
        let batch = q.next_batch(3, Duration::from_millis(1)).unwrap();
        assert_eq!(batch.len(), 2);
    }
}
