//! Bounded submission queue with admission control, per-request priority
//! and deadlines, and config-keyed batch formation.
//!
//! Producers ([`crate::server::ServerHandle::infer`]) push under a mutex
//! and are *never* blocked by a full queue — admission control answers
//! immediately with a queue-full error so callers can shed load or retry.
//! The single dispatcher consumes via [`SubmitQueue::next_batch`], which
//! blocks for the first live request and then gathers more until the
//! batch cap or the formation wait elapses.
//!
//! Ordering: requests are held in one binary heap per serving config,
//! popped highest [`Request::priority`] first with FIFO tie-break (a
//! global admission sequence number), so equal-priority traffic keeps the
//! old strict arrival order. A batch is always formed from a **single**
//! config's heap — two configs are never co-batched, which is what lets
//! the execution path bind one bits table per batch. Requests whose
//! deadline has already passed are answered with a deadline error during
//! the pop, so they never occupy a batch slot.

use std::cmp::Ordering as CmpOrdering;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::runtime::HostTensor;
use crate::Result;

/// One queued inference request.
pub(crate) struct Request {
    /// One example, leading dim == 1.
    pub x: HostTensor,
    pub resp: mpsc::Sender<Result<Vec<f32>>>,
    pub enqueued: Instant,
    /// Absolute deadline; expired requests are answered with an error.
    pub deadline: Option<Instant>,
    /// Higher pops first; FIFO among equals. Default 0.
    pub priority: i32,
    /// Serving config id (index into the server's config table).
    pub config: u32,
}

/// Heap entry: a request plus its admission sequence number. Max-heap
/// order is `(priority, Reverse(seq))` — highest priority first, oldest
/// first among equals.
struct Queued {
    req: Request,
    seq: u64,
}

impl Queued {
    fn rank(&self) -> (i32, std::cmp::Reverse<u64>) {
        (self.req.priority, std::cmp::Reverse(self.seq))
    }
}

impl PartialEq for Queued {
    fn eq(&self, other: &Self) -> bool {
        self.rank() == other.rank()
    }
}

impl Eq for Queued {}

impl PartialOrd for Queued {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}

impl Ord for Queued {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        self.rank().cmp(&other.rank())
    }
}

#[derive(Default)]
struct State {
    /// One priority heap per config id (index == id; grown lazily as
    /// configs are first seen).
    queues: Vec<BinaryHeap<Queued>>,
    /// Total queued requests across all configs.
    len: usize,
    /// Global admission counter — the FIFO tie-break.
    seq: u64,
    closed: bool,
    max_depth: usize,
}

/// Mutex+condvar bounded MPSC queue shared by all handle clones and the
/// dispatcher.
pub(crate) struct SubmitQueue {
    state: Mutex<State>,
    cond: Condvar,
    capacity: usize,
    rejected: AtomicUsize,
    expired: AtomicUsize,
}

impl SubmitQueue {
    pub fn new(capacity: usize) -> Self {
        Self {
            state: Mutex::new(State::default()),
            cond: Condvar::new(),
            capacity: capacity.max(1),
            rejected: AtomicUsize::new(0),
            expired: AtomicUsize::new(0),
        }
    }

    /// Admit a request, or answer immediately: queue-full rejections and
    /// submissions after shutdown never block the caller. The capacity
    /// bound is global across configs.
    pub fn push(&self, req: Request) -> Result<()> {
        let mut state = self.state.lock().unwrap();
        if state.closed {
            anyhow::bail!("server stopped");
        }
        if state.len >= self.capacity {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            anyhow::bail!("server queue full ({} pending)", state.len);
        }
        let ci = req.config as usize;
        while state.queues.len() <= ci {
            state.queues.push(BinaryHeap::new());
        }
        let seq = state.seq;
        state.seq += 1;
        state.queues[ci].push(Queued { req, seq });
        state.len += 1;
        state.max_depth = state.max_depth.max(state.len);
        drop(state);
        self.cond.notify_one();
        Ok(())
    }

    /// Close the queue: no new admissions, wake the dispatcher. Requests
    /// already queued are still drained into batches.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cond.notify_all();
    }

    /// Close the queue AND answer everything still queued with `msg` —
    /// the dispatcher's panic path. After a normal drain the queue is
    /// empty and this reduces to [`SubmitQueue::close`]; after a panic it
    /// turns would-be-forever hangs into immediate errors.
    pub fn fail_pending(&self, msg: &str) {
        let mut state = self.state.lock().unwrap();
        state.closed = true;
        for heap in &mut state.queues {
            for q in heap.drain() {
                let _ = q.req.resp.send(Err(anyhow::anyhow!("{msg}")));
            }
        }
        state.len = 0;
        drop(state);
        self.cond.notify_all();
    }

    /// Count one deadline miss (the caller answers the request itself) —
    /// used by [`crate::server::BatchJob`] when a deadline expires after
    /// execution already started.
    pub fn note_expired(&self) {
        self.expired.fetch_add(1, Ordering::Relaxed);
    }

    /// Answer `req` with a deadline error and count the miss. Used both by
    /// the pop path and by the dispatcher's pre-submit sweep.
    pub fn expire(&self, req: Request) {
        self.note_expired();
        let _ = req.resp.send(Err(anyhow::anyhow!(
            "deadline exceeded before execution ({:?} in queue)",
            req.enqueued.elapsed()
        )));
    }

    /// Pop config `ci`'s best live request — highest priority, FIFO among
    /// equals — expiring dead heads on the way. `None` when that config's
    /// heap is momentarily empty.
    fn pop_live_for(&self, state: &mut State, ci: usize) -> Option<Request> {
        let now = Instant::now();
        while let Some(q) = state.queues[ci].pop() {
            state.len -= 1;
            if q.req.deadline.is_some_and(|d| d <= now) {
                self.expire(q.req);
                continue;
            }
            return Some(q.req);
        }
        None
    }

    /// Pop the globally best live request and its config: the winning
    /// head across all config heaps by `(priority, admission order)`.
    /// Expired heads are answered and the choice re-made — an expiry can
    /// hand the win to another config.
    fn pop_best_live(&self, state: &mut State) -> Option<(u32, Request)> {
        loop {
            let mut best: Option<(usize, (i32, std::cmp::Reverse<u64>))> = None;
            for (ci, heap) in state.queues.iter().enumerate() {
                if let Some(head) = heap.peek() {
                    let rank = head.rank();
                    let better = match &best {
                        Some((_, b)) => rank > *b,
                        None => true,
                    };
                    if better {
                        best = Some((ci, rank));
                    }
                }
            }
            let (ci, _) = best?;
            let q = state.queues[ci].pop().expect("peeked above");
            state.len -= 1;
            if q.req.deadline.is_some_and(|d| d <= Instant::now()) {
                self.expire(q.req);
                continue;
            }
            return Some((ci as u32, q.req));
        }
    }

    /// Block for the first live request, then gather up to `max` total —
    /// all from the same config — until `max_wait` elapses. Returns the
    /// batch together with the config id it was formed for, or `None`
    /// once the queue is closed *and* drained — the dispatcher's exit
    /// condition.
    pub fn next_batch(&self, max: usize, max_wait: Duration) -> Option<(u32, Vec<Request>)> {
        let mut state = self.state.lock().unwrap();
        let (config, first) = loop {
            if let Some(hit) = self.pop_best_live(&mut state) {
                break hit;
            }
            if state.closed {
                return None;
            }
            state = self.cond.wait(state).unwrap();
        };
        let ci = config as usize;
        let formed_by = Instant::now() + max_wait;
        let mut batch = vec![first];
        while batch.len() < max {
            if let Some(req) = self.pop_live_for(&mut state, ci) {
                batch.push(req);
                continue;
            }
            if state.closed {
                break;
            }
            let now = Instant::now();
            if now >= formed_by {
                break;
            }
            let (guard, timeout) = self.cond.wait_timeout(state, formed_by - now).unwrap();
            state = guard;
            if timeout.timed_out() {
                // One final sweep for anything that raced the timeout.
                while batch.len() < max {
                    match self.pop_live_for(&mut state, ci) {
                        Some(req) => batch.push(req),
                        None => break,
                    }
                }
                break;
            }
        }
        Some((config, batch))
    }

    /// Admissions rejected because the queue was full.
    pub fn rejected(&self) -> usize {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Requests answered with a deadline error.
    pub fn expired(&self) -> usize {
        self.expired.load(Ordering::Relaxed)
    }

    /// Highest queue depth observed since startup.
    pub fn max_depth(&self) -> usize {
        self.state.lock().unwrap().max_depth
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(deadline: Option<Instant>) -> (Request, mpsc::Receiver<Result<Vec<f32>>>) {
        req_full(deadline, 0, 0)
    }

    fn req_full(
        deadline: Option<Instant>,
        priority: i32,
        config: u32,
    ) -> (Request, mpsc::Receiver<Result<Vec<f32>>>) {
        let (tx, rx) = mpsc::channel();
        let r = Request {
            x: HostTensor::f32(vec![0.0], vec![1, 1]),
            resp: tx,
            enqueued: Instant::now(),
            deadline,
            priority,
            config,
        };
        (r, rx)
    }

    #[test]
    fn admission_rejects_when_full() {
        let q = SubmitQueue::new(2);
        let (a, _ra) = req(None);
        let (b, _rb) = req(None);
        let (c, _rc) = req(None);
        q.push(a).unwrap();
        q.push(b).unwrap();
        let err = q.push(c).unwrap_err();
        assert!(format!("{err:#}").contains("queue full"), "{err:#}");
        assert_eq!(q.rejected(), 1);
        assert_eq!(q.max_depth(), 2);
    }

    #[test]
    fn push_after_close_errors_and_next_batch_drains() {
        let q = SubmitQueue::new(8);
        let (a, _ra) = req(None);
        let (b, _rb) = req(None);
        q.push(a).unwrap();
        q.push(b).unwrap();
        q.close();
        let (c, _rc) = req(None);
        assert!(format!("{:#}", q.push(c).unwrap_err()).contains("stopped"));
        // Queued-before-close requests still come out, then None.
        let (config, batch) = q.next_batch(8, Duration::from_millis(1)).unwrap();
        assert_eq!(config, 0);
        assert_eq!(batch.len(), 2);
        assert!(q.next_batch(8, Duration::from_millis(1)).is_none());
    }

    #[test]
    fn expired_requests_are_answered_not_batched() {
        let q = SubmitQueue::new(8);
        let past = Instant::now() - Duration::from_millis(5);
        let (a, ra) = req(Some(past));
        let (b, _rb) = req(None);
        q.push(a).unwrap();
        q.push(b).unwrap();
        let (_, batch) = q.next_batch(8, Duration::from_millis(1)).unwrap();
        assert_eq!(batch.len(), 1, "expired request must not occupy a slot");
        assert_eq!(q.expired(), 1);
        let answer = ra.recv().unwrap();
        assert!(format!("{:#}", answer.unwrap_err()).contains("deadline"));
    }

    #[test]
    fn next_batch_caps_at_max() {
        let q = SubmitQueue::new(16);
        let mut rxs = Vec::new();
        for _ in 0..5 {
            let (r, rx) = req(None);
            q.push(r).unwrap();
            rxs.push(rx);
        }
        let (_, batch) = q.next_batch(3, Duration::from_millis(1)).unwrap();
        assert_eq!(batch.len(), 3);
        let (_, batch) = q.next_batch(3, Duration::from_millis(1)).unwrap();
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn priority_pops_first_with_fifo_ties() {
        let q = SubmitQueue::new(16);
        // Tag each request's payload so pop order is observable.
        let push = |prio: i32, tag: f32| {
            let (mut r, rx) = req_full(None, prio, 0);
            r.x = HostTensor::f32(vec![tag], vec![1, 1]);
            q.push(r).unwrap();
            rx
        };
        let _rxs = [push(0, 1.0), push(5, 2.0), push(0, 3.0), push(5, 4.0), push(-1, 5.0)];
        let (_, batch) = q.next_batch(8, Duration::from_millis(1)).unwrap();
        let order: Vec<f32> = batch.iter().map(|r| r.x.f32_data().unwrap()[0]).collect();
        // Highest priority first; FIFO among equal priorities.
        assert_eq!(order, vec![2.0, 4.0, 1.0, 3.0, 5.0]);
    }

    #[test]
    fn batches_never_mix_configs() {
        let q = SubmitQueue::new(16);
        let mut rxs = Vec::new();
        for config in [0u32, 1, 0, 1, 1] {
            let (r, rx) = req_full(None, 0, config);
            q.push(r).unwrap();
            rxs.push(rx);
        }
        let (c0, b0) = q.next_batch(8, Duration::from_millis(1)).unwrap();
        let (c1, b1) = q.next_batch(8, Duration::from_millis(1)).unwrap();
        assert_ne!(c0, c1, "each call drains exactly one config");
        let (n0, n1) = if c0 == 0 { (b0.len(), b1.len()) } else { (b1.len(), b0.len()) };
        assert_eq!((n0, n1), (2, 3));
        assert!(b0.iter().all(|r| r.config == c0));
        assert!(b1.iter().all(|r| r.config == c1));
    }
}
