//! Metric-agreement report: how much the four sensitivity metrics agree,
//! and what the disagreement costs.
//!
//! One model runs through every informed metric (ε_QE, ε_N, Hessian,
//! inter-layer); the report renders pairwise rank correlation (Spearman
//! ρ with average ranks for ties) and Levenshtein distance between the
//! orderings, then both search algorithms under each ordering with the
//! final configuration, accuracy, cost, and evaluation deltas against
//! each algorithm's Hessian row — the paper's §4.1 agreement analysis
//! extended to the cross-layer metric.
//!
//! Everything the report serializes ([`AgreementReport::to_json`]) is
//! worker-count independent: sensitivities come from the sharded metric
//! drivers (or the shared synthetic stand-in) and search outcomes are
//! decision-exact at every worker count, so CI byte-diffs the RESULT
//! line across `--workers`.

use std::sync::Arc;

use crate::api::{run_search, synthetic_sensitivity, SyntheticCost, SyntheticEnv};
use crate::coordinator::{ParallelEnv, SearchAlgo};
use crate::quant::QUANT_BITS;
use crate::sensitivity::{self, MetricKind, Sensitivity};
use crate::util::json::Value;
use crate::Result;

use super::experiments::{run_cell, ExperimentCtx};

/// The informed metrics the agreement report compares, in render order.
pub const AGREEMENT_METRICS: [MetricKind; 4] =
    [MetricKind::Qe, MetricKind::Noise, MetricKind::Hessian, MetricKind::InterLayer];

/// Average 1-based ranks of `scores` (ties share the mean of the
/// positions they span).
fn average_ranks(scores: &[f64]) -> Vec<f64> {
    let n = scores.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| {
        scores[a].partial_cmp(&scores[b]).unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut ranks = vec![0.0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && scores[idx[j + 1]] == scores[idx[i]] {
            j += 1;
        }
        let mean_rank = (i + j) as f64 / 2.0 + 1.0;
        for &k in &idx[i..=j] {
            ranks[k] = mean_rank;
        }
        i = j + 1;
    }
    ranks
}

/// Spearman rank correlation between two score vectors of equal length,
/// with average ranks for ties. `1.0` for identical orderings, `-1.0`
/// for exactly inverted ones. Degenerate inputs (fewer than two layers,
/// or a constant vector) have no meaningful ordering: two constant
/// vectors agree perfectly (`1.0`), a constant against a varying one
/// carries no rank information (`0.0`).
pub fn rank_correlation(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "rank_correlation over mismatched score vectors");
    let n = a.len();
    if n < 2 {
        return 1.0;
    }
    let (ra, rb) = (average_ranks(a), average_ranks(b));
    let mean = (n as f64 + 1.0) / 2.0;
    let (mut cov, mut va, mut vb) = (0.0f64, 0.0f64, 0.0f64);
    for k in 0..n {
        let (da, db) = (ra[k] - mean, rb[k] - mean);
        cov += da * db;
        va += da * da;
        vb += db * db;
    }
    if va <= 0.0 || vb <= 0.0 {
        return if va <= 0.0 && vb <= 0.0 { 1.0 } else { 0.0 };
    }
    cov / (va.sqrt() * vb.sqrt())
}

/// Agreement between one pair of metrics.
#[derive(Debug, Clone)]
pub struct PairAgreement {
    pub a: MetricKind,
    pub b: MetricKind,
    /// Spearman ρ over the score vectors.
    pub rho: f64,
    /// Levenshtein distance between the induced orderings (§4.1).
    pub edit_distance: usize,
}

/// One (algorithm, metric) search outcome in the agreement grid.
#[derive(Debug, Clone)]
pub struct AgreementCell {
    pub algo: SearchAlgo,
    pub metric: MetricKind,
    pub accuracy: f64,
    pub rel_size: f64,
    pub rel_latency: f64,
    pub evals: usize,
    /// Final per-layer weight widths.
    pub bits: Vec<f32>,
}

/// The full report: orderings, pairwise agreement, and the search grid.
#[derive(Debug, Clone)]
pub struct AgreementReport {
    pub model: String,
    pub layers: usize,
    pub target: f64,
    pub seed: u64,
    pub trials: usize,
    /// One entry per metric in [`AGREEMENT_METRICS`] order.
    pub sensitivities: Vec<Sensitivity>,
    /// Upper-triangle metric pairs in [`AGREEMENT_METRICS`] order.
    pub pairs: Vec<PairAgreement>,
    /// (algo × metric) grid, algorithms outer, metrics inner.
    pub cells: Vec<AgreementCell>,
}

impl AgreementReport {
    /// Device-free report over the seeded synthetic model: metric
    /// orderings through [`synthetic_sensitivity`], searches over
    /// [`SyntheticEnv`]/[`SyntheticCost`]. Every serialized field is
    /// worker-count independent.
    pub fn synthetic(
        layers: usize,
        trials: usize,
        seed: u64,
        workers: usize,
        target: f64,
    ) -> Result<Self> {
        let sensitivities: Vec<Sensitivity> = AGREEMENT_METRICS
            .iter()
            .map(|&mk| synthetic_sensitivity(mk, layers, trials, seed, workers))
            .collect::<Result<_>>()?;
        let cost = Arc::new(SyntheticCost::new(layers, seed));
        let mut cells = Vec::new();
        for algo in [SearchAlgo::Bisection, SearchAlgo::Greedy] {
            for sens in &sensitivities {
                // Fresh env per cell so eval counters never leak across
                // cells; the synthetic float baseline is exactly 1.0, so
                // the floor is the target itself.
                let env = SyntheticEnv::new(layers, seed);
                let objective =
                    crate::api::ObjectiveSpec::AccuracyTarget.build(target, cost.clone());
                let mut penv = ParallelEnv::new(&env, workers);
                let outcome = run_search(
                    algo,
                    &mut penv,
                    &sens.order,
                    &QUANT_BITS,
                    objective.as_ref(),
                    None,
                    None,
                )?;
                cells.push(AgreementCell {
                    algo,
                    metric: sens.metric,
                    accuracy: outcome.accuracy,
                    rel_size: cost.rel_size(&outcome.config),
                    rel_latency: cost.rel_latency(&outcome.config),
                    evals: outcome.evals,
                    bits: outcome.config.bits_w.clone(),
                });
            }
        }
        Ok(Self::assemble("synthetic".into(), layers, target, seed, trials, sensitivities, cells))
    }

    /// Artifact-backed report: metrics through the context's disk-cached
    /// sensitivity path, searches through [`run_cell`] (pool-fanned at
    /// `workers > 1`, decision-exact at every worker count).
    pub fn for_model(
        ctx: &mut ExperimentCtx,
        trials: usize,
        seed: u64,
        target: f64,
    ) -> Result<Self> {
        ctx.ensure_calibrated()?;
        let sensitivities: Vec<Sensitivity> = AGREEMENT_METRICS
            .iter()
            .map(|&mk| ctx.cached_sensitivity(mk, trials, seed))
            .collect::<Result<_>>()?;
        let mut cells = Vec::new();
        for algo in [SearchAlgo::Bisection, SearchAlgo::Greedy] {
            for sens in &sensitivities {
                let cell = run_cell(ctx, algo, sens, seed, target)?;
                cells.push(AgreementCell {
                    algo,
                    metric: sens.metric,
                    accuracy: cell.accuracy,
                    rel_size: cell.rel_size_pct / 100.0,
                    rel_latency: cell.rel_latency_pct / 100.0,
                    evals: cell.evals,
                    bits: cell.config.bits_w.clone(),
                });
            }
        }
        let (model, layers) = (ctx.model(), ctx.pipeline.num_quant_layers());
        Ok(Self::assemble(model, layers, target, seed, trials, sensitivities, cells))
    }

    fn assemble(
        model: String,
        layers: usize,
        target: f64,
        seed: u64,
        trials: usize,
        sensitivities: Vec<Sensitivity>,
        cells: Vec<AgreementCell>,
    ) -> Self {
        let mut pairs = Vec::new();
        for i in 0..sensitivities.len() {
            for j in (i + 1)..sensitivities.len() {
                let (a, b) = (&sensitivities[i], &sensitivities[j]);
                pairs.push(PairAgreement {
                    a: a.metric,
                    b: b.metric,
                    rho: rank_correlation(&a.scores, &b.scores),
                    edit_distance: sensitivity::levenshtein(&a.order, &b.order),
                });
            }
        }
        Self { model, layers, target, seed, trials, sensitivities, pairs, cells }
    }

    /// The metric pair with the lowest rank correlation — the pair whose
    /// disagreement most deserves a look at the per-algorithm deltas.
    pub fn lowest_agreement(&self) -> Option<&PairAgreement> {
        self.pairs.iter().min_by(|x, y| {
            x.rho.partial_cmp(&y.rho).unwrap_or(std::cmp::Ordering::Equal)
        })
    }

    /// The baseline cell deltas are taken against: the same algorithm's
    /// Hessian row (the paper's best-performing single-layer metric).
    fn baseline(&self, algo: SearchAlgo) -> Option<&AgreementCell> {
        self.cells.iter().find(|c| c.algo == algo && c.metric == MetricKind::Hessian)
    }

    /// Human-readable rendering (stderr/stdout; the machine line is
    /// [`AgreementReport::to_json`] under the RESULT envelope).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "Metric agreement — {} ({} layers, target {:.1}%, seed {}, {} trials)\n",
            self.model,
            self.layers,
            self.target * 100.0,
            self.seed,
            self.trials,
        ));
        out.push_str("\npairwise agreement (Spearman rho / edit distance):\n");
        for p in &self.pairs {
            out.push_str(&format!(
                "  {:>10} vs {:<10}  rho={:+.3}  edit={}/{}\n",
                p.a.label(),
                p.b.label(),
                p.rho,
                p.edit_distance,
                self.layers,
            ));
        }
        if let Some(p) = self.lowest_agreement() {
            out.push_str(&format!(
                "lowest agreement: {} vs {} (rho={:+.3})\n",
                p.a.label(),
                p.b.label(),
                p.rho,
            ));
        }
        out.push_str("\nsearch grid (deltas vs the same algorithm's Hessian row):\n");
        for c in &self.cells {
            let base = self.baseline(c.algo);
            let delta = |v: f64, b: f64| format!("{:+.4}", v - b);
            let (da, ds, dl, de) = match base {
                Some(b) => (
                    delta(c.accuracy, b.accuracy),
                    delta(c.rel_size, b.rel_size),
                    delta(c.rel_latency, b.rel_latency),
                    format!("{:+}", c.evals as i64 - b.evals as i64),
                ),
                None => ("-".into(), "-".into(), "-".into(), "-".into()),
            };
            out.push_str(&format!(
                "  {:>9}/{:<10} acc={:.4} ({da})  size={:.4} ({ds})  \
                 latency={:.4} ({dl})  evals={} ({de})\n",
                c.algo.label(),
                c.metric.label(),
                c.accuracy,
                c.rel_size,
                c.rel_latency,
                c.evals,
            ));
        }
        out
    }

    /// The worker-count-independent machine payload (keys serialize
    /// sorted; CI byte-diffs this across worker counts).
    pub fn to_json(&self) -> Value {
        let metrics = Value::Arr(
            self.sensitivities
                .iter()
                .map(|s| {
                    Value::obj(vec![
                        ("metric", Value::Str(s.metric.label().to_string())),
                        (
                            "order",
                            Value::Arr(s.order.iter().map(|&l| Value::Num(l as f64)).collect()),
                        ),
                        ("scores", Value::Arr(s.scores.iter().map(|&v| Value::Num(v)).collect())),
                    ])
                })
                .collect(),
        );
        let pairs = Value::Arr(
            self.pairs
                .iter()
                .map(|p| {
                    Value::obj(vec![
                        ("a", Value::Str(p.a.label().to_string())),
                        ("b", Value::Str(p.b.label().to_string())),
                        ("edit_distance", Value::Num(p.edit_distance as f64)),
                        ("rho", Value::Num(p.rho)),
                    ])
                })
                .collect(),
        );
        let cells = Value::Arr(
            self.cells
                .iter()
                .map(|c| {
                    let base = self.baseline(c.algo);
                    let mut fields = vec![
                        ("accuracy", Value::Num(c.accuracy)),
                        ("algo", Value::Str(c.algo.label().to_string())),
                        ("bits", Value::arr_f32(&c.bits)),
                        ("evals", Value::Num(c.evals as f64)),
                        ("metric", Value::Str(c.metric.label().to_string())),
                        ("rel_latency", Value::Num(c.rel_latency)),
                        ("rel_size", Value::Num(c.rel_size)),
                    ];
                    if let Some(b) = base {
                        fields.push(("d_accuracy", Value::Num(c.accuracy - b.accuracy)));
                        fields.push(("d_evals", Value::Num(c.evals as f64 - b.evals as f64)));
                        fields.push(("d_rel_latency", Value::Num(c.rel_latency - b.rel_latency)));
                        fields.push(("d_rel_size", Value::Num(c.rel_size - b.rel_size)));
                    }
                    Value::obj(fields)
                })
                .collect(),
        );
        let mut fields = vec![
            ("cells", cells),
            ("layers", Value::Num(self.layers as f64)),
            ("metrics", metrics),
            ("model", Value::Str(self.model.clone())),
            ("pairs", pairs),
            ("seed", Value::Num(self.seed as f64)),
            ("target", Value::Num(self.target)),
            ("trials", Value::Num(self.trials as f64)),
        ];
        if let Some(p) = self.lowest_agreement() {
            fields.push((
                "lowest_agreement",
                Value::obj(vec![
                    ("a", Value::Str(p.a.label().to_string())),
                    ("b", Value::Str(p.b.label().to_string())),
                    ("rho", Value::Num(p.rho)),
                ]),
            ));
        }
        Value::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_correlation_perfect_inverted_and_uncorrelated() {
        let a = [1.0, 2.0, 3.0, 4.0];
        assert!((rank_correlation(&a, &a) - 1.0).abs() < 1e-12);
        let inv = [4.0, 3.0, 2.0, 1.0];
        assert!((rank_correlation(&a, &inv) + 1.0).abs() < 1e-12);
        // Monotone transforms preserve ranks exactly.
        let exp = [0.1, 10.0, 11.0, 1e6];
        assert!((rank_correlation(&a, &exp) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rank_correlation_handles_ties_and_degenerates() {
        // Ties share average ranks: identical tie structure still agrees
        // perfectly.
        let t = [1.0, 2.0, 2.0, 3.0];
        assert!((rank_correlation(&t, &t) - 1.0).abs() < 1e-12);
        // A tie against distinct values lowers but does not destroy
        // agreement.
        let d = [1.0, 2.0, 3.0, 4.0];
        let rho = rank_correlation(&t, &d);
        assert!(rho > 0.9 && rho < 1.0, "rho={rho}");
        // Constant vectors: no ordering information.
        let c = [5.0, 5.0, 5.0, 5.0];
        assert!((rank_correlation(&c, &c) - 1.0).abs() < 1e-12);
        assert_eq!(rank_correlation(&c, &d), 0.0);
        // Short vectors trivially agree.
        assert_eq!(rank_correlation(&[1.0], &[9.0]), 1.0);
    }

    #[test]
    fn average_ranks_spread_ties() {
        assert_eq!(average_ranks(&[10.0, 20.0, 30.0]), vec![1.0, 2.0, 3.0]);
        assert_eq!(average_ranks(&[10.0, 20.0, 20.0, 30.0]), vec![1.0, 2.5, 2.5, 4.0]);
        assert_eq!(average_ranks(&[7.0, 7.0]), vec![1.5, 1.5]);
    }

    #[test]
    fn synthetic_report_covers_the_full_grid() {
        let r = AgreementReport::synthetic(8, 2, 5, 1, 0.9).unwrap();
        assert_eq!(r.sensitivities.len(), AGREEMENT_METRICS.len());
        // C(4, 2) metric pairs, 2 algorithms x 4 metrics cells.
        assert_eq!(r.pairs.len(), 6);
        assert_eq!(r.cells.len(), 8);
        let low = r.lowest_agreement().unwrap();
        assert!(r.pairs.iter().all(|p| p.rho >= low.rho));
        // The render names the lowest-agreement pair.
        let text = r.render();
        assert!(text.contains("lowest agreement:"), "{text}");
        assert!(
            text.contains(&format!("{} vs {}", low.a.label(), low.b.label())),
            "{text}"
        );
    }
}
