//! Experiment drivers — one per paper table/figure (DESIGN.md §5).
//!
//! Contexts are constructed through the [`crate::api`] front door:
//! [`ExperimentCtx`] is a re-export of [`crate::api::ModelContext`], so
//! every table/figure run shares the CLI's spec-driven pipeline, cost
//! backend, and eval-cache wiring — and, when the spec asks for
//! `workers > 1`, the context's shared pipeline pool: calibration,
//! Hessian orderings, and every grid cell's evaluations all fan across
//! it (`mpq table --workers N`).

use std::time::Instant;

use crate::api::run_search;
use crate::coordinator::{SearchAlgo, SearchEnv};
use crate::quant::{QuantConfig, FLOAT_BITS, QUANT_BITS};
use crate::report::{aggregate, CellResult, Table};
use crate::sensitivity::{self, MetricKind, Sensitivity};
use crate::Result;

use super::table::fmt_pct;

/// The model context every experiment drives (pipeline + cost model +
/// calibration state), re-exported under its historical name.
pub use crate::api::ModelContext as ExperimentCtx;

/// Seeds used for the Random (uninformed) baseline — 5 trials, as in the
/// paper's Tables 2/3.
pub const RANDOM_SEEDS: [u64; 5] = [11, 22, 33, 44, 55];

/// Hutchinson / noise trials used by the metric computations.
pub const METRIC_TRIALS: usize = crate::api::DEFAULT_TRIALS;

/// Run one search cell: sensitivity ordering + algorithm + accuracy
/// target, under the context's configured objective (the paper's plain
/// accuracy floor by default, a latency/footprint budget when the spec
/// asks). The context itself is the evaluation environment, so with
/// `workers > 1` the cell's frontier fans across the shared pipeline
/// pool.
pub fn run_cell(
    ctx: &mut ExperimentCtx,
    algo: SearchAlgo,
    sens: &Sensitivity,
    seed: u64,
    target_frac: f64,
) -> Result<CellResult> {
    ctx.ensure_calibrated()?;
    let floor = target_frac * ctx.pipeline.float_val_acc();
    let objective = ctx.objective.build(floor, ctx.cost.clone());
    let t0 = Instant::now();
    let outcome =
        run_search(algo, ctx, &sens.order, &QUANT_BITS, objective.as_ref(), None, None)?;
    let search_seconds = t0.elapsed().as_secs_f64();
    Ok(CellResult {
        model: ctx.model(),
        algo,
        metric: sens.metric,
        seed,
        target_frac,
        rel_size_pct: ctx.cost.rel_size(&outcome.config) * 100.0,
        rel_latency_pct: ctx.cost.rel_latency(&outcome.config) * 100.0,
        cost_provenance: ctx.cost.provenance().to_string(),
        accuracy: outcome.accuracy,
        met_target: outcome.accuracy >= floor,
        evals: outcome.evals,
        search_seconds,
        config: outcome.config,
    })
}

// ------------------------------------------------------------------ Table 1

/// Table 1: uniform 4/8/16-bit accuracy, size, latency (absolute+relative).
/// The three uniform configurations are submitted as one `eval_many`
/// frontier (deduped/parallelized by the environment) instead of three
/// sequential round-trips.
pub fn table1(ctx: &mut ExperimentCtx) -> Result<Table> {
    ctx.ensure_calibrated()?;
    let n = ctx.pipeline.num_quant_layers();
    let mut t = Table::new(
        format!("Table 1 — uniform quantization baselines ({})", ctx.model()),
        &["bits", "accuracy", "rel acc", "size (MB)", "rel size", "latency (ms)", "rel latency"],
    );
    let all_bits = [4.0f32, 8.0, FLOAT_BITS];
    let cfgs: Vec<QuantConfig> = all_bits.iter().map(|&b| QuantConfig::uniform(n, b)).collect();
    // The context env routes through the pool when one exists.
    let results: Vec<crate::coordinator::EvalResult> =
        ctx.eval_many(&cfgs, None).into_iter().collect::<Result<_>>()?;
    // fp16 is the relative-accuracy baseline (== QuantConfig::float).
    let base_acc = results[all_bits.len() - 1].accuracy;
    for ((bits, cfg), r) in all_bits.iter().zip(&cfgs).zip(&results) {
        let size_mb = ctx.cost.size_bytes(cfg) / 1e6;
        let lat_ms = ctx.cost.latency_s(cfg) * 1e3;
        t.push_row(vec![
            format!("{}", *bits as u32),
            format!("{:.2}%", r.accuracy * 100.0),
            fmt_pct(r.accuracy / base_acc),
            format!("{size_mb:.3}"),
            fmt_pct(ctx.cost.rel_size(cfg)),
            format!("{lat_ms:.4}"),
            fmt_pct(ctx.cost.rel_latency(cfg)),
        ]);
    }
    Ok(t)
}

// -------------------------------------------------------------- Tables 2/3

/// The full search grid of Table 2 (targets 99%, 99.9%) or Table 3 (90%):
/// {bisection, greedy} × {Random×5, Hessian, Noise, QE} × targets.
pub fn search_grid(
    ctx: &mut ExperimentCtx,
    targets: &[f64],
    seed: u64,
) -> Result<Vec<CellResult>> {
    ctx.ensure_calibrated()?;
    let mut cells = Vec::new();
    // Compute informed metrics once; they are target/algo independent (and
    // disk-cached across invocations).
    let informed: Vec<Sensitivity> = [MetricKind::Hessian, MetricKind::Noise, MetricKind::Qe]
        .iter()
        .map(|&mk| ctx.cached_sensitivity(mk, METRIC_TRIALS, seed))
        .collect::<Result<_>>()?;
    let randoms: Vec<Sensitivity> = RANDOM_SEEDS
        .iter()
        .map(|&s| Sensitivity::random(ctx.pipeline.num_quant_layers(), s))
        .collect();
    for &target in targets {
        for algo in [SearchAlgo::Bisection, SearchAlgo::Greedy] {
            for (rs, sens) in RANDOM_SEEDS.iter().zip(&randoms) {
                eprintln!(
                    "[grid] {} target={target} algo={} metric=Random seed={rs}",
                    ctx.model(),
                    algo.label()
                );
                cells.push(run_cell(ctx, algo, sens, *rs, target)?);
            }
            for sens in &informed {
                eprintln!(
                    "[grid] {} target={target} algo={} metric={}",
                    ctx.model(),
                    algo.label(),
                    sens.metric.label()
                );
                cells.push(run_cell(ctx, algo, sens, seed, target)?);
            }
        }
    }
    Ok(cells)
}

/// Render the Table 2/3 layout: rows per (search, metric), columns per
/// (target: size, latency), Random aggregated mean ± σ.
pub fn render_search_table(title: &str, cells: &[CellResult], targets: &[f64]) -> Table {
    let mut headers: Vec<String> = vec!["search".into(), "metric".into()];
    for t in targets {
        headers.push(format!("{}% size", t * 100.0));
        headers.push(format!("{}% latency", t * 100.0));
    }
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(title, &hdr_refs);
    for algo in [SearchAlgo::Bisection, SearchAlgo::Greedy] {
        for metric in [MetricKind::Random, MetricKind::Hessian, MetricKind::Noise, MetricKind::Qe]
        {
            let mut row = vec![algo.label().to_string(), metric.label().to_string()];
            let mut sigma_row = vec![String::new(), "±σ".into()];
            let mut any_sigma = false;
            for &t in targets {
                let sel: Vec<&CellResult> = cells
                    .iter()
                    .filter(|c| c.algo == algo && c.metric == metric && c.target_frac == t)
                    .collect();
                if sel.is_empty() {
                    row.push("-".into());
                    row.push("-".into());
                    sigma_row.push("-".into());
                    sigma_row.push("-".into());
                    continue;
                }
                let (ms, ss, ml, sl) = aggregate(&sel);
                row.push(format!("{ms:.2}%"));
                row.push(format!("{ml:.2}%"));
                if sel.len() > 1 {
                    any_sigma = true;
                    sigma_row.push(format!("{ss:.2}%"));
                    sigma_row.push(format!("{sl:.2}%"));
                } else {
                    sigma_row.push(String::new());
                    sigma_row.push(String::new());
                }
            }
            table.push_row(row);
            if any_sigma {
                table.push_row(sigma_row);
            }
        }
    }
    table
}

// ------------------------------------------------------------------ Fig. 1

/// Prior-work anchor points from Fig. 1 (approximate digitization; letters
/// as in the paper). Tuples: (label, rel accuracy %, rel size %).
pub const FIG1_PRIOR: [(&str, f64, f64); 6] = [
    ("a Hubara'21", 98.8, 25.0),
    ("b Nahshan'21", 96.0, 25.0),
    ("c Nagel'20", 97.5, 25.0),
    ("d Wu'20", 98.9, 50.0),
    ("e Shen'20", 98.5, 30.0),
    ("f Jeon'22", 97.8, 25.0),
];

/// Fig. 1 data: ours (best cells per target) vs prior-work anchors.
pub fn fig1(cells: &[CellResult], float_acc_by_model: &[(String, f64)]) -> Table {
    let mut t = Table::new(
        "Figure 1 — relative accuracy vs relative model size (ours + prior work)",
        &["series", "model", "rel acc", "rel size", "rel latency"],
    );
    for c in cells {
        let float_acc = float_acc_by_model
            .iter()
            .find(|(m, _)| *m == c.model)
            .map(|(_, a)| *a)
            .unwrap_or(1.0);
        t.push_row(vec![
            format!("ours {}/{} @{}", c.algo.label(), c.metric.label(), c.target_frac),
            c.model.clone(),
            fmt_pct(c.accuracy / float_acc),
            format!("{:.2}%", c.rel_size_pct),
            format!("{:.2}%", c.rel_latency_pct),
        ]);
    }
    for (label, acc, size) in FIG1_PRIOR {
        t.push_row(vec![
            format!("prior {label}"),
            "resnet50/bert (paper)".into(),
            format!("{acc:.2}%"),
            format!("{size:.2}%"),
            "-".into(),
        ]);
    }
    t
}

// ------------------------------------------------------------------ Fig. 3

/// Fig. 3 data: per-layer bit allocations of selected configurations.
pub fn fig3(cells: &[CellResult], layer_names: &[String]) -> Table {
    let mut headers = vec!["layer".to_string()];
    for c in cells {
        headers.push(format!("{}/{}@{}", c.algo.label(), c.metric.label(), c.target_frac));
    }
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Figure 3 — per-layer bit-width allocation", &hdr_refs);
    for (i, name) in layer_names.iter().enumerate() {
        let mut row = vec![name.clone()];
        for c in cells {
            row.push(format!("{}", c.config.layer_bits(i) as u32));
        }
        t.push_row(row);
    }
    t
}

// ------------------------------------------------------------------ Fig. 4

/// Fig. 4 data: per-layer sensitivity mean ± σ over `trials` runs of each
/// metric, plus the pairwise Levenshtein distances between orderings.
pub fn fig4(ctx: &mut ExperimentCtx, trials: usize) -> Result<(Table, Table)> {
    ctx.ensure_calibrated()?;
    let metrics = [MetricKind::Qe, MetricKind::Noise, MetricKind::Hessian];
    let n = ctx.pipeline.num_quant_layers();
    let mut all: Vec<(MetricKind, Vec<Sensitivity>)> = Vec::new();
    for &mk in &metrics {
        let runs: Vec<Sensitivity> = (0..trials)
            .map(|t| sensitivity::compute(&mut ctx.pipeline, mk, METRIC_TRIALS, 1000 + t as u64))
            .collect::<Result<_>>()?;
        all.push((mk, runs));
    }
    let layer_names: Vec<String> = ctx
        .pipeline
        .artifacts
        .manifest
        .quant_layers()
        .iter()
        .map(|l| l.name.clone())
        .collect();

    let mut headers = vec!["layer".to_string()];
    for &mk in &metrics {
        headers.push(format!("{} mean", mk.label()));
        headers.push(format!("{} σ", mk.label()));
    }
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut curves = Table::new(
        format!("Figure 4 — sensitivity metrics per layer ({}, {trials} trials)", ctx.model()),
        &hdr_refs,
    );
    for i in 0..n {
        let mut row = vec![layer_names[i].clone()];
        for (_, runs) in &all {
            let vals: Vec<f64> = runs.iter().map(|r| r.scores[i]).collect();
            let mean = vals.iter().sum::<f64>() / vals.len() as f64;
            let var = vals.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / vals.len() as f64;
            row.push(format!("{mean:.3e}"));
            row.push(format!("{:.3e}", var.sqrt()));
        }
        curves.push_row(row);
    }

    let mut dist = Table::new(
        "Figure 4 (inset) — Levenshtein distance between metric orderings",
        &["pair", "distance", "max"],
    );
    for i in 0..all.len() {
        for j in (i + 1)..all.len() {
            let d = sensitivity::levenshtein(&all[i].1[0].order, &all[j].1[0].order);
            dist.push_row(vec![
                format!("{} vs {}", all[i].0.label(), all[j].0.label()),
                d.to_string(),
                n.to_string(),
            ]);
        }
    }
    Ok((curves, dist))
}
