//! Fixed-width ASCII + CSV table rendering.

/// A simple row-major table with a title, rendered for terminals and CSV.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push_row(&mut self, row: Vec<String>) {
        debug_assert_eq!(row.len(), self.headers.len());
        self.rows.push(row);
    }

    /// Render as an aligned ASCII table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let sep: String = {
            let total: usize = widths.iter().sum::<usize>() + 3 * widths.len() + 1;
            "-".repeat(total)
        };
        let mut out = String::new();
        out.push_str(&format!("{}\n{sep}\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (cell, w) in cells.iter().zip(&widths) {
                line.push_str(&format!(" {cell:>w$} |"));
            }
            line
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// Render as CSV (headers first; naive quoting — cells contain no commas).
    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// `fmt_pct(0.4974) == "49.74%"`.
pub fn fmt_pct(frac: f64) -> String {
    format!("{:.2}%", frac * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_and_csv() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.push_row(vec!["1".into(), "2".into()]);
        t.push_row(vec!["333".into(), "4".into()]);
        let r = t.render();
        assert!(r.contains("| 333 |  4 |"));
        assert_eq!(t.to_csv(), "a,bb\n1,2\n333,4\n");
    }

    #[test]
    fn pct() {
        assert_eq!(fmt_pct(0.4974), "49.74%");
    }
}
